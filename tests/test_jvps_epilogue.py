"""In-kernel jvp-contraction epilogues (ISSUE 4).

Covers: the ``*_mt_jvps`` epilogue kernels against their
materialize-then-contract jnp oracles (allclose at fp32-accumulator
precision, and BITWISE equality of T stacked tangents vs T single-tangent
epilogue passes — each lane runs the exact op sequence of the T=1 slice);
the dispatch cotangent-known route — vmap of ``*_jvp_contract`` tangents
inside ``forward_ad_region()`` must trace ONE ``_jvps`` pallas_call whose
outputs are per-block partials, with NO (K, ..., N) tangent output buffer
anywhere in the jaxpr; and the estimator-level fused route
(``SplitLoss`` + ``forward_gradient(fused_contraction=True)``) against the
standard materializing route, including the padded chunked scan.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    assert_no_tangent_stack,
    family_pallas_calls,
    kernel_src,
    pallas_calls,
    tangent_stack_outputs,
)
from repro.core.forward_grad import (
    SplitLoss,
    forward_gradient,
    fused_linearize,
)
from repro.kernels import dispatch
from repro.kernels.lora_dual import lora_dual_mt_jvps, lora_dual_mt_jvps_ref
from repro.kernels.mamba2_scan import (
    mamba2_scan_mt_jvps,
    mamba2_scan_mt_jvps_ref,
)
from repro.kernels.swa_attention import (
    swa_attention_mt_jvps,
    swa_attention_mt_jvps_ref,
)
from repro.kernels.wkv6_scan import wkv6_scan_mt_jvps, wkv6_scan_mt_jvps_ref


def _lora_problem(M=8, K=48, N=40, r=2, T=5, seed=0, scale=2.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    x = jax.random.normal(ks[0], (M, K))
    w = jax.random.normal(ks[1], (K, N)) * 0.05
    a = jax.random.normal(ks[2], (K, r)) * 0.05
    b = jax.random.normal(ks[3], (r, N)) * 0.05
    ad = jax.random.normal(ks[4], (T, K, r)) * 0.05
    bd = jax.random.normal(ks[5], (T, r, N)) * 0.05
    xd = jax.random.normal(ks[6], (T, M, K)) * 0.3
    gy = jax.random.normal(ks[7], (M, N))
    return (x, w, a, b), (xd, ad, bd), gy, scale


def _wkv_problem(B=2, S=96, H=2, hd=16, T=3, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 11)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, hd)) * 0.3
               for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    rd, kd, vd = (jax.random.normal(ks[5 + i], (T, B, S, H, hd)) * 0.3
                  for i in range(3))
    wd = jax.random.normal(ks[8], (T, B, S, H, hd)) * 0.1
    ud = jax.random.normal(ks[9], (T, H, hd)) * 0.3
    gy = jax.random.normal(ks[10], (B, S, H, hd))
    return (r, k, v, w, u), (rd, kd, vd, wd, ud), gy


def _mamba2_problem(B=2, S=96, H=2, hd=16, N=8, T=3, seed=4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 9)
    xdt = jax.random.normal(ks[0], (B, S, H, hd)) * 0.3
    bm = jax.random.normal(ks[1], (B, S, N)) * 0.3
    cm = jax.random.normal(ks[2], (B, S, N)) * 0.3
    dec = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H)))
    xd = jax.random.normal(ks[4], (T, B, S, H, hd)) * 0.3
    bd = jax.random.normal(ks[5], (T, B, S, N)) * 0.3
    cd = jax.random.normal(ks[6], (T, B, S, N)) * 0.3
    dd = jax.random.normal(ks[7], (T, B, S, H)) * 0.1
    gy = jax.random.normal(ks[8], (B, S, H, hd))
    return (xdt, bm, cm, dec), (xd, bd, cd, dd), gy


def _swa_problem(B=1, H=4, KV=2, S=128, hd=32, T=3, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    qd = jax.random.normal(ks[3], (T, B, H, S, hd))
    kd = jax.random.normal(ks[4], (T, B, KV, S, hd))
    vd = jax.random.normal(ks[5], (T, B, KV, S, hd))
    gy = jax.random.normal(ks[6], (B, H, S, hd))
    return (q, k, v), (qd, kd, vd), gy


def _rel(a, b):
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-12))


# ---------------------------------------------------------------------------
# lora epilogue kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("has_xd", [True, False])
def test_lora_jvps_kernel_matches_oracle(has_xd):
    (x, w, a, b), (xd, ad, bd), gy, scale = _lora_problem()
    xd = xd if has_xd else None
    jk = lora_dual_mt_jvps(x, w, a, ad, b, bd, gy, scale=scale, xdots=xd,
                           impl="kernel")
    jo = lora_dual_mt_jvps_ref(x, w, a, ad, b, bd, gy, scale, xdots=xd)
    np.testing.assert_allclose(np.asarray(jk), np.asarray(jo), rtol=2e-5,
                               atol=1e-6)
    # and against the reassociated jnp mirror (the dispatch 'jnp' route)
    jr = lora_dual_mt_jvps(x, w, a, ad, b, bd, gy, scale=scale, xdots=xd,
                           impl="reassoc")
    np.testing.assert_allclose(np.asarray(jk), np.asarray(jr), rtol=2e-5,
                               atol=1e-6)


def test_lora_jvps_kernel_multiblock():
    """Shapes spanning several (bm, bn, bk) tiles exercise the blockwise
    partial accumulation + host-side partial sum."""
    (x, w, a, b), (xd, ad, bd), gy, scale = _lora_problem(
        M=200, K=130, N=70, r=4, T=3, seed=3)
    jk = lora_dual_mt_jvps(x, w, a, ad, b, bd, gy, scale=scale, xdots=xd,
                           impl="kernel", block_m=64, block_n=64, block_k=64)
    jo = lora_dual_mt_jvps_ref(x, w, a, ad, b, bd, gy, scale, xdots=xd)
    np.testing.assert_allclose(np.asarray(jk), np.asarray(jo), rtol=2e-5,
                               atol=1e-6)


def test_lora_jvps_stacked_bitwise_equals_single_tangent_passes():
    """Each tangent lane of the epilogue runs the exact T=1 op sequence on
    independent accumulator rows — stacked partials are BITWISE equal to T
    single-tangent epilogue passes."""
    (x, w, a, b), (xd, ad, bd), gy, scale = _lora_problem()
    T = ad.shape[0]
    jk = lora_dual_mt_jvps(x, w, a, ad, b, bd, gy, scale=scale, xdots=xd,
                           impl="kernel")
    ones = jnp.concatenate([
        lora_dual_mt_jvps(x, w, a, ad[t:t + 1], b, bd[t:t + 1], gy,
                          scale=scale, xdots=xd[t:t + 1], impl="kernel")
        for t in range(T)])
    np.testing.assert_array_equal(np.asarray(jk), np.asarray(ones))


# ---------------------------------------------------------------------------
# wkv6 epilogue kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_ud,S", [(True, 96), (False, 96), (True, 75)])
def test_wkv6_jvps_kernel_matches_oracle(with_ud, S):
    (r, k, v, w, u), (rd, kd, vd, wd, ud), gy = _wkv_problem(S=S)
    uds = ud if with_ud else None
    jk = wkv6_scan_mt_jvps(r, k, v, w, u, rd, kd, vd, wd, gy, uds,
                           block_s=32)
    jo = wkv6_scan_mt_jvps_ref(r, k, v, w, u, rd, kd, vd, wd, gy, uds)
    np.testing.assert_allclose(np.asarray(jk), np.asarray(jo), rtol=2e-5,
                               atol=1e-5)


def test_wkv6_jvps_stacked_bitwise_equals_single_tangent_passes():
    (r, k, v, w, u), (rd, kd, vd, wd, ud), gy = _wkv_problem()
    T = rd.shape[0]
    jk = wkv6_scan_mt_jvps(r, k, v, w, u, rd, kd, vd, wd, gy, ud, block_s=32)
    ones = jnp.concatenate([
        wkv6_scan_mt_jvps(r, k, v, w, u, rd[t:t + 1], kd[t:t + 1],
                          vd[t:t + 1], wd[t:t + 1], gy, ud[t:t + 1],
                          block_s=32)
        for t in range(T)])
    np.testing.assert_array_equal(np.asarray(jk), np.asarray(ones))


# ---------------------------------------------------------------------------
# mamba2 epilogue kernel (ISSUE 5 satellite: the last mt family without one)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S", [96, 75])
def test_mamba2_jvps_kernel_matches_oracle(S):
    (xdt, bm, cm, dec), (xd, bd, cd, dd), gy = _mamba2_problem(S=S)
    jk = mamba2_scan_mt_jvps(xdt, bm, cm, dec, xd, bd, cd, dd, gy,
                             block_s=32)
    jo = mamba2_scan_mt_jvps_ref(xdt, bm, cm, dec, xd, bd, cd, dd, gy)
    np.testing.assert_allclose(np.asarray(jk), np.asarray(jo), rtol=2e-5,
                               atol=1e-5)


def test_mamba2_jvps_stacked_bitwise_equals_single_tangent_passes():
    (xdt, bm, cm, dec), (xd, bd, cd, dd), gy = _mamba2_problem()
    T = xd.shape[0]
    jk = mamba2_scan_mt_jvps(xdt, bm, cm, dec, xd, bd, cd, dd, gy,
                             block_s=32)
    ones = jnp.concatenate([
        mamba2_scan_mt_jvps(xdt, bm, cm, dec, xd[t:t + 1], bd[t:t + 1],
                            cd[t:t + 1], dd[t:t + 1], gy, block_s=32)
        for t in range(T)])
    np.testing.assert_array_equal(np.asarray(jk), np.asarray(ones))


def test_mamba2_contract_jnp_route_matches_oracle():
    (xdt, bm, cm, dec), (xd, bd, cd, dd), gy = _mamba2_problem()
    jo = mamba2_scan_mt_jvps_ref(xdt, bm, cm, dec, xd, bd, cd, dd, gy)
    dispatch.set_backend("jnp")
    try:
        vals = jax.vmap(lambda a, b, c, d: dispatch.mamba2_jvp_contract(
            gy, xdt, bm, cm, dec, a, b, c, d))(xd, bd, cd, dd)
    finally:
        dispatch.set_backend(None)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(jo), rtol=2e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# swa epilogue kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,S,force_pad",
                         [(48, 128, False), (None, 128, False),
                          (48, 100, False), (48, 128, True)])
def test_swa_jvps_kernel_matches_oracle(window, S, force_pad):
    (q, k, v), (qd, kd, vd), gy = _swa_problem(S=S)
    jk = swa_attention_mt_jvps(q, k, v, qd, kd, vd, gy, window=window,
                               block_q=64, block_k=64,
                               force_pad_hd=force_pad)
    jo = swa_attention_mt_jvps_ref(q, k, v, qd, kd, vd, gy, window=window)
    np.testing.assert_allclose(np.asarray(jk), np.asarray(jo), rtol=2e-4,
                               atol=1e-4)


def test_swa_jvps_stacked_bitwise_equals_single_tangent_passes():
    (q, k, v), (qd, kd, vd), gy = _swa_problem()
    T = qd.shape[0]
    jk = swa_attention_mt_jvps(q, k, v, qd, kd, vd, gy, window=48,
                               block_q=64, block_k=64)
    ones = jnp.concatenate([
        swa_attention_mt_jvps(q, k, v, qd[t:t + 1], kd[t:t + 1],
                              vd[t:t + 1], gy, window=48, block_q=64,
                              block_k=64)
        for t in range(T)])
    np.testing.assert_array_equal(np.asarray(jk), np.asarray(ones))


# ---------------------------------------------------------------------------
# dispatch: cotangent-known route (vmap-of-tangents -> ONE _jvps call,
# NO (K, ..., N) tangent output anywhere)
#
# jaxpr inspection goes through the shared repro.analysis pass; the old
# per-test _walk_eqns/_pallas_calls/_assert_no_tangent_stack_output
# helpers live there now.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["lora", "wkv6", "swa", "mamba2"])
def test_vmap_of_contract_traces_jvps_epilogue(kind):
    """vmap of a ``*_jvp_contract`` op's tangents inside
    ``forward_ad_region()`` must lower to ONE ``_jvps`` epilogue
    pallas_call whose outputs are per-block (..., K) partials — and the
    jaxpr must contain no (K,)+y.shape buffer at all."""
    K = 4
    if kind == "mamba2":
        (xdt, bm, cm, dec), _, gy = _mamba2_problem(B=1, S=32, H=2, hd=8,
                                                    N=4, T=1)
        y_shape = gy.shape

        def contract(xd, bd, cd, dd):
            return dispatch.mamba2_jvp_contract(gy, xdt, bm, cm, dec, xd,
                                                bd, cd, dd)

        tangents = (jnp.zeros((K,) + xdt.shape), jnp.zeros((K,) + bm.shape),
                    jnp.zeros((K,) + cm.shape), jnp.zeros((K,) + dec.shape))
    elif kind == "lora":
        (x, w, a, b), _, gy, scale = _lora_problem()
        y_shape = gy.shape

        def contract(ad, bd):
            return dispatch.lora_jvp_contract(gy, x, w, a, b, ad, bd,
                                              scale=scale)

        tangents = (jnp.zeros((K,) + a.shape), jnp.zeros((K,) + b.shape))
    elif kind == "wkv6":
        (r, k, v, w, u), _, gy = _wkv_problem(B=1, S=32, H=2, hd=8, T=1)
        y_shape = gy.shape

        def contract(rd, kd, vd, wd):
            return dispatch.wkv6_jvp_contract(gy, r, k, v, w, u, rd, kd, vd,
                                              wd)

        tangents = tuple(jnp.zeros((K,) + r.shape) for _ in range(4))
    else:
        (q, kk, vv), _, gy = _swa_problem(B=1, H=2, KV=2, S=64, hd=8, T=1)
        y_shape = gy.shape

        def contract(qd, kd, vd):
            return dispatch.swa_jvp_contract(gy, q, kk, vv, qd, kd, vd, 32)

        tangents = (jnp.zeros((K,) + q.shape),
                    jnp.zeros((K,) + kk.shape), jnp.zeros((K,) + vv.shape))

    dispatch.set_backend("interpret")
    try:
        with dispatch.forward_ad_region():
            jaxpr = jax.make_jaxpr(jax.vmap(contract))(*tangents)
    finally:
        dispatch.set_backend(None)

    calls = pallas_calls(jaxpr)
    assert len(calls) == 1, f"expected ONE _jvps pallas_call, got {calls}"
    (out_aval,) = [v.aval for v in calls[0].outvars]
    # per-block partials: trailing tangent axis K, tiny total size
    assert out_aval.shape[-1] == K
    assert_no_tangent_stack(jaxpr, K, y_shape)


# ---------------------------------------------------------------------------
# estimator: fused route == standard route; padded chunked scan; HBM claim
# ---------------------------------------------------------------------------

def _mixer_split_problem(kind, seed=2):
    B, S, H, hd = 1, 64, 2, 16
    N = 8
    D = H * hd
    ks = jax.random.split(jax.random.PRNGKey(seed), 10)
    x = jax.random.normal(ks[0], (B, S, D)) * 0.3
    wp = [jax.random.normal(ks[1 + i], (D, D)) * 0.05 for i in range(3)]
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    wdec = jax.nn.sigmoid(jax.random.normal(ks[5], (B, S, H, hd)))
    peft = {"A": jax.random.normal(ks[6], (D, 2)) * 0.05,
            "B": jax.random.normal(ks[7], (2, D)) * 0.05}
    wbc = [jax.random.normal(ks[8 + i], (D, N)) * 0.3 for i in range(2)]

    if kind == "lora":
        split = SplitLoss(lambda p: ((x, wp[0], p["A"], p["B"]), None),
                          "lora", lambda y, ctx, p: jnp.mean(y * y),
                          scale=2.0, x_has_tangent=False)
        return split, peft

    def pre(p):
        r = dispatch.lora_proj(x, wp[0], p["A"], p["B"], 2.0)
        k = (x @ wp[1]).reshape(B, S, H, hd)
        v = (x @ wp[2]).reshape(B, S, H, hd)
        if kind == "wkv6":
            return (r.reshape(B, S, H, hd), k, v, wdec, u), None
        if kind == "mamba2":
            return (r.reshape(B, S, H, hd), x @ wbc[0], x @ wbc[1],
                    wdec.mean(-1)), None
        return (r.reshape(B, S, H, hd).transpose(0, 2, 1, 3),
                k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)), None

    split = SplitLoss(pre, kind, lambda y, ctx, p: jnp.mean(y * y),
                      window=32)
    return split, peft


@pytest.mark.parametrize("backend", ["interpret", "jnp"])
@pytest.mark.parametrize("kind", ["lora", "wkv6", "swa", "mamba2"])
def test_fused_route_matches_standard(kind, backend):
    """fused_contraction on/off must produce the same loss (bitwise — the
    primal path is shared) and the same jvp scalars per seed up to float
    reassociation of the contraction."""
    split, peft = _mixer_split_problem(kind)
    key = jax.random.PRNGKey(9)
    dispatch.set_backend(backend)
    try:
        l0, g0, j0 = forward_gradient(split, peft, key, k_perturbations=4)
        l1, g1, j1 = forward_gradient(split, peft, key, k_perturbations=4,
                                      fused_contraction=True)
    finally:
        dispatch.set_backend(None)
    assert np.asarray(l0) == np.asarray(l1)
    assert _rel(j1, j0) < 1e-5
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


@pytest.mark.parametrize("kind", ["lora", "wkv6", "swa", "mamba2"])
def test_fused_chunked_scan_matches_full_batch(kind):
    """K=5 with tangent_batch=2 pads to 3 scanned groups with a masked-out
    lane; on the interpret backend (kernel lanes are exact replicas) the
    jvps must be BITWISE equal to the full-batch fused pass."""
    split, peft = _mixer_split_problem(kind)
    key = jax.random.PRNGKey(9)
    dispatch.set_backend("interpret")
    try:
        _, g2, j2 = forward_gradient(split, peft, key, k_perturbations=5,
                                     tangent_batch=2, fused_contraction=True)
        _, g3, j3 = forward_gradient(split, peft, key, k_perturbations=5,
                                     fused_contraction=True)
    finally:
        dispatch.set_backend(None)
    np.testing.assert_array_equal(np.asarray(j2), np.asarray(j3))
    for a, b in zip(jax.tree.leaves(g2), jax.tree.leaves(g3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_fused_k1_route():
    split, peft = _mixer_split_problem("lora")
    key = jax.random.PRNGKey(3)
    l0, g0, j0 = forward_gradient(split, peft, key, k_perturbations=1)
    l1, g1, j1 = forward_gradient(split, peft, key, k_perturbations=1,
                                  fused_contraction=True)
    assert j1.shape == (1,)
    assert _rel(j1, j0) < 1e-5


def test_split_loss_is_drop_in_callable():
    """SplitLoss(p) must equal the plain composition through the dispatched
    site op — BITWISE (same ops)."""
    split, peft = _mixer_split_problem("wkv6")

    def plain(p):
        args, ctx = split.pre(p)
        return jnp.mean(dispatch.wkv6_mix(*args) ** 2)

    np.testing.assert_array_equal(np.asarray(split(peft)),
                                  np.asarray(plain(peft)))


def test_fused_route_with_x_tangent():
    """x_has_tangent=True (x depends on the trainable tree via an upstream
    projection) exercises the epilogue's incremental frozen-W contraction."""
    B, S = 4, 16
    D = 32
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    x0 = jax.random.normal(ks[0], (B * S, D)) * 0.3
    w0 = jax.random.normal(ks[1], (D, D)) * 0.05
    w1 = jax.random.normal(ks[2], (D, D)) * 0.05
    peft = {"A0": jax.random.normal(ks[3], (D, 2)) * 0.05,
            "B0": jnp.zeros((2, D)),
            "A1": jax.random.normal(ks[4], (D, 2)) * 0.05,
            "B1": jnp.zeros((2, D))}
    # two stacked LoRA projections: the SECOND is the fused site and its x
    # input carries tangents from the first
    def pre(p):
        h = dispatch.lora_proj(x0, w0, p["A0"], p["B0"], 2.0)
        return (h, w1, p["A1"], p["B1"]), None

    split = SplitLoss(pre, "lora", lambda y, ctx, p: jnp.mean(y * y),
                      scale=2.0, x_has_tangent=True)
    key = jax.random.PRNGKey(5)
    for backend in ("interpret", "jnp"):
        dispatch.set_backend(backend)
        try:
            l0, g0, j0 = forward_gradient(split, peft, key,
                                          k_perturbations=4)
            l1, g1, j1 = forward_gradient(split, peft, key,
                                          k_perturbations=4,
                                          fused_contraction=True)
        finally:
            dispatch.set_backend(None)
        assert _rel(j1, j0) < 1e-5, backend


@pytest.mark.parametrize("kind", ["lora", "wkv6", "swa", "mamba2"])
def test_fused_route_jaxpr_has_no_tangent_stack_at_site(kind):
    """The acceptance claim: on the fused-contraction route, NO
    (K, ..., N) tangent output buffer exists at the epilogue-eligible site
    — asserted on the traced jaxpr of the vmapped fused tangent fn. The
    standard route's jaxpr DOES contain it (sanity check that the
    assertion has teeth)."""
    K = 4
    split, peft = _mixer_split_problem(kind)
    peft32 = jax.tree.map(lambda t: t.astype(jnp.float32), peft)
    y_shape = np.asarray(split(peft)).shape  # scalar loss — need site shape
    args, _ = split.pre(peft32)
    y_shape = split.site(args).shape
    vs = jax.tree.map(lambda t: jnp.zeros((K,) + t.shape, jnp.float32),
                      peft32)

    dispatch.set_backend("interpret")
    try:
        _, fused_map = fused_linearize(split, peft32)
        fused_jaxpr = jax.make_jaxpr(jax.vmap(fused_map))(vs)
        with dispatch.forward_ad_region():
            _, std_map = jax.linearize(split, peft32)
        std_jaxpr = jax.make_jaxpr(jax.vmap(std_map))(vs)
    finally:
        dispatch.set_backend(None)

    family = {"lora": "lora_dual", "wkv6": "wkv6_scan",
              "swa": "swa_attention", "mamba2": "mamba2_scan"}[kind]

    # upstream (non-site) mixers in ``pre`` legitimately materialize their
    # tangents — only the SITE family's kernels are under test
    fused_site = family_pallas_calls(fused_jaxpr, family)
    assert fused_site, "fused route lost the site kernel entirely"
    for eqn in fused_site:
        assert "_mt_jvps_kernel" in kernel_src(eqn)
    assert_no_tangent_stack(fused_jaxpr, K, y_shape, family=family)
    assert tangent_stack_outputs(std_jaxpr, K, y_shape, family=family), (
        "standard route should materialize the site tangent stack — the "
        "no-stack assertion would be vacuous")
