"""Split-forward refactor (ISSUE 5): full-model training losses through the
fused JVP-contraction route.

Covers: registry-wide split-vs-plain loss equality (BITWISE — the plain
losses now run the same pre -> mixer-site -> post composition the SplitLoss
builders expose); the split composition vs the retained fully-scanned
reference forward (allclose — XLA fuses an unrolled layer differently from a
scan iteration, so cross-program equality is float-ulp, which is exactly why
``forward`` itself was refactored to BE the composition); fused-vs-standard
estimator equivalence on full-model losses (loss bitwise, jvps <= 1e-6 rel);
the jaxpr assertion that the FULL-model fused path writes no
tangent-stack-sized buffer at the final-layer site (one ``_mt_jvps``
epilogue pallas_call, per-block-partial outputs only); the one-time
unsplittable-loss warning; and the round-step telemetry surfacing the active
route.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    assert_no_tangent_stack,
    kernel_src,
    pallas_calls,
)
from repro.configs import SpryConfig, get_config, reduce_config
from repro.core.forward_grad import (
    SplitLoss,
    _warned_unsplit_losses,
    forward_gradient,
    fused_linearize,
)
from repro.core.spry import init_state, make_round_step, make_task_loss
from repro.kernels import dispatch
from repro.models import encdec, hybrid, rwkv_model, transformer
from repro.models.registry import get_loss_fn, get_model
from repro.peft import init_peft

_ARCHS = {
    "dense": "llama2-7b",
    "moe": "qwen3-moe-235b-a22b",
    "vlm": "internvl2-76b",
    "ssm": "rwkv6-1.6b",
    "hybrid": "zamba2-1.2b",
    "audio": "whisper-tiny",
    "local_global": "gemma3-12b",
}


def _cfg(name):
    return reduce_config(get_config(_ARCHS[name]))


def _cfg_hybrid_m2():
    # final layer NOT an attention application site -> mamba2 mixer site
    cfg = reduce_config(get_config("zamba2-1.2b"))
    return dataclasses.replace(cfg, n_layers=3, hybrid_attn_every=2)


def _setup(cfg, task, seed=0, B=2, S=16):
    key = jax.random.PRNGKey(seed)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    peft32 = jax.tree.map(lambda x: x.astype(jnp.float32), peft)
    ks = jax.random.split(jax.random.PRNGKey(seed + 1), 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if task == "cls":
        batch["labels"] = jax.random.randint(ks[1], (B,), 0, cfg.n_classes)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_frontend_tokens or 4, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    return model, base, peft32, batch


def _rel(a, b):
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-12))


# ---------------------------------------------------------------------------
# split loss == plain loss, bitwise, on every family x task
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("task", ["lm", "cls"])
@pytest.mark.parametrize("family", ["dense", "moe", "vlm", "ssm", "hybrid",
                                    "audio", "local_global", "hybrid_m2"])
def test_split_loss_bitwise_equals_plain(family, task):
    """The registry split losses and the plain closures trace the identical
    program (``forward`` IS the split composition) -> bitwise equality, both
    eagerly and under jit."""
    cfg = _cfg_hybrid_m2() if family == "hybrid_m2" else _cfg(family)
    model, base, peft32, batch = _setup(cfg, task)
    plain = get_loss_fn(task)(cfg, base, peft32, batch)
    split_obj = get_loss_fn(task, split=True)(cfg, base, batch)
    assert isinstance(split_obj, SplitLoss)
    np.testing.assert_array_equal(np.asarray(plain),
                                  np.asarray(split_obj(peft32)))
    plain_j = jax.jit(lambda p: get_loss_fn(task)(cfg, base, p, batch))(peft32)
    split_j = jax.jit(split_obj)(peft32)
    np.testing.assert_array_equal(np.asarray(plain_j), np.asarray(split_j))


@pytest.mark.parametrize("family,mod", [
    ("dense", transformer), ("ssm", rwkv_model), ("hybrid", hybrid),
    ("audio", encdec)])
def test_split_composition_matches_scanned_reference(family, mod):
    """The composition forward equals the retained fully-scanned reference
    to float-ulp (the per-layer ops are identical; only XLA fusion of the
    unrolled final layer differs)."""
    cfg = _cfg(family)
    model, base, peft32, batch = _setup(cfg, "lm")
    h_new, aux_new = model.forward(cfg, base, peft32, batch)
    if family == "audio":
        h_ref, aux_ref = mod.forward_scanned(cfg, base, peft32,
                                             batch["tokens"],
                                             frames=batch["frames"])
    else:
        h_ref, aux_ref = mod.forward_scanned(cfg, base, peft32,
                                             batch["tokens"])
    np.testing.assert_allclose(np.asarray(h_new), np.asarray(h_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(aux_new), np.asarray(aux_ref),
                               rtol=1e-6, atol=1e-7)


def test_hybrid_site_kind_depends_on_final_layer():
    attn_cfg = _cfg("hybrid")                       # every=1 -> attn final
    m2_cfg = _cfg_hybrid_m2()
    assert get_model(attn_cfg).split_site(attn_cfg)[0] == "swa"
    assert get_model(m2_cfg).split_site(m2_cfg)[0] == "mamba2"


# ---------------------------------------------------------------------------
# estimator: fused == standard on full-model registry losses
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,task", [
    ("dense", "cls"), ("moe", "lm"), ("vlm", "lm"), ("ssm", "lm"),
    ("hybrid", "cls"), ("hybrid_m2", "lm"), ("audio", "cls")])
def test_fullmodel_fused_matches_standard_jnp(family, task):
    """fused_contraction on/off over the registry losses: loss BITWISE (the
    routes share the primal program), jvps equal up to reassociation of the
    contraction, gradients allclose ('jnp' backend)."""
    cfg = _cfg_hybrid_m2() if family == "hybrid_m2" else _cfg(family)
    model, base, peft32, batch = _setup(cfg, task)
    plain = lambda p: get_loss_fn(task)(cfg, base, p, batch)
    split = get_loss_fn(task, split=True)(cfg, base, batch)
    key = jax.random.PRNGKey(7)
    l0, g0, j0 = forward_gradient(plain, peft32, key, k_perturbations=3)
    l1, g1, j1 = forward_gradient(split, peft32, key, k_perturbations=3,
                                  fused_contraction=True)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    # jvps differ only by float reassociation of the site contraction
    # (fp32; ~1e-6-level on the reduced shapes)
    assert _rel(j1, j0) < 5e-6
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=2e-5)


@pytest.mark.parametrize("family,task", [
    ("dense", "cls"), ("ssm", "lm"),
    pytest.param("moe", "lm", marks=pytest.mark.slow),
    pytest.param("hybrid", "cls", marks=pytest.mark.slow),
    pytest.param("hybrid_m2", "lm", marks=pytest.mark.slow),
    pytest.param("audio", "cls", marks=pytest.mark.slow)])
def test_fullmodel_fused_matches_standard_interpret(family, task):
    """End-to-end through the Pallas epilogue kernels (interpret backend):
    the full-model fused estimate runs the ``*_jvp_contract`` route at the
    final-layer site and agrees with the standard kernel route."""
    cfg = _cfg_hybrid_m2() if family == "hybrid_m2" else _cfg(family)
    model, base, peft32, batch = _setup(cfg, task, B=1)
    plain = lambda p: get_loss_fn(task)(cfg, base, p, batch)
    split = get_loss_fn(task, split=True)(cfg, base, batch)
    key = jax.random.PRNGKey(9)
    dispatch.set_backend("interpret")
    try:
        l0, _, j0 = forward_gradient(plain, peft32, key, k_perturbations=3)
        l1, _, j1 = forward_gradient(split, peft32, key, k_perturbations=3,
                                     fused_contraction=True)
    finally:
        dispatch.set_backend(None)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    assert _rel(j1, j0) < 1e-5


# ---------------------------------------------------------------------------
# jaxpr: the FULL-model fused path writes no tangent stack at the site
# (inspection via the shared repro.analysis pass)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,task", [
    ("dense", "cls"), ("ssm", "lm"), ("hybrid", "cls"), ("hybrid_m2", "lm")])
def test_fullmodel_fused_jaxpr_no_tangent_stack_at_site(family, task):
    """The acceptance claim (ISSUE 5): under --fused-contraction, the
    FULL-model registry losses lower the final-layer site to ONE
    ``_mt_jvps`` contraction-epilogue pallas_call whose outputs are
    per-block partials — no (K,)+y.shape tangent-stack buffer is written at
    the site. (Upstream layers inside the scan legitimately materialize
    their mt tangents; only the site is epilogue-eligible.)"""
    K = 4
    cfg = _cfg_hybrid_m2() if family == "hybrid_m2" else _cfg(family)
    model, base, peft32, batch = _setup(cfg, task, B=1)
    split = get_loss_fn(task, split=True)(cfg, base, batch)
    vs = jax.tree.map(lambda t: jnp.zeros((K,) + t.shape, jnp.float32),
                      peft32)
    dispatch.set_backend("interpret")
    try:
        _, fused_map = fused_linearize(split, peft32)
        fused_jaxpr = jax.make_jaxpr(jax.vmap(fused_map))(vs)
        site_args, _ = split.pre(peft32)
        with dispatch.forward_ad_region():
            y_shape = split.site(site_args).shape
    finally:
        dispatch.set_backend(None)

    jvps_calls = [e for e in pallas_calls(fused_jaxpr)
                  if "_mt_jvps_kernel" in kernel_src(e)]
    assert len(jvps_calls) == 1, (
        f"expected exactly ONE _mt_jvps epilogue call at the site, got "
        f"{len(jvps_calls)}")
    # upstream scanned layers materialize their own mt tangents, so the
    # no-stack check targets the epilogue calls only
    assert_no_tangent_stack(fused_jaxpr, K, y_shape,
                            family="_mt_jvps_kernel")


# ---------------------------------------------------------------------------
# fallback warning + route telemetry
# ---------------------------------------------------------------------------

def test_unsplittable_loss_warns_once():
    """fused_contraction with a plain callable is no longer silent: a
    one-time UserWarning names the loss and the route taken."""
    def my_unsplittable_loss(p):
        return jnp.sum(p["x"] ** 2)

    peft = {"x": jnp.ones((4,))}
    key = jax.random.PRNGKey(0)
    _warned_unsplit_losses.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        forward_gradient(my_unsplittable_loss, peft, key,
                         k_perturbations=2, fused_contraction=True)
        msgs = [str(w.message) for w in rec
                if issubclass(w.category, UserWarning)]
    assert any("my_unsplittable_loss" in m and "standard" in m
               for m in msgs), msgs
    # one-time: a second call does not warn again
    with warnings.catch_warnings(record=True) as rec2:
        warnings.simplefilter("always")
        forward_gradient(my_unsplittable_loss, peft, key,
                         k_perturbations=2, fused_contraction=True)
        msgs2 = [str(w.message) for w in rec2
                 if issubclass(w.category, UserWarning)
                 and "my_unsplittable_loss" in str(w.message)]
    assert not msgs2


def test_make_task_loss_builds_split_when_fused():
    cfg = _cfg("ssm")
    model, base, peft32, batch = _setup(cfg, "cls")
    sc_fused = SpryConfig(fused_contraction=True)
    sc_std = SpryConfig()
    assert isinstance(make_task_loss(cfg, sc_fused, "cls", base, batch),
                      SplitLoss)
    assert not isinstance(make_task_loss(cfg, sc_std, "cls", base, batch),
                          SplitLoss)


def test_round_step_fused_runs_and_reports_route():
    """A spry round with --fused-contraction runs the split losses end to
    end; metrics surface the active route, and the loss equals the standard
    round's loss bitwise (shared primal program)."""
    cfg = _cfg("ssm")
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    sc = SpryConfig(n_clients_per_round=2, n_total_clients=4,
                    k_perturbations=2, fused_contraction=True)
    sc_std = dataclasses.replace(sc, fused_contraction=False)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)
    M, B, S = 2, 2, 16
    batch = {"tokens": jax.random.randint(key, (M, B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (M, B), 0, cfg.n_classes)}
    _, metrics = make_round_step(cfg, sc, "cls")(state, batch)
    _, metrics_std = make_round_step(cfg, sc_std, "cls")(state, batch)
    assert float(metrics["fused_route"]) == 1.0
    assert float(metrics_std["fused_route"]) == 0.0
    # the two rounds share the primal loss program; the vmap-of-clients +
    # local-iteration scan wrap them in different tangent surroundings, so
    # cross-program equality is float-ulp here (the direct bitwise claim is
    # asserted by test_fullmodel_fused_matches_standard_*)
    np.testing.assert_allclose(np.asarray(metrics["loss"]),
                               np.asarray(metrics_std["loss"]), rtol=1e-6)
