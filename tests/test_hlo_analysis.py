"""Loop-aware HLO analyser: exactness on known programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyse_hlo


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        c, _ = jax.lax.scan(body, x, w)
        return c.sum()

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    comp = _compile(f, x, w)
    t = analyse_hlo(comp.as_text())
    expected = 5 * 2 * 8 * 16 * 16
    assert t.flops == expected
    # and confirm XLA's own number is the body-once undercount
    # (cost_analysis returns a list of per-program dicts on newer jax)
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < expected


def test_nested_scan_flops():
    def g(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), ()
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, ()
        c, _ = jax.lax.scan(outer, x, w)
        return c.sum()

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    t = analyse_hlo(_compile(g, x, w).as_text())
    assert t.flops == 15 * 2 * 8 * 16 * 16


def test_plain_matmul_flops():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    t = analyse_hlo(_compile(f, a, b).as_text())
    assert t.flops == 2 * 32 * 64 * 128


def test_dot_bytes_accounts_operands_and_output():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    t = analyse_hlo(_compile(f, a, b).as_text())
    expected = 4 * (32 * 64 + 64 * 128 + 32 * 128)
    assert t.dot_bytes == expected
