"""Loop-aware HLO analyser: exactness on known programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyse_hlo, peak_live_bytes


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        c, _ = jax.lax.scan(body, x, w)
        return c.sum()

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    comp = _compile(f, x, w)
    t = analyse_hlo(comp.as_text())
    expected = 5 * 2 * 8 * 16 * 16
    assert t.flops == expected
    # and confirm XLA's own number is the body-once undercount
    # (cost_analysis returns a list of per-program dicts on newer jax)
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < expected


def test_nested_scan_flops():
    def g(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), ()
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, ()
        c, _ = jax.lax.scan(outer, x, w)
        return c.sum()

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    t = analyse_hlo(_compile(g, x, w).as_text())
    assert t.flops == 15 * 2 * 8 * 16 * 16


def test_plain_matmul_flops():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    t = analyse_hlo(_compile(f, a, b).as_text())
    assert t.flops == 2 * 32 * 64 * 128


def test_dot_bytes_accounts_operands_and_output():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    t = analyse_hlo(_compile(f, a, b).as_text())
    expected = 4 * (32 * 64 + 64 * 128 + 32 * 128)
    assert t.dot_bytes == expected


# ---------------------------------------------------------------------------
# peak_live_bytes: buffer-assignment-style liveness walk (ISSUE 4)
# ---------------------------------------------------------------------------

_SYNTH_HLO = """\
HloModule synth

ENTRY %main (p0: f32[100,100], p1: f32[100,100]) -> f32[100,100] {
  %p0 = f32[100,100]{1,0} parameter(0)
  %p1 = f32[100,100]{1,0} parameter(1)
  %dot.0 = f32[100,100]{1,0} dot(f32[100,100]{1,0} %p0, f32[100,100]{1,0} %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %dot.1 = f32[100,100]{1,0} dot(f32[100,100]{1,0} %dot.0, f32[100,100]{1,0} %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %add.0 = f32[100,100]{1,0} add(f32[100,100]{1,0} %dot.1, f32[100,100]{1,0} %dot.0)
}
"""


def test_peak_live_bytes_synthetic_exact():
    """Hand-built straight-line HLO: dot.0 (40kB) stays live through add.0
    (its last use), so the peak is dot.0 + dot.1 + add.0 = 120kB of temps;
    with params, + 80kB."""
    buf = 4 * 100 * 100
    assert peak_live_bytes(_SYNTH_HLO) == 3 * buf
    assert peak_live_bytes(_SYNTH_HLO, include_params=True) == 5 * buf


def test_peak_live_bytes_frees_dead_buffers():
    """A chain a->b->c frees each link after its last use: peak is two live
    links, not the whole chain."""
    hlo = """\
HloModule chain

ENTRY %main (p0: f32[100,100]) -> f32[100,100] {
  %p0 = f32[100,100]{1,0} parameter(0)
  %dot.0 = f32[100,100]{1,0} dot(f32[100,100]{1,0} %p0, f32[100,100]{1,0} %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %dot.1 = f32[100,100]{1,0} dot(f32[100,100]{1,0} %dot.0, f32[100,100]{1,0} %dot.0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %dot.2 = f32[100,100]{1,0} dot(f32[100,100]{1,0} %dot.1, f32[100,100]{1,0} %dot.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    assert peak_live_bytes(hlo) == 2 * 4 * 100 * 100


def test_peak_live_bytes_fused_contraction_below_materialized():
    """The property the bench records: a K-stacked tangent contraction that
    materializes the (K, M, N) stack must peak strictly above the
    reassociated contraction of the same estimate."""
    K, M, N, r = 8, 64, 64, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (M, M))
    w = jax.random.normal(ks[1], (M, N))
    gy = jax.random.normal(ks[2], (M, N))
    ads = jax.random.normal(ks[3], (K, M, r))
    bds = jax.random.normal(ks[4], (K, r, N))

    def materialized(ads, bds):
        ydots = (x @ ads) @ bds                       # (K, M, N)
        return jnp.einsum("mn,kmn->k", gy, ydots)

    def fused(ads, bds):
        z1 = gy @ jnp.swapaxes(bds, 1, 2)             # (K, M, r)
        return jnp.einsum("kmr,kmr->k", z1, x @ ads)

    pm = peak_live_bytes(
        jax.jit(materialized).lower(ads, bds).compile().as_text())
    pf = peak_live_bytes(
        jax.jit(fused).lower(ads, bds).compile().as_text())
    assert pf < pm, (pf, pm)
