"""The checked-in BENCH JSON artifacts must conform to the schemas the CI
bench-smoke job enforces (benchmarks/check_schemas.py) — and the checker
itself must actually reject broken documents."""
import json
import pathlib

from benchmarks.check_schemas import check_kernels, check_round

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_checked_in_bench_kernels_conforms():
    doc = json.load(open(REPO / "BENCH_kernels.json"))
    assert check_kernels(doc) == []


def test_checked_in_bench_round_conforms():
    doc = json.load(open(REPO / "BENCH_round.json"))
    assert check_round(doc) == []


def test_checker_rejects_broken_docs():
    doc = json.load(open(REPO / "BENCH_kernels.json"))
    del doc["fg_fullmodel"]
    assert check_kernels(doc)
    doc2 = json.load(open(REPO / "BENCH_kernels.json"))
    doc2["fg_ksweep"][0].pop("peak_live_mb_fused")
    assert check_kernels(doc2)
    rdoc = json.load(open(REPO / "BENCH_round.json"))
    rdoc["round_bench"] = []
    assert check_round(rdoc)
