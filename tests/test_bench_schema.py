"""The checked-in BENCH/ANALYSIS JSON artifacts must conform to the schemas
the CI jobs enforce (benchmarks/check_schemas.py) — and the checker itself
must actually reject broken documents."""
import json
import pathlib

from benchmarks.check_schemas import (
    check_analysis,
    check_async,
    check_kernels,
    check_roofline,
    check_round,
    check_serve,
)

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_checked_in_bench_kernels_conforms():
    doc = json.load(open(REPO / "BENCH_kernels.json"))
    assert check_kernels(doc) == []


def test_checked_in_bench_round_conforms():
    doc = json.load(open(REPO / "BENCH_round.json"))
    assert check_round(doc) == []


def test_checked_in_bench_serve_conforms():
    doc = json.load(open(REPO / "BENCH_serve.json"))
    assert check_serve(doc) == []
    # the artifact must record the continuous-batching win at scale
    assert any(s["n_adapters"] >= 8 and s["speedup"] > 1.5
               for s in doc["speedup"])
    # ...and the adapter-cache traffic of every continuous run
    for row in doc["serve_bench"]:
        if row["mode"] == "continuous":
            assert 0.0 <= row["cache_hit_rate"] <= 1.0


def test_checked_in_bench_roofline_conforms():
    doc = json.load(open(REPO / "BENCH_roofline.json"))
    assert check_roofline(doc) == []
    rows = [r for r in doc["roofline"] if not r.get("skipped")]
    # the tracked artifact covers the full assigned sweep
    assert len({r["arch"] for r in rows}) >= 8
    assert {"train_4k", "decode_32k"} <= {r["shape"] for r in rows}
    for r in rows:
        assert r["peak_bytes"] > 0


def test_checked_in_analysis_conforms():
    doc = json.load(open(REPO / "ANALYSIS.json"))
    assert check_analysis(doc) == []
    # the tracked artifact must be a CLEAN lint run: info findings (teeth
    # records, donation waivers) are fine, errors/warnings are not
    assert doc["summary"]["errors"] == 0
    assert doc["summary"]["warnings"] == 0
    # and every kernel in the residency table fits its budget
    assert all(row["ok"] for row in doc["vmem_kernels"])


def test_checked_in_bench_async_conforms():
    doc = json.load(open(REPO / "BENCH_async.json"))
    assert check_async(doc) == []
    # acceptance: >= 1.5x useful-compute utilization at 10^6 clients...
    util = doc["utilization"]
    assert util["n_clients"] >= 1_000_000
    assert util["utilization_ratio"] >= 1.5
    # ...and async reaches the sync run's loss in less simulated wall time
    assert doc["wall_clock"]["async"]["matched"]
    assert doc["wall_clock"]["speedup"] > 1.0
    # the sweep reports the stricter deadline quantiles transparently
    assert {r["deadline_quantile"] for r in util["sync"]} >= {0.5, 0.75, 0.9}


def test_async_checker_rejects_broken_docs():
    doc = json.load(open(REPO / "BENCH_async.json"))
    doc["utilization"]["utilization_ratio"] = 1.2
    assert check_async(doc)
    doc2 = json.load(open(REPO / "BENCH_async.json"))
    doc2["wall_clock"]["async"]["matched"] = False
    assert check_async(doc2)
    doc3 = json.load(open(REPO / "BENCH_async.json"))
    doc3["utilization"]["n_clients"] = 10_000
    assert check_async(doc3)
    doc4 = json.load(open(REPO / "BENCH_async.json"))
    doc4["utilization"]["async"].pop("staleness_mean")
    assert check_async(doc4)


def test_analysis_checker_rejects_broken_docs():
    doc = json.load(open(REPO / "ANALYSIS.json"))
    doc["schema"] = "something/else"
    assert check_analysis(doc)
    doc2 = json.load(open(REPO / "ANALYSIS.json"))
    doc2["vmem_kernels"] = [r for r in doc2["vmem_kernels"]
                            if r["family"] != "mamba2_scan"]
    assert check_analysis(doc2)
    doc3 = json.load(open(REPO / "ANALYSIS.json"))
    doc3["vmem_kernels"][0].pop("residency_bytes")
    assert check_analysis(doc3)
    doc4 = json.load(open(REPO / "ANALYSIS.json"))
    doc4["summary"].pop("errors")
    assert check_analysis(doc4)


def test_checker_rejects_broken_docs():
    doc = json.load(open(REPO / "BENCH_kernels.json"))
    del doc["fg_fullmodel"]
    assert check_kernels(doc)
    doc2 = json.load(open(REPO / "BENCH_kernels.json"))
    doc2["fg_ksweep"][0].pop("peak_live_mb_fused")
    assert check_kernels(doc2)
    rdoc = json.load(open(REPO / "BENCH_round.json"))
    rdoc["round_bench"] = []
    assert check_round(rdoc)
    sdoc = json.load(open(REPO / "BENCH_serve.json"))
    sdoc["serve_bench"] = [r for r in sdoc["serve_bench"]
                           if r["mode"] != "continuous"]
    assert check_serve(sdoc)
    sdoc2 = json.load(open(REPO / "BENCH_serve.json"))
    sdoc2["speedup"][0].pop("speedup")
    assert check_serve(sdoc2)
    sdoc3 = json.load(open(REPO / "BENCH_serve.json"))
    next(r for r in sdoc3["serve_bench"]
         if r["mode"] == "continuous").pop("cache_hits")
    assert check_serve(sdoc3)
    rfdoc = json.load(open(REPO / "BENCH_roofline.json"))
    rfdoc["roofline"] = [dict(r, skipped=True, reason="x")
                         for r in rfdoc["roofline"]]
    assert check_roofline(rfdoc)
    rfdoc2 = json.load(open(REPO / "BENCH_roofline.json"))
    next(r for r in rfdoc2["roofline"]
         if not r.get("skipped")).pop("dominant")
    assert check_roofline(rfdoc2)
