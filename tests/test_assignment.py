"""Property tests (hypothesis) for the layer->client assignment (Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core.assignment import (
    assignment_matrix,
    build_mask_tree,
    client_counts,
    enumerate_units,
)


@settings(max_examples=60, deadline=None)
@given(n_units=st.integers(1, 60), n_clients=st.integers(1, 40),
       offset=st.integers(0, 100))
def test_every_unit_covered_every_round(n_units, n_clients, offset):
    """The union of client assignments covers ALL units each round (the
    paper's requirement that the round updates every trainable weight)."""
    m = np.asarray(assignment_matrix(n_units, n_clients, offset))
    assert m.shape == (n_clients, n_units)
    assert (m.sum(axis=0) >= 1).all()


@settings(max_examples=60, deadline=None)
@given(n_units=st.integers(1, 60), n_clients=st.integers(1, 40),
       offset=st.integers(0, 100))
def test_every_client_gets_work(n_units, n_clients, offset):
    m = np.asarray(assignment_matrix(n_units, n_clients, offset))
    assert (m.sum(axis=1) >= 1).all()


@settings(max_examples=30, deadline=None)
@given(n_units=st.integers(2, 60), n_clients=st.integers(2, 40))
def test_balanced_load(n_units, n_clients):
    """Cyclic mapping: per-client unit counts differ by at most 1 when
    U >= M (paper: each client gets ceil/floor(L/M) layers)."""
    m = np.asarray(assignment_matrix(n_units, n_clients, 0))
    loads = m.sum(axis=1)
    if n_units >= n_clients:
        assert loads.max() - loads.min() <= 1


@settings(max_examples=30, deadline=None)
@given(offset=st.integers(0, 7))
def test_rotation_changes_mapping(offset):
    a = np.asarray(assignment_matrix(8, 4, 0))
    b = np.asarray(assignment_matrix(8, 4, offset))
    # rotated mapping is a column-permutation-compatible reassignment with
    # identical per-unit coverage
    assert (a.sum(0) == b.sum(0)).all()


def _toy_peft():
    return {
        "layers": {
            "wq": {"A": jnp.zeros((3, 4, 1)), "B": jnp.zeros((3, 1, 4))},
            "wv": {"A": jnp.zeros((3, 4, 1)), "B": jnp.zeros((3, 1, 4))},
        },
        "shared": {"wq": {"A": jnp.zeros((4, 1)), "B": jnp.zeros((1, 4))}},
        "head": {"w": jnp.zeros((4, 2)), "b": jnp.zeros(2)},
    }


def test_enumerate_units_structure():
    peft = _toy_peft()
    idx = enumerate_units(peft)
    # 3 layers x 2 targets + 1 shared unit; head excluded
    assert idx.n_units == 7
    groups = {u[0] for u in idx.units}
    assert groups == {"layers", "shared"}


def test_mask_tree_partition_property():
    """Summing all clients' mask trees must cover every unit leaf >= once,
    and the head is assigned to every client."""
    peft = _toy_peft()
    idx = enumerate_units(peft)
    M = 3
    mm = assignment_matrix(idx.n_units, M, 0)
    trees = [build_mask_tree(peft, idx, mm[m]) for m in range(M)]
    total = jax.tree.map(lambda *xs: sum(xs), *trees)
    for leaf in jax.tree.leaves(total["layers"]):
        assert (np.asarray(leaf) >= 1).all()
    for leaf in jax.tree.leaves(total["head"]):
        assert (np.asarray(leaf) == M).all()


def test_client_counts_match_mask():
    mm = assignment_matrix(5, 3, 0)
    counts = client_counts(mm)
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.asarray(mm.sum(0)))
