"""Federation runtime: engine bit-identity vs the in-process round steps,
sharded/streaming executor equivalence, dropout-corrected aggregation,
population scheduling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SpryConfig, get_config, reduce_config
from repro.core import (
    enumerate_units,
    init_state,
    make_client_update_fn,
    make_round_step,
    make_round_step_per_iteration,
)
from repro.fl.runtime import (
    ClientPopulation,
    CohortPlan,
    CohortScheduler,
    FederationEngine,
    SerialExecutor,
    ShardedExecutor,
    WireConfig,
)
from repro.fl.runtime.engine import _ideal_plan
from repro.models import get_model
from repro.peft import init_peft

ARCHS = ("roberta-large-lora", "rwkv6-1.6b")


def _setup(arch, M=4, B=2, S=16, local_iters=1, k=2):
    cfg = reduce_config(get_config(arch))
    sc = SpryConfig(n_clients_per_round=M, local_iters=local_iters,
                    local_lr=1e-2, server_lr=1e-2, k_perturbations=k)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)
    batch = {"tokens": jax.random.randint(key, (M, B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (M, B), 0, cfg.n_classes)}
    return cfg, sc, state, batch


def assert_trees_equal(a, b, what=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def assert_trees_close(a, b, atol=1e-6, rtol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# Acceptance: ideal-network full-participation rounds are bit-identical to
# the in-process round steps, both comm modes, >= 2 archs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_engine_per_epoch_bit_identical(arch):
    cfg, sc, state, batch = _setup(arch)
    ref_state, ref_m = jax.jit(make_round_step(cfg, sc, task="cls"))(state,
                                                                     batch)
    eng = FederationEngine(cfg, sc, task="cls", comm_mode="per_epoch")
    es, em = eng.run_ideal(state, batch)
    assert_trees_equal(ref_state.peft, es.peft, "peft")
    assert_trees_equal(ref_state.server, es.server, "server state")
    assert_trees_equal(ref_m, em, "metrics")
    assert int(es.round_idx) == int(ref_state.round_idx)


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_per_iteration_bit_identical(arch):
    cfg, sc, state, batch = _setup(arch)
    ref_state, ref_m = jax.jit(
        make_round_step_per_iteration(cfg, sc, task="cls"))(state, batch)
    eng = FederationEngine(cfg, sc, task="cls", comm_mode="per_iteration")
    es, em = eng.run_ideal(state, batch)
    assert_trees_equal(ref_state.peft, es.peft, "peft")
    assert_trees_equal(ref_state.server, es.server, "server state")
    assert_trees_equal(ref_m, em, "metrics")


def test_engine_wire_sim_fp32_bit_identical():
    """Routing every update through a serialized fp32 frame changes nothing."""
    cfg, sc, state, batch = _setup("roberta-large-lora")
    for mode, ref_fn in (("per_epoch", make_round_step),
                         ("per_iteration", make_round_step_per_iteration)):
        ref_state, _ = jax.jit(ref_fn(cfg, sc, task="cls"))(state, batch)
        eng = FederationEngine(cfg, sc, comm_mode=mode,
                               wire=WireConfig(simulate=True))
        es, _ = eng.run_ideal(state, batch)
        assert_trees_equal(ref_state.peft, es.peft, mode)


def test_engine_wire_bf16_close_but_not_identical():
    cfg, sc, state, batch = _setup("roberta-large-lora")
    ref_state, _ = jax.jit(make_round_step(cfg, sc, task="cls"))(state, batch)
    eng = FederationEngine(cfg, sc, comm_mode="per_epoch",
                           wire=WireConfig(simulate=True, dtype="bf16"))
    es, _ = eng.run_ideal(state, batch)
    assert_trees_close(ref_state.peft, es.peft, atol=1e-3, rtol=1e-2)
    diff = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree.leaves(ref_state.peft), jax.tree.leaves(es.peft)))
    assert diff > 0   # quantization must actually bite


# ---------------------------------------------------------------------------
# Acceptance: sharded 8-device executor vs single-device path — per-client
# payloads bitwise equal, aggregates to float tolerance
# ---------------------------------------------------------------------------

def test_sharded_payloads_bitwise_equal_serial():
    if len(jax.devices()) < 8:
        pytest.skip("needs xla_force_host_platform_device_count=8")
    cfg, sc, state, batch = _setup("roberta-large-lora", M=8)
    index = enumerate_units(state.peft)
    client_fn = make_client_update_fn(cfg, sc, task="cls")

    def kernel(base, peft, rk, sid, row, cb):
        delta, loss, jvps = client_fn(base, peft, rk, sid, row, cb)
        return delta, (loss, jvps)

    from repro.core.assignment import assignment_matrix
    mask = assignment_matrix(index.n_units, 8, 0)
    rk = jax.random.fold_in(jax.random.PRNGKey(sc.seed), 0)
    keep = jnp.ones(8, jnp.float32)
    args = (state.base, state.peft, rk, jnp.arange(8, dtype=jnp.int32), mask,
            batch, keep)

    serial = SerialExecutor(microbatch=1)
    sharded = ShardedExecutor(microbatch=1)
    pl_s, (ls_s, jv_s) = jax.jit(
        lambda *a: serial.run(kernel, *a, collect=True))(*args)
    pl_d, (ls_d, jv_d) = jax.jit(
        lambda *a: sharded.run(kernel, *a, collect=True))(*args)
    # per-client ClientUpdate payloads: bitwise equal across executors
    assert_trees_equal(pl_s, pl_d, "per-client delta payloads")
    assert_trees_equal(jv_s, jv_d, "per-client jvp scalars")
    assert_trees_equal(ls_s, ls_d, "per-client losses")


def test_sharded_engine_matches_serial_to_tolerance():
    if len(jax.devices()) < 8:
        pytest.skip("needs xla_force_host_platform_device_count=8")
    cfg, sc, state, batch = _setup("roberta-large-lora", M=8)
    for mode in ("per_epoch", "per_iteration"):
        ser = FederationEngine(cfg, sc, comm_mode=mode,
                               executor=SerialExecutor(microbatch=1))
        shd = FederationEngine(cfg, sc, comm_mode=mode,
                               executor=ShardedExecutor(microbatch=1))
        ss, _ = ser.run_ideal(state, batch)
        hs, _ = shd.run_ideal(state, batch)
        assert_trees_close(ss.peft, hs.peft)
        # and the whole-cohort reference stays within float tolerance too
        ref, _ = FederationEngine(cfg, sc, comm_mode=mode).run_ideal(state,
                                                                     batch)
        assert_trees_close(ref.peft, hs.peft)


def test_streaming_accumulator_is_o_peft():
    """The streaming executor's payload accumulator carries NO cohort axis —
    server-side aggregation memory is O(|peft|), independent of cohort."""
    cfg, sc, state, batch = _setup("roberta-large-lora", M=8)
    client_fn = make_client_update_fn(cfg, sc, task="cls")

    def kernel(base, peft, rk, sid, row, cb):
        delta, loss, jvps = client_fn(base, peft, rk, sid, row, cb)
        return delta, (loss, jvps)

    from repro.core.assignment import assignment_matrix
    index = enumerate_units(state.peft)
    mask = assignment_matrix(index.n_units, 8, 0)
    rk = jax.random.fold_in(jax.random.PRNGKey(sc.seed), 0)
    keep = jnp.ones(8, jnp.float32)
    ex = SerialExecutor(microbatch=2)
    shapes = jax.eval_shape(
        lambda *a: ex.run(kernel, *a, collect=False),
        state.base, state.peft, rk, jnp.arange(8, dtype=jnp.int32), mask,
        batch, keep)
    payload_shapes = jax.tree.leaves(shapes[0])
    peft_shapes = jax.tree.leaves(state.peft)
    assert [s.shape for s in payload_shapes] == \
        [p.shape for p in peft_shapes]


def test_cohort_larger_than_M_streams():
    """Cohorts ≫ the in-process M work through streaming aggregation."""
    cfg, sc, state, _ = _setup("roberta-large-lora", M=4)
    key = jax.random.PRNGKey(3)
    C = 24
    batch = {"tokens": jax.random.randint(key, (C, 2, 16), 0, cfg.vocab),
             "labels": jax.random.randint(key, (C, 2), 0, cfg.n_classes)}
    eng = FederationEngine(cfg, sc, comm_mode="per_epoch",
                           executor=SerialExecutor(microbatch=4))
    es, em = eng.run_ideal(state, batch)
    assert np.isfinite(float(em["loss"]))
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(jax.tree.leaves(es.peft),
                                jax.tree.leaves(state.peft)))
    assert moved


# ---------------------------------------------------------------------------
# Satellite: dropout-corrected aggregation — a dropped client's units are
# re-averaged with corrected counts == recomputing with the client excluded
# ---------------------------------------------------------------------------

def _manual_plan(round_idx, seed_ids, mask_matrix, keep):
    C = len(seed_ids)
    return CohortPlan(
        round_idx=round_idx, client_ids=np.asarray(seed_ids, np.int64),
        seed_ids=np.asarray(seed_ids, np.int32),
        mask_matrix=np.asarray(mask_matrix, np.float32),
        latencies=np.zeros(C), deadline=float("inf"),
        keep=np.asarray(keep, bool), assignments=[], n_requested=C)


@pytest.mark.parametrize("mode", ["per_epoch", "per_iteration"])
def test_dropout_corrected_aggregation(mode):
    """Drop client j mid-round: unit counts and the aggregated update must
    equal an explicit re-run with client j excluded. microbatch=1 makes the
    per-client computation width-invariant, so the equality is BITWISE."""
    from repro.core.assignment import assignment_matrix

    cfg, sc, state, batch = _setup("roberta-large-lora", M=5)
    index = enumerate_units(state.peft)
    mask = np.asarray(assignment_matrix(index.n_units, 5, 0), np.float32)
    # straggler = client 4, which SHARES unit 0 with client 0 under the
    # cyclic assignment (M=5 > U=4), so its drop changes a unit count 2 -> 1
    j = 4

    eng = FederationEngine(cfg, sc, comm_mode=mode,
                           executor=SerialExecutor(microbatch=1))
    keep = np.ones(5, bool)
    keep[j] = False
    plan_drop = _manual_plan(0, np.arange(5), mask, keep)
    sd, md, _ = eng.run_round(state, plan_drop, batch)

    survivors = [i for i in range(5) if i != j]
    plan_excl = _manual_plan(0, np.array(survivors), mask[survivors],
                             np.ones(4, bool))
    batch_excl = jax.tree.map(lambda x: x[np.array(survivors)], batch)
    se, me, _ = eng.run_round(state, plan_excl, batch_excl)

    # corrected unit counts equal the excluded recomputation's counts
    c_drop = np.maximum((mask * keep[:, None].astype(np.float32)).sum(0), 1)
    c_excl = np.maximum(mask[survivors].sum(0), 1)
    np.testing.assert_array_equal(c_drop, c_excl)
    assert (mask[j] > 0).any() and (c_drop < mask.sum(0)).any(), \
        "dropped client must actually own units for the test to bite"

    assert_trees_equal(sd.peft, se.peft, "aggregated update (peft)")
    assert_trees_equal(sd.server, se.server, "server state")
    assert_trees_equal(md, me, "metrics")


def test_dropout_differs_from_naive_full_counts():
    """Sanity: the corrected aggregation is NOT what fixed-M counts give."""
    cfg, sc, state, batch = _setup("roberta-large-lora", M=5)
    eng = FederationEngine(cfg, sc, comm_mode="per_epoch",
                           executor=SerialExecutor(microbatch=1))
    from repro.core.assignment import assignment_matrix
    index = enumerate_units(state.peft)
    mask = np.asarray(assignment_matrix(index.n_units, 5, 0), np.float32)
    keep = np.ones(5, bool)
    keep[0] = False
    sd, _, _ = eng.run_round(state, _manual_plan(0, np.arange(5), mask, keep),
                             batch)
    sf, _, _ = eng.run_round(state, _manual_plan(0, np.arange(5), mask,
                                                 np.ones(5, bool)), batch)
    diff = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree.leaves(sd.peft), jax.tree.leaves(sf.peft)))
    assert diff > 0


@pytest.mark.parametrize("mode", ["per_epoch", "per_iteration"])
def test_wire_sim_respects_noncontiguous_seed_ids(mode):
    """A survivor-subset plan has seed_ids != arange(C); the serialized-frame
    path must rebuild with the ORIGINAL fold-in ids (regression: the
    wire-sim aggregate once regenerated arange ids)."""
    from repro.core.assignment import assignment_matrix

    cfg, sc, state, batch = _setup("roberta-large-lora", M=5)
    index = enumerate_units(state.peft)
    mask = np.asarray(assignment_matrix(index.n_units, 5, 0), np.float32)
    survivors = np.array([0, 1, 3, 4])        # client 2 never scheduled
    plan = _manual_plan(0, survivors, mask[survivors], np.ones(4, bool))
    batch_s = jax.tree.map(lambda x: x[survivors], batch)
    plain = FederationEngine(cfg, sc, comm_mode=mode)
    wired = FederationEngine(cfg, sc, comm_mode=mode,
                             wire=WireConfig(simulate=True))
    sp, _, _ = plain.run_round(state, plan, batch_s)
    sw, _, _ = wired.run_round(state, plan, batch_s)
    assert_trees_equal(sp.peft, sw.peft, mode)


# ---------------------------------------------------------------------------
# Population & scheduler
# ---------------------------------------------------------------------------

def _tiny_data(n=256, classes=4, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 100, size=(n, 16), dtype=np.int64)
    y = rng.integers(0, classes, size=(n,), dtype=np.int64)
    return x, y


def test_population_scales_to_millions_lazily():
    x, y = _tiny_data()
    pop = ClientPopulation(x, y, n_clients=2_000_000, alpha=0.1, seed=0,
                           shard_size=32)
    # touching three arbitrary clients must not materialize anything global
    for cid in (0, 123_456, 1_999_999):
        shard = pop.shard(cid)
        assert len(shard) == 32
        assert (shard < len(y)).all()
    assert len(pop._shards) == 3
    # deterministic on re-touch and across instances
    again = ClientPopulation(x, y, n_clients=2_000_000, alpha=0.1, seed=0,
                             shard_size=32)
    np.testing.assert_array_equal(pop.shard(123_456), again.shard(123_456))
    # different clients get different (heterogeneous) shards
    assert not np.array_equal(pop.shard(0), pop.shard(1_999_999))


def test_population_dirichlet_heterogeneity():
    """Small alpha -> concentrated class mixtures; large alpha -> uniform."""
    x, y = _tiny_data(n=2048, classes=4)
    het = ClientPopulation(x, y, 1000, alpha=0.05, seed=0, shard_size=64)
    hom = ClientPopulation(x, y, 1000, alpha=100.0, seed=0, shard_size=64)

    def top_frac(pop):
        fracs = []
        for cid in range(20):
            labels = y[pop.shard(cid)]
            fracs.append(max(np.bincount(labels, minlength=4)) / len(labels))
        return np.mean(fracs)

    assert top_frac(het) > top_frac(hom) + 0.15


def test_population_batch_and_traces_deterministic():
    x, y = _tiny_data()
    pop = ClientPopulation(x, y, 1000, seed=7)
    bx1, by1 = pop.client_batch(42, 3, 8)
    bx2, by2 = pop.client_batch(42, 3, 8)
    np.testing.assert_array_equal(bx1, bx2)
    assert pop.available(42, 3) == pop.available(42, 3)
    assert pop.latency(42, 3) == pop.latency(42, 3)
    # availability trace varies over rounds for at least some client
    varies = any(len({pop.available(c, r) for r in range(30)}) > 1
                 for c in range(5))
    assert varies
    # device tiers are populated per hash with heterogeneous latency scales
    tiers = {pop.device_tier(c).name for c in range(64)}
    assert len(tiers) > 1


def test_scheduler_overselects_and_cuts_stragglers():
    x, y = _tiny_data()
    pop = ClientPopulation(x, y, 10_000, seed=1)
    sched = CohortScheduler(pop, cohort_size=8, over_select=1.5,
                            dropout_rate=0.1, seed=1)
    plan = sched.plan_round(0, n_units=4, spry_seed=0)
    assert plan.cohort_size == 12          # ceil(8 * 1.5)
    assert plan.n_requested == 8
    assert plan.mask_matrix.shape == (12, 4)
    # every unit still covered by the over-selected cohort
    assert (plan.mask_matrix.sum(0) >= 1).all()
    assert 0 < plan.n_survivors <= 12
    # stragglers beyond the deadline are exactly the non-kept set (unless
    # random dropout also fired)
    late = plan.latencies > plan.deadline
    assert (~plan.keep | ~late).all()      # kept -> not late
    # assignments serialize and rebuild the exact mask rows
    from repro.fl.runtime import TaskAssignment
    for i, a in enumerate(plan.assignments):
        rt = TaskAssignment.from_bytes(a.to_bytes())
        np.testing.assert_array_equal(rt.mask_row(), plan.mask_matrix[i])
    assert plan.downlink_bytes() > 0


def test_scheduler_plan_deterministic():
    x, y = _tiny_data()
    pop = ClientPopulation(x, y, 10_000, seed=1)
    sched = CohortScheduler(pop, cohort_size=4, over_select=1.25, seed=9)
    p1 = sched.plan_round(5, n_units=4, spry_seed=0)
    p2 = sched.plan_round(5, n_units=4, spry_seed=0)
    np.testing.assert_array_equal(p1.client_ids, p2.client_ids)
    np.testing.assert_array_equal(p1.keep, p2.keep)


def test_engine_scheduled_round_end_to_end():
    """Full scheduled path: population -> plan -> padded sharded cohort."""
    if len(jax.devices()) < 8:
        pytest.skip("needs xla_force_host_platform_device_count=8")
    cfg, sc, state, _ = _setup("roberta-large-lora", M=4)
    x, y = _tiny_data(n=512)
    y = y % cfg.n_classes
    x = x % cfg.vocab
    pop = ClientPopulation(x, y, 100_000, alpha=0.1, seed=0, shard_size=32)
    sched = CohortScheduler(pop, cohort_size=5, over_select=1.2,
                            dropout_rate=0.1, seed=0)
    index = enumerate_units(state.peft)
    eng = FederationEngine(cfg, sc, comm_mode="per_epoch",
                           executor=ShardedExecutor(microbatch=1))
    for r in range(2):
        plan = sched.plan_round(r, index.n_units, sc.seed)
        bx, by = sched.round_batch(plan, 2)
        state, metrics, report = eng.run_round(
            state, plan, {"tokens": jnp.asarray(bx),
                          "labels": jnp.asarray(by)})
        assert np.isfinite(float(metrics["loss"]))
        assert report.bytes_up > 0 and report.bytes_down > 0
        assert report.n_devices == 8
        assert report.agg_bytes_streaming < report.agg_bytes_stacked
    assert int(state.round_idx) == 2


# ---------------------------------------------------------------------------
# Scheduler edge cases (ISSUE 10 satellite): diurnal wraparound, empty
# availability windows, pool exhaustion during quorum re-extension
# ---------------------------------------------------------------------------

def test_diurnal_availability_wraps_at_period_boundary():
    """The sinusoidal trace is periodic: rates at round r and r + period
    agree, including across the 'midnight' boundary where the round index
    crosses a period multiple."""
    x, y = _tiny_data()
    pop = ClientPopulation(x, y, 1000, seed=3, avail_period=48)
    for cid in (0, 7, 999):
        for r in (0, 13, 47):            # 47 -> 95 crosses the boundary
            assert pop.availability_rate(cid, r) == pytest.approx(
                pop.availability_rate(cid, r + pop.avail_period), abs=1e-12)
    # planning at rounds period-1, period, period+1 stays well-formed
    sched = CohortScheduler(pop, cohort_size=4, seed=9)
    for r in (47, 48, 49):
        plan = sched.plan_round(r, n_units=4, spry_seed=0)
        assert len(plan.client_ids) == len(set(plan.client_ids.tolist()))
        assert plan.keep.any()


def test_empty_availability_window_falls_back_to_sequential_fill():
    """When every probe comes back unavailable (a dead window), selection
    must still return a full, duplicate-free cohort instead of spinning or
    under-filling."""
    x, y = _tiny_data()
    pop = ClientPopulation(x, y, 64, seed=3)
    pop.available = lambda cid, r: False        # dead window
    sched = CohortScheduler(pop, cohort_size=4, over_select=1.25, seed=9,
                            max_probe=32)
    plan = sched.plan_round(0, n_units=4, spry_seed=0)
    assert len(plan.client_ids) == 5            # ceil(4 * 1.25)
    assert len(set(plan.client_ids.tolist())) == 5
    assert plan.keep.any()                      # never lose a whole round


def test_requorum_pool_exhausted_skips_round():
    """Quorum above what the cohort can ever supply: re-extension drains
    the whole pool, the round is skipped (NaN metrics), the model is
    untouched, and the round index still advances."""
    cfg, sc, state, batch = _setup("roberta-large-lora", M=4)
    x, y = _tiny_data(n=512)
    x, y = x % cfg.vocab, y % cfg.n_classes
    pop = ClientPopulation(x, y, 1000, seed=0)
    sched = CohortScheduler(pop, cohort_size=4, over_select=1.0,
                            deadline=1e-9, seed=0)
    eng = FederationEngine(cfg, sc, comm_mode="per_epoch", quorum=9)
    plan = sched.plan_round(0, enumerate_units(state.peft).n_units, sc.seed)
    keep, requorumed, met = eng._requorum_prejit(plan, 9)
    assert keep.all() and not met        # every pool client activated
    assert requorumed == 4 - int(plan.keep.sum())
    bx, by = sched.round_batch(plan, 2)
    new_state, metrics, report = eng.run_round(
        state, plan, {"tokens": jnp.asarray(bx), "labels": jnp.asarray(by)})
    assert report.round_skipped and not report.quorum_met
    assert np.isnan(float(metrics["loss"]))
    assert_trees_equal(new_state.peft, state.peft, "skip must not update")
    assert int(new_state.round_idx) == int(state.round_idx) + 1


def test_requorum_partial_reextension_meets_quorum():
    """Quorum reachable only by re-activating deadline-cut stragglers: the
    re-extension activates exactly the fastest stragglers, in latency
    order, and the round proceeds."""
    cfg, sc, state, _ = _setup("roberta-large-lora", M=4)
    x, y = _tiny_data(n=512)
    x, y = x % cfg.vocab, y % cfg.n_classes
    pop = ClientPopulation(x, y, 1000, seed=0)
    sched = CohortScheduler(pop, cohort_size=4, over_select=1.0,
                            deadline=1e-9, seed=0)
    eng = FederationEngine(cfg, sc, comm_mode="per_epoch", quorum=3)
    plan = sched.plan_round(0, enumerate_units(state.peft).n_units, sc.seed)
    survivors = int(plan.keep.sum())
    keep, requorumed, met = eng._requorum_prejit(plan, 3)
    assert met and int(keep.sum()) == 3
    assert requorumed == 3 - survivors
    # re-extension picked the FASTEST cut stragglers
    cut = np.flatnonzero(~plan.keep)
    activated = np.flatnonzero(keep & ~plan.keep)
    fastest = cut[np.argsort(plan.latencies[cut], kind="stable")][:requorumed]
    np.testing.assert_array_equal(np.sort(activated), np.sort(fastest))
