"""Batched K-tangent engine: batched/chunked/sequential equivalence, the
multi-tangent lora_dual kernel vs its oracle, and client/server bit-identity
for the per-iteration communication mode (ISSUE 1 acceptance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.forward_grad import (
    forward_gradient,
    reconstruct_gradient,
    stacked_perturbations,
    masked_perturbation,
)
from repro.kernels.lora_dual import (
    lora_dual_mt,
    lora_dual_mt_jvps,
    lora_dual_mt_jvps_ref,
    lora_dual_mt_ref,
)


def quad_loss(w):
    A = jnp.arange(12.0).reshape(3, 4) / 10.0
    r = A @ w["w"] - jnp.ones(3)
    return 0.5 * jnp.sum(r * r) + jnp.sum(w["v"] ** 2)


@pytest.fixture()
def w():
    return {"w": jnp.array([1.0, -2.0, 0.5, 3.0]), "v": jnp.array([0.2, -0.1])}


# ---------------------------------------------------------------------------
# estimator equivalence across tangent_batch settings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [1, 4, 8])
def test_batched_equals_sequential_per_seed(w, rng_key, K):
    """Same seed -> same perturbations -> allclose grads and jvps between the
    one-pass batched path and the sequential fori_loop path."""
    ls, gs, js = forward_gradient(quad_loss, w, rng_key, k_perturbations=K,
                                  tangent_batch=1)
    lb, gb, jb = forward_gradient(quad_loss, w, rng_key, k_perturbations=K,
                                  tangent_batch=None)
    np.testing.assert_allclose(np.asarray(js), np.asarray(jb), rtol=1e-6)
    np.testing.assert_allclose(float(ls), float(lb), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(gs), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-7)


@pytest.mark.parametrize("K,tb", [(4, 2), (8, 4), (6, 4), (5, 2)])
def test_chunked_equals_batched(w, rng_key, K, tb):
    """tangent_batch chunks (incl. non-divisible remainders) reproduce the
    fully batched estimate."""
    _, gb, jb = forward_gradient(quad_loss, w, rng_key, k_perturbations=K)
    _, gc, jc = forward_gradient(quad_loss, w, rng_key, k_perturbations=K,
                                 tangent_batch=tb)
    np.testing.assert_allclose(np.asarray(jc), np.asarray(jb), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(gc), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-7)


def test_stacked_perturbations_bit_identical_to_sequential(w, rng_key):
    """vmap of the PRNG chain must reproduce masked_perturbation bit-for-bit
    per index — the property the per-iteration comm mode relies on."""
    mask = {"w": jnp.ones(()), "v": jnp.zeros(())}
    vs = stacked_perturbations(rng_key, w, jnp.arange(5), mask)
    for i in range(5):
        vi = masked_perturbation(jax.random.fold_in(rng_key, i), w, mask)
        for a, b in zip(jax.tree.leaves(vi),
                        jax.tree.leaves(jax.tree.map(lambda x: x[i], vs))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("K", [1, 3, 8])
def test_client_server_bit_identity(w, rng_key, K):
    """Per-iteration mode: the server rebuild from (seed, jvps) must be
    BIT-identical to the client-side batched estimate (shared stacked
    sampling + combine contraction)."""
    mask = {"w": jnp.ones(()), "v": jnp.ones(())}
    _, g_client, jvps = forward_gradient(quad_loss, w, rng_key,
                                         k_perturbations=K, mask_tree=mask)
    g_server = reconstruct_gradient(w, rng_key, jvps, mask_tree=mask)
    for a, b in zip(jax.tree.leaves(g_client), jax.tree.leaves(g_server)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_client_server_bit_identity_with_clip(w, rng_key):
    _, g_client, jvps = forward_gradient(quad_loss, w, rng_key,
                                         k_perturbations=4, jvp_clip=0.1)
    assert float(jnp.abs(jvps).max()) <= float(jnp.float32(0.1))
    g_server = reconstruct_gradient(w, rng_key, jvps)
    for a, b in zip(jax.tree.leaves(g_client), jax.tree.leaves(g_server)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batched_through_scan_loss(rng_key):
    """The linearize+vmap path must flow through lax.scan model bodies."""
    def loss(w):
        def body(c, x):
            return jnp.tanh(c @ w["m"]) + x, None
        c, _ = jax.lax.scan(body, jnp.ones(3), jnp.zeros((5, 3)))
        return jnp.sum(c ** 2)

    w = {"m": jnp.eye(3) * 0.5}
    _, gs, js = forward_gradient(loss, w, rng_key, k_perturbations=4,
                                 tangent_batch=1)
    _, gb, jb = forward_gradient(loss, w, rng_key, k_perturbations=4)
    np.testing.assert_allclose(np.asarray(js), np.asarray(jb), rtol=1e-5,
                               atol=1e-7)
    for a, b in zip(jax.tree.leaves(gs), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-7)


# ---------------------------------------------------------------------------
# multi-tangent kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T", [1, 4, 16])
@pytest.mark.parametrize("M,K,N,r", [(128, 128, 128, 4), (64, 192, 128, 8)])
def test_lora_dual_mt_allclose(M, K, N, r, T):
    ks = jax.random.split(jax.random.PRNGKey(M + T), 7)
    x = jax.random.normal(ks[0], (M, K))
    xd = jax.random.normal(ks[1], (T, M, K))
    w = jax.random.normal(ks[2], (K, N)) * 0.05
    a = jax.random.normal(ks[3], (K, r)) * 0.05
    ad = jax.random.normal(ks[4], (T, K, r)) * 0.05
    b = jax.random.normal(ks[5], (r, N)) * 0.05
    bd = jax.random.normal(ks[6], (T, r, N)) * 0.05
    y, yds = lora_dual_mt(x, xd, w, a, ad, b, bd, scale=2.0, block_m=64,
                          block_n=64, block_k=64)
    yr, ydr = lora_dual_mt_ref(x, xd, w, a, ad, b, bd, 2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(yds), np.asarray(ydr), atol=1e-3,
                               rtol=1e-3)


@pytest.mark.parametrize("T", [1, 4])
def test_lora_dual_mt_odd_shapes_and_no_xdot(T):
    """Padding path (non-block-multiple shapes) and the xdots=None variant
    (first perturbed unit: input carries no tangent)."""
    M, K, N, r = 111, 94, 77, 3
    ks = jax.random.split(jax.random.PRNGKey(T), 7)
    x = jax.random.normal(ks[0], (M, K))
    xd = jax.random.normal(ks[1], (T, M, K))
    w = jax.random.normal(ks[2], (K, N)) * 0.05
    a = jax.random.normal(ks[3], (K, r)) * 0.05
    ad = jax.random.normal(ks[4], (T, K, r)) * 0.05
    b = jax.random.normal(ks[5], (r, N)) * 0.05
    bd = jax.random.normal(ks[6], (T, r, N)) * 0.05
    for xdots in (xd, None):
        y, yds = lora_dual_mt(x, xdots, w, a, ad, b, bd, scale=1.5,
                              block_m=64, block_n=64, block_k=64)
        yr, ydr = lora_dual_mt_ref(x, xdots, w, a, ad, b, bd, 1.5)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3,
                                   rtol=1e-3)
        np.testing.assert_allclose(np.asarray(yds), np.asarray(ydr),
                                   atol=1e-3, rtol=1e-3)


def test_lora_dual_mt_matches_columnwise_jvp():
    """ydots[t] must equal jax.jvp of the LoRA projection along tangent t —
    the batched pass is exactly K column-by-column jvps fused."""
    M, K, N, r, T = 64, 96, 80, 2, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 7)
    x = jax.random.normal(ks[0], (M, K))
    xd = jax.random.normal(ks[1], (T, M, K))
    w = jax.random.normal(ks[2], (K, N)) * 0.05
    a = jax.random.normal(ks[3], (K, r)) * 0.05
    ad = jax.random.normal(ks[4], (T, K, r)) * 0.05
    b = jax.random.normal(ks[5], (r, N)) * 0.05
    bd = jax.random.normal(ks[6], (T, r, N)) * 0.05

    def f(x_, a_, b_):
        return x_ @ w + 2.0 * (x_ @ a_) @ b_

    y, yds = lora_dual_mt(x, xd, w, a, ad, b, bd, scale=2.0, block_m=64,
                          block_n=64, block_k=64)
    for t in range(T):
        y_ref, yd_ref = jax.jvp(f, (x, a, b), (xd[t], ad[t], bd[t]))
        np.testing.assert_allclose(np.asarray(yds[t]), np.asarray(yd_ref),
                                   atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("with_xdot", [False, True])
def test_lora_dual_mt_jvps_fused_contraction(with_xdot):
    """The reassociated jvp contraction (no (T,M,N) materialization) must
    match contracting the materialized oracle ydots."""
    M, K, N, r, T = 96, 80, 64, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 8)
    x = jax.random.normal(ks[0], (M, K))
    xd = jax.random.normal(ks[1], (T, M, K)) if with_xdot else None
    w = jax.random.normal(ks[2], (K, N)) * 0.05
    a = jax.random.normal(ks[3], (K, r)) * 0.05
    ad = jax.random.normal(ks[4], (T, K, r)) * 0.05
    b = jax.random.normal(ks[5], (r, N)) * 0.05
    bd = jax.random.normal(ks[6], (T, r, N)) * 0.05
    gy = jax.random.normal(ks[7], (M, N))
    jv = lora_dual_mt_jvps(x, w, a, ad, b, bd, gy, scale=2.0, xdots=xd)
    jvr = lora_dual_mt_jvps_ref(x, w, a, ad, b, bd, gy, 2.0, xdots=xd)
    np.testing.assert_allclose(np.asarray(jv), np.asarray(jvr), rtol=1e-4,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# dispatch routing
# ---------------------------------------------------------------------------

def test_dispatch_jnp_vs_interpret_consistent():
    """proj's custom-JVP rule: jnp reference mirror and the interpreted
    Pallas kernel agree under forward-mode AD."""
    from repro.kernels import dispatch
    from repro.kernels.dispatch import lora_proj

    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (4, 24, 48))
    w = jax.random.normal(ks[1], (48, 40)) * 0.05
    A = jax.random.normal(ks[2], (48, 2)) * 0.05
    B = jax.random.normal(ks[3], (2, 40)) * 0.05
    Ad = jax.random.normal(ks[4], (48, 2)) * 0.05
    Bd = jax.random.normal(ks[5], (2, 40)) * 0.05
    outs = {}
    for backend in ("jnp", "interpret"):
        dispatch.set_backend(backend)
        try:
            # the kernel tangent route is gated on the estimator's
            # forward-AD region (no transpose rule on pallas calls)
            with dispatch.forward_ad_region():
                outs[backend] = jax.jvp(
                    lambda a_, b_: lora_proj(x, w, a_, b_, 2.0), (A, B),
                    (Ad, Bd))
        finally:
            dispatch.set_backend(None)
    np.testing.assert_allclose(np.asarray(outs["jnp"][0]),
                               np.asarray(outs["interpret"][0]), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(outs["jnp"][1]),
                               np.asarray(outs["interpret"][1]), atol=1e-4,
                               rtol=1e-4)


def test_reverse_mode_works_on_kernel_backends():
    """jax.grad through lora_proj must work on every backend (the backprop
    baselines differentiate through proj in reverse mode; outside the
    forward-AD region the rule must trace the transposable jnp mirror)."""
    from repro.kernels import dispatch
    from repro.kernels.dispatch import lora_proj

    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (16, 24))
    w = jax.random.normal(ks[1], (24, 20)) * 0.05
    A = jax.random.normal(ks[2], (24, 2)) * 0.05
    B = jax.random.normal(ks[3], (2, 20)) * 0.05

    def loss(a_, b_):
        return jnp.sum(lora_proj(x, w, a_, b_, 2.0) ** 2)

    g_ref = jax.grad(loss, argnums=(0, 1))(A, B)
    for backend in ("interpret", "pallas"):
        dispatch.set_backend(backend)
        try:
            g = jax.grad(loss, argnums=(0, 1))(A, B)
        finally:
            dispatch.set_backend(None)
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


def test_proj_routes_through_dispatch(monkeypatch):
    """models.common.proj must call the dispatch layer for LoRA projections."""
    from repro.kernels import dispatch
    from repro.models.common import proj

    calls = []
    real = dispatch.lora_proj

    def spy(x, w, a, b, scale):
        calls.append(scale)
        return real(x, w, a, b, scale)

    import repro.models.common as common
    monkeypatch.setattr(common, "lora_proj", spy)
    x = jnp.ones((2, 8))
    w = jnp.ones((8, 4))
    lora = {"A": jnp.ones((8, 1)), "B": jnp.zeros((1, 4))}
    proj(x, w, lora=lora, lora_scale=3.0)
    assert calls == [3.0]
    proj(x, w)                      # no LoRA -> no dispatch
    assert calls == [3.0]
