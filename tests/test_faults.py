"""Fault injection + the engine's defensive stack.

Key contracts: the injector is deterministic in (seed, client, round,
attempt); a chaos engine with all rates at zero is BITWISE the clean
wire-sim engine; a quarantined/poisoned client is BITWISE equivalent to
that client having been excluded from the round; duplicates dedupe away.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SpryConfig, get_config, reduce_config
from repro.core import enumerate_units, init_state
from repro.core.assignment import assignment_matrix
from repro.fl.runtime import (
    CohortPlan,
    FederationEngine,
    FaultConfig,
    FaultInjector,
    WireConfig,
)
from repro.models import get_model
from repro.peft import init_peft


def _setup(arch="roberta-large-lora", M=5, B=2, S=16, k=2):
    cfg = reduce_config(get_config(arch))
    sc = SpryConfig(n_clients_per_round=M, local_iters=1, local_lr=1e-2,
                    server_lr=1e-2, k_perturbations=k)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)
    batch = {"tokens": jax.random.randint(key, (M, B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (M, B), 0, cfg.n_classes)}
    return cfg, sc, state, batch


def _plan(round_idx, M, n_units, keep=None, latencies=None):
    mask = np.asarray(assignment_matrix(n_units, M, round_idx % M),
                      np.float32)
    return CohortPlan(
        round_idx=round_idx, client_ids=np.arange(M, dtype=np.int64),
        seed_ids=np.arange(M, dtype=np.int32), mask_matrix=mask,
        latencies=(np.zeros(M) if latencies is None
                   else np.asarray(latencies, np.float64)),
        deadline=float("inf"),
        keep=(np.ones(M, bool) if keep is None else np.asarray(keep, bool)),
        assignments=[], n_requested=M)


def assert_trees_equal(a, b, what=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


# ---------------------------------------------------------------------------
# injector unit behaviour
# ---------------------------------------------------------------------------

def test_injector_deterministic_replay():
    cfg = FaultConfig(crash_rate=0.3, corrupt_rate=0.4, loss_rate=0.3,
                      seed=7)
    frame = bytes(range(256)) * 4
    a, b = FaultInjector(cfg), FaultInjector(cfg)
    for cid in range(40):
        for r in range(3):
            assert a.crashes(cid, r) == b.crashes(cid, r)
            da, na, ba = a.transmit(frame, cid, r)
            db, nb, bb = b.transmit(frame, cid, r)
            assert da == db and na == nb and ba == bb
    assert dataclasses.asdict(a.take_counters()) == \
        dataclasses.asdict(b.take_counters())


def test_transmit_retry_bounds_and_loss():
    inj = FaultInjector(FaultConfig(loss_rate=1.0, max_retries=3,
                                    backoff_base=0.5, seed=0))
    delivered, attempts, backoff = inj.transmit(b"x" * 64, 0, 0)
    assert delivered == [] and attempts == 3
    assert backoff == pytest.approx(0.5 + 1.0)   # 0.5 * 2**0 + 0.5 * 2**1
    assert inj.counters.lost == 1 and inj.counters.retries == 2

    inj = FaultInjector(FaultConfig(loss_rate=0.0, max_retries=3, seed=0))
    delivered, attempts, backoff = inj.transmit(b"x" * 64, 0, 0)
    assert delivered == [b"x" * 64] and attempts == 1 and backoff == 0.0


def test_crash_tier_scaling():
    inj = FaultInjector(FaultConfig(crash_rate=0.5, seed=1))
    assert not inj.crashes(0, 0, scale=0.0)     # scaled to rate 0
    inj = FaultInjector(FaultConfig(crash_rate=0.5, seed=1))
    assert all(inj.crashes(c, 0, scale=1e9) for c in range(20))  # rate -> 1
    # higher tier scale can only increase the per-client crash set
    lo = FaultInjector(FaultConfig(crash_rate=0.2, seed=3))
    hi = FaultInjector(FaultConfig(crash_rate=0.2, seed=3))
    crashed_lo = {c for c in range(200) if lo.crashes(c, 0, scale=0.5)}
    crashed_hi = {c for c in range(200) if hi.crashes(c, 0, scale=2.5)}
    assert crashed_lo < crashed_hi


def test_mangle_never_a_noop():
    inj = FaultInjector(FaultConfig(corrupt_rate=1.0, seed=5))
    frame = bytes(range(200))
    for i in range(30):
        out = inj._mangle(frame, np.random.default_rng(i))
        assert out != frame


def test_parse_presets_and_specs():
    assert not FaultConfig.parse("off").any_faults
    assert not FaultConfig.parse(None).any_faults
    agg = FaultConfig.parse("aggressive", seed=9)
    assert agg.any_faults and agg.seed == 9 and agg.crash_rate > 0
    c = FaultConfig.parse("crash_rate=0.1,loss_rate=0.25,max_retries=5")
    assert (c.crash_rate, c.loss_rate, c.max_retries) == (0.1, 0.25, 5)
    with pytest.raises(ValueError):
        FaultConfig.parse("bogus_knob=1")
    with pytest.raises(ValueError):
        FaultConfig(crash_rate=1.5)


def test_poison_array_modes():
    inj = FaultInjector(FaultConfig(nan_rate=1.0, blowup_scale=1e6))
    a = np.ones((8,), np.float32)
    nan = inj.poison_array(a, "nan")
    assert np.isnan(nan).any() and not np.isnan(a).any()
    blown = inj.poison_array(a, "blowup")
    assert np.abs(blown).max() == pytest.approx(1e6)
    zeros = inj.poison_array(np.zeros((4,), np.float32), "blowup")
    assert np.abs(zeros).max() > 0     # all-zero payload still outliers


def test_faults_require_wire_simulation():
    cfg, sc, _, _ = _setup(M=2)
    with pytest.raises(ValueError):
        FederationEngine(cfg, sc, comm_mode="per_epoch",
                         faults=FaultConfig(crash_rate=0.5))


# ---------------------------------------------------------------------------
# engine-level chaos + quorum contracts
#
# Engine construction compiles the jitted round bodies, so ALL tests below
# share two module-scoped engines (clean wire-sim reference + chaos) and
# swap the injector / quorum knobs per test — the jits don't depend on
# either.
# ---------------------------------------------------------------------------

J = 4          # target client: shares unit 0 with client 0 (M=5 > U=4)


@pytest.fixture(scope="module")
def ctx():
    cfg, sc, state, batch = _setup()
    index = enumerate_units(state.peft)
    plan = _plan(0, 5, index.n_units)
    keep_excl = np.ones(5, bool)
    keep_excl[J] = False
    ref = FederationEngine(cfg, sc, comm_mode="per_epoch",
                           wire=WireConfig(simulate=True))
    chaos = FederationEngine(cfg, sc, comm_mode="per_epoch",
                             wire=WireConfig(simulate=True),
                             faults=FaultConfig(seed=3))
    # reference runs shared by the bitwise-exclusion tests below
    full = ref.run_round(state, plan, batch)
    excl = ref.run_round(state, _plan(0, 5, index.n_units, keep=keep_excl),
                         batch)
    ns = type("Ctx", (), {})()
    ns.cfg, ns.sc, ns.state, ns.batch = cfg, sc, state, batch
    ns.index, ns.plan, ns.ref, ns.chaos = index, plan, ref, chaos
    ns.full, ns.excl, ns.keep_excl = full, excl, keep_excl
    return ns


def _arm(eng, faults=None, quorum=None):
    """Swap the chaos knobs on a shared engine (jits are knob-independent)."""
    if isinstance(faults, FaultConfig):
        faults = FaultInjector(faults)
    eng.faults = faults
    eng.quorum = quorum
    return eng


def test_zero_rate_chaos_bitwise_equals_clean_wire(ctx):
    """The chaos plumbing itself is neutral: all rates 0 => bitwise equal
    to the plain simulated wire."""
    eng = _arm(ctx.chaos, FaultConfig(seed=3))
    s2, m2, r2 = eng.run_round(ctx.state, ctx.plan, ctx.batch)
    s1, m1, r1 = ctx.full
    assert_trees_equal(s1.peft, s2.peft, "peft")
    assert_trees_equal(s1.server, s2.server, "server")
    assert_trees_equal(m1, m2, "metrics")
    assert r2.health.validated == r2.n_validated == r1.n_survivors
    assert r2.health.quarantined == 0 and r2.dropped_frame_ids == []
    assert r1.bytes_up == r2.bytes_up


def test_zero_rate_chaos_bitwise_per_iteration():
    """Same neutrality for the jvp wire (separate engines: other jits)."""
    cfg, sc, state, batch = _setup()
    index = enumerate_units(state.peft)
    plan = _plan(0, 5, index.n_units)
    clean = FederationEngine(cfg, sc, comm_mode="per_iteration",
                             wire=WireConfig(simulate=True))
    s1, m1, _ = clean.run_round(state, plan, batch)
    chaos = FederationEngine(cfg, sc, comm_mode="per_iteration",
                             wire=WireConfig(simulate=True),
                             faults=FaultConfig(seed=3))
    s2, m2, r2 = chaos.run_round(state, plan, batch)
    assert_trees_equal(s1.peft, s2.peft, "peft")
    assert_trees_equal(m1, m2, "metrics")
    assert r2.n_validated == 5 and not r2.round_skipped


class _TargetCorrupt(FaultInjector):
    """Deterministically corrupt exactly one client's frame."""

    def __init__(self, target):
        super().__init__(FaultConfig(seed=0))
        self.target = target

    def transmit(self, frame, client_id, round_idx):
        if client_id == self.target:
            bad = bytearray(frame)
            bad[len(bad) // 2] ^= 0x10
            self.counters.corrupted += 1
            return [bytes(bad)], 1, 0.0
        return [frame], 1, 0.0


class _TargetPoison(FaultInjector):
    """Deterministically NaN-poison exactly one client's payload."""

    def __init__(self, target):
        super().__init__(FaultConfig(seed=0))
        self.target = target

    def poison_mode(self, client_id, round_idx):
        return "nan" if client_id == self.target else None


class _TargetBlowup(FaultInjector):
    """Finite but absurd payload for one client (norm-outlier case)."""

    def __init__(self, target):
        super().__init__(FaultConfig(blowup_scale=1e8, seed=0))
        self.target = target

    def poison_mode(self, client_id, round_idx):
        return "blowup" if client_id == self.target else None


class _TargetDuplicate(FaultInjector):
    """Deliver exactly one client's frame twice."""

    def __init__(self, target):
        super().__init__(FaultConfig(seed=0))
        self.target = target

    def transmit(self, frame, client_id, round_idx):
        if client_id == self.target:
            self.counters.duplicated += 1
            return [frame, frame], 1, 0.0
        return [frame], 1, 0.0


@pytest.mark.parametrize("injector_cls,health_field",
                         [(_TargetCorrupt, "quarantined"),
                          (_TargetPoison, "invalid"),
                          (_TargetBlowup, "invalid")])
def test_bad_client_bitwise_equals_excluded_client(ctx, injector_cls,
                                                   health_field):
    """A quarantined (corrupt frame) or rejected (NaN / norm-outlier
    payload) client is aggregated EXACTLY as if its update never arrived."""
    eng = _arm(ctx.chaos, injector_cls(J))
    sd, md, rd = eng.run_round(ctx.state, ctx.plan, ctx.batch)
    assert getattr(rd.health, health_field) == 1
    assert rd.n_validated == 4
    assert rd.dropped_frame_ids == [J]

    se, me, _ = ctx.excl
    assert_trees_equal(sd.peft, se.peft, "peft")
    assert_trees_equal(sd.server, se.server, "server")
    assert_trees_equal(md, me, "metrics")


def test_duplicate_frames_deduped_bitwise(ctx):
    eng = _arm(ctx.chaos, _TargetDuplicate(2))
    sd, md, rd = eng.run_round(ctx.state, ctx.plan, ctx.batch)
    assert rd.health.duplicates == 1 and rd.n_validated == 5
    se, me, _ = ctx.full
    assert_trees_equal(sd.peft, se.peft, "peft")
    assert_trees_equal(md, me, "metrics")


def test_all_poisoned_round_skips_server_step(ctx):
    """Every payload NaN'd + quorum: the server step must be skipped and
    the state carried forward untouched (except the round index)."""
    eng = _arm(ctx.chaos, FaultConfig(nan_rate=1.0, seed=0), quorum=1.0)
    s2, m2, r2 = eng.run_round(ctx.state, ctx.plan, ctx.batch)
    assert r2.round_skipped and not r2.quorum_met
    assert r2.quorum == 5                      # ceil(1.0 * n_requested)
    assert r2.n_validated == 0 and r2.health.invalid == 5
    assert_trees_equal(ctx.state.peft, s2.peft, "peft must be untouched")
    assert_trees_equal(ctx.state.server, s2.server, "server untouched")
    assert int(s2.round_idx) == int(ctx.state.round_idx) + 1
    assert np.isnan(float(m2["loss"]))


def test_total_loss_skips_round(ctx):
    """loss_rate=1: every frame exhausts its retries; below quorum the
    round is skipped and every attempt still burned uplink bytes."""
    eng = _arm(ctx.chaos, FaultConfig(loss_rate=1.0, max_retries=2, seed=0),
               quorum=1)
    s2, m2, r2 = eng.run_round(ctx.state, ctx.plan, ctx.batch)
    assert r2.round_skipped and r2.health.lost == 5
    assert r2.health.transmissions == 10       # 5 clients x 2 attempts
    assert r2.bytes_up > 0                     # lost frames still cost bytes
    assert sorted(r2.dropped_frame_ids) == [0, 1, 2, 3, 4]
    assert_trees_equal(ctx.state.peft, s2.peft, "peft")


def test_chaos_replay_is_deterministic(ctx):
    """Same chaos seed + same plan => identical chaotic round, including the
    health tally (the crash-resume precondition)."""
    fc = FaultConfig(crash_rate=0.3, corrupt_rate=0.4, loss_rate=0.3,
                     nan_rate=0.2, seed=11)
    runs = []
    for _ in range(2):
        eng = _arm(ctx.chaos, fc)
        runs.append(eng.run_round(ctx.state, ctx.plan, ctx.batch))
    (s1, m1, r1), (s2, m2, r2) = runs
    assert_trees_equal(s1.peft, s2.peft, "peft")
    assert_trees_equal(m1, m2, "metrics")
    assert dataclasses.asdict(r1.health) == dataclasses.asdict(r2.health)
    assert r1.bytes_up == r2.bytes_up
    assert r1.dropped_frame_ids == r2.dropped_frame_ids


# ---------------------------------------------------------------------------
# quorum gate (clean path — no faults)
# ---------------------------------------------------------------------------

def test_clean_requorum_bitwise_equals_manual_extension(ctx):
    """Below quorum, the clean path re-extends the survivor set from the
    pool in latency order — bitwise the same round as a plan that simply
    kept those clients."""
    lat = np.array([1.0, 2.0, 3.0, 9.0, 4.0])
    keep = np.array([True, True, False, False, False])
    plan = _plan(0, 5, ctx.index.n_units, keep=keep, latencies=lat)
    eng = _arm(ctx.ref, quorum=4)
    sq, mq, rq = eng.run_round(ctx.state, plan, ctx.batch)
    _arm(ctx.ref)
    # pool latency order is [2 (3.0), 4 (4.0), 3 (9.0)] -> extend 2 then 4
    manual = np.array([True, True, True, False, True])
    sm, mm, rm = ctx.ref.run_round(
        ctx.state, _plan(0, 5, ctx.index.n_units, keep=manual,
                         latencies=lat), ctx.batch)
    assert rq.health.requorumed == 2 and rq.quorum_met
    assert rq.n_validated == 4 and not rq.round_skipped
    assert_trees_equal(sq.peft, sm.peft, "peft")
    assert_trees_equal(sq.server, sm.server, "server")
    assert_trees_equal(mq, mm, "metrics")
    assert rq.bytes_up == rm.bytes_up


def test_clean_quorum_exhausted_skips_round(ctx):
    """Quorum above cohort + pool: skip, state untouched, NaN metrics."""
    eng = _arm(ctx.ref, quorum=6)
    s2, m2, r2 = eng.run_round(ctx.state, ctx.plan, ctx.batch)
    _arm(ctx.ref)
    assert r2.round_skipped and not r2.quorum_met and r2.quorum == 6
    assert r2.n_validated == 0 and r2.bytes_up == 0
    assert_trees_equal(ctx.state.peft, s2.peft, "peft")
    assert int(s2.round_idx) == int(ctx.state.round_idx) + 1
    assert all(np.isnan(float(v)) for k, v in m2.items()
               if k != "fused_route")


def test_quorum_fraction_resolution(ctx):
    """quorum=0.8 over 5 requested resolves to 4; a full cohort meets it
    without re-extension and reports it."""
    eng = _arm(ctx.ref, quorum=0.8)
    _, _, r = eng.run_round(ctx.state, ctx.plan, ctx.batch)
    _arm(ctx.ref)
    assert r.quorum == 4 and r.quorum_met and not r.round_skipped
    assert r.health.requorumed == 0 and r.health.validated == 5


def test_device_tier_crash_scales_in_plan():
    from repro.fl.runtime import ClientPopulation, CohortScheduler
    rng = np.random.default_rng(0)
    x = rng.integers(0, 50, size=(64, 16))
    y = rng.integers(0, 2, size=(64,))
    pop = ClientPopulation(x, y, n_clients=32, seed=0)
    sched = CohortScheduler(pop, cohort_size=8, seed=0)
    plan = sched.plan_round(0, n_units=4, spry_seed=0)
    assert plan.crash_scales is not None
    assert plan.crash_scales.shape == plan.client_ids.shape
    tiers = {t.crash_scale for t in pop.tiers}
    assert set(np.unique(plan.crash_scales)) <= tiers
