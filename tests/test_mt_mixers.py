"""Multi-tangent wkv6/swa kernels + estimator dispatch (ISSUE 2).

Covers: mt-kernel oracles (allclose vs jax.jvp of the jnp reference, and
BITWISE equality of T stacked tangents vs T single-tangent kernel passes),
the GQA no-repeat kernel path vs the model's contiguous-group convention,
the forced padded-lane dataflow under interpret, and the dispatch routing —
vmap of tangents inside ``forward_ad_region()`` must trace ONE multi-tangent
pallas_call (leading T=K axis), not the Pallas default vmap lowering.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import pallas_calls
from repro.core.forward_grad import forward_gradient
from repro.kernels import dispatch
from repro.kernels.swa_attention import (
    swa_attention,
    swa_attention_gqa_ref,
    swa_attention_mt,
    swa_attention_mt_ref,
    swa_attention_mt_tangents,
    swa_attention_ref,
)
from repro.kernels.wkv6_scan import (
    wkv6_scan_mt,
    wkv6_scan_mt_ref,
    wkv6_scan_mt_tangents,
)


def _wkv_problem(B=2, S=96, H=2, hd=16, T=3, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 10)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, hd)) * 0.3 for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    rd, kd, vd = (jax.random.normal(ks[5 + i], (T, B, S, H, hd)) * 0.3
                  for i in range(3))
    wd = jax.random.normal(ks[8], (T, B, S, H, hd)) * 0.1
    ud = jax.random.normal(ks[9], (T, H, hd)) * 0.3
    return (r, k, v, w, u), (rd, kd, vd, wd, ud)


def _swa_problem(B=1, H=4, KV=2, S=128, hd=32, T=3, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    qd = jax.random.normal(ks[3], (T, B, H, S, hd))
    kd = jax.random.normal(ks[4], (T, B, KV, S, hd))
    vd = jax.random.normal(ks[5], (T, B, KV, S, hd))
    return (q, k, v), (qd, kd, vd)


# ---------------------------------------------------------------------------
# wkv6 multi-tangent kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_ud", [False, True])
def test_wkv6_mt_matches_jvp_oracle(with_ud):
    (r, k, v, w, u), (rd, kd, vd, wd, ud) = _wkv_problem()
    uds = ud if with_ud else None
    y, yds = wkv6_scan_mt(r, k, v, w, u, rd, kd, vd, wd, uds, block_s=32)
    yr, ydr = wkv6_scan_mt_ref(r, k, v, w, u, rd, kd, vd, wd, uds)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(yds), np.asarray(ydr), atol=1e-5,
                               rtol=1e-5)


def test_wkv6_mt_odd_seq_padding():
    """Non-block-multiple S exercises the padded-step state preservation
    (w=1 keeps S, wd=0/kvd=0 keep every Sd)."""
    (r, k, v, w, u), (rd, kd, vd, wd, ud) = _wkv_problem(S=75)
    y, yds = wkv6_scan_mt(r, k, v, w, u, rd, kd, vd, wd, ud, block_s=32)
    yr, ydr = wkv6_scan_mt_ref(r, k, v, w, u, rd, kd, vd, wd, ud)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(yds), np.asarray(ydr), atol=1e-5,
                               rtol=1e-5)


def test_wkv6_mt_stacked_bitwise_equals_single_tangent_passes():
    """T stacked tangents must be BITWISE equal to T single-tangent kernel
    passes (each tangent lane runs the exact T=1 op sequence on independent
    scratch) — the batched estimate is exactly K column-by-column jvps."""
    (r, k, v, w, u), (rd, kd, vd, wd, ud) = _wkv_problem()
    T = rd.shape[0]
    yds = wkv6_scan_mt_tangents(r, k, v, w, u, rd, kd, vd, wd, ud, block_s=32)
    for t in range(T):
        one = wkv6_scan_mt_tangents(r, k, v, w, u, rd[t:t + 1], kd[t:t + 1],
                                    vd[t:t + 1], wd[t:t + 1], ud[t:t + 1],
                                    block_s=32)
        np.testing.assert_array_equal(np.asarray(yds[t]), np.asarray(one[0]))


def test_wkv6_mt_tangents_match_full_pass():
    (r, k, v, w, u), (rd, kd, vd, wd, ud) = _wkv_problem(seed=5)
    _, yds = wkv6_scan_mt(r, k, v, w, u, rd, kd, vd, wd, ud, block_s=32)
    ydt = wkv6_scan_mt_tangents(r, k, v, w, u, rd, kd, vd, wd, ud, block_s=32)
    np.testing.assert_array_equal(np.asarray(yds), np.asarray(ydt))


# ---------------------------------------------------------------------------
# swa multi-tangent kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 48, 96])
def test_swa_mt_matches_jvp_oracle(window):
    (q, k, v), (qd, kd, vd) = _swa_problem()
    out, outds = swa_attention_mt(q, k, v, qd, kd, vd, window=window,
                                  block_q=64, block_k=64)
    outr, outdr = swa_attention_mt_ref(q, k, v, qd, kd, vd, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(outds), np.asarray(outdr),
                               atol=2e-3, rtol=2e-3)


def test_swa_mt_odd_seq_padding():
    """Non-block-multiple S exercises the query/key zero-padding (padded
    keys sit beyond every real query's causal band)."""
    (q, k, v), (qd, kd, vd) = _swa_problem(S=100, seed=7)
    out, outds = swa_attention_mt(q, k, v, qd, kd, vd, window=48, block_q=64,
                                  block_k=64)
    outr, outdr = swa_attention_mt_ref(q, k, v, qd, kd, vd, window=48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(outds), np.asarray(outdr),
                               atol=2e-3, rtol=2e-3)
    out2 = swa_attention(q, k, v, window=48, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(outr), atol=2e-3,
                               rtol=2e-3)


def test_swa_mixed_blocks_no_padding_explosion():
    """Clamped block sizes that don't nest (S=100 -> bq=100, bk=64) must not
    lcm-explode the padded sequence; the plan clamps to the smaller block."""
    from repro.kernels.swa_attention.ops import _block_plan
    bq, bk, pad_s = _block_plan(100, 128, 64)
    assert (bq, bk) == (64, 64) and pad_s == 28
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 100, 32))
    k = jax.random.normal(ks[1], (1, 2, 100, 32))
    v = jax.random.normal(ks[2], (1, 2, 100, 32))
    out = swa_attention(q, k, v, window=48, block_q=128, block_k=64)
    ref = swa_attention_ref(q, k, v, window=48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=2e-3)


def test_swa_mt_stacked_bitwise_equals_single_tangent_passes():
    (q, k, v), (qd, kd, vd) = _swa_problem()
    T = qd.shape[0]
    outds = swa_attention_mt_tangents(q, k, v, qd, kd, vd, window=48,
                                      block_q=64, block_k=64)
    for t in range(T):
        one = swa_attention_mt_tangents(q, k, v, qd[t:t + 1], kd[t:t + 1],
                                        vd[t:t + 1], window=48, block_q=64,
                                        block_k=64)
        np.testing.assert_array_equal(np.asarray(outds[t]),
                                      np.asarray(one[0]))


# ---------------------------------------------------------------------------
# GQA without K/V materialization (ISSUE 2 satellite bugfix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 2), (6, 3)])
def test_swa_gqa_parity_with_model_convention(H, KV):
    """The in-grid head mapping must agree with the model's contiguous-group
    jnp.repeat convention (models/attention.py::_sdpa) — head h reads kv
    head h // (H//KV) — with K/V never repeated in HBM on the kernel path."""
    ks = jax.random.split(jax.random.PRNGKey(H * 10 + KV), 3)
    B, S, hd, W = 2, 128, 32, 48
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    out = swa_attention(q, k, v, window=W, block_q=64, block_k=64)
    rep = H // KV
    ref = swa_attention_ref(q, jnp.repeat(k, rep, axis=1),
                            jnp.repeat(v, rep, axis=1), window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=2e-3)
    refg = swa_attention_gqa_ref(q, k, v, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refg), atol=2e-3,
                               rtol=2e-3)


def test_swa_kernel_path_has_no_repeat():
    """The H/KV× K/V materialization must be gone from the kernel path: the
    pallas_call's k/v operands stay at (B*KV, S, hd), and no repeat
    primitive appears anywhere in the traced jaxpr."""
    B, H, KV, S, hd = 1, 8, 2, 128, 128   # hd=128: no lane pad in the trace
    q = jnp.zeros((B, H, S, hd))
    k = jnp.zeros((B, KV, S, hd))
    v = jnp.zeros((B, KV, S, hd))
    jaxpr = jax.make_jaxpr(
        lambda q_, k_, v_: swa_attention(q_, k_, v_, window=48, block_q=64,
                                         block_k=64))(q, k, v)

    def walk(j):
        for eqn in j.eqns:
            yield eqn
            for p in eqn.params.values():
                inner = getattr(p, "jaxpr", None)
                if inner is not None:
                    yield from walk(inner if hasattr(inner, "eqns")
                                    else inner.jaxpr)

    calls = []
    for eqn in walk(jaxpr.jaxpr):
        assert "repeat" not in eqn.primitive.name, eqn
        if eqn.primitive.name == "pallas_call":
            calls.append(eqn)
    assert len(calls) == 1
    q_aval, k_aval, v_aval = [var.aval for var in calls[0].invars[-3:]]
    assert q_aval.shape == (B * H, S, hd)
    assert k_aval.shape == (B * KV, S, hd), "k was widened before the kernel"
    assert v_aval.shape == (B * KV, S, hd), "v was widened before the kernel"


@pytest.mark.parametrize("hd", [96, 72])
def test_swa_forced_pad_hd_under_interpret(hd):
    """hd not a multiple of 128: forcing the lane pad under interpret must
    exercise the padded dataflow and still match the unpadded oracle."""
    ks = jax.random.split(jax.random.PRNGKey(hd), 3)
    B, H, S, W = 1, 2, 128, 64
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    out = swa_attention(q, k, v, window=W, block_q=64, block_k=64,
                        force_pad_hd=True)
    ref = swa_attention_ref(q, k, v, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=2e-3)
    # the pad must actually be live: the kernel input gains padded lanes
    jaxpr = jax.make_jaxpr(
        lambda q_, k_, v_: swa_attention(q_, k_, v_, window=W, block_q=64,
                                         block_k=64, force_pad_hd=True))(
        q, k, v)
    assert f"{128 * ((hd + 127) // 128)}" in str(jaxpr)


def test_swa_mt_forced_pad_hd():
    (q, k, v), (qd, kd, vd) = _swa_problem(hd=48)
    out, outds = swa_attention_mt(q, k, v, qd, kd, vd, window=48, block_q=64,
                                  block_k=64, force_pad_hd=True)
    outr, outdr = swa_attention_mt_ref(q, k, v, qd, kd, vd, window=48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(outds), np.asarray(outdr),
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# dispatch: estimator routing (vmap-of-tangents -> ONE mt pallas_call)
# ---------------------------------------------------------------------------

def test_vmap_of_lora_tangents_traces_mt_route():
    """vmap of lora_proj tangents inside forward_ad_region() must lower to
    the multi-tangent kernel directly — ONE pallas_call whose tangent output
    carries the leading K axis (3-dim (K, M, N)) — and NOT the Pallas
    default vmap lowering of the T=1 kernel (which re-grids to a 4-dim
    (K, 1, M, N) output and recomputes per-tangent)."""
    K = 5
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (8, 48))
    w = jax.random.normal(ks[1], (48, 40)) * 0.05
    peft = {"A": jax.random.normal(ks[2], (48, 2)) * 0.05,
            "B": jax.random.normal(ks[3], (2, 40)) * 0.05}

    def loss_of(p):
        y = dispatch.lora_proj(x, w, p["A"], p["B"], 2.0)
        return jnp.mean(y * y)

    dispatch.set_backend("interpret")
    try:
        with dispatch.forward_ad_region():
            _, tangent_map = jax.linearize(loss_of, peft)
        vs = {"A": jnp.zeros((K,) + peft["A"].shape),
              "B": jnp.zeros((K,) + peft["B"].shape)}
        jaxpr = jax.make_jaxpr(jax.vmap(tangent_map))(vs)
    finally:
        dispatch.set_backend(None)

    calls = pallas_calls(jaxpr)
    assert len(calls) == 1, f"expected ONE fused mt pallas_call, got {calls}"
    (out_aval,) = [v.aval for v in calls[0].outvars]
    assert out_aval.ndim == 3 and out_aval.shape[0] == K, (
        f"tangent output {out_aval.shape} is not the (K, M, N) mt contract "
        "— the default Pallas batching rule was used")


@pytest.mark.parametrize("mixer", ["wkv6", "swa"])
def test_vmap_of_mixer_tangents_traces_mt_route(mixer):
    """Same routing assertion for the sequence mixers: the batched
    estimator's vmap must hit wkv6_scan_mt_tangents /
    swa_attention_mt_tangents (leading-K tangent outputs), not a re-gridded
    T=1 kernel."""
    K = 4
    if mixer == "wkv6":
        (r, k, v, w, u), _ = _wkv_problem(B=1, S=32, H=2, hd=8, T=1)

        def f(rkv):
            return jnp.mean(
                dispatch.wkv6_mix(rkv["r"], rkv["k"], rkv["v"], w, u) ** 2)

        prim = {"r": r, "k": k, "v": v}
    else:
        (q, kk, vv), _ = _swa_problem(B=1, H=2, KV=2, S=64, hd=8, T=1)

        def f(rkv):
            return jnp.mean(
                dispatch.swa_attend(rkv["q"], rkv["k"], rkv["v"], 32) ** 2)

        prim = {"q": q, "k": kk, "v": vv}

    dispatch.set_backend("interpret")
    try:
        with dispatch.forward_ad_region():
            _, tangent_map = jax.linearize(f, prim)
        vs = jax.tree.map(lambda t: jnp.zeros((K,) + t.shape), prim)
        jaxpr = jax.make_jaxpr(jax.vmap(tangent_map))(vs)
    finally:
        dispatch.set_backend(None)

    calls = pallas_calls(jaxpr)
    assert len(calls) == 1, f"expected ONE fused mt pallas_call, got {calls}"
    (out_aval,) = [v.aval for v in calls[0].outvars]
    assert out_aval.shape[0] == K, (
        f"tangent output {out_aval.shape} does not carry the leading K axis")


@pytest.mark.parametrize("mixer", ["wkv6", "swa"])
def test_mixer_estimator_batched_jvps_bitwise_equal_sequential(mixer):
    """The batched K-tangent estimate through a dispatched mixer must give
    jvps BITWISE equal to the sequential tangent_batch=1 run (the
    column-by-column baseline) on the interpret backend — per-tangent kernel
    lanes are exact replicas of the T=1 pass."""
    ks = jax.random.split(jax.random.PRNGKey(2), 8)
    B, S, H, hd = 1, 64, 2, 16
    D = H * hd
    x = jax.random.normal(ks[0], (B, S, D)) * 0.3
    wp = [jax.random.normal(ks[1 + i], (D, D)) * 0.05 for i in range(3)]
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    wdec = jax.nn.sigmoid(jax.random.normal(ks[5], (B, S, H, hd)))
    peft = {"A": jax.random.normal(ks[6], (D, 2)) * 0.05,
            "B": jax.random.normal(ks[7], (2, D)) * 0.05}

    def loss(p):
        r = dispatch.lora_proj(x, wp[0], p["A"], p["B"], 2.0)
        k = (x @ wp[1]).reshape(B, S, H, hd)
        v = (x @ wp[2]).reshape(B, S, H, hd)
        if mixer == "wkv6":
            y = dispatch.wkv6_mix(r.reshape(B, S, H, hd), k, v, wdec, u)
        else:
            y = dispatch.swa_attend(
                r.reshape(B, S, H, hd).transpose(0, 2, 1, 3),
                k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), 32)
        return jnp.mean(y * y)

    key = jax.random.PRNGKey(9)
    dispatch.set_backend("interpret")
    try:
        _, _, j_seq = forward_gradient(loss, peft, key, k_perturbations=4,
                                       tangent_batch=1)
        _, _, j_bat = forward_gradient(loss, peft, key, k_perturbations=4)
    finally:
        dispatch.set_backend(None)
    np.testing.assert_array_equal(np.asarray(j_seq), np.asarray(j_bat))


def test_mixers_not_dispatched_outside_region_or_on_jnp():
    """Outside forward_ad_region(), and on the jnp backend, the model paths
    must stay on their native scan/chunked implementations (reverse-mode
    baselines depend on transposability)."""
    assert not dispatch.use_kernel_mixers()
    dispatch.set_backend("jnp")
    try:
        with dispatch.forward_ad_region():
            assert not dispatch.use_kernel_mixers()
    finally:
        dispatch.set_backend(None)
    dispatch.set_backend("interpret")
    try:
        assert not dispatch.use_kernel_mixers()
        with dispatch.forward_ad_region():
            assert dispatch.use_kernel_mixers()
    finally:
        dispatch.set_backend(None)


def test_mixer_reverse_mode_unaffected():
    """jax.grad through the dispatched ops (outside the region) must work on
    every backend — the jnp-mirror jvp rule is transposable."""
    (r, k, v, w, u), _ = _wkv_problem(B=1, S=32, H=2, hd=8, T=1)

    def loss_w(r_):
        return jnp.mean(dispatch.wkv6_mix(r_, k, v, w, u) ** 2)

    (q, kk, vv), _ = _swa_problem(B=1, H=2, KV=2, S=64, hd=8, T=1)

    def loss_s(q_):
        return jnp.mean(dispatch.swa_attend(q_, kk, vv, 32) ** 2)

    g_ref_w = jax.grad(loss_w)(r)
    g_ref_s = jax.grad(loss_s)(q)
    for backend in ("interpret", "pallas"):
        dispatch.set_backend(backend)
        try:
            np.testing.assert_allclose(np.asarray(jax.grad(loss_w)(r)),
                                       np.asarray(g_ref_w), rtol=1e-6)
            np.testing.assert_allclose(np.asarray(jax.grad(loss_s)(q)),
                                       np.asarray(g_ref_s), rtol=1e-6)
        finally:
            dispatch.set_backend(None)
