"""bf16/fp16 operand coverage through the _mt and _jvps kernel variants
(ISSUE 4 satellite).

The kernels accumulate in fp32 regardless of operand dtype
(``preferred_element_type=jnp.float32`` on every dot; fp32 VMEM scratch):

- lora_dual_mt: the output is the fp32 accumulation rounded ONCE to the
  operand dtype — asserted BITWISE against the fp32-upcast oracle rounded
  the same way (the "fp32 accumulator" property).
- *_mt_jvps epilogues: jvp partials stay fp32 end-to-end — asserted against
  the fp32-upcast oracle at fp32-reduction tolerance (reduction order
  differs blockwise, so bitwise does not apply; the tolerance is the same
  ~1e-6 class the fp32 tests use).
- wkv6/mamba2 ops cast operands to fp32 at the layout step, so
  low-precision inputs follow the fp32 path exactly; swa keeps the operand
  dtype through the softmax-weights matmuls (p is rounded to v.dtype, as
  on real TPUs), so the oracle comparison uses per-dtype tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.lora_dual import (
    lora_dual_mt,
    lora_dual_mt_jvps,
    lora_dual_mt_jvps_ref,
    lora_dual_mt_ref,
)
from repro.kernels.swa_attention import (
    swa_attention_mt,
    swa_attention_mt_jvps,
    swa_attention_mt_jvps_ref,
    swa_attention_mt_ref,
)
from repro.kernels.wkv6_scan import (
    wkv6_scan_mt,
    wkv6_scan_mt_jvps,
    wkv6_scan_mt_jvps_ref,
    wkv6_scan_mt_ref,
)

TOL = {jnp.bfloat16: 2e-2, jnp.float16: 2e-3}
DTYPES = [jnp.bfloat16, jnp.float16]


def _f32(t):
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


def _lora_problem(dt, M=8, K=48, N=40, r=2, T=3, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    x = jax.random.normal(ks[0], (M, K)).astype(dt)
    w = (jax.random.normal(ks[1], (K, N)) * 0.05).astype(dt)
    a = jax.random.normal(ks[2], (K, r)) * 0.05      # fp32 master LoRA
    b = jax.random.normal(ks[3], (r, N)) * 0.05
    ad = jax.random.normal(ks[4], (T, K, r)) * 0.05
    bd = jax.random.normal(ks[5], (T, r, N)) * 0.05
    xd = (jax.random.normal(ks[6], (T, M, K)) * 0.3).astype(dt)
    gy = jax.random.normal(ks[7], (M, N))
    return x, w, a, b, ad, bd, xd, gy


@pytest.mark.parametrize("dt", DTYPES)
def test_lora_mt_fp32_accumulator_bitwise(dt):
    """Low-precision operands, fp32 accumulation: the kernel output must be
    BITWISE the fp32-upcast oracle rounded once to the operand dtype —
    i.e. no intermediate rounding anywhere in the K-reduction."""
    x, w, a, b, ad, bd, xd, _ = _lora_problem(dt)
    y, yds = lora_dual_mt(x, xd, w, a, ad, b, bd)
    assert y.dtype == dt and yds.dtype == dt
    yr, ydr = lora_dual_mt_ref(x.astype(jnp.float32),
                               xd.astype(jnp.float32),
                               w.astype(jnp.float32), a, ad, b, bd, 1.0)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr.astype(dt)))
    np.testing.assert_array_equal(np.asarray(yds),
                                  np.asarray(ydr.astype(dt)))


@pytest.mark.parametrize("dt", DTYPES)
def test_lora_jvps_fp32_out_vs_fp32_oracle(dt):
    """The epilogue's jvp partials stay fp32 for low-precision operands and
    match the fp32-upcast oracle at fp32-reduction tolerance."""
    x, w, a, b, ad, bd, xd, gy = _lora_problem(dt)
    jk = lora_dual_mt_jvps(x, w, a, ad, b, bd, gy, xdots=xd, impl="kernel")
    assert jk.dtype == jnp.float32
    jr = lora_dual_mt_jvps_ref(x.astype(jnp.float32),
                               w.astype(jnp.float32), a, ad, b, bd, gy,
                               1.0, xdots=xd.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(jk), np.asarray(jr), rtol=2e-5,
                               atol=1e-6)


def _wkv_problem(dt, B=2, S=64, H=2, hd=16, T=2, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 11)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, hd)).astype(dt) * 0.3
               for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd))).astype(dt)
    u = (jax.random.normal(ks[4], (H, hd)) * 0.3).astype(dt)
    rd, kd, vd = (jax.random.normal(ks[5 + i], (T, B, S, H, hd)).astype(dt)
                  * 0.3 for i in range(3))
    wd = (jax.random.normal(ks[8], (T, B, S, H, hd)) * 0.1).astype(dt)
    ud = (jax.random.normal(ks[9], (T, H, hd)) * 0.3).astype(dt)
    gy = jax.random.normal(ks[10], (B, S, H, hd))
    return (r, k, v, w, u), (rd, kd, vd, wd, ud), gy


@pytest.mark.parametrize("dt", DTYPES)
def test_wkv6_mt_low_precision_operands(dt):
    """wkv6 ops upcast to fp32 at the layout step — low-precision operands
    must match the oracle on the SAME upcast inputs bitwise-rounded-once:
    the state walk itself is pure fp32."""
    (r, k, v, w, u), (rd, kd, vd, wd, ud), _ = _wkv_problem(dt)
    y, yds = wkv6_scan_mt(r, k, v, w, u, rd, kd, vd, wd, ud, block_s=32)
    assert y.dtype == jnp.float32
    yr, ydr = wkv6_scan_mt_ref(*_f32((r, k, v, w, u)),
                               *_f32((rd, kd, vd, wd, ud)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(yds), np.asarray(ydr), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("dt", DTYPES)
def test_wkv6_jvps_low_precision_operands(dt):
    (r, k, v, w, u), (rd, kd, vd, wd, ud), gy = _wkv_problem(dt)
    jk = wkv6_scan_mt_jvps(r, k, v, w, u, rd, kd, vd, wd, gy, ud,
                           block_s=32)
    assert jk.dtype == jnp.float32
    jr = wkv6_scan_mt_jvps_ref(*_f32((r, k, v, w, u)),
                               *_f32((rd, kd, vd, wd)), gy,
                               ud.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(jk), np.asarray(jr), rtol=2e-5,
                               atol=1e-5)


def _swa_problem(dt, B=1, H=2, KV=2, S=64, hd=32, T=2, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    q = jax.random.normal(ks[0], (B, H, S, hd)).astype(dt)
    k = jax.random.normal(ks[1], (B, KV, S, hd)).astype(dt)
    v = jax.random.normal(ks[2], (B, KV, S, hd)).astype(dt)
    qd = jax.random.normal(ks[3], (T, B, H, S, hd)).astype(dt)
    kd = jax.random.normal(ks[4], (T, B, KV, S, hd)).astype(dt)
    vd = jax.random.normal(ks[5], (T, B, KV, S, hd)).astype(dt)
    gy = jax.random.normal(ks[6], (B, H, S, hd))
    return (q, k, v), (qd, kd, vd), gy


@pytest.mark.parametrize("dt", DTYPES)
def test_swa_mt_low_precision_operands(dt):
    """swa keeps the operand dtype through the softmax-weights matmul (p is
    rounded to v.dtype, as on real TPUs) — per-dtype tolerance vs the
    fp32-upcast oracle."""
    (q, k, v), (qd, kd, vd), _ = _swa_problem(dt)
    out, outds = swa_attention_mt(q, k, v, qd, kd, vd, window=32,
                                  block_q=32, block_k=32)
    assert out.dtype == dt
    outr, outdr = swa_attention_mt_ref(*_f32((q, k, v)),
                                       *_f32((qd, kd, vd)), window=32)
    tol = TOL[dt]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(outr), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(outds, np.float32),
                               np.asarray(outdr), atol=tol, rtol=tol)


@pytest.mark.parametrize("dt", DTYPES)
def test_swa_jvps_low_precision_operands(dt):
    (q, k, v), (qd, kd, vd), gy = _swa_problem(dt)
    jk = swa_attention_mt_jvps(q, k, v, qd, kd, vd, gy, window=32,
                               block_q=32, block_k=32)
    assert jk.dtype == jnp.float32
    jr = swa_attention_mt_jvps_ref(*_f32((q, k, v)), *_f32((qd, kd, vd)),
                                   gy, window=32)
    tol = TOL[dt]
    denom = float(jnp.abs(jr).max())
    np.testing.assert_allclose(np.asarray(jk) / denom,
                               np.asarray(jr) / denom, atol=tol, rtol=tol)
