"""Dry-run smoke: one (arch x shape) per kind lowers + compiles on the
production meshes, in a subprocess (the 512-device XLA flag must be set
before jax initialises, so it cannot run in this process).
"""
import json
import subprocess
import sys

import pytest


def _run(arch, shape, multi_pod=False):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", "/tmp/dryrun_test"]
    if multi_pod:
        cmd.append("--multi-pod")
    res = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    return res.stdout


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("whisper-tiny", "train_4k"),        # train kind + enc-dec family
    ("rwkv6-1.6b", "long_500k"),         # decode kind + ssm family
    ("whisper-tiny", "prefill_32k"),     # prefill kind
])
def test_single_pod_lowers(arch, shape):
    out = _run(arch, shape)
    assert "all requested combinations lowered + compiled OK" in out


@pytest.mark.slow
def test_multi_pod_lowers():
    out = _run("rwkv6-1.6b", "decode_32k", multi_pod=True)
    assert "all requested combinations lowered + compiled OK" in out


def test_long_500k_skip_is_documented():
    out = _run("command-r-plus-104b", "long_500k")
    rec = json.loads(out.splitlines()[0])
    assert rec["skipped"] and "full-attention" in rec["reason"]
