"""Unit tests for the repro.obs telemetry subsystem: metrics registry,
span tracing + Chrome-trace export, sinks, the facade, and the report CLI.
All host-side — nothing here touches jax beyond scalar coercion."""
import json
import math

import numpy as np
import pytest

from benchmarks.check_schemas import check_telemetry_jsonl
from repro.obs import (
    NULL,
    InMemorySink,
    JSONLSink,
    MetricsRegistry,
    PrometheusTextfileSink,
    Telemetry,
    Tracer,
    chrome_trace_doc,
    load_chrome_trace,
    make_telemetry,
    write_chrome_trace,
)
from repro.obs.report import render
from repro.obs.telemetry import _NULL_INSTRUMENT, _NULL_SPAN, _jsonable


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.add(4)
    g = reg.gauge("g")
    g.set(2.5)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 5
    assert snap["gauges"]["g"] == 2.5


def test_registry_get_or_create_returns_same_instrument():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    assert reg.gauge("y") is reg.gauge("y")
    assert reg.histogram("h") is reg.histogram("h")


def test_never_set_gauge_omitted_from_snapshot():
    reg = MetricsRegistry()
    reg.gauge("unset")
    assert "unset" not in reg.snapshot()["gauges"]


def test_histogram_count_sum_min_max_exact():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in (0.01, 0.02, 0.03, 0.5):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 4
    assert s["min"] == 0.01 and s["max"] == 0.5
    assert abs(s["sum"] - 0.56) < 1e-12
    assert abs(s["mean"] - 0.14) < 1e-12


def test_histogram_percentiles_ordered_and_bounded():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    vals = [0.001 * (i + 1) for i in range(200)]
    for v in vals:
        h.observe(v)
    s = h.snapshot()
    assert s["min"] <= s["p50"] <= s["p95"] <= s["p99"] <= s["max"]
    # interpolated p50 lands near the true median (bucket resolution)
    assert 0.05 <= s["p50"] <= 0.2


def test_histogram_empty_snapshot():
    reg = MetricsRegistry()
    s = reg.histogram("empty").snapshot()
    assert s["count"] == 0


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("fl.rounds").add(3)
    reg.gauge("fl.loss").set(0.5)
    reg.histogram("fl.round_seconds").observe(0.1)
    text = reg.prometheus_text()
    assert "fl_rounds 3" in text
    assert "fl_loss 0.5" in text
    assert "fl_round_seconds_count 1" in text
    assert 'le="+Inf"' in text


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def test_tracer_nesting_and_chrome_doc(tmp_path):
    tr = Tracer()
    with tr.span("outer", round=1):
        with tr.span("inner"):
            pass
    assert [s.name for s in tr.spans] == ["inner", "outer"]
    assert tr.spans[0].depth == 1 and tr.spans[1].depth == 0

    doc = chrome_trace_doc(tr.spans, process_name="test")
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"outer", "inner"}
    for e in xs:
        assert e["dur"] >= 0 and isinstance(e["ts"], (int, float))

    path = tmp_path / "trace.json"
    write_chrome_trace(str(path), tr.spans, process_name="test")
    loaded = load_chrome_trace(str(path))
    assert {e["name"] for e in loaded["traceEvents"]
            if e["ph"] == "X"} == {"outer", "inner"}


def test_span_records_on_exception():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("failing"):
            raise ValueError("boom")
    assert [s.name for s in tr.spans] == ["failing"]


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------

def test_jsonl_sink_one_object_per_line(tmp_path):
    path = tmp_path / "run.jsonl"
    sink = JSONLSink(str(path))
    sink.emit({"kind": "a", "n": 1})
    sink.emit({"kind": "b", "n": 2})
    sink.close()
    lines = path.read_text().strip().splitlines()
    assert [json.loads(ln)["kind"] for ln in lines] == ["a", "b"]


def test_in_memory_sink_by_kind():
    sink = InMemorySink()
    sink.emit({"kind": "round", "n": 0})
    sink.emit({"kind": "round", "n": 1})
    sink.emit({"kind": "eval"})
    assert len(sink.by_kind("round")) == 2
    assert len(sink.events) == 3


def test_prometheus_textfile_sink(tmp_path):
    path = tmp_path / "metrics.prom"
    tel = Telemetry(run_id="t", sinks=[PrometheusTextfileSink(str(path))])
    tel.counter("serve.requests").add(7)
    tel.close()
    assert "serve_requests 7" in path.read_text()


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

def test_make_telemetry_without_sinks_is_null():
    assert make_telemetry() is NULL
    assert not NULL.enabled


def test_null_telemetry_is_allocation_free():
    # disabled instruments and spans are preallocated module singletons —
    # the hot loop holds the same object no matter how often it asks
    assert NULL.counter("a") is NULL.counter("b") is _NULL_INSTRUMENT
    assert NULL.gauge("a") is NULL.histogram("b") is _NULL_INSTRUMENT
    assert NULL.span("s", x=1) is NULL.span("t") is _NULL_SPAN
    with NULL.span("s"):
        pass
    NULL.event("anything", x=1)
    NULL.close()


def test_event_envelope_and_jsonable_coercion():
    sink = InMemorySink()
    tel = Telemetry(run_id="r1", sinks=[sink])
    tel.event("round", loss=np.float32(0.5), n=np.int64(3),
              arr=np.arange(2), nested={"x": np.float64(1.0)})
    ev = sink.by_kind("round")[0]
    assert ev["run_id"] == "r1" and "ts" in ev
    assert ev["loss"] == 0.5 and ev["n"] == 3
    assert ev["arr"] == [0, 1] and ev["nested"]["x"] == 1.0
    json.dumps(ev)   # strictly JSON-serializable


def test_jsonable_jax_scalar():
    import jax.numpy as jnp
    assert _jsonable(jnp.float32(2.0)) == 2.0
    assert _jsonable(jnp.int32(5)) == 5


def test_close_emits_metrics_snapshot_and_is_idempotent():
    sink = InMemorySink()
    tel = Telemetry(run_id="r", sinks=[sink])
    tel.counter("c").inc()
    tel.close()
    tel.close()
    metrics = sink.by_kind("metrics")
    assert len(metrics) == 1
    assert metrics[0]["metrics"]["counters"]["c"] == 1


def test_workload_stamps_run_meta():
    sink = InMemorySink()
    Telemetry(run_id="r", sinks=[sink], workload="serve")
    assert sink.by_kind("run_meta")[0]["workload"] == "serve"


# ---------------------------------------------------------------------------
# report CLI + JSONL validator
# ---------------------------------------------------------------------------

def _write_jsonl(path, events):
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(e) + "\n")


def test_report_renders_round_and_serving_sections(tmp_path):
    path = tmp_path / "run.jsonl"
    _write_jsonl(path, [
        {"ts": 1.0, "run_id": "r", "kind": "run_meta", "workload": "train"},
        {"ts": 1.1, "run_id": "r", "kind": "round", "round": 0, "loss": 0.9,
         "bytes_up": 100, "bytes_down": 50, "survivors": 3, "cohort": 4,
         "stragglers": 1},
        {"ts": 1.2, "run_id": "r", "kind": "eval", "round": 0, "acc": 0.75},
        {"ts": 1.3, "run_id": "r", "kind": "request", "request_id": "q0",
         "adapter_id": 1, "prompt_len": 8, "gen_tokens": 4, "ttft_s": 0.1,
         "latency_s": 0.2, "tok_per_sec": 20.0},
        {"ts": 1.4, "run_id": "r", "kind": "memory", "label": "post",
         "live_bytes": 1024},
        {"ts": 1.5, "run_id": "r", "kind": "metrics", "metrics": {
            "counters": {"adapter_cache.hits": 1,
                         "adapter_cache.misses": 1},
            "gauges": {"serve.decode_tok_per_sec": 33.3},
            "histograms": {}}},
    ])
    out = render(str(path))
    assert "== rounds ==" in out and "bytes_up_total=100" in out
    assert "== serving ==" in out and "q0" in out
    assert "33.3 tok/s" in out
    assert "hit rate 0.500" in out
    assert "== memory ==" in out
    assert "0.75" in out   # eval acc joined onto the round row


def test_report_rejects_bad_jsonl(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"ok": 1}\nnot json\n')
    with pytest.raises(ValueError):
        render(str(path))


def test_check_telemetry_jsonl_validator(tmp_path):
    good = tmp_path / "good.jsonl"
    _write_jsonl(good, [
        {"ts": 1.0, "run_id": "r", "kind": "round"},
        {"ts": 1.1, "run_id": "r", "kind": "metrics"},
    ])
    assert check_telemetry_jsonl(str(good),
                                 expect_kinds=("round", "metrics")) == []
    assert check_telemetry_jsonl(str(good), expect_kinds=("request",))

    bad = tmp_path / "bad.jsonl"
    _write_jsonl(bad, [{"kind": "round"}])   # missing ts/run_id envelope
    assert check_telemetry_jsonl(str(bad))

    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert check_telemetry_jsonl(str(empty))


def test_memory_probe_emits_events():
    from repro.obs import MemoryProbe
    sink = InMemorySink()
    tel = Telemetry(run_id="m", sinks=[sink])
    MemoryProbe(tel).sample("here", modeled_bytes=123)
    ev = sink.by_kind("memory")[0]
    assert ev["label"] == "here"
    assert ev["modeled_peak_bytes"] == 123
    assert ev["live_bytes"] >= 0
