"""Unit + statistical tests for the forward-gradient estimator (paper Eq. 1-3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.forward_grad import (
    forward_gradient,
    masked_perturbation,
    reconstruct_gradient,
)


def quad_loss(w):
    # f(w) = 0.5 ||A w - b||^2 with fixed A, b -> exact gradient known
    A = jnp.arange(12.0).reshape(3, 4) / 10.0
    b = jnp.ones(3)
    r = A @ w["w"] - b
    return 0.5 * jnp.sum(r * r) + jnp.sum(w["v"] ** 2)


def true_grad(w):
    return jax.grad(quad_loss)(w)


def test_jvp_matches_directional_derivative(rng_key):
    w = {"w": jnp.array([1.0, -2.0, 0.5, 3.0]), "v": jnp.array([0.2, -0.1])}
    loss, g, jvps = forward_gradient(quad_loss, w, rng_key, k_perturbations=1)
    assert jnp.isfinite(loss)
    # jvp = <grad, v>; reconstruct v from the same seed and check
    v = masked_perturbation(jax.random.fold_in(rng_key, 0), w)
    tg = true_grad(w)
    expect = sum(jnp.sum(a * b) for a, b in zip(jax.tree.leaves(tg),
                                                jax.tree.leaves(v)))
    np.testing.assert_allclose(jvps[0], expect, rtol=1e-5)


def test_estimator_is_unbiased(rng_key):
    """E[jvp * v] = grad f  (paper Eq. 2-3): average many single-perturbation
    estimates and compare to the exact gradient."""
    w = {"w": jnp.array([1.0, -2.0, 0.5, 3.0]), "v": jnp.array([0.2, -0.1])}
    tg = true_grad(w)

    def one(key):
        _, g, _ = forward_gradient(quad_loss, w, key, k_perturbations=1)
        return g

    keys = jax.random.split(rng_key, 4000)
    gs = jax.vmap(one)(keys)
    mean = jax.tree.map(lambda x: x.mean(0), gs)
    for m, t in zip(jax.tree.leaves(mean), jax.tree.leaves(tg)):
        np.testing.assert_allclose(m, t, atol=0.25 * float(jnp.abs(t).max() + 1))


def test_k_perturbations_reduce_variance(rng_key):
    w = {"w": jnp.array([1.0, -2.0, 0.5, 3.0]), "v": jnp.array([0.2, -0.1])}

    def var_of(k, n=300):
        def one(key):
            _, g, _ = forward_gradient(quad_loss, w, key, k_perturbations=k)
            return g["w"]
        keys = jax.random.split(rng_key, n)
        gs = jax.vmap(one)(keys)
        return float(gs.var(0).mean())

    assert var_of(8) < var_of(1) * 0.5


def test_mask_zeroes_unassigned(rng_key):
    w = {"w": jnp.ones(4), "v": jnp.ones(2)}
    mask = {"w": jnp.zeros(()), "v": jnp.ones(())}
    _, g, _ = forward_gradient(quad_loss, w, rng_key, mask_tree=mask)
    assert float(jnp.abs(g["w"]).max()) == 0.0
    assert float(jnp.abs(g["v"]).max()) > 0.0


def test_server_reconstruction_matches_client(rng_key):
    """Per-iteration mode (paper §3.2): server regenerates v from the seed and
    must rebuild the client's gradient estimate (up to float accumulation
    order — XLA fuses the two paths differently)."""
    w = {"w": jnp.array([1.0, -2.0, 0.5, 3.0]), "v": jnp.array([0.2, -0.1])}
    mask = {"w": jnp.ones(()), "v": jnp.zeros(())}
    _, g_client, jvps = forward_gradient(quad_loss, w, rng_key,
                                         k_perturbations=3, mask_tree=mask)
    g_server = reconstruct_gradient(w, rng_key, jvps, mask_tree=mask)
    for a, b in zip(jax.tree.leaves(g_client), jax.tree.leaves(g_server)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-7)


def test_forward_grad_through_scan(rng_key):
    """jvp must flow through lax.scan (the layer-stacked model bodies)."""
    def loss(w):
        def body(c, x):
            return jnp.tanh(c @ w["m"]) + x, None
        c, _ = jax.lax.scan(body, jnp.ones(3), jnp.zeros((5, 3)))
        return jnp.sum(c ** 2)

    w = {"m": jnp.eye(3) * 0.5}
    _, g, _ = forward_gradient(loss, w, rng_key)
    assert all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(g))
