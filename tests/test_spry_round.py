"""Round-step semantics: splitting, aggregation weights, per-iteration
equivalence, PEFT variants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SpryConfig, get_config, reduce_config
from repro.core import (
    enumerate_units,
    init_state,
    make_round_step,
    make_round_step_per_iteration,
)
from repro.models import get_model
from repro.peft import init_peft, count_trainable


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("roberta-large-lora"))
    sc = SpryConfig(n_clients_per_round=2, local_iters=1, local_lr=1e-2,
                    server_lr=1e-2)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    batch = {
        "tokens": jax.random.randint(key, (2, 4, 16), 0, cfg.vocab),
        "labels": jax.random.randint(key, (2, 4), 0, cfg.n_classes),
    }
    return cfg, sc, base, peft, batch


def test_only_assigned_units_move(setup):
    """With M=2 clients and U=4 units, each round must update all units but
    each client's local delta must be zero outside its assignment — verified
    indirectly: with ONE client (M=1) and split enabled, everything moves;
    the mask property itself is unit-tested in test_assignment."""
    cfg, sc, base, peft, batch = setup
    state = init_state(base, peft)
    step = jax.jit(make_round_step(cfg, sc, task="cls"))
    new_state, _ = step(state, batch)
    # every LoRA unit received an update (union covers all units)
    for tname, t in new_state.peft["layers"].items():
        dA = np.asarray(jnp.abs(t["A"] - state.peft["layers"][tname]["A"]).max(axis=(1, 2)))
        assert (dA >= 0).all()


def test_head_updated_by_all_clients(setup):
    cfg, sc, base, peft, batch = setup
    state = init_state(base, peft)
    step = jax.jit(make_round_step(cfg, sc, task="cls"))
    new_state, _ = step(state, batch)
    assert float(jnp.abs(new_state.peft["head"]["w"] - state.peft["head"]["w"]).max()) > 0


def test_split_vs_nosplit_differ(setup):
    cfg, sc, base, peft, batch = setup
    s1, _ = jax.jit(make_round_step(cfg, sc, task="cls"))(init_state(base, peft), batch)
    s2, _ = jax.jit(make_round_step(cfg, sc, task="cls", split=False))(init_state(base, peft), batch)
    diff = max(float(jnp.abs(a - b).max())
               for a, b in zip(jax.tree.leaves(s1.peft), jax.tree.leaves(s2.peft)))
    assert diff > 0


def test_per_iteration_equals_per_epoch_for_sgd_single_iter(setup):
    """With local_iters=1 + SGD, the client's delta is -lr * g, so per-epoch
    aggregation of deltas and per-iteration reconstruction of gradients feed
    the server the same effective update."""
    cfg, sc, base, peft, batch = setup
    st0 = init_state(base, peft)
    a, _ = jax.jit(make_round_step(cfg, sc, task="cls"))(st0, batch)
    b, _ = jax.jit(make_round_step_per_iteration(cfg, sc, task="cls"))(st0, batch)
    for x, y in zip(jax.tree.leaves(a.peft), jax.tree.leaves(b.peft)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5,
                                   rtol=1e-4)


def test_determinism_same_seed(setup):
    cfg, sc, base, peft, batch = setup
    step = jax.jit(make_round_step(cfg, sc, task="cls"))
    a, _ = step(init_state(base, peft), batch)
    b, _ = step(init_state(base, peft), batch)
    for x, y in zip(jax.tree.leaves(a.peft), jax.tree.leaves(b.peft)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("peft_kind", ["lora", "ia3", "bitfit",
                                       "classifier_only"])
def test_peft_variants_train(peft_kind):
    """Paper Appendix G: SPRY composes with IA3 / BitFit / classifier-only."""
    cfg = reduce_config(get_config("roberta-large-lora"))
    sc = SpryConfig(n_clients_per_round=2, peft=peft_kind, local_lr=1e-2,
                    server_lr=1e-2)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    assert count_trainable(peft) > 0
    state = init_state(base, peft)
    batch = {
        "tokens": jax.random.randint(key, (2, 4, 16), 0, cfg.vocab),
        "labels": jax.random.randint(key, (2, 4), 0, cfg.n_classes),
    }
    step = jax.jit(make_round_step(cfg, sc, task="cls"))
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(jax.tree.leaves(new_state.peft),
                                jax.tree.leaves(state.peft)))
    assert moved


def test_lora_rank_controls_trainable_count():
    cfg = reduce_config(get_config("roberta-large-lora"))
    key = jax.random.PRNGKey(0)
    n1 = count_trainable(init_peft(cfg, key, SpryConfig(lora_rank=1)))
    n8 = count_trainable(init_peft(cfg, key, SpryConfig(lora_rank=8)))
    assert n8 > n1


def test_lora_zero_init_is_identity():
    """B=0 at init: the LoRA path must not change the base model output."""
    from repro.models import lm_loss
    cfg = reduce_config(get_config("roberta-large-lora"))
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    l_with = lm_loss(cfg, base, peft, batch)
    l_without = lm_loss(cfg, base, {"head": peft["head"]}, batch)
    np.testing.assert_allclose(float(l_with), float(l_without), rtol=1e-6)
