"""Paged adapter cache + continuous-batching engine: LRU residency is
deterministic, rehydrated pages are bitwise what the store holds, and the
engine's per-request outputs are EXACTLY what isolated per-request greedy
serving produces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.launch.adapter_cache import (
    AdapterCache,
    CheckpointAdapterStore,
    SyntheticAdapterStore,
)
from repro.launch.serve import build_serve_fns, greedy_generate
from repro.launch.serving import Request, ServingEngine
from repro.models import get_model


def _cfg(arch="llama2-7b"):
    return reduce_config(get_config(arch))


def _trees_bitwise(a, b):
    flat_a, _ = jax.tree_util.tree_flatten(a)
    flat_b, _ = jax.tree_util.tree_flatten(b)
    return all(bool(jnp.all(x == y)) for x, y in zip(flat_a, flat_b))


def test_lru_eviction_and_rehydration_deterministic():
    cfg = _cfg()
    store = SyntheticAdapterStore(cfg)
    cache = AdapterCache(store, capacity=2)
    assert cache.acquire(0) != cache.acquire(1)
    assert cache.resident() == [0, 1]
    # hit refreshes recency: 0 becomes MRU, so 1 is the LRU victim
    p0 = cache.acquire(0)
    cache.acquire(2)
    assert cache.resident() == [0, 2]
    assert cache.stats()["evictions"] == 1
    # rehydrating the evicted adapter evicts 0 (now LRU) and lands the
    # bitwise-identical tree (synthetic store is deterministic per aid)
    p1 = cache.acquire(1)
    assert cache.resident() == [2, 1]
    assert _trees_bitwise(cache.page_tree(p1), _drop_head(store.load(1)))
    assert p1 == p0            # adapter 0's page slot was recycled in place
    assert cache.stats()["evictions"] == 2


def _drop_head(tree):
    return {g: t for g, t in tree.items() if g != "head"}


def test_page_tree_bitwise_roundtrip():
    cfg = _cfg("zamba2-1.2b")   # stacked layers + shared attention groups
    store = SyntheticAdapterStore(cfg)
    cache = AdapterCache(store, capacity=3)
    for aid in (4, 7, 9):
        page = cache.acquire(aid)
        assert _trees_bitwise(cache.page_tree(page),
                              _drop_head(store.load(aid))), aid


def test_pinning_blocks_eviction():
    cfg = _cfg()
    store = SyntheticAdapterStore(cfg)
    cache = AdapterCache(store, capacity=2)
    cache.pin(0)
    cache.pin(0)               # two in-flight requests share adapter 0
    cache.pin(1)
    with pytest.raises(RuntimeError, match="pinned"):
        cache.acquire(2)
    cache.unpin(0)
    # one unpin is not enough — the page is still referenced
    with pytest.raises(RuntimeError, match="pinned"):
        cache.acquire(2)
    cache.unpin(0)
    cache.acquire(2)           # now evictable
    assert 1 in cache.resident() and 0 not in cache.resident()


def test_checkpoint_store_roundtrip(tmp_path):
    cfg = _cfg()
    synth = SyntheticAdapterStore(cfg)
    ckpt = CheckpointAdapterStore(tmp_path, template=synth.template())
    for aid in (0, 3):
        ckpt.save(aid, synth.load(aid))
    assert _trees_bitwise(ckpt.load(3), synth.load(3))
    cache = AdapterCache(ckpt, capacity=2)
    page = cache.acquire(3)
    assert _trees_bitwise(cache.page_tree(page), _drop_head(synth.load(3)))


# whisper rides with encoder frames through the engine's admission encode
_ARCHS = ["llama2-7b", "rwkv6-1.6b", "zamba2-1.2b", "whisper-tiny"]


@pytest.mark.parametrize("arch", _ARCHS)
def test_engine_matches_per_request_greedy(arch):
    """Continuous batching with staggered admissions, shared rows, LRU
    evictions mid-flight: every request's generated ids are EXACTLY what
    isolated per-request ``greedy_generate`` produces with that request's
    adapter."""
    cfg = _cfg(arch)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    store = SyntheticAdapterStore(cfg)
    P, n_new = 6, 5
    reqs = []
    for i in range(5):
        prompt = np.asarray(
            jax.random.randint(jax.random.fold_in(key, i), (P,), 0,
                               cfg.vocab), np.int32)
        frames = None
        if arch == "whisper-tiny":
            frames = np.asarray(jax.random.normal(
                jax.random.fold_in(key, 100 + i),
                (cfg.encoder_seq, cfg.d_model)), np.float32)
        reqs.append(Request(request_id=f"r{i}", adapter_id=i % 4,
                            prompt=prompt, max_new_tokens=n_new,
                            frames=frames))

    # max_batch 3 < 5 requests forces staggered admission into in-flight
    # decode; capacity 3 < 4 adapters forces eviction + rehydration
    ac = AdapterCache(store, capacity=3)
    eng = ServingEngine(cfg, base, ac, max_batch=3, cache_len=P + n_new)
    out = eng.run(reqs)
    assert ac.stats()["evictions"] >= 1

    fns = build_serve_fns(cfg, model)
    for req in reqs:
        fr = None if req.frames is None else jnp.asarray(req.frames)[None]
        ids = greedy_generate(cfg, base, store.load(req.adapter_id),
                              jnp.asarray(req.prompt)[None], n_new,
                              cache_len=P + n_new, fns=fns, frames=fr)
        assert out[req.request_id] == list(np.asarray(ids[0])), \
            req.request_id


def test_engine_rejects_overlong_request():
    cfg = _cfg()
    model = get_model(cfg)
    base = model.init_base(cfg, jax.random.PRNGKey(0))
    ac = AdapterCache(SyntheticAdapterStore(cfg), capacity=2)
    eng = ServingEngine(cfg, base, ac, max_batch=2, cache_len=8)
    eng.submit(Request(request_id="big", adapter_id=0,
                       prompt=np.zeros(6, np.int32), max_new_tokens=4))
    with pytest.raises(ValueError, match="cache_len"):
        eng.step()


def test_engine_pins_inflight_pages():
    """While a request is in flight its adapter page is pinned: admitting
    more distinct adapters than capacity raises rather than evicting a page
    an active row still reads."""
    cfg = _cfg()
    model = get_model(cfg)
    base = model.init_base(cfg, jax.random.PRNGKey(0))
    ac = AdapterCache(SyntheticAdapterStore(cfg), capacity=2)
    eng = ServingEngine(cfg, base, ac, max_batch=3, cache_len=8)
    for i in range(3):
        eng.submit(Request(request_id=f"r{i}", adapter_id=i,
                           prompt=np.zeros(3, np.int32), max_new_tokens=4))
    with pytest.raises(RuntimeError, match="pinned"):
        eng.step()
