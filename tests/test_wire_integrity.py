"""Wire integrity: fuzzed frames are DETECTED or decode bitwise-equal.

The contract under test (messages.py wire schema v2): strict decode of a
mutated ``TaskAssignment``/``ClientUpdate`` frame either raises a classified
``WireError`` or — if the mutation happened to leave the frame intact, which
the CRC makes essentially impossible — returns a value bitwise-equal to the
original. There is NO silent third outcome.
"""
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st
from repro.fl.runtime.messages import (
    FAILURE_KINDS,
    MAGIC_ASSIGN,
    MAGIC_UPDATE,
    ClientUpdate,
    TaskAssignment,
    WireError,
    decode_frame,
)


def _assignment(round_idx=3, client_id=17, seed_id=2):
    return TaskAssignment(
        round_idx=round_idx, client_id=client_id, seed_id=seed_id,
        cohort_size=8, seed=42, n_units=16,
        unit_ids=np.array([1, 5, 9], np.int32),
        hparams={"local_lr": 5e-3, "local_iters": 2})


def _update_delta():
    rng = np.random.default_rng(0)
    return ClientUpdate(
        round_idx=3, client_id=17, seed_id=2, mode="delta", wire="fp32",
        unit_payload={1: [rng.normal(size=(4, 3)).astype(np.float32),
                          rng.normal(size=(3,)).astype(np.float32)],
                      5: [rng.normal(size=(2, 2)).astype(np.float32)]},
        head_payload=[rng.normal(size=(6,)).astype(np.float32)],
        loss=0.731)


def _update_jvp():
    return ClientUpdate(
        round_idx=3, client_id=17, seed_id=2, mode="jvp", wire="fp32",
        jvps=np.array([0.1, -0.25, 3.5, -4.125], np.float32), loss=1.25)


def _assert_equal_assignment(a, b):
    assert (a.round_idx, a.client_id, a.seed_id, a.cohort_size, a.seed,
            a.n_units) == (b.round_idx, b.client_id, b.seed_id,
                           b.cohort_size, b.seed, b.n_units)
    np.testing.assert_array_equal(a.unit_ids, b.unit_ids)
    assert a.hparams == b.hparams


def _assert_equal_update(a, b):
    assert (a.round_idx, a.client_id, a.seed_id, a.mode, a.wire) == \
        (b.round_idx, b.client_id, b.seed_id, b.mode, b.wire)
    assert np.float32(a.loss).tobytes() == np.float32(b.loss).tobytes()
    if a.mode == "delta":
        assert sorted(a.unit_payload) == sorted(b.unit_payload)
        for uid in a.unit_payload:
            for x, y in zip(a.unit_payload[uid], b.unit_payload[uid]):
                assert np.asarray(x).tobytes() == np.asarray(y).tobytes()
        if a.head_payload is None:
            assert b.head_payload is None
        else:
            for x, y in zip(a.head_payload, b.head_payload):
                assert np.asarray(x).tobytes() == np.asarray(y).tobytes()
    else:
        assert np.asarray(a.jvps).tobytes() == np.asarray(b.jvps).tobytes()


_MESSAGES = {
    "assign": (_assignment, _assert_equal_assignment),
    "delta": (_update_delta, _assert_equal_update),
    "jvp": (_update_jvp, _assert_equal_update),
}


def _check_no_silent_third_outcome(original, mutated_bytes, assert_equal):
    """Decode mutated bytes: classified WireError OR bitwise-equal value."""
    try:
        out = decode_frame(mutated_bytes)
    except WireError as e:
        assert e.kind in FAILURE_KINDS
        return "detected"
    assert_equal(original, out)
    return "equal"


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(_MESSAGES))
def test_roundtrip_bitwise(kind):
    make, assert_equal = _MESSAGES[kind]
    msg = make()
    out = decode_frame(msg.to_bytes())
    assert_equal(msg, out)
    assert type(out) is type(msg)


def test_decode_frame_dispatches_on_magic():
    assert isinstance(decode_frame(_assignment().to_bytes()), TaskAssignment)
    assert isinstance(decode_frame(_update_jvp().to_bytes()), ClientUpdate)


# ---------------------------------------------------------------------------
# classification of hand-built failures
# ---------------------------------------------------------------------------

def test_truncation_detected():
    frame = _update_delta().to_bytes()
    for cut in (0, 1, 4, 11, len(frame) // 2, len(frame) - 1):
        with pytest.raises(WireError) as ei:
            decode_frame(frame[:cut])
        assert ei.value.kind in ("truncated", "corrupt", "shape_mismatch")


def test_version_mismatch_classified():
    frame = bytearray(_assignment().to_bytes())
    assert frame[:4] == MAGIC_ASSIGN
    frame[3] = ord("9")          # SPA2 -> SPA9: same family, other version
    with pytest.raises(WireError) as ei:
        decode_frame(bytes(frame))
    assert ei.value.kind == "version_mismatch"


def test_bad_magic_classified():
    frame = b"NOPE" + _update_jvp().to_bytes()[4:]
    with pytest.raises(WireError) as ei:
        decode_frame(frame)
    assert ei.value.kind == "bad_magic"


def test_crc_catches_payload_bitflip():
    frame = bytearray(_update_jvp().to_bytes())
    frame[-10] ^= 0x40           # flip a payload bit, keep length
    with pytest.raises(WireError) as ei:
        decode_frame(bytes(frame))
    assert ei.value.kind == "corrupt"


def test_appended_bytes_detected():
    frame = _update_delta().to_bytes() + b"\x00\x00"
    with pytest.raises(WireError) as ei:
        decode_frame(frame)
    assert ei.value.kind in ("shape_mismatch", "corrupt")


def test_cross_magic_confusion_detected():
    """An update frame forced under the assignment magic must not decode."""
    frame = bytearray(_update_jvp().to_bytes())
    frame[:4] = MAGIC_ASSIGN
    with pytest.raises(WireError):
        decode_frame(bytes(frame))


def test_wire_error_kind_is_closed_set():
    with pytest.raises(AssertionError):
        WireError("made_up_kind")


# ---------------------------------------------------------------------------
# fuzz: every mutation detected or bitwise-equal — no silent third outcome
# ---------------------------------------------------------------------------

@settings(max_examples=120)
@given(kind=st.sampled_from(sorted(_MESSAGES)),
       mutation=st.sampled_from(["bitflip", "truncate", "dtype", "grow"]),
       pos_frac=st.floats(min_value=0.0, max_value=0.999),
       bit=st.integers(min_value=0, max_value=7),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_fuzzed_mutations_no_silent_outcome(kind, mutation, pos_frac, bit,
                                            seed):
    make, assert_equal = _MESSAGES[kind]
    msg = make()
    frame = bytearray(msg.to_bytes())
    rnd = np.random.default_rng(seed)
    if mutation == "bitflip":
        pos = int(pos_frac * len(frame))
        frame[pos] ^= 1 << bit
    elif mutation == "truncate":
        frame = frame[: int(pos_frac * len(frame))]
    elif mutation == "grow":
        frame = frame + bytes(rnd.integers(0, 256,
                                           size=1 + int(pos_frac * 16),
                                           dtype=np.uint8))
    else:  # dtype: mutate the declared buffer dtype inside the header json
        for old, new in ((b'"float32"', b'"float64"'),
                         (b'"int32"', b'"int16"')):
            i = bytes(frame).find(old)
            if i >= 0:
                frame = frame[:i] + new + frame[i + len(old):]
                break
    outcome = _check_no_silent_third_outcome(msg, bytes(frame), assert_equal)
    if mutation in ("truncate", "dtype", "grow"):
        # these always change the byte stream; CRC/length must catch them
        assert outcome == "detected"


@settings(max_examples=60)
@given(kind=st.sampled_from(sorted(_MESSAGES)),
       n_flips=st.integers(min_value=1, max_value=16),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_fuzzed_multi_bitflips_detected(kind, n_flips, seed):
    """Any nonzero set of bit flips changes bytes -> the CRC must fire."""
    make, assert_equal = _MESSAGES[kind]
    msg = make()
    frame = bytearray(msg.to_bytes())
    rnd = np.random.default_rng(seed)
    for _ in range(n_flips):
        frame[int(rnd.integers(0, len(frame)))] ^= 1 << int(
            rnd.integers(0, 8))
    if bytes(frame) == msg.to_bytes():    # flips cancelled out: intact frame
        assert_equal(msg, decode_frame(bytes(frame)))
        return
    outcome = _check_no_silent_third_outcome(msg, bytes(frame), assert_equal)
    assert outcome == "detected"
