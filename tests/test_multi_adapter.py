"""Batched multi-adapter LoRA projection: kernel, dispatch route, and the
models' decode path — each batch row reads its own adapter page, bitwise
equal to running that row alone with its adapter."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.kernels.lora_dual import (
    lora_dual_mt,
    lora_dual_multi,
    lora_dual_multi_ref,
)
from repro.kernels.dispatch import lora_proj, lora_proj_multi
from repro.configs import get_config, reduce_config
from repro.launch.adapter_cache import AdapterCache, SyntheticAdapterStore
from repro.models import get_model


def _operands(key, M=40, K=48, N=56, P=5, r=4, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = jax.random.normal(ks[1], (K, N), dtype)
    a = jax.random.normal(ks[2], (P, K, r), jnp.float32) * 0.1
    b = jax.random.normal(ks[3], (P, r, N), jnp.float32) * 0.1
    idx = jax.random.randint(ks[4], (M,), 0, P, jnp.int32)
    return x, idx, w, a, b


def test_multi_kernel_matches_oracle():
    x, idx, w, a, b = _operands(jax.random.PRNGKey(0))
    y = lora_dual_multi(x, idx, w, a, b, scale=2.0, interpret=True)
    ref = lora_dual_multi_ref(x, idx, w, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_multi_kernel_matches_per_row_single_kernel():
    """Each row of the multi-adapter kernel output matches the
    single-adapter fused kernel run on that row's page at the same M (the
    one-hot page epilogue adds exactly 0.0 for non-selected pages; the
    residual last-ulp wiggle is interpret-mode XLA compiling the P-page dot
    unroll differently from a single dot, not adapter routing)."""
    x, idx, w, a, b = _operands(jax.random.PRNGKey(1), M=8)
    y = lora_dual_multi(x, idx, w, a, b, scale=1.5, interpret=True)
    zero = jnp.zeros((1,) + a.shape[1:], jnp.float32)
    zero_b = jnp.zeros((1,) + b.shape[1:], jnp.float32)
    for p in range(a.shape[0]):
        rows = np.flatnonzero(np.asarray(idx) == p)
        if rows.size == 0:
            continue
        yp, _ = lora_dual_mt(x, None, w, a[p], zero, b[p], zero_b,
                             scale=1.5, interpret=True)
        np.testing.assert_allclose(np.asarray(yp)[rows],
                                   np.asarray(y)[rows],
                                   atol=2e-6, rtol=2e-6, err_msg=str(p))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dispatch_multi_bitwise_vs_per_row_lora_proj(dtype):
    """The jnp-backend multi-adapter route (the CPU mirror every model test
    exercises) equals a per-row loop of the single-adapter ``lora_proj`` —
    bitwise, including rows that share one adapter."""
    B, S, K, N, P, r = 5, 7, 32, 48, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, K), dtype)
    w = jax.random.normal(ks[1], (K, N), dtype)
    a = jax.random.normal(ks[2], (P, K, r), jnp.float32) * 0.1
    b = jax.random.normal(ks[3], (P, r, N), jnp.float32) * 0.1
    for idx in (jax.random.randint(ks[4], (B,), 0, P, jnp.int32),
                jnp.full((B,), 2, jnp.int32)):        # all rows share page 2
        y = lora_proj_multi(x, idx, w, a, b, 2.0)
        for m in range(B):
            row = lora_proj(x[m], w, a[int(idx[m])], b[int(idx[m])], 2.0)
            assert bool(jnp.all(row == y[m])), m


def test_dispatch_multi_interpret_matches_mirror():
    x, idx, w, a, b = _operands(jax.random.PRNGKey(3))
    x = x[:, None, :]                      # (B, S=1, K), idx (B,)
    y_jnp = lora_proj_multi(x, idx, w, a, b, 1.0)
    dispatch.set_backend("interpret")
    try:
        y_int = lora_proj_multi(x, idx, w, a, b, 1.0)
    finally:
        dispatch.set_backend("jnp")
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_jnp),
                               atol=1e-4, rtol=1e-4)


# gemma3: GQA + mixed local:global; h2o: pure sliding-window; zamba2:
# mamba2 + shared attention; whisper: encoder-decoder cross-attention
_ARCHS = ["llama2-7b", "gemma3-12b", "h2o-danube-3-4b", "rwkv6-1.6b",
          "zamba2-1.2b", "whisper-tiny"]


@pytest.mark.parametrize("arch", _ARCHS)
def test_decode_step_multi_adapter_per_row(arch):
    """One batched decode_step where each row reads its own adapter page
    computes what the plain single-adapter route computes for that row at
    the SAME batch size (rows are independent through every batched op);
    rows sharing one adapter included. Tolerance covers XLA CPU choosing
    different matmul kernels for the shared-A matmul vs the per-row
    gathered einsum (last-ulp only; greedy token choice must agree — the
    serving-level test asserts exact generated-ids equality)."""
    cfg = reduce_config(get_config(arch))
    model = get_model(cfg)
    base = model.init_base(cfg, jax.random.PRNGKey(0))
    store = SyntheticAdapterStore(cfg)
    cache = AdapterCache(store, capacity=4)
    aids = [2, 0, 2, 1]          # rows 0 and 2 share adapter 2
    pages = [cache.acquire(a) for a in aids]
    B = len(aids)
    kv = model.init_cache(cfg, B, 8)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab)
    logits_multi, _ = model.decode_step(cfg, base, cache.multi_peft(pages),
                                        kv, tok, jnp.int32(0))
    for b, aid in enumerate(aids):
        logits_plain, _ = model.decode_step(cfg, base, store.load(aid), kv,
                                            tok, jnp.int32(0))
        np.testing.assert_allclose(
            np.asarray(logits_plain[b], np.float32),
            np.asarray(logits_multi[b], np.float32),
            atol=2e-5, rtol=2e-5, err_msg=f"{arch} row {b}")
        assert int(jnp.argmax(logits_plain[b])) == int(
            jnp.argmax(logits_multi[b])), (arch, b)


def test_decode_step_multi_adapter_vector_pos():
    """Per-row positions compose with per-row adapters: a batched step at
    pos vector [p, p] equals the scalar-pos step bitwise."""
    cfg = reduce_config(get_config("llama2-7b"))
    model = get_model(cfg)
    base = model.init_base(cfg, jax.random.PRNGKey(0))
    store = SyntheticAdapterStore(cfg)
    cache = AdapterCache(store, capacity=2)
    pages = [cache.acquire(0), cache.acquire(1)]
    peft = cache.multi_peft(pages)
    kv = model.init_cache(cfg, 2, 8)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0, cfg.vocab)
    lg_s, kv_s = model.decode_step(cfg, base, peft, kv, tok, jnp.int32(3))
    lg_v, kv_v = model.decode_step(cfg, base, peft, kv, tok,
                                   jnp.full((2,), 3, jnp.int32))
    assert bool(jnp.all(lg_s == lg_v))
    for k in kv_s:
        assert bool(jnp.all(kv_s[k] == kv_v[k])), k
