"""Wire-protocol frames: roundtrips, measured bytes vs Table-2 analytics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SpryConfig, get_config, reduce_config
from repro.core import enumerate_units, init_state
from repro.fl import comm_cost
from repro.fl.runtime import ClientUpdate, TaskAssignment, WIRE_DTYPES
from repro.models import get_model
from repro.peft import init_peft


@pytest.fixture(scope="module")
def peft_setup():
    cfg = reduce_config(get_config("roberta-large-lora"))
    sc = SpryConfig(n_clients_per_round=2)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)
    index = enumerate_units(state.peft)
    return cfg, state.peft, index


def _fake_delta(peft, key):
    leaves, treedef = jax.tree.flatten(peft)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [jax.random.normal(k, l.shape, jnp.float32)
                  for k, l in zip(keys, leaves)])


# ---------------------------------------------------------------------------
# roundtrips
# ---------------------------------------------------------------------------

def test_assignment_roundtrip():
    a = TaskAssignment(round_idx=7, client_id=123456, seed_id=3,
                       cohort_size=16, seed=42, n_units=4,
                       unit_ids=np.array([1, 3], np.int32),
                       hparams={"local_lr": 5e-3, "k": 2})
    b = TaskAssignment.from_bytes(a.to_bytes())
    assert (b.round_idx, b.client_id, b.seed_id, b.cohort_size, b.seed,
            b.n_units) == (7, 123456, 3, 16, 42, 4)
    np.testing.assert_array_equal(b.unit_ids, [1, 3])
    assert b.hparams == {"local_lr": 5e-3, "k": 2}
    row = b.mask_row()
    np.testing.assert_array_equal(row, [0, 1, 0, 1])
    assert a.byte_size() == len(a.to_bytes())


def test_delta_update_roundtrip_fp32_bitexact(peft_setup):
    cfg, peft, index = peft_setup
    delta = _fake_delta(peft, jax.random.PRNGKey(1))
    # zero the unassigned units like the estimator mask does
    unit_ids = np.array([0, 2], np.int64)
    keepmask = np.zeros(index.n_units)
    keepmask[unit_ids] = 1
    masked = jax.tree.map(lambda x: np.array(x, np.float32), delta)
    for uid, (g, t, layer) in enumerate(index.units):
        if keepmask[uid]:
            continue
        for leaf in jax.tree.leaves(masked[g][t]):
            leaf[layer] = 0.0
    u = ClientUpdate.from_delta(masked, index, unit_ids, round_idx=2,
                                client_id=9, seed_id=1, wire="fp32")
    u2 = ClientUpdate.from_bytes(u.to_bytes())
    rebuilt = u2.to_delta(peft, index)
    for a, b in zip(jax.tree.leaves(masked), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_jvp_update_roundtrip(peft_setup):
    jvps = np.array([0.123, -4.5, 6.75], np.float32)
    u = ClientUpdate.from_jvps(jvps, round_idx=1, client_id=2, seed_id=0,
                               wire="fp32", loss=1.5)
    u2 = ClientUpdate.from_bytes(u.to_bytes())
    np.testing.assert_array_equal(np.asarray(u2.jvps, np.float32), jvps)
    assert u2.mode == "jvp" and abs(u2.loss - 1.5) < 1e-9
    assert u.byte_size() == len(u.to_bytes())


@pytest.mark.parametrize("wire", sorted(WIRE_DTYPES))
def test_wire_quantization_shrinks_payload(peft_setup, wire):
    cfg, peft, index = peft_setup
    delta = _fake_delta(peft, jax.random.PRNGKey(2))
    u = ClientUpdate.from_delta(delta, index, np.array([0]), round_idx=0,
                                client_id=0, seed_id=0, wire=wire)
    u2 = ClientUpdate.from_bytes(u.to_bytes())
    itemsize = WIRE_DTYPES[wire].itemsize
    assert u.payload_byte_size() == u.n_payload_scalars() * itemsize
    if wire != "fp32":
        assert u.payload_byte_size() \
            == ClientUpdate.from_delta(delta, index, np.array([0]),
                                       round_idx=0, client_id=0, seed_id=0,
                                       wire="fp32").payload_byte_size() // 2
    # quantized roundtrip stays close (values are O(1) normals)
    rb = u2.to_delta(peft, index)
    for (g, t, layer) in [index.units[0]]:
        for a, b in zip(jax.tree.leaves(delta[g][t]),
                        jax.tree.leaves(rb[g][t])):
            np.testing.assert_allclose(np.asarray(a[layer]),
                                       np.asarray(b[layer]),
                                       atol=0.05, rtol=0.05)


# ---------------------------------------------------------------------------
# measured bytes vs the analytic Table-2 accounting (fl/comm.py)
# ---------------------------------------------------------------------------

def _unit_sizes(peft, index):
    sizes = []
    for (g, t, layer) in index.units:
        leaves = jax.tree.leaves(peft[g][t])
        sizes.append(sum(int(l[layer].size if layer >= 0 else l.size)
                         for l in leaves))
    return sizes


def test_per_epoch_bytes_match_table2(peft_setup):
    """spry per-epoch uplink = w_l * max(L/M, 1) parameters (Table 2)."""
    cfg, peft, index = peft_setup
    U = index.n_units
    sizes = _unit_sizes(peft, index)
    assert len(set(sizes)) == 1, "uniform LoRA units expected"
    w_l = sizes[0]
    M = 2
    analytic = comm_cost("spry", "per_epoch", w_l, U, M).client_to_server
    # this client gets U/M units (the cyclic assignment's per-client share)
    unit_ids = np.arange(U // M)
    delta = _fake_delta(peft, jax.random.PRNGKey(3))
    u = ClientUpdate.from_delta(delta, index, unit_ids, round_idx=0,
                                client_id=0, seed_id=0, wire="fp32",
                                include_head=False)
    # payload parameter count matches the analytic count EXACTLY
    assert u.n_payload_scalars() == int(analytic)
    assert u.payload_byte_size() == int(analytic) * 4
    # full frame = payload + bounded serialization overhead
    overhead = u.byte_size() - u.payload_byte_size()
    assert 0 < overhead < 2048


def test_per_iteration_bytes_match_table2(peft_setup):
    """spry per-iteration uplink = 1 scalar (K=1) + seed ref (Table 2)."""
    cfg, peft, index = peft_setup
    analytic = comm_cost("spry", "per_iteration", 512, index.n_units,
                         2).client_to_server
    u = ClientUpdate.from_jvps(np.zeros((1,), np.float32), round_idx=0,
                               client_id=0, seed_id=0, wire="fp32")
    assert u.n_payload_scalars() == int(analytic) == 1
    overhead = u.byte_size() - u.payload_byte_size()
    assert 0 < overhead < 512
    # K>1 scales the scalar count, still orders below the delta payload
    u8 = ClientUpdate.from_jvps(np.zeros((8,), np.float32), round_idx=0,
                                client_id=0, seed_id=0, wire="fp32")
    assert u8.n_payload_scalars() == 8
    assert u8.byte_size() < 1024


def test_engine_uplink_accounting_matches_messages(peft_setup):
    """The engine's streamed byte estimate equals the measured frames the
    wire simulation actually produces (frame size is shape-only)."""
    import jax.numpy as jnp
    from repro.core import init_state
    from repro.fl.runtime import FederationEngine, WireConfig
    from repro.fl.runtime.engine import _ideal_plan
    from repro.models import get_model

    cfg, peft, index = peft_setup
    sc = SpryConfig(n_clients_per_round=2, local_iters=1, local_lr=1e-2,
                    server_lr=1e-2)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    state = init_state(base, init_peft(cfg, key, sc))
    batch = {"tokens": jax.random.randint(key, (2, 2, 16), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, 2), 0, cfg.n_classes)}
    plan = _ideal_plan(0, 2, index.n_units)
    sim = FederationEngine(cfg, sc, comm_mode="per_epoch",
                           wire=WireConfig(simulate=True))
    est = FederationEngine(cfg, sc, comm_mode="per_epoch",
                           wire=WireConfig(simulate=False))
    _, _, rep_sim = sim.run_round(state, plan, batch)
    _, _, rep_est = est.run_round(state, plan, batch)
    assert rep_sim.bytes_up == rep_est.bytes_up > 0


# ---------------------------------------------------------------------------
# encode-once caching (ISSUE 10 satellite): byte_size()/to_bytes() must not
# re-serialize; mutation goes through invalidate_encoding()
# ---------------------------------------------------------------------------

def _count_frames(monkeypatch):
    """Count calls to the low-level framer (one call == one serialization)."""
    from repro.fl.runtime import messages as msg
    calls = {"n": 0}
    real = msg._frame

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(msg, "_frame", counting)
    return calls


def test_update_encodes_exactly_once(monkeypatch):
    calls = _count_frames(monkeypatch)
    jvps = np.array([0.5, -1.0], np.float32)
    u = ClientUpdate.from_jvps(jvps, round_idx=0, client_id=1, seed_id=0,
                               wire="fp32", loss=0.1)
    n = u.byte_size()
    assert calls["n"] == 1
    assert u.byte_size() == n          # cached: no second encode
    frame = u.to_bytes()               # same bytes, same single encode
    assert calls["n"] == 1
    assert len(frame) == n
    assert u.to_bytes() is frame       # identity: the send path reuses it


def test_assignment_encodes_exactly_once(monkeypatch):
    calls = _count_frames(monkeypatch)
    a = TaskAssignment(round_idx=1, client_id=7, seed_id=0, cohort_size=4,
                       seed=3, n_units=4, unit_ids=np.array([0], np.int32))
    a.byte_size(), a.byte_size(), a.to_bytes()
    assert calls["n"] == 1


def test_from_bytes_seeds_cache_with_received_frame():
    """Decode -> re-encode must reproduce the received bytes verbatim (the
    async snapshot stores in-flight frames through this path)."""
    jvps = np.array([1.25, -2.5, 3.0], np.float32)
    u = ClientUpdate.from_jvps(jvps, round_idx=2, client_id=3, seed_id=1,
                               wire="bf16", loss=0.7)
    u.base_version = 5
    frame = u.to_bytes()
    u2 = ClientUpdate.from_bytes(frame)
    assert u2.base_version == 5
    assert u2.to_bytes() == frame


def test_invalidate_encoding_reencodes(monkeypatch):
    calls = _count_frames(monkeypatch)
    jvps = np.array([0.5], np.float32)
    u = ClientUpdate.from_jvps(jvps, round_idx=0, client_id=1, seed_id=0,
                               wire="fp32", loss=0.1)
    before = u.to_bytes()
    assert calls["n"] == 1
    u.jvps = np.array([9.0], np.float32)
    u.invalidate_encoding()
    after = u.to_bytes()
    assert calls["n"] == 2
    assert after != before
    np.testing.assert_array_equal(
        ClientUpdate.from_bytes(after).jvps, [9.0])


def test_base_version_absent_keeps_sync_frames_byte_identical():
    """Sync frames never carry the staleness tag — adding the async field
    must not change a single byte of the existing wire format."""
    jvps = np.array([0.5, 1.5], np.float32)
    mk = lambda: ClientUpdate.from_jvps(jvps, round_idx=3, client_id=2,
                                        seed_id=0, wire="fp32", loss=0.2)
    u, v = mk(), mk()
    v.base_version = 0
    v.invalidate_encoding()
    assert ClientUpdate.from_bytes(u.to_bytes()).base_version is None
    assert u.to_bytes() != v.to_bytes()
    w = mk()
    assert u.to_bytes() == w.to_bytes()
