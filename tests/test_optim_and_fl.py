"""Optimizers, server aggregation, Dirichlet partition, comm-cost tables."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.fl import (
    comm_cost,
    compute_cost,
    dirichlet_partition,
    heterogeneity_coefficients,
    server_init,
    server_update,
)
from repro.optim import adam, adamw, momentum, sgd, yogi
from repro.optim.optimizers import apply_updates


def _quad(opt, steps=300):
    # minimize (w-3)^2 -> w should approach 3
    w = {"w": jnp.zeros(())}
    state = opt.init(w)
    for _ in range(steps):
        g = jax.grad(lambda p: (p["w"] - 3.0) ** 2)(w)
        upd, state = opt.update(g, state, w)
        w = apply_updates(w, upd)
    return float(w["w"])


def test_sgd_converges():
    assert abs(_quad(sgd(0.1)) - 3.0) < 1e-3


def test_momentum_converges():
    assert abs(_quad(momentum(0.05)) - 3.0) < 1e-2


def test_adam_converges():
    assert abs(_quad(adam(0.1)) - 3.0) < 1e-2


def test_adamw_decays_weights():
    # with pure weight decay and zero gradient, weights shrink
    opt = adamw(0.1, weight_decay=0.5)
    w = {"w": jnp.ones(())}
    state = opt.init(w)
    g = {"w": jnp.zeros(())}
    upd, state = opt.update(g, state, w)
    w2 = apply_updates(w, upd)
    assert float(w2["w"]) < 1.0


def test_yogi_converges():
    assert abs(_quad(yogi(0.1)) - 3.0) < 1e-2


def test_adam_matches_closed_form_first_step():
    opt = adam(0.1, b1=0.9, b2=0.999, eps=1e-8)
    w = {"w": jnp.array(2.0)}
    state = opt.init(w)
    g = {"w": jnp.array(0.5)}
    upd, _ = opt.update(g, state, w)
    # first Adam step = -lr * g/|g| (bias-corrected) = -lr * sign-ish
    expect = -0.1 * 0.5 / (np.sqrt(0.5 ** 2) + 1e-8)
    np.testing.assert_allclose(float(upd["w"]), expect, rtol=1e-4)


# ---------------------------------------------------------------------------
# Server optimizers
# ---------------------------------------------------------------------------

def test_fedavg_server_is_plain_average_application():
    w = {"w": jnp.zeros(3)}
    delta = {"w": jnp.array([1.0, 2.0, 3.0])}
    new, _ = server_update("fedavg", w, delta, server_init(w), lr=1.0)
    np.testing.assert_allclose(np.asarray(new["w"]), [1, 2, 3])


def test_fedyogi_moves_toward_delta():
    w = {"w": jnp.zeros(3)}
    st_ = server_init(w)
    delta = {"w": jnp.array([1.0, -1.0, 2.0])}
    new, st_ = server_update("fedyogi", w, delta, st_, lr=0.1)
    assert float(jnp.sign(new["w"][0])) == 1.0
    assert float(jnp.sign(new["w"][1])) == -1.0


def test_fedyogi_second_moment_sign_rule():
    """Yogi: v update uses sign(v - d^2), differing from Adam exactly when
    v > d^2 (additive vs multiplicative decay)."""
    w = {"w": jnp.zeros(1)}
    st_ = server_init(w)
    d = {"w": jnp.array([2.0])}
    _, st1 = server_update("fedyogi", w, d, st_, lr=0.1)
    # v after first step: 0 - (1-b2)*sign(0-4)*4 = +(1-b2)*4
    np.testing.assert_allclose(np.asarray(st1.v["w"]), [0.01 * 4.0], rtol=1e-5)


# ---------------------------------------------------------------------------
# Dirichlet partition (paper Appendix B)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(alpha=st.sampled_from([0.1, 1.0, 10.0]), n_clients=st.integers(4, 32))
def test_partition_is_a_partition(alpha, n_clients):
    labels = np.random.default_rng(0).integers(0, 4, size=2000)
    parts = dirichlet_partition(labels, n_clients, alpha, seed=1)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)
    assert min(len(p) for p in parts) >= 2


def test_heterogeneity_grows_as_alpha_shrinks():
    labels = np.random.default_rng(0).integers(0, 4, size=4000)
    h = []
    for alpha in (10.0, 1.0, 0.1):
        parts = dirichlet_partition(labels, 16, alpha, seed=1)
        coef = heterogeneity_coefficients(labels, parts, 1.0)
        # dispersion of per-client class fractions grows with heterogeneity
        fracs = np.stack([
            [(labels[p] == c).mean() if len(p) else 0 for c in range(4)]
            for p in parts])
        h.append(fracs.std())
    assert h[0] < h[1] < h[2]


def test_homogeneous_split_coefficients_near_zero():
    """Paper Thm 4.1: alpha_c=1 and matching fractions -> alpha_{m,c} ~ 0."""
    labels = np.tile(np.arange(4), 2500)
    parts = dirichlet_partition(labels, 8, 1000.0, seed=0)  # near-uniform
    coef = heterogeneity_coefficients(labels, parts, 1.0)
    assert np.abs(coef).mean() < 0.05


# ---------------------------------------------------------------------------
# Communication / computation cost tables (paper Tables 2-3)
# ---------------------------------------------------------------------------

def test_comm_cost_spry_beats_backprop_per_epoch():
    w_l, L, M = 1000.0, 48, 16
    spry = comm_cost("spry", "per_epoch", w_l, L, M)
    fedavg = comm_cost("fedavg", "per_epoch", w_l, L, M)
    assert spry.client_to_server < fedavg.client_to_server
    assert spry.server_to_client < fedavg.server_to_client
    # client->server reduced by exactly M when L >= M (paper §1)
    assert fedavg.client_to_server / spry.client_to_server == M


def test_comm_cost_per_iteration_scalar():
    spry = comm_cost("spry", "per_iteration", 1000.0, 48, 16)
    assert spry.client_to_server == 1


def test_compute_cost_spry_client_cheaper_than_zero_order():
    w_l, L, M = 1000.0, 48, 16
    spry = compute_cost("spry", "per_epoch", w_l, L, M, c=100.0, v=10.0)
    baffle = compute_cost("baffle", "per_epoch", w_l, L, M, c=100.0, v=10.0,
                          K=20)
    assert spry.client_per_iter < baffle.client_per_iter
