"""Crash-safe checkpointing: atomic writes, strict restore, manifest
integrity, and the headline kill-and-resume bitwise-replay contract."""
import json
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointError,
    RunManifest,
    load_checkpoint,
    load_pytree,
    read_manifest,
    save_checkpoint,
    save_pytree,
    tree_content_hash,
    write_manifest,
)
from repro.checkpoint.manifest import MANIFEST_NAME, MANIFEST_SCHEMA


def _tree(scale=1.0):
    rng = np.random.default_rng(0)
    return {"a": {"w": (scale * rng.normal(size=(4, 3))).astype(np.float32),
                  "b": (scale * rng.normal(size=(3,))).astype(np.float32)},
            "head": [np.arange(6, dtype=np.float32) * scale]}


def assert_trees_equal(a, b, what=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


# ---------------------------------------------------------------------------
# save_pytree / load_pytree: atomic + strict
# ---------------------------------------------------------------------------

def test_save_load_roundtrip(tmp_path):
    t = _tree()
    p = str(tmp_path / "ck.npz")
    save_pytree(p, t)
    assert_trees_equal(t, load_pytree(p, jax.tree.map(np.zeros_like, t)))


def test_save_leaves_no_tmp_file(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_pytree(p, _tree())
    assert os.path.exists(p)
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []


def test_save_overwrites_stale_tmp(tmp_path):
    """A tmp file abandoned by a previous crash must not break the save."""
    p = str(tmp_path / "ck.npz")
    with open(p + ".tmp", "wb") as f:
        f.write(b"torn garbage from a crashed writer")
    save_pytree(p, _tree())
    assert_trees_equal(_tree(),
                       load_pytree(p, jax.tree.map(np.zeros_like, _tree())))


def test_load_rejects_missing_keys(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_pytree(p, {"a": np.ones(3, np.float32)})
    like = {"a": np.zeros(3, np.float32), "new": np.zeros(2, np.float32)}
    with pytest.raises(CheckpointError, match="missing"):
        load_pytree(p, like)


def test_load_rejects_extra_keys(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_pytree(p, {"a": np.ones(3, np.float32),
                    "stale": np.zeros(2, np.float32)})
    with pytest.raises(CheckpointError, match="extra"):
        load_pytree(p, {"a": np.zeros(3, np.float32)})


def test_load_rejects_shape_mismatch(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_pytree(p, {"a": np.ones((3, 2), np.float32)})
    with pytest.raises(CheckpointError, match="shape"):
        load_pytree(p, {"a": np.zeros((2, 3), np.float32)})


# ---------------------------------------------------------------------------
# manifest: content hash, schema strictness, ordering, gc
# ---------------------------------------------------------------------------

def test_content_hash_is_value_identity():
    t1, t2 = _tree(), _tree()
    assert tree_content_hash(t1) == tree_content_hash(t2)
    t2["a"]["w"][0, 0] += 1
    assert tree_content_hash(t1) != tree_content_hash(t2)


def test_save_load_checkpoint_roundtrip(tmp_path):
    t = _tree()
    rng = np.random.default_rng(7)
    rng.random(5)                       # advance: a mid-run rng state
    man = save_checkpoint(str(tmp_path), t, round_idx=3, algo_seed=11,
                          rng_state=rng.bit_generator.state,
                          history=[{"round": 1, "loss": 0.5}],
                          extra={"bytes_up_total": 123})
    assert man.round_idx == 3 and man.schema == MANIFEST_SCHEMA
    state, got = load_checkpoint(str(tmp_path),
                                 jax.tree.map(np.zeros_like, t))
    assert_trees_equal(t, state)
    assert got.algo_seed == 11
    assert got.history == [{"round": 1, "loss": 0.5}]
    assert got.extra == {"bytes_up_total": 123}
    # the restored host-RNG state replays the exact draw stream
    rng2 = np.random.default_rng(0)
    rng2.bit_generator.state = got.rng_state
    np.testing.assert_array_equal(rng.random(8),
                                  rng2.random(8))


def test_manifest_rejects_unknown_schema_and_keys():
    doc = json.loads(RunManifest(round_idx=1, algo_seed=0, content_hash="x",
                                 state_file="s.npz").to_json())
    bad = dict(doc, schema="repro.checkpoint/v999")
    with pytest.raises(CheckpointError, match="schema"):
        RunManifest.from_json(json.dumps(bad))
    bad = dict(doc, surprise=1)
    with pytest.raises(CheckpointError, match="unknown manifest keys"):
        RunManifest.from_json(json.dumps(bad))


def test_tampered_state_detected(tmp_path):
    t = _tree()
    man = save_checkpoint(str(tmp_path), t, round_idx=1, algo_seed=0)
    # bit-rot / tamper: rewrite the state file with different VALUES but
    # identical keys and shapes — only the content hash can catch this
    save_pytree(str(tmp_path / man.state_file), _tree(scale=2.0))
    with pytest.raises(CheckpointError, match="content hash"):
        load_checkpoint(str(tmp_path), jax.tree.map(np.zeros_like, t))


def test_manifest_points_at_missing_state(tmp_path):
    man = save_checkpoint(str(tmp_path), _tree(), round_idx=1, algo_seed=0)
    os.remove(str(tmp_path / man.state_file))
    with pytest.raises(CheckpointError, match="missing state"):
        load_checkpoint(str(tmp_path), _tree())
    with pytest.raises(CheckpointError, match="no manifest"):
        read_manifest(str(tmp_path / "nowhere"))


def test_gc_keeps_newest_and_current(tmp_path):
    t = _tree()
    for r in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), t, round_idx=r, algo_seed=0,
                        keep_last=2)
    states = sorted(f for f in os.listdir(tmp_path)
                    if f.startswith("state_"))
    assert states == ["state_000003.npz", "state_000004.npz"]
    state, man = load_checkpoint(str(tmp_path),
                                 jax.tree.map(np.zeros_like, t))
    assert man.round_idx == 4
    assert_trees_equal(t, state)


def test_crash_between_state_and_manifest_resumes_previous(tmp_path):
    """The crash window the write ORDER protects: the round-N state landed
    but the manifest didn't. Resume must cleanly land on round N-1."""
    t1, t2 = _tree(), _tree(scale=3.0)
    save_checkpoint(str(tmp_path), t1, round_idx=1, algo_seed=0)
    # simulate a crash mid-save_checkpoint: new state written, manifest not
    save_pytree(str(tmp_path / "state_000002.npz"), t2)
    state, man = load_checkpoint(str(tmp_path),
                                 jax.tree.map(np.zeros_like, t1))
    assert man.round_idx == 1
    assert_trees_equal(t1, state)


def test_write_manifest_atomic(tmp_path):
    man = RunManifest(round_idx=1, algo_seed=0, content_hash="h",
                      state_file="s.npz")
    write_manifest(str(tmp_path), man)
    assert not os.path.exists(str(tmp_path / MANIFEST_NAME) + ".tmp")
    assert read_manifest(str(tmp_path)).content_hash == "h"


# ---------------------------------------------------------------------------
# HEADLINE: kill the run at an arbitrary round, resume, and the final state
# is bitwise identical to the uninterrupted run
# ---------------------------------------------------------------------------

def test_kill_and_resume_bitwise_identical(tmp_path):
    from repro.configs import SpryConfig, get_config, reduce_config
    from repro.core import init_state
    from repro.fl.runtime import FederationEngine
    from repro.models import get_model
    from repro.peft import init_peft

    cfg = reduce_config(get_config("roberta-large-lora"))
    sc = SpryConfig(n_clients_per_round=4, local_iters=1, local_lr=1e-2,
                    server_lr=1e-2, k_perturbations=2)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    state0 = init_state(model.init_base(cfg, key), init_peft(cfg, key, sc))
    batch = {"tokens": jax.random.randint(key, (4, 2, 16), 0, cfg.vocab),
             "labels": jax.random.randint(key, (4, 2), 0, cfg.n_classes)}
    eng = FederationEngine(cfg, sc, comm_mode="per_epoch")

    ROUNDS, KILL_AT = 4, 2
    # uninterrupted trajectory (the round key folds in state.round_idx, so
    # each round is distinct and order matters)
    s = state0
    for _ in range(ROUNDS):
        s, _ = eng.run_ideal(s, batch)
    straight = s

    # killed-and-resumed trajectory: run to the kill point, checkpoint,
    # throw EVERYTHING away, restore from disk into a fresh template, and
    # replay the remaining rounds
    s = state0
    for _ in range(KILL_AT):
        s, _ = eng.run_ideal(s, batch)
    save_checkpoint(str(tmp_path), s, round_idx=KILL_AT, algo_seed=sc.seed)
    del s                                        # the "crash"

    restored, man = load_checkpoint(str(tmp_path), state0)
    assert man.round_idx == KILL_AT
    assert int(np.asarray(restored.round_idx)) == KILL_AT
    for _ in range(ROUNDS - KILL_AT):
        restored, _ = eng.run_ideal(restored, batch)

    assert tree_content_hash(straight.peft) == \
        tree_content_hash(restored.peft)
    assert_trees_equal(straight.peft, restored.peft, "peft")
    assert_trees_equal(straight.server, restored.server, "server")
    assert int(np.asarray(restored.round_idx)) == ROUNDS


@pytest.mark.slow
def test_run_training_resume_bitwise(tmp_path):
    """End-to-end --resume: kill a runtime training run after 2 of 4 rounds
    and resume; the history losses must match the uninterrupted run."""
    from repro.launch.train import run_training

    kw = dict(arch="roberta-large-lora", task="sst2", method="spry",
              rounds=4, clients_per_round=4, total_clients=8,
              batch_size=2, seed=3, eval_every=1, runtime=True,
              log=lambda *a, **k: None)
    full = run_training(**kw)

    ck = str(tmp_path / "ck")
    run_training(rounds=2, checkpoint_dir=ck,
                 **{k: v for k, v in kw.items() if k != "rounds"})
    resumed = run_training(checkpoint_dir=ck, resume=True, **kw)

    assert len(full) == len(resumed) == 4
    for a, b in zip(full, resumed):
        assert a["round"] == b["round"]
        assert np.float32(a["loss"]).tobytes() == \
            np.float32(b["loss"]).tobytes()
        assert a["acc"] == b["acc"]
