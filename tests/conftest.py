import jax
import pytest

# Tests run on the single CPU device (the 512-device dry-run sets its own
# XLA_FLAGS in a subprocess; see tests/test_dryrun_smoke.py).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
