"""Fallback for the ``hypothesis`` property-testing library.

The container image does not ship ``hypothesis`` (and tier-0 policy forbids
installing packages at test time), so the property-test modules import
``given``/``settings``/``st`` from here instead. When the real library is
available (see requirements-dev.txt) it is used unchanged; otherwise a tiny
deterministic shim replays ``max_examples`` pseudo-random draws per test —
weaker shrinking/coverage than real hypothesis, but the same assertions run.
"""
from __future__ import annotations

import functools
import random

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rnd):
            return self._draw(rnd)

    class st:  # noqa: N801 - mirrors ``hypothesis.strategies`` alias
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rnd: rnd.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda rnd: rnd.random() < 0.5)

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(**strategies_):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", 10)
                rnd = random.Random(0)
                for _ in range(n):
                    draw = {k: s.example(rnd) for k, s in strategies_.items()}
                    fn(*args, **kwargs, **draw)

            # hide the wrapped signature or pytest mistakes draw parameters
            # for fixtures (functools.wraps sets __wrapped__)
            del wrapper.__wrapped__
            return wrapper

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
