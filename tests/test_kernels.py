"""Pallas kernels vs pure-jnp oracles (interpret mode), sweeping shapes and
dtypes, plus hypothesis property sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels.lora_dual import lora_dual, lora_dual_ref
from repro.kernels.swa_attention import swa_attention, swa_attention_ref
from repro.kernels.wkv6_scan import wkv6_scan, wkv6_scan_ref


# ---------------------------------------------------------------------------
# lora_dual
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-3), (jnp.bfloat16, 1e-1)])
@pytest.mark.parametrize("M,K,N,r", [(128, 128, 128, 1), (200, 300, 250, 4),
                                     (64, 512, 128, 16), (256, 128, 384, 8)])
def test_lora_dual_allclose(M, K, N, r, dtype, atol):
    ks = jax.random.split(jax.random.PRNGKey(0), 7)
    mk = lambda k, s, sc=0.05: (jax.random.normal(k, s) * sc).astype(dtype)
    x, xd = mk(ks[0], (M, K), 1.0), mk(ks[1], (M, K), 1.0)
    w = mk(ks[2], (K, N))
    a, ad = mk(ks[3], (K, r)), mk(ks[4], (K, r))
    b, bd = mk(ks[5], (r, N)), mk(ks[6], (r, N))
    y, yd = lora_dual(x, xd, w, a, ad, b, bd, scale=2.0)
    yr, ydr = lora_dual_ref(x, xd, w, a, ad, b, bd, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=atol, rtol=atol)
    np.testing.assert_allclose(np.asarray(yd, np.float32),
                               np.asarray(ydr, np.float32), atol=atol, rtol=atol)


def test_lora_dual_matches_jax_jvp():
    """The kernel's (y, ydot) must equal jax.jvp of the LoRA projection —
    the semantics SPRY's forward gradients rely on."""
    ks = jax.random.split(jax.random.PRNGKey(1), 7)
    M, K, N, r = 64, 96, 80, 2
    x = jax.random.normal(ks[0], (M, K))
    xd = jax.random.normal(ks[1], (M, K))
    w = jax.random.normal(ks[2], (K, N)) * 0.05
    a = jax.random.normal(ks[3], (K, r)) * 0.05
    ad = jax.random.normal(ks[4], (K, r)) * 0.05
    b = jax.random.normal(ks[5], (r, N)) * 0.05
    bd = jax.random.normal(ks[6], (r, N)) * 0.05

    def f(x_, a_, b_):
        return x_ @ w + 2.0 * (x_ @ a_) @ b_

    y_ref, yd_ref = jax.jvp(f, (x, a, b), (xd, ad, bd))
    y, yd = lora_dual(x, xd, w, a, ad, b, bd, scale=2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yd_ref), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(M=st.integers(1, 4), K=st.integers(1, 4), N=st.integers(1, 4),
       r=st.integers(1, 4))
def test_lora_dual_odd_shapes(M, K, N, r):
    """Padding path: arbitrary small shapes (not block multiples)."""
    M, K, N = M * 37, K * 53, N * 41
    ks = jax.random.split(jax.random.PRNGKey(M * K * N), 7)
    x = jax.random.normal(ks[0], (M, K))
    xd = jax.random.normal(ks[1], (M, K))
    w = jax.random.normal(ks[2], (K, N)) * 0.05
    a = jax.random.normal(ks[3], (K, r)) * 0.05
    ad = jax.random.normal(ks[4], (K, r)) * 0.05
    b = jax.random.normal(ks[5], (r, N)) * 0.05
    bd = jax.random.normal(ks[6], (r, N)) * 0.05
    y, yd = lora_dual(x, xd, w, a, ad, b, bd, scale=1.0, block_m=64,
                      block_n=64, block_k=64)
    yr, ydr = lora_dual_ref(x, xd, w, a, ad, b, bd, 1.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ydr), atol=1e-3,
                               rtol=1e-3)


# ---------------------------------------------------------------------------
# swa_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,hd,W,bq,bk", [
    (2, 4, 4, 256, 64, None, 64, 64),
    (1, 4, 2, 256, 64, 96, 64, 64),
    (2, 2, 2, 512, 32, 128, 128, 128),
    (1, 8, 4, 128, 64, 32, 32, 32),
    (1, 2, 2, 512, 64, 200, 64, 128),
    (1, 1, 1, 1024, 64, 256, 128, 64),
])
def test_swa_attention_allclose(B, H, KV, S, hd, W, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    out = swa_attention(q, k, v, window=W, block_q=bq, block_k=bk)
    kr = jnp.repeat(k, H // KV, axis=1)
    vr = jnp.repeat(v, H // KV, axis=1)
    ref = swa_attention_ref(q, kr, vr, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=2e-3)


def test_swa_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, H, S, hd = 1, 2, 256, 64
    q = jax.random.normal(ks[0], (B, H, S, hd)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, H, S, hd)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, H, S, hd)).astype(jnp.bfloat16)
    out = swa_attention(q, k, v, window=64, block_q=64, block_k=64)
    ref = swa_attention_ref(q, k, v, window=64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2,
                               rtol=3e-2)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(wmul=st.integers(1, 6))
def test_swa_attention_window_sweep(wmul):
    W = wmul * 48
    ks = jax.random.split(jax.random.PRNGKey(wmul), 3)
    q = jax.random.normal(ks[0], (1, 2, 384, 32))
    k = jax.random.normal(ks[1], (1, 2, 384, 32))
    v = jax.random.normal(ks[2], (1, 2, 384, 32))
    out = swa_attention(q, k, v, window=W, block_q=96, block_k=96)
    ref = swa_attention_ref(q, k, v, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=2e-3)


# ---------------------------------------------------------------------------
# wkv6_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hd,bs", [(2, 128, 4, 32, 32),
                                         (1, 100, 2, 64, 64),
                                         (2, 64, 8, 16, 16),
                                         (1, 256, 1, 8, 128)])
def test_wkv6_scan_allclose(B, S, H, hd, bs):
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.5
    y = wkv6_scan(r, k, v, w, u, block_s=bs)
    yr, _ = wkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)


def test_wkv6_matches_model_recurrence():
    """Kernel semantics == the model's decode recurrence state evolution."""
    from repro.models.ssm import wkv6_recurrence
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    B, S, H, hd = 1, 32, 2, 16
    r, k, v = (jax.random.normal(ks[i], (B, S, H, hd)) * 0.3 for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    y_kernel = wkv6_scan(r, k, v, w, u, block_s=16)
    y_model, _ = wkv6_recurrence(r, k, v, w, u,
                                 jnp.zeros((B, H, hd, hd), jnp.float32))
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=1e-5, rtol=1e-5)
