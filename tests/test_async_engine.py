"""Async (FedBuff-style) federation engine: event-clock determinism,
staleness-weighted buffering, kill-and-resume bitwise replay under chaos,
and the deterministic event simulator it is driven by."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    decode_async_snapshot,
    encode_async_snapshot,
    tree_content_hash,
)
from repro.configs import SpryConfig, get_config, reduce_config
from repro.core import init_state
from repro.fl.runtime import (
    AsyncConfig,
    AsyncFederationEngine,
    ClientPopulation,
    EventHeap,
    FaultConfig,
    FaultInjector,
    WireConfig,
    sample_available,
    simulate_async_utilization,
    simulate_sync_utilization,
)
from repro.models import get_model
from repro.peft import init_peft

ARCH = "roberta-large-lora"


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config(ARCH))
    sc = SpryConfig(n_clients_per_round=4, local_iters=1, local_lr=1e-2,
                    server_lr=1e-2, k_perturbations=2)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)
    rng = np.random.default_rng(0)
    x = rng.integers(0, cfg.vocab, size=(256, 16), dtype=np.int64)
    y = rng.integers(0, cfg.n_classes, size=(256,), dtype=np.int64)
    return cfg, sc, state, x, y


def _engine(setup, mode="per_epoch", faults=None, **overrides):
    cfg, sc, _, x, y = setup
    pop = ClientPopulation(x, y, n_clients=1000, seed=7)
    kw = dict(buffer_size=2, staleness_decay=0.5, concurrency=4, seed=11)
    kw.update(overrides)
    inj = FaultInjector(faults) if faults is not None else None
    return AsyncFederationEngine(cfg, sc, pop, task="cls", comm_mode=mode,
                                 async_cfg=AsyncConfig(**kw),
                                 wire=WireConfig(simulate=True), faults=inj)


_CHAOS = FaultConfig(crash_rate=0.1, loss_rate=0.1, corrupt_rate=0.05,
                     nan_rate=0.05, blowup_rate=0.05, seed=3)


# ---------------------------------------------------------------------------
# determinism & buffer semantics
# ---------------------------------------------------------------------------

def test_async_replay_is_bitwise(setup):
    """Two fresh engines over the same population produce bit-identical
    model states, metrics, and virtual clocks."""
    _, _, state, _, _ = setup
    runs = []
    for _ in range(2):
        eng = _engine(setup)
        s, losses, clocks = state, [], []
        for _ in range(3):
            s, m, rep = eng.run_version(s, batch_size=2)
            losses.append(float(m["loss"]))
            clocks.append(rep.sim_time_s)
        runs.append((tree_content_hash(s.peft), losses, clocks))
    assert runs[0] == runs[1]


def test_async_staleness_weighting_and_late_arrivals(setup):
    """Late round-r updates land in a later buffer with staleness > 0, and
    the staleness histogram reaches the report."""
    _, _, state, _, _ = setup
    eng = _engine(setup)
    s, stale = state, []
    for _ in range(4):
        s, _, rep = eng.run_version(s, batch_size=2)
        stale.extend(rep.staleness)
        assert rep.n_aggregated == 2          # buffer_size arrivals each
        assert rep.in_flight >= 0
    assert any(st > 0 for st in stale)        # some update aggregated late
    assert all(st >= 0 for st in stale)
    assert int(s.round_idx) == 4 == eng.version


def test_async_max_staleness_discards(setup):
    """max_staleness=0 forces every stale buffered update to be dropped and
    accounted as discarded compute."""
    _, _, state, _, _ = setup
    eng = _engine(setup, max_staleness=0)
    s = state
    for _ in range(4):
        s, _, rep = eng.run_version(s, batch_size=2)
    assert all(st == 0 for st in rep.staleness)
    strict = rep.discarded_compute_s
    loose_eng = _engine(setup)
    s2 = state
    for _ in range(4):
        s2, _, rep2 = loose_eng.run_version(s2, batch_size=2)
    assert strict > rep2.discarded_compute_s  # strictness wasted compute


def test_async_fresh_buffer_reduces_to_unit_average(setup):
    """staleness_decay=0 weights everything equally — an all-fresh buffer
    aggregation must agree with decay>0 (weights only differ when stale)."""
    _, _, state, _, _ = setup
    a = _engine(setup, staleness_decay=0.0)
    b = _engine(setup, staleness_decay=0.9)
    sa, _, ra = a.run_version(state, batch_size=2)
    sb, _, rb = b.run_version(state, batch_size=2)
    # first version: nothing can be stale yet in either engine
    assert ra.staleness == rb.staleness == [0, 0]
    assert tree_content_hash(sa.peft) == tree_content_hash(sb.peft)


def test_async_version_mismatch_raises(setup):
    """A fresh engine adopts the state's round (resume-from-sync is legal),
    but an engine mid-run must reject a state from a different version."""
    _, _, state, _, _ = setup
    eng = _engine(setup)
    eng.run_version(state, batch_size=2)      # engine now at version 1
    with pytest.raises(ValueError):
        eng.run_version(state, batch_size=2)  # stale round-0 state again


# ---------------------------------------------------------------------------
# kill-and-resume (crash-safe replay) under chaos
# ---------------------------------------------------------------------------

def test_async_kill_and_resume_bitwise_under_chaos(setup):
    """Snapshot mid-run (through JSON, as the manifest stores it), restore
    into a FRESH engine, and the continuation is bit-identical to an
    uninterrupted run — with the full fault schedule active."""
    _, _, state, _, _ = setup
    ref = _engine(setup, faults=_CHAOS)
    s, ref_losses = state, []
    for _ in range(4):
        s, m, _ = ref.run_version(s, batch_size=2)
        ref_losses.append(float(m["loss"]))
    ref_hash = tree_content_hash(s.peft)

    a = _engine(setup, faults=_CHAOS)
    s2 = state
    for _ in range(2):
        s2, _, _ = a.run_version(s2, batch_size=2)
    doc = json.loads(json.dumps(encode_async_snapshot(a.snapshot())))
    b = _engine(setup, faults=_CHAOS)
    b.restore(decode_async_snapshot(doc))
    losses = []
    for _ in range(2):
        s2, m, _ = b.run_version(s2, batch_size=2)
        losses.append(float(m["loss"]))
    assert tree_content_hash(s2.peft) == ref_hash
    assert losses == ref_losses[2:]


@pytest.mark.slow
def test_async_kill_and_resume_bitwise_per_iteration(setup):
    _, _, state, _, _ = setup
    ref = _engine(setup, mode="per_iteration", faults=_CHAOS)
    s, ref_losses = state, []
    for _ in range(3):
        s, m, _ = ref.run_version(s, batch_size=2)
        ref_losses.append(float(m["loss"]))
    ref_hash = tree_content_hash(s.peft)

    a = _engine(setup, mode="per_iteration", faults=_CHAOS)
    s2 = state
    s2, _, _ = a.run_version(s2, batch_size=2)
    doc = json.loads(json.dumps(encode_async_snapshot(a.snapshot())))
    b = _engine(setup, mode="per_iteration", faults=_CHAOS)
    b.restore(decode_async_snapshot(doc))
    losses = []
    for _ in range(2):
        s2, m, _ = b.run_version(s2, batch_size=2)
        losses.append(float(m["loss"]))
    assert tree_content_hash(s2.peft) == ref_hash
    assert losses == ref_losses[1:]


# ---------------------------------------------------------------------------
# event heap & simulators
# ---------------------------------------------------------------------------

def test_event_heap_snapshot_restores_ordering():
    h = EventHeap()
    h.push(5.0, {"id": "late"})
    h.push(1.0, {"id": "early"})
    h.push(1.0, {"id": "early-tie"})   # FIFO tie-break via seq
    snap = h.snapshot()
    h2 = EventHeap.restore(json.loads(json.dumps(snap)))
    order = [h2.pop()[2]["id"] for _ in range(3)]
    assert order == ["early", "early-tie", "late"]
    h2.push(0.5, {"id": "new"})        # next_seq survives the round-trip
    t, seq, p = h2.pop()
    assert p["id"] == "new" and seq == 3


def test_sample_available_deterministic():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, size=(64, 16), dtype=np.int64)
    y = rng.integers(0, 4, size=(64,), dtype=np.int64)
    pop = ClientPopulation(x, y, n_clients=100_000, seed=7)
    picks = [sample_available(pop, tick=3, draw=d, seed=5) for d in range(8)]
    again = [sample_available(pop, tick=3, draw=d, seed=5) for d in range(8)]
    assert picks == again
    assert all(0 <= c < pop.n_clients for c in picks)


def test_async_sim_beats_sync_utilization_small():
    """Fast-gate scale check at 10k clients: the async policy wastes less
    of the fleet's compute than deadline-cut sync."""
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, size=(128, 16), dtype=np.int64)
    y = rng.integers(0, 4, size=(128,), dtype=np.int64)
    pop = ClientPopulation(x, y, n_clients=10_000, seed=7)
    sync = simulate_sync_utilization(pop, cohort=16, rounds=8,
                                     deadline_quantile=0.75,
                                     dropout_rate=0.1, seed=5)
    asy = simulate_async_utilization(pop, concurrency=16, buffer_size=4,
                                     server_steps=32, dropout_rate=0.1,
                                     seed=5)
    assert 0.0 < sync.utilization < 1.0
    assert asy.utilization > sync.utilization
    assert asy.updates_applied == 32 * 4
    # replays are bitwise: same seeds, same report
    again = simulate_async_utilization(pop, concurrency=16, buffer_size=4,
                                       server_steps=32, dropout_rate=0.1,
                                       seed=5)
    assert again.to_doc() == asy.to_doc()


@pytest.mark.slow
def test_async_sim_million_client_sweep():
    """The full 10^6-client sweep behind BENCH_async.json's acceptance bar:
    async must clear 1.5x useful-compute vs the q0.75 sync baseline."""
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, size=(256, 16), dtype=np.int64)
    y = rng.integers(0, 4, size=(256,), dtype=np.int64)
    pop = ClientPopulation(x, y, n_clients=1_000_000, seed=7)
    sync = simulate_sync_utilization(pop, cohort=64, rounds=40,
                                     deadline_quantile=0.75,
                                     dropout_rate=0.1, seed=5)
    asy = simulate_async_utilization(pop, concurrency=64, buffer_size=16,
                                     server_steps=160, dropout_rate=0.1,
                                     seed=5)
    assert asy.utilization / sync.utilization >= 1.5
    assert asy.staleness_mean > 0.0
