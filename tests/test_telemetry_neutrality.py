"""Telemetry must be a pure observer: enabling it changes NOTHING the
workloads compute. Engine round state/metrics and ServingEngine token ids
are asserted bitwise identical with telemetry on vs off, and the
instrumented entry points must lower to identical HLO either way (the same
invariant the repro.analysis telemetry-neutrality rule enforces in CI)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SpryConfig, get_config, reduce_config
from repro.core import init_state
from repro.fl.runtime import FederationEngine, SerialExecutor, WireConfig
from repro.launch.adapter_cache import AdapterCache, SyntheticAdapterStore
from repro.launch.serving import Request, ServingEngine
from repro.models import get_model
from repro.obs import InMemorySink, Telemetry
from repro.peft import init_peft

ARCH = "rwkv6-1.6b"


def _fed_setup(M=3, B=2, S=16):
    cfg = reduce_config(get_config(ARCH))
    sc = SpryConfig(n_clients_per_round=M, local_iters=1, local_lr=1e-2,
                    server_lr=1e-2, k_perturbations=2)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)
    batch = {"tokens": jax.random.randint(key, (M, B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (M, B), 0, cfg.n_classes)}
    return cfg, sc, state, batch


def _assert_trees_equal(a, b, what=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def test_engine_round_bitwise_identical_with_telemetry():
    cfg, sc, state, batch = _fed_setup()

    eng_off = FederationEngine(cfg, sc, task="cls")
    s_off, m_off = eng_off.run_ideal(state, batch)

    sink = InMemorySink()
    tel = Telemetry(run_id="t", sinks=[sink])
    eng_on = FederationEngine(cfg, sc, task="cls", telemetry=tel)
    s_on, m_on = eng_on.run_ideal(state, batch)

    _assert_trees_equal(s_off.peft, s_on.peft, "peft")
    _assert_trees_equal(s_off.server, s_on.server, "server state")
    _assert_trees_equal(m_off, m_on, "metrics")
    # ...and the instrumented run actually recorded the round
    rounds = sink.by_kind("round")
    assert len(rounds) == 1
    assert rounds[0]["loss"] == float(m_on["loss"])
    assert rounds[0]["survivors"] == 3 and rounds[0]["cohort"] == 3
    assert tel.registry.counter("fl.rounds").value == 1


def test_engine_wire_sim_bitwise_identical_with_telemetry():
    cfg, sc, state, batch = _fed_setup()
    wire = WireConfig(dtype="fp32", simulate=True)

    s_off, m_off = FederationEngine(
        cfg, sc, task="cls", wire=wire).run_ideal(state, batch)
    sink = InMemorySink()
    s_on, m_on = FederationEngine(
        cfg, sc, task="cls", wire=wire,
        telemetry=Telemetry(run_id="t", sinks=[sink])).run_ideal(state, batch)

    _assert_trees_equal(s_off.peft, s_on.peft, "peft (wire-sim)")
    _assert_trees_equal(m_off, m_on, "metrics (wire-sim)")
    assert sink.by_kind("round")[0]["bytes_up"] > 0


def _serving_outputs(telemetry):
    cfg = reduce_config(get_config(ARCH))
    model = get_model(cfg)
    base = model.init_base(cfg, jax.random.PRNGKey(0))
    store = SyntheticAdapterStore(cfg)
    cache = AdapterCache(store, capacity=2, telemetry=telemetry)
    eng = ServingEngine(cfg, base, cache, max_batch=2, cache_len=16,
                        telemetry=telemetry)
    rng = np.random.default_rng(3)
    reqs = [Request(request_id=f"q{i}", adapter_id=i,
                    prompt=rng.integers(0, cfg.vocab, size=6).astype(
                        np.int32),
                    max_new_tokens=5)
            for i in range(3)]
    return eng.run(reqs), eng


def test_serving_token_ids_bitwise_identical_with_telemetry():
    out_off, _ = _serving_outputs(None)

    sink = InMemorySink()
    tel = Telemetry(run_id="s", sinks=[sink])
    out_on, eng_on = _serving_outputs(tel)

    assert out_off == out_on   # exact integer token ids, every request
    reqs = sink.by_kind("request")
    assert {e["request_id"] for e in reqs} == {"q0", "q1", "q2"}
    for e in reqs:
        assert e["gen_tokens"] == 5
        assert e["ttft_s"] >= 0 and e["latency_s"] >= e["ttft_s"]
    snap = tel.metrics_snapshot()
    assert snap["counters"]["serve.requests"] == 3
    assert snap["counters"]["serve.gen_tokens"] == 15
    assert snap["counters"]["adapter_cache.misses"] >= 3
    assert snap["histograms"]["serve.ttft_s"]["count"] == 3


def test_instrumented_entrypoints_lower_identically():
    """The jaxpr/HLO sweep: every telemetry-pair entry point must lower to
    byte-identical text with telemetry on vs off."""
    from repro.analysis.entrypoints import telemetry_pair_lowered
    from repro.analysis.rules import check_telemetry_neutrality

    traces = telemetry_pair_lowered("ssm")
    assert len(traces) >= 3   # engine round + serving decode1 + scatter
    for t in traces:
        findings = check_telemetry_neutrality(
            t.name, t.meta["text_off"], t.meta["text_on"])
        assert all(f.severity == "info" for f in findings), (
            t.name, [str(f) for f in findings])


def test_neutrality_rule_has_teeth():
    from repro.analysis.rules import check_telemetry_neutrality
    same = check_telemetry_neutrality("e", "aaa\nbbb", "aaa\nbbb")
    assert [f.severity for f in same] == ["info"]
    diff = check_telemetry_neutrality("e", "aaa\nbbb", "aaa\nccc")
    assert [f.severity for f in diff] == ["error"]
    assert diff[0].data["first_diff_line"] == 2


def test_chrome_trace_exports_spans_from_real_run(tmp_path):
    cfg, sc, state, batch = _fed_setup()
    tel = Telemetry(run_id="t", sinks=[InMemorySink()])
    FederationEngine(cfg, sc, task="cls", telemetry=tel).run_ideal(state,
                                                                   batch)
    path = tmp_path / "trace.json"
    tel.export_chrome_trace(str(path))
    from repro.obs import load_chrome_trace
    doc = load_chrome_trace(str(path))
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert "fl.round" in names
