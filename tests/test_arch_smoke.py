"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(2 layers, d_model<=512, <=4 experts) runs one forward pass, one SPRY train
round, and one decode step on CPU — asserting shapes and finiteness.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, SpryConfig, get_config, reduce_config
from repro.core import init_state, make_round_step
from repro.models import get_model, lm_loss
from repro.peft import init_peft


def _batch_for(cfg, key, M=None, B=2, S=24):
    shape = (M, B, S) if M else (B, S)
    batch = {"tokens": jax.random.randint(key, shape, 0, cfg.vocab)}
    if cfg.frontend == "vision" and cfg.n_frontend_tokens:
        eshape = ((M, B) if M else (B,)) + (cfg.n_frontend_tokens, cfg.d_model)
        batch["patch_embeds"] = jnp.zeros(eshape, jnp.float32)
    if cfg.family == "audio":
        fshape = ((M, B) if M else (B,)) + (cfg.encoder_seq, cfg.d_model)
        batch["frames"] = jnp.zeros(fshape, jnp.float32)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_shapes_and_finite(arch, key):
    cfg = reduce_config(get_config(arch))
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    batch = _batch_for(cfg, key)
    h, aux = model.forward(cfg, base, peft, batch)
    S_total = batch["tokens"].shape[1] + (
        cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert h.shape == (2, S_total, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
    loss = lm_loss(cfg, base, peft, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_spry_train_step(arch, key):
    cfg = reduce_config(get_config(arch))
    sc = SpryConfig(n_clients_per_round=2, local_iters=1, local_lr=1e-3,
                    server_lr=1e-2)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)
    step = jax.jit(make_round_step(cfg, sc, task="lm"))
    batch = _batch_for(cfg, key, M=2)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # peft actually changed
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(new_state.peft),
                        jax.tree.leaves(state.peft)))
    assert moved
    # base frozen
    for a, b in zip(jax.tree.leaves(new_state.base),
                    jax.tree.leaves(state.base)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_decode_step(arch, key):
    cfg = reduce_config(get_config(arch))
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    B = 2
    cache = model.init_cache(cfg, B, 32)
    logits, cache2 = model.decode_step(cfg, base, peft, cache,
                                       jnp.zeros((B, 1), jnp.int32),
                                       jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["gemma3-12b", "h2o-danube-3-4b",
                                  "rwkv6-1.6b", "zamba2-1.2b"])
def test_decode_matches_teacher_forcing(arch, key):
    """Decode with cache must reproduce the teacher-forced last-position
    logits (sub-quadratic archs: the long_500k path's correctness)."""
    cfg = reduce_config(get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    h, _ = model.forward(cfg, base, peft, {"tokens": toks})
    un = base["embed"].T if cfg.tie_embeddings else base["lm_head"]
    ref = (h[:, -1, :] @ un).astype(jnp.float32)
    cache = model.init_cache(cfg, B, S + 2)
    step = jax.jit(lambda c, t, p: model.decode_step(cfg, base, peft, c, t, p))
    for i in range(S):
        logits, cache = step(cache, toks[:, i:i + 1], jnp.int32(i))
    rel = float(jnp.abs(logits - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 2e-2, rel


def test_kv_int8_decode_matches_bf16(key):
    """Beyond-paper int8 KV cache: decode logits must match the full-precision
    teacher-forced reference closely (EXPERIMENTS §Perf-2 iter 4)."""
    from repro.models import transformer
    cfg = reduce_config(get_config("gemma3-27b"))
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    h, _ = model.forward(cfg, base, peft, {"tokens": toks})
    ref = (h[:, -1, :] @ base["embed"].T).astype(jnp.float32)
    cache = transformer.init_cache(cfg, B, S + 2, kv_int8=True)
    step = jax.jit(lambda c, t, p: model.decode_step(cfg, base, peft, c, t, p))
    for i in range(S):
        logits, cache = step(cache, toks[:, i:i + 1], jnp.int32(i))
    rel = float(jnp.abs(logits - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 5e-2, rel


def test_ring_buffer_cache_equivalence(key):
    """SWA arch: a ring-buffer cache (len=window) must give the same logits
    as a full-length cache once both cover the window."""
    cfg = reduce_config(get_config("h2o-danube-3-4b"))
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    B, S = 1, 40                     # window after reduce_config = 64 > S
    cfg_small_window = dataclasses.replace(cfg, window=8)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    def run(cache_len):
        cache = model.init_cache(cfg_small_window, B, cache_len)
        step = jax.jit(lambda c, t, p: model.decode_step(
            cfg_small_window, base, peft, c, t, p))
        for i in range(S):
            logits, cache = step(cache, toks[:, i:i + 1], jnp.int32(i))
        return logits

    ring = run(8)        # == window -> ring buffer
    full = run(S + 1)    # full cache, window applied by masking
    np.testing.assert_allclose(np.asarray(ring), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
