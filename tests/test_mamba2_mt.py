"""Mamba2 multi-tangent kernel + dispatch (ISSUE 4 satellite — closes the
last ROADMAP mt-coverage gap).

Covers: the mamba2_scan kernels against the jnp scan oracle (which is
bit-identical to the scan previously inlined in models/ssm.py::mamba2_mix,
with the dt multiplication hoisted — an exact elementwise identity);
bitwise equality of stacked vs single-tangent passes; the dispatch routing
(vmap-of-tangents -> ONE multi-tangent pallas_call); the model-level
fresh-state fast path; and reverse-mode non-interference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.forward_grad import forward_gradient
from repro.kernels import dispatch
from repro.kernels.mamba2_scan import (
    mamba2_scan,
    mamba2_scan_mt,
    mamba2_scan_mt_ref,
    mamba2_scan_mt_tangents,
    mamba2_scan_ref,
)


def _problem(B=2, S=96, H=3, hd=8, N=16, T=3, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    xdt = jax.random.normal(ks[0], (B, S, H, hd)) * 0.3
    bm = jax.random.normal(ks[1], (B, S, N)) * 0.3
    cm = jax.random.normal(ks[2], (B, S, N)) * 0.3
    dec = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H)))
    xd = jax.random.normal(ks[4], (T, B, S, H, hd)) * 0.3
    bd = jax.random.normal(ks[5], (T, B, S, N)) * 0.3
    cd = jax.random.normal(ks[6], (T, B, S, N)) * 0.3
    dd = jax.random.normal(ks[7], (T, B, S, H)) * 0.1
    return (xdt, bm, cm, dec), (xd, bd, cd, dd)


@pytest.mark.parametrize("S", [96, 75])
def test_mamba2_primal_kernel_matches_ref(S):
    (xdt, bm, cm, dec), _ = _problem(S=S)
    y = mamba2_scan(xdt, bm, cm, dec, block_s=32)
    yr, _ = mamba2_scan_ref(xdt, bm, cm, dec)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("S", [96, 75])
def test_mamba2_mt_matches_jvp_oracle(S):
    (xdt, bm, cm, dec), (xd, bd, cd, dd) = _problem(S=S)
    y, yds = mamba2_scan_mt(xdt, bm, cm, dec, xd, bd, cd, dd, block_s=32)
    yr, ydr = mamba2_scan_mt_ref(xdt, bm, cm, dec, xd, bd, cd, dd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(yds), np.asarray(ydr), atol=1e-5,
                               rtol=1e-5)


def test_mamba2_mt_stacked_bitwise_equals_single_tangent_passes():
    (xdt, bm, cm, dec), (xd, bd, cd, dd) = _problem()
    T = xd.shape[0]
    yds = mamba2_scan_mt_tangents(xdt, bm, cm, dec, xd, bd, cd, dd,
                                  block_s=32)
    for t in range(T):
        one = mamba2_scan_mt_tangents(xdt, bm, cm, dec, xd[t:t + 1],
                                      bd[t:t + 1], cd[t:t + 1], dd[t:t + 1],
                                      block_s=32)
        np.testing.assert_array_equal(np.asarray(yds[t]), np.asarray(one[0]))


def test_mamba2_mt_tangents_match_full_pass():
    (xdt, bm, cm, dec), (xd, bd, cd, dd) = _problem(seed=5)
    _, yds = mamba2_scan_mt(xdt, bm, cm, dec, xd, bd, cd, dd, block_s=32)
    ydt = mamba2_scan_mt_tangents(xdt, bm, cm, dec, xd, bd, cd, dd,
                                  block_s=32)
    np.testing.assert_array_equal(np.asarray(yds), np.asarray(ydt))


def test_mamba2_bc_streams_not_widened_per_head():
    """B_t/C_t are shared across heads — the kernel folds the head row back
    to its batch row in-grid, so the pallas_call's B/C operands must stay
    (B, S, N), never the (B*H, S, N) pre-broadcast."""
    B, S, H, hd, N = 1, 64, 4, 8, 16
    (xdt, bm, cm, dec), _ = _problem(B=B, S=S, H=H, hd=hd, N=N)
    jaxpr = jax.make_jaxpr(
        lambda *a: mamba2_scan(*a, block_s=32))(xdt, bm, cm, dec)

    def walk(j):
        for eqn in j.eqns:
            if eqn.primitive.name == "pallas_call":
                yield eqn
            for p in eqn.params.values():
                inner = getattr(p, "jaxpr", None)
                if inner is not None:
                    yield from walk(inner if hasattr(inner, "eqns")
                                    else inner.jaxpr)

    calls = list(walk(jaxpr.jaxpr))
    assert len(calls) == 1
    in_shapes = [tuple(v.aval.shape) for v in calls[0].invars]
    assert (B, S, N) in in_shapes, in_shapes
    assert (B * H, S, N) not in in_shapes, "B/C were widened per head"


# ---------------------------------------------------------------------------
# dispatch routing + estimator equivalence
# ---------------------------------------------------------------------------

def _pallas_calls(closed_jaxpr):
    def walk(j):
        for eqn in j.eqns:
            if eqn.primitive.name == "pallas_call":
                yield eqn
            for p in eqn.params.values():
                inner = getattr(p, "jaxpr", None)
                if inner is not None:
                    yield from walk(inner if hasattr(inner, "eqns")
                                    else inner.jaxpr)
    return list(walk(closed_jaxpr.jaxpr))


def test_vmap_of_mamba2_tangents_traces_mt_route():
    """The batched estimator's vmap through ``dispatch.mamba2_mix`` must
    hit mamba2_scan_mt_tangents (leading-K tangent output), not a
    re-gridded T=1 kernel."""
    K = 4
    (xdt, bm, cm, dec), _ = _problem(B=1, S=32, H=2, hd=8, N=8, T=1)

    def f(prim):
        return jnp.mean(dispatch.mamba2_mix(prim["x"], prim["b"], prim["c"],
                                            prim["d"]) ** 2)

    prim = {"x": xdt, "b": bm, "c": cm, "d": dec}
    dispatch.set_backend("interpret")
    try:
        with dispatch.forward_ad_region():
            _, tangent_map = jax.linearize(f, prim)
        vs = jax.tree.map(lambda t: jnp.zeros((K,) + t.shape), prim)
        jaxpr = jax.make_jaxpr(jax.vmap(tangent_map))(vs)
    finally:
        dispatch.set_backend(None)

    calls = _pallas_calls(jaxpr)
    assert len(calls) == 1, f"expected ONE fused mt pallas_call, got {calls}"
    (out_aval,) = [v.aval for v in calls[0].outvars]
    assert out_aval.shape[0] == K, (
        f"tangent output {out_aval.shape} does not carry the leading K axis")


def test_mamba2_estimator_batched_jvps_bitwise_equal_sequential():
    """The batched K-tangent estimate through the dispatched mamba2 mixer
    must give jvps BITWISE equal to the sequential tangent_batch=1 run on
    the interpret backend — per-tangent kernel lanes are exact replicas of
    the T=1 pass."""
    B, S, H, hd, N = 1, 48, 2, 8, 8
    D = H * hd
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    x = jax.random.normal(ks[0], (B, S, D)) * 0.3
    w0 = jax.random.normal(ks[1], (D, D)) * 0.05
    bmw = jax.random.normal(ks[2], (D, N)) * 0.1
    cmw = jax.random.normal(ks[3], (D, N)) * 0.1
    peft = {"A": jax.random.normal(ks[4], (D, 2)) * 0.05,
            "B": jax.random.normal(ks[5], (2, D)) * 0.05}
    dec = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(3), (B, S, H)))

    def loss(p):
        h = dispatch.lora_proj(x, w0, p["A"], p["B"], 2.0)
        y = dispatch.mamba2_mix(h.reshape(B, S, H, hd),
                                (h @ bmw).astype(jnp.float32),
                                (h @ cmw).astype(jnp.float32), dec)
        return jnp.mean(y * y)

    key = jax.random.PRNGKey(9)
    dispatch.set_backend("interpret")
    try:
        _, _, j_seq = forward_gradient(loss, peft, key, k_perturbations=4,
                                       tangent_batch=1)
        _, _, j_bat = forward_gradient(loss, peft, key, k_perturbations=4)
    finally:
        dispatch.set_backend(None)
    np.testing.assert_array_equal(np.asarray(j_seq), np.asarray(j_bat))


def test_mamba2_model_fast_path_matches_jnp_scan():
    """models/ssm.py::mamba2_mix under use_kernel_mixers() (fresh state)
    must produce the same output as the native scan path, and return
    state=None there (the estimator's loss closures never consume it)."""
    from repro.configs import get_config, reduce_config
    from repro.models.ssm import mamba2_mix, mamba2_params

    cfg = reduce_config(get_config("zamba2-1.2b"))
    key = jax.random.PRNGKey(0)
    p = mamba2_params(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, 24, cfg.d_model)) * 0.3

    out_ref, state_ref, conv_ref = mamba2_mix(cfg, p, x)
    assert state_ref is not None

    dispatch.set_backend("interpret")
    try:
        with dispatch.forward_ad_region():
            out_k, state_k, conv_k = mamba2_mix(cfg, p, x)
    finally:
        dispatch.set_backend(None)
    assert state_k is None
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(conv_k), np.asarray(conv_ref))


def test_mamba2_reverse_mode_unaffected():
    """jax.grad through dispatch.mamba2_mix (outside the region) must work
    on every backend — the jnp-mirror jvp rule is transposable."""
    (xdt, bm, cm, dec), _ = _problem(B=1, S=32, H=2, hd=8, N=8, T=1)

    def loss(x_):
        return jnp.mean(dispatch.mamba2_mix(x_, bm, cm, dec) ** 2)

    g_ref = jax.grad(loss)(xdt)
    for backend in ("interpret", "pallas"):
        dispatch.set_backend(backend)
        try:
            np.testing.assert_allclose(np.asarray(jax.grad(loss)(xdt)),
                                       np.asarray(g_ref), rtol=1e-6)
        finally:
            dispatch.set_backend(None)
