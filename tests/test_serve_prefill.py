"""Fused prefill vs the token-by-token decode loop: identical decode output."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SpryConfig, get_config, reduce_config
from repro.launch.serve import greedy_generate, tokenwise_prefill
from repro.models import get_model
from repro.peft import init_peft


def _setup(arch):
    cfg = reduce_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    return cfg, model, base, peft, key


# h2o-danube3 is pure sliding-window: P=70 > window=64 exercises the
# ring-buffer slot mapping of the fused cache insert; zamba2 P=70 exercises
# the hybrid shared-attention ring the same way (plus mamba2/conv state
# capture); whisper exercises the encoder-decoder prefill
CASES = [("llama2-7b", 12, 6), ("rwkv6-1.6b", 12, 6),
         ("gemma3-12b", 12, 6), ("h2o-danube-3-4b", 70, 5),
         ("zamba2-1.2b", 12, 6), ("zamba2-1.2b", 70, 5),
         ("whisper-tiny", 12, 6)]


@pytest.mark.parametrize("arch,P,steps", CASES)
def test_fused_prefill_decode_identical(arch, P, steps):
    cfg, model, base, peft, key = _setup(arch)
    prompt = jax.random.randint(key, (2, P), 0, cfg.vocab)
    ids_fused = greedy_generate(cfg, base, peft, prompt, steps,
                                fused_prefill=True)
    ids_loop = greedy_generate(cfg, base, peft, prompt, steps,
                               fused_prefill=False)
    np.testing.assert_array_equal(np.asarray(ids_fused), np.asarray(ids_loop))


@pytest.mark.parametrize("arch", ["llama2-7b", "rwkv6-1.6b"])
def test_fused_prefill_state_matches_tokenwise(arch):
    """Logits and the post-prefill cache agree with the decode-loop oracle."""
    cfg, model, base, peft, key = _setup(arch)
    P, steps = 10, 4
    prompt = jax.random.randint(key, (2, P), 0, cfg.vocab)
    cache0 = model.init_cache(cfg, 2, P + steps)
    lg_loop, cache_loop = tokenwise_prefill(cfg, model, base, peft, cache0,
                                            prompt)
    lg_fused, cache_fused = jax.jit(
        lambda b, p, c, t: model.prefill(cfg, b, p, c, t))(
        base, peft, cache0, prompt)
    np.testing.assert_allclose(np.asarray(lg_loop), np.asarray(lg_fused),
                               atol=2e-4, rtol=2e-4)
    for (ka, a), (kb, b) in zip(
            sorted(cache_loop.items()), sorted(cache_fused.items())):
        assert ka == kb
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-4, err_msg=ka)


def test_fused_prefill_matches_loop_with_bitfit():
    """BitFit biases are a decode-path no-op (decode_step never applies
    bias1/bias2); the fused prefill must mirror that, not the train
    forward."""
    cfg = reduce_config(get_config("llama2-7b"))
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig(peft="bitfit"))
    # make the biases decidedly nonzero so a mismatch would show
    peft = jax.tree.map(
        lambda x: x + 0.1 if x.ndim == 2 else x, peft)
    prompt = jax.random.randint(key, (2, 10), 0, cfg.vocab)
    a = greedy_generate(cfg, base, peft, prompt, 4, fused_prefill=True)
    b = greedy_generate(cfg, base, peft, prompt, 4, fused_prefill=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch,P,steps,cache_len", [
    ("gemma3-12b", 20, 3, 12),        # global layers + lossy ring
    ("h2o-danube-3-4b", 40, 3, 32),   # pure swa but ring < window
])
def test_lossy_ring_falls_back_to_tokenwise(arch, P, steps, cache_len):
    """cache_len < prompt with global layers (or a ring shorter than the
    window) makes fused full/banded attention diverge from the lossy decode
    loop — greedy_generate must fall back and stay identical."""
    cfg, model, base, peft, key = _setup(arch)
    prompt = jax.random.randint(key, (1, P), 0, cfg.vocab)
    a = greedy_generate(cfg, base, peft, prompt, steps, cache_len=cache_len,
                        fused_prefill=True)
    b = greedy_generate(cfg, base, peft, prompt, steps, cache_len=cache_len,
                        fused_prefill=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_kv_cache_falls_back_to_tokenwise():
    """Quantized caches make fused ingestion inequivalent (the loop attends
    to quantized history) — greedy_generate must take the token loop and
    stay identical to fused_prefill=False."""
    cfg, model, base, peft, key = _setup("llama2-7b")
    prompt = jax.random.randint(key, (2, 10), 0, cfg.vocab)
    a = greedy_generate(cfg, base, peft, prompt, 4, fused_prefill=True,
                        kv_int8=True)
    b = greedy_generate(cfg, base, peft, prompt, 4, fused_prefill=False,
                        kv_int8=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefill_registered_per_family():
    """Every family now serves through a fused prefill."""
    for arch in ("llama2-7b", "qwen3-moe-235b-a22b", "rwkv6-1.6b",
                 "zamba2-1.2b", "whisper-tiny"):
        cfg = reduce_config(get_config(arch))
        assert get_model(cfg).prefill is not None, arch


def test_kv_int8_capability_flag():
    """``ModelFns.supports_kv_int8`` replaces the old try/except TypeError
    signature probe: transformer-cache families advertise it, stateful /
    hybrid / encdec families do not, and requesting kv_int8 on a family
    without it raises instead of being silently ignored."""
    for arch, has in (("llama2-7b", True), ("qwen3-moe-235b-a22b", True),
                      ("rwkv6-1.6b", False), ("zamba2-1.2b", False),
                      ("whisper-tiny", False)):
        cfg = reduce_config(get_config(arch))
        assert get_model(cfg).supports_kv_int8 == has, arch
    cfg, model, base, peft, key = _setup("rwkv6-1.6b")
    prompt = jax.random.randint(key, (1, 4), 0, cfg.vocab)
    with pytest.raises(ValueError, match="kv_int8"):
        greedy_generate(cfg, base, peft, prompt, 2, kv_int8=True)


def test_fallback_families_still_generate():
    """A whisper decoder cache SHORTER than the prompt cannot be fused
    (full-attention decode loop is lossy there) — greedy_generate must
    silently fall back to the token loop and produce the same ids."""
    cfg, model, base, peft, key = _setup("whisper-tiny")
    prompt = jax.random.randint(key, (1, 12), 0, cfg.vocab)
    a = greedy_generate(cfg, base, peft, prompt, 3, cache_len=8,
                        fused_prefill=True)
    b = greedy_generate(cfg, base, peft, prompt, 3, cache_len=8,
                        fused_prefill=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
