"""End-to-end FL integration: SPRY and baselines actually learn on a
Dirichlet-split synthetic task, and the paper's qualitative orderings hold.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import run_training


SPRY_KW = dict(arch="roberta-large-lora", task="toy", rounds=30,
               clients_per_round=8, total_clients=12, batch_size=8,
               seed=0, local_lr=1e-2, server_lr=2e-2, k_perturbations=4,
               jvp_clip=10.0, log=lambda *a: None)


@pytest.fixture(scope="module")
def spry_history():
    return run_training(method="spry", eval_every=10, **SPRY_KW)


@pytest.mark.slow
def test_spry_learns(spry_history):
    accs = [h["acc"] for h in spry_history]
    assert accs[-1] > 0.62, accs       # well above the 0.5 chance level


@pytest.mark.slow
def test_spry_loss_decreases(spry_history):
    losses = [h["loss"] for h in spry_history]
    assert losses[-1] < 0.69           # below chance-level binary CE


@pytest.mark.slow
def test_personalized_eval_works(spry_history):
    """Acc_p (paper Table 5) is produced and is above chance. (Whether
    Acc_p > Acc_g is task-dependent: measured 0.75 vs 0.55 on the harder
    sst2 split — see EXPERIMENTS §Repro-claims addendum — while on the
    easy toy task the global model already saturates.)"""
    last = spry_history[-1]
    assert last["personalized_acc"] > 0.55


@pytest.mark.slow
def test_fedavg_backprop_learns_faster_per_round():
    """Paper Table 1: backprop reaches higher accuracy in a fixed round
    budget; SPRY approaches it."""
    bp = run_training(arch="roberta-large-lora", task="sst2", method="fedyogi",
                      rounds=20, clients_per_round=4, total_clients=12,
                      batch_size=8, eval_every=20, seed=0, log=lambda *a: None)
    assert bp[-1]["acc"] > 0.6


@pytest.mark.slow
def test_spry_beats_fedmezo_under_equal_budget():
    """Paper §5.1: forward-mode AD beats finite differences (5.2-13.5% in
    the paper). A single sst2 seed at 30 rounds is inside the noise band
    (the old xfail: spry 0.538 vs mezo 0.565 at seed 0, sign-flipping across
    seeds), so the ordering is asserted on PAIRED MULTI-SEED runs instead:
    same partition/sampling/eval per seed, both methods at their paper
    configs. SPRY runs K=4 averaged forward gradients with jvp clipping
    (the SPRY_KW config used throughout this module) — an equal COMPUTE
    budget per iteration, since the batched K-tangent engine evaluates one
    primal plus 4 cheap tangents, comparable to FedMeZO's two full forward
    passes for its single central-difference probe. Measured diffs at these
    seeds: +0.011 / +0.022 / +0.096 (spry wins every seed)."""
    base = dict(arch="roberta-large-lora", task="toy", rounds=30,
                clients_per_round=8, total_clients=12, batch_size=8,
                eval_every=30, local_lr=1e-2, server_lr=2e-2,
                log=lambda *a: None)
    diffs = []
    for seed in (0, 1, 2):
        spry = run_training(method="spry", seed=seed, k_perturbations=4,
                            jvp_clip=10.0, **base)
        mezo = run_training(method="fedmezo", seed=seed, **base)
        diffs.append(spry[-1]["acc"] - mezo[-1]["acc"])
    # statistically separable: spry wins the paired mean with margin AND the
    # majority of seeds (guards against one lucky/unlucky seed deciding it)
    assert np.mean(diffs) > 0.005, diffs
    assert sum(d > 0 for d in diffs) >= 2, diffs


@pytest.mark.slow
def test_per_iteration_mode_learns():
    hist = run_training(method="spry_periter", eval_every=30, **SPRY_KW)
    assert hist[-1]["acc"] > 0.62


def test_checkpoint_roundtrip(tmp_path):
    import jax
    from repro.checkpoint import load_pytree, save_pytree
    from repro.configs import SpryConfig, get_config, reduce_config
    from repro.models import get_model
    from repro.peft import init_peft

    cfg = reduce_config(get_config("roberta-large-lora"))
    key = jax.random.PRNGKey(0)
    peft = init_peft(cfg, key, SpryConfig())
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, peft)
    restored = load_pytree(path, peft)
    for a, b in zip(jax.tree.leaves(peft), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
