"""Static-analysis suite (ISSUE 7): every rule class must (a) catch a
SEEDED violation on a synthetic program — the analyzer has teeth — and
(b) come back clean (golden) on a real registry family traced through the
real entry-point harness. Plus the shared jaxpr walker and the HLO-text
mirrors in launch/hlo_analysis.py.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import (
    assert_no_tangent_stack,
    entrypoints as eps,
    kernel_name,
    kernel_src,
    kernel_vmem,
    pallas_calls,
    representative_kernel_rows,
    rules,
    tangent_stack_outputs,
    vmem_table,
)
from repro.analysis.vmem import VMEM_BYTES
from repro.kernels import dispatch
from repro.kernels.lora_dual.ops import lora_dual_mt, lora_dual_mt_jvps


def _lora_shapes(M=8, K=48, N=40, r=2, T=3):
    z = jnp.zeros
    x, w = z((M, K)), z((K, N))
    a, b = z((K, r)), z((r, N))
    ad, bd, xd = z((T, K, r)), z((T, r, N)), z((T, M, K))
    gy = z((M, N))
    return x, w, a, b, ad, bd, xd, gy


def _materializing_jaxpr(T=3):
    """The mt route: writes the (T,)+y tangent stack — the seeded
    violation the tangent rule must catch."""
    x, w, a, b, ad, bd, xd, gy = _lora_shapes(T=T)
    thunk = lambda: lora_dual_mt(x, xd, w, a, ad, b, bd, interpret=True)
    return jax.make_jaxpr(thunk)(), T, gy.shape


def _epilogue_jaxpr(T=3):
    """The jvps contraction route: per-block partials only — clean."""
    x, w, a, b, ad, bd, xd, gy = _lora_shapes(T=T)
    thunk = lambda: lora_dual_mt_jvps(x, w, a, ad, b, bd, gy, xdots=xd,
                                      impl="kernel", interpret=True)
    return jax.make_jaxpr(thunk)(), T, gy.shape


# ---------------------------------------------------------------------------
# shared walker
# ---------------------------------------------------------------------------

def test_walker_finds_pallas_calls_through_nesting():
    x, w, a, b, ad, bd, xd, gy = _lora_shapes()
    # wrap in jit so the pallas_call sits under a pjit sub-jaxpr
    thunk = jax.jit(lambda: lora_dual_mt(x, xd, w, a, ad, b, bd,
                                         interpret=True))
    jaxpr = jax.make_jaxpr(thunk)()
    calls = pallas_calls(jaxpr)
    assert calls, "walker lost the pallas_call nested under pjit"
    assert kernel_name(calls[0]) == "_mt_kernel"
    assert "lora_dual" in kernel_src(calls[0])


# ---------------------------------------------------------------------------
# rule 1: tangent-materialization
# ---------------------------------------------------------------------------

def test_tangent_rule_catches_materializing_route():
    jaxpr, T, y_shape = _materializing_jaxpr()
    hits = tangent_stack_outputs(jaxpr, T, y_shape)
    assert hits, "seeded tangent stack not detected"
    with pytest.raises(AssertionError, match="tangent-stack-sized"):
        assert_no_tangent_stack(jaxpr, T, y_shape)
    findings = rules.check_tangent_stack("toy.mt", jaxpr, T, y_shape,
                                         expect_epilogue=False)
    assert any(f.severity == "error" for f in findings)


def test_tangent_rule_passes_epilogue_route():
    jaxpr, T, y_shape = _epilogue_jaxpr()
    assert rules.check_tangent_stack("toy.jvps", jaxpr, T, y_shape) == []
    assert_no_tangent_stack(jaxpr, T, y_shape)


# ---------------------------------------------------------------------------
# rule 2: vmem-budget
# ---------------------------------------------------------------------------

def test_vmem_rows_within_budget_and_seeded_overflow(monkeypatch):
    jaxpr, _, _ = _epilogue_jaxpr()
    rows = vmem_table(jaxpr)
    assert rows and all(r["ok"] for r in rows)
    row = rows[0]
    assert row["residency_bytes"] == (2 * row["block_bytes"]
                                      + row["scratch_bytes"])
    assert rules.check_vmem("toy.jvps", jaxpr) == []
    # seed an overflow: a 1 KiB budget no kernel fits
    monkeypatch.setitem(VMEM_BYTES, "tiny", 1024)
    findings = rules.check_vmem("toy.jvps", jaxpr, generation="tiny")
    assert findings and all(f.severity == "error" for f in findings)
    assert not kernel_vmem(pallas_calls(jaxpr)[0], "tiny")["ok"]


def test_representative_kernel_table_covers_all_families():
    rows = representative_kernel_rows()
    fams = {r["family"] for r in rows}
    assert {"lora_dual", "wkv6_scan", "swa_attention", "mamba2_scan"} <= fams
    assert all(r["ok"] for r in rows), [r["kernel"] for r in rows
                                        if not r["ok"]]
    assert rules.check_vmem_rows("kernels.representative", rows) == []


# ---------------------------------------------------------------------------
# rule 3: transpose-reachability
# ---------------------------------------------------------------------------

def test_transpose_rule_catches_seeded_kernel_in_reverse_trace():
    # hand the checker a trace that DOES contain pallas_calls, standing in
    # for a reverse-mode trace that reached a kernel
    jaxpr, _, _ = _materializing_jaxpr()
    findings = rules.check_transpose_reachability("toy.reverse", jaxpr)
    assert findings and all(f.severity == "error" for f in findings)
    assert "transpose" in findings[0].message


def test_transpose_rule_clean_on_grad_outside_region():
    x = jnp.zeros((8, 48))
    w = jnp.zeros((48, 40))
    peft = {"A": jnp.zeros((48, 2)), "B": jnp.zeros((2, 40))}

    def loss(p):
        y = dispatch.lora_proj(x, w, p["A"], p["B"], 2.0)
        return jnp.mean(y * y)

    dispatch.set_backend("interpret")
    try:
        g_jaxpr = jax.make_jaxpr(jax.grad(loss))(peft)
    finally:
        dispatch.set_backend(None)
    assert rules.check_transpose_reachability("toy.grad", g_jaxpr) == []


# ---------------------------------------------------------------------------
# rule 4: donation
# ---------------------------------------------------------------------------

def _toy_step_lowered(donate):
    state = jnp.zeros((512, 512), jnp.float32)      # exactly 1 MiB
    x = jnp.float32(0.0)

    def step(s, x):
        return s + x, jnp.sum(s)

    kw = {"donate_argnums": (0,)} if donate else {}
    return jax.jit(step, **kw).lower(state, x)


def test_donation_rule_catches_undonated_carried_state():
    findings = rules.check_donation("toy.step", _toy_step_lowered(False))
    assert any(f.severity == "error" and "donate_argnums" in f.message
               for f in findings)


def test_donation_rule_clean_when_donated_and_waivable():
    assert rules.check_donation("toy.step", _toy_step_lowered(True)) == []
    waived = rules.check_donation("toy.step", _toy_step_lowered(False),
                                  waivers={"toy.step": "toy reason"})
    assert waived and all(f.severity == "info" for f in waived)
    assert "toy reason" in waived[0].message


# ---------------------------------------------------------------------------
# rule 5: dtype-policy
# ---------------------------------------------------------------------------

def _bad_dtype_jaxpr():
    """A kernel that seeds BOTH violations: f16 scratch accumulator and an
    in-kernel dot_general accumulating in f16."""
    def kernel(x_ref, o_ref, acc_ref):
        acc_ref[...] = jax.lax.dot_general(
            x_ref[...], x_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float16)
        o_ref[...] = acc_ref[...].astype(jnp.float32)

    def thunk():
        x = jnp.zeros((8, 8), jnp.float16)
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
            scratch_shapes=[pltpu.VMEM((8, 8), jnp.float16)],
            interpret=True)(x)

    return jax.make_jaxpr(thunk)()


def test_dtype_rule_catches_seeded_f16_accumulators():
    findings = rules.check_dtype_policy("toy.bad", _bad_dtype_jaxpr())
    msgs = " | ".join(f.message for f in findings)
    assert any(f.severity == "error" for f in findings)
    assert "scratch" in msgs and "dot_general" in msgs


def test_dtype_rule_clean_on_real_kernel_and_wire_table():
    jaxpr, _, _ = _epilogue_jaxpr()
    assert rules.check_dtype_policy("toy.jvps", jaxpr) == []
    assert not [f for f in rules.check_wire_dtypes()
                if f.severity == "error"]


# ---------------------------------------------------------------------------
# golden: one real family (ssm — the cheapest full-model trace) per rule
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ssm_loss_traces():
    return eps.loss_traces("ssm", "cls", K=4)


def test_golden_ssm_fused_clean(ssm_loss_traces):
    fused, _ = ssm_loss_traces
    assert fused.kind == "fused_loss"
    assert rules.check_tangent_stack(fused.name, fused.jaxpr, fused.K,
                                     fused.y_shape,
                                     family=fused.site_family) == []
    assert rules.check_vmem(fused.name, fused.jaxpr) == []
    assert rules.check_dtype_policy(fused.name, fused.jaxpr) == []


def test_golden_ssm_standard_route_has_teeth(ssm_loss_traces):
    _, std = ssm_loss_traces
    (teeth,) = rules.record_expected_stack(std.name, std.jaxpr, std.K,
                                           std.y_shape,
                                           family=std.site_family)
    assert teeth.severity == "info" and "teeth" in teeth.message


def test_golden_ssm_grad_guard_clean():
    (tr,) = eps.grad_guard_traces("ssm")
    assert rules.check_transpose_reachability(tr.name, tr.jaxpr) == []


def test_golden_ssm_serve_donation_clean():
    for tr in eps.serve_lowered("ssm"):
        bad = [f for f in rules.check_donation(tr.name, tr.lowered)
               if f.severity == "error"]
        assert bad == [], f"{tr.name}: {bad}"


# ---------------------------------------------------------------------------
# report plumbing + the HLO-text mirrors
# ---------------------------------------------------------------------------

def test_report_doc_shape(tmp_path):
    from repro.analysis.report import summarize, to_doc, write_analysis

    jaxpr, _, _ = _epilogue_jaxpr()
    rows = vmem_table(jaxpr)
    findings = [rules.Finding("donation", "error", "ep", "x", "msg"),
                rules.Finding("vmem-budget", "info", "ep", "y", "msg2")]
    doc = to_doc(findings, rows, ["ep"], "v5e", VMEM_BYTES["v5e"])
    assert doc["schema"] == "repro.analysis/v1"
    assert doc["summary"]["errors"] == 1 and doc["summary"]["info"] == 1
    assert summarize(findings)["errors"] == 1
    path = tmp_path / "ANALYSIS.json"
    write_analysis(path, doc)
    import json
    assert json.load(open(path))["budget"]["generation"] == "v5e"


_HLO = """\
HloModule toy, input_output_alias={ {0}: (0, {}, may-alias) }, \
entry_computation_layout={(f32[1024,1024]{1,0}, f32[1024,1024]{1,0})->\
(f32[1024,1024]{1,0})}
"""


def test_hlo_text_alias_and_donation_helpers():
    from repro.launch.hlo_analysis import (
        entry_parameter_bytes,
        parse_input_output_aliases,
        undonated_param_bytes,
    )
    assert parse_input_output_aliases(_HLO) == {0: 0}
    assert entry_parameter_bytes(_HLO) == [4 << 20, 4 << 20]
    assert undonated_param_bytes(_HLO) == [(1, 4 << 20)]
    no_alias = _HLO.replace("input_output_alias={ {0}: (0, {}, may-alias) }, ",
                            "")
    assert parse_input_output_aliases(no_alias) == {}
    assert undonated_param_bytes(no_alias) == [(0, 4 << 20), (1, 4 << 20)]
