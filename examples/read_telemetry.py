"""Read a run's telemetry JSONL programmatically.

Generate a log first (2 runtime federation rounds), then point this script
at it:

    PYTHONPATH=src JAX_PLATFORMS=cpu python -m repro.launch.train \
        --arch roberta-large-lora --method spry --rounds 2 --clients 2 \
        --total-clients 4 --runtime --telemetry run.jsonl
    PYTHONPATH=src python examples/read_telemetry.py run.jsonl

Every line is one JSON event with an envelope (``ts``, ``run_id``,
``kind``); the pre-built summary tables live in ``repro.obs.report``
(``python -m repro.obs.report run.jsonl``) — this shows the raw access
pattern for custom analysis.
"""
import sys

from repro.obs.report import load_events


def main(path):
    events = load_events(path)
    by_kind = {}
    for e in events:
        by_kind.setdefault(e["kind"], []).append(e)
    print(f"{path}: {len(events)} events "
          f"({', '.join(f'{k}={len(v)}' for k, v in sorted(by_kind.items()))})")

    # loss trajectory straight off the round events
    rounds = by_kind.get("round", [])
    if rounds:
        losses = [e["loss"] for e in rounds]
        print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"over {len(losses)} rounds")
        up = sum(e.get("bytes_up", 0) for e in rounds)
        if up:
            print(f"total bytes on the wire (up): {up}")

    # per-request serving latencies
    for e in by_kind.get("request", []):
        print(f"request {e['request_id']}: ttft={e['ttft_s']}s "
              f"latency={e['latency_s']}s ({e['gen_tokens']} tokens)")

    # the final metrics snapshot aggregates everything the run counted
    metrics = by_kind.get("metrics", [])
    if metrics:
        snap = metrics[-1]["metrics"]
        for name, value in sorted(snap.get("counters", {}).items()):
            print(f"counter {name} = {value}")
        for name, h in sorted(snap.get("histograms", {}).items()):
            if h.get("count"):
                print(f"histogram {name}: count={h['count']} "
                      f"p50={h['p50']:.4g} p95={h['p95']:.4g}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "telemetry.jsonl")
