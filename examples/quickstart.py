"""Quickstart: finetune a small LM with SPRY on a synthetic federated task.

    PYTHONPATH=src python examples/quickstart.py

~1 minute on CPU. Shows the whole public API surface: config -> model ->
PEFT -> Dirichlet clients -> jitted SPRY round step -> evaluation.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SpryConfig, get_config, reduce_config
from repro.core import init_state, make_round_step
from repro.data import make_task
from repro.data.loader import ClientDataset, stack_client_batches
from repro.fl import dirichlet_partition, sample_clients
from repro.models import cls_logits, get_model
from repro.models.common import accuracy_from_logits
from repro.peft import init_peft, count_trainable
import dataclasses

# 1. architecture (any of the 10 assigned ids works with --full dimensions;
#    reduce_config gives the CPU-sized variant of the same family)
cfg = reduce_config(get_config("roberta-large-lora"))

# 2. synthetic SST2-like task, Dirichlet-heterogeneous across 16 clients
x_tr, y_tr, x_te, y_te = make_task("sst2", vocab=cfg.vocab)
cfg = dataclasses.replace(cfg, n_classes=int(y_tr.max()) + 1)
parts = dirichlet_partition(y_tr, n_clients=16, alpha=0.1)
clients = [ClientDataset(x_tr, y_tr, p) for p in parts]

# 3. frozen base + trainable LoRA (r=1, the paper default)
sc = SpryConfig(n_clients_per_round=4, local_lr=2e-2, server_lr=5e-2)
key = jax.random.PRNGKey(0)
model = get_model(cfg)
base = model.init_base(cfg, key)
peft = init_peft(cfg, key, sc)
print(f"trainable params: {count_trainable(peft):,} "
      f"(of ~{int(cfg.n_param_estimate()):,} total)")

# 4. SPRY: one jitted call = one federated round
state = init_state(base, peft)
round_step = jax.jit(make_round_step(cfg, sc, task="cls"))
rng = np.random.default_rng(0)

for r in range(50):
    chosen = sample_clients(rng, 16, sc.n_clients_per_round)
    bx, by = stack_client_batches([clients[c] for c in chosen], rng, 8)
    state, metrics = round_step(state, {"tokens": jnp.asarray(bx),
                                        "labels": jnp.asarray(by)})
    if (r + 1) % 10 == 0:
        logits = cls_logits(cfg, state.base, state.peft,
                            {"tokens": jnp.asarray(x_te[:256])})
        acc = accuracy_from_logits(logits, jnp.asarray(y_te[:256]))
        print(f"round {r+1:3d}  loss={float(metrics['loss']):.4f}  "
              f"test_acc={float(acc):.3f}")

print("done — SPRY finetuned the model with forward-mode AD only "
      "(no backprop, no stored activation stack).")
