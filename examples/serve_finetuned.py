"""Serve a SPRY-finetuned model: batched greedy decoding with KV /
recurrent-state caches, across architecture families.

    PYTHONPATH=src python examples/serve_finetuned.py --arch rwkv6-1.6b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import SpryConfig, get_config, reduce_config
from repro.launch.serve import greedy_generate
from repro.models import get_model
from repro.peft import init_peft


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    prompt = jax.random.randint(key, (args.batch, 8), 0, cfg.vocab)

    t0 = time.time()
    ids = greedy_generate(cfg, base, peft, prompt, args.steps)
    dt = time.time() - t0
    print(f"{args.arch} [{cfg.family}] generated {ids.shape[0]}x{ids.shape[1]} "
          f"tokens in {dt:.1f}s ({ids.shape[0]*ids.shape[1]/dt:.1f} tok/s)")
    print("sample:", np.asarray(ids[0]))


if __name__ == "__main__":
    main()
