"""Reproduce the paper's headline memory claim (Fig. 2): peak client-side
training memory of backprop vs zero-order vs SPRY's forward-mode AD, via
compiled memory analysis of the three client programs.

    PYTHONPATH=src python examples/memory_comparison.py [--arch llama2-7b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.bench_memory import run  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="roberta-large-lora")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    rows = run(args.arch, args.batch_size, args.seq)
    bp = next(r for r in rows if r["method"] == "backprop")
    print(f"\n{args.arch}  (batch={args.batch_size}, seq={args.seq})")
    print(f"{'method':18s} {'temp (activations)':>20s} {'peak':>12s} {'vs backprop':>12s}")
    for r in rows:
        print(f"{r['method']:18s} {r['temp_bytes']/1e9:>17.2f}GB "
              f"{r['peak_bytes']/1e9:>10.2f}GB "
              f"{bp['temp_bytes']/max(r['temp_bytes'],1):>11.2f}x")
    print("\nPaper's claim: forward-mode AD removes the stored-activation "
          "stack; memory ~= the largest single activation.")


if __name__ == "__main__":
    main()
