"""End-to-end driver: federated finetuning of a ~100M-parameter model
(RoBERTa-Large family at full width, CPU-feasible depth) for a few hundred
rounds, comparing SPRY against the FedYogi backprop baseline.

    PYTHONPATH=src python examples/federated_finetune.py [--rounds 200]

This is the deliverable-(b) end-to-end run; results land in
experiments/federated_finetune.json and EXPERIMENTS.md §Repro-claims.
"""
import argparse
import dataclasses
import json
import os

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--task", default="sst2")
    ap.add_argument("--methods", nargs="+",
                    default=["spry", "fedyogi", "fedmezo"])
    ap.add_argument("--arch", default="roberta-large-lora")
    ap.add_argument("--full-size", action="store_true",
                    help="full 355M config (slow on CPU)")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="drive SPRY through the event-driven FedBuff "
                         "engine (staleness-weighted buffered aggregation "
                         "over simulated device tiers) instead of "
                         "round-synchronous cohorts")
    ap.add_argument("--buffer-size", type=int, default=4)
    ap.add_argument("--staleness-decay", type=float, default=0.5)
    ap.add_argument("--out", default="experiments/federated_finetune.json")
    args = ap.parse_args()

    if args.async_mode:
        # async federation is a SPRY-runtime feature; baselines stay sync
        args.methods = [m for m in args.methods if m == "spry"] or ["spry"]

    results = {}
    for method in args.methods:
        print(f"=== {method}{' (async)' if args.async_mode else ''} ===")
        hist = run_training(
            arch=args.arch, task=args.task, method=method,
            rounds=args.rounds, clients_per_round=8, total_clients=32,
            batch_size=8, dirichlet_alpha=0.1, eval_every=20,
            reduced=not args.full_size, seed=0,
            local_lr=2e-2, server_lr=5e-2,
            async_mode=args.async_mode, buffer_size=args.buffer_size,
            staleness_decay=args.staleness_decay)
        results[method] = hist
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print("\nfinal accuracies:")
    for m, h in results.items():
        print(f"  {m:10s} {h[-1]['acc']:.4f}  ({h[-1]['t']:.0f}s)")


if __name__ == "__main__":
    main()
