"""Synthetic language-classification tasks mirroring the paper's 8 datasets.

No external datasets are downloadable in this environment, so each paper task
is mirrored by a synthetic generator with the same *shape*: C classes, a
vocabulary, sequence length, and a learnable class signal. Sequences are
drawn from class-conditioned token distributions (a mixture of a shared
background unigram model and per-class "keyword" tokens), which gives tasks
that are trivially separable by a full-capacity learner but produce smooth,
optimizer-sensitive learning curves — exactly what the paper's comparisons
(SPRY vs FedAvg vs zero-order) need.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTask:
    name: str
    n_classes: int
    seq_len: int
    vocab: int
    n_train: int
    n_test: int
    signal: float = 0.25     # fraction of positions carrying class keywords


# name -> (C, seq, n_train, n_test): mirrors Appendix B scale ratios (scaled down)
TASKS = {
    # high-signal toy task for fast CI convergence checks
    "toy": SyntheticTask("toy", 2, 16, 256, 2000, 400, signal=0.6),
    "agnews": SyntheticTask("agnews", 4, 64, 512, 8000, 1000),
    "sst2": SyntheticTask("sst2", 2, 32, 512, 4000, 500),
    "yelp": SyntheticTask("yelp", 2, 64, 512, 8000, 1000),
    "yahoo": SyntheticTask("yahoo", 10, 64, 512, 10000, 1000),
    "snli": SyntheticTask("snli", 3, 48, 512, 6000, 800),
    "mnli": SyntheticTask("mnli", 3, 48, 512, 6000, 800),
    "squadv2": SyntheticTask("squadv2", 2, 128, 512, 4000, 500),
    "multirc": SyntheticTask("multirc", 2, 96, 512, 3000, 400),
}


def make_task(name: str, seed: int = 0, vocab: int | None = None,
              seq_len: int | None = None):
    """Generate (x_train, y_train, x_test, y_test) numpy arrays for a task."""
    spec = TASKS[name]
    vocab = vocab or spec.vocab
    seq_len = seq_len or spec.seq_len
    rng = np.random.default_rng(seed)

    # shared background unigram distribution (zipf-ish)
    ranks = np.arange(1, vocab + 1)
    bg = (1.0 / ranks) / np.sum(1.0 / ranks)
    # per-class keyword sets (disjoint slices of the vocab tail)
    kw_per_class = max(4, vocab // (8 * spec.n_classes))
    keywords = [
        rng.choice(vocab // 2, size=kw_per_class, replace=False) + vocab // 2
        for _ in range(spec.n_classes)
    ]

    def sample(n):
        y = rng.integers(0, spec.n_classes, size=n)
        x = rng.choice(vocab, size=(n, seq_len), p=bg)
        mask = rng.random((n, seq_len)) < spec.signal
        for c in range(spec.n_classes):
            rows = y == c
            kw = rng.choice(keywords[c], size=(int(rows.sum()), seq_len))
            x[rows] = np.where(mask[rows], kw, x[rows])
        return x.astype(np.int32), y.astype(np.int32)

    x_tr, y_tr = sample(spec.n_train)
    x_te, y_te = sample(spec.n_test)
    return x_tr, y_tr, x_te, y_te
