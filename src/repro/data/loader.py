"""Client-side batching for the FL simulator."""
from __future__ import annotations

import numpy as np


class ClientDataset:
    """A client's shard of a task: indices into the global arrays."""

    def __init__(self, x: np.ndarray, y: np.ndarray, indices: np.ndarray):
        self.x = x
        self.y = y
        self.indices = np.asarray(indices)

    def __len__(self):
        return len(self.indices)

    def sample_batch(self, rng: np.random.Generator, batch_size: int):
        take = rng.choice(self.indices, size=batch_size,
                          replace=len(self.indices) < batch_size)
        return self.x[take], self.y[take]


def batch_iterator(x, y, batch_size, rng: np.random.Generator, epochs=1):
    n = len(x)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            take = perm[i:i + batch_size]
            yield x[take], y[take]


def stack_client_batches(clients, rng, batch_size):
    """Sample one batch per client and stack to (M, B, S) for the vmapped
    round step."""
    xs, ys = [], []
    for c in clients:
        bx, by = c.sample_batch(rng, batch_size)
        xs.append(bx)
        ys.append(by)
    return np.stack(xs), np.stack(ys)
