from repro.data.synthetic import SyntheticTask, make_task, TASKS
from repro.data.loader import ClientDataset, batch_iterator
