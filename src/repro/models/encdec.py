"""Whisper-style encoder-decoder. The mel/conv frontend is a STUB per the
assignment carve-out: the encoder consumes precomputed frame embeddings
(B, encoder_seq, d_model) supplied by ``input_specs``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (
    apply_norm,
    dense_init,
    layer_slice,
    norm_params,
    scan_prefix_unroll_tail,
    sinusoidal_positions,
)
from repro.models.mlp import mlp_block, mlp_params
from repro.models.partitioning import constrain


def init_base(cfg, key):
    keys = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.vocab
    Le, Ld = cfg.encoder_layers, cfg.n_layers
    enc_layers = {
        "attn": attn.attn_params(cfg, keys[0], layers=Le),
        "mlp": mlp_params(cfg, keys[1], layers=Le),
        "ln1": norm_params(cfg, d, layers=Le),
        "ln2": norm_params(cfg, d, layers=Le),
    }
    dec_layers = {
        "self_attn": attn.attn_params(cfg, keys[2], layers=Ld),
        "cross_attn": attn.attn_params(cfg, keys[3], layers=Ld),
        "mlp": mlp_params(cfg, keys[4], layers=Ld),
        "ln1": norm_params(cfg, d, layers=Ld),
        "ln2": norm_params(cfg, d, layers=Ld),
        "ln3": norm_params(cfg, d, layers=Ld),
    }
    return {
        "embed": dense_init(keys[5], (V, d), in_axis=-1, dtype=cfg.dtype),
        "enc_layers": enc_layers,
        "enc_norm": norm_params(cfg, d),
        "layers": dec_layers,
        "final_norm": norm_params(cfg, d),
    }


def unembed(cfg, base):
    return base["embed"].T  # whisper ties decoder output to the embedding


def encode(cfg, base, frames, peft=None, lora_scale=1.0):
    """frames: (B, F, D) precomputed frontend-stub embeddings."""
    F = frames.shape[1]
    h = frames.astype(cfg.dtype) + sinusoidal_positions(F, cfg.d_model).astype(cfg.dtype)
    peft_layers = (peft or {}).get("enc_layers", {})

    def body(h, xs):
        lp, pl = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        h = h + attn.attn_block_prefill(cfg, lp["attn"], hn, pl or None,
                                        lora_scale, causal=False)
        hn = apply_norm(cfg, h, lp["ln2"])
        return h + mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale), None

    h, _ = jax.lax.scan(body, h, (base["enc_layers"], peft_layers))
    return apply_norm(cfg, h, base["enc_norm"])


def _decoder_body(cfg, memory, lora_scale):
    """One full decoder layer as a scan body — shared by ``forward`` (all L
    layers) and ``split_forward`` (the first L-1)."""
    def body(h, xs):
        lp, pl = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        h = h + attn.attn_block_prefill(cfg, lp["self_attn"], hn, pl or None,
                                        lora_scale)
        hn = apply_norm(cfg, h, lp["ln2"])
        h = h + attn.cross_attn_block(cfg, lp["cross_attn"], hn, memory,
                                      pl or None, lora_scale)
        hn = apply_norm(cfg, h, lp["ln3"])
        h = h + mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale)
        return constrain(h, "prefill_h"), None
    return body


def _decoder_embed(cfg, base, tokens):
    S = tokens.shape[1]
    h = jnp.take(base["embed"], tokens, axis=0)
    return h + sinusoidal_positions(S, cfg.d_model).astype(h.dtype)


def forward_scanned(cfg, base, peft, tokens, frames=None, lora_scale=1.0,
                    memory=None):
    """Reference train forward: ONE ``lax.scan`` over all L decoder layers
    (see ``transformer.forward_scanned`` for the ulp caveat vs
    ``forward``)."""
    if memory is None:
        memory = encode(cfg, base, frames, peft, lora_scale)
    h = _decoder_embed(cfg, base, tokens)
    peft_layers = (peft or {}).get("layers", {})
    h, _ = jax.lax.scan(_decoder_body(cfg, memory, lora_scale), h,
                        (base["layers"], peft_layers))
    return apply_norm(cfg, h, base["final_norm"]), jnp.float32(0.0)


def forward(cfg, base, peft, tokens, frames=None, lora_scale=1.0):
    """Teacher-forced decoder pass as the split composition (scan L-1
    decoder layers, unroll the final one around its self-attention mixer)
    — identical program to the registry split losses. Returns
    (hidden (B,S,D), aux)."""
    site_args, ctx = split_forward(cfg, base, peft, tokens, frames=frames,
                                   lora_scale=lora_scale)
    y = mixer_site(cfg, site_args)
    return split_post(cfg, base, y, ctx, peft, lora_scale=lora_scale)


# ---------------------------------------------------------------------------
# Split forward: scan L-1 decoder layers, unroll the final one up to its
# self-attention mixer (cross-attn + MLP tail live in the post-head)
# ---------------------------------------------------------------------------

def split_site(cfg):
    return "swa", {"window": None}


def mixer_site(cfg, site_args):
    """The final decoder layer's causal self-attention mixer on the split
    site args (backend-gated; see ``attention.swa_mixer_site``)."""
    return attn.swa_mixer_site(cfg, site_args, None)


def split_forward(cfg, base, peft, tokens, frames=None, lora_scale=1.0):
    """Split (train) forward: encoder + first L-1 decoder layers scanned,
    final decoder layer unrolled up to its causal self-attention mixer.
    Returns (site_args, ctx); the pre->site->post composition is
    bitwise-identical to ``forward``."""
    memory = encode(cfg, base, frames, peft, lora_scale)
    h = _decoder_embed(cfg, base, tokens)
    peft_layers = (peft or {}).get("layers", {})
    h, (lp, pl) = scan_prefix_unroll_tail(
        _decoder_body(cfg, memory, lora_scale), h,
        (base["layers"], peft_layers), cfg.n_layers)
    hn = apply_norm(cfg, h, lp["ln1"])
    q, k, v = attn.attn_site_qkv(cfg, lp["self_attn"], hn, pl or None,
                                 lora_scale)
    site_args = (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                 v.transpose(0, 2, 1, 3))
    return site_args, {"h": h, "memory": memory}


def split_post(cfg, base, y, ctx, peft, lora_scale=1.0):
    """Post-head of the split forward: self-attn mixer output (B,H,S,hd) ->
    (final hidden, aux). The final layer's cross-attention + MLP tail are
    reversed once here by the fused estimator."""
    lp = layer_slice(base["layers"], cfg.n_layers - 1)
    pl = layer_slice((peft or {}).get("layers", {}), cfg.n_layers - 1)
    h, memory = ctx["h"], ctx["memory"]
    a = attn.attn_finish(cfg, lp["self_attn"], y.transpose(0, 2, 1, 3),
                         pl or None, lora_scale)
    h = h + a
    hn = apply_norm(cfg, h, lp["ln2"])
    h = h + attn.cross_attn_block(cfg, lp["cross_attn"], hn, memory,
                                  pl or None, lora_scale)
    hn = apply_norm(cfg, h, lp["ln3"])
    h = h + mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale)
    h = constrain(h, "prefill_h")
    return apply_norm(cfg, h, base["final_norm"]), jnp.float32(0.0)


def init_cache(cfg, batch: int, seq_len: int):
    L = cfg.n_layers
    shape = (L, batch, seq_len, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "memory": jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), cfg.dtype),
    }


def prefill(cfg, base, peft, cache, tokens, lora_scale=1.0):
    """Fused decoder prompt ingestion: ONE chunked causal self-attention
    pass over the whole prompt instead of P decode_step calls. Cross-attends
    to ``cache["memory"]`` (the encoder output the caller stashed there —
    NOT recomputed, so the pass composes with the decode loop exactly).
    Returns (last-token logits (B,V), cache) with the self-attention cache
    holding the rows the token loop would have written. Whisper's decoder
    cache is full-length (attn_pattern "full"), so slot placement is the
    identity; serve falls back to the token loop when the cache is shorter
    than the prompt."""
    B, P = tokens.shape
    h = _decoder_embed(cfg, base, tokens)
    memory = cache["memory"]
    peft_layers = (peft or {}).get("layers", {})

    def body(h, xs):
        lp, pl = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        a, k, v = attn.attn_block_prefill_kv(cfg, lp["self_attn"], hn,
                                             pl or None, lora_scale)
        h = h + a
        hn = apply_norm(cfg, h, lp["ln2"])
        h = h + attn.cross_attn_block(cfg, lp["cross_attn"], hn, memory,
                                      pl or None, lora_scale)
        hn = apply_norm(cfg, h, lp["ln3"])
        return h + mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale), (k, v)

    h, (ks, vs) = jax.lax.scan(body, h, (base["layers"], peft_layers))
    h = apply_norm(cfg, h, base["final_norm"])
    logits = (h[:, -1, :] @ unembed(cfg, base)).astype(jnp.float32)
    cache = {
        "k": cache["k"].at[:, :, :P].set(ks.astype(cache["k"].dtype)),
        "v": cache["v"].at[:, :, :P].set(vs.astype(cache["v"].dtype)),
        "memory": memory,
    }
    return logits, cache


def decode_step(cfg, base, peft, cache, token, pos, lora_scale=1.0):
    """``pos``: scalar, or a (B,) vector for per-row positions (continuous
    batching). Sinusoidal row p is identical regardless of table length, so
    gathering per-row rows matches the scalar slice bitwise."""
    h = jnp.take(base["embed"], token, axis=0)
    # learned/sinusoidal position for the current step
    pos_table = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    if jnp.ndim(pos) == 0:
        h = h + jax.lax.dynamic_slice_in_dim(pos_table, pos, 1, axis=0)[None].astype(h.dtype)
    else:
        h = h + jnp.take(pos_table, pos, axis=0)[:, None, :].astype(h.dtype)
    memory = cache["memory"]
    peft_layers = (peft or {}).get("layers", {})

    def body(h, xs):
        lp, pl, kc, vc = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        a, kc, vc = attn.attn_block_decode(cfg, lp["self_attn"], hn, pl or None,
                                           lora_scale, kc, vc, pos)
        h = h + a
        hn = apply_norm(cfg, h, lp["ln2"])
        h = h + attn.cross_attn_block(cfg, lp["cross_attn"], hn, memory,
                                      pl or None, lora_scale)
        hn = apply_norm(cfg, h, lp["ln3"])
        return h + mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale), (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(
        body, h, (base["layers"], peft_layers, cache["k"], cache["v"]))
    h = apply_norm(cfg, h, base["final_norm"])
    logits = (h[:, 0, :] @ unembed(cfg, base)).astype(jnp.float32)
    return logits, {"k": kcs, "v": vcs, "memory": memory}
