"""Whisper-style encoder-decoder. The mel/conv frontend is a STUB per the
assignment carve-out: the encoder consumes precomputed frame embeddings
(B, encoder_seq, d_model) supplied by ``input_specs``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (
    apply_norm,
    dense_init,
    norm_params,
    sinusoidal_positions,
)
from repro.models.mlp import mlp_block, mlp_params
from repro.models.partitioning import constrain


def init_base(cfg, key):
    keys = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.vocab
    Le, Ld = cfg.encoder_layers, cfg.n_layers
    enc_layers = {
        "attn": attn.attn_params(cfg, keys[0], layers=Le),
        "mlp": mlp_params(cfg, keys[1], layers=Le),
        "ln1": norm_params(cfg, d, layers=Le),
        "ln2": norm_params(cfg, d, layers=Le),
    }
    dec_layers = {
        "self_attn": attn.attn_params(cfg, keys[2], layers=Ld),
        "cross_attn": attn.attn_params(cfg, keys[3], layers=Ld),
        "mlp": mlp_params(cfg, keys[4], layers=Ld),
        "ln1": norm_params(cfg, d, layers=Ld),
        "ln2": norm_params(cfg, d, layers=Ld),
        "ln3": norm_params(cfg, d, layers=Ld),
    }
    return {
        "embed": dense_init(keys[5], (V, d), in_axis=-1, dtype=cfg.dtype),
        "enc_layers": enc_layers,
        "enc_norm": norm_params(cfg, d),
        "layers": dec_layers,
        "final_norm": norm_params(cfg, d),
    }


def unembed(cfg, base):
    return base["embed"].T  # whisper ties decoder output to the embedding


def encode(cfg, base, frames, peft=None, lora_scale=1.0):
    """frames: (B, F, D) precomputed frontend-stub embeddings."""
    F = frames.shape[1]
    h = frames.astype(cfg.dtype) + sinusoidal_positions(F, cfg.d_model).astype(cfg.dtype)
    peft_layers = (peft or {}).get("enc_layers", {})

    def body(h, xs):
        lp, pl = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        h = h + attn.attn_block_prefill(cfg, lp["attn"], hn, pl or None,
                                        lora_scale, causal=False)
        hn = apply_norm(cfg, h, lp["ln2"])
        return h + mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale), None

    h, _ = jax.lax.scan(body, h, (base["enc_layers"], peft_layers))
    return apply_norm(cfg, h, base["enc_norm"])


def forward(cfg, base, peft, tokens, frames=None, lora_scale=1.0, memory=None):
    """Teacher-forced decoder pass. Returns (hidden (B,S,D), aux)."""
    if memory is None:
        memory = encode(cfg, base, frames, peft, lora_scale)
    S = tokens.shape[1]
    h = jnp.take(base["embed"], tokens, axis=0)
    h = h + sinusoidal_positions(S, cfg.d_model).astype(h.dtype)
    peft_layers = (peft or {}).get("layers", {})

    def body(h, xs):
        lp, pl = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        h = h + attn.attn_block_prefill(cfg, lp["self_attn"], hn, pl or None,
                                        lora_scale)
        hn = apply_norm(cfg, h, lp["ln2"])
        h = h + attn.cross_attn_block(cfg, lp["cross_attn"], hn, memory,
                                      pl or None, lora_scale)
        hn = apply_norm(cfg, h, lp["ln3"])
        h = h + mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale)
        return constrain(h, "prefill_h"), None

    h, _ = jax.lax.scan(body, h, (base["layers"], peft_layers))
    return apply_norm(cfg, h, base["final_norm"]), jnp.float32(0.0)


def init_cache(cfg, batch: int, seq_len: int):
    L = cfg.n_layers
    shape = (L, batch, seq_len, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "memory": jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), cfg.dtype),
    }


def decode_step(cfg, base, peft, cache, token, pos, lora_scale=1.0):
    h = jnp.take(base["embed"], token, axis=0)
    # learned/sinusoidal position for the current step
    pos_table = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    h = h + jax.lax.dynamic_slice_in_dim(pos_table, pos, 1, axis=0)[None].astype(h.dtype)
    memory = cache["memory"]
    peft_layers = (peft or {}).get("layers", {})

    def body(h, xs):
        lp, pl, kc, vc = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        a, kc, vc = attn.attn_block_decode(cfg, lp["self_attn"], hn, pl or None,
                                           lora_scale, kc, vc, pos)
        h = h + a
        hn = apply_norm(cfg, h, lp["ln2"])
        h = h + attn.cross_attn_block(cfg, lp["cross_attn"], hn, memory,
                                      pl or None, lora_scale)
        hn = apply_norm(cfg, h, lp["ln3"])
        return h + mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale), (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(
        body, h, (base["layers"], peft_layers, cache["k"], cache["v"]))
    h = apply_norm(cfg, h, base["final_norm"])
    logits = (h[:, 0, :] @ unembed(cfg, base)).astype(jnp.float32)
    return logits, {"k": kcs, "v": vcs, "memory": memory}
