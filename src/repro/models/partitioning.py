"""Optional sharding hints for model internals.

Model code stays mesh-agnostic: it calls ``constrain(x, "name")``, which is a
no-op unless the launcher installed a PartitionSpec for that name via the
``sharding_hints`` context manager (dryrun/serve do this while lowering under
the production mesh).

Why this exists: GSPMD's sharding propagation sometimes picks an internal
sharding that conflicts with the cache layout (e.g. re-sharding a 32k-token
KV cache from sequence-sharded to kv-head-sharded *inside the layer scan*,
which costs a full all-gather per layer). A single constraint at the right
spot pins the intended data flow.
"""
from __future__ import annotations

import contextlib
import threading

import jax

_STATE = threading.local()


def _hints():
    return getattr(_STATE, "hints", {})


@contextlib.contextmanager
def sharding_hints(hints: dict):
    old = _hints()
    _STATE.hints = {**old, **hints}
    try:
        yield
    finally:
        _STATE.hints = old


def constrain(x, name: str):
    spec = _hints().get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
