"""Gated-MLP (SwiGLU / GeGLU) block."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init, maybe_lora, proj


def mlp_params(cfg, key, layers=None, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    stack = (layers,) if layers else ()
    p = {
        "wi": dense_init(keys[0], stack + (d, f), dtype=cfg.dtype),
        "wg": dense_init(keys[1], stack + (d, f), dtype=cfg.dtype),
        "wd": dense_init(keys[2], stack + (f, d), dtype=cfg.dtype),
    }
    if cfg.use_bias:
        p["wi_b"] = jnp.zeros(stack + (f,), cfg.dtype)
        p["wd_b"] = jnp.zeros(stack + (d,), cfg.dtype)
    return p


def _lora_entry(peft_layer, name):
    e = maybe_lora(peft_layer, name)
    return e if (e is not None and "A" in e) else None


def mlp_block(cfg, p, x, peft_layer=None, lora_scale=1.0):
    up = proj(x, p["wi"], p.get("wi_b"), _lora_entry(peft_layer, "wi"), lora_scale)
    gate = proj(x, p["wg"], None, _lora_entry(peft_layer, "wg"), lora_scale)
    h = activation(cfg, gate) * up
    if peft_layer is not None and "ia3_ff" in peft_layer:
        h = h * peft_layer["ia3_ff"]["s"].astype(h.dtype)
    return proj(h, p["wd"], p.get("wd_b"), _lora_entry(peft_layer, "wd"), lora_scale)
