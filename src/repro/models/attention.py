"""GQA attention: full / sliding-window / local-global, memory-bounded.

Prefill and training scan over query chunks with online masking so the
(S x S) score matrix never materialises; sliding-window layers additionally
slice keys to a (window + chunk) band, making SWA prefill linear in S
(structurally sub-quadratic, not just masked). Decode attends one query
against the cache. This pure-jnp path mirrors the Pallas swa_attention
kernel (kernels/swa_attention) used on real TPUs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.models.common import dense_init, maybe_lora, proj, rope
from repro.models.partitioning import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attn_params(cfg, key, layers=None, prefix_shape=()):
    d, hd = cfg.d_model, cfg.hd
    shapes = {
        "wq": (d, cfg.n_heads * hd),
        "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd),
        "wo": (cfg.n_heads * hd, d),
    }
    keys = jax.random.split(key, len(shapes))
    stack = (layers,) if layers else ()
    p = {}
    for k, (name, shape) in zip(keys, shapes.items()):
        full = prefix_shape + stack + shape
        p[name] = dense_init(k, full, in_axis=-2, dtype=cfg.dtype)
        if cfg.use_bias:
            p[name + "_b"] = jnp.zeros(full[:-2] + (shape[1],), cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# Core scores
# ---------------------------------------------------------------------------

def _sdpa(q, k, v, mask, scale):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd), mask: (B?,Sq,Sk) bool keep."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def attend_prefill(q, k, v, *, window=None, causal=True, q_chunk=512):
    """Chunked causal attention. q,k,v over the same sequence.

    window=None -> full causal; window=W -> tokens attend to the last W keys
    only, with keys sliced to the band (linear cost in S).
    """
    B, S_orig, H, hd = q.shape
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q_chunk = min(q_chunk, S_orig)
    pad = (-S_orig) % q_chunk
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    S = S_orig + pad
    n = S // q_chunk

    banded = window is not None and (window + q_chunk) < S
    band = (window + q_chunk) if banded else S

    qs = q.reshape(B, n, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)

    def body(_, xs):
        ci, qc = xs
        start_q = ci * q_chunk
        if banded:
            start_k = jnp.clip(start_q + q_chunk - band, 0, S - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start_k, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start_k, band, axis=1)
            kpos = start_k + jnp.arange(band)
        else:
            kc, vc = k, v
            kpos = jnp.arange(S)
        qpos = start_q + jnp.arange(q_chunk)
        keep = kpos[None, :] <= qpos[:, None] if causal else jnp.ones(
            (q_chunk, kpos.shape[0]), bool)
        if window is not None:
            keep = keep & (kpos[None, :] > qpos[:, None] - window)
        keep = keep & (kpos[None, :] < S_orig)          # padded keys invalid
        keep = jnp.broadcast_to(keep[None], (B,) + keep.shape)
        return (), _sdpa(qc, kc, vc, keep, scale)

    _, out = jax.lax.scan(body, (), (jnp.arange(n), qs))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return out[:, :S_orig]


def attend_decode(q, k_cache, v_cache, pos, *, window=None):
    """One-token decode. q: (B,1,H,hd); caches: (B,Sc,KV,hd).

    For ring-buffer (window) caches, slot order is scrambled but attention is
    permutation-invariant over keys, so only slot *validity* matters:
    slot i valid iff i < min(pos+1, Sc).

    ``window`` may be a python int OR a traced scalar (per-layer window in
    local:global stacks — a traced mask keeps the scan body uniform so SPMD
    sharding propagates cleanly, unlike a lax.cond over two attention
    variants).

    ``pos`` may be a scalar (whole batch at one position) or a (B,) vector
    (continuous-batching serve: each row at its own position).
    """
    B, Sc = k_cache.shape[0], k_cache.shape[1]
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    n_valid = jnp.minimum(pos + 1, Sc)
    if jnp.ndim(pos) == 0:
        keep = (jnp.arange(Sc)[None, :] < n_valid)[None]
        keep = jnp.broadcast_to(keep, (B, 1, Sc))
        if window is not None:
            # mask stale entries beyond the (possibly per-layer) window; only
            # meaningful when the cache is longer than the window
            keep = keep & (jnp.arange(Sc)[None, None, :] > pos - window)
    else:
        keep = (jnp.arange(Sc)[None, :] < n_valid[:, None])[:, None, :]
        if window is not None:
            keep = keep & (jnp.arange(Sc)[None, None, :]
                           > (pos - window)[:, None, None])
    return _sdpa(q, k_cache, v_cache, keep, scale)


# ---------------------------------------------------------------------------
# Block-level API used by the model stacks
# ---------------------------------------------------------------------------

def qkv(cfg, p, x, peft_layer, lora_scale):
    B, S, _ = x.shape
    hd = cfg.hd
    q = proj(x, p["wq"], p.get("wq_b"), maybe_lora(peft_layer, "wq"), lora_scale)
    k = proj(x, p["wk"], p.get("wk_b"), maybe_lora(peft_layer, "wk"), lora_scale)
    v = proj(x, p["wv"], p.get("wv_b"), maybe_lora(peft_layer, "wv"), lora_scale)
    if peft_layer is not None and "ia3_kv" in peft_layer:
        s = peft_layer["ia3_kv"]["s"].astype(k.dtype)
        k = k * s
        v = v * s
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def attn_site_qkv(cfg, p, x, peft_layer, lora_scale, *, positions=None,
                  rope_cs=None):
    """Roped + sharding-constrained (q, k, v) in model layout (B,S,H,hd) —
    ``attn_block_prefill`` up to the sequence mixer. Shared by the prefill
    block below and the split forwards (the mixer is the declared
    fused-contraction site there). ``rope_cs``: precomputed rope tables
    shared across layers (see ``common.rope_tables``)."""
    S = x.shape[1]
    q, k, v = qkv(cfg, p, x, peft_layer, lora_scale)
    if positions is not None:
        rope_cs = None   # precomputed tables encode positions 0..S-1 only
    else:
        positions = jnp.arange(S)[None, :]
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta, tables=rope_cs)
        k = rope(k, positions, cfg.rope_theta, tables=rope_cs)
    # context-parallel hint: when the head count does not divide the model
    # axis (llama4: H=40, whisper: H=6), GSPMD falls back to sharding the
    # contraction (hd) dim and ALL-REDUCES the full score tensor per chunk
    # per layer. Sequence-sharding q instead keeps scores local (keys are
    # gathered once — orders of magnitude cheaper). Installed by the
    # launcher via sharding_hints; no-op otherwise.
    q = constrain(q, "prefill_q")
    k = constrain(k, "prefill_kv")
    v = constrain(v, "prefill_kv")
    return q, k, v


def swa_mixer_site(cfg, args, window):
    """Causal GQA mixer on kernel-layout args (q (B,H,S,hd); k,v
    (B,KV,S,hd)) with the model's backend gating: the dispatched op
    (multi-tangent Pallas kernels inside the estimator's forward-AD region)
    on kernel backends, the chunked/banded ``attend_prefill`` otherwise —
    exactly the ops ``attn_block_prefill`` runs. The split forwards declare
    this call as their fused-contraction site."""
    q, k, v = args
    if dispatch.use_kernel_mixers():
        return dispatch.swa_attend(q, k, v, window)
    out = attend_prefill(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), window=window, causal=True)
    return out.transpose(0, 2, 1, 3)


def attn_finish(cfg, p, out, peft_layer, lora_scale):
    """Mixer output (B,S,H,hd) -> output projection (B,S,D) — the tail of
    ``attn_block_prefill`` after the sequence mixer (the split forwards'
    post side)."""
    B, S = out.shape[:2]
    out = constrain(out, "prefill_q")
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return proj(out, p["wo"], p.get("wo_b"), maybe_lora(peft_layer, "wo"),
                lora_scale)


def attn_block_prefill_kv(cfg, p, x, peft_layer, lora_scale, *,
                          is_global=True, positions=None, causal=True,
                          rope_cs=None):
    """attn_block_prefill that additionally returns the roped (k, v) rows —
    exactly what decode would have inserted into the KV cache for these
    positions. Used by the fused-prefill serve path."""
    q, k, v = attn_site_qkv(cfg, p, x, peft_layer, lora_scale,
                            positions=positions, rope_cs=rope_cs)
    window = None if is_global else cfg.window
    if causal:
        # the gated mixer site: the dispatched op lowers K stacked tangents
        # to the multi-tangent SWA Pallas kernel on kernel backends — one
        # online-softmax walk over the primal q/k/v for all K perturbations
        # (K/V stay at KV-head width: contiguous groups, no repeat) — and
        # the chunked jnp path otherwise
        out = swa_mixer_site(
            cfg, (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                  v.transpose(0, 2, 1, 3)), window).transpose(0, 2, 1, 3)
    else:
        out = attend_prefill(q, k, v, window=window, causal=causal)
    out = attn_finish(cfg, p, out, peft_layer, lora_scale)
    return out, k, v


def attn_block_prefill(cfg, p, x, peft_layer, lora_scale, *, is_global=True,
                       positions=None, causal=True, rope_cs=None):
    out, _, _ = attn_block_prefill_kv(cfg, p, x, peft_layer, lora_scale,
                                      is_global=is_global,
                                      positions=positions, causal=causal,
                                      rope_cs=rope_cs)
    return out


def attn_block_decode(cfg, p, x, peft_layer, lora_scale, k_cache, v_cache, pos,
                      *, is_global=True, window_len=None):
    """x: (B,1,D). Returns (out, new_k_cache, new_v_cache).

    ``window_len``: optional traced per-layer window (overrides is_global;
    use a huge value for global layers). ``pos``: scalar, or a (B,) vector
    for per-row positions (each row then writes its own ring slot)."""
    B = x.shape[0]
    hd = cfg.hd
    q, k, v = qkv(cfg, p, x, peft_layer, lora_scale)
    if cfg.rope_theta:
        pos_arr = jnp.full((1, 1), pos) if jnp.ndim(pos) == 0 else pos[:, None]
        q = rope(q, pos_arr, cfg.rope_theta)
        k = rope(k, pos_arr, cfg.rope_theta)
    Sc = k_cache.shape[1]
    slot = pos % Sc   # ring-buffer insert; identity while pos < Sc
    q = constrain(q, "decode_q")
    if jnp.ndim(pos) == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), slot, axis=1)
    else:
        rows = jnp.arange(B)
        k_cache = k_cache.at[rows, slot].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, slot].set(v[:, 0].astype(v_cache.dtype))
    k_cache = constrain(k_cache, "decode_cache")
    v_cache = constrain(v_cache, "decode_cache")
    if window_len is not None:
        window = window_len
    else:
        window = None if is_global else cfg.window
        if window is not None and window >= Sc:
            window = None   # ring buffer already bounds the visible set
    out = attend_decode(q, k_cache, v_cache, pos, window=window)
    out = constrain(out, "decode_q")
    out = out.reshape(B, 1, cfg.n_heads * hd)
    out = proj(out, p["wo"], p.get("wo_b"), maybe_lora(peft_layer, "wo"), lora_scale)
    return out, k_cache, v_cache


def attn_block_decode_nocopy(cfg, p, x, peft_layer, lora_scale, k_cache,
                             v_cache, pos, *, is_global=True, window_len=None):
    """Decode WITHOUT writing the cache: returns (out, k_new, v_new).

    The caller inserts the (L,B,1,KV,hd) new-token keys/values into the full
    stacked cache with ONE dynamic_update_slice after the layer scan, so the
    multi-GB cache is never double-buffered through scan xs/ys (the naive
    pattern costs 2x cache bytes of temps; this costs one token row).

    The current token's contribution is handled out-of-band: its score is
    concatenated after the cache scores. For ring buffers the slot that the
    new token will overwrite is exactly the entry falling out of the window,
    so it is masked out of the cache part.
    """
    B = x.shape[0]
    hd = cfg.hd
    q, k_new, v_new = qkv(cfg, p, x, peft_layer, lora_scale)
    if cfg.rope_theta:
        pos_arr = jnp.full((1, 1), pos) if jnp.ndim(pos) == 0 else pos[:, None]
        q = rope(q, pos_arr, cfg.rope_theta)
        k_new = rope(k_new, pos_arr, cfg.rope_theta)
    q = constrain(q, "decode_q")

    Sc = k_cache.shape[1]
    H = cfg.n_heads
    KV = cfg.n_kv_heads
    rep = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    if window_len is not None:
        window = window_len
    else:
        window = None if is_global else cfg.window
        if window is not None and window >= Sc:
            window = None

    kc = jnp.repeat(k_cache, rep, axis=2)
    vc = jnp.repeat(v_cache, rep, axis=2)
    s_cache = jnp.einsum("bqhd,bkhd->bhqk", q, kc).astype(jnp.float32) * scale
    slot = pos % Sc
    idx = jnp.arange(Sc)
    if jnp.ndim(pos) == 0:
        valid = idx < jnp.minimum(pos, Sc)      # strictly past tokens
        valid = valid & (idx != slot)           # slot being overwritten
        if window is not None:
            valid = valid & (idx > pos - window)
        s_cache = jnp.where(valid[None, None, None, :], s_cache, NEG_INF)
    else:
        valid = idx[None, :] < jnp.minimum(pos, Sc)[:, None]
        valid = valid & (idx[None, :] != slot[:, None])
        if window is not None:
            valid = valid & (idx[None, :] > (pos - window)[:, None])
        s_cache = jnp.where(valid[:, None, None, :], s_cache, NEG_INF)

    kq = jnp.repeat(k_new, rep, axis=2)
    s_new = jnp.einsum("bqhd,bkhd->bhqk", q, kq).astype(jnp.float32) * scale

    s_all = jnp.concatenate([s_cache, s_new], axis=-1)       # (B,H,1,Sc+1)
    p_all = jax.nn.softmax(s_all, axis=-1).astype(q.dtype)
    p_cache, p_new = p_all[..., :Sc], p_all[..., Sc:]
    out = jnp.einsum("bhqk,bkhd->bqhd", p_cache, vc)
    out = out + jnp.einsum("bhqk,bkhd->bqhd", p_new,
                           jnp.repeat(v_new, rep, axis=2))
    out = constrain(out, "decode_q")
    out = out.reshape(B, 1, H * hd)
    out = proj(out, p["wo"], p.get("wo_b"), maybe_lora(peft_layer, "wo"),
               lora_scale)
    return out, k_new, v_new


def cross_attn_block(cfg, p, x, memory, peft_layer, lora_scale):
    """Decoder cross-attention (whisper): queries from x, keys/values from
    encoder memory (recomputed per call; memory is small and fixed)."""
    B, S, _ = x.shape
    Sm = memory.shape[1]
    hd = cfg.hd
    q = proj(x, p["wq"], p.get("wq_b"), maybe_lora(peft_layer, "wq"), lora_scale)
    k = proj(memory, p["wk"], p.get("wk_b"))
    v = proj(memory, p["wv"], p.get("wv_b"))
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, Sm, cfg.n_kv_heads, hd)
    v = v.reshape(B, Sm, cfg.n_kv_heads, hd)
    keep = jnp.ones((B, S, Sm), bool)
    out = _sdpa(q, k, v, keep, 1.0 / jnp.sqrt(hd).astype(jnp.float32))
    out = out.reshape(B, S, cfg.n_heads * hd)
    return proj(out, p["wo"], p.get("wo_b"), maybe_lora(peft_layer, "wo"), lora_scale)
