"""Zamba2-style hybrid: Mamba2 backbone + a single SHARED attention block
applied every ``cfg.hybrid_attn_every`` layers (weights reused at each
application — the Zamba trick for parameter efficiency).

The shared block's KV caches are per *application site* (layer // every),
carried through the layer scan and updated at the matching sites.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models.common import (
    apply_norm,
    dense_init,
    layer_slice,
    norm_params,
    rope_tables_for,
    scan_prefix_unroll_tail,
)
from repro.models.mlp import mlp_block, mlp_params
from repro.models.partitioning import constrain
from repro.models.ssm import (
    mamba2_finish,
    mamba2_mix,
    mamba2_mixer_site,
    mamba2_params,
    mamba2_preamble,
)


def n_attn_sites(cfg) -> int:
    return cfg.n_layers // cfg.hybrid_attn_every


def init_base(cfg, key):
    keys = jax.random.split(key, 6)
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab
    return {
        "embed": dense_init(keys[0], (V, d), in_axis=-1, dtype=cfg.dtype),
        "layers": {
            "mix": mamba2_params(cfg, keys[1], layers=L),
            "ln1": norm_params(cfg, d, layers=L),
        },
        "shared": {
            "attn": attn.attn_params(cfg, keys[2]),
            "mlp": mlp_params(cfg, keys[3]),
            "ln1": norm_params(cfg, d),
            "ln2": norm_params(cfg, d),
        },
        "final_norm": norm_params(cfg, d),
        "lm_head": dense_init(keys[4], (d, V), dtype=cfg.dtype),
    }


def embed_tokens(cfg, base, tokens):
    return jnp.take(base["embed"], tokens, axis=0)


def unembed(cfg, base):
    return base["lm_head"]


def _shared_block_prefill(cfg, shared, shared_peft, h, lora_scale,
                          rope_cs=None):
    hn = apply_norm(cfg, h, shared["ln1"])
    h = h + attn.attn_block_prefill(cfg, shared["attn"], hn, shared_peft,
                                    lora_scale, is_global=False,
                                    rope_cs=rope_cs)
    hn = apply_norm(cfg, h, shared["ln2"])
    return h + mlp_block(cfg, shared["mlp"], hn)


def _train_body(cfg, base, shared_peft, lora_scale, rope_cs):
    """One full hybrid layer as a scan body — shared by ``forward`` (all L
    layers) and ``split_forward`` (the first L-1)."""
    every = cfg.hybrid_attn_every

    def body(h, xs):
        lp, pl, idx = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        mix, _, _ = mamba2_mix(cfg, lp["mix"], hn, pl or None, lora_scale)
        h = h + mix
        h = jax.lax.cond(
            (idx % every) == (every - 1),
            lambda hh: _shared_block_prefill(cfg, base["shared"], shared_peft,
                                             hh, lora_scale, rope_cs),
            lambda hh: hh,
            h)
        return constrain(h, "prefill_h"), None
    return body


def forward_scanned(cfg, base, peft, tokens, extra_embeds=None,
                    lora_scale=1.0):
    """Reference train forward: ONE ``lax.scan`` over all L layers (see
    ``transformer.forward_scanned`` for the ulp caveat vs ``forward``)."""
    h = embed_tokens(cfg, base, tokens)
    peft_layers = (peft or {}).get("layers", {})
    shared_peft = (peft or {}).get("shared") or None
    idxs = jnp.arange(cfg.n_layers)
    body = _train_body(cfg, base, shared_peft, lora_scale,
                       rope_tables_for(cfg, h))
    h, _ = jax.lax.scan(body, h, (base["layers"], peft_layers, idxs))
    h = apply_norm(cfg, h, base["final_norm"])
    return h, jnp.float32(0.0)


def forward(cfg, base, peft, tokens, extra_embeds=None, lora_scale=1.0):
    """Train forward as the split composition (scan L-1 layers, unroll the
    final layer around its LAST mixer — shared attention or mamba2) —
    identical program to the registry split losses."""
    site_args, ctx = split_forward(cfg, base, peft, tokens,
                                   lora_scale=lora_scale)
    y = mixer_site(cfg, site_args)
    return split_post(cfg, base, y, ctx, peft, lora_scale=lora_scale)


# ---------------------------------------------------------------------------
# Split forward: scan L-1 layers, unroll the final layer up to its mixer
# ---------------------------------------------------------------------------

def _final_is_attn(cfg) -> bool:
    """True when the final layer ends with the shared attention block — its
    mixer is then the swa site; otherwise the mamba2 recurrence is."""
    every = cfg.hybrid_attn_every
    return ((cfg.n_layers - 1) % every) == (every - 1)


def split_site(cfg):
    if _final_is_attn(cfg):
        return "swa", {"window": cfg.window}
    return "mamba2", {}


def mixer_site(cfg, site_args):
    """The final layer's last mixer on the split site args (backend-gated;
    see ``attention.swa_mixer_site`` / ``ssm.mamba2_mixer_site``)."""
    if _final_is_attn(cfg):
        return attn.swa_mixer_site(cfg, site_args, cfg.window)
    return mamba2_mixer_site(site_args)


def split_forward(cfg, base, peft, tokens, extra_embeds=None, lora_scale=1.0):
    """Split (train) forward: scan the first L-1 layers, unroll the final
    layer up to its LAST mixer — the shared attention block when the final
    layer is an application site ((L-1) % every == every-1), the mamba2
    recurrence otherwise. The pre->site->post composition is
    bitwise-identical to ``forward``."""
    h = embed_tokens(cfg, base, tokens)
    peft_layers = (peft or {}).get("layers", {})
    shared_peft = (peft or {}).get("shared") or None
    idxs = jnp.arange(cfg.n_layers)
    rope_cs = rope_tables_for(cfg, h)
    body = _train_body(cfg, base, shared_peft, lora_scale, rope_cs)
    h, (lp, pl, _) = scan_prefix_unroll_tail(
        body, h, (base["layers"], peft_layers, idxs), cfg.n_layers)
    hn = apply_norm(cfg, h, lp["ln1"])
    if _final_is_attn(cfg):
        mix, _, _ = mamba2_mix(cfg, lp["mix"], hn, pl or None, lora_scale)
        h = h + mix
        hn = apply_norm(cfg, h, base["shared"]["ln1"])
        q, k, v = attn.attn_site_qkv(cfg, base["shared"]["attn"], hn,
                                     shared_peft, lora_scale,
                                     rope_cs=rope_cs)
        site_args = (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                     v.transpose(0, 2, 1, 3))
        return site_args, {"h": h}
    xh, dt, bmat, cmat, decay, z, _ = mamba2_preamble(
        cfg, lp["mix"], hn, pl or None, lora_scale)
    site_args = (xh * dt[..., None], bmat, cmat, decay)
    return site_args, {"h": h, "z": z, "xh": xh}


def split_post(cfg, base, y, ctx, peft, lora_scale=1.0):
    """Post-head of the split forward: final mixer output -> (final hidden,
    aux)."""
    lp = layer_slice(base["layers"], cfg.n_layers - 1)
    pl = layer_slice((peft or {}).get("layers", {}), cfg.n_layers - 1)
    shared_peft = (peft or {}).get("shared") or None
    h = ctx["h"]
    if _final_is_attn(cfg):
        a = attn.attn_finish(cfg, base["shared"]["attn"],
                             y.transpose(0, 2, 1, 3), shared_peft, lora_scale)
        h = h + a
        hn = apply_norm(cfg, h, base["shared"]["ln2"])
        h = h + mlp_block(cfg, base["shared"]["mlp"], hn)
    else:
        mix = mamba2_finish(cfg, lp["mix"], y, ctx["z"], ctx["xh"], h.dtype,
                            pl or None, lora_scale)
        h = h + mix
    h = constrain(h, "prefill_h")
    h = apply_norm(cfg, h, base["final_norm"])
    return h, jnp.float32(0.0)


def init_cache(cfg, batch: int, seq_len: int):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    L = cfg.n_layers
    W = min(cfg.window, seq_len)
    sites = n_attn_sites(cfg)
    return {
        "ssm": jnp.zeros((L, batch, H, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((L, batch, s.conv_kernel - 1, d_inner), cfg.dtype),
        "attn_k": jnp.zeros((sites, batch, W, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "attn_v": jnp.zeros((sites, batch, W, cfg.n_kv_heads, cfg.hd), cfg.dtype),
    }


def prefill(cfg, base, peft, cache, tokens, lora_scale=1.0):
    """Fused prompt ingestion for the hybrid stack: ONE pass over the whole
    prompt instead of P decode_step calls. The mamba2 recurrence is an exact
    per-token scan either way, so threading the cache's (ssm, conv) states
    through one multi-token ``mamba2_mix`` call composes identically to the
    token loop; the shared attention sites run chunked prefill attention and
    capture the roped K/V rows the decode loop would have inserted
    (ring-buffer aware, same slot mapping as ``transformer.prefill``)."""
    B, P = tokens.shape
    h = embed_tokens(cfg, base, tokens)
    peft_layers = (peft or {}).get("layers", {})
    shared_peft = (peft or {}).get("shared") or None
    every = cfg.hybrid_attn_every
    idxs = jnp.arange(cfg.n_layers)
    W = cache["attn_k"].shape[2]
    # slot s <- the LAST prompt position p < P with p % W == s
    slots = np.arange(min(P, W))
    gather = jnp.asarray(slots + W * ((P - 1 - slots) // W), jnp.int32)
    n_slots = len(slots)

    def shared_prefill(h, ks, vs, site):
        hn = apply_norm(cfg, h, base["shared"]["ln1"])
        a, k, v = attn.attn_block_prefill_kv(cfg, base["shared"]["attn"], hn,
                                             shared_peft, lora_scale,
                                             is_global=False)
        h = h + a
        hn = apply_norm(cfg, h, base["shared"]["ln2"])
        h = h + mlp_block(cfg, base["shared"]["mlp"], hn)
        kc = jax.lax.dynamic_index_in_dim(ks, site, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vs, site, 0, keepdims=False)
        kc = kc.at[:, :n_slots].set(k[:, gather].astype(kc.dtype))
        vc = vc.at[:, :n_slots].set(v[:, gather].astype(vc.dtype))
        ks = jax.lax.dynamic_update_index_in_dim(ks, kc, site, 0)
        vs = jax.lax.dynamic_update_index_in_dim(vs, vc, site, 0)
        return h, ks, vs

    def body(carry, xs):
        h, ks, vs = carry
        lp, pl, ssm_s, conv_s, idx = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        mix, ssm_s, conv_s = mamba2_mix(cfg, lp["mix"], hn, pl or None,
                                        lora_scale, state=ssm_s,
                                        conv_state=conv_s)
        h = h + mix
        site = idx // every
        h, ks, vs = jax.lax.cond(
            (idx % every) == (every - 1),
            lambda h, ks, vs: shared_prefill(h, ks, vs, site),
            lambda h, ks, vs: (h, ks, vs),
            h, ks, vs)
        return (h, ks, vs), (ssm_s, conv_s)

    (h, ks, vs), (ssm_states, conv_states) = jax.lax.scan(
        body, (h, cache["attn_k"], cache["attn_v"]),
        (base["layers"], peft_layers, cache["ssm"], cache["conv"], idxs))
    h = apply_norm(cfg, h, base["final_norm"])
    logits = (h[:, -1, :] @ unembed(cfg, base)).astype(jnp.float32)
    return logits, {"ssm": ssm_states, "conv": conv_states,
                    "attn_k": ks, "attn_v": vs}


def decode_step(cfg, base, peft, cache, token, pos, lora_scale=1.0):
    h = embed_tokens(cfg, base, token)
    peft_layers = (peft or {}).get("layers", {})
    shared_peft = (peft or {}).get("shared") or None
    every = cfg.hybrid_attn_every
    idxs = jnp.arange(cfg.n_layers)

    def shared_decode(h, ks, vs, site):
        kc = jax.lax.dynamic_index_in_dim(ks, site, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vs, site, 0, keepdims=False)
        hn = apply_norm(cfg, h, base["shared"]["ln1"])
        a, kc, vc = attn.attn_block_decode(cfg, base["shared"]["attn"], hn,
                                           shared_peft, lora_scale, kc, vc,
                                           pos, is_global=False)
        h = h + a
        hn = apply_norm(cfg, h, base["shared"]["ln2"])
        h = h + mlp_block(cfg, base["shared"]["mlp"], hn)
        ks = jax.lax.dynamic_update_index_in_dim(ks, kc, site, 0)
        vs = jax.lax.dynamic_update_index_in_dim(vs, vc, site, 0)
        return h, ks, vs

    def body(carry, xs):
        h, ks, vs = carry
        lp, pl, ssm_s, conv_s, idx = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        mix, ssm_s, conv_s = mamba2_mix(cfg, lp["mix"], hn, pl or None,
                                        lora_scale, state=ssm_s, conv_state=conv_s)
        h = h + mix
        site = idx // every
        h, ks, vs = jax.lax.cond(
            (idx % every) == (every - 1),
            lambda h, ks, vs: shared_decode(h, ks, vs, site),
            lambda h, ks, vs: (h, ks, vs),
            h, ks, vs)
        return (h, ks, vs), (ssm_s, conv_s)

    (h, ks, vs), (ssm_states, conv_states) = jax.lax.scan(
        body, (h, cache["attn_k"], cache["attn_v"]),
        (base["layers"], peft_layers, cache["ssm"], cache["conv"], idxs))
    h = apply_norm(cfg, h, base["final_norm"])
    logits = (h[:, 0, :] @ unembed(cfg, base)).astype(jnp.float32)
    return logits, {"ssm": ssm_states, "conv": conv_states,
                    "attn_k": ks, "attn_v": vs}
