from repro.models.registry import (
    ModelFns,
    cls_logits,
    cls_loss,
    get_loss_fn,
    get_model,
    lm_loss,
)
