"""Family registry: one uniform API over all 10 assigned architectures.

    model = get_model(cfg)
    base  = model.init_base(cfg, key)          # frozen weights
    h,aux = model.forward(cfg, base, peft, batch)
    loss  = lm_loss(cfg, base, peft, batch) / cls_loss(...)
    cache = model.init_cache(cfg, batch, seq_len)
    logits, cache = model.decode_step(cfg, base, peft, cache, token, pos)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, rwkv_model, transformer
from repro.models.common import chunked_lm_loss, classification_loss


@dataclasses.dataclass(frozen=True)
class ModelFns:
    init_base: Callable
    forward: Callable          # (cfg, base, peft, batch) -> (hidden, aux)
    unembed: Callable
    init_cache: Callable
    decode_step: Callable
    # fused prompt ingestion: (cfg, base, peft, cache, tokens) ->
    # (last-token logits, cache). None -> serve falls back to the
    # token-by-token decode loop.
    prefill: Optional[Callable] = None
    # whether init_cache accepts kv_int8=True (int8 KV entries + bf16
    # scales). Explicit capability flag — serve checks this instead of
    # probing the signature with try/except.
    supports_kv_int8: bool = False
    # split forward (scan L-1 layers, unroll the final one up to its
    # sequence mixer — the fused jvp-contraction site):
    #   split_forward (cfg, base, peft, batch, lora_scale) -> (site_args, ctx)
    #   split_post    (cfg, base, y, ctx, peft, batch, lora_scale) -> (h, aux)
    #   split_site    cfg -> (site kind, static site kwargs)
    #   mixer_site    (cfg, site_args) -> y   (the backend-gated site primal)
    # ``forward`` IS the composition pre -> mixer_site -> post, so the
    # registry split losses trace the identical program (bitwise-equal).
    split_forward: Optional[Callable] = None
    split_post: Optional[Callable] = None
    split_site: Optional[Callable] = None
    mixer_site: Optional[Callable] = None


def _tf_forward(cfg, base, peft, batch, lora_scale=1.0):
    return transformer.forward(cfg, base, peft, batch["tokens"],
                               extra_embeds=batch.get("patch_embeds"),
                               lora_scale=lora_scale)


def _tf_split_forward(cfg, base, peft, batch, lora_scale=1.0):
    return transformer.split_forward(cfg, base, peft, batch["tokens"],
                                     extra_embeds=batch.get("patch_embeds"),
                                     lora_scale=lora_scale)


def _tf_split_post(cfg, base, y, ctx, peft, batch, lora_scale=1.0):
    return transformer.split_post(cfg, base, y, ctx, peft,
                                  lora_scale=lora_scale)


def _rwkv_forward(cfg, base, peft, batch, lora_scale=1.0):
    return rwkv_model.forward(cfg, base, peft, batch["tokens"],
                              lora_scale=lora_scale)


def _rwkv_split_forward(cfg, base, peft, batch, lora_scale=1.0):
    return rwkv_model.split_forward(cfg, base, peft, batch["tokens"],
                                    lora_scale=lora_scale)


def _rwkv_split_post(cfg, base, y, ctx, peft, batch, lora_scale=1.0):
    return rwkv_model.split_post(cfg, base, y, ctx, peft,
                                 lora_scale=lora_scale)


def _hybrid_forward(cfg, base, peft, batch, lora_scale=1.0):
    return hybrid.forward(cfg, base, peft, batch["tokens"],
                          lora_scale=lora_scale)


def _hybrid_split_forward(cfg, base, peft, batch, lora_scale=1.0):
    return hybrid.split_forward(cfg, base, peft, batch["tokens"],
                                lora_scale=lora_scale)


def _hybrid_split_post(cfg, base, y, ctx, peft, batch, lora_scale=1.0):
    return hybrid.split_post(cfg, base, y, ctx, peft, lora_scale=lora_scale)


def _encdec_forward(cfg, base, peft, batch, lora_scale=1.0):
    return encdec.forward(cfg, base, peft, batch["tokens"],
                          frames=batch["frames"], lora_scale=lora_scale)


def _encdec_split_forward(cfg, base, peft, batch, lora_scale=1.0):
    return encdec.split_forward(cfg, base, peft, batch["tokens"],
                                frames=batch["frames"],
                                lora_scale=lora_scale)


def _encdec_split_post(cfg, base, y, ctx, peft, batch, lora_scale=1.0):
    return encdec.split_post(cfg, base, y, ctx, peft, lora_scale=lora_scale)


_TF_SPLIT = dict(split_forward=_tf_split_forward, split_post=_tf_split_post,
                 split_site=transformer.split_site,
                 mixer_site=transformer.mixer_site)

_FAMILIES = {
    "dense": ModelFns(transformer.init_base, _tf_forward, transformer.unembed,
                      transformer.init_cache, transformer.decode_step,
                      transformer.prefill, supports_kv_int8=True,
                      **_TF_SPLIT),
    "moe": ModelFns(transformer.init_base, _tf_forward, transformer.unembed,
                    transformer.init_cache, transformer.decode_step,
                    transformer.prefill, supports_kv_int8=True, **_TF_SPLIT),
    "vlm": ModelFns(transformer.init_base, _tf_forward, transformer.unembed,
                    transformer.init_cache, transformer.decode_step,
                    transformer.prefill, supports_kv_int8=True, **_TF_SPLIT),
    "ssm": ModelFns(rwkv_model.init_base, _rwkv_forward, rwkv_model.unembed,
                    rwkv_model.init_cache, rwkv_model.decode_step,
                    rwkv_model.prefill,
                    split_forward=_rwkv_split_forward,
                    split_post=_rwkv_split_post,
                    split_site=rwkv_model.split_site,
                    mixer_site=rwkv_model.mixer_site),
    "hybrid": ModelFns(hybrid.init_base, _hybrid_forward, hybrid.unembed,
                       hybrid.init_cache, hybrid.decode_step,
                       hybrid.prefill,
                       split_forward=_hybrid_split_forward,
                       split_post=_hybrid_split_post,
                       split_site=hybrid.split_site,
                       mixer_site=hybrid.mixer_site),
    "audio": ModelFns(encdec.init_base, _encdec_forward, encdec.unembed,
                      encdec.init_cache, encdec.decode_step,
                      encdec.prefill,
                      split_forward=_encdec_split_forward,
                      split_post=_encdec_split_post,
                      split_site=encdec.split_site,
                      mixer_site=encdec.mixer_site),
}


def get_model(cfg) -> ModelFns:
    return _FAMILIES[cfg.family]


# ---------------------------------------------------------------------------
# Losses — the objective f(w; D) the paper differentiates
# ---------------------------------------------------------------------------

def lm_loss(cfg, base, peft, batch, lora_scale=1.0):
    """Causal-LM next-token loss (billion-scale configs / dry-run)."""
    model = get_model(cfg)
    h, aux = model.forward(cfg, base, peft, batch, lora_scale=lora_scale)
    return _lm_head(cfg, base, model, h, aux, batch)


def cls_loss(cfg, base, peft, batch, lora_scale=1.0):
    """Sequence-classification loss (the paper's FL tasks) using the
    trainable head in ``peft['head']``."""
    model = get_model(cfg)
    h, aux = model.forward(cfg, base, peft, batch, lora_scale=lora_scale)
    loss, _ = classification_loss(h, peft["head"], batch["labels"])
    return loss + 0.01 * aux


def cls_logits(cfg, base, peft, batch, lora_scale=1.0):
    model = get_model(cfg)
    h, _ = model.forward(cfg, base, peft, batch, lora_scale=lora_scale)
    pooled = h[:, -1, :]
    return (pooled @ peft["head"]["w"] + peft["head"]["b"]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Split losses — the same objectives with the final mixer site exposed, so
# forward_gradient(..., fused_contraction=True) runs the in-kernel
# jvp-contraction epilogue for FULL-model training losses
# ---------------------------------------------------------------------------

def _lm_head(cfg, base, model, h, aux, batch):
    tokens = batch["tokens"]
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    valid = jnp.ones_like(targets, jnp.float32).at[:, -1].set(0.0)
    if "patch_embeds" in batch and batch["patch_embeds"] is not None:
        h = h[:, batch["patch_embeds"].shape[1]:, :]   # loss on text only
    loss = chunked_lm_loss(h, model.unembed(cfg, base), targets, valid)
    return loss + 0.01 * aux


def _split_loss(cfg, base, batch, head_fn, lora_scale):
    from repro.core.forward_grad import SplitLoss
    model = get_model(cfg)
    if model.split_forward is None:
        raise ValueError(
            f"family {cfg.family!r} has no split forward; use the plain "
            f"loss closure (get_loss_fn(task))")
    kind, site_kwargs = model.split_site(cfg)

    def pre(p):
        return model.split_forward(cfg, base, p, batch,
                                   lora_scale=lora_scale)

    def post(y, ctx, p):
        h, aux = model.split_post(cfg, base, y, ctx, p, batch,
                                  lora_scale=lora_scale)
        return head_fn(cfg, base, model, h, aux, batch, p)

    # the model's backend-gated mixer as the site primal: the SplitLoss
    # traces exactly the program ``model.forward`` (= the plain loss) does
    return SplitLoss(pre, kind, post,
                     site_fn=lambda args: model.mixer_site(cfg, args),
                     **site_kwargs)


def split_lm_loss(cfg, base, batch, lora_scale=1.0):
    """``lm_loss`` as a ``SplitLoss``: a function of the peft tree only,
    bitwise-equal to the plain closure, whose final-mixer site runs the
    fused jvp-contraction route under ``fused_contraction=True``."""
    def head(cfg_, base_, model, h, aux, batch_, p):
        return _lm_head(cfg_, base_, model, h, aux, batch_)
    return _split_loss(cfg, base, batch, head, lora_scale)


def split_cls_loss(cfg, base, batch, lora_scale=1.0):
    """``cls_loss`` as a ``SplitLoss`` (trainable head read from the peft
    tree inside the reversed-once post-head)."""
    def head(cfg_, base_, model, h, aux, batch_, p):
        loss, _ = classification_loss(h, p["head"], batch_["labels"])
        return loss + 0.01 * aux
    return _split_loss(cfg, base, batch, head, lora_scale)


def get_loss_fn(task: str, split: bool = False):
    """Plain loss closures (split=False; byte-identical to the historical
    behaviour) or the split-loss builders (split=True): ``builder(cfg,
    base, batch, lora_scale=...) -> SplitLoss``. The SplitLoss value equals
    the plain loss bitwise on every family; under ``forward_gradient(...,
    fused_contraction=True)`` its final mixer site contracts the K tangent
    outputs in-kernel instead of materializing them."""
    if split:
        return {"lm": split_lm_loss, "cls": split_cls_loss}[task]
    return {"lm": lm_loss, "cls": cls_loss}[task]
