"""Family registry: one uniform API over all 10 assigned architectures.

    model = get_model(cfg)
    base  = model.init_base(cfg, key)          # frozen weights
    h,aux = model.forward(cfg, base, peft, batch)
    loss  = lm_loss(cfg, base, peft, batch) / cls_loss(...)
    cache = model.init_cache(cfg, batch, seq_len)
    logits, cache = model.decode_step(cfg, base, peft, cache, token, pos)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, rwkv_model, transformer
from repro.models.common import chunked_lm_loss, classification_loss


@dataclasses.dataclass(frozen=True)
class ModelFns:
    init_base: Callable
    forward: Callable          # (cfg, base, peft, batch) -> (hidden, aux)
    unembed: Callable
    init_cache: Callable
    decode_step: Callable
    # fused prompt ingestion: (cfg, base, peft, cache, tokens) ->
    # (last-token logits, cache). None -> serve falls back to the
    # token-by-token decode loop (hybrid/encdec families).
    prefill: Optional[Callable] = None


def _tf_forward(cfg, base, peft, batch, lora_scale=1.0):
    return transformer.forward(cfg, base, peft, batch["tokens"],
                               extra_embeds=batch.get("patch_embeds"),
                               lora_scale=lora_scale)


def _rwkv_forward(cfg, base, peft, batch, lora_scale=1.0):
    return rwkv_model.forward(cfg, base, peft, batch["tokens"],
                              lora_scale=lora_scale)


def _hybrid_forward(cfg, base, peft, batch, lora_scale=1.0):
    return hybrid.forward(cfg, base, peft, batch["tokens"],
                          lora_scale=lora_scale)


def _encdec_forward(cfg, base, peft, batch, lora_scale=1.0):
    return encdec.forward(cfg, base, peft, batch["tokens"],
                          frames=batch["frames"], lora_scale=lora_scale)


_FAMILIES = {
    "dense": ModelFns(transformer.init_base, _tf_forward, transformer.unembed,
                      transformer.init_cache, transformer.decode_step,
                      transformer.prefill),
    "moe": ModelFns(transformer.init_base, _tf_forward, transformer.unembed,
                    transformer.init_cache, transformer.decode_step,
                    transformer.prefill),
    "vlm": ModelFns(transformer.init_base, _tf_forward, transformer.unembed,
                    transformer.init_cache, transformer.decode_step,
                    transformer.prefill),
    "ssm": ModelFns(rwkv_model.init_base, _rwkv_forward, rwkv_model.unembed,
                    rwkv_model.init_cache, rwkv_model.decode_step,
                    rwkv_model.prefill),
    "hybrid": ModelFns(hybrid.init_base, _hybrid_forward, hybrid.unembed,
                       hybrid.init_cache, hybrid.decode_step),
    "audio": ModelFns(encdec.init_base, _encdec_forward, encdec.unembed,
                      encdec.init_cache, encdec.decode_step),
}


def get_model(cfg) -> ModelFns:
    return _FAMILIES[cfg.family]


# ---------------------------------------------------------------------------
# Losses — the objective f(w; D) the paper differentiates
# ---------------------------------------------------------------------------

def lm_loss(cfg, base, peft, batch, lora_scale=1.0):
    """Causal-LM next-token loss (billion-scale configs / dry-run)."""
    model = get_model(cfg)
    h, aux = model.forward(cfg, base, peft, batch, lora_scale=lora_scale)
    tokens = batch["tokens"]
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    valid = jnp.ones_like(targets, jnp.float32).at[:, -1].set(0.0)
    if "patch_embeds" in batch and batch["patch_embeds"] is not None:
        h = h[:, batch["patch_embeds"].shape[1]:, :]   # loss on text only
    loss = chunked_lm_loss(h, model.unembed(cfg, base), targets, valid)
    return loss + 0.01 * aux


def cls_loss(cfg, base, peft, batch, lora_scale=1.0):
    """Sequence-classification loss (the paper's FL tasks) using the
    trainable head in ``peft['head']``."""
    model = get_model(cfg)
    h, aux = model.forward(cfg, base, peft, batch, lora_scale=lora_scale)
    loss, _ = classification_loss(h, peft["head"], batch["labels"])
    return loss + 0.01 * aux


def cls_logits(cfg, base, peft, batch, lora_scale=1.0):
    model = get_model(cfg)
    h, _ = model.forward(cfg, base, peft, batch, lora_scale=lora_scale)
    pooled = h[:, -1, :]
    return (pooled @ peft["head"]["w"] + peft["head"]["b"]).astype(jnp.float32)


def get_loss_fn(task: str):
    return {"lm": lm_loss, "cls": cls_loss}[task]
