"""Shared model primitives: norms, RoPE, activations, chunked losses, init.

Models are pure functions over pytrees. Per-layer parameters are stacked on a
leading ``n_layers`` axis and iterated with ``lax.scan`` so that 60-90 layer
configs lower to compact HLO (one loop body), which keeps the 512-device
dry-run compile times tractable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import lora_proj, lora_proj_multi


# ---------------------------------------------------------------------------
# Initialisation
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    """LeCun-normal in fp32, cast by caller."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def layernorm(x, w, b, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w + b).astype(x.dtype)


def apply_norm(cfg, x, p):
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


def norm_params(cfg, d, layers=None):
    shape = (layers, d) if layers else (d,)
    p = {"w": jnp.ones(shape, jnp.float32)}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros(shape, jnp.float32)
    return p


def activation(cfg, x):
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta, tables=None):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S) or scalar.

    ``tables``: optional precomputed (cos, sin) pair from ``rope_tables``
    (positions are ignored then) — used by the train forwards so the scan
    body and the split forwards' unrolled final layer share ONE table (see
    ``rope_tables``)."""
    hd = x.shape[-1]
    if tables is None:
        freqs = theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))
        ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, hd/2)
        cos = jnp.cos(ang)[..., None, :]                             # (..., S, 1, hd/2)
        sin = jnp.sin(ang)[..., None, :]
    else:
        cos, sin = tables                                            # (S, hd/2)
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def rope_tables(theta, seq, hd):
    """(cos, sin) rope tables ((S, hd/2) fp32) for constant positions
    0..S-1, computed ONCE per forward and shared by every layer. XLA
    constant-folds transcendentals of constant operands with a different
    code path than the runtime kernels, so computing cos/sin inside a scan
    body AND inline (the split forwards' unrolled final layer) yields
    ulp-different values — one shared table keeps the split forward
    bitwise-equal to the fully-scanned one."""
    freqs = theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs        # (S, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def rope_tables_for(cfg, h):
    """Forward-wide rope tables for hidden stream h (B,S,D), or None when
    the config uses no rope (whisper's sinusoidal positions)."""
    if not cfg.rope_theta:
        return None
    return rope_tables(cfg.rope_theta, h.shape[1], cfg.hd)


def sinusoidal_positions(seq, d):
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32)


# ---------------------------------------------------------------------------
# Split forward: scan prefix + unrolled final layer
# ---------------------------------------------------------------------------

def scan_prefix_unroll_tail(body, init, xs, n_layers):
    """Scan ``body`` over the first ``n_layers - 1`` stacked layer slices of
    ``xs`` and hand the final layer back unrolled: returns
    (carry_after_prefix, tail_slice) with ``tail_slice =
    tree.map(lambda t: t[n_layers - 1], xs)``.

    This is the shared skeleton of every family's split forward (registry
    ``split_lm_loss`` / ``split_cls_loss``): the caller finishes the final
    layer explicitly, exposing its sequence-mixer site to the estimator's
    fused jvp-contraction route. Running the SAME scan ``body`` over the
    prefix keeps the composition bitwise-identical to the full ``lax.scan``
    over all layers (the body applies identical ops per layer either way).
    """
    head = jax.tree.map(lambda t: t[: n_layers - 1], xs)
    tail = jax.tree.map(lambda t: t[n_layers - 1], xs)
    carry, _ = jax.lax.scan(body, init, head)
    return carry, tail


def layer_slice(tree, i):
    """Per-layer slice of a stacked parameter tree ({} stays {})."""
    return jax.tree.map(lambda t: t[i], tree)


# ---------------------------------------------------------------------------
# Dense (+LoRA) projection
# ---------------------------------------------------------------------------

def proj(x, w, b=None, lora=None, lora_scale=1.0):
    """y = x @ W (+ b) (+ s * (x@A)@B).

    ``lora`` is None or {"A": (din, r), "B": (r, dout)}. The LoRA path is the
    paper's trainable subspace; it routes through ``kernels/dispatch`` so
    forward-mode differentiation (SPRY's estimator) hits the fused
    primal+tangent kernel — Pallas on TPU, the jnp reference mirror on CPU.

    A multi-adapter entry carries page-stacked factors plus a per-row page
    index: {"A": (P, din, r), "B": (P, r, dout), "idx": (B,)}. Each batch row
    then reads its own adapter page through ``lora_proj_multi`` (one pass
    over the shared frozen W), which the serving engine uses to decode a
    batch of requests bound to different adapters.
    """
    if lora is not None:
        if "idx" in lora:
            y = lora_proj_multi(x, lora["idx"], w, lora["A"], lora["B"],
                                float(lora_scale))
        else:
            y = lora_proj(x, w, lora["A"], lora["B"], float(lora_scale))
    else:
        y = x @ w
    if b is not None:
        y = y + b
    return y


def maybe_lora(peft_layer, name):
    if peft_layer is None:
        return None
    entry = peft_layer.get(name)
    return entry


# ---------------------------------------------------------------------------
# Losses (chunked over sequence so the (B,S,V) logits tensor never
# materialises — essential for V=256k at seq 4k)
# ---------------------------------------------------------------------------

def chunked_lm_loss(h, unembed, targets, valid=None, chunk=512):
    """Next-token CE.  h: (B,S,D) final hidden, unembed: (D,V),
    targets: (B,S) already shifted. Scans over S in chunks."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    hs = h[:, : n * chunk].reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ts = targets[:, : n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)
    if valid is None:
        vs = jnp.ones((n, B, chunk), jnp.float32)
    else:
        vs = valid[:, : n * chunk].reshape(B, n, chunk).transpose(1, 0, 2).astype(jnp.float32)

    def body(carry, xs):
        hc, tc, vc = xs
        logits = (hc @ unembed).astype(jnp.float32)          # (B, chunk, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * vc
        return (carry[0] + nll.sum(), carry[1] + vc.sum()), None

    (total, count), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                     (hs, ts, vs))
    return total / jnp.maximum(count, 1.0)


def classification_loss(h, head, labels):
    """Pooled (last-token) classification CE; ``head``={"w","b"} trainable by
    every client (the paper's personalisation head)."""
    pooled = h[:, -1, :]
    logits = (pooled @ head["w"] + head["b"]).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return nll.mean(), logits


def accuracy_from_logits(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
