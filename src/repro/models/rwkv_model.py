"""RWKV6 ("Finch") full model stack — attention-free family."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (
    apply_norm,
    dense_init,
    layer_slice,
    norm_params,
    scan_prefix_unroll_tail,
)
from repro.models.partitioning import constrain
from repro.models.ssm import (
    rwkv6_channel_mix,
    rwkv6_finish,
    rwkv6_params,
    rwkv6_site_args,
    rwkv6_time_mix,
    wkv6_mixer_site,
)


def init_base(cfg, key):
    keys = jax.random.split(key, 4)
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab
    return {
        "embed": dense_init(keys[0], (V, d), in_axis=-1, dtype=cfg.dtype),
        "layers": {
            "mix": rwkv6_params(cfg, keys[1], layers=L),
            "ln1": norm_params(cfg, d, layers=L),
            "ln2": norm_params(cfg, d, layers=L),
        },
        "final_norm": norm_params(cfg, d),
        "lm_head": dense_init(keys[2], (d, V), dtype=cfg.dtype),
    }


def embed_tokens(cfg, base, tokens):
    return jnp.take(base["embed"], tokens, axis=0)


def unembed(cfg, base):
    return base["lm_head"]


def _train_body(cfg, lora_scale):
    """One full RWKV6 layer as a scan body — shared by ``forward`` (all L
    layers) and ``split_forward`` (the first L-1)."""
    def body(h, xs):
        lp, pl = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        tm, _, _ = rwkv6_time_mix(cfg, lp["mix"], hn, pl or None, lora_scale)
        h = h + tm
        hn = apply_norm(cfg, h, lp["ln2"])
        cm, _ = rwkv6_channel_mix(cfg, lp["mix"], hn)
        return constrain(h + cm, "prefill_h"), None
    return body


def forward_scanned(cfg, base, peft, tokens, extra_embeds=None,
                    lora_scale=1.0):
    """Reference train forward: ONE ``lax.scan`` over all L layers (see
    ``transformer.forward_scanned`` for the ulp caveat vs ``forward``)."""
    h = embed_tokens(cfg, base, tokens)
    peft_layers = (peft or {}).get("layers", {})
    h, _ = jax.lax.scan(_train_body(cfg, lora_scale), h,
                        (base["layers"], peft_layers))
    h = apply_norm(cfg, h, base["final_norm"])
    return h, jnp.float32(0.0)


def forward(cfg, base, peft, tokens, extra_embeds=None, lora_scale=1.0):
    """Train forward as the split composition (scan L-1 layers, unroll the
    final layer around its WKV6 recurrence) — identical program to the
    registry split losses."""
    site_args, ctx = split_forward(cfg, base, peft, tokens,
                                   lora_scale=lora_scale)
    y = mixer_site(cfg, site_args)
    return split_post(cfg, base, y, ctx, peft, lora_scale=lora_scale)


# ---------------------------------------------------------------------------
# Split forward: scan L-1 layers, unroll the final layer up to its mixer
# ---------------------------------------------------------------------------

def split_site(cfg):
    return "wkv6", {}


def mixer_site(cfg, site_args):
    """The final layer's WKV6 recurrence on the split site args
    (backend-gated; see ``ssm.wkv6_mixer_site``)."""
    return wkv6_mixer_site(site_args)


def split_forward(cfg, base, peft, tokens, extra_embeds=None, lora_scale=1.0):
    """Split (train) forward: scan the first L-1 layers, unroll the final
    layer up to its WKV6 recurrence. Returns (site_args, ctx) with
    site_args = (r, k, v, w, u) and ctx carrying the residual stream + gate
    the post-mixer tail needs; the pre->site->post composition is
    bitwise-identical to ``forward``."""
    h = embed_tokens(cfg, base, tokens)
    peft_layers = (peft or {}).get("layers", {})
    h, (lp, pl) = scan_prefix_unroll_tail(
        _train_body(cfg, lora_scale), h, (base["layers"], peft_layers),
        cfg.n_layers)
    hn = apply_norm(cfg, h, lp["ln1"])
    site_args, g = rwkv6_site_args(cfg, lp["mix"], hn, pl or None, lora_scale)
    return site_args, {"h": h, "g": g}


def split_post(cfg, base, y, ctx, peft, lora_scale=1.0):
    """Post-head of the split forward: WKV6 mixer output (B,S,H,hd) fp32 ->
    (final hidden, aux)."""
    lp = layer_slice(base["layers"], cfg.n_layers - 1)
    pl = layer_slice((peft or {}).get("layers", {}), cfg.n_layers - 1)
    h, g = ctx["h"], ctx["g"]
    tm = rwkv6_finish(cfg, lp["mix"], y, g, h.dtype, pl or None, lora_scale)
    h = h + tm
    hn = apply_norm(cfg, h, lp["ln2"])
    cm, _ = rwkv6_channel_mix(cfg, lp["mix"], hn)
    h = constrain(h + cm, "prefill_h")
    h = apply_norm(cfg, h, base["final_norm"])
    return h, jnp.float32(0.0)


def prefill(cfg, base, peft, cache, tokens, lora_scale=1.0):
    """Fused prompt ingestion: one full-sequence recurrence pass per layer
    instead of P decode_step calls. The explicit state threading forces the
    sequential-recurrence path (state is consumed), so the carried
    (wkv, token-shift) states land exactly where the decode loop would have
    left them. Returns (last-token logits (B,V), cache)."""
    h = embed_tokens(cfg, base, tokens)                # (B,P,D)
    peft_layers = (peft or {}).get("layers", {})

    def body(h, xs):
        lp, pl, wkv, s_tm, s_cm = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        tm, wkv, last_tm = rwkv6_time_mix(
            cfg, lp["mix"], hn, pl or None, lora_scale,
            state=wkv, shift_prev=s_tm)
        h = h + tm
        hn = apply_norm(cfg, h, lp["ln2"])
        cm, last_cm = rwkv6_channel_mix(cfg, lp["mix"], hn, shift_prev=s_cm)
        return h + cm, (wkv, last_tm.astype(s_tm.dtype),
                        last_cm.astype(s_cm.dtype))

    h, (wkvs, stms, scms) = jax.lax.scan(
        body, h,
        (base["layers"], peft_layers, cache["wkv"], cache["shift_tm"],
         cache["shift_cm"]))
    h = apply_norm(cfg, h, base["final_norm"])
    logits = (h[:, -1, :] @ unembed(cfg, base)).astype(jnp.float32)
    return logits, {"wkv": wkvs, "shift_tm": stms, "shift_cm": scms}


def init_cache(cfg, batch: int, seq_len: int):
    hd = cfg.ssm.head_dim
    H = cfg.d_model // hd
    L = cfg.n_layers
    return {
        "wkv": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((L, batch, 1, cfg.d_model), cfg.dtype),
        "shift_cm": jnp.zeros((L, batch, 1, cfg.d_model), cfg.dtype),
    }


def decode_step(cfg, base, peft, cache, token, pos, lora_scale=1.0):
    h = embed_tokens(cfg, base, token)     # (B,1,D)
    peft_layers = (peft or {}).get("layers", {})

    def body(h, xs):
        lp, pl, wkv, s_tm, s_cm = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        tm, wkv, last_tm = rwkv6_time_mix(
            cfg, lp["mix"], hn, pl or None, lora_scale,
            state=wkv, shift_prev=s_tm)
        h = h + tm
        hn = apply_norm(cfg, h, lp["ln2"])
        cm, last_cm = rwkv6_channel_mix(cfg, lp["mix"], hn, shift_prev=s_cm)
        return h + cm, (wkv, last_tm.astype(s_tm.dtype), last_cm.astype(s_cm.dtype))

    h, (wkvs, stms, scms) = jax.lax.scan(
        body, h,
        (base["layers"], peft_layers, cache["wkv"], cache["shift_tm"],
         cache["shift_cm"]))
    h = apply_norm(cfg, h, base["final_norm"])
    logits = (h[:, 0, :] @ unembed(cfg, base)).astype(jnp.float32)
    return logits, {"wkv": wkvs, "shift_tm": stms, "shift_cm": scms}
