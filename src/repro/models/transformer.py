"""Decoder-only transformer stack covering the dense / moe / vlm families.

Layers are stacked on a leading axis and driven by ``lax.scan``; the gemma3
local:global pattern is handled with a per-layer ``lax.cond`` whose branches
are *statically* specialised (banded key-slicing for local layers, full
attention for global layers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models.common import (
    apply_norm,
    dense_init,
    layer_slice,
    norm_params,
    rope_tables_for,
    scan_prefix_unroll_tail,
)
from repro.models.partitioning import constrain
from repro.models.mlp import mlp_block, mlp_params
from repro.models.moe import moe_block, moe_params


def init_base(cfg, key):
    keys = jax.random.split(key, 6)
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab
    layers = {
        "attn": attn.attn_params(cfg, keys[0], layers=L),
        "ln1": norm_params(cfg, d, layers=L),
        "ln2": norm_params(cfg, d, layers=L),
    }
    if cfg.moe is not None:
        layers["moe"] = moe_params(cfg, keys[1], layers=L)
    else:
        layers["mlp"] = mlp_params(cfg, keys[1], layers=L)
    base = {
        "embed": dense_init(keys[2], (V, d), in_axis=-1, dtype=cfg.dtype),
        "layers": layers,
        "final_norm": norm_params(cfg, d),
    }
    if not cfg.tie_embeddings:
        base["lm_head"] = dense_init(keys[3], (d, V), dtype=cfg.dtype)
    return base


def _peft_bias(pl, name, like):
    """BitFit additive bias (zero when absent)."""
    if pl and name in pl:
        return pl[name]["b"].astype(like.dtype)
    return jnp.zeros((), like.dtype)


def _layer_flags(cfg):
    return jnp.asarray(
        np.array([cfg.is_global_layer(i) for i in range(cfg.n_layers)]), bool)


def _mixed_pattern(cfg) -> bool:
    flags = [cfg.is_global_layer(i) for i in range(cfg.n_layers)]
    return any(flags) and not all(flags)


def embed_tokens(cfg, base, tokens):
    h = jnp.take(base["embed"], tokens, axis=0)
    if cfg.tie_embeddings:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    return h


def unembed(cfg, base):
    return base["embed"].T if cfg.tie_embeddings else base["lm_head"]


def _layer_tail(cfg, h, aux, lp, pl, lora_scale):
    """ln2 + MLP/MoE + residual (+BitFit bias) — the back half of a decoder
    layer once its attention output has been added to the residual."""
    hn = apply_norm(cfg, h, lp["ln2"])
    if cfg.moe is not None:
        y, aux_l = moe_block(cfg, lp["moe"], hn)
        aux = aux + aux_l
    else:
        y = mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale)
    h = constrain(h + y + _peft_bias(pl, "bias2", h), "prefill_h")
    return h, aux


def _attn_branch(cfg, lora_scale, is_global_static, rope_cs):
    def run(lp, pl, hn):
        return attn.attn_block_prefill(
            cfg, lp["attn"], hn, pl or None, lora_scale,
            is_global=is_global_static, rope_cs=rope_cs)
    return run


def _train_body(cfg, lora_scale, mixed, rope_cs):
    """One full decoder layer as a scan body — shared by ``forward`` (all
    L layers) and ``split_forward`` (the first L-1). ``rope_cs`` is the
    forward-wide rope table (see ``common.rope_tables``)."""
    def body(carry, xs):
        h, aux = carry
        lp, pl, is_global = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        if mixed:
            a = jax.lax.cond(
                is_global,
                lambda: _attn_branch(cfg, lora_scale, True, rope_cs)(
                    lp, pl, hn),
                lambda: _attn_branch(cfg, lora_scale, False, rope_cs)(
                    lp, pl, hn))
        else:
            a = _attn_branch(cfg, lora_scale, bool(cfg.is_global_layer(0)),
                             rope_cs)(lp, pl, hn)
        h = h + a + _peft_bias(pl, "bias1", h)
        h, aux = _layer_tail(cfg, h, aux, lp, pl, lora_scale)
        return (h, aux), None
    return body


def _embed(cfg, base, tokens, extra_embeds):
    h = embed_tokens(cfg, base, tokens)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    return h




def forward_scanned(cfg, base, peft, tokens, extra_embeds=None,
                    lora_scale=1.0):
    """Reference train forward: ONE ``lax.scan`` over all L layers (the
    pre-split-refactor structure). ``forward`` below is the split
    composition — numerically it applies identical per-layer ops, but XLA
    fuses the unrolled final layer differently from a scan iteration, so
    the two agree to float-ulp (tests assert allclose), while ``forward``
    vs the registry split losses agree BITWISE (same traced program)."""
    h = _embed(cfg, base, tokens, extra_embeds)
    flags = _layer_flags(cfg)
    peft_layers = (peft or {}).get("layers", {})
    body = _train_body(cfg, lora_scale, _mixed_pattern(cfg),
                       rope_tables_for(cfg, h))
    (h, aux), _ = jax.lax.scan(
        body, (h, jnp.float32(0.0)), (base["layers"], peft_layers, flags))
    h = apply_norm(cfg, h, base["final_norm"])
    return h, aux / cfg.n_layers


def forward(cfg, base, peft, tokens, extra_embeds=None, lora_scale=1.0):
    """Full train forward pass -> (hidden (B,S,D), aux_loss), structured as
    the split composition (the tentpole refactor): scan the first L-1
    layers, unroll the final layer around its attention mixer
    (``split_forward`` -> ``mixer_site`` -> ``split_post``). The registry
    split losses expose exactly these pieces, so the plain loss closures
    and the ``SplitLoss`` objects trace identical programs.

    ``extra_embeds`` (B,P,D) are frontend-stub embeddings (VLM patches /
    early-fusion image tokens) prepended to the token embeddings.
    """
    site_args, ctx = split_forward(cfg, base, peft, tokens,
                                   extra_embeds=extra_embeds,
                                   lora_scale=lora_scale)
    y = mixer_site(cfg, site_args)
    return split_post(cfg, base, y, ctx, peft, lora_scale=lora_scale)


# ---------------------------------------------------------------------------
# Split forward: scan L-1 layers, unroll the final layer up to its mixer
# ---------------------------------------------------------------------------

def split_site(cfg):
    """Site kind + static kwargs of the final layer's sequence mixer."""
    is_global = bool(cfg.is_global_layer(cfg.n_layers - 1))
    return "swa", {"window": None if is_global else cfg.window}


def mixer_site(cfg, site_args):
    """The final layer's mixer on the split site args (backend-gated; see
    ``attention.swa_mixer_site``)."""
    return attn.swa_mixer_site(cfg, site_args, split_site(cfg)[1]["window"])


def split_forward(cfg, base, peft, tokens, extra_embeds=None, lora_scale=1.0):
    """Split (train) forward: scan the first L-1 layers, unroll the final
    layer up to its attention mixer. Returns (site_args, ctx) with
    site_args = (q, k, v) in kernel layout ((B,H,S,hd) / (B,KV,S,hd)) and
    ctx carrying the residual stream + MoE aux entering the final mixer.
    ``split_post`` finishes the layer; the pre->site->post composition is
    bitwise-identical to ``forward``."""
    h = _embed(cfg, base, tokens, extra_embeds)
    flags = _layer_flags(cfg)
    peft_layers = (peft or {}).get("layers", {})
    rope_cs = rope_tables_for(cfg, h)
    body = _train_body(cfg, lora_scale, _mixed_pattern(cfg), rope_cs)
    (h, aux), (lp, pl, _) = scan_prefix_unroll_tail(
        body, (h, jnp.float32(0.0)), (base["layers"], peft_layers, flags),
        cfg.n_layers)
    hn = apply_norm(cfg, h, lp["ln1"])
    q, k, v = attn.attn_site_qkv(cfg, lp["attn"], hn, pl or None, lora_scale,
                                 rope_cs=rope_cs)
    site_args = (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                 v.transpose(0, 2, 1, 3))
    return site_args, {"h": h, "aux": aux}


def split_post(cfg, base, y, ctx, peft, lora_scale=1.0):
    """Post-head of the split forward: final mixer output (B,H,S,hd) ->
    (final hidden, aux). Reversed ONCE by the fused estimator (jax.vjp),
    so its stored activations are O(one layer + head)."""
    lp = layer_slice(base["layers"], cfg.n_layers - 1)
    pl = layer_slice((peft or {}).get("layers", {}), cfg.n_layers - 1)
    h, aux = ctx["h"], ctx["aux"]
    a = attn.attn_finish(cfg, lp["attn"], y.transpose(0, 2, 1, 3),
                         pl or None, lora_scale)
    h = h + a + _peft_bias(pl, "bias1", h)
    h, aux = _layer_tail(cfg, h, aux, lp, pl, lora_scale)
    h = apply_norm(cfg, h, base["final_norm"])
    return h, aux / cfg.n_layers


# ---------------------------------------------------------------------------
# Fused prefill
# ---------------------------------------------------------------------------

def prefill(cfg, base, peft, cache, tokens, lora_scale=1.0):
    """Fused prompt ingestion: ONE chunked-attention pass over the whole
    prompt instead of P decode_step calls. Returns (last-token logits (B,V),
    cache) with the cache holding exactly the rows the token-by-token decode
    loop would have written (ring-buffer aware: when the prompt is longer
    than a sliding-window cache, each slot keeps its LAST occupant).

    int8-KV caches are supported (rows are quantized on insert) but NOT
    decode-loop equivalent: the loop attends to quantized history during
    ingestion while this pass attends to exact K/V — launch/serve.py falls
    back to the token loop for quantized caches.
    """
    B, P = tokens.shape
    h = embed_tokens(cfg, base, tokens)
    flags = _layer_flags(cfg)
    mixed = _mixed_pattern(cfg)
    peft_layers = (peft or {}).get("layers", {})

    def attn_branch(is_global_static):
        def run(lp, pl, hn):
            return attn.attn_block_prefill_kv(
                cfg, lp["attn"], hn, pl or None, lora_scale,
                is_global=is_global_static)
        return run

    def body(h, xs):
        lp, pl, is_global = xs
        hn = apply_norm(cfg, h, lp["ln1"])
        if mixed:
            a, k, v = jax.lax.cond(is_global,
                                   lambda: attn_branch(True)(lp, pl, hn),
                                   lambda: attn_branch(False)(lp, pl, hn))
        else:
            a, k, v = attn_branch(bool(cfg.is_global_layer(0)))(lp, pl, hn)
        # NOTE: no BitFit _peft_bias here — decode_step does not apply the
        # bias1/bias2 residual biases, and prefill must match the
        # token-by-token decode loop exactly (tests/test_serve_prefill.py)
        h = h + a
        hn = apply_norm(cfg, h, lp["ln2"])
        if cfg.moe is not None:
            y, _ = moe_block(cfg, lp["moe"], hn)
        else:
            y = mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale)
        return h + y, (k, v)

    h, (ks, vs) = jax.lax.scan(body, h, (base["layers"], peft_layers, flags))
    h = apply_norm(cfg, h, base["final_norm"])
    logits = (h[:, -1, :] @ unembed(cfg, base)).astype(jnp.float32)

    # cache insert: slot s <- the LAST prompt position p < P with p % Sc == s
    # (identity placement while P <= Sc; ring semantics beyond)
    Sc = cache["k"].shape[2]
    slots = np.arange(min(P, Sc))
    last_pos = slots + Sc * ((P - 1 - slots) // Sc)   # static (P, Sc known)
    gather = jnp.asarray(last_pos, jnp.int32)
    k_rows = ks[:, :, gather]                          # (L,B,min(P,Sc),KV,hd)
    v_rows = vs[:, :, gather]
    quantized = "k_scale" in cache
    if quantized:
        kq, ksc = _quantize_kv(k_rows)
        vq, vsc = _quantize_kv(v_rows)
        cache = {
            "k": cache["k"].at[:, :, : len(slots)].set(kq),
            "v": cache["v"].at[:, :, : len(slots)].set(vq),
            "k_scale": cache["k_scale"].at[:, :, : len(slots)].set(
                ksc.astype(cache["k_scale"].dtype)),
            "v_scale": cache["v_scale"].at[:, :, : len(slots)].set(
                vsc.astype(cache["v_scale"].dtype)),
        }
    else:
        cache = {
            "k": cache["k"].at[:, :, : len(slots)].set(
                k_rows.astype(cache["k"].dtype)),
            "v": cache["v"].at[:, :, : len(slots)].set(
                v_rows.astype(cache["v"].dtype)),
        }
    return logits, cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def cache_len(cfg, seq_len: int) -> int:
    if cfg.attn_pattern == "swa":
        return min(cfg.window, seq_len)
    return seq_len


def init_cache(cfg, batch: int, seq_len: int, kv_int8: bool = False):
    """KV cache. kv_int8=True stores int8 entries + per-(token,head) bf16
    absmax scales — halves cache HBM (beyond-paper; EXPERIMENTS §Perf-2)."""
    Sc = cache_len(cfg, seq_len)
    shape = (cfg.n_layers, batch, Sc, cfg.n_kv_heads, cfg.hd)
    if kv_int8:
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, cfg.dtype),
                "v_scale": jnp.zeros(sshape, cfg.dtype)}
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def _quantize_kv(x):
    """x: (..., hd) -> (int8, scale (...,1)). Per-vector absmax."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def decode_step(cfg, base, peft, cache, token, pos, lora_scale=1.0):
    """token: (B,1) int32; pos: scalar int32 or (B,) vector (continuous
    batching: each row decodes at its own position and writes its own ring
    slot). Returns (logits (B,V), cache).

    Mixed local:global stacks use a traced per-layer window length instead
    of lax.cond — the masks differ, the computation (and hence the SPMD
    sharding) stays uniform across the layer scan.

    The KV cache is NOT threaded through scan xs/ys (that double-buffers the
    multi-GB arrays); the layer scan reads the loop-invariant cache via
    dynamic indexing and emits only the new-token K/V rows, inserted with
    one fused in-place write after the scan (§Perf-2)."""
    h = embed_tokens(cfg, base, token)
    flags = _layer_flags(cfg)
    mixed = _mixed_pattern(cfg)
    peft_layers = (peft or {}).get("layers", {})
    Sc = cache["k"].shape[2]
    window_lens = jnp.where(flags, jnp.int32(2**30), jnp.int32(cfg.window))
    cache_k_all, cache_v_all = cache["k"], cache["v"]
    quantized = "k_scale" in cache

    def body(carry, xs):
        h, li = carry
        lp, pl, wlen = xs
        kc = jax.lax.dynamic_index_in_dim(cache_k_all, li, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(cache_v_all, li, 0, keepdims=False)
        if quantized:
            ks = jax.lax.dynamic_index_in_dim(cache["k_scale"], li, 0,
                                              keepdims=False)
            vs = jax.lax.dynamic_index_in_dim(cache["v_scale"], li, 0,
                                              keepdims=False)
            kc = _dequantize_kv(kc, ks, cfg.dtype)
            vc = _dequantize_kv(vc, vs, cfg.dtype)
        hn = apply_norm(cfg, h, lp["ln1"])
        if mixed:
            a, k_new, v_new = attn.attn_block_decode_nocopy(
                cfg, lp["attn"], hn, pl or None, lora_scale, kc, vc, pos,
                window_len=wlen)
        else:
            a, k_new, v_new = attn.attn_block_decode_nocopy(
                cfg, lp["attn"], hn, pl or None, lora_scale, kc, vc, pos,
                is_global=bool(cfg.is_global_layer(0)))
        h = h + a
        hn = apply_norm(cfg, h, lp["ln2"])
        if cfg.moe is not None:
            y, _ = moe_block(cfg, lp["moe"], hn)
        else:
            y = mlp_block(cfg, lp["mlp"], hn, pl or None, lora_scale)
        return (h + y, li + 1), (k_new, v_new)

    (h, _), (k_news, v_news) = jax.lax.scan(
        body, (h, jnp.int32(0)),
        (base["layers"], peft_layers, window_lens))
    h = apply_norm(cfg, h, base["final_norm"])
    logits = (h[:, 0, :] @ unembed(cfg, base)).astype(jnp.float32)
    # single fused insert of all layers' new-token K/V (see
    # attn_block_decode_nocopy): one in-place row write instead of scanning
    # the multi-GB cache through ys
    slot = pos % Sc
    if jnp.ndim(pos) == 0:
        if quantized:
            kq, ksc = _quantize_kv(k_news)
            vq, vsc = _quantize_kv(v_news)
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=2),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=2),
                "k_scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["k_scale"], ksc.astype(cache["k_scale"].dtype), slot, axis=2),
                "v_scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["v_scale"], vsc.astype(cache["v_scale"].dtype), slot, axis=2),
            }
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k_news.astype(cache["k"].dtype), slot, axis=2),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v_news.astype(cache["v"].dtype), slot, axis=2),
            }
    else:
        rows = jnp.arange(k_news.shape[1])
        if quantized:
            kq, ksc = _quantize_kv(k_news)
            vq, vsc = _quantize_kv(v_news)
            new_cache = {
                "k": cache["k"].at[:, rows, slot].set(kq[:, :, 0]),
                "v": cache["v"].at[:, rows, slot].set(vq[:, :, 0]),
                "k_scale": cache["k_scale"].at[:, rows, slot].set(
                    ksc[:, :, 0].astype(cache["k_scale"].dtype)),
                "v_scale": cache["v_scale"].at[:, rows, slot].set(
                    vsc[:, :, 0].astype(cache["v_scale"].dtype)),
            }
        else:
            new_cache = {
                "k": cache["k"].at[:, rows, slot].set(
                    k_news[:, :, 0].astype(cache["k"].dtype)),
                "v": cache["v"].at[:, rows, slot].set(
                    v_news[:, :, 0].astype(cache["v"].dtype)),
            }
    return logits, new_cache
