"""Mixture-of-Experts block: top-k routing with GShard-style capacity
dispatch, token-chunked so the (tokens, E, C) dispatch tensor stays
VMEM/HBM-bounded at 32k-token prefills.

Experts are sharded over the `model` mesh axis (expert parallelism); the
dispatch/combine einsums become all-to-alls under GSPMD. Router auxiliary
load-balancing loss follows Switch Transformer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init


def moe_params(cfg, key, layers=None):
    d = cfg.d_model
    m = cfg.moe
    f = m.d_expert
    keys = jax.random.split(key, 5)
    stack = (layers,) if layers else ()
    p = {
        "router": dense_init(keys[0], stack + (d, m.n_experts), dtype=jnp.float32),
        "wi": dense_init(keys[1], stack + (m.n_experts, d, f), dtype=cfg.dtype),
        "wg": dense_init(keys[2], stack + (m.n_experts, d, f), dtype=cfg.dtype),
        "wd": dense_init(keys[3], stack + (m.n_experts, f, d), dtype=cfg.dtype),
    }
    if m.n_shared_experts:
        sf = f * m.n_shared_experts
        sk = jax.random.split(keys[4], 3)
        p["shared"] = {
            "wi": dense_init(sk[0], stack + (d, sf), dtype=cfg.dtype),
            "wg": dense_init(sk[1], stack + (d, sf), dtype=cfg.dtype),
            "wd": dense_init(sk[2], stack + (sf, d), dtype=cfg.dtype),
        }
    return p


def _capacity(n_tokens: int, m) -> int:
    return max(4, int(n_tokens * m.top_k * m.capacity_factor / m.n_experts))


def _dispatch_chunk(cfg, p, chunk):
    """chunk: (T, D) -> (out: (T, D), aux_loss scalar).

    Capacity-based top-k dispatch. Tokens above an expert's capacity are
    dropped for that expert (standard GShard semantics).
    """
    m = cfg.moe
    T, D = chunk.shape
    E = m.n_experts
    C = _capacity(T, m)

    logits = (chunk.astype(jnp.float32) @ p["router"])           # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topg, topi = jax.lax.top_k(gates, m.top_k)                   # (T, k)
    topg = topg / jnp.maximum(topg.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e (frac_tokens_e * frac_prob_e)
    me = gates.mean(0)
    ce = jnp.zeros(E).at[topi[:, 0]].add(1.0) / T
    aux = E * jnp.sum(me * ce)

    counts = jnp.zeros((E,), jnp.int32)
    dispatch = jnp.zeros((T, E, C), chunk.dtype)
    combine = jnp.zeros((T, E, C), jnp.float32)
    for j in range(m.top_k):                                      # static k
        onehot = jax.nn.one_hot(topi[:, j], E, dtype=jnp.int32)   # (T, E)
        pos = jnp.cumsum(onehot, axis=0) - 1 + counts[None, :]    # (T, E)
        counts = counts + onehot.sum(0)
        keep = (onehot == 1) & (pos < C)
        posc = jnp.clip(pos, 0, C - 1)
        d_j = keep[..., None] & (jax.nn.one_hot(posc, C, dtype=jnp.int32) == 1)
        dispatch = dispatch + d_j.astype(chunk.dtype)
        combine = combine + d_j.astype(jnp.float32) * topg[:, j][:, None, None]

    xe = jnp.einsum("tec,td->ecd", dispatch, chunk)               # (E, C, D)
    up = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    gate = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    h = activation(cfg, gate) * up
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"])                   # (E, C, D)
    out = jnp.einsum("tec,ecd->td", combine.astype(chunk.dtype), ye)

    if m.n_shared_experts:
        s = p["shared"]
        sh = activation(cfg, chunk @ s["wg"]) * (chunk @ s["wi"])
        out = out + sh @ s["wd"]
    return out, aux


def moe_block(cfg, p, x):
    """x: (B, S, D) -> (out, aux_loss). Chunked over tokens."""
    B, S, D = x.shape
    tokens = x.reshape(B * S, D)
    T = tokens.shape[0]
    chunk = min(cfg.moe.router_chunk, T)
    n = T // chunk
    if n * chunk != T:            # pad to a whole number of chunks
        pad = n * chunk + chunk - T
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
        n += 1
    else:
        pad = 0

    if n == 1:
        out, aux = _dispatch_chunk(cfg, p, tokens)
    else:
        chunks = tokens.reshape(n, chunk, D)

        def body(acc, tc):
            out_c, aux_c = _dispatch_chunk(cfg, p, tc)
            return acc + aux_c, out_c

        aux, outs = jax.lax.scan(body, jnp.float32(0.0), chunks)
        out = outs.reshape(n * chunk, D)
        aux = aux / n
    del pad  # padded tail (if any) is dropped by the slice below
    return out[: B * S].reshape(B, S, D), aux
