"""Recurrent sequence-mixing blocks: RWKV6 ("Finch") and Mamba2.

Both are implemented as exact sequential recurrences with ``lax.scan`` over
time — O(1) state, which is what makes the long_500k decode shape lower for
these families. The TPU fast path for the RWKV6 recurrence is the
kernels/wkv6_scan Pallas kernel (chunk-parallel inside VMEM); this module is
the semantics-defining reference the kernel is tested against.

RWKV6 (data-dependent decay, the paper's headline Finch feature):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T        (per head, S in R^{hd x hd})
    y_t = r_t^T (S_{t-1} + (u * k_t) v_t^T)
with w_t = exp(-exp(w0 + lora(x_t))) in (0,1) elementwise.

Mamba2 (scalar-per-head decay):
    h_t = exp(-softplus(a) * dt_t) h_{t-1} + dt_t * (x_t outer B_t)
    y_t = h_t C_t + D * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.mamba2_scan.ref import mamba2_scan_ref
from repro.models.common import dense_init, maybe_lora, proj


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

def rwkv6_params(cfg, key, layers=None):
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    H = d // hd
    stack = (layers,) if layers else ()
    names = ["wr", "wk", "wv", "wg", "wo", "w_lora_a", "w_lora_b",
             "mu", "w0", "u", "ln_w", "ln_b",
             "cm_wr", "cm_wk", "cm_wv"]
    keys = dict(zip(names, jax.random.split(key, len(names))))
    r_decay = 64  # decay-LoRA rank (Finch uses low-rank data-dependent decay)
    p = {
        # time-mix projections
        "wr": dense_init(keys["wr"], stack + (d, d), dtype=cfg.dtype),
        "wk": dense_init(keys["wk"], stack + (d, d), dtype=cfg.dtype),
        "wv": dense_init(keys["wv"], stack + (d, d), dtype=cfg.dtype),
        "wg": dense_init(keys["wg"], stack + (d, d), dtype=cfg.dtype),
        "wo": dense_init(keys["wo"], stack + (d, d), dtype=cfg.dtype),
        # data-dependent decay (low-rank)
        "w_lora_a": dense_init(keys["w_lora_a"], stack + (d, r_decay), dtype=cfg.dtype),
        "w_lora_b": dense_init(keys["w_lora_b"], stack + (r_decay, d), dtype=cfg.dtype) * 0.1,
        "w0": jnp.zeros(stack + (d,), jnp.float32) + 0.5,
        # token-shift interpolation factors per projection (r,k,v,g,w)
        "mu": jax.random.uniform(keys["mu"], stack + (5, d), jnp.float32),
        # per-head bonus
        "u": dense_init(keys["u"], stack + (H, hd), dtype=jnp.float32),
        # group norm over heads
        "ln_w": jnp.ones(stack + (d,), jnp.float32),
        "ln_b": jnp.zeros(stack + (d,), jnp.float32),
        # channel-mix
        "cm_wr": dense_init(keys["cm_wr"], stack + (d, d), dtype=cfg.dtype),
        "cm_wk": dense_init(keys["cm_wk"], stack + (d, cfg.d_ff), dtype=cfg.dtype),
        "cm_wv": dense_init(keys["cm_wv"], stack + (cfg.d_ff, d), dtype=cfg.dtype),
    }
    return p


def _token_shift(x, prev):
    """Shift right by one along S; ``prev`` is the carry from decode (B,1,D)
    or zeros for a fresh sequence."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv6_recurrence(r, k, v, w, u, state):
    """Sequential WKV scan. r,k,v,w: (B,S,H,hd); u: (H,hd);
    state: (B,H,hd,hd). Returns (y: (B,S,H,hd), new_state)."""
    def step(s, xs):
        rt, kt, vt, wt = xs                                   # (B,H,hd)
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)              # (B,H,hd,hd)
        yt = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, yt

    xs = jax.tree.map(lambda a: a.transpose(1, 0, 2, 3), (r, k, v, w))  # (S,B,H,hd)
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def rwkv6_site_args(cfg, p, x, peft_layer=None, lora_scale=1.0,
                    shift_prev=None):
    """Time-mix projections up to the WKV recurrence: the mixer-site
    operands ((r, k, v, w) (B,S,H,hd) fp32 + u (H,hd)) plus the gate stream
    ``g`` the post-mixer tail needs. Shared by ``rwkv6_time_mix`` and the
    rwkv split forward (the recurrence is the declared fused-contraction
    site there)."""
    B, S, D = x.shape
    hd = cfg.ssm.head_dim
    H = D // hd
    prev = shift_prev if shift_prev is not None else jnp.zeros((B, 1, D), x.dtype)
    xs = _token_shift(x, prev)
    mu = p["mu"]                                             # (5, D)

    def lerp(i):
        return (x + (xs - x) * mu[i]).astype(x.dtype)

    r = proj(lerp(0), p["wr"], lora=maybe_lora(peft_layer, "wr"), lora_scale=lora_scale)
    k = proj(lerp(1), p["wk"], lora=maybe_lora(peft_layer, "wk"), lora_scale=lora_scale)
    v = proj(lerp(2), p["wv"], lora=maybe_lora(peft_layer, "wv"), lora_scale=lora_scale)
    g = proj(lerp(3), p["wg"], lora=maybe_lora(peft_layer, "wg"), lora_scale=lora_scale)
    # data-dependent decay in fp32, in (0,1)
    dw = (lerp(4) @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(p["w0"] + dw.astype(jnp.float32)))   # (B,S,D)

    hsplit = lambda t: t.reshape(B, S, H, hd)
    return (hsplit(r).astype(jnp.float32), hsplit(k).astype(jnp.float32),
            hsplit(v).astype(jnp.float32), hsplit(w), p["u"]), g


def rwkv6_finish(cfg, p, y, g, out_dtype, peft_layer=None, lora_scale=1.0):
    """Group-norm + gate + output projection on the mixer output y
    ((B,S,H,hd) fp32) — the time-mix tail after the WKV recurrence (the
    split forwards' post side)."""
    B, S, H, hd = y.shape
    D = H * hd
    # group-norm per head then gate
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = ((y - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, D)
    y = (y * p["ln_w"] + p["ln_b"]).astype(out_dtype) * jax.nn.silu(g)
    return proj(y, p["wo"], lora=maybe_lora(peft_layer, "wo"),
                lora_scale=lora_scale)


def wkv6_mixer_site(args):
    """Fresh-state WKV6 recurrence on the ``rwkv6_site_args`` operands with
    the model's backend gating: the dispatched op (multi-tangent kernels
    inside the estimator's forward-AD region) on kernel backends, the exact
    sequential jnp recurrence otherwise. The rwkv split forward declares
    this call as its fused-contraction site."""
    r, k, v, w, u = args
    if dispatch.use_kernel_mixers():
        return dispatch.wkv6_mix(r, k, v, w, u)
    B, _, H, hd = r.shape
    state = jnp.zeros((B, H, hd, hd), jnp.float32)
    return wkv6_recurrence(r, k, v, w, u, state)[0]


def rwkv6_time_mix(cfg, p, x, peft_layer=None, lora_scale=1.0, state=None,
                   shift_prev=None):
    """x: (B,S,D). state: (B,H,hd,hd) or None (zeros). Returns
    (out, new_state, last_x). On the dispatched forward-gradient fast path
    (fresh state inside ``dispatch.use_kernel_mixers()``) new_state is None —
    the estimator's loss closures never consume it."""
    B, S, D = x.shape
    hd = cfg.ssm.head_dim
    H = D // hd
    (r, k, v, w, u), g = rwkv6_site_args(cfg, p, x, peft_layer, lora_scale,
                                         shift_prev)
    if state is None and dispatch.use_kernel_mixers():
        # forward-gradient fast path (fresh state): the dispatched op lowers
        # K stacked tangents to the multi-tangent wkv6 Pallas kernel — one
        # primal state walk for all K perturbations. The estimator's loss
        # closures discard the carried state, so none is produced here.
        y = dispatch.wkv6_mix(r, k, v, w, u)
        state = None
    else:
        if state is None:
            state = jnp.zeros((B, H, hd, hd), jnp.float32)
        y, state = wkv6_recurrence(r, k, v, w, u, state)
    out = rwkv6_finish(cfg, p, y, g, x.dtype, peft_layer, lora_scale)
    return out, state, x[:, -1:, :]


def rwkv6_channel_mix(cfg, p, x, shift_prev=None):
    B, S, D = x.shape
    prev = shift_prev if shift_prev is not None else jnp.zeros((B, 1, D), x.dtype)
    xs = _token_shift(x, prev)
    r = jax.nn.sigmoid(x @ p["cm_wr"])
    k = jnp.square(jax.nn.relu(xs @ p["cm_wk"]))
    return (r * (k @ p["cm_wv"])).astype(x.dtype), x[:, -1:, :]


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------

def mamba2_params(cfg, key, layers=None):
    d = cfg.d_model
    s = cfg.ssm
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    N = s.state_dim
    names = ["in_proj", "conv", "dt", "wb", "wc", "out"]
    keys = dict(zip(names, jax.random.split(key, len(names))))
    stack = (layers,) if layers else ()
    return {
        "in_proj": dense_init(keys["in_proj"], stack + (d, 2 * d_inner), dtype=cfg.dtype),
        "conv_w": dense_init(keys["conv"], stack + (s.conv_kernel, d_inner), dtype=cfg.dtype),
        "w_dt": dense_init(keys["dt"], stack + (d, H), dtype=cfg.dtype),
        "dt_bias": jnp.zeros(stack + (H,), jnp.float32),
        "w_b": dense_init(keys["wb"], stack + (d, N), dtype=cfg.dtype),
        "w_c": dense_init(keys["wc"], stack + (d, N), dtype=cfg.dtype),
        "a_log": jnp.zeros(stack + (H,), jnp.float32),
        "d_skip": jnp.ones(stack + (H,), jnp.float32),
        "out_proj": dense_init(keys["out"], stack + (d_inner, d), dtype=cfg.dtype),
    }


def _causal_depthwise_conv(x, w, conv_state=None):
    """x: (B,S,C), w: (K,C). Returns (y, new_conv_state (B,K-1,C))."""
    K = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)             # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return y, xp[:, -(K - 1):]


def mamba2_preamble(cfg, p, x, peft_layer=None, lora_scale=1.0,
                    conv_state=None):
    """in_proj + depthwise conv + dt/B/C/decay streams — ``mamba2_mix`` up
    to the state recurrence. Returns (xh, dt, bmat, cmat, decay, z,
    conv_state). Shared by ``mamba2_mix`` and the hybrid split forward (the
    recurrence over the dt-premultiplied input ``xh * dt`` is the declared
    fused-contraction site there)."""
    B, S, D = x.shape
    s = cfg.ssm
    d_inner = s.expand * D
    hd = s.head_dim
    H = d_inner // hd

    zx = proj(x, p["in_proj"], lora=maybe_lora(peft_layer, "in_proj"),
              lora_scale=lora_scale)
    z, xb = jnp.split(zx, 2, axis=-1)
    xb, conv_state = _causal_depthwise_conv(xb, p["conv_w"], conv_state)
    xb = jax.nn.silu(xb)

    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])                                   # (H,)
    decay = jnp.exp(a[None, None] * dt)                        # (B,S,H)
    bmat = (x @ p["w_b"]).astype(jnp.float32)                  # (B,S,N)
    cmat = (x @ p["w_c"]).astype(jnp.float32)                  # (B,S,N)
    xh = xb.reshape(B, S, H, hd).astype(jnp.float32)
    return xh, dt, bmat, cmat, decay, z, conv_state


def mamba2_finish(cfg, p, y, z, xh, out_dtype, peft_layer=None,
                  lora_scale=1.0):
    """Skip connection + gate + output projection on the mixer output y
    ((B,S,H,hd) fp32) — the mamba2 tail after the state recurrence (the
    split forwards' post side)."""
    B, S, H, hd = y.shape
    d_inner = H * hd
    y = y + p["d_skip"][None, None, :, None] * xh
    y = (y.reshape(B, S, d_inner) * jax.nn.silu(z.astype(jnp.float32))).astype(out_dtype)
    return proj(y, p["out_proj"], lora=maybe_lora(peft_layer, "out_proj"),
                lora_scale=lora_scale)


def mamba2_mixer_site(args):
    """Fresh-state Mamba2 recurrence on (xdt, bmat, cmat, decay) with the
    model's backend gating: the dispatched op on kernel backends, the exact
    jnp scan mirror otherwise (the dt hoist is an exact elementwise
    identity — bit-identical to the in-scan multiply). The hybrid split
    forward declares this call as its fused-contraction site when the final
    layer's last mixer is the mamba2 recurrence."""
    xdt, bmat, cmat, decay = args
    if dispatch.use_kernel_mixers():
        return dispatch.mamba2_mix(xdt, bmat, cmat, decay)
    return mamba2_scan_ref(xdt, bmat, cmat, decay)[0]


def mamba2_mix(cfg, p, x, peft_layer=None, lora_scale=1.0, state=None,
               conv_state=None):
    """x: (B,S,D). state: (B,H,hd,N) or None (zeros). Returns
    (out, state, conv_state). On the dispatched forward-gradient fast path
    (fresh state inside ``dispatch.use_kernel_mixers()``) state is None —
    the estimator's loss closures never consume it."""
    B, S, D = x.shape
    s = cfg.ssm
    d_inner = s.expand * D
    hd = s.head_dim
    H = d_inner // hd
    N = s.state_dim

    xh, dt, bmat, cmat, decay, z, conv_state = mamba2_preamble(
        cfg, p, x, peft_layer, lora_scale, conv_state)

    if state is None and dispatch.use_kernel_mixers():
        # forward-gradient fast path (fresh state): the dispatched op lowers
        # K stacked tangents to the multi-tangent mamba2 Pallas kernel — one
        # primal state walk for all K perturbations. The dt multiplication
        # is hoisted out of the scan (exact elementwise identity); the
        # estimator's loss closures discard the carried state, so none is
        # produced here.
        y = dispatch.mamba2_mix(xh * dt[..., None], bmat, cmat, decay)
        state = None
    else:
        if state is None:
            state = jnp.zeros((B, H, hd, N), jnp.float32)

        def step(h, xs):
            xt, bt, ct, dct, dtt = xs    # (B,H,hd), (B,N), (B,N), (B,H), (B,H)
            upd = jnp.einsum("bhi,bn->bhin", xt * dtt[..., None], bt)
            h = dct[..., None, None] * h + upd
            yt = jnp.einsum("bhin,bn->bhi", h, ct)
            return h, yt

        xs = (xh.transpose(1, 0, 2, 3), bmat.transpose(1, 0, 2),
              cmat.transpose(1, 0, 2), decay.transpose(1, 0, 2),
              dt.transpose(1, 0, 2))
        state, ys = jax.lax.scan(step, state, xs)
        y = ys.transpose(1, 0, 2, 3)                           # (B,S,H,hd)
    out = mamba2_finish(cfg, p, y, z, xh, x.dtype, peft_layer, lora_scale)
    return out, state, conv_state
