"""The rule classes: declared invariants checked against traced programs.

Six rules, each a pure function from a traced artifact (closed jaxpr or
``jax.jit(...).lower(...)`` Lowered) to ``Finding``s:

``tangent-materialization``  no pallas_call inside a fused-contraction
    trace writes a buffer as large as the (K,)+y tangent stack, and the
    site lowers to exactly one ``_mt_jvps`` contraction epilogue.
``vmem-budget``  every pallas_call's statically-computed per-grid-step
    VMEM residency fits the selected TPU generation's per-core budget.
``transpose-reachability``  a reverse-mode trace taken OUTSIDE
    ``dispatch.forward_ad_region()`` must contain NO pallas_call: the
    kernels ship no transpose rule, so reaching one under reverse-mode is
    a latent trace-time crash only convention prevented until now.
``donation``  jitted hot loops must donate their large carried buffers
    (decode caches, round-threaded state); intentional non-donation is
    waived by name with a recorded reason.
``dtype-policy``  kernel accumulators (VMEM scratch, in-kernel
    dot_generals) stay fp32, and the wire-payload dtype table matches the
    declared widths of ``fl/runtime/messages.py``.
``telemetry-neutrality``  engines built with telemetry enabled vs disabled
    must lower every jit to IDENTICAL text — the repro.obs contract is
    host-side recording on returned values only, so telemetry must never
    reach a traced program.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.analysis import jaxpr_walker as jw
from repro.analysis.vmem import DEFAULT_GENERATION, vmem_table

RULES = (
    "tangent-materialization",
    "vmem-budget",
    "transpose-reachability",
    "donation",
    "dtype-policy",
    "telemetry-neutrality",
)

# intentional non-donation, by entrypoint name. A waiver downgrades the
# finding to severity "info" with the recorded rationale instead of
# silencing it — ANALYSIS.json keeps the audit trail.
DONATION_WAIVERS = {
    "engine.round_step": (
        "FederationEngine.run_round borrows the caller's state; callers "
        "(reference comparisons, benches) legitimately reuse it after the "
        "round"),
    "engine.clients": (
        "wire-sim phase 1: the same state is re-read by engine.aggregate "
        "in the same round"),
    "engine.aggregate": (
        "public wire-sim API borrows caller state (see engine.round_step)"),
    "serve.tokenwise_default_decode": (
        "tokenwise_prefill's fallback decode is intentionally non-donating "
        "so callers keep using the cache they passed in"),
}


@dataclasses.dataclass
class Finding:
    rule: str
    severity: str           # "error" | "warning" | "info"
    entrypoint: str
    where: str
    message: str
    data: Dict = dataclasses.field(default_factory=dict)

    def __str__(self):
        return (f"[{self.severity:7s}] {self.rule} @ {self.entrypoint}: "
                f"{self.message} ({self.where})")


# ---------------------------------------------------------------------------
# rule 1: tangent-materialization
# ---------------------------------------------------------------------------

def check_tangent_stack(entrypoint: str, jaxpr, K: int, y_shape,
                        family: Optional[str] = None,
                        expect_epilogue: bool = True) -> List[Finding]:
    """Fused-contraction traces must lower the SITE to exactly one
    ``_mt_jvps`` contraction epilogue whose outputs are per-block partials
    — never a (K,)+y_shape tangent stack. Upstream scanned layers
    legitimately materialize their per-layer mt tangents (only the final
    mixer is epilogue-eligible), so with ``expect_epilogue`` the stack
    check targets the epilogue call(s); with ``expect_epilogue=False``
    (single-site toy traces) every pallas_call is checked."""
    out = []
    stack = jw.tangent_stack_size(K, y_shape)
    calls = (jw.family_pallas_calls(jaxpr, family) if family
             else jw.pallas_calls(jaxpr))
    scan_calls = calls
    if expect_epilogue:
        jvps = [e for e in calls if "_mt_jvps_kernel" in jw.kernel_src(e)]
        if len(jvps) != 1:
            out.append(Finding(
                "tangent-materialization", "error", entrypoint,
                family or "<site>",
                f"expected exactly ONE _mt_jvps contraction epilogue at "
                f"the site, found {len(jvps)}",
                {"n_epilogues": len(jvps), "n_site_calls": len(calls)}))
        scan_calls = jvps
    for eqn in scan_calls:
        for var in eqn.outvars:
            if var.aval.size >= stack:
                out.append(Finding(
                    "tangent-materialization", "error", entrypoint,
                    jw.kernel_src(eqn),
                    f"site kernel writes a tangent-stack-sized buffer "
                    f"{tuple(var.aval.shape)} (>= K x y = {stack} elems)",
                    {"K": K, "y_shape": list(map(int, y_shape)),
                     "out_shape": list(map(int, var.aval.shape))}))
    return out


def record_expected_stack(entrypoint: str, jaxpr, K: int, y_shape,
                          family: Optional[str] = None) -> List[Finding]:
    """The standard (non-fused) route SHOULD materialize the site tangent
    stack — recorded as an info finding so the no-stack rule is proven
    non-vacuous on every lint run (the 'teeth' check)."""
    hits = jw.tangent_stack_outputs(jaxpr, K, y_shape, family=family)
    if hits:
        return [Finding(
            "tangent-materialization", "info", entrypoint,
            jw.kernel_src(hits[0][0]),
            f"standard route materializes the (K={K},)+y tangent stack as "
            f"expected — rule has teeth", {"n_stack_outputs": len(hits)})]
    return [Finding(
        "tangent-materialization", "warning", entrypoint, family or "<site>",
        "standard route did NOT materialize a tangent stack — the fused "
        "no-stack assertion may be vacuous for this entrypoint", {})]


# ---------------------------------------------------------------------------
# rule 2: vmem-budget
# ---------------------------------------------------------------------------

def check_vmem(entrypoint: str, jaxpr,
               generation: str = DEFAULT_GENERATION) -> List[Finding]:
    out = []
    for row in vmem_table(jaxpr, generation):
        if not row["ok"]:
            out.append(Finding(
                "vmem-budget", "error", entrypoint, row["src"],
                f"per-grid-step VMEM residency {row['residency_mib']} MiB "
                f"exceeds the {generation} budget "
                f"{row['budget_bytes'] / (1 << 20):.0f} MiB", row))
    return out


def check_vmem_rows(entrypoint: str, rows: List[Dict]) -> List[Finding]:
    """Budget findings for precomputed residency rows (the representative
    per-kernel table)."""
    return [Finding(
        "vmem-budget", "error", entrypoint, row["src"],
        f"per-grid-step VMEM residency {row['residency_mib']} MiB exceeds "
        f"the {row['generation']} budget "
        f"{row['budget_bytes'] / (1 << 20):.0f} MiB", row)
        for row in rows if not row["ok"]]


# ---------------------------------------------------------------------------
# rule 3: transpose-reachability
# ---------------------------------------------------------------------------

def check_transpose_reachability(entrypoint: str,
                                 reverse_jaxpr) -> List[Finding]:
    """``reverse_jaxpr`` must be a trace taken under reverse-mode AD with a
    kernel backend selected but OUTSIDE ``forward_ad_region()`` — any
    pallas_call in it is reachable by a transpose pass that has no rule to
    apply, i.e. a latent crash."""
    return [Finding(
        "transpose-reachability", "error", entrypoint, jw.kernel_src(eqn),
        "pallas_call reachable under reverse-mode outside "
        "dispatch.forward_ad_region() — kernels have no transpose rule",
        {"kernel": jw.kernel_name(eqn)})
        for eqn in jw.pallas_calls(reverse_jaxpr)]


# ---------------------------------------------------------------------------
# rule 4: donation / aliasing
# ---------------------------------------------------------------------------

def _flat_args_info(lowered):
    import jax.tree_util as jtu
    args, kwargs = lowered.args_info
    leaves = []
    for tree in (args, kwargs):
        for path, info in jtu.tree_flatten_with_path(tree)[0]:
            leaves.append((jtu.keystr(path), info))
    return leaves


def check_donation(entrypoint: str, lowered, min_bytes: int = 1 << 20,
                   waivers: Optional[Dict[str, str]] = None) -> List[Finding]:
    """Large inputs of a jitted hot loop whose shape+dtype matches an
    output (i.e. carried state XLA could update in place) must be donated.

    ``lowered`` is ``jax.jit(f, ...).lower(*args)``; donation flags come
    from ``args_info`` and candidate aliases from ``out_info`` — no
    compile needed. A waiver for ``entrypoint`` downgrades to info."""
    waivers = DONATION_WAIVERS if waivers is None else waivers
    out_sigs = {}
    import jax
    for leaf in jax.tree_util.tree_leaves(lowered.out_info):
        sig = (tuple(leaf.shape), np.dtype(leaf.dtype))
        out_sigs[sig] = out_sigs.get(sig, 0) + 1
    findings = []
    for path, info in _flat_args_info(lowered):
        aval = info._aval
        nbytes = int(np.prod(aval.shape, dtype=np.int64)) * np.dtype(
            aval.dtype).itemsize
        sig = (tuple(aval.shape), np.dtype(aval.dtype))
        if (info.donated or nbytes < min_bytes
                or not out_sigs.get(sig)):
            continue
        waived = waivers.get(entrypoint)
        findings.append(Finding(
            "donation", "info" if waived else "error", entrypoint, path,
            (f"donation waived: {waived}" if waived else
             f"large carried buffer ({nbytes / (1 << 20):.1f} MiB, shape "
             f"{tuple(aval.shape)}) matches an output but is not donated "
             f"— add donate_argnums"),
            {"bytes": nbytes, "shape": list(map(int, aval.shape)),
             "dtype": str(aval.dtype), "waived": bool(waived)}))
    return findings


# ---------------------------------------------------------------------------
# rule 5: dtype-policy
# ---------------------------------------------------------------------------

def check_dtype_policy(entrypoint: str, jaxpr) -> List[Finding]:
    """Inside every pallas kernel body: VMEM scratch (the accumulators)
    must be fp32, and every dot_general over floating inputs must emit an
    fp32 result (``preferred_element_type`` discipline)."""
    out = []
    for eqn in jw.pallas_calls(jaxpr):
        body = eqn.params["jaxpr"]
        gm = eqn.params["grid_mapping"]
        n_scratch = int(getattr(gm, "num_scratch_operands", 0))
        for var in (body.invars[-n_scratch:] if n_scratch else []):
            if np.dtype(var.aval.dtype) != np.float32:
                out.append(Finding(
                    "dtype-policy", "error", entrypoint, jw.kernel_src(eqn),
                    f"kernel scratch accumulator is {var.aval.dtype}, "
                    f"policy requires float32",
                    {"shape": list(map(int, var.aval.shape)),
                     "dtype": str(var.aval.dtype)}))
        for inner in jw.walk_eqns(body):
            if inner.primitive.name != "dot_general":
                continue
            in_dt = np.dtype(inner.invars[0].aval.dtype)
            out_dt = np.dtype(inner.outvars[0].aval.dtype)
            if np.issubdtype(in_dt, np.floating) and out_dt != np.float32:
                out.append(Finding(
                    "dtype-policy", "error", entrypoint, jw.kernel_src(eqn),
                    f"in-kernel dot_general accumulates in {out_dt}, "
                    f"policy requires float32 accumulation",
                    {"in_dtype": str(in_dt), "out_dtype": str(out_dt)}))
    return out


# ---------------------------------------------------------------------------
# rule 6: telemetry-neutrality
# ---------------------------------------------------------------------------

def check_telemetry_neutrality(entrypoint: str, text_off: str,
                               text_on: str) -> List[Finding]:
    """Lowered texts of the same jit built with telemetry disabled vs
    enabled. Any divergence means instrumentation leaked into a traced
    program — an error; identity is recorded as an info finding so the
    rule is proven non-vacuous on every lint run."""
    if text_off == text_on:
        return [Finding(
            "telemetry-neutrality", "info", entrypoint, "<lowered>",
            "telemetry-on lowers identically to telemetry-off "
            f"({len(text_off)} chars compared)",
            {"chars": len(text_off)})]
    diff_at = next((i for i, (a, b) in enumerate(
        zip(text_off.splitlines(), text_on.splitlines())) if a != b),
        min(len(text_off.splitlines()), len(text_on.splitlines())))
    return [Finding(
        "telemetry-neutrality", "error", entrypoint, f"line {diff_at + 1}",
        "telemetry-enabled build lowers DIFFERENTLY from telemetry-off — "
        "instrumentation reached the traced program",
        {"first_diff_line": diff_at + 1,
         "len_off": len(text_off), "len_on": len(text_on)})]


def check_wire_dtypes(entrypoint: str = "wire.messages") -> List[Finding]:
    """The wire-payload dtype table must carry the widths its names
    declare (fp32=4B, fp16/bf16=2B) and round-trip through
    ``wire_dtype``."""
    from repro.fl.runtime import messages
    declared = {"fp32": 4, "fp16": 2, "bf16": 2}
    out = []
    for name, width in declared.items():
        if name not in messages.WIRE_DTYPES:
            # bf16 is gated on ml_dtypes being importable — its absence is
            # a recorded degradation, not a policy violation
            sev = "info" if name == "bf16" else "error"
            out.append(Finding(
                "dtype-policy", sev, entrypoint, f"WIRE_DTYPES[{name}]",
                f"wire dtype {name!r} unavailable in WIRE_DTYPES", {}))
            continue
        dt = np.dtype(messages.WIRE_DTYPES[name])
        if dt.itemsize != width:
            out.append(Finding(
                "dtype-policy", "error", entrypoint, f"WIRE_DTYPES[{name}]",
                f"wire dtype {name!r} is {dt} ({dt.itemsize}B), declared "
                f"width is {width}B", {"dtype": str(dt)}))
        if np.dtype(messages.wire_dtype(name)) != dt:
            out.append(Finding(
                "dtype-policy", "error", entrypoint, f"wire_dtype({name})",
                f"wire_dtype({name!r}) does not round-trip WIRE_DTYPES", {}))
    return out
