"""Static per-grid-step VMEM residency model for the Pallas kernels.

A ``pallas_call`` eqn carries everything needed to bound its on-chip
footprint WITHOUT compiling for TPU: the kernel body jaxpr's invars are
``AbstractMemoryRef``s — first the per-grid-step operand/output blocks
(shapes fixed by the BlockSpecs), then the VMEM scratch allocations
(``pltpu.VMEM`` shapes: the tangent accumulators and jvp-partial buffers
ROADMAP item 6 calls unmeasured). Per-grid-step residency is then

    residency = 2 * (operand + output block bytes) + scratch bytes

— the factor 2 because the Pallas pipeline double-buffers block operands
(the next grid step's copies overlap the current compute), while scratch
persists unbuffered across the grid. This is an upper-bound model (Mosaic
may skip double-buffering for grid-invariant blocks), which is exactly
what a budget gate wants.

Budgets are per-core VMEM (~16 MB on current TPU generations, per the
Pallas guide); the lint compares every kernel's residency against the
selected generation's budget and ``ANALYSIS.json`` records the table.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.analysis.jaxpr_walker import kernel_name, kernel_src, pallas_calls

MIB = 1 << 20

# per-generation usable VMEM per core (the Pallas TPU guide's ~16 MB/core;
# kept as a table so future generations with bigger VMEM slot in here)
VMEM_BYTES = {
    "v4": 16 * MIB,
    "v5e": 16 * MIB,
    "v5p": 16 * MIB,
}
DEFAULT_GENERATION = "v5e"


def _ref_bytes(var) -> int:
    aval = var.aval
    return int(np.prod(aval.shape, dtype=np.int64)) * np.dtype(
        aval.dtype).itemsize


def _is_scratch(var) -> bool:
    # operand/output block refs carry memory_space None; explicit scratch
    # allocations are tagged 'vmem' (empirically stable on the pinned jax)
    return "vmem" in str(getattr(var.aval, "memory_space", "")).lower()


def kernel_vmem(eqn, generation: str = DEFAULT_GENERATION) -> Dict:
    """One residency-table row for a pallas_call eqn."""
    gm = eqn.params["grid_mapping"]
    body = eqn.params["jaxpr"]
    n_scratch = int(getattr(gm, "num_scratch_operands", 0))
    invars = list(body.invars)
    if n_scratch:
        block_refs, scratch_refs = invars[:-n_scratch], invars[-n_scratch:]
    else:
        # fall back to the memory-space tag if the count is unavailable
        block_refs = [v for v in invars if not _is_scratch(v)]
        scratch_refs = [v for v in invars if _is_scratch(v)]
    block_bytes = sum(_ref_bytes(v) for v in block_refs)
    scratch_bytes = sum(_ref_bytes(v) for v in scratch_refs)
    residency = 2 * block_bytes + scratch_bytes
    budget = VMEM_BYTES[generation]
    src = kernel_src(eqn)
    family = next((f for f in ("lora_dual", "wkv6_scan", "swa_attention",
                               "mamba2_scan") if f in src), "other")
    return {
        "kernel": f"{family}.{kernel_name(eqn)}",
        "family": family,
        "src": src,
        "grid": [int(g) for g in gm.grid],
        "block_shapes": [list(map(int, v.aval.shape)) for v in block_refs],
        "scratch_shapes": [list(map(int, v.aval.shape))
                           for v in scratch_refs],
        "block_bytes": int(block_bytes),
        "scratch_bytes": int(scratch_bytes),
        "residency_bytes": int(residency),
        "residency_mib": round(residency / MIB, 4),
        "generation": generation,
        "budget_bytes": int(budget),
        "ok": bool(residency <= budget),
    }


def vmem_table(jaxpr, generation: str = DEFAULT_GENERATION) -> List[Dict]:
    """Residency rows for every pallas_call in a traced program."""
    return [kernel_vmem(e, generation) for e in pallas_calls(jaxpr)]


def dedupe_rows(rows: List[Dict]) -> List[Dict]:
    """Collapse repeated instantiations of the same kernel at the same
    block/scratch shapes (scan bodies re-trace identical calls)."""
    seen, out = set(), []
    for row in rows:
        key = (row["kernel"], row["src"].split(" at ")[-1],
               tuple(map(tuple, row["block_shapes"])),
               tuple(map(tuple, row["scratch_shapes"])))
        if key not in seen:
            seen.add(key)
            out.append(row)
    return out


def representative_kernel_rows(
        generation: str = DEFAULT_GENERATION) -> List[Dict]:
    """Trace every shipped kernel family at representative (paper-scale
    block) shapes and return its residency row — the per-kernel table
    ANALYSIS.json tracks: lora_dual (mt / mt_jvps / multi), wkv6_scan,
    swa_attention, mamba2_scan and their ``_mt_jvps`` epilogues.

    Tracing is shape-level only (``jax.make_jaxpr`` on the jit'd dispatch
    wrappers, interpret=True): nothing executes, so this runs on CPU."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.lora_dual.ops import (
        lora_dual_mt, lora_dual_mt_jvps, lora_dual_multi)
    from repro.kernels.mamba2_scan.ops import (
        mamba2_scan_mt, mamba2_scan_mt_jvps)
    from repro.kernels.swa_attention.ops import (
        swa_attention_mt, swa_attention_mt_jvps)
    from repro.kernels.wkv6_scan.ops import wkv6_scan_mt, wkv6_scan_mt_jvps

    f32 = jnp.float32
    T, r = 8, 8                       # K tangents / LoRA rank

    def z(*shape):
        return jnp.zeros(shape, f32)

    # lora: M=B*S=256 tokens, d=512, one 128^3-blocked projection
    M, Kd, N = 256, 512, 512
    x, w, a, b = z(M, Kd), z(Kd, N), z(Kd, r), z(r, N)
    ad, bd, xd = z(T, Kd, r), z(T, r, N), z(T, M, Kd)
    gy = z(M, N)
    # mixers: B=1, S=256, H=8 heads, hd=64, mamba2 state N=64
    B, S, H, hd, Nst = 1, 256, 8, 64, 64
    rr, kk, vv, ww, u = (z(B, S, H, hd),) * 4 + (z(H, hd),)
    rds, kds, vds, wds = (z(T, B, S, H, hd),) * 4
    gy_m = z(B, S, H, hd)
    q, ks_, vs_ = z(B, H, S, hd), z(B, H, S, hd), z(B, H, S, hd)
    qd, kd_, vd_ = (z(T, B, H, S, hd),) * 3
    xdt, bm, cm = z(B, S, H, hd), z(B, S, Nst), z(B, S, Nst)
    dec = z(B, S, H)
    xdd, bdd, cdd, ddd = (z(T, B, S, H, hd), z(T, B, S, Nst),
                          z(T, B, S, Nst), z(T, B, S, H))
    idx = jnp.zeros((M,), jnp.int32)
    a_st, b_st = z(4, Kd, r), z(4, r, N)

    traces = [
        lambda: lora_dual_mt(x, xd, w, a, ad, b, bd, interpret=True),
        lambda: lora_dual_mt_jvps(x, w, a, ad, b, bd, gy, xdots=xd,
                                  impl="kernel", interpret=True),
        lambda: lora_dual_multi(x, idx, w, a_st, b_st, interpret=True),
        lambda: wkv6_scan_mt(rr, kk, vv, ww, u, rds, kds, vds, wds,
                             interpret=True),
        lambda: wkv6_scan_mt_jvps(rr, kk, vv, ww, u, rds, kds, vds, wds,
                                  gy_m, interpret=True),
        lambda: swa_attention_mt(q, ks_, vs_, qd, kd_, vd_, window=128,
                                 interpret=True),
        lambda: swa_attention_mt_jvps(q, ks_, vs_, qd, kd_, vd_, gy_m,
                                      window=128, interpret=True),
        lambda: mamba2_scan_mt(xdt, bm, cm, dec, xdd, bdd, cdd, ddd,
                               interpret=True),
        lambda: mamba2_scan_mt_jvps(xdt, bm, cm, dec, xdd, bdd, cdd, ddd,
                                    gy_m, interpret=True),
    ]
    rows = []
    for thunk in traces:
        rows += vmem_table(jax.make_jaxpr(thunk)(), generation)
    return dedupe_rows(rows)
