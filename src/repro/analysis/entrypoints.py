"""Tracer harness: the repo's REAL entry points, traced to closed jaxprs /
lowered HLO for the rules to inspect.

Registered entry points (each returns ``Trace`` records):

* ``loss_traces``     — every registry family x {lm, cls} x {fused,
  standard} estimator route, traced exactly the way
  ``core.forward_grad.forward_gradient`` lowers them (``fused_linearize``
  + vmap for the contraction route; ``jax.linearize`` inside
  ``forward_ad_region`` + vmap for the standard route) on the interpret
  kernel backend — the traces carry real pallas_calls.
* ``grad_guard_traces`` — ``jax.grad`` of the plain registry losses with a
  kernel backend selected but OUTSIDE ``forward_ad_region()``: the
  transpose-reachability rule demands these contain no pallas_call.
* ``serve_lowered``   — ``launch.serve.build_serve_fns`` decode/prefill
  jits lowered at serving shapes, plus the ServingEngine's admission
  decode, for the donation rule.
* ``round_step_lowered`` — the runtime FederationEngine round jits and the
  train-loop round step, lowered for the donation rule.
* ``telemetry_pair_lowered`` — the instrumented engines lowered twice,
  telemetry disabled vs enabled, for the telemetry-neutrality rule.

Everything runs at ``reduce_config`` scale (B=1, S=16) — tracing only,
nothing executes, so the whole sweep is CPU-cheap.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs import SpryConfig, get_config, reduce_config
from repro.core.forward_grad import fused_linearize
from repro.kernels import dispatch
from repro.models.registry import get_loss_fn, get_model
from repro.peft import init_peft

# one representative reduced arch per registry family (the gemma3
# local-global attention variant rides along as a seventh sweep arch)
ARCHS = {
    "dense": "llama2-7b",
    "moe": "qwen3-moe-235b-a22b",
    "vlm": "internvl2-76b",
    "ssm": "rwkv6-1.6b",
    "hybrid": "zamba2-1.2b",
    "audio": "whisper-tiny",
    "local_global": "gemma3-12b",
}
QUICK_FAMILIES = ("dense", "ssm")
TASKS = ("lm", "cls")

# which kernel-source substring identifies the family's final-mixer site
SITE_FAMILY = {"lora": "lora_dual", "wkv6": "wkv6_scan",
               "swa": "swa_attention", "mamba2": "mamba2_scan"}


@dataclasses.dataclass
class Trace:
    name: str              # e.g. "loss.dense.cls.fused"
    kind: str              # "fused_loss" | "standard_loss" | "grad_guard"
                           # | "lowered"
    jaxpr: Any = None      # ClosedJaxpr for jaxpr-level rules
    lowered: Any = None    # jax.stages.Lowered for the donation rule
    K: Optional[int] = None
    y_shape: Optional[tuple] = None
    site_family: Optional[str] = None
    meta: Dict = dataclasses.field(default_factory=dict)


def build_setup(cfg, task, seed=0, B=1, S=16):
    """Model + base + fp32 peft + a shaped batch for one family/task."""
    key = jax.random.PRNGKey(seed)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    peft32 = jax.tree.map(lambda x: x.astype(jnp.float32), peft)
    ks = jax.random.split(jax.random.PRNGKey(seed + 1), 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if task == "cls":
        batch["labels"] = jax.random.randint(ks[1], (B,), 0, cfg.n_classes)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_frontend_tokens or 4, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    return model, base, peft32, batch


def _cfg(family):
    return reduce_config(get_config(ARCHS[family]))


def loss_traces(family: str, task: str, K: int = 4) -> List[Trace]:
    """Fused + standard estimator-route traces for one family/task."""
    cfg = _cfg(family)
    model, base, peft32, batch = build_setup(cfg, task)
    split = get_loss_fn(task, split=True)(cfg, base, batch)
    vs = jax.tree.map(lambda t: jnp.zeros((K,) + t.shape, jnp.float32),
                      peft32)
    dispatch.set_backend("interpret")
    try:
        _, fused_map = fused_linearize(split, peft32)
        fused_jaxpr = jax.make_jaxpr(jax.vmap(fused_map))(vs)
        site_args, _ = split.pre(peft32)
        with dispatch.forward_ad_region():
            y_shape = jax.eval_shape(split.site, site_args).shape
            _, std_map = jax.linearize(split, peft32)
        std_jaxpr = jax.make_jaxpr(jax.vmap(std_map))(vs)
    finally:
        dispatch.set_backend(None)
    site = SITE_FAMILY[split.kind]
    return [
        Trace(f"loss.{family}.{task}.fused", "fused_loss",
              jaxpr=fused_jaxpr, K=K, y_shape=tuple(y_shape),
              site_family=site, meta={"arch": ARCHS[family]}),
        Trace(f"loss.{family}.{task}.standard", "standard_loss",
              jaxpr=std_jaxpr, K=K, y_shape=tuple(y_shape),
              site_family=site, meta={"arch": ARCHS[family]}),
    ]


def grad_guard_traces(family: str, task: str = "cls") -> List[Trace]:
    """Reverse-mode trace of the plain loss, kernel backend selected,
    OUTSIDE forward_ad_region — must contain no pallas_call."""
    cfg = _cfg(family)
    model, base, peft32, batch = build_setup(cfg, task)
    plain = lambda p: get_loss_fn(task)(cfg, base, p, batch)
    dispatch.set_backend("interpret")
    try:
        g_jaxpr = jax.make_jaxpr(jax.grad(plain))(peft32)
    finally:
        dispatch.set_backend(None)
    return [Trace(f"grad.{family}.{task}", "grad_guard", jaxpr=g_jaxpr,
                  meta={"arch": ARCHS[family]})]


def serve_lowered(family: str = "dense", B: int = 2, P: int = 8,
                  steps: int = 8) -> List[Trace]:
    """The jitted serving entry points, lowered at serving shapes."""
    from repro.launch.serve import build_serve_fns

    cfg = _cfg(family)
    model, base, peft32, _ = build_setup(cfg, "lm", B=B)
    fns = build_serve_fns(cfg, model)
    cache = model.init_cache(cfg, B, P + steps)
    tok = jnp.zeros((B, 1), jnp.int32)
    out = [Trace(f"serve.decode.{family}", "lowered",
                 lowered=fns["decode"].lower(base, peft32, cache, tok,
                                             jnp.int32(P)),
                 meta={"arch": ARCHS[family]})]
    if fns["prefill"] is not None:
        toks = jnp.zeros((B, P), jnp.int32)
        out.append(Trace(
            f"serve.prefill.{family}", "lowered",
            lowered=fns["prefill"].lower(base, peft32, cache, toks),
            meta={"arch": ARCHS[family]}))
    return out


def serving_engine_lowered(family: str = "dense") -> List[Trace]:
    """The ServingEngine's admission-path jits (B=1 decode + row scatter),
    lowered the way ``_admit``/``step`` invoke them."""
    from repro.launch.adapter_cache import (AdapterCache,
                                            SyntheticAdapterStore)
    from repro.launch.serving import ServingEngine

    cfg = _cfg(family)
    model = get_model(cfg)
    base = model.init_base(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, base, AdapterCache(SyntheticAdapterStore(cfg),
                                                capacity=2),
                        max_batch=2, cache_len=16)
    peft1 = eng.adapters.page_tree(eng.adapters.acquire(0))
    cache1 = model.init_cache(cfg, 1, eng.cache_len)
    tok = jnp.zeros((1, 1), jnp.int32)
    return [
        Trace(f"serving.decode1.{family}", "lowered",
              lowered=eng._decode1.lower(base, peft1, cache1, tok,
                                         jnp.int32(0)),
              meta={"arch": ARCHS[family]}),
        Trace(f"serving.scatter.{family}", "lowered",
              lowered=eng._scatter.lower(eng.cache, cache1, 0),
              meta={"arch": ARCHS[family]}),
    ]


def round_step_lowered(family: str = "ssm") -> List[Trace]:
    """The runtime FederationEngine round jits and the train-loop round
    step, lowered at a tiny cohort. Engine jits are donation-waived by
    design (the public API borrows caller state); the train-loop step
    donates its threaded state."""
    from repro.core.assignment import enumerate_units
    from repro.core.spry import init_state, make_round_step
    from repro.fl.runtime import FederationEngine, SerialExecutor, WireConfig

    cfg = _cfg(family)
    sc = SpryConfig(n_clients_per_round=2, n_total_clients=4,
                    k_perturbations=2)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)
    M, B, S = 2, 2, 16
    batch = {"tokens": jax.random.randint(key, (M, B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (M, B), 0, cfg.n_classes)}
    n_units = enumerate_units(peft).n_units
    seed_ids = jnp.arange(M, dtype=jnp.int32)
    mask = jnp.ones((M, n_units), jnp.float32)
    keep = jnp.ones((M,), jnp.float32)

    engine = FederationEngine(cfg, sc, task="cls",
                              executor=SerialExecutor(),
                              wire=WireConfig(dtype="fp32"))
    # the train loop jits the in-process round step with its state donated
    # (mirrors launch/train.py run_training)
    step = jax.jit(make_round_step(cfg, sc, "cls"), donate_argnums=(0,))
    return [
        Trace("engine.round_step", "lowered",
              lowered=engine._round_jit.lower(state, seed_ids, mask, keep,
                                              batch),
              meta={"arch": ARCHS[family]}),
        Trace("train.round_step", "lowered",
              lowered=step.lower(state, batch),
              meta={"arch": ARCHS[family]}),
    ]


def telemetry_pair_lowered(family: str = "ssm") -> List[Trace]:
    """The instrumented engines built twice — telemetry disabled vs an
    enabled in-memory Telemetry — and their jits lowered both ways. The
    telemetry-neutrality rule demands the lowered texts be IDENTICAL:
    recording happens host-side on returned values only, so enabling
    telemetry must not reach any traced program."""
    from repro.core.assignment import enumerate_units
    from repro.core.spry import init_state
    from repro.fl.runtime import FederationEngine, SerialExecutor, WireConfig
    from repro.launch.adapter_cache import (AdapterCache,
                                            SyntheticAdapterStore)
    from repro.launch.serving import ServingEngine
    from repro.obs import InMemorySink, Telemetry

    cfg = _cfg(family)
    sc = SpryConfig(n_clients_per_round=2, n_total_clients=4,
                    k_perturbations=2)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)
    M, B, S = 2, 2, 16
    batch = {"tokens": jax.random.randint(key, (M, B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (M, B), 0, cfg.n_classes)}
    n_units = enumerate_units(peft).n_units
    seed_ids = jnp.arange(M, dtype=jnp.int32)
    mask = jnp.ones((M, n_units), jnp.float32)
    keep = jnp.ones((M,), jnp.float32)

    def engine_round_text(telemetry):
        eng = FederationEngine(cfg, sc, task="cls",
                               executor=SerialExecutor(),
                               wire=WireConfig(dtype="fp32"),
                               telemetry=telemetry)
        return eng._round_jit.lower(state, seed_ids, mask, keep,
                                    batch).as_text()

    def serving_texts(telemetry):
        eng = ServingEngine(
            cfg, base,
            AdapterCache(SyntheticAdapterStore(cfg), capacity=2,
                         telemetry=telemetry),
            max_batch=2, cache_len=16, telemetry=telemetry)
        peft1 = eng.adapters.page_tree(eng.adapters.acquire(0))
        cache1 = model.init_cache(cfg, 1, eng.cache_len)
        tok = jnp.zeros((1, 1), jnp.int32)
        return {
            "decode1": eng._decode1.lower(base, peft1, cache1, tok,
                                          jnp.int32(0)).as_text(),
            "scatter": eng._scatter.lower(eng.cache, cache1, 0).as_text(),
        }

    def tel_on():
        return Telemetry(run_id="analysis", sinks=[InMemorySink()])

    traces = [Trace(f"telemetry.engine.round_step.{family}",
                    "telemetry_pair",
                    meta={"arch": ARCHS[family],
                          "text_off": engine_round_text(None),
                          "text_on": engine_round_text(tel_on())})]
    off, on = serving_texts(None), serving_texts(tel_on())
    for name in off:
        traces.append(Trace(f"telemetry.serving.{name}.{family}",
                            "telemetry_pair",
                            meta={"arch": ARCHS[family],
                                  "text_off": off[name],
                                  "text_on": on[name]}))
    return traces


def sweep(families=None, tasks=TASKS, quick=False, K: int = 4) -> List[Trace]:
    """The full registered entry-point sweep the lint runs."""
    if families is None:
        families = QUICK_FAMILIES if quick else tuple(ARCHS)
    traces: List[Trace] = []
    for fam in families:
        for task in tasks:
            traces += loss_traces(fam, task, K=K)
        traces += grad_guard_traces(fam)
    traces += serve_lowered("dense")
    traces += serve_lowered("ssm")
    traces += serving_engine_lowered("dense")
    traces += round_step_lowered("ssm")
    traces += telemetry_pair_lowered("ssm")
    return traces
