"""repro.analysis — jaxpr/HLO static-analysis suite for the memory & AD
invariants the reproduction's value proposition rests on.

The paper's claim is a MEMORY claim (forward-mode AD never materializes
activation-scale tangents); this package turns the repo's load-bearing
invariants from scattered test assertions into one rule-based analyzer
that traces the real entry points (every model family x lm/cls on the
fused and standard estimator routes, serving decode/prefill, the runtime
round step) and checks:

  tangent-materialization  no (K,)+y tangent stack written by a kernel
                           inside a fused-contraction trace
  vmem-budget              per-grid-step VMEM residency of every Pallas
                           kernel fits the TPU generation's per-core budget
  transpose-reachability   pallas_call unreachable under reverse-mode
                           outside dispatch.forward_ad_region()
  donation                 jitted hot loops donate large carried buffers
  dtype-policy             fp32 kernel accumulators; wire dtypes as declared

CLI:  PYTHONPATH=src python -m repro.analysis.lint [--strict] [--json ...]
"""
from repro.analysis.jaxpr_walker import (
    assert_no_tangent_stack,
    family_pallas_calls,
    kernel_name,
    kernel_src,
    pallas_calls,
    tangent_stack_outputs,
    tangent_stack_size,
    walk_eqns,
)
from repro.analysis.rules import DONATION_WAIVERS, RULES, Finding
from repro.analysis.vmem import (
    DEFAULT_GENERATION,
    VMEM_BYTES,
    kernel_vmem,
    representative_kernel_rows,
    vmem_table,
)

__all__ = [
    "DEFAULT_GENERATION",
    "DONATION_WAIVERS",
    "Finding",
    "RULES",
    "VMEM_BYTES",
    "assert_no_tangent_stack",
    "family_pallas_calls",
    "kernel_name",
    "kernel_src",
    "kernel_vmem",
    "pallas_calls",
    "representative_kernel_rows",
    "tangent_stack_outputs",
    "tangent_stack_size",
    "vmem_table",
    "walk_eqns",
]
