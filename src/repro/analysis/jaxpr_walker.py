"""Shared jaxpr-walking pass: every eqn, every nesting level, one place.

This generalizes the ad-hoc ``_walk_eqns`` / ``_pallas_calls`` /
``_assert_no_tangent_stack_output`` helpers that used to be copy-pasted
across ``tests/test_jvps_epilogue.py`` / ``test_split_forward.py`` /
``test_mt_mixers.py`` into the one pass the static-analysis rules and all
tests call. Sub-jaxprs are found wherever primitives carry them: scan /
while / pjit / custom_jvp bodies hold a single (Closed)Jaxpr param,
``cond`` holds a tuple of branches, and ``pallas_call`` carries the kernel
body itself (whose invars are the VMEM block/scratch refs the vmem model
reads).
"""
from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np


def _inner_jaxprs(param):
    """Yield every (Closed)Jaxpr reachable from one eqn param value."""
    if isinstance(param, (tuple, list)):
        for p in param:
            yield from _inner_jaxprs(p)
        return
    inner = getattr(param, "jaxpr", None)
    if inner is not None:
        yield inner if hasattr(inner, "eqns") else inner.jaxpr


def walk_eqns(jaxpr) -> Iterator:
    """Yield every eqn of ``jaxpr`` (Jaxpr or ClosedJaxpr), recursing into
    sub-jaxprs carried in eqn params (scan/while/cond/pjit bodies,
    custom_jvp/vjp closures, pallas_call kernel bodies)."""
    j = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in j.eqns:
        yield eqn
        for p in eqn.params.values():
            for inner in _inner_jaxprs(p):
                yield from walk_eqns(inner)


def pallas_calls(jaxpr) -> List:
    """All ``pallas_call`` eqns anywhere in a (nested) jaxpr."""
    return [e for e in walk_eqns(jaxpr) if e.primitive.name == "pallas_call"]


def kernel_src(eqn) -> str:
    """The kernel's ``name_and_src_info`` string, e.g.
    ``'_mt_jvps_kernel at .../kernels/lora_dual/kernel.py:161'``."""
    return str(eqn.params.get("name_and_src_info"))


def kernel_name(eqn) -> str:
    """Just the kernel function name (``'_mt_jvps_kernel'``)."""
    return kernel_src(eqn).split(" at ")[0].strip()


def family_pallas_calls(jaxpr, family: str) -> List:
    """pallas_calls whose source path mentions ``family`` (e.g.
    ``'lora_dual'`` / ``'wkv6_scan'`` / ``'swa_attention'`` /
    ``'mamba2_scan'``) — upstream (non-site) mixers legitimately
    materialize their tangents, so site checks filter by kernel family."""
    return [e for e in pallas_calls(jaxpr) if family in kernel_src(e)]


def tangent_stack_size(K: int, y_shape) -> int:
    """Element count of the (K,) + y_shape tangent stack the contraction
    epilogues exist to remove."""
    return int(K) * int(np.prod(y_shape))


def tangent_stack_outputs(jaxpr, K: int, y_shape,
                          family: str = None) -> List[Tuple]:
    """Every (eqn, outvar) where a pallas_call WRITES a buffer at least as
    large as the (K,) + y_shape tangent stack. Site INPUT tangents of that
    size are unavoidable (they are kernel operands); the invariant targets
    kernel outputs — the buffers the ``*_mt_tangents`` route materializes
    and the ``*_mt_jvps`` epilogues replace with per-block partials."""
    stack = tangent_stack_size(K, y_shape)
    calls = (family_pallas_calls(jaxpr, family) if family
             else pallas_calls(jaxpr))
    return [(eqn, var) for eqn in calls for var in eqn.outvars
            if var.aval.size >= stack]


def assert_no_tangent_stack(jaxpr, K: int, y_shape, family: str = None):
    """Raise AssertionError if any pallas_call writes a tangent-stack-sized
    buffer — the drop-in replacement for the old per-test helpers."""
    for eqn, var in tangent_stack_outputs(jaxpr, K, y_shape, family=family):
        raise AssertionError(
            f"kernel writes a tangent-stack-sized buffer {var.aval.shape} "
            f"(>= K x y = {tangent_stack_size(K, y_shape)} elems): {eqn}")
