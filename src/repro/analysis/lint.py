"""The static-analysis lint CLI — the jaxpr/HLO-level correctness gate.

    PYTHONPATH=src JAX_PLATFORMS=cpu python -m repro.analysis.lint \
        [--strict] [--quick] [--families dense,ssm] [--tasks lm,cls] \
        [--generation v5e] [--json ANALYSIS.json]

Traces every registered entry point (``analysis.entrypoints``) and checks
the six rule classes (``analysis.rules``). Exit code: 0 when clean,
1 on any error finding; ``--strict`` also fails on warnings. ``--json``
writes the tracked ``ANALYSIS.json`` artifact (per-kernel VMEM residency
table + findings audit trail) that ``benchmarks/check_schemas.py``
validates in CI.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Tuple

from repro.analysis import entrypoints as eps
from repro.analysis import rules as R
from repro.analysis.report import render, summarize, to_doc, write_analysis
from repro.analysis.vmem import (DEFAULT_GENERATION, VMEM_BYTES,
                                 representative_kernel_rows)


def run(families=None, tasks=eps.TASKS, quick=False, K=4,
        generation=DEFAULT_GENERATION) -> Tuple[List[R.Finding], list, list]:
    """Trace + check everything; returns (findings, vmem_rows, names)."""
    traces = eps.sweep(families=families, tasks=tasks, quick=quick, K=K)
    findings: List[R.Finding] = []
    for t in traces:
        if t.kind == "fused_loss":
            findings += R.check_tangent_stack(t.name, t.jaxpr, t.K,
                                              t.y_shape,
                                              family=t.site_family)
            findings += R.check_vmem(t.name, t.jaxpr, generation)
            findings += R.check_dtype_policy(t.name, t.jaxpr)
        elif t.kind == "standard_loss":
            findings += R.record_expected_stack(t.name, t.jaxpr, t.K,
                                                t.y_shape,
                                                family=t.site_family)
            findings += R.check_vmem(t.name, t.jaxpr, generation)
            findings += R.check_dtype_policy(t.name, t.jaxpr)
        elif t.kind == "grad_guard":
            findings += R.check_transpose_reachability(t.name, t.jaxpr)
        elif t.kind == "lowered":
            findings += R.check_donation(t.name, t.lowered)
        elif t.kind == "telemetry_pair":
            findings += R.check_telemetry_neutrality(
                t.name, t.meta["text_off"], t.meta["text_on"])
    findings += R.check_wire_dtypes()
    vmem_rows = representative_kernel_rows(generation)
    findings += R.check_vmem_rows("kernels.representative", vmem_rows)
    return findings, vmem_rows, [t.name for t in traces]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="jaxpr/HLO static-analysis gate "
                    "(memory & AD-safety invariants)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on warnings too, not just errors")
    ap.add_argument("--quick", action="store_true",
                    help="dense+ssm only (CI smoke)")
    ap.add_argument("--families", default=None,
                    help="comma-separated registry families "
                         f"(default: all of {', '.join(eps.ARCHS)})")
    ap.add_argument("--tasks", default=",".join(eps.TASKS))
    ap.add_argument("--k", type=int, default=4,
                    help="K perturbations for the estimator traces")
    ap.add_argument("--generation", default=DEFAULT_GENERATION,
                    choices=sorted(VMEM_BYTES),
                    help="TPU generation whose VMEM budget to enforce")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the ANALYSIS.json artifact here")
    args = ap.parse_args(argv)

    families = (tuple(f for f in args.families.split(",") if f)
                if args.families else None)
    tasks = tuple(t for t in args.tasks.split(",") if t)
    findings, vmem_rows, names = run(
        families=families, tasks=tasks, quick=args.quick, K=args.k,
        generation=args.generation)
    print(render(findings, vmem_rows, names))
    if args.json:
        write_analysis(args.json, to_doc(
            findings, vmem_rows, names, args.generation,
            VMEM_BYTES[args.generation]))
        print(f"\nwrote {args.json}")
    s = summarize(findings)
    failed = s["errors"] > 0 or (args.strict and s["warnings"] > 0)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
