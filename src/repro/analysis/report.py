"""Findings rendering + the tracked ``ANALYSIS.json`` artifact.

``ANALYSIS.json`` is the machine-readable output CI validates
(``benchmarks/check_schemas.py``): the per-kernel VMEM residency table
(closing the unmeasured-budget half of ROADMAP item 6), the rule list,
every finding (including waived/info ones — the audit trail), and a
summary the schema check and the real-TPU run key off."""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List

from repro.analysis.rules import RULES, Finding

SCHEMA = "repro.analysis/v1"

_SEV_ORDER = {"error": 0, "warning": 1, "info": 2}


def sort_findings(findings: List[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (_SEV_ORDER[f.severity], f.rule,
                                           f.entrypoint, f.where))


def summarize(findings: List[Finding]) -> Dict[str, int]:
    out = {"errors": 0, "warnings": 0, "info": 0}
    for f in findings:
        out[{"error": "errors", "warning": "warnings",
             "info": "info"}[f.severity]] += 1
    return out


def render(findings: List[Finding], vmem_rows: List[Dict],
           entrypoints: List[str]) -> str:
    lines = [f"repro.analysis: {len(entrypoints)} entry points, "
             f"{len(RULES)} rules, {len(vmem_rows)} kernels in the VMEM "
             f"table"]
    lines.append("")
    lines.append("per-kernel VMEM residency (per grid step, double-buffered "
                 "blocks + scratch):")
    for row in vmem_rows:
        mark = "ok" if row["ok"] else "OVER BUDGET"
        lines.append(
            f"  {row['kernel']:28s} grid={str(row['grid']):14s} "
            f"blocks={row['block_bytes'] / 1024:8.1f}KiB "
            f"scratch={row['scratch_bytes'] / 1024:8.1f}KiB "
            f"residency={row['residency_mib']:7.3f}MiB [{mark}]")
    lines.append("")
    s = summarize(findings)
    if not findings:
        lines.append("findings: none")
    else:
        lines.append(f"findings: {s['errors']} error(s), {s['warnings']} "
                     f"warning(s), {s['info']} info")
        for f in sort_findings(findings):
            lines.append(f"  {f}")
    return "\n".join(lines)


def to_doc(findings: List[Finding], vmem_rows: List[Dict],
           entrypoints: List[str], generation: str,
           budget_bytes: int) -> Dict:
    return {
        "schema": SCHEMA,
        "generated_by": "python -m repro.analysis.lint --json ANALYSIS.json",
        "rules": list(RULES),
        "budget": {"generation": generation,
                   "vmem_bytes_per_core": int(budget_bytes)},
        "entrypoints": list(entrypoints),
        "vmem_kernels": vmem_rows,
        "findings": [dataclasses.asdict(f) for f in sort_findings(findings)],
        "summary": dict(summarize(findings),
                        entrypoints=len(entrypoints),
                        kernels=len(vmem_rows)),
    }


def write_analysis(path: str, doc: Dict) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")
