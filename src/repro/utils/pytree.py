"""Pytree arithmetic helpers used throughout the framework.

All functions are pure and jit-safe; they operate on arbitrary pytrees of
jnp arrays (model params, optimizer states, perturbations).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(a, x, y):
    """a*x + y elementwise over trees."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_dot(a, b):
    """Inner product across the full flattened tree (float32 accumulate)."""
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(tree):
    return jnp.sqrt(tree_dot(tree, tree))


def tree_size(tree) -> int:
    """Total number of scalar entries (static)."""
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_where_mask(mask_tree, a, b):
    """Select a where mask truthy, b elsewhere; mask leaves broadcast."""
    return jax.tree.map(lambda m, x, y: jnp.where(m, x, y), mask_tree, a, b)


def normal_like(key, tree, dtype=None):
    """Sample a standard-normal pytree matching ``tree``'s structure.

    Each leaf gets an independent fold of ``key`` so the sample for one leaf
    does not depend on iteration order elsewhere.
    """
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    samples = [
        jax.random.normal(k, l.shape, dtype or l.dtype) for k, l in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, samples)
