from repro.utils.pytree import (
    tree_zeros_like,
    tree_add,
    tree_sub,
    tree_scale,
    tree_dot,
    tree_norm,
    tree_size,
    tree_where_mask,
    tree_cast,
    normal_like,
)
