"""ShapeDtypeStruct input specs for every (architecture x input-shape) pair.

Nothing here allocates: model/optimizer state comes from jax.eval_shape over
the real init functions, so the dry-run lowers the exact same pytree
structures the runtime uses.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import SpryConfig, get_config, get_shape, shape_applicable
from repro.core.spry import init_state
from repro.models.registry import get_model
from repro.peft import init_peft


def spry_config_for(cfg, shape, n_clients: int) -> SpryConfig:
    mb = None
    if shape.kind == "train":
        # bound per-device live activations: mb_B * S * d * (bf16+jvp+slack)
        per_client_b = shape.global_batch // n_clients
        target = 4e9
        mb = max(1, int(target / (shape.seq_len * cfg.d_model * 40)))
        mb = None if mb >= per_client_b else mb
    return SpryConfig(n_clients_per_round=n_clients, local_iters=1,
                      k_perturbations=1, microbatch_size=mb)


def eval_state(cfg, spry_cfg):
    """SpryState as ShapeDtypeStructs (no allocation)."""
    model = get_model(cfg)

    def build():
        key = jax.random.PRNGKey(0)
        base = model.init_base(cfg, key)
        peft = init_peft(cfg, key, spry_cfg)
        return init_state(base, peft)

    return jax.eval_shape(build)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg, shape, n_clients: int):
    """{'tokens': (M, B/M, S), ...} for the SPRY round step (task='lm')."""
    B, S = shape.global_batch, shape.seq_len
    assert B % n_clients == 0
    b = B // n_clients
    text = S
    batch = {}
    if cfg.frontend == "vision" and cfg.n_frontend_tokens:
        text = S - cfg.n_frontend_tokens
        batch["patch_embeds"] = _sds((n_clients, b, cfg.n_frontend_tokens,
                                      cfg.d_model), cfg.dtype)
    if cfg.family == "audio":
        batch["frames"] = _sds((n_clients, b, cfg.encoder_seq, cfg.d_model),
                               cfg.dtype)
    batch["tokens"] = _sds((n_clients, b, text), jnp.int32)
    return batch


def prefill_batch_specs(cfg, shape):
    B, S = shape.global_batch, shape.seq_len
    text = S
    batch = {}
    if cfg.frontend == "vision" and cfg.n_frontend_tokens:
        text = S - cfg.n_frontend_tokens
        batch["patch_embeds"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                     cfg.dtype)
    if cfg.family == "audio":
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    batch["tokens"] = _sds((B, text), jnp.int32)
    return batch


def decode_specs(cfg, shape, kv_int8: bool = False):
    """(cache, token, pos) ShapeDtypeStructs for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    model = get_model(cfg)
    if kv_int8 and cfg.family in ("dense", "moe", "vlm"):
        cache = jax.eval_shape(
            lambda: model.init_cache(cfg, B, S, kv_int8=True))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(cfg, B, S))
    token = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return cache, token, pos


@dataclasses.dataclass(frozen=True)
class DryrunCase:
    arch: str
    shape_name: str
    applicable: bool
    skip_reason: str = ""


def all_cases(arch_ids, shape_names):
    cases = []
    for a in arch_ids:
        cfg = get_config(a)
        for s in shape_names:
            shp = get_shape(s)
            ok = shape_applicable(cfg, shp)
            reason = "" if ok else (
                "pure full-attention arch: 500k-token decode is excluded by "
                "the shape contract (see DESIGN.md §5)")
            cases.append(DryrunCase(a, s, ok, reason))
    return cases
