"""Batched serving of a (SPRY-finetuned) model: prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --steps 32

CPU-runnable on reduced configs; the full-config sharded path is what
dryrun.py lowers (prefill_32k / decode_32k / long_500k serve_step). The
multi-tenant continuous-batching engine built on these pieces lives in
``repro.launch.serving``.
"""
from __future__ import annotations

import argparse
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SpryConfig, get_config, reduce_config
from repro.models import get_model
from repro.models.encdec import encode as encdec_encode
from repro.peft import init_peft

# cache donation through the jitted decode step: XLA reuses the multi-GB
# KV-cache buffers in place instead of allocating a fresh copy per token.
# CPU sometimes declines individual buffers — that is fine, not a bug.
warnings.filterwarnings("ignore",
                        message="Some donated buffers were not usable")


def tokenwise_prefill(cfg, model, base, peft, cache, prompt_tokens,
                      decode=None):
    """Reference prompt ingestion: P decode_step calls (exercises the cache
    exactly as production decode does). Kept as the fallback for families
    whose fused prefill cannot reproduce the loop (quantized / too-short
    ring caches) and as the equivalence oracle in tests. ``decode`` reuses
    an already-jitted decode_step (avoids a second compilation of the
    identical function); when absent a NON-donating one is built so callers
    may keep using the cache they passed in."""
    if decode is None:
        decode = jax.jit(
            lambda base, peft, cache, tok, pos: model.decode_step(
                cfg, base, peft, cache, tok, pos))
    P = prompt_tokens.shape[1]
    for p in range(P):
        logits, cache = decode(base, peft, cache, prompt_tokens[:, p:p + 1],
                               jnp.int32(p))
    return logits, cache


def can_fuse_prefill(cfg, model, cache, prompt_len):
    """Whether ``model.prefill`` reproduces the token-by-token decode loop
    for this cache shape (the fused pass must write exactly the rows the
    loop would have)."""
    if model.prefill is None:
        return False
    if not isinstance(cache, dict):
        return False
    if "k" in cache:
        # int8-KV caches: the decode loop attends to QUANTIZED history
        # during ingestion while a fused pass would attend to exact K/V —
        # not equivalent; take the token loop
        if "k_scale" in cache:
            return False
        # a ring cache SHORTER than the prompt makes the decode loop lossy
        # (early keys are overwritten before later prompt tokens attend);
        # fused attention over the full prompt cannot reproduce that unless
        # every layer is sliding-window AND the ring still covers the window
        Sc = cache["k"].shape[2]
        if Sc < prompt_len:
            all_swa = not any(cfg.is_global_layer(i)
                              for i in range(cfg.n_layers))
            if not (all_swa and Sc >= cfg.window):
                return False
        return True
    if "attn_k" in cache:
        # hybrid shared-attention ring: fusible unless the ring is both
        # shorter than the prompt AND narrower than the window (the loop
        # then wraps while still attending full-window — lossy)
        W = cache["attn_k"].shape[2]
        if W < prompt_len and W < cfg.window:
            return False
        return True
    return True   # stateful families (rwkv): prefill threads exact state


def build_serve_fns(cfg, model):
    """Hoisted jitted serve entry points — build ONCE and reuse across
    requests so steady-state serving never re-traces. The decode step
    donates its cache argument (the multi-GB buffers update in place)."""
    decode = jax.jit(
        lambda base, peft, cache, tok, pos: model.decode_step(
            cfg, base, peft, cache, tok, pos),
        donate_argnums=(2,))
    run_prefill = None
    if model.prefill is not None:
        # the prompt cache is carried state exactly like the decode cache:
        # every caller rebinds it (logits, cache = prefill(...)), so the
        # pre-prefill buffers can be reused in place
        run_prefill = jax.jit(
            lambda base, peft, cache, toks: model.prefill(
                cfg, base, peft, cache, toks),
            donate_argnums=(2,))
    return {"decode": decode, "prefill": run_prefill}


def greedy_generate(cfg, base, peft, prompt_tokens, n_steps, cache_len=None,
                    fused_prefill=True, kv_int8=False, fns=None, frames=None):
    """prompt_tokens: (B, P) int32. Returns (B, n_steps) generated ids.

    ``fused_prefill=True`` ingests the prompt with ONE chunked-attention /
    recurrence pass (model.prefill) instead of P decode_step calls — decode
    output is identical (asserted in tests/test_serve_prefill.py);
    ``can_fuse_prefill`` gates the cases the fused pass cannot reproduce.
    ``fns``: reuse entry points from ``build_serve_fns`` (skips re-jitting
    per call). ``frames``: encoder frames for encoder-decoder families —
    encoded once into the cache's memory slot before the decoder runs.
    """
    model = get_model(cfg)
    B, P = prompt_tokens.shape
    if kv_int8 and not model.supports_kv_int8:
        raise ValueError(
            f"family {cfg.family!r} has no int8-KV cache "
            f"(ModelFns.supports_kv_int8 is False)")
    if model.supports_kv_int8:
        cache = model.init_cache(cfg, B, cache_len or (P + n_steps),
                                 kv_int8=kv_int8)
    else:
        cache = model.init_cache(cfg, B, cache_len or (P + n_steps))
    if frames is not None:
        if not (isinstance(cache, dict) and "memory" in cache):
            raise ValueError("frames given but the cache has no memory slot")
        memory = encdec_encode(cfg, base, frames, peft)
        cache = dict(cache, memory=memory.astype(cache["memory"].dtype))
    if fns is None:
        fns = build_serve_fns(cfg, model)
    decode = fns["decode"]

    if fused_prefill and can_fuse_prefill(cfg, model, cache, P):
        logits, cache = fns["prefill"](base, peft, cache, prompt_tokens)
    else:
        logits, cache = tokenwise_prefill(cfg, model, base, peft, cache,
                                          prompt_tokens, decode=decode)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for s in range(n_steps):
        out.append(tok)
        logits, cache = decode(base, peft, cache, tok, jnp.int32(P + s))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduce_config(cfg)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    total = args.prompt_len + args.steps

    # warmup: compile prefill + decode at the serving shapes OUTSIDE the
    # timed region (compile time otherwise dominates and the reported
    # "throughput" is mostly XLA)
    fns = build_serve_fns(cfg, model)
    greedy_generate(cfg, base, peft, prompt, 1, cache_len=total,
                    fns=fns).block_until_ready()

    t0 = time.time()
    ids = greedy_generate(cfg, base, peft, prompt, args.steps,
                          cache_len=total, fns=fns)
    ids.block_until_ready()
    e2e = time.time() - t0

    # steady-state decode throughput, separated from end-to-end latency
    # (which includes prompt ingestion)
    cache = model.init_cache(cfg, args.batch, total)
    tok = jnp.zeros((args.batch, 1), jnp.int32)
    logits, cache = fns["decode"](base, peft, cache, tok, jnp.int32(0))
    t0 = time.time()
    for s in range(args.steps):
        logits, cache = fns["decode"](base, peft, cache, tok,
                                      jnp.int32(1 + s))
    logits.block_until_ready()
    decode_tps = args.batch * args.steps / (time.time() - t0)

    print(f"[serve] {args.arch}: generated {ids.shape} in {e2e:.2f}s "
          f"end-to-end ({args.batch * args.steps / e2e:.1f} tok/s incl. "
          f"prefill); steady-state decode {decode_tps:.1f} tok/s; "
          f"sample row: {np.asarray(ids[0, :16])}")


if __name__ == "__main__":
    main()
