"""Batched serving of a (SPRY-finetuned) model: prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --steps 32

CPU-runnable on reduced configs; the full-config sharded path is what
dryrun.py lowers (prefill_32k / decode_32k / long_500k serve_step). The
multi-tenant continuous-batching engine built on these pieces lives in
``repro.launch.serving``.
"""
from __future__ import annotations

import argparse
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SpryConfig, get_config, reduce_config
from repro.models import get_model
from repro.models.encdec import encode as encdec_encode
from repro.peft import init_peft

# cache donation through the jitted decode step: XLA reuses the multi-GB
# KV-cache buffers in place instead of allocating a fresh copy per token.
# CPU sometimes declines individual buffers — that is fine, not a bug.
warnings.filterwarnings("ignore",
                        message="Some donated buffers were not usable")


def tokenwise_prefill(cfg, model, base, peft, cache, prompt_tokens,
                      decode=None):
    """Reference prompt ingestion: P decode_step calls (exercises the cache
    exactly as production decode does). Kept as the fallback for families
    whose fused prefill cannot reproduce the loop (quantized / too-short
    ring caches) and as the equivalence oracle in tests. ``decode`` reuses
    an already-jitted decode_step (avoids a second compilation of the
    identical function); when absent a NON-donating one is built so callers
    may keep using the cache they passed in."""
    if decode is None:
        decode = jax.jit(
            lambda base, peft, cache, tok, pos: model.decode_step(
                cfg, base, peft, cache, tok, pos))
    P = prompt_tokens.shape[1]
    for p in range(P):
        logits, cache = decode(base, peft, cache, prompt_tokens[:, p:p + 1],
                               jnp.int32(p))
    return logits, cache


def can_fuse_prefill(cfg, model, cache, prompt_len):
    """Whether ``model.prefill`` reproduces the token-by-token decode loop
    for this cache shape (the fused pass must write exactly the rows the
    loop would have)."""
    if model.prefill is None:
        return False
    if not isinstance(cache, dict):
        return False
    if "k" in cache:
        # int8-KV caches: the decode loop attends to QUANTIZED history
        # during ingestion while a fused pass would attend to exact K/V —
        # not equivalent; take the token loop
        if "k_scale" in cache:
            return False
        # a ring cache SHORTER than the prompt makes the decode loop lossy
        # (early keys are overwritten before later prompt tokens attend);
        # fused attention over the full prompt cannot reproduce that unless
        # every layer is sliding-window AND the ring still covers the window
        Sc = cache["k"].shape[2]
        if Sc < prompt_len:
            all_swa = not any(cfg.is_global_layer(i)
                              for i in range(cfg.n_layers))
            if not (all_swa and Sc >= cfg.window):
                return False
        return True
    if "attn_k" in cache:
        # hybrid shared-attention ring: fusible unless the ring is both
        # shorter than the prompt AND narrower than the window (the loop
        # then wraps while still attending full-window — lossy)
        W = cache["attn_k"].shape[2]
        if W < prompt_len and W < cfg.window:
            return False
        return True
    return True   # stateful families (rwkv): prefill threads exact state


def build_serve_fns(cfg, model):
    """Hoisted jitted serve entry points — build ONCE and reuse across
    requests so steady-state serving never re-traces. The decode step
    donates its cache argument (the multi-GB buffers update in place)."""
    decode = jax.jit(
        lambda base, peft, cache, tok, pos: model.decode_step(
            cfg, base, peft, cache, tok, pos),
        donate_argnums=(2,))
    run_prefill = None
    if model.prefill is not None:
        # the prompt cache is carried state exactly like the decode cache:
        # every caller rebinds it (logits, cache = prefill(...)), so the
        # pre-prefill buffers can be reused in place
        run_prefill = jax.jit(
            lambda base, peft, cache, toks: model.prefill(
                cfg, base, peft, cache, toks),
            donate_argnums=(2,))
    return {"decode": decode, "prefill": run_prefill}


def greedy_generate(cfg, base, peft, prompt_tokens, n_steps, cache_len=None,
                    fused_prefill=True, kv_int8=False, fns=None, frames=None):
    """prompt_tokens: (B, P) int32. Returns (B, n_steps) generated ids.

    ``fused_prefill=True`` ingests the prompt with ONE chunked-attention /
    recurrence pass (model.prefill) instead of P decode_step calls — decode
    output is identical (asserted in tests/test_serve_prefill.py);
    ``can_fuse_prefill`` gates the cases the fused pass cannot reproduce.
    ``fns``: reuse entry points from ``build_serve_fns`` (skips re-jitting
    per call). ``frames``: encoder frames for encoder-decoder families —
    encoded once into the cache's memory slot before the decoder runs.
    """
    model = get_model(cfg)
    B, P = prompt_tokens.shape
    if kv_int8 and not model.supports_kv_int8:
        raise ValueError(
            f"family {cfg.family!r} has no int8-KV cache "
            f"(ModelFns.supports_kv_int8 is False)")
    if model.supports_kv_int8:
        cache = model.init_cache(cfg, B, cache_len or (P + n_steps),
                                 kv_int8=kv_int8)
    else:
        cache = model.init_cache(cfg, B, cache_len or (P + n_steps))
    if frames is not None:
        if not (isinstance(cache, dict) and "memory" in cache):
            raise ValueError("frames given but the cache has no memory slot")
        memory = encdec_encode(cfg, base, frames, peft)
        cache = dict(cache, memory=memory.astype(cache["memory"].dtype))
    if fns is None:
        fns = build_serve_fns(cfg, model)
    decode = fns["decode"]

    if fused_prefill and can_fuse_prefill(cfg, model, cache, P):
        logits, cache = fns["prefill"](base, peft, cache, prompt_tokens)
    else:
        logits, cache = tokenwise_prefill(cfg, model, base, peft, cache,
                                          prompt_tokens, decode=decode)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for s in range(n_steps):
        out.append(tok)
        logits, cache = decode(base, peft, cache, tok, jnp.int32(P + s))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def run_engine(cfg, n_requests, prompt_len, steps, max_batch=4,
               cache_capacity=4, telemetry=None, seed=0):
    """Drive the multi-tenant ServingEngine with ``n_requests`` requests on
    distinct synthetic adapters (the CLI/CI smoke path for the engine +
    adapter cache + telemetry stack). Returns (outputs, engine)."""
    from repro.launch.adapter_cache import AdapterCache, SyntheticAdapterStore
    from repro.launch.serving import Request, ServingEngine

    key = jax.random.PRNGKey(seed)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    store = SyntheticAdapterStore(cfg, SpryConfig(), seed=seed)
    cache = AdapterCache(store, capacity=cache_capacity, telemetry=telemetry)
    engine = ServingEngine(cfg, base, cache, max_batch=max_batch,
                           cache_len=prompt_len + steps,
                           telemetry=telemetry)
    rng = np.random.default_rng(seed)
    reqs = [Request(request_id=f"req-{i}", adapter_id=i % max(1, n_requests),
                    prompt=rng.integers(0, cfg.vocab,
                                        size=prompt_len).astype(np.int32),
                    max_new_tokens=steps)
            for i in range(n_requests)]
    outputs = engine.run(reqs)
    return outputs, engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--engine", type=int, default=0, metavar="N",
                    help="serve N multi-tenant requests through the "
                         "continuous-batching ServingEngine instead of the "
                         "single-tenant greedy loop")
    ap.add_argument("--cache-capacity", type=int, default=4,
                    help="resident adapter pages in the AdapterCache "
                         "(engine mode)")
    ap.add_argument("--telemetry", default=None,
                    help="JSONL event-log path ('off' disables)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON (Perfetto-loadable) "
                         "of the run's spans to this path")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduce_config(cfg)

    if args.engine:
        from repro.obs import make_telemetry
        tel = make_telemetry(
            jsonl=(None if args.telemetry in (None, "off", "none", "")
                   else args.telemetry),
            run_id=f"serve-{args.arch}", workload="serve")
        if tel.enabled:
            tel.event("run_meta", workload="serve", arch=args.arch,
                      n_requests=args.engine, prompt_len=args.prompt_len,
                      steps=args.steps, max_batch=args.batch,
                      cache_capacity=args.cache_capacity)
        outputs, engine = run_engine(
            cfg, args.engine, args.prompt_len, args.steps,
            max_batch=args.batch, cache_capacity=args.cache_capacity,
            telemetry=tel)
        print(f"[serve] engine: {len(outputs)} requests drained in "
              f"{engine.steps} decode steps; adapter cache {engine.adapters.stats()}")
        if tel.enabled:
            if args.trace_out:
                tel.export_chrome_trace(args.trace_out)
            tel.close()
            print(f"[telemetry] events -> {args.telemetry}"
                  + (f"  trace -> {args.trace_out}" if args.trace_out
                     else ""))
        return

    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    total = args.prompt_len + args.steps

    # warmup: compile prefill + decode at the serving shapes OUTSIDE the
    # timed region (compile time otherwise dominates and the reported
    # "throughput" is mostly XLA)
    fns = build_serve_fns(cfg, model)
    greedy_generate(cfg, base, peft, prompt, 1, cache_len=total,
                    fns=fns).block_until_ready()

    t0 = time.time()
    ids = greedy_generate(cfg, base, peft, prompt, args.steps,
                          cache_len=total, fns=fns)
    ids.block_until_ready()
    e2e = time.time() - t0

    # steady-state decode throughput, separated from end-to-end latency
    # (which includes prompt ingestion)
    cache = model.init_cache(cfg, args.batch, total)
    tok = jnp.zeros((args.batch, 1), jnp.int32)
    logits, cache = fns["decode"](base, peft, cache, tok, jnp.int32(0))
    t0 = time.time()
    for s in range(args.steps):
        logits, cache = fns["decode"](base, peft, cache, tok,
                                      jnp.int32(1 + s))
    logits.block_until_ready()
    decode_tps = args.batch * args.steps / (time.time() - t0)

    print(f"[serve] {args.arch}: generated {ids.shape} in {e2e:.2f}s "
          f"end-to-end ({args.batch * args.steps / e2e:.1f} tok/s incl. "
          f"prefill); steady-state decode {decode_tps:.1f} tok/s; "
          f"sample row: {np.asarray(ids[0, :16])}")


if __name__ == "__main__":
    main()
