"""Batched serving of a (SPRY-finetuned) model: prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --steps 32

CPU-runnable on reduced configs; the full-config sharded path is what
dryrun.py lowers (prefill_32k / decode_32k / long_500k serve_step).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SpryConfig, get_config, reduce_config
from repro.models import get_model
from repro.peft import init_peft


def tokenwise_prefill(cfg, model, base, peft, cache, prompt_tokens,
                      decode=None):
    """Reference prompt ingestion: P decode_step calls (exercises the cache
    exactly as production decode does). Kept as the fallback for families
    without a fused prefill and as the equivalence oracle in tests.
    ``decode`` reuses an already-jitted decode_step (avoids a second
    compilation of the identical function)."""
    if decode is None:
        decode = jax.jit(
            lambda base, peft, cache, tok, pos: model.decode_step(
                cfg, base, peft, cache, tok, pos))
    P = prompt_tokens.shape[1]
    for p in range(P):
        logits, cache = decode(base, peft, cache, prompt_tokens[:, p:p + 1],
                               jnp.int32(p))
    return logits, cache


def greedy_generate(cfg, base, peft, prompt_tokens, n_steps, cache_len=None,
                    fused_prefill=True, kv_int8=False):
    """prompt_tokens: (B, P) int32. Returns (B, n_steps) generated ids.

    ``fused_prefill=True`` ingests the prompt with ONE chunked-attention /
    recurrence pass (model.prefill) instead of P decode_step calls — decode
    output is identical (asserted in tests/test_serve_prefill.py); families
    without a fused path (hybrid/encdec) fall back to the token loop.
    """
    model = get_model(cfg)
    B, P = prompt_tokens.shape
    try:
        cache = model.init_cache(cfg, B, cache_len or (P + n_steps),
                                 kv_int8=kv_int8)
    except TypeError:   # families without a quantized-cache knob
        cache = model.init_cache(cfg, B, cache_len or (P + n_steps))

    decode = jax.jit(
        lambda base, peft, cache, tok, pos: model.decode_step(
            cfg, base, peft, cache, tok, pos))

    use_fused = fused_prefill and model.prefill is not None
    if use_fused and isinstance(cache, dict) and "k" in cache:
        # int8-KV caches: the decode loop attends to QUANTIZED history
        # during ingestion while a fused pass would attend to exact K/V —
        # not equivalent; take the token loop
        if "k_scale" in cache:
            use_fused = False
        # a ring cache SHORTER than the prompt makes the decode loop lossy
        # (early keys are overwritten before later prompt tokens attend);
        # fused attention over the full prompt cannot reproduce that unless
        # every layer is sliding-window AND the ring still covers the window
        Sc = cache["k"].shape[2]
        if Sc < P:
            all_swa = not any(cfg.is_global_layer(i)
                              for i in range(cfg.n_layers))
            if not (all_swa and Sc >= cfg.window):
                use_fused = False
    if use_fused:
        run_prefill = jax.jit(
            lambda base, peft, cache, toks: model.prefill(
                cfg, base, peft, cache, toks))
        logits, cache = run_prefill(base, peft, cache, prompt_tokens)
    else:
        logits, cache = tokenwise_prefill(cfg, model, base, peft, cache,
                                          prompt_tokens, decode=decode)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for s in range(n_steps):
        out.append(tok)
        logits, cache = decode(base, peft, cache, tok, jnp.int32(P + s))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduce_config(cfg)
    key = jax.random.PRNGKey(0)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, SpryConfig())
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)

    t0 = time.time()
    ids = greedy_generate(cfg, base, peft, prompt, args.steps)
    dt = time.time() - t0
    tps = args.batch * args.steps / dt
    print(f"[serve] {args.arch}: generated {ids.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s); sample row: {np.asarray(ids[0, :16])}")


if __name__ == "__main__":
    main()
