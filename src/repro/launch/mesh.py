"""Production mesh + sharding recipes.

Single pod : (data=16, model=16)            = 256 v5e chips
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

Recipes:
  'tp'      — base weights tensor-parallel over 'model'; replicated over
              'data'. For models whose weights fit per device (<~20B).
  'fsdp_tp' — 2D: the complementary weight dim additionally sharded over
              'data' (and 'pod'); GSPMD inserts the gather/reduce
              collectives. Required for the 104B/235B/400B configs.

Every rule degrades gracefully: an axis is only applied when the dimension
is divisible by the mesh-axis size (e.g. whisper's vocab 51865 stays
replicated instead of failing to lower).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def wants_fsdp(cfg) -> bool:
    """2D-shard base weights when they cannot fit one device replicated over
    'data' (bf16 bytes / model-axis > ~8GB)."""
    return cfg.n_param_estimate() * 2 / 16 > 8e9


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

# matrices laid out (..., d_in, d_out): shard d_out over model, d_in over fsdp
_IN_OUT = {"wq", "wk", "wv", "wi", "wg", "in_proj", "cm_wk", "w_dt", "wr",
           "lm_head"}
# matrices laid out (..., d_out_model_sharded, d_in): transpose-flavoured
_OUT_IN = {"wo", "wd", "out_proj", "cm_wv"}


def _spec_for(path_names, shape, fsdp_axes):
    """PartitionSpec for one base-weight leaf, by name + rank."""
    name = path_names[-1]
    under_moe = "moe" in path_names
    nd = len(shape)

    def lead(n):
        return (None,) * n

    if name == "embed":
        return ("model", fsdp_axes)
    if name == "router":
        return lead(nd - 2) + (fsdp_axes, "model")
    # experts over model; d_model over data. (§Perf-3 iter 2 REFUTED the
    # F-over-data variant: with clients/tokens sharded on 'data', any other
    # placement forces per-chunk gathers of the dispatch tensors — measured
    # 1.96TB -> 3.85TB/dev on qwen3 train_4k. D-over-data is the best
    # single-program layout; the next structural step would be
    # all-to-all token exchange (Megatron-MoE), see EXPERIMENTS §Perf-3.)
    if under_moe and name in ("wi", "wg") and nd == 4:
        return (None, "model", fsdp_axes, None)
    if under_moe and name == "wd" and nd == 4:
        return (None, "model", None, fsdp_axes)
    if name in _IN_OUT:
        return lead(nd - 2) + (fsdp_axes, "model")
    if name in _OUT_IN:
        return lead(nd - 2) + ("model", fsdp_axes)
    if name == "conv_w":
        return lead(nd - 1) + ("model",)
    if name in ("w_b", "w_c"):
        return lead(nd - 2) + (fsdp_axes, None)
    if name == "w_lora_a":
        return lead(nd - 2) + (fsdp_axes, None)
    if name == "w_lora_b":
        return lead(nd - 2) + (None, "model")
    return lead(nd)   # norms, biases, scalars, mu/u/w0 vectors: replicated


def _prune_indivisible(spec, shape, mesh):
    out = []
    for axes, dim in zip(spec, shape):
        if axes is None:
            out.append(None)
            continue
        if axis_size(mesh, axes) == 0 or dim % axis_size(mesh, axes) != 0:
            out.append(None)
        else:
            out.append(axes)
    return P(*out)


def base_shardings(cfg, mesh, base_tree):
    """NamedSharding tree for the frozen base weights."""
    fsdp = data_axes(mesh) if wants_fsdp(cfg) else None
    fsdp = fsdp if fsdp is None else (fsdp[0] if len(fsdp) == 1 else fsdp)

    def one(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
        spec = _spec_for(names, leaf.shape, fsdp)
        spec = _prune_indivisible(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, base_tree)


def replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def train_batch_shardings(mesh, batch_tree):
    """Client axis (leading) over ('pod','data')."""
    d = data_axes(mesh)
    d = d[0] if len(d) == 1 else d

    def one(leaf):
        spec = [d] + [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, _prune_indivisible(spec, leaf.shape, mesh))

    return jax.tree.map(one, batch_tree)


def serve_batch_shardings(mesh, batch_tree):
    return train_batch_shardings(mesh, batch_tree)   # batch-leading too


def cache_shardings(cfg, mesh, cache_tree):
    """Caches: batch dim -> data axes; the long 'sequence-like' dim (KV
    positions / conv taps) or head dim -> 'model' when divisible."""
    d = data_axes(mesh)
    d = d[0] if len(d) == 1 else d

    def one(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = leaf.ndim
        if name in ("k", "v", "attn_k", "attn_v", "k_scale", "v_scale"):
            spec = [None, d, "model", None, None][:nd]
        elif name == "wkv":        # (L,B,H,hd,hd)
            spec = [None, d, "model", None, None]
        elif name == "ssm":        # (L,B,H,hd,N)
            spec = [None, d, "model", None, None]
        elif name == "conv":       # (L,B,K-1,d_inner)
            spec = [None, d, None, "model"]
        elif name in ("shift_tm", "shift_cm"):   # (L,B,1,D)
            spec = [None, d, None, "model"]
        elif name == "memory":     # (B,F,D)
            spec = [d, None, None]
        else:
            spec = [None] * nd
        return NamedSharding(mesh, _prune_indivisible(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
