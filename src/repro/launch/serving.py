"""Multi-tenant continuous-batching serving engine.

Requests arrive bound to per-client LoRA adapters (``adapter_id`` into an
``AdapterCache``); the engine decodes up to ``max_batch`` requests in ONE
batched decode step per token, each row reading its own adapter page through
the batched multi-adapter projection route. New requests are admitted into
free rows of the in-flight batch without draining it (continuous batching):
admission runs a fused B=1 prefill for the new prompt, scatters the
resulting row cache into the big batch cache, and the next engine step
decodes old and new rows together — per-row positions, per-row ring slots,
per-row adapters.

Per-row outputs match ``serve.greedy_generate`` run per request: rows are
independent through every batched op, the admission prefill is the same B=1
pass greedy runs, and the token protocol is identical (first token from the
prefill logits, each decode step appends one).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import (
    build_serve_fns,
    can_fuse_prefill,
    tokenwise_prefill,
)
from repro.models import get_model
from repro.models.encdec import encode as encdec_encode
from repro.obs import NULL


@dataclasses.dataclass
class Request:
    request_id: str
    adapter_id: int
    prompt: np.ndarray            # (P,) int32 prompt tokens
    max_new_tokens: int
    frames: Optional[np.ndarray] = None   # encoder frames (audio family)


def _scatter_row(big, row, b):
    """Write the B=1 ``row`` cache into batch row ``b`` of ``big``. Every
    cache leaf carries batch on axis 1 (leading layer/site axis) except the
    encoder memory (batch-leading)."""
    out = {}
    for key, buf in big.items():
        ax = 0 if key == "memory" else 1
        rowv = jnp.take(row[key], 0, axis=ax).astype(buf.dtype)
        out[key] = jax.lax.dynamic_update_index_in_dim(buf, rowv, b, ax)
    return out


class ServingEngine:
    """Request-driven continuous-batching decoder over one frozen base.

    ``adapter_cache``: an ``AdapterCache``; each in-flight row pins its
    adapter's page (pages of completed requests become evictable again).
    ``cache_len`` bounds prompt + generation length for every request.
    """

    def __init__(self, cfg, base, adapter_cache, max_batch: int,
                 cache_len: int, fused_prefill: bool = True,
                 telemetry=None):
        self.cfg = cfg
        self.base = base
        self.adapters = adapter_cache
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.fused_prefill = fused_prefill
        self.model = get_model(cfg)
        # host-side telemetry on returned token ids/timestamps only — the
        # decode/prefill jits never see this object, and per-request ids
        # stay bitwise those of isolated greedy serving (tested)
        tel = telemetry if telemetry is not None else NULL
        self.telemetry = tel
        self._tc_requests = tel.counter("serve.requests")
        self._tc_tokens = tel.counter("serve.gen_tokens")
        self._tc_steps = tel.counter("serve.decode_steps")
        self._tg_queue = tel.gauge("serve.queue_depth")
        self._tg_inflight = tel.gauge("serve.in_flight")
        self._tg_tps = tel.gauge("serve.decode_tok_per_sec")
        self._th_ttft = tel.histogram("serve.ttft_s")
        self._th_latency = tel.histogram("serve.request_latency_s")
        self._th_step = tel.histogram("serve.decode_step_s")
        self._t_submit = {}             # request_id -> perf_counter stamp
        self._ttft = {}                 # request_id -> observed TTFT
        self._decode_tokens = 0         # steady-state accounting (decode
        self._decode_time = 0.0         # steps only, admissions excluded)

        fns = build_serve_fns(cfg, self.model)
        self._decode = fns["decode"]          # donates the batch cache
        self._prefill1 = fns["prefill"]
        # B=1 decode for the tokenwise-prefill fallback. The admission
        # cache is engine-internal (rebound every step, then scattered into
        # the batch cache), so its buffers are donated like the batch
        # decode's — flagged by repro.analysis's donation rule.
        self._decode1 = jax.jit(
            lambda base, peft, cache, tok, pos: self.model.decode_step(
                cfg, base, peft, cache, tok, pos),
            donate_argnums=(2,))
        self._scatter = jax.jit(_scatter_row, donate_argnums=(0,))

        self.cache = self.model.init_cache(cfg, max_batch, cache_len)
        self._queue = deque()
        # host-side per-row state
        self._active = np.zeros(max_batch, bool)
        self._pos = np.zeros(max_batch, np.int32)
        self._plen = np.zeros(max_batch, np.int32)
        self._tok = np.zeros(max_batch, np.int32)
        self._page = np.zeros(max_batch, np.int32)
        self._aid = np.zeros(max_batch, np.int64)
        self._remaining = np.zeros(max_batch, np.int32)
        self._rid = [None] * max_batch
        self.outputs = {}
        self.steps = 0

    # -- admission -----------------------------------------------------------

    def submit(self, request: Request) -> None:
        if self.telemetry.enabled:
            self._t_submit[request.request_id] = time.perf_counter()
        self._queue.append(request)
        self._tg_queue.set(len(self._queue))

    def _admit(self, b: int, req: Request) -> None:
        prompt = jnp.asarray(req.prompt, jnp.int32).reshape(1, -1)
        P = prompt.shape[1]
        if P + req.max_new_tokens - 1 > self.cache_len:
            raise ValueError(
                f"request {req.request_id!r}: prompt {P} + "
                f"{req.max_new_tokens} new tokens exceeds cache_len "
                f"{self.cache_len}")
        with self.telemetry.span("serve.admit", request=req.request_id,
                                 prompt_len=int(P)):
            page = self.adapters.pin(req.adapter_id)
            peft1 = self.adapters.page_tree(page)
            cache1 = self.model.init_cache(self.cfg, 1, self.cache_len)
            if req.frames is not None:
                frames = jnp.asarray(req.frames)
                if frames.ndim == 2:
                    frames = frames[None]
                memory = encdec_encode(self.cfg, self.base, frames, peft1)
                cache1 = dict(cache1,
                              memory=memory.astype(cache1["memory"].dtype))
            if self.fused_prefill and can_fuse_prefill(self.cfg, self.model,
                                                       cache1, P):
                logits, cache1 = self._prefill1(self.base, peft1, cache1,
                                                prompt)
            else:
                logits, cache1 = tokenwise_prefill(
                    self.cfg, self.model, self.base, peft1, cache1, prompt,
                    decode=self._decode1)
            self.cache = self._scatter(self.cache, cache1, b)
            t0 = int(jnp.argmax(logits[0]))
        self._active[b] = True
        self._pos[b] = P
        self._plen[b] = P
        self._tok[b] = t0
        self._page[b] = page
        self._aid[b] = req.adapter_id
        self._remaining[b] = req.max_new_tokens - 1
        self._rid[b] = req.request_id
        self.outputs[req.request_id] = [t0]
        if self.telemetry.enabled:
            # first token exists HERE (the prefill logits produced it):
            # time-to-first-token runs from submit to this point
            ttft = time.perf_counter() - self._t_submit.get(
                req.request_id, time.perf_counter())
            self._ttft[req.request_id] = ttft
            self._th_ttft.observe(ttft)
            self._tc_requests.inc()
            self._tg_queue.set(len(self._queue))
        if self._remaining[b] == 0:
            self._finish(b)

    def _finish(self, b: int) -> None:
        self._active[b] = False
        self.adapters.unpin(int(self._aid[b]))
        if self.telemetry.enabled:
            rid = self._rid[b]
            done = time.perf_counter()
            latency = done - self._t_submit.pop(rid, done)
            self._th_latency.observe(latency)
            n_tok = len(self.outputs.get(rid, ()))
            self._tc_tokens.add(n_tok)
            self.telemetry.event(
                "request",
                request_id=rid,
                adapter_id=int(self._aid[b]),
                prompt_len=int(self._plen[b]),
                gen_tokens=n_tok,
                ttft_s=round(self._ttft.pop(rid, float("nan")), 6),
                latency_s=round(latency, 6),
                tok_per_sec=(round(n_tok / latency, 3) if latency > 0
                             else None),
            )
        self._rid[b] = None

    # -- stepping ------------------------------------------------------------

    def step(self) -> int:
        """Admit waiting requests into free rows, then run ONE batched
        decode step over the in-flight rows. Returns the number of rows
        still active (0 -> drained)."""
        for b in range(self.max_batch):
            if not self._queue:
                break
            if not self._active[b]:
                self._admit(b, self._queue.popleft())
        if not self._active.any():
            return 0

        # inactive rows ride along with page 0 / pos 0 / token 0; every
        # batched op is row-independent, so their garbage never reaches an
        # active row, and their outputs are simply dropped here
        pages = np.where(self._active, self._page, 0)
        peft = self.adapters.multi_peft(pages)
        tok = jnp.asarray(np.where(self._active, self._tok, 0),
                          jnp.int32)[:, None]
        pos = jnp.asarray(np.where(self._active, self._pos, 0), jnp.int32)
        n_active = int(self._active.sum())
        t_step = time.perf_counter() if self.telemetry.enabled else 0.0
        logits, self.cache = self._decode(self.base, peft, self.cache, tok,
                                          pos)
        self.steps += 1
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        if self.telemetry.enabled:
            # next_tok is on host, so the decode step has fully resolved
            dt = time.perf_counter() - t_step
            self._record_step(dt, n_active)
        for b in range(self.max_batch):
            if not self._active[b]:
                continue
            self._tok[b] = next_tok[b]
            self._pos[b] += 1
            self._remaining[b] -= 1
            self.outputs[self._rid[b]].append(int(next_tok[b]))
            if self._remaining[b] == 0:
                self._finish(b)
        return int(self._active.sum())

    def _record_step(self, dt: float, n_active: int) -> None:
        self._tc_steps.inc()
        self._th_step.observe(dt)
        self._tg_inflight.set(n_active)
        # steady-state decode throughput: batched decode steps only, the
        # admission prefills (cold path) are deliberately excluded
        self._decode_tokens += n_active
        self._decode_time += dt
        if self._decode_time > 0:
            self._tg_tps.set(self._decode_tokens / self._decode_time)

    def run(self, requests=None):
        """Submit ``requests`` (if given) and step until drained. Returns
        {request_id: generated ids}."""
        for req in requests or ():
            self.submit(req)
        while self._queue or self._active.any():
            self.step()
        return dict(self.outputs)
