"""Static analysis of compiled HLO text with LOOP-AWARE accounting.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which undercounts
layer-scanned models by ~n_layers x (and chunk-scanned attention by the chunk
count). This module walks the call graph — while bodies multiplied by their
``known_trip_count``, fusion/call computations attributed per call site,
conditionals taken at max over branches — and produces:

    flops             2 * prod(dot output dims) * prod(contracting dims)
    dot_bytes         operand + output bytes of every dot (activation-traffic
                      proxy for the roofline memory term)
    collective_bytes  per collective kind (all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute)

All numbers are per-device: the module is the SPMD-partitioned program.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "u4": 1, "s4": 1, "f8e4m3": 1,
                "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_dims(shape_str):
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return None, []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


def _shape_bytes(shape_str):
    dtype, dims = _shape_dims(shape_str)
    if dtype is None:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class OpLine:
    name: str
    shape: str
    opcode: str
    raw: str


_COMP_HEADER = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+)\s*\{")
_OP_RE = re.compile(r"^(?:ROOT )?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")


def parse_computations(hlo_text):
    comps = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if cur is None:
            m = _COMP_HEADER.match(s)
            if m and (s.endswith("{")):
                cur = m.group(1)
                comps[cur] = {"ops": [], "params": {}}
                if line.startswith("ENTRY") or s.startswith("ENTRY"):
                    entry = cur
                # header params give shapes: "name: f32[8,16], ..."
                for p in _split_top(m.group(2)):
                    if ":" in p:
                        pname, pshape = p.split(":", 1)
                        comps[cur]["params"][pname.strip()] = pshape.strip()
                continue
            m2 = re.match(r"^ENTRY", s)
            continue
        if s == "}":
            cur = None
            continue
        m = _OP_RE.match(s)
        if m:
            comps[cur]["ops"].append(OpLine(m.group(1), m.group(2),
                                            m.group(3), s))
    return comps, entry


def _symbol_table(comp):
    """name -> shape string for every op + parameter in a computation."""
    table = dict(comp["params"])
    for op in comp["ops"]:
        table[op.name] = op.shape
    return table


def _split_top(s, sep=","):
    """Split on ``sep`` at bracket depth 0 — shape strings carry commas
    inside ``[dims]`` and layout ``{1,0}`` annotations."""
    parts, buf, depth = [], "", 0
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == sep and depth == 0:
            parts.append(buf.strip())
            buf = ""
        else:
            buf += ch
    if buf.strip():
        parts.append(buf.strip())
    return parts


def _operands(raw):
    """names of operands inside the top-level parens of `opcode(...)`."""
    i = raw.index("(")
    depth = 0
    args, buf = [], ""
    for ch in raw[i:]:
        if ch in "([{":
            depth += 1
            if depth == 1 and ch == "(":
                continue
        elif ch in ")]}":
            depth -= 1
            if depth == 0:
                if buf.strip():
                    args.append(buf.strip())
                break
        if depth >= 1:
            if ch == "," and depth == 1:
                args.append(buf.strip())
                buf = ""
            else:
                buf += ch
    names = []
    for a in args:
        m = re.search(r"%([\w\.\-]+)\s*$", a)
        names.append(m.group(1) if m else None)
    return names


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    dot_bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    # f32 collective bytes — on a bf16 model these are a CPU-backend
    # promotion artifact (verified: f32-param and bf16-param lowers produce
    # IDENTICAL collective bytes); a TPU runs them in native bf16 at half
    # the bytes. See EXPERIMENTS §Perf-1.
    collective_f32_bytes: float = 0.0

    def add(self, other, mult=1.0):
        self.flops += other.flops * mult
        self.dot_bytes += other.dot_bytes * mult
        self.collective_f32_bytes += other.collective_f32_bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] += v * mult


def _trip_count(raw):
    m = re.search(r'known_trip_count.{0,6}n.{0,4}?"(\d+)"', raw)
    if m:
        return int(m.group(1))
    return 1


def _called_comps(raw, key):
    m = re.search(key + r"=\{?([^,}]+(?:,\s*%[\w\.\-]+)*)\}?", raw)
    if not m:
        return []
    return [c.strip().lstrip("%") for c in m.group(1).split(",")]


def analyse_computation(name, comps, cache):
    if name in cache:
        return cache[name]
    comp = comps.get(name)
    t = Totals()
    if comp is None:
        cache[name] = t
        return t
    table = _symbol_table(comp)
    for op in comp["ops"]:
        if op.opcode == "dot":
            out_dtype, out_dims = _shape_dims(op.shape)
            contract = 1
            m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.raw)
            lhs_name = _operands(op.raw)[0]
            lhs_shape = table.get(lhs_name, "")
            _, lhs_dims = _shape_dims(lhs_shape or "")
            if m and lhs_dims:
                for d in m.group(1).split(","):
                    if d:
                        contract *= lhs_dims[int(d)]
            out_n = 1
            for d in out_dims:
                out_n *= d
            t.flops += 2.0 * out_n * contract
            t.dot_bytes += _shape_bytes(op.shape)
            for opr in _operands(op.raw):
                if opr and opr in table:
                    t.dot_bytes += _shape_bytes(table[opr])
        elif op.opcode == "while":
            body = _called_comps(op.raw, "body")
            trips = _trip_count(op.raw)
            for b in body:
                t.add(analyse_computation(b, comps, cache), trips)
            for c in _called_comps(op.raw, "condition"):
                t.add(analyse_computation(c, comps, cache), trips)
        elif op.opcode == "conditional":
            branches = _called_comps(op.raw, "branch_computations")
            if not branches:
                branches = (_called_comps(op.raw, "true_computation")
                            + _called_comps(op.raw, "false_computation"))
            if branches:
                subs = [analyse_computation(b, comps, cache) for b in branches]
                best = max(subs, key=lambda s: s.flops)
                t.add(best)
        elif op.opcode in ("fusion", "call", "async-start"):
            key = "calls" if op.opcode == "fusion" else "to_apply"
            for c in _called_comps(op.raw, key):
                t.add(analyse_computation(c, comps, cache))
        kind = None
        for c in COLLECTIVES:
            if op.opcode == c or op.opcode.startswith(c + "-"):
                kind = c
                break
        if kind:
            if op.shape.startswith("("):
                total = sum(_shape_bytes(s.strip())
                            for s in op.shape[1:-1].split(",") if "[" in s)
                is_f32 = "f32[" in op.shape
            else:
                total = _shape_bytes(op.shape)
                is_f32 = op.shape.startswith("f32[")
            t.collective_bytes[kind] += total
            t.collective_counts[kind] += 1
            if is_f32:
                t.collective_f32_bytes += total
    # NOTE: cache only pure computations (no context-dependent multipliers
    # inside) — safe because multipliers are applied by the caller.
    cache[name] = t
    return t


def bf16_upcast_bytes(hlo_text, min_bytes=50_000_000) -> float:
    """Bytes of large f32 copies produced by bf16->f32 ``convert`` ops.

    The XLA *CPU* backend emulates bf16 by materialising f32 copies of bf16
    parameters (weights, KV caches) — on gemma3-27b decode_32k these account
    for 23.1GB of the 24.0GB "temp" allocation (see EXPERIMENTS §Perf-2).
    A TPU backend computes in native bf16 and allocates none of them, so the
    dry-run report subtracts this to obtain the TPU-adjusted peak.
    """
    comps, entry = parse_computations(hlo_text)
    total = 0.0
    # only ENTRY-level convert fusions allocate standalone buffers; converts
    # nested inside other fused computations are fused into their consumers
    # (verified against the CPU buffer-assignment dump, §Perf-2)
    for cname, comp in comps.items():
        if cname != entry:
            continue
        table = _symbol_table(comp)
        for op in comp["ops"]:
            looks_convert = (op.opcode == "convert"
                             or op.name.startswith("wrapped_convert"))
            if not looks_convert:
                continue
            dtype, dims = _shape_dims(op.shape)
            if dtype != "f32":
                continue
            b = _shape_bytes(op.shape)
            if b < min_bytes:
                continue
            # operand must be a same-dims bf16 tensor
            ok = False
            for opr in _operands(op.raw):
                if opr and opr in table:
                    od, odims = _shape_dims(table[opr])
                    if od == "bf16" and odims == dims:
                        ok = True
            if ok:
                total += b
    return total


def _buffer_bytes(shape_str):
    """Bytes of one op's output allocation (tuple shapes sum elements)."""
    if shape_str.startswith("("):
        return float(sum(_shape_bytes(s.strip())
                         for s in _split_top(shape_str[1:-1]) if "[" in s))
    return float(_shape_bytes(shape_str))


def peak_live_bytes(hlo_text, include_params: bool = False) -> float:
    """Peak sum of live buffer bytes over a program-order walk of the ENTRY
    computation — a buffer-assignment-style liveness proxy for the compiled
    program's temp memory.

    Model: every entry-level op allocates its output buffer (fusion/call
    intermediates live in registers — only the fusion OUTPUT allocates,
    which matches XLA's one-buffer-per-entry-op assignment); an operand is
    freed after its last entry-level use; a while op additionally holds its
    body's peak while it runs (multiplied by 1 — iterations reuse the same
    body buffers); conditionals take the max over branches. Buffer
    aliasing/reuse by the real assigner makes this an upper-bound-flavoured
    proxy, exact on straight-line programs — see tests/test_hlo_analysis.py.

    ``include_params=True`` also counts entry parameters as live from the
    start until their last use.
    """
    comps, entry = parse_computations(hlo_text)
    if entry is None:
        return 0.0
    cache = {}
    return _peak_live(entry, comps, cache, include_params)


def _peak_live(name, comps, cache, include_params=False):
    key = (name, include_params)
    if key in cache:
        return cache[key]
    comp = comps.get(name)
    if comp is None:
        cache[key] = 0.0
        return 0.0
    ops = comp["ops"]
    last_use = {}
    op_operands = []
    for i, op in enumerate(ops):
        names = [n for n in _operands(op.raw) if n]
        op_operands.append(names)
        for n in names:
            last_use[n] = i
    sizes = {}
    live = 0.0
    if include_params:
        for pname, pshape in comp["params"].items():
            sizes[pname] = _buffer_bytes(pshape)
            live += sizes[pname]
    peak = live
    for i, op in enumerate(ops):
        out_b = _buffer_bytes(op.shape)
        sizes[op.name] = out_b
        live += out_b
        inner = 0.0
        if op.opcode == "while":
            for b in (_called_comps(op.raw, "body")
                      + _called_comps(op.raw, "condition")):
                inner = max(inner, _peak_live(b, comps, cache))
        elif op.opcode == "conditional":
            branches = _called_comps(op.raw, "branch_computations")
            if not branches:
                branches = (_called_comps(op.raw, "true_computation")
                            + _called_comps(op.raw, "false_computation"))
            for b in branches:
                inner = max(inner, _peak_live(b, comps, cache))
        peak = max(peak, live + inner)
        for n in op_operands[i]:
            if last_use.get(n) == i and n in sizes:
                live -= sizes.pop(n)
    cache[key] = peak
    return peak


def analyse_hlo(hlo_text) -> Totals:
    comps, entry = parse_computations(hlo_text)
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c]["ops"])) if comps else None
    cache = {}
    if entry is None:
        return Totals()
    return analyse_computation(entry, comps, cache)


# ---------------------------------------------------------------------------
# donation / aliasing: entry parameters vs input_output_alias
# ---------------------------------------------------------------------------

_ALIAS_BLOCK_RE = re.compile(r"input_output_alias=\{(.*?)\}[,\s]", re.S)
_ALIAS_ENTRY_RE = re.compile(r"\{([0-9,\s]*)\}\s*:\s*\((\d+)\s*,")
_ENTRY_LAYOUT_RE = re.compile(
    r"entry_computation_layout=\{\((.*?)\)\s*->", re.S)


def parse_input_output_aliases(hlo_text):
    """The module-level ``input_output_alias`` map of compiled HLO text as
    ``{param_index: output_index}`` (tuple output indices flattened to their
    leading position). Empty dict when the program donates nothing."""
    m = _ALIAS_BLOCK_RE.search(hlo_text)
    if not m:
        return {}
    aliases = {}
    for out_idx, param in _ALIAS_ENTRY_RE.findall(m.group(1)):
        first = out_idx.split(",")[0].strip()
        aliases[int(param)] = int(first) if first else 0
    return aliases


def entry_parameter_bytes(hlo_text):
    """Byte size of each entry parameter, in parameter order, from the
    ``entry_computation_layout`` line (falls back to the ENTRY header's
    parameter list for hand-written HLO)."""
    m = _ENTRY_LAYOUT_RE.search(hlo_text)
    if m:
        return [_shape_bytes(p.strip())
                for p in _split_top(m.group(1)) if p.strip()]
    comps, entry = parse_computations(hlo_text)
    if entry is None:
        return []
    out = []
    for raw in _split_top(comps[entry]["params"]):
        if ":" in raw:
            out.append(_shape_bytes(raw.split(":", 1)[1].strip()))
    return out


def undonated_param_bytes(hlo_text, min_bytes=1 << 20):
    """Parameters of at least ``min_bytes`` NOT covered by an
    input_output_alias entry: ``[(param_index, nbytes), ...]``. The HLO-text
    mirror of the jaxpr-level donation rule (``repro.analysis.rules``),
    usable on dryrun/launch artifacts where only compiled text survives."""
    aliases = parse_input_output_aliases(hlo_text)
    return [(i, b) for i, b in enumerate(entry_parameter_bytes(hlo_text))
            if b >= min_bytes and i not in aliases]
