import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape) combination
for the production meshes, and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

The XLA_FLAGS line above MUST run before any other jax-touching import:
jax locks the device count on first backend init. 512 host devices cover
both the 256-chip single-pod mesh and the 2x256 multi-pod mesh.
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    get_config,
    get_shape,
    shape_applicable,
)
from repro.core.spry import make_round_step
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib
from repro.models.registry import get_model
from repro.models.partitioning import sharding_hints
from repro.launch.mesh import make_production_mesh
from jax.sharding import PartitionSpec as P

# v5e hardware constants for the roofline report
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Parse an HLO shape like 'bf16[16,128,4096]{...}' -> byte count."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dtype, dims = m.groups()
    sizes = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
             "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
             "f64": 8, "c64": 8}
    unit = sizes.get(dtype, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * unit if dims else unit


def decode_hints(cfg, shape, mesh, cache):
    """PartitionSpecs pinning the decode attention data flow: tiny per-step
    tensors (q / attn out) replicated over 'model', the big KV cache
    sequence-sharded. Axes are pruned when a dim is not divisible."""
    d = mesh_lib.data_axes(mesh)
    d = d[0] if len(d) == 1 else d
    B = shape.global_batch
    b_axis = d if B % mesh_lib.axis_size(mesh, d) == 0 else None
    hints = {"decode_q": P(b_axis, None, None, None)}
    k = cache.get("k") if isinstance(cache, dict) else None
    if k is not None:
        Sc = k.shape[2]
        s_axis = "model" if Sc % mesh_lib.axis_size(mesh, "model") == 0 else None
        hints["decode_cache"] = P(b_axis, s_axis, None, None)
    return hints


def prefill_hints(cfg, shape, mesh):
    """Prefill sharding hints (EXPERIMENTS §Perf-3 / §Perf-1-family).

    1. Residual h pinned batch-sharded over the data axes: without this,
       GSPMD drops the batch sharding inside the layer scan of FSDP'd
       models (to avoid gathering D-sharded weights) and computes the whole
       batch REDUNDANTLY on every data slice — 16x wasted FLOPs and 12.9GB
       f32 score buffers at 32k (found via buffer-assignment dump).
    2. Context-parallel attention for archs whose head count does not
       divide the model axis (llama4 H=40, whisper H=6) — see
       attn_block_prefill. NOTE: sequence-sharding the residual was REFUTED
       (it moves the conflict into the MoE einsums); batch-sharding
       composes fine.
    """
    d = mesh_lib.data_axes(mesh)
    d = d[0] if len(d) == 1 else d
    B = shape.global_batch
    b_axis = d if B % mesh_lib.axis_size(mesh, d) == 0 else None
    hints = {"prefill_h": P(b_axis, None, None)}
    msize = mesh_lib.axis_size(mesh, "model")
    if cfg.n_heads % msize != 0:
        # q/out: (B, S, H, hd) — shard S over model; k/v gathered
        hints["prefill_q"] = P(b_axis, "model", None, None)
        hints["prefill_kv"] = P(b_axis, None, None, None)
    return hints


def lower_case(arch: str, shape_name: str, multi_pod: bool,
               kv_int8: bool = False):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_data = mesh_lib.axis_size(mesh, mesh_lib.data_axes(mesh))

    if shape.kind == "train":
        n_clients = n_data                      # one simulated cohort per data slice
        spry_cfg = specs_lib.spry_config_for(cfg, shape, n_clients)
        state = specs_lib.eval_state(cfg, spry_cfg)
        batch = specs_lib.train_batch_specs(cfg, shape, n_clients)
        step = make_round_step(cfg, spry_cfg, task="lm")
        in_shardings = (
            type(state)(
                base=mesh_lib.base_shardings(cfg, mesh, state.base),
                peft=mesh_lib.replicated(mesh, state.peft),
                server=mesh_lib.replicated(mesh, state.server),
                round_idx=mesh_lib.replicated(mesh, state.round_idx),
            ),
            mesh_lib.train_batch_shardings(mesh, batch),
        )
        args = (state, batch)
        fn = step
        donate = (0,)   # SpryState is threaded round-to-round
    elif shape.kind == "prefill":
        spry_cfg = specs_lib.spry_config_for(cfg, shape, n_data)
        state = specs_lib.eval_state(cfg, spry_cfg)
        batch = specs_lib.prefill_batch_specs(cfg, shape)
        model = get_model(cfg)
        hints = prefill_hints(cfg, shape, mesh)

        def fn(base, peft, batch):
            # serving runs the adapters in bf16 (f32 LoRA intermediates at
            # 32k tokens cost 3.2GB each on the big archs; §Perf notes)
            from repro.utils.pytree import tree_cast
            peft = tree_cast(peft, cfg.dtype)
            with sharding_hints(hints):
                h, _ = model.forward(cfg, base, peft, batch)
                return (h[:, -1, :] @ model.unembed(cfg, base)).astype(jnp.float32)

        in_shardings = (
            mesh_lib.base_shardings(cfg, mesh, state.base),
            mesh_lib.replicated(mesh, state.peft),
            mesh_lib.serve_batch_shardings(mesh, batch),
        )
        args = (state.base, state.peft, batch)
        donate = ()
    else:  # decode
        spry_cfg = specs_lib.spry_config_for(cfg, shape, n_data)
        state = specs_lib.eval_state(cfg, spry_cfg)
        cache, token, pos = specs_lib.decode_specs(cfg, shape, kv_int8=kv_int8)
        model = get_model(cfg)
        hints = decode_hints(cfg, shape, mesh, cache)

        def fn(base, peft, cache, token, pos):
            with sharding_hints(hints):
                return model.decode_step(cfg, base, peft, cache, token, pos)

        in_shardings = (
            mesh_lib.base_shardings(cfg, mesh, state.base),
            mesh_lib.replicated(mesh, state.peft),
            mesh_lib.cache_shardings(cfg, mesh, cache),
            mesh_lib.serve_batch_shardings(mesh, {"t": token})["t"],
            mesh_lib.replicated(mesh, pos),
        )
        args = (state.base, state.peft, cache, token, pos)
        donate = (2,)   # the KV/state cache is updated in place

    with mesh:
        t0 = time.time()
        lowered = jax.jit(fn, in_shardings=in_shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return cfg, shape, mesh, lowered, compiled, t_lower, t_compile


def analyse(cfg, shape, mesh, lowered, compiled, multi_pod: bool):
    from repro.launch.hlo_analysis import analyse_hlo, bf16_upcast_bytes

    n_chips = 512 if multi_pod else 256
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):    # newer jax: list of per-program dicts
        cost = cost[0] if cost else None
    hlo = compiled.as_text()

    # XLA's cost_analysis counts while bodies ONCE; the loop-aware static
    # analyser multiplies by known_trip_count (layers, q-chunks, MoE chunks).
    totals = analyse_hlo(hlo)
    upcast = bf16_upcast_bytes(hlo)
    flops = totals.flops
    xla_flops = float(cost.get("flops", 0.0)) if cost else 0.0
    xla_bytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    coll = {k: float(v) for k, v in totals.collective_bytes.items()}
    coll_counts = {k: float(v) for k, v in totals.collective_counts.items()}
    coll_total = float(sum(coll.values()))
    # TPU adjustment: f32 collectives on a bf16 model are a CPU-backend
    # promotion (verified by the f32-vs-bf16 param A/B in §Perf-1); native
    # bf16 halves those bytes on the target hardware
    if cfg.dtype == jnp.bfloat16:
        coll_tpu = coll_total - 0.5 * float(totals.collective_f32_bytes)
    else:
        coll_tpu = coll_total
    # memory-traffic proxy: loop-aware dot operand/output bytes (weights are
    # read once per trip via per-layer slices) vs XLA's body-once number
    bytes_acc = max(xla_bytes, totals.dot_bytes)

    # all numbers are per-device: the module is SPMD-partitioned
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_collective = coll_tpu / ICI_BW

    n_dense = cfg.n_param_estimate()
    n_active = cfg.n_active_param_estimate()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        # forward + jvp-tangent pass (no backward): ~2x forward = 4*N*D
        model_flops = 4.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch * 1
        model_flops = 2.0 * n_active * tokens

    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    return {
        "arch": cfg.arch_id,
        "shape": shape.name,
        "mesh": list(mesh.devices.shape),
        "n_chips": n_chips,
        "per_device": {
            "flops": flops,
            "bytes_accessed": bytes_acc,
            "collective_bytes": coll,
            "collective_counts": coll_counts,
            "collective_bytes_total": coll_total,
            "collective_bytes_tpu_adj": coll_tpu,
            "collective_f32_bytes": float(totals.collective_f32_bytes),
            "xla_body_once_flops": xla_flops,
            "xla_body_once_bytes": xla_bytes,
            "dot_bytes": totals.dot_bytes,
        },
        "memory_analysis": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
                          + (getattr(mem, "argument_size_in_bytes", 0) or 0),
            # the CPU backend materialises f32 copies of bf16 params/caches
            # (native on TPU): subtracted to obtain the deployable peak
            "cpu_bf16_upcast_bytes": upcast,
            "tpu_adjusted_peak": max(
                0.0,
                (getattr(mem, "temp_size_in_bytes", 0) or 0)
                + (getattr(mem, "argument_size_in_bytes", 0) or 0) - upcast),
        },
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops_global": model_flops,
            "model_flops_per_chip": model_flops / n_chips,
            "useful_flop_ratio": (model_flops / n_chips) / flops if flops else None,
        },
    }


def run_case(arch: str, shape_name: str, multi_pod: bool, out_dir: str | None,
             kv_int8: bool = False):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not shape_applicable(cfg, shape):
        rec = {"arch": arch, "shape": shape_name,
               "mesh": [2, 16, 16] if multi_pod else [16, 16],
               "skipped": True,
               "reason": "full-attention arch excluded from long_500k "
                         "(DESIGN.md §5)"}
        print(json.dumps(rec))
        return rec
    cfg, shape, mesh, lowered, compiled, t_lower, t_compile = lower_case(
        arch, shape_name, multi_pod, kv_int8=kv_int8)
    rec = analyse(cfg, shape, mesh, lowered, compiled, multi_pod)
    rec["t_lower_s"] = round(t_lower, 2)
    rec["t_compile_s"] = round(t_compile, 2)
    mem = rec["memory_analysis"]
    print(f"[dryrun] {arch} x {shape_name} mesh={rec['mesh']} "
          f"flops/dev={rec['per_device']['flops']:.3e} "
          f"coll/dev={rec['per_device']['collective_bytes_total']:.3e}B "
          f"peak={mem['peak_bytes']/1e9 if mem['peak_bytes'] else 0:.2f}GB "
          f"(tpu-adj {mem['tpu_adjusted_peak']/1e9:.2f}GB) "
          f"dominant={rec['roofline']['dominant']} "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = "pod2" if multi_pod else "pod1"
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV cache for decode shapes (beyond-paper)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                try:
                    run_case(a, s, mp, args.out, kv_int8=args.kv_int8)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append((a, s, mp, repr(e)))
                    print(f"[dryrun] FAIL {a} x {s} multi_pod={mp}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("[dryrun] all requested combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
