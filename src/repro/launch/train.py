"""FL-simulation training driver (CPU-runnable; the multi-device path is
exercised by dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch roberta-large-lora \
        --task sst2 --method spry --rounds 200 --clients 8

Runs the full paper pipeline: synthetic task -> Dirichlet partition ->
client sampling -> jitted round step (SPRY or a baseline) -> server update,
with periodic generalized/personalized evaluation.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SpryConfig, get_config, reduce_config
from repro.core import (
    estimator_route,
    init_state,
    make_round_step,
    make_round_step_per_iteration,
    run_fields,
)
from repro.core.baselines import make_backprop_round_step, make_zeroorder_round_step
from repro.core.baselines.zeroorder import ZOState, init_zo_state

# round-state donation through the jitted step: CPU sometimes declines
# individual buffers — harmless, not worth a per-round warning
warnings.filterwarnings("ignore",
                        message="Some donated buffers were not usable")
from repro.data import make_task
from repro.data.loader import ClientDataset, stack_client_batches
from repro.fl import dirichlet_partition, sample_clients
from repro.models import cls_logits, get_model
from repro.models.common import accuracy_from_logits
from repro.obs import NULL, MemoryProbe, make_telemetry
from repro.peft import init_peft


METHODS = ("spry", "spry_periter", "fedavg", "fedyogi", "fedsgd",
           "fedavgsplit", "fedfgd", "fedmezo", "baffle", "fwdllm")


def personalized_accuracy(cfg, state, clients, x, y, rng, steps=5,
                          lr=5e-2, batch_size=8, max_clients=8):
    """Paper's Acc_p: each client finetunes the trainable head on its own
    shard (the personalisation layers SPRY assigns to every client, §3.1)
    and is evaluated on its own held-out samples."""
    from repro.core.forward_grad import forward_gradient
    from repro.models.registry import cls_loss

    accs = []
    for c in clients[:max_clients]:
        idx = c.indices
        if len(idx) < 4:
            continue
        cut = max(2, int(0.8 * len(idx)))
        tr, te = idx[:cut], idx[cut:]
        peft = state.peft
        for s in range(steps):
            take = rng.choice(tr, size=min(batch_size, len(tr)), replace=False)
            batch = {"tokens": jnp.asarray(x[take]),
                     "labels": jnp.asarray(y[take])}
            # head-only forward-gradient step (stays in the paper's paradigm)
            head_mask = {g: jax.tree.map(
                lambda leaf: jnp.float32(1.0 if g == "head" else 0.0), t)
                for g, t in peft.items()}
            _, g, _ = forward_gradient(
                lambda p: cls_loss(cfg, state.base, p, batch),
                peft, jax.random.PRNGKey(int(take[0]) + s),
                mask_tree=head_mask)
            peft = jax.tree.map(lambda p_, g_: p_ - lr * g_, peft, g)
        logits = cls_logits(cfg, state.base, peft,
                            {"tokens": jnp.asarray(x[te])})
        accs.append(float(accuracy_from_logits(logits, jnp.asarray(y[te]))))
    return float(np.mean(accs)) if accs else float("nan")


def build_round_step(cfg, sc: SpryConfig, method: str, task="cls"):
    if method == "spry":
        return make_round_step(cfg, sc, task), "spry"
    if method == "spry_periter":
        return make_round_step_per_iteration(cfg, sc, task), "spry"
    if method == "fedfgd":
        # forward gradients WITHOUT splitting: every client perturbs all units
        return make_round_step(cfg, sc, task, split=False), "spry"
    if method in ("fedavg", "fedyogi", "fedsgd"):
        return make_backprop_round_step(cfg, sc, task, method=method), "bp"
    if method == "fedavgsplit":
        return make_backprop_round_step(cfg, sc, task, method="fedavg",
                                        split=True), "bp"
    if method in ("fedmezo", "baffle", "fwdllm"):
        return make_zeroorder_round_step(cfg, sc, task, method=method), "zo"
    raise ValueError(method)


def run_training(arch="roberta-large-lora", task="sst2", method="spry",
                 rounds=100, clients_per_round=8, total_clients=32,
                 batch_size=8, local_iters=1, local_lr=None, server_lr=None,
                 dirichlet_alpha=0.1, seed=0, eval_every=10, reduced=True,
                 k_perturbations=1, jvp_clip=None, tangent_batch=None,
                 fused_contraction=False, log=print,
                 runtime=False, runtime_executor="serial",
                 runtime_microbatch=None, over_select=1.0, deadline=None,
                 dropout_rate=0.0, wire_dtype="fp32", wire_simulate=False,
                 telemetry=None, faults=None, quorum=None,
                 checkpoint_dir=None, checkpoint_every=1, resume=False,
                 async_mode=False, buffer_size=4, staleness_decay=0.5,
                 async_concurrency=None, max_staleness=None):
    tel = telemetry if telemetry is not None else NULL
    if async_mode:
        # the async engine IS a runtime path (population + wire frames)
        runtime = True
    # fault injection rides the simulated wire (frames must exist to be
    # corrupted), so --faults implies --wire-simulate on the runtime path
    # (the async engine always frames its uplink)
    from repro.fl.runtime.faults import FaultConfig
    if isinstance(faults, str):
        faults = FaultConfig.parse(faults, seed=seed)
    if faults is not None and not faults.any_faults:
        faults = None
    if faults is not None:
        if not runtime:
            raise ValueError("--faults requires --runtime (the chaotic wire "
                             "lives in the federation engine)")
        wire_simulate = True
    cfg = get_config(arch)
    if reduced:
        cfg = reduce_config(cfg)
    x_tr, y_tr, x_te, y_te = make_task(task, seed=seed, vocab=cfg.vocab)
    cfg = dataclasses.replace(cfg, n_classes=int(y_tr.max()) + 1)

    defaults = {
        "spry": (5e-3, 1e-2), "spry_periter": (5e-3, 1e-2),
        "fedfgd": (5e-3, 1e-2),
        "fedavg": (5e-2, 1.0), "fedyogi": (5e-2, 1e-2), "fedsgd": (5e-2, 1.0),
        "fedavgsplit": (5e-2, 1.0),
        "fedmezo": (5e-3, 1e-2), "baffle": (5e-3, 1e-2), "fwdllm": (5e-3, 1e-2),
    }
    d_lr, d_slr = defaults[method]
    sc = SpryConfig(
        n_clients_per_round=clients_per_round,
        n_total_clients=total_clients,
        local_iters=local_iters,
        local_lr=local_lr if local_lr is not None else d_lr,
        server_lr=server_lr if server_lr is not None else d_slr,
        k_perturbations=k_perturbations,
        jvp_clip=jvp_clip,
        tangent_batch=tangent_batch,
        fused_contraction=fused_contraction,
        dirichlet_alpha=dirichlet_alpha,
        server_opt="fedavg" if method in ("fedavg", "fedsgd", "fedavgsplit")
        else "fedyogi",
        seed=seed,
    )

    route = estimator_route(sc)
    if tel.enabled:
        tel.event("run_meta", workload="train", method=method, arch=arch,
                  task=task, rounds=rounds, clients_per_round=clients_per_round,
                  total_clients=total_clients, batch_size=batch_size,
                  runtime=runtime, seed=seed, **run_fields(sc))
    if method in ("spry", "spry_periter", "fedfgd"):
        # surface the active gradient-estimator route (satellite of the
        # split-forward refactor: --fused-contraction no longer falls back
        # silently — the registry split losses serve every family, and the
        # estimator warns if it still receives an unsplittable loss)
        log(f"[{method}] estimator route: {route}"
            + (" (in-kernel jvp-contraction at the final mixer site)"
               if route == "fused" else ""))

    rng = np.random.default_rng(seed)

    key = jax.random.PRNGKey(seed)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)

    engine = scheduler = None
    if runtime:
        # federation-runtime path: logical client population with lazy
        # Dirichlet shards + cohort scheduler + message-level round engine
        # (or, with --async, the event-driven FedBuff engine)
        from repro.core.assignment import enumerate_units
        from repro.fl.runtime import (
            AsyncConfig, AsyncFederationEngine, ClientPopulation,
            CohortScheduler, FederationEngine, SerialExecutor,
            ShardedExecutor, WireConfig)
        if method not in ("spry", "spry_periter"):
            raise ValueError(f"--runtime supports spry/spry_periter, "
                             f"not {method!r}")
        comm_mode = "per_epoch" if method == "spry" else "per_iteration"
        population = ClientPopulation(
            x_tr, y_tr, n_clients=total_clients, alpha=dirichlet_alpha,
            seed=seed)
        if async_mode:
            engine = AsyncFederationEngine(
                cfg, sc, population, task="cls", comm_mode=comm_mode,
                async_cfg=AsyncConfig(
                    buffer_size=buffer_size,
                    staleness_decay=staleness_decay,
                    concurrency=(async_concurrency if async_concurrency
                                 else max(clients_per_round, buffer_size)),
                    max_staleness=max_staleness, seed=seed),
                wire=WireConfig(dtype=wire_dtype, simulate=True),
                telemetry=tel, faults=faults)
        else:
            scheduler = CohortScheduler(
                population, clients_per_round, over_select=over_select,
                deadline=deadline, dropout_rate=dropout_rate, seed=seed)
            executor = (ShardedExecutor(microbatch=runtime_microbatch)
                        if runtime_executor == "sharded"
                        else SerialExecutor(microbatch=runtime_microbatch))
            engine = FederationEngine(
                cfg, sc, task="cls", comm_mode=comm_mode, executor=executor,
                wire=WireConfig(dtype=wire_dtype, simulate=wire_simulate),
                telemetry=tel, faults=faults, quorum=quorum)
            n_units = enumerate_units(state.peft).n_units
        client_data = [ClientDataset(x_tr, y_tr, population.shard(c))
                       for c in range(min(total_clients, 8))]
    else:
        parts = dirichlet_partition(y_tr, total_clients, dirichlet_alpha,
                                    seed=seed)
        client_data = [ClientDataset(x_tr, y_tr, idx) for idx in parts]

    if engine is None:
        step_fn, kind = build_round_step(cfg, sc, method)
        # the round state is threaded round-to-round and never re-read, so
        # its buffers update in place (CPU may decline — that is fine)
        step_fn = jax.jit(step_fn, donate_argnums=(0,))
        if kind == "zo":
            state = init_zo_state(state)

    eval_logits = jax.jit(lambda st, xb: cls_logits(
        cfg, st.base, st.peft, {"tokens": xb}))

    def the_state(s):
        return s.inner if isinstance(s, ZOState) else s

    def eval_personalized():
        st = the_state(state)
        return personalized_accuracy(cfg, st, client_data, x_tr, y_tr, rng)

    history = []
    bytes_up_total = bytes_down_total = 0
    start_round = 0
    if resume:
        # crash-safe resume: the manifest carries everything the loop
        # consumes host-side (round idx, host RNG state, history, byte
        # totals); the jitted round key is fold_in(PRNGKey(seed),
        # round_idx), so restoring the state + round index replays the
        # remaining trajectory bit-identically
        from repro.checkpoint import load_checkpoint
        if not checkpoint_dir:
            raise ValueError("--resume requires --checkpoint-dir")
        state, man = load_checkpoint(checkpoint_dir, state)
        if man.algo_seed != seed:
            raise ValueError(f"checkpoint seed {man.algo_seed} != run seed "
                             f"{seed}: refusing to splice trajectories")
        start_round = man.round_idx
        history = list(man.history)
        bytes_up_total = int(man.extra.get("bytes_up_total", 0))
        bytes_down_total = int(man.extra.get("bytes_down_total", 0))
        if man.rng_state is not None:
            rng.bit_generator.state = man.rng_state
        if async_mode:
            # async determinism rides on the virtual-time snapshot: the
            # event heap (in-flight frames byte-for-byte), the staleness
            # buffer, the clock, and the dispatch counter
            from repro.checkpoint import decode_async_snapshot
            if "async" not in man.extra:
                raise ValueError("--async --resume needs a checkpoint "
                                 "written by an async run (no snapshot in "
                                 "the manifest)")
            engine.restore(decode_async_snapshot(man.extra["async"]))
        log(f"[{method}] resumed from {checkpoint_dir} at round "
            f"{start_round}")

    def maybe_checkpoint(r):
        if not checkpoint_dir:
            return
        if (r + 1) % max(1, checkpoint_every) != 0 and r != rounds - 1:
            return
        from repro.checkpoint import encode_async_snapshot, save_checkpoint
        extra = {"bytes_up_total": bytes_up_total,
                 "bytes_down_total": bytes_down_total}
        if async_mode:
            extra["async"] = encode_async_snapshot(engine.snapshot())
        save_checkpoint(
            checkpoint_dir, state, round_idx=r + 1, algo_seed=seed,
            rng_state=rng.bit_generator.state, history=history,
            extra=extra)

    probe = MemoryProbe(tel) if tel.enabled else None
    t0 = time.time()
    if start_round >= rounds:
        # the checkpoint already covers the whole run; only the final
        # personalized eval may be outstanding
        if history and "personalized_acc" not in history[-1]:
            history[-1]["personalized_acc"] = eval_personalized()
            log(f"[{method}] personalized_acc="
                f"{history[-1]['personalized_acc']:.4f}")
        return history
    for r in range(start_round, rounds):
        t_round = time.perf_counter()
        if engine is not None and async_mode:
            state, metrics, report = engine.run_version(state, batch_size)
            # async reports carry ENGINE-LIFETIME byte totals (restored
            # across resume by the snapshot) — assign, don't accumulate
            bytes_up_total = report.bytes_up
            bytes_down_total = report.bytes_down
        elif engine is not None:
            plan = scheduler.plan_round(r, n_units, sc.seed)
            bx, by = scheduler.round_batch(plan, batch_size)
            state, metrics, report = engine.run_round(
                state, plan, {"tokens": jnp.asarray(bx),
                              "labels": jnp.asarray(by)})
            bytes_up_total += report.bytes_up
            bytes_down_total += report.bytes_down
        else:
            chosen = sample_clients(rng, total_clients, clients_per_round)
            bx, by = stack_client_batches([client_data[c] for c in chosen],
                                          rng, batch_size)
            with tel.span("train.round", round=r, method=method):
                state, metrics = step_fn(state, {"tokens": jnp.asarray(bx),
                                                 "labels": jnp.asarray(by)})
            if tel.enabled:
                # engine emits "round" events itself on the runtime path;
                # the in-process path emits its own here (one per round)
                ev = {"round": r, "method": method,
                      "loss": float(metrics["loss"]),
                      "wall_s": round(time.perf_counter() - t_round, 6)}
                for k in ("jvp_abs_mean", "delta_norm"):
                    if k in metrics:
                        ev[k] = float(metrics[k])
                if "fused_route" in metrics:
                    ev["route"] = ("fused" if float(metrics["fused_route"])
                                   else "standard")
                tel.event("round", **ev)
        if probe is not None and r == 0:
            probe.sample("post_round_1")
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            st = the_state(state)
            accs = []
            for i in range(0, min(len(x_te), 512), 64):
                lg = eval_logits(st, jnp.asarray(x_te[i:i + 64]))
                accs.append(np.asarray(
                    accuracy_from_logits(lg, jnp.asarray(y_te[i:i + 64]))))
            acc = float(np.mean(accs))
            entry = {"round": r + 1, "acc": acc,
                     "loss": float(metrics["loss"]),
                     "t": time.time() - t0}
            if "fused_route" in metrics:
                entry["route"] = ("fused" if float(metrics["fused_route"])
                                  else "standard")
            extra = ""
            if engine is not None and async_mode:
                entry["bytes_up"] = bytes_up_total
                entry["bytes_down"] = bytes_down_total
                extra = (f" up={bytes_up_total/1e6:.2f}MB "
                         f"sim_t={report.sim_time_s:.0f}s "
                         f"staleness={np.mean(report.staleness):.1f} "
                         f"util={report.utilization:.2f}")
            elif engine is not None:
                entry["bytes_up"] = bytes_up_total
                entry["bytes_down"] = bytes_down_total
                extra = (f" up={bytes_up_total/1e6:.2f}MB "
                         f"down={bytes_down_total/1e6:.2f}MB "
                         f"survivors={report.n_validated}/"
                         f"{report.cohort_size}")
                if report.round_skipped:
                    extra += " [below quorum: round skipped]"
            history.append(entry)
            if tel.enabled:
                ev = {k: v for k, v in entry.items() if k != "t"}
                ev["round"] = r   # 0-based, matching the "round" events
                tel.event("eval", **ev)
            log(f"[{method}] round {r+1:4d} loss={float(metrics['loss']):.4f} "
                f"test_acc={acc:.4f} ({time.time()-t0:.0f}s){extra}")
        maybe_checkpoint(r)
    history[-1]["personalized_acc"] = eval_personalized()
    if tel.enabled:
        probe.sample("end_of_run")
        tel.event("personalized_eval",
                  personalized_acc=history[-1]["personalized_acc"])
    log(f"[{method}] personalized_acc={history[-1]['personalized_acc']:.4f}")
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="roberta-large-lora")
    ap.add_argument("--task", default="sst2")
    ap.add_argument("--method", default="spry", choices=METHODS)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--total-clients", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--local-iters", type=int, default=1)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--server-lr", type=float, default=None)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--jvp-clip", type=float, default=None)
    ap.add_argument("--tangent-batch", type=int, default=None,
                    help="tangents per batched estimator pass (None = all "
                         "K; 1 = sequential; 1<b<K = scanned groups of b)")
    ap.add_argument("--fused-contraction", action="store_true",
                    help="contract final-mixer-site tangents against the "
                         "post-head cotangent in-kernel (effective for "
                         "losses that declare a fused site)")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full (unreduced) architecture")
    ap.add_argument("--runtime", action="store_true",
                    help="drive rounds through the federation runtime "
                         "(fl/runtime: scheduler -> executor -> engine)")
    ap.add_argument("--runtime-executor", default="serial",
                    choices=("serial", "sharded"))
    ap.add_argument("--runtime-microbatch", type=int, default=None,
                    help="clients per executor vmap chunk (None = whole "
                         "cohort; finite = streaming aggregation)")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="event-driven FedBuff engine: clients stream "
                         "updates as they finish; the server aggregates "
                         "the first --buffer-size validated arrivals with "
                         "staleness-weighted combination (implies "
                         "--runtime)")
    ap.add_argument("--buffer-size", type=int, default=4,
                    help="async: validated arrivals per server step (B)")
    ap.add_argument("--staleness-decay", type=float, default=0.5,
                    help="async: a in w = 1/(1+s)^a (0 = ignore staleness)")
    ap.add_argument("--async-concurrency", type=int, default=None,
                    help="async: clients kept in flight (default: "
                         "max(--clients, --buffer-size))")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="async: drop updates staler than this many "
                         "versions (None = never)")
    ap.add_argument("--over-select", type=float, default=1.0)
    ap.add_argument("--deadline", type=float, default=None,
                    help="straggler cutoff seconds (None = 90%% quantile)")
    ap.add_argument("--dropout-rate", type=float, default=0.0)
    ap.add_argument("--wire-dtype", default="fp32",
                    choices=("fp32", "bf16", "fp16"))
    ap.add_argument("--wire-simulate", action="store_true",
                    help="route every update through a serialized frame")
    ap.add_argument("--faults", default=None,
                    help="chaos schedule: 'mild'/'aggressive' preset or "
                         "'crash_rate=0.1,corrupt_rate=0.2,...' (implies "
                         "--wire-simulate; requires --runtime)")
    ap.add_argument("--quorum", type=float, default=None,
                    help="min validated survivors per round: fraction of "
                         "the requested cohort if <= 1.0, else an absolute "
                         "count; below quorum the cohort is re-extended or "
                         "the server step is skipped")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="crash-safe checkpoint directory (atomic state + "
                         "manifest written every --checkpoint-every rounds)")
    ap.add_argument("--checkpoint-every", type=int, default=1)
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint-dir's manifest, "
                         "replaying the remaining rounds bit-identically")
    ap.add_argument("--out", default=None)
    ap.add_argument("--telemetry", default="telemetry.jsonl",
                    help="JSONL event-log path (machine-readable round "
                         "reporting, on by default; 'off' disables)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON (Perfetto-loadable) "
                         "of the run's spans to this path")
    ap.add_argument("--prom-out", default=None,
                    help="Prometheus textfile-collector snapshot path")
    args = ap.parse_args()
    tel = make_telemetry(
        jsonl=None if args.telemetry in ("off", "none", "") else args.telemetry,
        prometheus=args.prom_out, run_id=f"train-{args.method}-{args.seed}",
        workload="train")
    hist = run_training(arch=args.arch, task=args.task, method=args.method,
                        rounds=args.rounds, clients_per_round=args.clients,
                        total_clients=args.total_clients,
                        batch_size=args.batch_size,
                        local_iters=args.local_iters, local_lr=args.lr,
                        server_lr=args.server_lr, dirichlet_alpha=args.alpha,
                        seed=args.seed, reduced=not args.full_size,
                        k_perturbations=args.k, jvp_clip=args.jvp_clip,
                        tangent_batch=args.tangent_batch,
                        fused_contraction=args.fused_contraction,
                        runtime=args.runtime,
                        runtime_executor=args.runtime_executor,
                        runtime_microbatch=args.runtime_microbatch,
                        over_select=args.over_select, deadline=args.deadline,
                        dropout_rate=args.dropout_rate,
                        wire_dtype=args.wire_dtype,
                        wire_simulate=args.wire_simulate,
                        telemetry=tel, faults=args.faults,
                        quorum=args.quorum,
                        checkpoint_dir=args.checkpoint_dir,
                        checkpoint_every=args.checkpoint_every,
                        resume=args.resume,
                        async_mode=args.async_mode,
                        buffer_size=args.buffer_size,
                        staleness_decay=args.staleness_decay,
                        async_concurrency=args.async_concurrency,
                        max_staleness=args.max_staleness)
    if tel.enabled:
        if args.trace_out:
            tel.export_chrome_trace(args.trace_out)
        tel.close()
        print(f"[telemetry] events -> {args.telemetry}"
              + (f"  trace -> {args.trace_out}" if args.trace_out else ""))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=1)


if __name__ == "__main__":
    main()
