"""Paged multi-tenant LoRA adapter cache for serving.

A deployment finetunes one PEFT tree per client (the paper's federated
personalisation); serving then has to decode requests from MANY clients
against ONE frozen base. Holding every adapter resident is wasteful and
re-materialising per request is slow, so the cache keeps N adapter *pages*
resident in page-stacked buffers — each LoRA factor stored as (P, din, r) /
(P, r, dout) with the page axis adjacent to the batched multi-adapter
kernels' gather axis — and evicts least-recently-used pages on overflow
(the same OrderedDict LRU idiom as ``fl/runtime/population.py`` client
shards).

Stores supply the per-client trees: ``SyntheticAdapterStore`` fabricates
deterministic distinct adapters (benchmarks / tests);
``CheckpointAdapterStore`` reads the npz pytrees that
``checkpoint.io.save_pytree`` wrote for each client's finetuned peft state.
"""
from __future__ import annotations

from collections import OrderedDict
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint.io import load_pytree, save_pytree
from repro.configs import SpryConfig
from repro.obs import NULL
from repro.peft import init_peft

# peft groups whose LoRA factors are stacked on a leading n_layers axis
_STACKED_GROUPS = ("layers", "enc_layers")


class SyntheticAdapterStore:
    """Deterministic fabricated adapters: adapter ``aid`` is ``init_peft``
    under a fold_in(seed, aid) key with the B factors randomised (init_peft
    zeros them — identity adapters would make every tenant identical, hiding
    routing bugs). Same (seed, aid) -> bitwise-identical tree, every call."""

    def __init__(self, cfg, spry_cfg=None, seed: int = 0):
        self.cfg = cfg
        self.spry_cfg = spry_cfg or SpryConfig()
        self.seed = seed

    def template(self):
        return self.load(0)

    def load(self, aid: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), aid)
        tree = init_peft(self.cfg, key, self.spry_cfg)
        counter = [0]

        def randomize_b(path, leaf):
            counter[0] += 1
            last = path[-1]
            if isinstance(last, jax.tree_util.DictKey) and last.key == "B":
                k = jax.random.fold_in(key, counter[0])
                return (0.05 * jax.random.normal(k, leaf.shape)).astype(
                    leaf.dtype)
            return leaf

        return jax.tree_util.tree_map_with_path(randomize_b, tree)


class CheckpointAdapterStore:
    """Adapters from per-client checkpoint files (``adapter_<aid>.npz``
    pytrees in ``directory``, the format ``checkpoint.io`` writes).
    ``template`` supplies the tree structure npz restoration needs."""

    def __init__(self, directory, template):
        self.directory = Path(directory)
        self._template = template

    def template(self):
        return self._template

    def path(self, aid: int) -> str:
        return str(self.directory / f"adapter_{aid}.npz")

    def save(self, aid: int, tree) -> None:
        self.directory.mkdir(parents=True, exist_ok=True)
        save_pytree(self.path(aid), tree)

    def load(self, aid: int):
        return load_pytree(self.path(aid), self._template)


class AdapterCache:
    """``capacity`` resident adapter pages with LRU eviction + lazy
    materialisation from ``store``.

    ``acquire(aid)`` returns the adapter's page index, loading and evicting
    as needed; ``pin``/``unpin`` protect pages referenced by in-flight
    requests from eviction. ``multi_peft(row_pages)`` builds the
    index-augmented peft tree the models' multi-adapter projection route
    consumes; ``page_tree(page)`` slices one page back out as a plain
    single-adapter tree (bitwise-identical to what the store loaded).
    """

    def __init__(self, store, capacity: int, telemetry=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.store = store
        self.capacity = capacity
        self._stacked = {}            # group -> target -> {"A","B"} buffers
        self._pages = OrderedDict()   # aid -> page, LRU order (oldest first)
        self._free = list(range(capacity))
        self._pins = {}               # aid -> refcount
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # the ints above stay the source of truth (stats() is telemetry-free
        # API); the counters mirror them into the shared metrics registry
        tel = telemetry if telemetry is not None else NULL
        self.telemetry = tel
        self._tc_hits = tel.counter("adapter_cache.hits")
        self._tc_misses = tel.counter("adapter_cache.misses")
        self._tc_evictions = tel.counter("adapter_cache.evictions")
        self._tc_pins = tel.counter("adapter_cache.pins")
        self._tg_resident = tel.gauge("adapter_cache.resident")

        template = store.template()
        for group, gtree in template.items():
            if group == "head":
                continue   # classifier head is not a per-row LoRA page
            paged = {}
            for target, pair in gtree.items():
                if not (isinstance(pair, dict) and set(pair) == {"A", "B"}):
                    raise ValueError(
                        f"AdapterCache pages LoRA trees only; "
                        f"{group}/{target} has entries {sorted(pair)}")
                axis = 1 if group in _STACKED_GROUPS else 0
                paged[target] = {
                    name: jnp.zeros(
                        leaf.shape[:axis] + (capacity,) + leaf.shape[axis:],
                        leaf.dtype)
                    for name, leaf in pair.items()
                }
            self._stacked[group] = paged

    # -- residency -----------------------------------------------------------

    def resident(self):
        """aids currently resident, least-recently-used first."""
        return list(self._pages)

    def acquire(self, aid: int) -> int:
        """Page index for ``aid``, materialising (and evicting) if needed."""
        if aid in self._pages:
            self.hits += 1
            self._tc_hits.inc()
            self._pages.move_to_end(aid)
            return self._pages[aid]
        self.misses += 1
        self._tc_misses.inc()
        if self._free:
            page = self._free.pop()
        else:
            victim = next((a for a in self._pages
                           if self._pins.get(a, 0) == 0), None)
            if victim is None:
                raise RuntimeError(
                    "all resident adapter pages are pinned by in-flight "
                    "requests; raise the cache capacity or max batch")
            page = self._pages.pop(victim)
            self.evictions += 1
            self._tc_evictions.inc()
        with self.telemetry.span("adapter_cache.load", aid=aid):
            self._materialize(page, self.store.load(aid))
        self._pages[aid] = page
        self._tg_resident.set(len(self._pages))
        return page

    def pin(self, aid: int) -> int:
        page = self.acquire(aid)
        self._pins[aid] = self._pins.get(aid, 0) + 1
        self._tc_pins.inc()
        return page

    def unpin(self, aid: int) -> None:
        n = self._pins.get(aid, 0)
        if n <= 1:
            self._pins.pop(aid, None)
        else:
            self._pins[aid] = n - 1

    def _materialize(self, page: int, tree) -> None:
        for group, paged in self._stacked.items():
            gtree = tree[group]
            for target, pair in paged.items():
                for name, buf in pair.items():
                    leaf = jnp.asarray(gtree[target][name], buf.dtype)
                    if group in _STACKED_GROUPS:
                        pair[name] = buf.at[:, page].set(leaf)
                    else:
                        pair[name] = buf.at[page].set(leaf)

    # -- views ---------------------------------------------------------------

    def page_tree(self, page: int):
        """Plain single-adapter peft tree sliced from one resident page."""
        out = {}
        for group, paged in self._stacked.items():
            out[group] = {
                target: {
                    name: (buf[:, page] if group in _STACKED_GROUPS
                           else buf[page])
                    for name, buf in pair.items()
                }
                for target, pair in paged.items()
            }
        return out

    def multi_peft(self, row_pages):
        """Index-augmented peft tree for a batch whose row b reads page
        ``row_pages[b]``: every LoRA entry becomes {"A": page-stacked,
        "B": page-stacked, "idx": per-row pages} — ``models.common.proj``
        routes such entries through the batched multi-adapter projection.
        Stacked groups carry idx as (L, B) so the layer scan slices it to
        (B,) alongside the (P, din, r) factors."""
        idx = jnp.asarray(row_pages, jnp.int32)
        out = {}
        for group, paged in self._stacked.items():
            if group in _STACKED_GROUPS:
                L = next(iter(next(iter(paged.values())).values())).shape[0]
                gidx = jnp.broadcast_to(idx[None, :], (L, idx.shape[0]))
            else:
                gidx = idx
            out[group] = {
                target: dict(pair, idx=gidx)
                for target, pair in paged.items()
            }
        return out

    def stats(self):
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "resident": len(self._pages), "capacity": self.capacity,
                "pinned": sum(self._pins.values())}
