"""Client sampling per FL round."""
from __future__ import annotations

import numpy as np


def sample_clients(rng: np.random.Generator, n_total: int, n_per_round: int):
    return rng.choice(n_total, size=min(n_per_round, n_total), replace=False)
