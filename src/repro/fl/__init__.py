from repro.fl.partition import dirichlet_partition, heterogeneity_coefficients
from repro.fl.server import ServerState, server_init, server_update
from repro.fl.comm import comm_cost, compute_cost, CommCost, ComputeCost
from repro.fl.sampling import sample_clients
