"""Server-side federated optimizers (paper §3.1, Reddi et al. AFO).

The server treats the aggregated client delta  Δ = w' − w  as a pseudo-
gradient and applies FedAvg / FedSGD / FedAdam / FedYogi / FedAdagrad.
All are pure pytree functions so they compose into the jitted round step.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_zeros_like


class ServerState(NamedTuple):
    count: jnp.ndarray
    m: Any            # first moment of deltas
    v: Any            # second moment of deltas


def server_init(params) -> ServerState:
    return ServerState(jnp.zeros([], jnp.int32), tree_zeros_like(params),
                       tree_zeros_like(params))


def server_update(kind: str, params, delta, state: ServerState, lr: float,
                  b1: float = 0.9, b2: float = 0.99, tau: float = 1e-3):
    """Apply one server-optimizer step. ``delta`` is the aggregated client
    update direction (already weighted-averaged over clients per layer).

    Returns (new_params, new_state).
    """
    count = state.count + 1
    if kind in ("fedavg", "fedsgd"):
        # FedAvg: w <- w + Δ (server lr folded to 1.0 for parity with paper);
        # FedSGD is the same rule applied every iteration.
        new_params = jax.tree.map(lambda p, d: (p + lr * d).astype(p.dtype),
                                  params, delta)
        return new_params, ServerState(count, state.m, state.v)

    m = jax.tree.map(lambda mi, d: b1 * mi + (1 - b1) * d, state.m, delta)

    if kind == "fedadam":
        v = jax.tree.map(lambda vi, d: b2 * vi + (1 - b2) * d * d, state.v, delta)
    elif kind == "fedyogi":
        v = jax.tree.map(
            lambda vi, d: vi - (1 - b2) * jnp.sign(vi - d * d) * (d * d),
            state.v, delta)
    elif kind == "fedadagrad":
        v = jax.tree.map(lambda vi, d: vi + d * d, state.v, delta)
    else:
        raise ValueError(f"unknown server optimizer {kind!r}")

    new_params = jax.tree.map(
        lambda p, mi, vi: (p + lr * mi / (jnp.sqrt(vi) + tau)).astype(p.dtype),
        params, m, v)
    return new_params, ServerState(count, m, v)
