"""Dirichlet client partitioning (paper §5 / Appendix B).

``dirichlet_partition`` splits a labelled dataset across N clients where the
per-client class mixture is drawn from Dir(alpha). alpha=1.0 reproduces the
paper's homogeneous split, alpha=0.1 the heterogeneous split.
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_per_client: int = 2):
    """Return list of index arrays, one per client.

    Implementation: for each class, split its sample indices among clients
    with proportions ~ Dir(alpha) (the standard Hsu et al. protocol the paper
    cites via [37]).
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    client_indices = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        # cumulative split points
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for client, part in enumerate(np.split(idx, cuts)):
            client_indices[client].extend(part.tolist())
    out = []
    for client in range(n_clients):
        idx = np.array(sorted(client_indices[client]), dtype=np.int64)
        out.append(idx)
    # guarantee every client has at least min_per_client samples (steal from
    # the largest client) so local training is well-defined
    sizes = np.array([len(i) for i in out])
    for client in range(n_clients):
        while len(out[client]) < min_per_client:
            donor = int(np.argmax([len(i) for i in out]))
            out[client] = np.append(out[client], out[donor][-1])
            out[donor] = out[donor][:-1]
    return out


def heterogeneity_coefficients(labels: np.ndarray, parts, alpha: float):
    """The paper's alpha_{m,c} = n_c/|D| - n_{m,c}*alpha_c/|D_m| (Thm 4.1).

    Returns an (n_clients, n_classes) array. Under the paper's convention
    alpha_c = 1.0 for the homogeneous split; we pass the Dirichlet
    concentration used for the split.
    """
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    n = len(labels)
    global_frac = np.array([(labels == c).sum() / n for c in range(n_classes)])
    coeffs = np.zeros((len(parts), n_classes))
    for m, idx in enumerate(parts):
        lm = labels[idx]
        dm = max(1, len(lm))
        for c in range(n_classes):
            coeffs[m, c] = global_frac[c] - (lm == c).sum() * alpha / dm
    return coeffs
