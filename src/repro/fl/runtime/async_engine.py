"""FedBuff-style asynchronous federation engine over the SPRY wire protocol.

Instead of round-synchronous cohorts with a straggler deadline, the server
keeps ``concurrency`` clients in flight at all times and aggregates the
first ``buffer_size`` (B) VALIDATED arrivals with staleness-weighted
combination:

    w_i = 1 / (1 + s_i) ** staleness_decay

where ``s_i = server_version_now - server_version_at_dispatch``. A late
update is never thrown away (the round-synchronous engine's deadline cut):
it simply lands in the NEXT buffer with one more unit of staleness and a
correspondingly smaller relative weight. Aggregation is the dropout-
corrected per-unit weighted mean — with an all-fresh buffer (every s_i
equal) the weights cancel and the combination reduces to the synchronous
engine's unit average.

Time is virtual: an ``EventHeap`` orders (dispatch -> arrival) events by
``(virtual_seconds, seq)`` over ``population.py``'s two-part latency model
(per-tier compute seconds + uplink transit, both seeded per (client,
dispatch)), diurnal availability gates client selection, and every random
draw is stateless — so a run replays bit-identically, including across
kill-and-resume: ``snapshot()`` captures the buffer, the in-flight event
heap (frames and all), the virtual clock, and the dispatch counter;
``restore()`` resumes mid-buffer with zero drift. Wall time never enters.

Fault tolerance composes with PR 9's substrate unchanged: dispatched
frames run the same gauntlet (tier-scaled crash -> poison -> retry/loss ->
corruption -> strict decode + quarantine -> dedupe), and defensive
validation (NaN/Inf + norm-outlier-vs-crowd) gates entry into the
aggregation — the B-arrivals trigger counts validated updates only, the
async analogue of the sync engine's quorum gate.

The per-iteration mode works unchanged because ``make_rebuild_fn`` uses
the peft only for SHAPES: the server rebuilds a stale update's gradient
from (base_version, seed_id, K jvp scalars) at aggregation time, exactly
the paper's Table-2 seed-ref trick extended with a staleness tag.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment import assignment_matrix, enumerate_units
from repro.core.spry import (
    SpryState,
    aggregate_payloads,
    make_client_jvp_fn,
    make_client_update_fn,
    make_rebuild_fn,
)
from repro.fl.runtime.engine import (
    WireConfig,
    WireHealth,
    poison_update,
    validate_updates,
)
from repro.fl.runtime.events import EventHeap, sample_available
from repro.fl.runtime.executor import _weighted
from repro.fl.runtime.faults import FaultConfig, FaultInjector
from repro.fl.runtime.messages import (
    ClientUpdate,
    TaskAssignment,
    WireError,
    decode_frame,
)
from repro.fl.server import server_update
from repro.obs import NULL

ASYNC_SNAPSHOT_SCHEMA = "repro.async/v1"


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Knobs of the buffered-asynchronous aggregation policy."""
    buffer_size: int = 4          # B: validated arrivals per server step
    staleness_decay: float = 0.5  # a in w = 1/(1+s)^a  (0 = ignore staleness)
    concurrency: int = 8          # clients kept in flight
    max_staleness: Optional[int] = None   # drop updates staler than this
    work_seconds: float = 60.0    # nominal local-epoch wall time at scale 1.0
    seed: int = 0                 # dispatch/selection seed (not the algo seed)
    max_events_per_step: int = 100_000    # runaway-loop guard

    def __post_init__(self):
        if self.buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")
        if self.concurrency < self.buffer_size:
            raise ValueError(
                f"concurrency ({self.concurrency}) must be >= buffer_size "
                f"({self.buffer_size}) or the buffer can never fill")
        if self.staleness_decay < 0.0:
            raise ValueError("staleness_decay must be >= 0")


@dataclasses.dataclass
class AsyncRoundReport:
    """One server step (version bump) of the async engine."""
    version: int                  # server version AFTER this step
    sim_time_s: float             # virtual clock at the step
    n_aggregated: int
    staleness: List[int]          # per aggregated update
    buffer_occupancy: int         # left in the buffer after the step
    in_flight: int
    bytes_down: int               # cumulative TaskAssignment bytes
    bytes_up: int                 # cumulative uplink bytes (all attempts)
    useful_compute_s: float       # cumulative client compute aggregated
    discarded_compute_s: float    # cumulative client compute wasted
    events_processed: int
    health: Optional[WireHealth] = None

    @property
    def utilization(self) -> float:
        total = self.useful_compute_s + self.discarded_compute_s
        return self.useful_compute_s / max(total, 1e-12)


class AsyncFederationEngine:
    """Event-driven FedBuff server over ``ClientPopulation``.

    ``run_version(state, batch_size)`` advances the simulation until ONE
    server step has been applied and returns ``(state', metrics, report)``
    — the same call shape as ``FederationEngine.run_round``, so the
    training loop drives either engine interchangeably.
    """

    def __init__(self, cfg, spry_cfg, population, task: str = "cls",
                 comm_mode: Optional[str] = None,
                 async_cfg: Optional[AsyncConfig] = None,
                 wire: Optional[WireConfig] = None, telemetry=None,
                 faults=None, norm_outlier_mult: float = 100.0):
        self.cfg = cfg
        self.spry_cfg = spry_cfg
        self.population = population
        self.task = task
        self.async_cfg = async_cfg or AsyncConfig()
        self.wire = wire or WireConfig()
        if isinstance(faults, FaultConfig):
            faults = FaultInjector(faults)
        self.faults: Optional[FaultInjector] = faults
        self.norm_outlier_mult = float(norm_outlier_mult)
        self.comm_mode = comm_mode or spry_cfg.comm_mode
        if self.comm_mode not in ("per_epoch", "per_iteration"):
            raise ValueError(self.comm_mode)
        if self.comm_mode == "per_epoch":
            self._client_fn = make_client_update_fn(cfg, spry_cfg, task)
        else:
            self._client_fn = make_client_jvp_fn(cfg, spry_cfg, task)
            self._rebuild_fn = make_rebuild_fn()
        self._client_jit = jax.jit(self._client_one_fn)
        self._agg_jit = jax.jit(
            self._agg_delta_fn if self.comm_mode == "per_epoch"
            else self._agg_jvp_fn)

        # -- virtual-time state (everything snapshot() captures) ----------
        self.heap = EventHeap()
        self.clock = 0.0
        self.version: Optional[int] = None    # locked to state.round_idx
        self.dispatched = 0                   # global dispatch counter
        self.buffer: List[Dict[str, Any]] = []
        self.bytes_up = 0
        self.bytes_down = 0
        self.useful_compute_s = 0.0
        self.discarded_compute_s = 0.0
        self.updates_used = 0
        self.updates_discarded = 0
        self.events_processed = 0

        self._n_units: Optional[int] = None
        self._assign_rows: Dict[int, np.ndarray] = {}
        self._np_template = None
        # cumulative totals already pushed to the byte counters (the report
        # carries running totals; telemetry must only see each version's
        # increment)
        self._bytes_up_reported = 0
        self._bytes_down_reported = 0

        # host-side telemetry ONLY — the jitted bodies never see this
        # object, so telemetry-on traces the identical program (the same
        # HLO-neutrality contract as the sync engine)
        tel = telemetry if telemetry is not None else NULL
        self.telemetry = tel
        self._tc_steps = tel.counter("fl.async.server_steps")
        self._tc_dispatches = tel.counter("fl.async.dispatches")
        self._tc_used = tel.counter("fl.async.updates_used")
        self._tc_discarded = tel.counter("fl.async.updates_discarded")
        self._tc_useful_s = tel.counter("fl.async.useful_compute_s")
        self._tc_wasted_s = tel.counter("fl.async.discarded_compute_s")
        self._tc_bytes_up = tel.counter("fl.bytes_up")
        self._tc_bytes_down = tel.counter("fl.bytes_down")
        self._tg_buffer = tel.gauge("fl.async.buffer")
        self._tg_loss = tel.gauge("fl.loss")
        self._th_staleness = tel.histogram(
            "fl.async.staleness", buckets=(0, 1, 2, 4, 8, 16, 32, 64, 128))
        self._tc_quarantined = tel.counter("fl.quarantined")
        self._tc_lost = tel.counter("fl.lost_updates")
        self._tc_crashed = tel.counter("fl.crashed_clients")
        self._tc_dups = tel.counter("fl.duplicate_frames")
        self._tc_invalid = tel.counter("fl.invalid_payloads")

    # ------------------------------------------------------------------
    # jitted bodies
    # ------------------------------------------------------------------

    def _client_one_fn(self, state, version, sid, row, batch):
        """One client's local work against the CURRENT model; the round key
        is the fold-in chain keyed by the server version at dispatch."""
        rk = jax.random.fold_in(
            jax.random.PRNGKey(self.spry_cfg.seed), version)
        return self._client_fn(state.base, state.peft, rk, sid, row, batch)

    def _finish_agg(self, state, agg):
        if self.comm_mode == "per_iteration":
            delta = jax.tree.map(lambda g: -self.spry_cfg.local_lr * g, agg)
        else:
            delta = agg
        new_peft, server = server_update(
            self.spry_cfg.server_opt, state.peft, delta, state.server,
            lr=self.spry_cfg.server_lr)
        delta_norm = jnp.sqrt(
            sum(jnp.sum(d * d) for d in jax.tree.leaves(delta)))
        return (SpryState(state.base, new_peft, server, state.round_idx + 1),
                delta_norm)

    def _weighted_mean(self, peft, stacked, weights, mask_rows):
        """Per-unit staleness-weighted mean: Σ w_i m_iu x_i / Σ w_i m_iu.
        With equal weights this is exactly the sync engine's dropout-
        corrected unit average (weights cancel)."""
        index = enumerate_units(peft)
        counts = jnp.maximum((mask_rows * weights[:, None]).sum(0), 1e-8)
        head_count = jnp.maximum(weights.sum(), 1e-8)
        return aggregate_payloads(peft, index, _weighted(stacked, weights),
                                  counts, head_count)

    def _agg_delta_fn(self, state, stacked, weights, mask_rows):
        agg = self._weighted_mean(state.peft, stacked, weights, mask_rows)
        return self._finish_agg(state, agg)

    def _agg_jvp_fn(self, state, jvps, vtags, sids, mask_rows, weights):
        peft = state.peft
        base_key = jax.random.PRNGKey(self.spry_cfg.seed)
        rks = jax.vmap(lambda v: jax.random.fold_in(base_key, v))(vtags)
        grads = jax.vmap(
            lambda rk, sid, row, jv: self._rebuild_fn(peft, rk, sid, row,
                                                      jv))(
            rks, sids, mask_rows, jvps)
        agg = self._weighted_mean(peft, grads, weights, mask_rows)
        return self._finish_agg(state, agg)

    # ------------------------------------------------------------------
    # dispatch / arrival
    # ------------------------------------------------------------------

    def _ensure_static(self, state) -> None:
        if self.version is None:
            self.version = int(state.round_idx)
        if self._n_units is None:
            index = enumerate_units(state.peft)
            self._n_units = index.n_units
        if self._np_template is None and self.comm_mode == "per_epoch":
            self._np_template = jax.tree.map(
                lambda x: np.zeros(x.shape, np.float32), state.peft)

    def _mask_row(self, d: int) -> np.ndarray:
        """Cyclic unit assignment by dispatch index: every ``buffer_size``
        consecutive dispatches tile all units, with a rotating offset so
        unit->client pairings vary across buffers."""
        A = max(int(self.async_cfg.buffer_size), 1)
        offset = (d // A) % max(A, 1)
        if offset not in self._assign_rows:
            self._assign_rows[offset] = np.asarray(
                assignment_matrix(self._n_units, A, offset), np.float32)
        return self._assign_rows[offset][d % A]

    def _dispatch(self, state, batch_size: int, health: WireHealth) -> None:
        cfg = self.async_cfg
        pop = self.population
        d = self.dispatched
        self.dispatched += 1
        tick = int(self.clock // max(cfg.work_seconds, 1e-9))
        cid = sample_available(pop, tick, d, cfg.seed)
        tier = pop.device_tier(cid)
        comp = pop.compute_seconds(cid, d, cfg.work_seconds)
        uplink = pop.uplink_seconds(cid, d)
        row = self._mask_row(d)
        unit_ids = np.flatnonzero(row > 0).astype(np.int32)
        assignment = TaskAssignment(
            round_idx=self.version, client_id=cid, seed_id=d,
            cohort_size=cfg.concurrency, seed=self.spry_cfg.seed,
            n_units=self._n_units, unit_ids=unit_ids, hparams={})
        self.bytes_down += assignment.byte_size()
        self._tc_dispatches.inc()

        ev: Dict[str, Any] = {"client_id": cid, "dispatch_version":
                              self.version, "compute_s": float(comp),
                              "crashed": False, "frames": []}
        inj = self.faults
        if inj is not None and inj.crashes(cid, d, tier.crash_scale):
            ev["crashed"] = True
            # the device died mid-epoch: the slot frees when the work would
            # have finished, the server just never hears from it
            self.heap.push(self.clock + comp, ev)
            return

        # the client's local work happens EAGERLY against the current
        # model; the resulting frame rides the event so a checkpoint of the
        # heap preserves in-flight updates byte-for-byte
        bx, by = pop.client_batch(cid, d, batch_size)
        out = self._client_jit(state, np.uint32(self.version), np.int32(d),
                               row, {"tokens": bx, "labels": by})
        if self.comm_mode == "per_epoch":
            delta, loss, _jvps = out
            index = enumerate_units(state.peft)
            u = ClientUpdate.from_delta(
                jax.tree.map(np.asarray, delta), index, unit_ids,
                round_idx=self.version, client_id=cid, seed_id=d,
                wire=self.wire.dtype, loss=float(loss),
                include_head=self.wire.include_head)
        else:
            loss, jvps = out
            u = ClientUpdate.from_jvps(
                np.asarray(jvps), round_idx=self.version, client_id=cid,
                seed_id=d, wire=self.wire.dtype, loss=float(loss))
        u.base_version = self.version
        backoff = 0.0
        if inj is not None:
            mode = inj.poison_mode(cid, d)
            if mode is not None:
                poison_update(inj, u, mode)
            frame = u.to_bytes()
            health.sent += 1
            delivered, attempts, backoff = inj.transmit(frame, cid, d)
            self.bytes_up += len(frame) * attempts
            health.transmissions += attempts
            health.retries += attempts - 1
        else:
            frame = u.to_bytes()
            health.sent += 1
            health.transmissions += 1
            delivered = [frame]
            self.bytes_up += len(frame)
        ev["frames"] = delivered
        self.heap.push(self.clock + comp + uplink + backoff, ev)

    def _on_arrival(self, ev: Dict[str, Any], health: WireHealth) -> None:
        comp = float(ev["compute_s"])
        if ev["crashed"]:
            health.crashed += 1
            self._waste(comp)
            self._tc_crashed.inc()
            return
        if not ev["frames"]:
            health.lost += 1
            self._waste(comp)
            self._tc_lost.inc()
            return
        buffered_ids = {e["update"].seed_id for e in self.buffer}
        landed = False
        for fb in ev["frames"]:
            health.delivered += 1
            try:
                dec = decode_frame(fb)
            except WireError as e:
                health.quarantined += 1
                health.failure_kinds[e.kind] = \
                    health.failure_kinds.get(e.kind, 0) + 1
                self._tc_quarantined.inc()
                continue
            if not isinstance(dec, ClientUpdate) \
                    or dec.seed_id in buffered_ids:
                health.duplicates += 1
                self._tc_dups.inc()
                continue
            buffered_ids.add(dec.seed_id)
            health.accepted += 1
            dv = dec.base_version if dec.base_version is not None \
                else dec.round_idx
            self.buffer.append({"update": dec, "dispatch_version": int(dv),
                                "compute_s": comp})
            landed = True
        if not landed:
            self._waste(comp)
        self._tg_buffer.set(len(self.buffer))

    def _waste(self, comp: float) -> None:
        self.discarded_compute_s += comp
        self.updates_discarded += 1
        self._tc_discarded.inc()
        self._tc_wasted_s.add(comp)

    # ------------------------------------------------------------------
    # aggregation
    # ------------------------------------------------------------------

    def _expire_stale(self, health: WireHealth) -> None:
        ms = self.async_cfg.max_staleness
        if ms is None:
            return
        kept = []
        for e in self.buffer:
            if self.version - e["dispatch_version"] > ms:
                health.invalid += 1
                self._waste(e["compute_s"])
            else:
                kept.append(e)
        self.buffer = kept

    def _try_aggregate(self, state, health: WireHealth):
        """If >= B validated updates are buffered, apply one server step.
        Returns (state', metrics-or-None)."""
        B = self.async_cfg.buffer_size
        while True:
            self._expire_stale(health)
            if len(self.buffer) < B:
                return state, None
            head = self.buffer[:B]
            valid = validate_updates(
                {i: e["update"] for i, e in enumerate(head)},
                self.norm_outlier_mult)
            if len(valid) < B:
                bad = set(range(B)) - valid
                health.invalid += len(bad)
                self._tc_invalid.add(len(bad))
                for i in sorted(bad):
                    self._waste(head[i]["compute_s"])
                self.buffer = [e for i, e in enumerate(self.buffer)
                               if i >= B or i in valid]
                continue
            health.validated += B
            return self._aggregate(state, head)

    def _aggregate(self, state, entries: List[Dict[str, Any]]):
        a = self.async_cfg.staleness_decay
        stale = np.asarray([self.version - e["dispatch_version"]
                            for e in entries], np.int64)
        w64 = (1.0 + stale.astype(np.float64)) ** (-a)
        weights = jnp.asarray(w64, jnp.float32)
        updates = [e["update"] for e in entries]
        losses = np.asarray([u.loss for u in updates], np.float64)

        if self.comm_mode == "per_epoch":
            index = enumerate_units(state.peft)
            mask_rows = np.zeros((len(updates), self._n_units), np.float32)
            for i, u in enumerate(updates):
                mask_rows[i, sorted(u.unit_payload or {})] = 1.0
            deltas = [u.to_delta(self._np_template, index) for u in updates]
            stacked = jax.tree.map(
                lambda *xs: jnp.asarray(np.stack(xs)), *deltas)
            new_state, delta_norm = self._agg_jit(
                state, stacked, weights, jnp.asarray(mask_rows))
        else:
            mask_rows = np.stack([self._mask_row(u.seed_id)
                                  for u in updates])
            jvps = np.stack([np.asarray(u.jvps, np.float32)
                             for u in updates])
            vtags = np.asarray([e["dispatch_version"] for e in entries],
                               np.uint32)
            sids = np.asarray([u.seed_id for u in updates], np.int32)
            new_state, delta_norm = self._agg_jit(
                state, jnp.asarray(jvps), vtags, sids,
                jnp.asarray(mask_rows), weights)

        self.buffer = self.buffer[len(entries):]
        self.version += 1
        for e in entries:
            self.useful_compute_s += e["compute_s"]
            self.updates_used += 1
            self._tc_used.inc()
            self._tc_useful_s.add(e["compute_s"])
        for s in stale.tolist():
            self._th_staleness.observe(float(s))
        self._tc_steps.inc()

        metrics = {
            "loss": jnp.float32(np.average(losses, weights=w64)),
            "delta_norm": delta_norm,
            "staleness_mean": jnp.float32(stale.mean()),
            "fused_route": jnp.float32(self.spry_cfg.fused_contraction),
        }
        if self.comm_mode == "per_iteration":
            metrics["jvp_abs_mean"] = jnp.float32(np.mean(np.abs(
                np.stack([np.asarray(u.jvps, np.float64)
                          for u in updates]))))
        return new_state, {"metrics": metrics,
                           "staleness": [int(s) for s in stale]}

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def run_version(self, state, batch_size: int
                    ) -> Tuple[Any, Dict[str, Any], AsyncRoundReport]:
        """Advance the event simulation until ONE server step lands."""
        self._ensure_static(state)
        if self.version != int(state.round_idx):
            raise ValueError(
                f"engine version {self.version} out of step with "
                f"state.round_idx {int(state.round_idx)} — restore() the "
                f"matching snapshot when resuming")
        tel = self.telemetry
        t_wall = time.perf_counter()
        health = WireHealth()
        agg = None
        guard = 0
        with tel.span("fl.async.version", version=self.version,
                      comm_mode=self.comm_mode):
            while agg is None:
                guard += 1
                if guard > self.async_cfg.max_events_per_step:
                    raise RuntimeError(
                        f"no aggregation after {guard} events — buffer "
                        f"cannot fill (check max_staleness / faults)")
                while len(self.heap) < self.async_cfg.concurrency:
                    self._dispatch(state, batch_size, health)
                t, _, ev = self.heap.pop()
                self.clock = float(t)
                self.events_processed += 1
                self._on_arrival(ev, health)
                state, agg = self._try_aggregate(state, health)

        metrics = agg["metrics"]
        report = AsyncRoundReport(
            version=self.version, sim_time_s=self.clock,
            n_aggregated=self.async_cfg.buffer_size,
            staleness=agg["staleness"],
            buffer_occupancy=len(self.buffer), in_flight=len(self.heap),
            bytes_down=self.bytes_down, bytes_up=self.bytes_up,
            useful_compute_s=self.useful_compute_s,
            discarded_compute_s=self.discarded_compute_s,
            events_processed=self.events_processed, health=health)
        if tel.enabled:
            self._record_version(metrics, report,
                                 time.perf_counter() - t_wall)
        return state, metrics, report

    def _record_version(self, metrics, report: AsyncRoundReport,
                        wall_s: float) -> None:
        host = {k: float(v) for k, v in metrics.items()}
        self._tg_loss.set(host["loss"])
        self._tc_bytes_up.add(report.bytes_up - self._bytes_up_reported)
        self._tc_bytes_down.add(report.bytes_down
                                - self._bytes_down_reported)
        self._bytes_up_reported = report.bytes_up
        self._bytes_down_reported = report.bytes_down
        self.telemetry.event(
            "async_round",
            version=report.version,
            comm_mode=self.comm_mode,
            loss=host["loss"],
            delta_norm=host.get("delta_norm"),
            staleness=report.staleness,
            staleness_mean=host.get("staleness_mean"),
            buffer_occupancy=report.buffer_occupancy,
            in_flight=report.in_flight,
            sim_time_s=round(report.sim_time_s, 6),
            bytes_up=report.bytes_up,
            bytes_down=report.bytes_down,
            useful_compute_s=round(report.useful_compute_s, 6),
            discarded_compute_s=round(report.discarded_compute_s, 6),
            utilization=round(report.utilization, 6),
            wall_s=round(wall_s, 6),
        )

    # ------------------------------------------------------------------
    # checkpoint / resume
    # ------------------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """Raw snapshot of the virtual-time state (frames as raw bytes —
        use ``checkpoint.async_state.encode_async_snapshot`` to make it
        JSON-safe for the run manifest). Captures buffer + event clock,
        never wall time."""
        return {
            "schema": ASYNC_SNAPSHOT_SCHEMA,
            "clock": float(self.clock),
            "version": self.version,
            "dispatched": int(self.dispatched),
            "events_processed": int(self.events_processed),
            "bytes_up": int(self.bytes_up),
            "bytes_down": int(self.bytes_down),
            "useful_compute_s": float(self.useful_compute_s),
            "discarded_compute_s": float(self.discarded_compute_s),
            "updates_used": int(self.updates_used),
            "updates_discarded": int(self.updates_discarded),
            "heap": self.heap.snapshot(),
            "buffer": [{"frame": e["update"].to_bytes(),
                        "dispatch_version": int(e["dispatch_version"]),
                        "compute_s": float(e["compute_s"])}
                       for e in self.buffer],
        }

    def restore(self, snap: Dict[str, Any]) -> None:
        """Rebuild the virtual-time state from a raw snapshot: the heap
        pops in the original order, buffered/in-flight frames are restored
        byte-for-byte, and every future draw re-keys identically — replay
        after restore is bitwise."""
        if snap.get("schema") != ASYNC_SNAPSHOT_SCHEMA:
            raise ValueError(
                f"unknown async snapshot schema {snap.get('schema')!r}")
        self.clock = float(snap["clock"])
        self.version = int(snap["version"])
        self.dispatched = int(snap["dispatched"])
        self.events_processed = int(snap["events_processed"])
        self.bytes_up = int(snap["bytes_up"])
        self.bytes_down = int(snap["bytes_down"])
        # don't re-emit pre-snapshot traffic to this process's counters
        self._bytes_up_reported = self.bytes_up
        self._bytes_down_reported = self.bytes_down
        self.useful_compute_s = float(snap["useful_compute_s"])
        self.discarded_compute_s = float(snap["discarded_compute_s"])
        self.updates_used = int(snap["updates_used"])
        self.updates_discarded = int(snap["updates_discarded"])
        self.heap = EventHeap.restore(snap["heap"])
        self.buffer = [
            {"update": ClientUpdate.from_bytes(e["frame"]),
             "dispatch_version": int(e["dispatch_version"]),
             "compute_s": float(e["compute_s"])}
            for e in snap["buffer"]]
