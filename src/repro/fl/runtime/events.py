"""Deterministic event-driven simulation for asynchronous federation.

Two layers live here:

``EventHeap``
    A virtual-clock priority queue ordered by ``(time, seq)`` with a
    monotonically assigned sequence number, so ties break identically on
    every replay. Entries are JSON-serializable dicts — the async engine
    checkpoints the heap (buffer + event clock, never wall time) and a
    restored heap pops in exactly the original order, which is what makes
    kill-and-resume bitwise.

``simulate_sync_utilization`` / ``simulate_async_utilization``
    Pure event simulators over ``ClientPopulation``'s device tiers +
    diurnal availability at up to 10^6 logical clients. No gradients are
    computed — only the *shape* of the traffic: per-dispatch compute and
    uplink durations from the population's two-part latency model, and the
    server's aggregation policy (deadline cutoff vs FedBuff buffer). They
    measure what the round-synchronous engine throws away: a straggler past
    the reporting deadline has burned its full local epoch, but its update
    never lands. The async buffer banks that same update into the next
    aggregation instead, so useful-compute utilization approaches 1.

All randomness is drawn through stateless ``SeedSequence`` keys per
(client, dispatch) — the same pattern as ``population._rng`` — so both
simulators replay bit-identically from any point.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Any, Dict, List, Optional

import numpy as np

from repro.fl.runtime.population import ClientPopulation, _rng

# entropy tags for the simulators' draws (disjoint from population/faults)
_T_PICK, _T_DROP = 0xA51C, 0xA5D0


class EventHeap:
    """Virtual-clock event queue with deterministic (time, seq) ordering.

    ``push`` assigns each entry the next sequence number, so two events at
    the same virtual time pop in insertion order — heapq never compares the
    payloads themselves. ``snapshot``/``restore`` round-trip the full queue
    (including the seq counter) through JSON-able structures.
    """

    def __init__(self):
        self._heap: List[tuple] = []
        self._next_seq = 0

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, time: float, payload: Dict[str, Any]) -> int:
        seq = self._next_seq
        self._next_seq += 1
        heapq.heappush(self._heap, (float(time), seq, payload))
        return seq

    def pop(self):
        """-> (time, seq, payload) of the earliest event."""
        return heapq.heappop(self._heap)

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def snapshot(self) -> Dict[str, Any]:
        entries = [{"t": t, "seq": s, "payload": p}
                   for t, s, p in sorted(self._heap, key=lambda e: e[:2])]
        return {"next_seq": self._next_seq, "entries": entries}

    @classmethod
    def restore(cls, snap: Dict[str, Any]) -> "EventHeap":
        out = cls()
        out._next_seq = int(snap["next_seq"])
        out._heap = [(float(e["t"]), int(e["seq"]), e["payload"])
                     for e in snap["entries"]]
        heapq.heapify(out._heap)
        return out


def sample_available(pop: ClientPopulation, tick: int, draw: int,
                     seed: int, max_probe: int = 64) -> int:
    """One available client id, rejection-sampled from the population at
    diurnal tick ``tick``. Deterministic in (seed, tick, draw); falls back
    to the last probe when the window is (nearly) empty so dispatch never
    stalls."""
    rng = _rng(seed, _T_PICK, tick, draw)
    cand = 0
    for _ in range(max_probe):
        cand = int(rng.integers(0, pop.n_clients))
        if pop.available(cand, tick):
            return cand
    return cand


@dataclasses.dataclass
class UtilizationReport:
    """What one simulated policy did with the fleet's compute."""
    mode: str                     # 'sync' | 'async'
    n_clients: int
    updates_applied: int          # updates that reached an aggregation
    updates_discarded: int        # computed but thrown away
    server_steps: int
    useful_compute_s: float       # Σ compute of applied updates
    total_compute_s: float        # Σ compute of every dispatched client
    sim_wall_s: float             # virtual seconds of server wall clock
    staleness_mean: float = 0.0
    staleness_max: int = 0

    @property
    def utilization(self) -> float:
        return self.useful_compute_s / max(self.total_compute_s, 1e-12)

    def to_doc(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["utilization"] = self.utilization
        d["updates_per_sim_hour"] = (
            3600.0 * self.updates_applied / max(self.sim_wall_s, 1e-12))
        return d


def simulate_sync_utilization(pop: ClientPopulation, *, cohort: int,
                              rounds: int, over_select: float = 1.25,
                              deadline_quantile: float = 0.9,
                              dropout_rate: float = 0.0,
                              work_s: float = 60.0,
                              seed: int = 0) -> UtilizationReport:
    """Round-synchronous policy: every round over-selects an available
    cohort, waits until the reporting deadline (a quantile of THIS cohort's
    completion times, mirroring ``CohortScheduler``'s cutoff), and discards
    every straggler's fully-computed update. Wall clock advances to the
    deadline whenever anyone was cut, else to the slowest survivor."""
    useful = total = wall = 0.0
    applied = discarded = 0
    target = int(math.ceil(cohort * over_select))
    for r in range(rounds):
        ids = [sample_available(pop, r, d, seed) for d in range(target)]
        comp = np.asarray([pop.compute_seconds(c, r, work_s) for c in ids])
        fin = comp + np.asarray([pop.uplink_seconds(c, r) for c in ids])
        deadline = float(np.quantile(fin, deadline_quantile))
        keep = fin <= deadline
        if dropout_rate > 0.0:
            keep &= _rng(seed, _T_DROP, r).random(len(ids)) >= dropout_rate
        total += float(comp.sum())
        useful += float(comp[keep].sum())
        applied += int(keep.sum())
        discarded += int((~keep).sum())
        wall += deadline if not keep.all() else float(fin.max())
    return UtilizationReport(
        mode="sync", n_clients=pop.n_clients, updates_applied=applied,
        updates_discarded=discarded, server_steps=rounds,
        useful_compute_s=useful, total_compute_s=total, sim_wall_s=wall)


def simulate_async_utilization(pop: ClientPopulation, *, concurrency: int,
                               buffer_size: int, server_steps: int,
                               dropout_rate: float = 0.0,
                               work_s: float = 60.0, seed: int = 0,
                               max_staleness: Optional[int] = None
                               ) -> UtilizationReport:
    """FedBuff policy: keep ``concurrency`` clients in flight; every
    arrival lands in the buffer (stragglers included — their work is merely
    STALE, not discarded); each ``buffer_size`` validated arrivals trigger a
    server step. Only dropouts and beyond-``max_staleness`` arrivals waste
    compute."""
    heap = EventHeap()
    clock = 0.0
    version = 0
    dispatched = 0
    buffered = 0
    useful = total = 0.0
    applied = discarded = 0
    staleness: List[int] = []

    def dispatch():
        nonlocal dispatched
        d = dispatched
        dispatched += 1
        tick = int(clock // max(work_s, 1e-9))
        cid = sample_available(pop, tick, d, seed)
        comp = pop.compute_seconds(cid, d, work_s)
        up = pop.uplink_seconds(cid, d)
        lost = (dropout_rate > 0.0 and
                _rng(seed, _T_DROP, cid, d).random() < dropout_rate)
        heap.push(clock + comp + up,
                  {"dispatch_version": version, "compute_s": comp,
                   "lost": lost})

    while version < server_steps:
        while len(heap) < concurrency:
            dispatch()
        clock, _, ev = heap.pop()
        # compute is accounted when the work has actually happened (at
        # arrival), so in-flight work at termination never skews the ratio
        total += float(ev["compute_s"])
        s = version - int(ev["dispatch_version"])
        if ev["lost"] or (max_staleness is not None and s > max_staleness):
            discarded += 1
            continue
        staleness.append(s)
        useful += float(ev["compute_s"])
        applied += 1
        buffered += 1
        if buffered >= buffer_size:
            buffered = 0
            version += 1
    return UtilizationReport(
        mode="async", n_clients=pop.n_clients, updates_applied=applied,
        updates_discarded=discarded, server_steps=version,
        useful_compute_s=useful, total_compute_s=total, sim_wall_s=clock,
        staleness_mean=float(np.mean(staleness)) if staleness else 0.0,
        staleness_max=int(np.max(staleness)) if staleness else 0)
