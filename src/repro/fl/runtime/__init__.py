"""Scale-out federation runtime (wire protocol, population, executors,
round engine). Import explicitly — ``from repro.fl.runtime import ...`` —
rather than via ``repro.fl`` (which core.spry imports; keeping the runtime
out of that __init__ avoids an import cycle)."""
from repro.fl.runtime.async_engine import (
    AsyncConfig,
    AsyncFederationEngine,
    AsyncRoundReport,
)
from repro.fl.runtime.engine import (
    FederationEngine,
    RoundReport,
    WireConfig,
    WireHealth,
)
from repro.fl.runtime.events import (
    EventHeap,
    UtilizationReport,
    sample_available,
    simulate_async_utilization,
    simulate_sync_utilization,
)
from repro.fl.runtime.executor import (
    SerialExecutor,
    ShardedExecutor,
    pad_cohort,
)
from repro.fl.runtime.faults import (
    FaultConfig,
    FaultCounters,
    FaultInjector,
)
from repro.fl.runtime.messages import (
    ClientUpdate,
    TaskAssignment,
    WIRE_DTYPES,
    WIRE_SCHEMA,
    WireError,
    decode_frame,
    wire_dtype,
)
from repro.fl.runtime.population import (
    ClientPopulation,
    CohortPlan,
    CohortScheduler,
    DeviceTier,
)
