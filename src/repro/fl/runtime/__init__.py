"""Scale-out federation runtime (wire protocol, population, executors,
round engine). Import explicitly — ``from repro.fl.runtime import ...`` —
rather than via ``repro.fl`` (which core.spry imports; keeping the runtime
out of that __init__ avoids an import cycle)."""
from repro.fl.runtime.engine import (
    FederationEngine,
    RoundReport,
    WireConfig,
)
from repro.fl.runtime.executor import (
    SerialExecutor,
    ShardedExecutor,
    pad_cohort,
)
from repro.fl.runtime.messages import (
    ClientUpdate,
    TaskAssignment,
    WIRE_DTYPES,
    wire_dtype,
)
from repro.fl.runtime.population import (
    ClientPopulation,
    CohortPlan,
    CohortScheduler,
    DeviceTier,
)
