"""Federation round engine: scheduler -> executor -> aggregator -> server.

One ``FederationEngine`` drives SPRY rounds through the runtime pieces for
both communication modes:

  per_epoch      clients run local forward-gradient SGD and ship masked
                 deltas; the server re-averages each unit over the clients
                 whose update actually ARRIVED (dropout-corrected counts —
                 the fixed-M ``client_counts`` of the in-process step cannot
                 express a straggler whose payload never lands).
  per_iteration  clients ship K jvp scalars + seed ref; the server
                 regenerates the perturbations and rebuilds/aggregates the
                 gradients (paper §3.2 / Table 2).

Bit-identity contract (tests/test_runtime.py): with full participation, an
ideal network (no wire quantization / wire simulation off or fp32) and the
whole-cohort SerialExecutor, ``run_round`` is bit-identical to
``core.spry.make_round_step`` / ``make_round_step_per_iteration`` — the
engine composes exactly the pieces those round steps are built from
(make_client_update_fn / make_client_jvp_fn / make_rebuild_fn /
aggregate_payloads) in the same op order inside one jit.

Wire simulation (``WireConfig(simulate=True)``) routes every surviving
client's payload through a real serialized ``ClientUpdate`` frame
(measured bytes, configurable fp32/bf16/fp16 scalar quantization) before
aggregation; fp32 framing is bit-exact.

Fault tolerance (``faults=`` + ``quorum=``): with a ``FaultInjector`` the
simulated wire becomes chaotic — crashes, corruption, loss-with-retry,
duplication, poisoned payloads — and the server side gains the full
defensive stack: strict decode quarantines bad frames (counted, never
aggregated), payload validation rejects NaN/Inf and norm-outlier updates,
dedupe drops duplicate deliveries, and quorum gating either re-extends the
cohort deterministically from the over-selection pool (stragglers whose
updates were already computed) or skips the server step and carries the
round forward. Dropout-corrected unit counts and all survivor metrics
derive from the VALIDATED survivor set only. With faults disabled the
engine takes the exact pre-existing code paths (bit-identity preserved).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment import assignment_matrix, enumerate_units
from repro.core.spry import (
    SpryState,
    aggregate_payloads,
    make_client_jvp_fn,
    make_client_update_fn,
    make_count_tree,
    make_rebuild_fn,
)
from repro.fl.runtime.executor import (
    SerialExecutor,
    _weighted,
    pad_cohort,
)
from repro.fl.runtime.faults import FaultConfig, FaultInjector
from repro.fl.runtime.messages import (
    ClientUpdate,
    WireError,
    decode_frame,
    wire_dtype,
)
from repro.fl.runtime.population import CohortPlan
from repro.fl.server import server_update
from repro.obs import NULL
from repro.utils.pytree import tree_size


@dataclasses.dataclass(frozen=True)
class WireConfig:
    """Uplink wire behaviour. ``simulate=True`` packs/unpacks real frames
    (collect mode — test/accounting scale); False streams in-process and
    only *accounts* bytes from zero-filled template frames."""
    dtype: str = "fp32"
    simulate: bool = False
    include_head: bool = True


@dataclasses.dataclass
class WireHealth:
    """Per-round tally of the chaotic uplink and the server's defenses."""
    sent: int = 0            # frames serialized for transmission
    transmissions: int = 0   # uplink attempts (every one burns bytes)
    delivered: int = 0       # frames that reached the server at all
    accepted: int = 0        # strict-decoded OK after dedupe
    validated: int = 0       # passed defensive payload validation
    crashed: int = 0         # clients that died before transmitting
    lost: int = 0            # frames that exhausted every retry
    retries: int = 0         # attempts beyond the first
    backoff_s: float = 0.0   # total simulated retry backoff
    quarantined: int = 0     # delivered frames rejected by strict decode
    duplicates: int = 0      # deliveries deduped at the server
    invalid: int = 0         # decoded OK but failed payload validation
    requorumed: int = 0      # pool clients activated to reach quorum
    failure_kinds: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RoundReport:
    round_idx: int
    cohort_size: int                 # scheduled (over-selected) cohort
    n_requested: int
    n_survivors: int
    dropped_client_ids: List[int]
    deadline: float
    bytes_down: int                  # Σ TaskAssignment frames
    bytes_up: int                    # Σ surviving ClientUpdate frames
    wire: str
    executor: str
    n_devices: int
    agg_bytes_streaming: int         # accumulator bytes (O(peft) / device)
    agg_bytes_stacked: int           # (C, peft) materialization equivalent
    # fault-tolerance fields (defaulted: clean-path constructors unchanged)
    n_validated: int = -1            # survivors the aggregator actually used
    dropped_frame_ids: List[int] = dataclasses.field(default_factory=list)
    quorum: int = 0                  # resolved quorum (0 = ungated)
    quorum_met: bool = True
    round_skipped: bool = False      # below quorum: server step skipped
    health: Optional[WireHealth] = None

    def __post_init__(self):
        if self.n_validated < 0:
            self.n_validated = self.n_survivors


def update_payload_arrays(u: ClientUpdate) -> List[np.ndarray]:
    """Flat list of a ClientUpdate's payload arrays in canonical order
    (shared by the sync and async engines' defensive validation)."""
    arrs = []
    if u.mode == "delta":
        for uid in sorted(u.unit_payload or {}):
            arrs.extend(u.unit_payload[uid])
        if u.head_payload is not None:
            arrs.extend(u.head_payload)
    elif u.jvps is not None:
        arrs.append(u.jvps)
    return arrs


def poison_update(inj: FaultInjector, u: ClientUpdate, mode: str) -> None:
    """Client-side numeric poisoning BEFORE framing: the frame's CRC is
    valid — only defensive payload validation can catch these."""
    if u.mode == "delta":
        u.unit_payload = {
            k: [inj.poison_array(np.asarray(a), mode) for a in v]
            for k, v in (u.unit_payload or {}).items()}
        if u.head_payload is not None:
            u.head_payload = [inj.poison_array(np.asarray(a), mode)
                              for a in u.head_payload]
    else:
        u.jvps = inj.poison_array(np.asarray(u.jvps), mode)
    u.invalidate_encoding()


def validate_updates(accepted: Dict[int, ClientUpdate],
                     norm_outlier_mult: float) -> set:
    """Defensive payload validation: reject NaN/Inf outright; with a
    crowd (>= 4 finite updates) also reject norm outliers beyond
    ``norm_outlier_mult`` x the median survivor norm."""
    norms = {}
    for pos, u in accepted.items():
        sq, ok = 0.0, True
        for a in update_payload_arrays(u):
            a = np.asarray(a, np.float64)
            if not np.all(np.isfinite(a)):
                ok = False
                break
            sq += float(np.sum(a * a))
        norms[pos] = math.sqrt(sq) if ok else None
    valid = {p for p, n in norms.items() if n is not None}
    if len(valid) >= 4:
        med = float(np.median([norms[p] for p in valid]))
        if med > 0.0:
            valid = {p for p in valid
                     if norms[p] <= norm_outlier_mult * med}
    return valid


def _ideal_plan(round_idx: int, M: int, n_units: int) -> CohortPlan:
    """Full participation, no over-selection, everyone on time."""
    mask = np.asarray(assignment_matrix(n_units, M, round_idx % M),
                      np.float32)
    return CohortPlan(
        round_idx=round_idx, client_ids=np.arange(M, dtype=np.int64),
        seed_ids=np.arange(M, dtype=np.int32), mask_matrix=mask,
        latencies=np.zeros(M), deadline=float("inf"),
        keep=np.ones(M, bool), assignments=[], n_requested=M)


class FederationEngine:
    def __init__(self, cfg, spry_cfg, task: str = "cls",
                 comm_mode: Optional[str] = None, executor=None,
                 wire: Optional[WireConfig] = None, telemetry=None,
                 faults=None, quorum: Optional[float] = None,
                 norm_outlier_mult: float = 100.0):
        self.cfg = cfg
        self.spry_cfg = spry_cfg
        self.task = task
        self.wire = wire or WireConfig()
        if isinstance(faults, FaultConfig):
            faults = FaultInjector(faults)
        if faults is not None and not self.wire.simulate:
            raise ValueError(
                "fault injection perturbs serialized frames — it requires "
                "WireConfig(simulate=True)")
        self.faults: Optional[FaultInjector] = faults
        # quorum: fraction of the requested cohort in (0, 1], or an
        # absolute survivor count >= 1; None disables the gate
        if quorum is not None and quorum <= 0:
            raise ValueError(f"quorum must be positive, got {quorum}")
        self.quorum = quorum
        self.norm_outlier_mult = float(norm_outlier_mult)
        # host-side telemetry on already-returned values ONLY: the jitted
        # round bodies below never see this object, so telemetry-on traces
        # the identical program (tests/test_telemetry_neutrality.py)
        tel = telemetry if telemetry is not None else NULL
        self.telemetry = tel
        self._tc_rounds = tel.counter("fl.rounds")
        self._tc_bytes_up = tel.counter("fl.bytes_up")
        self._tc_bytes_down = tel.counter("fl.bytes_down")
        self._tc_stragglers = tel.counter("fl.stragglers")
        self._tg_survivors = tel.gauge("fl.survivors")
        self._tg_mask_units = tel.gauge("fl.surviving_mask_units")
        self._tg_loss = tel.gauge("fl.loss")
        self._tg_jvp = tel.gauge("fl.jvp_abs_mean")
        self._tg_delta = tel.gauge("fl.delta_norm")
        self._th_round_s = tel.histogram("fl.round_seconds")
        # fault-tolerance observability (host-side, zero-cost when clean)
        self._tc_quarantined = tel.counter("fl.quarantined")
        self._tc_corrupt = tel.counter("fl.corrupt_frames")
        self._tc_lost = tel.counter("fl.lost_updates")
        self._tc_crashed = tel.counter("fl.crashed_clients")
        self._tc_dups = tel.counter("fl.duplicate_frames")
        self._tc_retried = tel.counter("fl.retried_attempts")
        self._tc_invalid = tel.counter("fl.invalid_payloads")
        self._tc_requorumed = tel.counter("fl.requorumed")
        self._tc_skipped = tel.counter("fl.rounds_skipped")
        self._th_retries = tel.histogram("fl.retries_per_round")
        self.comm_mode = comm_mode or spry_cfg.comm_mode
        if self.comm_mode not in ("per_epoch", "per_iteration"):
            raise ValueError(self.comm_mode)
        self.executor = executor if executor is not None else SerialExecutor()
        # whole-cohort serial execution can materialize the client stack and
        # reuse the reference aggregation verbatim (bit-identity); any
        # microbatched/sharded executor streams instead
        self.collect = (isinstance(self.executor, SerialExecutor)
                        and self.executor.microbatch is None)
        if self.comm_mode == "per_epoch":
            self._client_fn = make_client_update_fn(cfg, spry_cfg, task)
        else:
            self._client_fn = make_client_jvp_fn(cfg, spry_cfg, task)
            self._rebuild_fn = make_rebuild_fn()
        self._round_jit = jax.jit(self._round_fn)
        self._clients_jit = jax.jit(self._clients_fn)
        self._aggregate_jit = jax.jit(self._aggregate_fn)

    # ------------------------------------------------------------------
    # jitted bodies
    # ------------------------------------------------------------------

    def _kernels(self):
        if self.comm_mode == "per_epoch":
            def kernel(base, peft, rk, sid, row, cb):
                delta, loss, jvps = self._client_fn(base, peft, rk, sid, row,
                                                    cb)
                return delta, (loss, jvps)
            return kernel, None

        def kernel(base, peft, rk, sid, row, cb):
            loss, jvps = self._client_fn(base, peft, rk, sid, row, cb)
            return (), (loss, jvps)

        def rebuild_kernel(base, peft, rk, sid, row, jvps):
            return self._rebuild_fn(peft, rk, sid, row, jvps), ()
        return kernel, rebuild_kernel

    def _round_key(self, state):
        return jax.random.fold_in(
            jax.random.PRNGKey(self.spry_cfg.seed), state.round_idx)

    def _finish(self, state, peft, index, payload_sum_or_stack, counts,
                head_count, losses, jvps, keep, stacked: bool):
        """Shared tail: unit-averaged payload -> server update + metrics."""
        if stacked:
            agg = aggregate_payloads(peft, index, payload_sum_or_stack,
                                     counts, head_count)
        else:
            count_tree = make_count_tree(peft, index, counts, head_count)
            agg = jax.tree.map(lambda s, c: s / c, payload_sum_or_stack,
                               count_tree)
        if self.comm_mode == "per_iteration":
            delta = jax.tree.map(lambda g: -self.spry_cfg.local_lr * g, agg)
        else:
            delta = agg
        new_peft, server = server_update(
            self.spry_cfg.server_opt, peft, delta, state.server,
            lr=self.spry_cfg.server_lr)
        jvps_flat = jvps.reshape(jvps.shape[0], -1)   # (C, local_iters*K)
        n_kept = keep.sum()
        metrics = {
            "loss": (losses * keep).sum() / n_kept,
            "jvp_abs_mean": (jnp.abs(jvps_flat) * keep[:, None]).sum()
            / (n_kept * jvps_flat.shape[-1]),
            # active estimator route (matches make_round_step's metrics so
            # the ideal-round bit-identity contract extends to telemetry)
            "fused_route": jnp.float32(self.spry_cfg.fused_contraction),
        }
        if self.comm_mode == "per_epoch":
            metrics["delta_norm"] = jnp.sqrt(
                sum(jnp.sum(d * d) for d in jax.tree.leaves(delta)))
        new_state = SpryState(state.base, new_peft, server,
                              state.round_idx + 1)
        return new_state, metrics

    def _round_fn(self, state, seed_ids, mask_matrix, keep, batch):
        """Whole round in one jit (wire simulation off)."""
        base, peft = state.base, state.peft
        index = enumerate_units(peft)
        rk = self._round_key(state)
        kernel, rebuild_kernel = self._kernels()
        counts = jnp.maximum((mask_matrix * keep[:, None]).sum(0), 1.0)
        head_count = keep.sum()

        payload, (losses, jvps) = self.executor.run(
            kernel, base, peft, rk, seed_ids, mask_matrix, batch, keep,
            collect=self.collect)
        if self.comm_mode == "per_iteration":
            payload, _ = self.executor.run(
                rebuild_kernel, base, peft, rk, seed_ids, mask_matrix, jvps,
                keep, collect=self.collect)
        if self.collect:
            payload = _weighted(payload, keep)
        return self._finish(state, peft, index, payload, counts, head_count,
                            losses, jvps, keep, stacked=self.collect)

    def _clients_fn(self, state, seed_ids, mask_matrix, keep, batch):
        """Wire-sim phase 1: per-client payload stack + telemetry."""
        base, peft = state.base, state.peft
        rk = self._round_key(state)
        kernel, _ = self._kernels()
        payload, (losses, jvps) = self.executor.run(
            kernel, base, peft, rk, seed_ids, mask_matrix, batch, keep,
            collect=True)
        return payload, losses, jvps

    def _aggregate_fn(self, state, stacked, seed_ids, mask_matrix, keep,
                      losses, jvps):
        """Wire-sim phase 2: aggregate the unpacked payload stack."""
        peft = state.peft
        index = enumerate_units(peft)
        counts = jnp.maximum((mask_matrix * keep[:, None]).sum(0), 1.0)
        if self.comm_mode == "per_iteration":
            rk = self._round_key(state)
            _, rebuild_kernel = self._kernels()
            stacked, _ = self.executor.run(
                rebuild_kernel, state.base, peft, rk, seed_ids, mask_matrix,
                stacked, keep, collect=True)
        return self._finish(state, peft, index, _weighted(stacked, keep),
                            counts, keep.sum(), losses, jvps, keep,
                            stacked=True)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def run_ideal(self, state, batch) -> Tuple[Any, Dict[str, Any]]:
        """Full-participation round on a stacked (M, B, ...) batch —
        semantically the in-process ``make_round_step`` executed through the
        runtime (bit-identical with the default whole-cohort executor)."""
        M = jax.tree.leaves(batch)[0].shape[0]
        index = enumerate_units(state.peft)
        plan = _ideal_plan(int(state.round_idx), M, index.n_units)
        state, metrics, _ = self.run_round(state, plan, batch)
        return state, metrics

    def _resolve_quorum(self, plan: CohortPlan) -> int:
        """Resolve the quorum knob to an absolute validated-survivor count:
        a float <= 1.0 is a fraction of the REQUESTED cohort, anything else
        an absolute count. 0 = gate disabled."""
        if self.quorum is None:
            return 0
        q = self.quorum
        if isinstance(q, float) and q <= 1.0:
            return int(math.ceil(q * plan.n_requested))
        return int(q)

    def _requorum_prejit(self, plan: CohortPlan, quorum_n: int):
        """Clean-path quorum: deterministically re-extend the survivor set
        from the over-selection pool in latency order (the next-fastest
        stragglers — their compute exists, only their deadline was missed).
        Returns (effective_keep, n_requorumed, quorum_met)."""
        keep = np.asarray(plan.keep, bool).copy()
        requorumed = 0
        if quorum_n and int(keep.sum()) < quorum_n:
            pool = np.flatnonzero(~keep)
            pool = pool[np.argsort(plan.latencies[pool], kind="stable")]
            for i in pool:
                if int(keep.sum()) >= quorum_n:
                    break
                keep[i] = True
                requorumed += 1
        met = (not quorum_n) or int(keep.sum()) >= quorum_n
        return keep, requorumed, met

    def _skip_round(self, state):
        """Below quorum with the pool exhausted: skip the server step and
        carry the round index forward (the caller sees NaN metrics)."""
        new_state = SpryState(state.base, state.peft, state.server,
                              state.round_idx + 1)
        nan = jnp.float32(float("nan"))
        metrics = {"loss": nan, "jvp_abs_mean": nan,
                   "fused_route": jnp.float32(self.spry_cfg.fused_contraction)}
        if self.comm_mode == "per_epoch":
            metrics["delta_norm"] = nan
        return new_state, metrics

    def run_round(self, state, plan: CohortPlan, batch):
        """Execute one scheduled round. ``batch`` leaves lead with the plan's
        cohort axis. Returns (state, metrics, RoundReport)."""
        tel = self.telemetry
        t_round = time.perf_counter()
        index = enumerate_units(state.peft)
        quorum_n = self._resolve_quorum(plan)
        extra: Dict[str, Any] = {}
        if self.faults is None:
            keep_eff, requorumed, quorum_met = self._requorum_prejit(
                plan, quorum_n)
        else:  # chaos path re-quorums post-validation, not pre-jit
            keep_eff, requorumed, quorum_met = (
                np.asarray(plan.keep, bool), 0, True)
        keep = np.asarray(keep_eff, np.float32)
        seed_ids, mask_rows, batch_p, keep_p, C = pad_cohort(
            self.executor, np.asarray(plan.seed_ids, np.int32),
            plan.mask_matrix, batch, keep)

        with tel.span("fl.round", round=int(plan.round_idx),
                      cohort=plan.cohort_size, comm_mode=self.comm_mode):
            if self.faults is not None:
                new_state, metrics, bytes_up, extra = self._run_chaos(
                    state, seed_ids, mask_rows, keep_p, batch_p, plan, C,
                    quorum_n)
            elif not quorum_met:
                new_state, metrics = self._skip_round(state)
                bytes_up = 0
            elif self.wire.simulate:
                new_state, metrics, bytes_up = self._run_simulated(
                    state, seed_ids, mask_rows, keep_p, batch_p, plan, C,
                    keep_eff)
            else:
                with tel.span("fl.execute"):
                    new_state, metrics = self._round_jit(
                        state, seed_ids, mask_rows, keep_p, batch_p)
                bytes_up = self._estimate_uplink(state.peft, index, plan,
                                                 keep_override=keep_eff)

        if self.faults is None:
            skipped = not quorum_met
            n_validated = 0 if skipped else int(keep_eff.sum())
            health = None
            dropped_frame_ids: List[int] = []
            if quorum_n:
                health = WireHealth(validated=n_validated,
                                    requorumed=requorumed)
        else:
            skipped = extra["round_skipped"]
            quorum_met = extra["quorum_met"]
            n_validated = extra["n_validated"]
            health = extra["health"]
            dropped_frame_ids = extra["dropped_frame_ids"]

        peft_bytes = tree_size(state.peft) * 4
        m = self.executor.microbatch or (len(seed_ids)
                                         // self.executor.n_devices)
        report = RoundReport(
            round_idx=int(plan.round_idx),
            cohort_size=plan.cohort_size,
            n_requested=plan.n_requested,
            n_survivors=plan.n_survivors,
            dropped_client_ids=[int(c) for c, k in
                                zip(plan.client_ids, plan.keep) if not k],
            deadline=float(plan.deadline),
            bytes_down=plan.downlink_bytes(),
            bytes_up=int(bytes_up),
            wire=self.wire.dtype,
            executor=type(self.executor).__name__,
            n_devices=self.executor.n_devices,
            agg_bytes_streaming=(m + 1) * peft_bytes,
            agg_bytes_stacked=len(seed_ids) * peft_bytes,
            n_validated=n_validated,
            dropped_frame_ids=dropped_frame_ids,
            quorum=quorum_n,
            quorum_met=bool(quorum_met),
            round_skipped=bool(skipped),
            health=health,
        )
        if tel.enabled:
            self._record_round(plan, metrics, report,
                               time.perf_counter() - t_round)
        return new_state, metrics, report

    def _record_round(self, plan: CohortPlan, metrics, report: RoundReport,
                      wall_s: float) -> None:
        """Host-side recording on the round's RETURNED values: the float()
        conversions below force a device sync on already-computed arrays,
        never a recompute — the metrics tree handed back to the caller is
        untouched (bitwise-identity asserted in tests)."""
        host = {k: float(v) for k, v in metrics.items()}
        # survivors/stragglers derive from the VALIDATED survivor set the
        # aggregator actually used (n_validated == n_survivors on the clean
        # path), so telemetry can never drift from the aggregation
        stragglers = report.cohort_size - report.n_validated
        mask_units = float(
            np.asarray(plan.mask_matrix)[np.asarray(plan.keep, bool)].sum())
        self._tc_rounds.inc()
        self._tc_bytes_up.add(report.bytes_up)
        self._tc_bytes_down.add(report.bytes_down)
        self._tc_stragglers.add(stragglers)
        self._tg_survivors.set(report.n_validated)
        self._tg_mask_units.set(mask_units)
        self._tg_loss.set(host["loss"])
        if "jvp_abs_mean" in host:
            self._tg_jvp.set(host["jvp_abs_mean"])
        if "delta_norm" in host:
            self._tg_delta.set(host["delta_norm"])
        self._th_round_s.observe(wall_s)
        if report.round_skipped:
            self._tc_skipped.inc()
        h = report.health
        if h is not None:
            self._tc_quarantined.add(h.quarantined)
            self._tc_corrupt.add(h.failure_kinds.get("corrupt", 0)
                                 + h.failure_kinds.get("truncated", 0))
            self._tc_lost.add(h.lost)
            self._tc_crashed.add(h.crashed)
            self._tc_dups.add(h.duplicates)
            self._tc_retried.add(h.retries)
            self._tc_invalid.add(h.invalid)
            self._tc_requorumed.add(h.requorumed)
            self._th_retries.observe(h.retries)
            self.telemetry.event(
                "wire_health",
                round=report.round_idx,
                quorum=report.quorum,
                quorum_met=report.quorum_met,
                round_skipped=report.round_skipped,
                dropped_frame_ids=report.dropped_frame_ids,
                **dataclasses.asdict(h),
            )
        self.telemetry.event(
            "round",
            round=report.round_idx,
            comm_mode=self.comm_mode,
            route=("fused" if host.get("fused_route") else "standard"),
            loss=host["loss"],
            jvp_abs_mean=host.get("jvp_abs_mean"),
            delta_norm=host.get("delta_norm"),
            bytes_up=report.bytes_up,
            bytes_down=report.bytes_down,
            cohort=report.cohort_size,
            survivors=report.n_validated,
            stragglers=stragglers,
            dropped=report.dropped_client_ids,
            surviving_mask_units=mask_units,
            executor=report.executor,
            wire=report.wire,
            n_devices=report.n_devices,
            wall_s=round(wall_s, 6),
        )

    # -- wire simulation ------------------------------------------------

    def _stack_arrived(self, payload, jvps, seed_ids, index, rows):
        """Rebuild the cohort payload stack from what ARRIVED: ``rows`` maps
        cohort position -> decoded ClientUpdate; everyone else gets zeros."""
        if self.comm_mode == "per_epoch":
            template = jax.tree.map(np.zeros_like, jax.tree.map(
                lambda x: np.asarray(x[0]), payload))
            deltas = {pos: u.to_delta(template, index)
                      for pos, u in rows.items()}
            return jax.tree.map(
                lambda *xs: jnp.asarray(np.stack(xs)),
                *[deltas.get(i, template) for i in range(len(seed_ids))])
        arr = np.zeros((len(seed_ids),) + tuple(np.shape(jvps)[1:]),
                       np.float32)
        for pos, u in rows.items():
            arr[pos] = np.asarray(u.jvps, np.float32)
        return jnp.asarray(arr)

    def _run_simulated(self, state, seed_ids, mask_rows, keep, batch, plan,
                       C, keep_eff):
        tel = self.telemetry
        with tel.span("fl.clients"):
            payload, losses, jvps = self._clients_jit(
                state, seed_ids, mask_rows, keep, batch)
        with tel.span("fl.wire", n_survivors=int(keep_eff.sum())):
            updates = self.pack_updates(state.peft, payload, jvps, losses,
                                        plan, keep_override=keep_eff)
            bytes_up = sum(u.byte_size() for u in updates)
            # the server only sees what arrived: unpack frames back into the
            # cohort stack (zeros for dropped clients). Frames carry the
            # fold-in seed_id; cohort POSITION comes from keep order
            # (pack_updates emits survivors in plan order).
            survivor_pos = np.flatnonzero(keep_eff)
            index = enumerate_units(state.peft)
            rows = {int(pos): u for pos, u in zip(survivor_pos, updates)}
            stacked = self._stack_arrived(payload, jvps, seed_ids, index,
                                          rows)
        with tel.span("fl.aggregate"):
            new_state, metrics = self._aggregate_jit(
                state, stacked, seed_ids, mask_rows, keep, losses, jvps)
        return new_state, metrics, bytes_up

    def _pack_one(self, index, payload, jvps, losses, plan: CohortPlan,
                  i: int) -> ClientUpdate:
        """Serialize cohort row ``i``'s uplink frame."""
        cid, sid = int(plan.client_ids[i]), int(plan.seed_ids[i])
        if self.comm_mode == "per_epoch":
            delta_i = jax.tree.map(lambda x: np.asarray(x[i]), payload)
            unit_ids = np.flatnonzero(plan.mask_matrix[i] > 0)
            return ClientUpdate.from_delta(
                delta_i, index, unit_ids, round_idx=plan.round_idx,
                client_id=cid, seed_id=sid, wire=self.wire.dtype,
                loss=float(losses[i]), include_head=self.wire.include_head)
        return ClientUpdate.from_jvps(
            np.asarray(jvps[i]), round_idx=plan.round_idx, client_id=cid,
            seed_id=sid, wire=self.wire.dtype, loss=float(losses[i]))

    def pack_updates(self, peft, payload, jvps, losses, plan: CohortPlan,
                     keep_override=None) -> List[ClientUpdate]:
        """Serialize every SURVIVING client's uplink frame."""
        index = enumerate_units(peft)
        keep_vec = plan.keep if keep_override is None else keep_override
        return [self._pack_one(index, payload, jvps, losses, plan, i)
                for i in range(len(plan.client_ids)) if keep_vec[i]]

    # -- chaos path -----------------------------------------------------

    def _update_arrays(self, u: ClientUpdate):
        return update_payload_arrays(u)

    def _poison_update(self, u: ClientUpdate, mode: str) -> None:
        poison_update(self.faults, u, mode)

    def _validate_updates(self, accepted) -> set:
        return validate_updates(accepted, self.norm_outlier_mult)

    def _run_chaos(self, state, seed_ids, mask_rows, keep, batch, plan, C,
                   quorum_n):
        """Wire simulation under fault injection: every kept client's frame
        runs the full gauntlet (crash -> poison -> retry/loss -> corrupt ->
        strict decode -> dedupe -> validate), quorum re-extends from the
        over-selection pool through the SAME gauntlet, and aggregation sees
        only validated survivors. Returns (state', metrics, bytes_up,
        extra-dict for the RoundReport)."""
        tel = self.telemetry
        inj = self.faults
        inj.take_counters()          # fresh per-round injector tally
        with tel.span("fl.clients"):
            payload, losses, jvps = self._clients_jit(
                state, seed_ids, mask_rows, keep, batch)
        index = enumerate_units(state.peft)
        health = WireHealth()
        accepted: Dict[int, ClientUpdate] = {}
        attempted: List[int] = []
        bytes_up = 0

        def push(i: int) -> None:
            nonlocal bytes_up
            cid = int(plan.client_ids[i])
            attempted.append(i)
            scale = (float(plan.crash_scales[i])
                     if plan.crash_scales is not None else 1.0)
            if inj.crashes(cid, plan.round_idx, scale):
                health.crashed += 1
                return
            u = self._pack_one(index, payload, jvps, losses, plan, i)
            mode = inj.poison_mode(cid, plan.round_idx)
            if mode is not None:
                self._poison_update(u, mode)
            frame = u.to_bytes()
            health.sent += 1
            delivered, attempts, _ = inj.transmit(frame, cid, plan.round_idx)
            bytes_up += len(frame) * attempts   # every attempt burns uplink
            health.transmissions += attempts
            health.retries += attempts - 1
            if not delivered:
                health.lost += 1
                return
            for fb in delivered:
                health.delivered += 1
                if i in accepted:       # at-least-once delivery: dedupe
                    health.duplicates += 1
                    continue
                try:
                    dec = decode_frame(fb)
                except WireError as e:
                    health.quarantined += 1
                    health.failure_kinds[e.kind] = \
                        health.failure_kinds.get(e.kind, 0) + 1
                    continue
                accepted[i] = dec

        with tel.span("fl.wire", chaos=True):
            for i in np.flatnonzero(np.asarray(plan.keep, bool)):
                push(int(i))
            valid = self._validate_updates(accepted)
            # quorum gate: re-extend deterministically from the
            # over-selection pool in latency order; pool clients run the
            # same chaotic gauntlet (they may crash/corrupt too)
            pool = np.flatnonzero(~np.asarray(plan.keep, bool))
            pool = pool[np.argsort(plan.latencies[pool], kind="stable")]
            pi = 0
            while quorum_n and len(valid) < quorum_n and pi < len(pool):
                i = int(pool[pi])
                pi += 1
                health.requorumed += 1
                push(i)
                valid = self._validate_updates(accepted)

        health.accepted = len(accepted)
        health.validated = len(valid)
        health.invalid = len(accepted) - len(valid)
        health.backoff_s = inj.take_counters().backoff_s
        quorum_met = (not quorum_n) or len(valid) >= quorum_n
        extra = {
            "n_validated": len(valid),
            "dropped_frame_ids": sorted(int(plan.seed_ids[i])
                                        for i in attempted if i not in valid),
            "quorum_met": quorum_met,
            "round_skipped": not quorum_met,
            "health": health,
        }
        if not quorum_met:
            new_state, metrics = self._skip_round(state)
            return new_state, metrics, bytes_up, extra
        keep_valid = np.zeros(len(seed_ids), np.float32)
        keep_valid[sorted(valid)] = 1.0
        rows = {p: accepted[p] for p in valid}
        stacked = self._stack_arrived(payload, jvps, seed_ids, index, rows)
        with tel.span("fl.aggregate"):
            new_state, metrics = self._aggregate_jit(
                state, stacked, seed_ids, mask_rows, keep_valid, losses,
                jvps)
        return new_state, metrics, bytes_up, extra

    def _estimate_uplink(self, peft, index, plan: CohortPlan,
                         keep_override=None) -> int:
        """Measured frame size of zero-filled template updates. Frame size
        depends only on the unit-id set and the header-int digit widths, so
        sizes are memoized — no per-round O(|peft|) serialization."""
        if not hasattr(self, "_uplink_cache"):
            self._uplink_cache = {}
            self._zeros_peft = jax.tree.map(
                lambda x: np.zeros(x.shape, np.float32), peft)
        total = 0
        K = self.spry_cfg.k_perturbations
        keep_vec = plan.keep if keep_override is None else keep_override
        for i, (cid, k) in enumerate(zip(plan.client_ids, keep_vec)):
            if not k:
                continue
            sid = int(plan.seed_ids[i])
            if self.comm_mode == "per_epoch":
                unit_ids = np.flatnonzero(plan.mask_matrix[i] > 0)
                ckey = (tuple(unit_ids.tolist()),)
            else:
                unit_ids = None
                ckey = (K,)
            ckey += (len(str(int(plan.round_idx))), len(str(int(cid))),
                     len(str(sid)))
            if ckey not in self._uplink_cache:
                if self.comm_mode == "per_epoch":
                    u = ClientUpdate.from_delta(
                        self._zeros_peft, index, unit_ids,
                        round_idx=plan.round_idx, client_id=int(cid),
                        seed_id=sid, wire=self.wire.dtype,
                        include_head=self.wire.include_head)
                else:
                    u = ClientUpdate.from_jvps(
                        np.zeros((K,), np.float32),
                        round_idx=plan.round_idx, client_id=int(cid),
                        seed_id=sid, wire=self.wire.dtype)
                self._uplink_cache[ckey] = u.byte_size()
            total += self._uplink_cache[ckey]
        return total
