"""Federation round engine: scheduler -> executor -> aggregator -> server.

One ``FederationEngine`` drives SPRY rounds through the runtime pieces for
both communication modes:

  per_epoch      clients run local forward-gradient SGD and ship masked
                 deltas; the server re-averages each unit over the clients
                 whose update actually ARRIVED (dropout-corrected counts —
                 the fixed-M ``client_counts`` of the in-process step cannot
                 express a straggler whose payload never lands).
  per_iteration  clients ship K jvp scalars + seed ref; the server
                 regenerates the perturbations and rebuilds/aggregates the
                 gradients (paper §3.2 / Table 2).

Bit-identity contract (tests/test_runtime.py): with full participation, an
ideal network (no wire quantization / wire simulation off or fp32) and the
whole-cohort SerialExecutor, ``run_round`` is bit-identical to
``core.spry.make_round_step`` / ``make_round_step_per_iteration`` — the
engine composes exactly the pieces those round steps are built from
(make_client_update_fn / make_client_jvp_fn / make_rebuild_fn /
aggregate_payloads) in the same op order inside one jit.

Wire simulation (``WireConfig(simulate=True)``) routes every surviving
client's payload through a real serialized ``ClientUpdate`` frame
(measured bytes, configurable fp32/bf16/fp16 scalar quantization) before
aggregation; fp32 framing is bit-exact.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment import assignment_matrix, enumerate_units
from repro.core.spry import (
    SpryState,
    aggregate_payloads,
    make_client_jvp_fn,
    make_client_update_fn,
    make_count_tree,
    make_rebuild_fn,
)
from repro.fl.runtime.executor import (
    SerialExecutor,
    _weighted,
    pad_cohort,
)
from repro.fl.runtime.messages import ClientUpdate, wire_dtype
from repro.fl.runtime.population import CohortPlan
from repro.fl.server import server_update
from repro.obs import NULL
from repro.utils.pytree import tree_size


@dataclasses.dataclass(frozen=True)
class WireConfig:
    """Uplink wire behaviour. ``simulate=True`` packs/unpacks real frames
    (collect mode — test/accounting scale); False streams in-process and
    only *accounts* bytes from zero-filled template frames."""
    dtype: str = "fp32"
    simulate: bool = False
    include_head: bool = True


@dataclasses.dataclass
class RoundReport:
    round_idx: int
    cohort_size: int                 # scheduled (over-selected) cohort
    n_requested: int
    n_survivors: int
    dropped_client_ids: List[int]
    deadline: float
    bytes_down: int                  # Σ TaskAssignment frames
    bytes_up: int                    # Σ surviving ClientUpdate frames
    wire: str
    executor: str
    n_devices: int
    agg_bytes_streaming: int         # accumulator bytes (O(peft) / device)
    agg_bytes_stacked: int           # (C, peft) materialization equivalent


def _ideal_plan(round_idx: int, M: int, n_units: int) -> CohortPlan:
    """Full participation, no over-selection, everyone on time."""
    mask = np.asarray(assignment_matrix(n_units, M, round_idx % M),
                      np.float32)
    return CohortPlan(
        round_idx=round_idx, client_ids=np.arange(M, dtype=np.int64),
        seed_ids=np.arange(M, dtype=np.int32), mask_matrix=mask,
        latencies=np.zeros(M), deadline=float("inf"),
        keep=np.ones(M, bool), assignments=[], n_requested=M)


class FederationEngine:
    def __init__(self, cfg, spry_cfg, task: str = "cls",
                 comm_mode: Optional[str] = None, executor=None,
                 wire: Optional[WireConfig] = None, telemetry=None):
        self.cfg = cfg
        self.spry_cfg = spry_cfg
        self.task = task
        # host-side telemetry on already-returned values ONLY: the jitted
        # round bodies below never see this object, so telemetry-on traces
        # the identical program (tests/test_telemetry_neutrality.py)
        tel = telemetry if telemetry is not None else NULL
        self.telemetry = tel
        self._tc_rounds = tel.counter("fl.rounds")
        self._tc_bytes_up = tel.counter("fl.bytes_up")
        self._tc_bytes_down = tel.counter("fl.bytes_down")
        self._tc_stragglers = tel.counter("fl.stragglers")
        self._tg_survivors = tel.gauge("fl.survivors")
        self._tg_mask_units = tel.gauge("fl.surviving_mask_units")
        self._tg_loss = tel.gauge("fl.loss")
        self._tg_jvp = tel.gauge("fl.jvp_abs_mean")
        self._tg_delta = tel.gauge("fl.delta_norm")
        self._th_round_s = tel.histogram("fl.round_seconds")
        self.comm_mode = comm_mode or spry_cfg.comm_mode
        if self.comm_mode not in ("per_epoch", "per_iteration"):
            raise ValueError(self.comm_mode)
        self.executor = executor if executor is not None else SerialExecutor()
        self.wire = wire or WireConfig()
        # whole-cohort serial execution can materialize the client stack and
        # reuse the reference aggregation verbatim (bit-identity); any
        # microbatched/sharded executor streams instead
        self.collect = (isinstance(self.executor, SerialExecutor)
                        and self.executor.microbatch is None)
        if self.comm_mode == "per_epoch":
            self._client_fn = make_client_update_fn(cfg, spry_cfg, task)
        else:
            self._client_fn = make_client_jvp_fn(cfg, spry_cfg, task)
            self._rebuild_fn = make_rebuild_fn()
        self._round_jit = jax.jit(self._round_fn)
        self._clients_jit = jax.jit(self._clients_fn)
        self._aggregate_jit = jax.jit(self._aggregate_fn)

    # ------------------------------------------------------------------
    # jitted bodies
    # ------------------------------------------------------------------

    def _kernels(self):
        if self.comm_mode == "per_epoch":
            def kernel(base, peft, rk, sid, row, cb):
                delta, loss, jvps = self._client_fn(base, peft, rk, sid, row,
                                                    cb)
                return delta, (loss, jvps)
            return kernel, None

        def kernel(base, peft, rk, sid, row, cb):
            loss, jvps = self._client_fn(base, peft, rk, sid, row, cb)
            return (), (loss, jvps)

        def rebuild_kernel(base, peft, rk, sid, row, jvps):
            return self._rebuild_fn(peft, rk, sid, row, jvps), ()
        return kernel, rebuild_kernel

    def _round_key(self, state):
        return jax.random.fold_in(
            jax.random.PRNGKey(self.spry_cfg.seed), state.round_idx)

    def _finish(self, state, peft, index, payload_sum_or_stack, counts,
                head_count, losses, jvps, keep, stacked: bool):
        """Shared tail: unit-averaged payload -> server update + metrics."""
        if stacked:
            agg = aggregate_payloads(peft, index, payload_sum_or_stack,
                                     counts, head_count)
        else:
            count_tree = make_count_tree(peft, index, counts, head_count)
            agg = jax.tree.map(lambda s, c: s / c, payload_sum_or_stack,
                               count_tree)
        if self.comm_mode == "per_iteration":
            delta = jax.tree.map(lambda g: -self.spry_cfg.local_lr * g, agg)
        else:
            delta = agg
        new_peft, server = server_update(
            self.spry_cfg.server_opt, peft, delta, state.server,
            lr=self.spry_cfg.server_lr)
        jvps_flat = jvps.reshape(jvps.shape[0], -1)   # (C, local_iters*K)
        n_kept = keep.sum()
        metrics = {
            "loss": (losses * keep).sum() / n_kept,
            "jvp_abs_mean": (jnp.abs(jvps_flat) * keep[:, None]).sum()
            / (n_kept * jvps_flat.shape[-1]),
            # active estimator route (matches make_round_step's metrics so
            # the ideal-round bit-identity contract extends to telemetry)
            "fused_route": jnp.float32(self.spry_cfg.fused_contraction),
        }
        if self.comm_mode == "per_epoch":
            metrics["delta_norm"] = jnp.sqrt(
                sum(jnp.sum(d * d) for d in jax.tree.leaves(delta)))
        new_state = SpryState(state.base, new_peft, server,
                              state.round_idx + 1)
        return new_state, metrics

    def _round_fn(self, state, seed_ids, mask_matrix, keep, batch):
        """Whole round in one jit (wire simulation off)."""
        base, peft = state.base, state.peft
        index = enumerate_units(peft)
        rk = self._round_key(state)
        kernel, rebuild_kernel = self._kernels()
        counts = jnp.maximum((mask_matrix * keep[:, None]).sum(0), 1.0)
        head_count = keep.sum()

        payload, (losses, jvps) = self.executor.run(
            kernel, base, peft, rk, seed_ids, mask_matrix, batch, keep,
            collect=self.collect)
        if self.comm_mode == "per_iteration":
            payload, _ = self.executor.run(
                rebuild_kernel, base, peft, rk, seed_ids, mask_matrix, jvps,
                keep, collect=self.collect)
        if self.collect:
            payload = _weighted(payload, keep)
        return self._finish(state, peft, index, payload, counts, head_count,
                            losses, jvps, keep, stacked=self.collect)

    def _clients_fn(self, state, seed_ids, mask_matrix, keep, batch):
        """Wire-sim phase 1: per-client payload stack + telemetry."""
        base, peft = state.base, state.peft
        rk = self._round_key(state)
        kernel, _ = self._kernels()
        payload, (losses, jvps) = self.executor.run(
            kernel, base, peft, rk, seed_ids, mask_matrix, batch, keep,
            collect=True)
        return payload, losses, jvps

    def _aggregate_fn(self, state, stacked, seed_ids, mask_matrix, keep,
                      losses, jvps):
        """Wire-sim phase 2: aggregate the unpacked payload stack."""
        peft = state.peft
        index = enumerate_units(peft)
        counts = jnp.maximum((mask_matrix * keep[:, None]).sum(0), 1.0)
        if self.comm_mode == "per_iteration":
            rk = self._round_key(state)
            _, rebuild_kernel = self._kernels()
            stacked, _ = self.executor.run(
                rebuild_kernel, state.base, peft, rk, seed_ids, mask_matrix,
                stacked, keep, collect=True)
        return self._finish(state, peft, index, _weighted(stacked, keep),
                            counts, keep.sum(), losses, jvps, keep,
                            stacked=True)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def run_ideal(self, state, batch) -> Tuple[Any, Dict[str, Any]]:
        """Full-participation round on a stacked (M, B, ...) batch —
        semantically the in-process ``make_round_step`` executed through the
        runtime (bit-identical with the default whole-cohort executor)."""
        M = jax.tree.leaves(batch)[0].shape[0]
        index = enumerate_units(state.peft)
        plan = _ideal_plan(int(state.round_idx), M, index.n_units)
        state, metrics, _ = self.run_round(state, plan, batch)
        return state, metrics

    def run_round(self, state, plan: CohortPlan, batch):
        """Execute one scheduled round. ``batch`` leaves lead with the plan's
        cohort axis. Returns (state, metrics, RoundReport)."""
        tel = self.telemetry
        t_round = time.perf_counter()
        index = enumerate_units(state.peft)
        keep = np.asarray(plan.keep, np.float32)
        seed_ids, mask_rows, batch_p, keep_p, C = pad_cohort(
            self.executor, np.asarray(plan.seed_ids, np.int32),
            plan.mask_matrix, batch, keep)

        with tel.span("fl.round", round=int(plan.round_idx),
                      cohort=plan.cohort_size, comm_mode=self.comm_mode):
            if self.wire.simulate:
                new_state, metrics, bytes_up = self._run_simulated(
                    state, seed_ids, mask_rows, keep_p, batch_p, plan, C)
            else:
                with tel.span("fl.execute"):
                    new_state, metrics = self._round_jit(
                        state, seed_ids, mask_rows, keep_p, batch_p)
                bytes_up = self._estimate_uplink(state.peft, index, plan)

        peft_bytes = tree_size(state.peft) * 4
        m = self.executor.microbatch or (len(seed_ids)
                                         // self.executor.n_devices)
        report = RoundReport(
            round_idx=int(plan.round_idx),
            cohort_size=plan.cohort_size,
            n_requested=plan.n_requested,
            n_survivors=plan.n_survivors,
            dropped_client_ids=[int(c) for c, k in
                                zip(plan.client_ids, plan.keep) if not k],
            deadline=float(plan.deadline),
            bytes_down=plan.downlink_bytes(),
            bytes_up=int(bytes_up),
            wire=self.wire.dtype,
            executor=type(self.executor).__name__,
            n_devices=self.executor.n_devices,
            agg_bytes_streaming=(m + 1) * peft_bytes,
            agg_bytes_stacked=len(seed_ids) * peft_bytes,
        )
        if tel.enabled:
            self._record_round(plan, metrics, report,
                               time.perf_counter() - t_round)
        return new_state, metrics, report

    def _record_round(self, plan: CohortPlan, metrics, report: RoundReport,
                      wall_s: float) -> None:
        """Host-side recording on the round's RETURNED values: the float()
        conversions below force a device sync on already-computed arrays,
        never a recompute — the metrics tree handed back to the caller is
        untouched (bitwise-identity asserted in tests)."""
        host = {k: float(v) for k, v in metrics.items()}
        stragglers = report.cohort_size - report.n_survivors
        mask_units = float(
            np.asarray(plan.mask_matrix)[np.asarray(plan.keep, bool)].sum())
        self._tc_rounds.inc()
        self._tc_bytes_up.add(report.bytes_up)
        self._tc_bytes_down.add(report.bytes_down)
        self._tc_stragglers.add(stragglers)
        self._tg_survivors.set(report.n_survivors)
        self._tg_mask_units.set(mask_units)
        self._tg_loss.set(host["loss"])
        if "jvp_abs_mean" in host:
            self._tg_jvp.set(host["jvp_abs_mean"])
        if "delta_norm" in host:
            self._tg_delta.set(host["delta_norm"])
        self._th_round_s.observe(wall_s)
        self.telemetry.event(
            "round",
            round=report.round_idx,
            comm_mode=self.comm_mode,
            route=("fused" if host.get("fused_route") else "standard"),
            loss=host["loss"],
            jvp_abs_mean=host.get("jvp_abs_mean"),
            delta_norm=host.get("delta_norm"),
            bytes_up=report.bytes_up,
            bytes_down=report.bytes_down,
            cohort=report.cohort_size,
            survivors=report.n_survivors,
            stragglers=stragglers,
            dropped=report.dropped_client_ids,
            surviving_mask_units=mask_units,
            executor=report.executor,
            wire=report.wire,
            n_devices=report.n_devices,
            wall_s=round(wall_s, 6),
        )

    # -- wire simulation ------------------------------------------------

    def _run_simulated(self, state, seed_ids, mask_rows, keep, batch, plan,
                       C):
        tel = self.telemetry
        with tel.span("fl.clients"):
            payload, losses, jvps = self._clients_jit(
                state, seed_ids, mask_rows, keep, batch)
        with tel.span("fl.wire", n_survivors=plan.n_survivors):
            updates = self.pack_updates(state.peft, payload, jvps, losses,
                                        plan)
            bytes_up = sum(u.byte_size() for u in updates)
            # the server only sees what arrived: unpack frames back into the
            # cohort stack (zeros for dropped clients). Frames carry the
            # fold-in seed_id; cohort POSITION comes from keep order
            # (pack_updates emits survivors in plan order).
            survivor_pos = np.flatnonzero(plan.keep)
            index = enumerate_units(state.peft)
            if self.comm_mode == "per_epoch":
                template = jax.tree.map(np.zeros_like, jax.tree.map(
                    lambda x: np.asarray(x[0]), payload))
                rows = {int(pos): u.to_delta(template, index)
                        for pos, u in zip(survivor_pos, updates)}
                stacked = jax.tree.map(
                    lambda *xs: jnp.asarray(np.stack(xs)),
                    *[rows.get(i, template) for i in range(len(seed_ids))])
            else:
                K = jvps.shape[-1]
                arr = np.zeros((len(seed_ids), K), np.float32)
                for pos, u in zip(survivor_pos, updates):
                    arr[int(pos)] = np.asarray(u.jvps, np.float32)
                stacked = jnp.asarray(arr)
        with tel.span("fl.aggregate"):
            new_state, metrics = self._aggregate_jit(
                state, stacked, seed_ids, mask_rows, keep, losses, jvps)
        return new_state, metrics, bytes_up

    def pack_updates(self, peft, payload, jvps, losses,
                     plan: CohortPlan) -> List[ClientUpdate]:
        """Serialize every SURVIVING client's uplink frame."""
        index = enumerate_units(peft)
        out = []
        for i, (cid, k) in enumerate(zip(plan.client_ids, plan.keep)):
            if not k:
                continue
            sid = int(plan.seed_ids[i])   # the fold-in seed ref ON THE WIRE
            if self.comm_mode == "per_epoch":
                delta_i = jax.tree.map(lambda x: np.asarray(x[i]), payload)
                unit_ids = np.flatnonzero(plan.mask_matrix[i] > 0)
                out.append(ClientUpdate.from_delta(
                    delta_i, index, unit_ids, round_idx=plan.round_idx,
                    client_id=int(cid), seed_id=sid, wire=self.wire.dtype,
                    loss=float(losses[i]),
                    include_head=self.wire.include_head))
            else:
                out.append(ClientUpdate.from_jvps(
                    np.asarray(jvps[i]), round_idx=plan.round_idx,
                    client_id=int(cid), seed_id=sid, wire=self.wire.dtype,
                    loss=float(losses[i])))
        return out

    def _estimate_uplink(self, peft, index, plan: CohortPlan) -> int:
        """Measured frame size of zero-filled template updates. Frame size
        depends only on the unit-id set and the header-int digit widths, so
        sizes are memoized — no per-round O(|peft|) serialization."""
        if not hasattr(self, "_uplink_cache"):
            self._uplink_cache = {}
            self._zeros_peft = jax.tree.map(
                lambda x: np.zeros(x.shape, np.float32), peft)
        total = 0
        K = self.spry_cfg.k_perturbations
        for i, (cid, k) in enumerate(zip(plan.client_ids, plan.keep)):
            if not k:
                continue
            sid = int(plan.seed_ids[i])
            if self.comm_mode == "per_epoch":
                unit_ids = np.flatnonzero(plan.mask_matrix[i] > 0)
                ckey = (tuple(unit_ids.tolist()),)
            else:
                unit_ids = None
                ckey = (K,)
            ckey += (len(str(int(plan.round_idx))), len(str(int(cid))),
                     len(str(sid)))
            if ckey not in self._uplink_cache:
                if self.comm_mode == "per_epoch":
                    u = ClientUpdate.from_delta(
                        self._zeros_peft, index, unit_ids,
                        round_idx=plan.round_idx, client_id=int(cid),
                        seed_id=sid, wire=self.wire.dtype,
                        include_head=self.wire.include_head)
                else:
                    u = ClientUpdate.from_jvps(
                        np.zeros((K,), np.float32),
                        round_idx=plan.round_idx, client_id=int(cid),
                        seed_id=sid, wire=self.wire.dtype)
                self._uplink_cache[ckey] = u.byte_size()
            total += self._uplink_cache[ckey]
        return total
