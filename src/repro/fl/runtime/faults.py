"""Seeded, deterministic fault injection for the federation runtime.

The chaos harness perturbs a round at configurable rates with every failure
mode the wire-integrity layer and the engine's quarantine path are built to
survive:

  * client crash mid-epoch   — the update is computed but never sent (the
                               partial-work case: device died / app killed);
                               per-device-tier ``crash_scale`` multiplies
                               the base rate (iot boards die more often than
                               flagship phones).
  * frame corruption         — random bit flips in the serialized frame
                               (caught by the CRC32 seal).
  * frame truncation         — the uplink cut the frame short.
  * frame duplication        — at-least-once delivery: the same frame lands
                               twice; the engine must dedupe by seed_id.
  * transient uplink loss    — the send fails; the client retries with
                               exponential backoff up to ``max_retries``
                               attempts, then gives up (update lost).
  * NaN / blow-up payloads   — a numerically-poisoned update that passes
                               the CRC (the bytes are intact — the *values*
                               are garbage); the engine's defensive
                               validation must reject it before
                               aggregation.

Every draw is keyed by ``SeedSequence([seed, tag, client, round, attempt])``
— stateless per call, like ``population._rng`` — so a resumed run replays
the exact same fault schedule and the kill-and-resume bitwise test holds
under chaos. Injection happens at the byte level on already-serialized
frames (corruption) or at the value level before serialization (poison), so
the clean path never touches this module.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


def _rng(*entropy) -> np.random.Generator:
    """Deterministic per-key generator (order-sensitive integer entropy)."""
    return np.random.default_rng(
        np.random.SeedSequence([int(e) & 0x7FFFFFFF for e in entropy]))


# entropy tags so independent fault draws never collide on the same stream
_T_CRASH, _T_LOSS, _T_CORRUPT, _T_MODE, _T_POISON, _T_DUP = (
    0xC4A5, 0x1055, 0xC0FF, 0x30DE, 0xBAD0, 0xD0B1)


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Rates and knobs for one chaos schedule. All rates are per-client
    per-round probabilities in [0, 1]; 0 everywhere = clean network."""
    crash_rate: float = 0.0       # update computed but never transmitted
    corrupt_rate: float = 0.0     # frame bit-flip / truncation / duplication
    loss_rate: float = 0.0        # per-attempt transient uplink loss
    nan_rate: float = 0.0         # payload poisoned with NaN/Inf
    blowup_rate: float = 0.0      # payload scaled into norm-outlier range
    max_retries: int = 3          # uplink attempts per frame (>= 1)
    backoff_base: float = 0.5     # seconds; attempt i waits base * 2**i
    blowup_scale: float = 1e6     # multiplier for blow-up poisoning
    seed: int = 0                 # chaos seed (independent of algo seed)

    def __post_init__(self):
        for name in ("crash_rate", "corrupt_rate", "loss_rate", "nan_rate",
                     "blowup_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} not in [0, 1]")
        if self.max_retries < 1:
            raise ValueError("max_retries must be >= 1")

    @property
    def any_faults(self) -> bool:
        return any(getattr(self, n) > 0.0 for n in
                   ("crash_rate", "corrupt_rate", "loss_rate", "nan_rate",
                    "blowup_rate"))

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "FaultConfig":
        """Parse a CLI spec: ``k=v,k=v`` over the field names, or the
        presets ``off`` / ``mild`` / ``aggressive``."""
        presets = {
            "off": {},
            "mild": {"crash_rate": 0.05, "corrupt_rate": 0.05,
                     "loss_rate": 0.05, "nan_rate": 0.02},
            "aggressive": {"crash_rate": 0.2, "corrupt_rate": 0.25,
                           "loss_rate": 0.25, "nan_rate": 0.1,
                           "blowup_rate": 0.1},
        }
        spec = (spec or "off").strip()
        if spec in presets:
            return cls(seed=seed, **presets[spec])
        kwargs = {}
        valid = {f.name for f in dataclasses.fields(cls)}
        for part in spec.split(","):
            if not part.strip():
                continue
            try:
                k, v = part.split("=", 1)
            except ValueError:
                raise ValueError(f"bad fault spec component {part!r} "
                                 f"(want k=v)")
            k = k.strip()
            if k not in valid:
                raise ValueError(f"unknown fault knob {k!r}; "
                                 f"valid: {sorted(valid)}")
            kwargs[k] = int(v) if k in ("max_retries", "seed") else float(v)
        kwargs.setdefault("seed", seed)
        return cls(**kwargs)


@dataclasses.dataclass
class FaultCounters:
    """Host-side tally of what the injector actually did (one round)."""
    crashed: int = 0
    corrupted: int = 0
    truncated: int = 0
    duplicated: int = 0
    lost: int = 0            # frames that exhausted every retry
    retries: int = 0         # extra attempts beyond the first
    poisoned_nan: int = 0
    poisoned_blowup: int = 0
    backoff_s: float = 0.0   # total simulated backoff latency

    def merge(self, other: "FaultCounters") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


class FaultInjector:
    """Applies a ``FaultConfig`` deterministically per (client, round).

    The injector never mutates inputs in place; corrupted frames are new
    byte strings, poisoned payloads are new arrays. Methods are pure in
    (config.seed, client_id, round_idx[, attempt]) so replay — including a
    crash-resume replay — reproduces the identical fault schedule.
    """

    def __init__(self, config: FaultConfig):
        self.config = config
        self.counters = FaultCounters()

    # -- client-side faults -------------------------------------------------

    def crashes(self, client_id: int, round_idx: int,
                scale: float = 1.0) -> bool:
        """Did this client die mid-epoch? ``scale`` is the device tier's
        crash multiplier; the effective rate is clipped to [0, 1]."""
        rate = min(1.0, self.config.crash_rate * float(scale))
        if rate <= 0.0:
            return False
        hit = _rng(self.config.seed, _T_CRASH, client_id,
                   round_idx).random() < rate
        if hit:
            self.counters.crashed += 1
        return hit

    def poison_mode(self, client_id: int,
                    round_idx: int) -> Optional[str]:
        """'nan' | 'blowup' | None — drawn once per (client, round)."""
        cfg = self.config
        if cfg.nan_rate <= 0.0 and cfg.blowup_rate <= 0.0:
            return None
        u = _rng(cfg.seed, _T_POISON, client_id, round_idx).random()
        if u < cfg.nan_rate:
            self.counters.poisoned_nan += 1
            return "nan"
        if u < cfg.nan_rate + cfg.blowup_rate:
            self.counters.poisoned_blowup += 1
            return "blowup"
        return None

    def poison_array(self, arr: np.ndarray, mode: str) -> np.ndarray:
        """Apply a poison mode to one payload array (new array)."""
        out = np.array(arr, copy=True)
        if out.size == 0 or not np.issubdtype(out.dtype, np.floating):
            return out
        if mode == "nan":
            flat = out.reshape(-1)
            flat[: max(1, flat.size // 8)] = np.nan
        elif mode == "blowup":
            out = out * out.dtype.type(self.config.blowup_scale)
            if not np.any(out):       # all-zero payload: force an outlier
                out.reshape(-1)[0] = out.dtype.type(
                    self.config.blowup_scale)
        else:
            raise ValueError(f"unknown poison mode {mode!r}")
        return out

    # -- wire-level faults --------------------------------------------------

    def _mangle(self, frame: bytes, rng: np.random.Generator) -> bytes:
        """Bit-flip or truncate one frame (never both; never a no-op)."""
        buf = bytearray(frame)
        if rng.random() < 0.5 and len(buf) > 1:
            cut = int(rng.integers(1, len(buf)))
            self.counters.truncated += 1
            return bytes(buf[:cut])
        n_flips = int(rng.integers(1, 9))
        for _ in range(n_flips):
            pos = int(rng.integers(0, len(buf)))
            bit = int(rng.integers(0, 8))
            buf[pos] ^= 1 << bit
        self.counters.corrupted += 1
        return bytes(buf)

    def transmit(self, frame: bytes, client_id: int,
                 round_idx: int) -> Tuple[List[bytes], int, float]:
        """Push one serialized frame through the chaotic uplink.

        Returns ``(delivered_frames, attempts, backoff_seconds)``:
        ``delivered_frames`` holds what the server actually receives — empty
        if every retry was lost, 2+ entries if the frame was duplicated,
        possibly mangled bytes if it was corrupted in flight. ``attempts``
        counts transmissions (for bytes-up accounting: every attempt burns
        uplink bytes, delivered or not). Deterministic in
        (seed, client, round, attempt).
        """
        cfg = self.config
        attempts = 0
        backoff = 0.0
        delivered: List[bytes] = []
        for attempt in range(cfg.max_retries):
            attempts += 1
            if attempt > 0:
                self.counters.retries += 1
                backoff += cfg.backoff_base * (2.0 ** (attempt - 1))
            lost = (cfg.loss_rate > 0.0 and
                    _rng(cfg.seed, _T_LOSS, client_id, round_idx,
                         attempt).random() < cfg.loss_rate)
            if lost:
                continue
            rng = _rng(cfg.seed, _T_CORRUPT, client_id, round_idx, attempt)
            out = frame
            if cfg.corrupt_rate > 0.0 and rng.random() < cfg.corrupt_rate:
                out = self._mangle(out, rng)
            delivered.append(out)
            if (cfg.corrupt_rate > 0.0 and
                    _rng(cfg.seed, _T_DUP, client_id, round_idx,
                         attempt).random() < cfg.corrupt_rate / 2.0):
                self.counters.duplicated += 1
                delivered.append(out)
            break
        if not delivered:
            self.counters.lost += 1
        self.counters.backoff_s += backoff
        return delivered, attempts, backoff

    # -- bookkeeping --------------------------------------------------------

    def take_counters(self) -> FaultCounters:
        """Return and reset the tally (one engine round)."""
        out = self.counters
        self.counters = FaultCounters()
        return out
