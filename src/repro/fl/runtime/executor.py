"""Cohort executors: run a per-client function over a round's cohort.

A *client kernel* is any ``fn(base, peft, round_key, seed_id, mask_row,
batch) -> (payload_tree, aux)`` where ``payload_tree`` is a peft-shaped tree
(the per-epoch delta, or the server-side rebuilt gradient in per-iteration
mode; may be an empty tuple for the jvp-only client pass) and ``aux`` is a
small per-client pytree (loss, jvp scalars) that is always stacked.

Two execution strategies, both traceable inside the engine's single jit:

  SerialExecutor    single device. microbatch=None runs ONE vmap over the
                    whole cohort and returns stacked payloads — this is
                    op-identical to the in-process round step (vmap widths
                    change CPU numerics at the ~1e-7 level, so bit-identity
                    REQUIRES the same width; asserted in tests). A finite
                    microbatch m instead lax.scans over C/m chunks and
                    stream-accumulates Σ keep_i·payload_i, so peak
                    aggregation memory is O(m·|peft|) + O(|peft|)
                    independent of cohort size.
  ShardedExecutor   shard_map over the host's devices: each device scans its
                    C/D clients with the same chunked vmap and psums the
                    partial payload sums — server-side memory O(|peft|) per
                    device + one O(|peft|) replicated result, enabling
                    cohorts ≫ the in-process M. Per-client payloads (collect
                    mode) are bitwise-equal to the SerialExecutor at the
                    same microbatch (same per-chunk program, different
                    scheduling); only the cross-device reduction order
                    differs, so aggregates match to float tolerance.

``collect=True`` additionally materializes the (C, |peft|) payload stack —
used for wire simulation (pack real ClientUpdate messages) and equivalence
tests; the streaming mode is the scalable path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _weighted(tree, w):
    """Scale each client's payload leaf by its keep weight (leading C axis)."""
    return jax.tree.map(
        lambda x: x * w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype),
        tree)


def _chunk_run(client_fn, base, peft, round_key, seed_ids, mask_rows,
               batches, keep, microbatch, collect):
    """Shared chunked-vmap driver (single-device view of the cohort).

    Returns (payload, aux): payload stacked (C, ...) when collect else the
    keep-weighted streaming sum; aux always stacked (C, ...).
    """
    C = seed_ids.shape[0]
    vfn = jax.vmap(
        lambda sid, row, cb: client_fn(base, peft, round_key, sid, row, cb))

    if microbatch is None or microbatch >= C:
        payload, aux = vfn(seed_ids, mask_rows, batches)
        if not collect:
            payload = jax.tree.map(lambda x: x.sum(0),
                                   _weighted(payload, keep))
        return payload, aux

    m = int(microbatch)
    if C % m != 0:
        raise ValueError(f"cohort size {C} not divisible by microbatch {m} "
                         "(pad the cohort with keep=0 rows)")
    n = C // m
    xs = jax.tree.map(lambda x: x.reshape((n, m) + x.shape[1:]),
                      (seed_ids, mask_rows, batches, keep))

    def body(carry, chunk):
        sid, row, cb, kp = chunk
        payload, aux = vfn(sid, row, cb)
        if collect:
            return carry, (payload, aux)
        carry = jax.tree.map(
            jnp.add, carry, jax.tree.map(lambda x: x.sum(0),
                                         _weighted(payload, kp)))
        return carry, aux

    if collect:
        _, (payload, aux) = jax.lax.scan(body, (), xs)
        return (jax.tree.map(lambda x: x.reshape((C,) + x.shape[2:]), payload),
                jax.tree.map(lambda x: x.reshape((C,) + x.shape[2:]), aux))

    zeros = jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32),
        jax.eval_shape(lambda: client_fn(base, peft, round_key, seed_ids[0],
                                         mask_rows[0],
                                         jax.tree.map(lambda b: b[0],
                                                      batches))[0]))
    payload_sum, aux = jax.lax.scan(body, zeros, xs)
    return payload_sum, jax.tree.map(
        lambda x: x.reshape((C,) + x.shape[2:]), aux)


class SerialExecutor:
    """Single-device cohort execution (reference / memory-bounded)."""

    def __init__(self, microbatch: Optional[int] = None):
        self.microbatch = microbatch

    @property
    def n_devices(self) -> int:
        return 1

    def pad_to(self, C: int) -> int:
        m = self.microbatch
        if m is None:
            return C
        return C + (-C) % m

    def run(self, client_fn, base, peft, round_key, seed_ids, mask_rows,
            batches, keep, *, collect: bool = False):
        return _chunk_run(client_fn, base, peft, round_key, seed_ids,
                          mask_rows, batches, keep, self.microbatch, collect)


class ShardedExecutor:
    """shard_map cohort execution over the host's devices.

    The cohort axis is split across ``devices``; each device runs the same
    chunked vmap as SerialExecutor on its shard. Streaming payload sums are
    psum-reduced (replicated O(|peft|) result); collect mode returns the
    cohort-stacked payloads (device-sharded in memory, gathered on exit).
    """

    def __init__(self, devices=None, microbatch: Optional[int] = None,
                 axis: str = "clients"):
        devices = jax.devices() if devices is None else list(devices)
        self.mesh = Mesh(np.array(devices), (axis,))
        self.axis = axis
        self.microbatch = microbatch

    @property
    def n_devices(self) -> int:
        return self.mesh.shape[self.axis]

    def pad_to(self, C: int) -> int:
        quantum = self.n_devices * (self.microbatch or 1)
        padded = C + (-C) % quantum
        if self.microbatch is None:
            padded = C + (-C) % self.n_devices
        return padded

    def run(self, client_fn, base, peft, round_key, seed_ids, mask_rows,
            batches, keep, *, collect: bool = False):
        C = seed_ids.shape[0]
        D = self.n_devices
        if C % D != 0:
            raise ValueError(f"cohort size {C} not divisible by {D} devices "
                             "(pad the cohort with keep=0 rows)")

        def local(base_l, peft_l, round_key_l, sid, row, cb, kp):
            return _chunk_run(client_fn, base_l, peft_l, round_key_l, sid,
                              row, cb, kp, self.microbatch, collect)

        payload_spec = P(self.axis) if collect else P()
        out = shard_map(
            (lambda b, p, rk, sid, row, cb, kp:
             ((lambda pl, aux:
               (pl if collect else jax.lax.psum(pl, self.axis), aux))
              (*local(b, p, rk, sid, row, cb, kp)))),
            mesh=self.mesh,
            in_specs=(P(), P(), P(), P(self.axis), P(self.axis),
                      P(self.axis), P(self.axis)),
            out_specs=(payload_spec, P(self.axis)),
            check_rep=False,
        )(base, peft, round_key, seed_ids, mask_rows, batches, keep)
        return out


def pad_cohort(executor, seed_ids, mask_rows, batches, keep):
    """Pad cohort arrays to the executor's quantum with keep=0 rows (the pad
    rows still compute on garbage inputs but carry zero aggregation weight
    and are sliced off per-client outputs)."""
    C = len(seed_ids)
    Cp = executor.pad_to(C)
    if Cp == C:
        return seed_ids, mask_rows, batches, keep, C
    pad = Cp - C

    def padrow(x):
        x = np.asarray(x)
        return np.concatenate([x, np.repeat(x[-1:], pad, axis=0)], axis=0)

    return (padrow(seed_ids), padrow(mask_rows),
            jax.tree.map(padrow, batches),
            np.concatenate([np.asarray(keep), np.zeros(pad, keep.dtype)]), C)
