"""Client population + cohort scheduling for the federation runtime.

``ClientPopulation`` models millions of *logical* clients over a finite
labelled dataset without materializing anything per client up front:

  * data shard    — lazily materialized on first touch: client c draws its
                    class mixture from Dir(alpha) with an rng seeded by
                    (seed, c), then samples its shard from the global
                    per-class pools (the Hsu et al. protocol the paper cites,
                    evaluated pointwise instead of as a global partition).
                    An LRU cache bounds resident shards.
  * device tier   — commodity-edge heterogeneity (Chen et al. 2025 style):
                    each client hashes into a tier with a compute-speed
                    multiplier; per-round latency adds lognormal jitter.
  * availability  — a deterministic diurnal trace: each client has a phase
                    offset and sinusoidal availability rate over rounds.

``CohortScheduler`` turns a population into per-round ``CohortPlan``s:
over-select ``ceil(cohort_size * over_select)`` available clients, build the
cyclic unit assignment over the selected cohort, and mark stragglers
(simulated latency beyond the deadline) and mid-round dropouts. Dropped
clients still *compute* in the simulator but their updates never arrive —
the engine re-averages each unit with corrected counts (which the fixed-M
``client_counts`` of the in-process step cannot express).

Everything is deterministic in (seed, client_id, round_idx) — the same plan
is produced on replay, which is what makes dropout-corrected aggregation
testable against an explicit re-run with the dropped client excluded.
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.assignment import assignment_matrix
from repro.fl.runtime.messages import TaskAssignment


def _rng(*entropy) -> np.random.Generator:
    """Deterministic per-key generator (order-sensitive integer entropy)."""
    return np.random.default_rng(
        np.random.SeedSequence([int(e) & 0x7FFFFFFF for e in entropy]))


@dataclasses.dataclass(frozen=True)
class DeviceTier:
    name: str
    flops_scale: float       # relative client compute speed
    base_latency: float      # mean round-trip seconds at scale 1.0
    crash_scale: float = 1.0  # multiplier on the FaultInjector's crash rate


DEFAULT_TIERS: Tuple[DeviceTier, ...] = (
    DeviceTier("hi_end_phone", 1.0, 4.0, crash_scale=0.5),
    DeviceTier("mid_phone", 0.5, 8.0, crash_scale=1.0),
    DeviceTier("iot_board", 0.2, 20.0, crash_scale=2.5),
)
DEFAULT_TIER_PROBS: Tuple[float, ...] = (0.3, 0.5, 0.2)


class ClientPopulation:
    """Logical clients over (x, y); shards materialize lazily."""

    def __init__(self, x: np.ndarray, y: np.ndarray, n_clients: int,
                 alpha: float = 0.1, seed: int = 0, shard_size: int = 64,
                 cache_size: int = 4096,
                 tiers: Sequence[DeviceTier] = DEFAULT_TIERS,
                 tier_probs: Sequence[float] = DEFAULT_TIER_PROBS,
                 avail_base: float = 0.7, avail_swing: float = 0.25,
                 avail_period: int = 48):
        self.x, self.y = x, np.asarray(y)
        self.n_clients = int(n_clients)
        self.alpha = float(alpha)
        self.seed = int(seed)
        self.shard_size = int(shard_size)
        self.tiers = tuple(tiers)
        self.tier_probs = np.asarray(tier_probs, np.float64)
        self.tier_probs = self.tier_probs / self.tier_probs.sum()
        self.avail_base = avail_base
        self.avail_swing = avail_swing
        self.avail_period = avail_period
        n_classes = int(self.y.max()) + 1
        self._class_pools = [np.flatnonzero(self.y == c)
                             for c in range(n_classes)]
        self._shards: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._cache_size = int(cache_size)

    # -- data ---------------------------------------------------------------

    def shard(self, client_id: int) -> np.ndarray:
        """Indices of this client's (lazily materialized) Dirichlet shard."""
        cid = int(client_id)
        if cid in self._shards:
            self._shards.move_to_end(cid)
            return self._shards[cid]
        rng = _rng(self.seed, 0xD1A, cid)
        p = rng.dirichlet(np.full(len(self._class_pools), self.alpha))
        counts = rng.multinomial(self.shard_size, p)
        parts = []
        for pool, n in zip(self._class_pools, counts):
            if n == 0 or len(pool) == 0:
                continue
            parts.append(rng.choice(pool, size=n, replace=len(pool) < n))
        idx = (np.sort(np.concatenate(parts)) if parts
               else rng.integers(0, len(self.y), size=self.shard_size))
        self._shards[cid] = idx
        if len(self._shards) > self._cache_size:
            self._shards.popitem(last=False)
        return idx

    def client_batch(self, client_id: int, round_idx: int, batch_size: int):
        """One deterministic local minibatch for (client, round)."""
        idx = self.shard(client_id)
        rng = _rng(self.seed, 0xBA7, client_id, round_idx)
        take = rng.choice(idx, size=batch_size, replace=len(idx) < batch_size)
        return self.x[take], self.y[take]

    # -- device / availability simulation ------------------------------------

    def device_tier(self, client_id: int) -> DeviceTier:
        u = _rng(self.seed, 0x7E1, client_id).random()
        return self.tiers[int(np.searchsorted(np.cumsum(self.tier_probs), u))]

    def latency(self, client_id: int, round_idx: int) -> float:
        """Simulated seconds until this client's update arrives."""
        tier = self.device_tier(client_id)
        jitter = _rng(self.seed, 0x1A7, client_id, round_idx).lognormal(
            mean=0.0, sigma=0.5)
        return tier.base_latency * jitter

    # two-part latency model for the event-driven async simulator: local
    # compute time (tier flops scale + jitter) and uplink transit time are
    # drawn SEPARATELY per (client, task) so the async engine can account
    # useful-vs-wasted client compute. Seeded per (client, task_idx) —
    # replay after a resume redraws identical values. The sync ``latency``
    # stream above is untouched (different entropy tags).

    def compute_seconds(self, client_id: int, task_idx: int,
                        work_s: float = 60.0) -> float:
        """Seconds of local compute for one dispatch: ``work_s`` is the
        nominal local-epoch wall time on a flops_scale=1.0 device."""
        tier = self.device_tier(client_id)
        jitter = _rng(self.seed, 0xC0F0, client_id, task_idx).lognormal(
            mean=0.0, sigma=0.35)
        return work_s / tier.flops_scale * jitter

    def uplink_seconds(self, client_id: int, task_idx: int) -> float:
        """Seconds in flight for one dispatch's uplink frame."""
        tier = self.device_tier(client_id)
        jitter = _rng(self.seed, 0x0971, client_id, task_idx).lognormal(
            mean=0.0, sigma=0.5)
        return tier.base_latency * jitter

    def availability_rate(self, client_id: int, round_idx: int) -> float:
        phase = _rng(self.seed, 0xFA5E, client_id).random()
        wave = math.sin(2 * math.pi * (round_idx / self.avail_period + phase))
        return float(np.clip(self.avail_base + self.avail_swing * wave,
                             0.05, 1.0))

    def available(self, client_id: int, round_idx: int) -> bool:
        u = _rng(self.seed, 0xA7A, client_id, round_idx).random()
        return u < self.availability_rate(client_id, round_idx)


# ---------------------------------------------------------------------------
# Cohort scheduling
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CohortPlan:
    """One round's marching orders: who runs, what units, who survives."""
    round_idx: int
    client_ids: np.ndarray          # (C,) logical population ids (selected)
    seed_ids: np.ndarray            # (C,) fold_in chain positions = arange(C)
    mask_matrix: np.ndarray         # (C, U) float32 unit assignment
    latencies: np.ndarray           # (C,) simulated completion seconds
    deadline: float                 # straggler cutoff
    keep: np.ndarray                # (C,) bool — update arrived in time
    assignments: List[TaskAssignment]
    n_requested: int                # cohort size before over-selection
    crash_scales: Optional[np.ndarray] = None  # (C,) per-tier fault scaling

    @property
    def cohort_size(self) -> int:
        return len(self.client_ids)

    @property
    def n_survivors(self) -> int:
        return int(self.keep.sum())

    def downlink_bytes(self) -> int:
        return sum(a.byte_size() for a in self.assignments)


class CohortScheduler:
    """Over-select, assign units cyclically, simulate stragglers/dropout."""

    def __init__(self, population: ClientPopulation, cohort_size: int,
                 over_select: float = 1.25, deadline: Optional[float] = None,
                 dropout_rate: float = 0.0, seed: int = 0,
                 max_probe: int = 4096):
        if over_select < 1.0:
            raise ValueError("over_select must be >= 1.0")
        self.population = population
        self.cohort_size = int(cohort_size)
        self.over_select = float(over_select)
        self.deadline = deadline
        self.dropout_rate = float(dropout_rate)
        self.seed = int(seed)
        self.max_probe = int(max_probe)

    def _select(self, round_idx: int) -> np.ndarray:
        """Rejection-sample available clients (scales to huge populations —
        never scans the full id space)."""
        pop = self.population
        target = int(math.ceil(self.cohort_size * self.over_select))
        target = min(target, pop.n_clients)
        rng = _rng(self.seed, 0x5E1, round_idx)
        chosen: List[int] = []
        seen = set()
        probes = 0
        while len(chosen) < target and probes < self.max_probe:
            cand = int(rng.integers(0, pop.n_clients))
            probes += 1
            if cand in seen:
                continue
            seen.add(cand)
            if pop.available(cand, round_idx):
                chosen.append(cand)
        if len(chosen) < target:      # degenerate availability: fill anyway
            for cand in range(pop.n_clients):
                if cand not in seen:
                    chosen.append(cand)
                if len(chosen) >= target:
                    break
        return np.asarray(chosen[:target], np.int64)

    def plan_round(self, round_idx: int, n_units: int, spry_seed: int,
                   hparams: Optional[dict] = None,
                   client_ids: Optional[np.ndarray] = None) -> CohortPlan:
        """Build the round plan. ``client_ids`` overrides selection (tests /
        full-participation replays)."""
        pop = self.population
        if client_ids is None:
            client_ids = self._select(round_idx)
        client_ids = np.asarray(client_ids, np.int64)
        C = len(client_ids)
        seed_ids = np.arange(C, dtype=np.int32)
        mask_matrix = np.asarray(
            assignment_matrix(n_units, C, round_idx % C), np.float32)

        latencies = np.asarray(
            [pop.latency(int(c), round_idx) for c in client_ids], np.float64)
        if self.deadline is not None:
            deadline = float(self.deadline)
        else:
            # default cutoff: generous quantile of THIS cohort — drops the
            # heavy straggler tail, keeps the bulk
            deadline = float(np.quantile(latencies, 0.9)) if C > 1 \
                else float("inf")
        keep = latencies <= deadline
        if self.dropout_rate > 0.0:
            drop_rng = _rng(self.seed, 0xD0, round_idx)
            keep = keep & (drop_rng.random(C) >= self.dropout_rate)
        if not keep.any():
            keep = latencies <= latencies.min()   # never lose a whole round

        hparams = dict(hparams or {})
        assignments = []
        for i, cid in enumerate(client_ids):
            unit_ids = np.flatnonzero(mask_matrix[i] > 0).astype(np.int32)
            assignments.append(TaskAssignment(
                round_idx=int(round_idx), client_id=int(cid),
                seed_id=int(seed_ids[i]), cohort_size=C, seed=int(spry_seed),
                n_units=int(n_units), unit_ids=unit_ids, hparams=hparams))
        crash_scales = np.asarray(
            [pop.device_tier(int(c)).crash_scale for c in client_ids],
            np.float64)
        return CohortPlan(
            round_idx=int(round_idx), client_ids=client_ids,
            seed_ids=seed_ids, mask_matrix=mask_matrix, latencies=latencies,
            deadline=deadline, keep=keep, assignments=assignments,
            n_requested=self.cohort_size, crash_scales=crash_scales)

    def round_batch(self, plan: CohortPlan, batch_size: int):
        """Stack each planned client's local minibatch to (C, B, ...)."""
        xs, ys = [], []
        for cid in plan.client_ids:
            bx, by = self.population.client_batch(int(cid), plan.round_idx,
                                                  batch_size)
            xs.append(bx)
            ys.append(by)
        return np.stack(xs), np.stack(ys)
