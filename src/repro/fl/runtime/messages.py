"""Federation wire protocol: the two messages of one SPRY round.

    server -> client   TaskAssignment   round seed ref + unit-mask id +
                                        hyperparams (the weights themselves
                                        travel via the model-distribution
                                        channel, not per-round)
    client -> server   ClientUpdate     per-epoch:     masked delta payload
                                        per-iteration: K jvp scalars + seed
                                                       ref (the paper's
                                                       Table-2 trick: the
                                                       server regenerates
                                                       the perturbations
                                                       from the shared seed)

Both messages serialize to a self-describing binary frame:

    MAGIC(4) | header_len uint32 LE | header json (utf-8) | raw buffers

and ``byte_size()`` is MEASURED from the actual serialized frame — the
reconciliation against the analytic ``fl/comm.py`` Table-2 parameter counts
is asserted in tests/test_messages.py. Scalar payloads are quantized on the
wire with a configurable dtype (fp32 lossless / bf16 / fp16); fp32 framing
round-trips bit-exactly, which is what keeps the runtime's ideal-network
round bit-identical to the in-process round step.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

import numpy as np

try:  # bf16 comes with jax's ml_dtypes dependency; fall back gracefully
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover - ml_dtypes ships with jax
    _BF16 = None

MAGIC_ASSIGN = b"SPA1"
MAGIC_UPDATE = b"SPU1"

WIRE_DTYPES: Dict[str, np.dtype] = {
    "fp32": np.dtype(np.float32),
    "fp16": np.dtype(np.float16),
}
if _BF16 is not None:
    WIRE_DTYPES["bf16"] = _BF16


def wire_dtype(name: str) -> np.dtype:
    try:
        return WIRE_DTYPES[name]
    except KeyError:
        raise ValueError(
            f"unknown wire dtype {name!r}; available: {sorted(WIRE_DTYPES)}")


def _encode_buffers(buffers):
    """buffers: list of np arrays -> (meta list, concatenated bytes)."""
    meta, blobs = [], []
    for b in buffers:
        b = np.ascontiguousarray(b)
        meta.append({"shape": list(b.shape), "dtype": b.dtype.name})
        blobs.append(b.tobytes())
    return meta, b"".join(blobs)


def _decode_buffers(meta, raw: bytes):
    out, off = [], 0
    for m in meta:
        dt = _BF16 if (m["dtype"] == "bfloat16" and _BF16 is not None) \
            else np.dtype(m["dtype"])
        n = int(np.prod(m["shape"], dtype=np.int64)) * dt.itemsize
        out.append(np.frombuffer(raw[off:off + n], dtype=dt)
                   .reshape(m["shape"]))
        off += n
    if off != len(raw):
        raise ValueError(f"trailing bytes in frame: {len(raw) - off}")
    return out


def _frame(magic: bytes, header: dict, raw: bytes) -> bytes:
    hj = json.dumps(header, separators=(",", ":")).encode()
    return magic + np.uint32(len(hj)).tobytes() + hj + raw


def _unframe(magic: bytes, data: bytes) -> Tuple[dict, bytes]:
    if data[:4] != magic:
        raise ValueError(f"bad magic {data[:4]!r} (want {magic!r})")
    hlen = int(np.frombuffer(data[4:8], np.uint32)[0])
    header = json.loads(data[8:8 + hlen].decode())
    return header, data[8 + hlen:]


# ---------------------------------------------------------------------------
# TaskAssignment (server -> client)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TaskAssignment:
    """One client's marching orders for one round.

    ``seed_id`` is the client's position in the round's fold_in chain (what
    the reference round step calls ``client_id = arange(M)``); ``client_id``
    is the logical population id (data shard / availability identity).
    ``unit_ids`` are indices into the round's UnitIndex — the unit-mask id.
    """
    round_idx: int
    client_id: int
    seed_id: int
    cohort_size: int
    seed: int                    # global algorithm seed; the chain is
                                 # fold_in(fold_in(PRNGKey(seed), round), seed_id)
    n_units: int                 # U — so the mask row can be rebuilt
    unit_ids: np.ndarray         # (n_assigned,) int32
    hparams: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def mask_row(self) -> np.ndarray:
        row = np.zeros((self.n_units,), np.float32)
        row[np.asarray(self.unit_ids, np.int64)] = 1.0
        return row

    def to_bytes(self) -> bytes:
        meta, raw = _encode_buffers(
            [np.asarray(self.unit_ids, np.int32)])
        header = {
            "round_idx": int(self.round_idx),
            "client_id": int(self.client_id),
            "seed_id": int(self.seed_id),
            "cohort_size": int(self.cohort_size),
            "seed": int(self.seed),
            "n_units": int(self.n_units),
            "hparams": self.hparams,
            "buffers": meta,
        }
        return _frame(MAGIC_ASSIGN, header, raw)

    @classmethod
    def from_bytes(cls, data: bytes) -> "TaskAssignment":
        header, raw = _unframe(MAGIC_ASSIGN, data)
        (unit_ids,) = _decode_buffers(header.pop("buffers"), raw)
        return cls(unit_ids=unit_ids.astype(np.int32), **header)

    def byte_size(self) -> int:
        return len(self.to_bytes())


# ---------------------------------------------------------------------------
# ClientUpdate (client -> server)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClientUpdate:
    """One client's uplink for one round.

    mode='delta' (per-epoch): ``unit_payload`` maps unit id -> flat list of
    that unit's delta leaves (e.g. the LoRA (A,B) slices at one depth);
    ``head_payload`` carries the always-trained personalisation head.
    mode='jvp' (per-iteration): ``jvps`` carries the K scalars; the seed ref
    (round_idx, seed_id) is all the server needs to rebuild the gradient.
    """
    round_idx: int
    client_id: int
    seed_id: int
    mode: str                                      # 'delta' | 'jvp'
    wire: str = "fp32"
    unit_payload: Optional[Dict[int, list]] = None  # unit id -> [np arrays]
    head_payload: Optional[list] = None             # [np arrays] or None
    jvps: Optional[np.ndarray] = None               # (K,) in wire dtype
    loss: float = float("nan")                      # telemetry, not payload

    # -- construction from in-process trees ---------------------------------

    @classmethod
    def from_delta(cls, delta_tree, index, unit_ids, *, round_idx, client_id,
                   seed_id, wire="fp32", loss=float("nan"),
                   include_head=True) -> "ClientUpdate":
        """Extract the masked slices of a peft-shaped delta tree.

        Only the leaves of the client's assigned ``unit_ids`` (plus the head)
        are packed — the rest of the tree is exactly zero by construction
        (the estimator masks the gradient), so the wire payload is lossless
        at fp32.
        """
        import jax

        dt = wire_dtype(wire)
        unit_payload: Dict[int, list] = {}
        for uid in np.asarray(unit_ids, np.int64).tolist():
            group, target, layer = index.units[uid]
            leaves = jax.tree.leaves(delta_tree[group][target])
            sel = [np.asarray(l[layer] if layer >= 0 else l).astype(dt)
                   for l in leaves]
            unit_payload[int(uid)] = sel
        head_payload = None
        if include_head and "head" in delta_tree:
            head_payload = [np.asarray(l).astype(dt)
                            for l in jax.tree.leaves(delta_tree["head"])]
        return cls(round_idx=round_idx, client_id=client_id, seed_id=seed_id,
                   mode="delta", wire=wire, unit_payload=unit_payload,
                   head_payload=head_payload, loss=loss)

    @classmethod
    def from_jvps(cls, jvps, *, round_idx, client_id, seed_id, wire="fp32",
                  loss=float("nan")) -> "ClientUpdate":
        dt = wire_dtype(wire)
        return cls(round_idx=round_idx, client_id=client_id, seed_id=seed_id,
                   mode="jvp", wire=wire,
                   jvps=np.asarray(jvps).astype(dt), loss=loss)

    def to_delta(self, peft_template, index):
        """Expand the payload back into a peft-shaped tree (zeros outside the
        assigned units). fp32 wire round-trips bit-exactly."""
        import jax

        leaves, treedef = jax.tree.flatten(peft_template)
        out = [np.zeros(l.shape, np.float32) for l in leaves]
        # enumerate flat positions through the same tree structure so subtree
        # leaves can be mapped to indices without relying on leaf identity
        pos_tree = jax.tree.unflatten(treedef, list(range(len(leaves))))

        def leaf_indices(subtree_pos):
            return jax.tree.leaves(subtree_pos)

        for uid, bufs in (self.unit_payload or {}).items():
            group, target, layer = index.units[int(uid)]
            for li, buf in zip(leaf_indices(pos_tree[group][target]), bufs):
                if layer >= 0:
                    out[li][layer] = np.asarray(buf, np.float32)
                else:
                    out[li][...] = np.asarray(buf, np.float32)
        if self.head_payload is not None and "head" in peft_template:
            for li, buf in zip(leaf_indices(pos_tree["head"]),
                               self.head_payload):
                out[li][...] = np.asarray(buf, np.float32)
        return jax.tree.unflatten(treedef, out)

    # -- serialization ------------------------------------------------------

    def _payload_buffers(self):
        bufs, layout = [], []
        if self.mode == "delta":
            for uid in sorted(self.unit_payload or {}):
                arrs = self.unit_payload[uid]
                layout.append({"unit": int(uid), "n": len(arrs)})
                bufs.extend(arrs)
            if self.head_payload is not None:
                layout.append({"unit": -1, "n": len(self.head_payload)})
                bufs.extend(self.head_payload)
        else:
            layout.append({"unit": -2, "n": 1})
            bufs.append(np.asarray(self.jvps))
        return bufs, layout

    def to_bytes(self) -> bytes:
        bufs, layout = self._payload_buffers()
        meta, raw = _encode_buffers(bufs)
        header = {
            "round_idx": int(self.round_idx),
            "client_id": int(self.client_id),
            "seed_id": int(self.seed_id),
            "mode": self.mode,
            "wire": self.wire,
            "layout": layout,
            "buffers": meta,
        }
        # loss telemetry rides as a FIXED 4-byte trailer (a json float field
        # would make the frame size value-dependent, breaking the shape-only
        # byte accounting the engine's streamed estimate relies on)
        trailer = np.float32(self.loss).tobytes()
        return _frame(MAGIC_UPDATE, header, raw) + trailer

    @classmethod
    def from_bytes(cls, data: bytes) -> "ClientUpdate":
        header, raw = _unframe(MAGIC_UPDATE, data[:-4])
        loss = float(np.frombuffer(data[-4:], np.float32)[0])
        bufs = _decode_buffers(header["buffers"], raw)
        out = cls(round_idx=header["round_idx"], client_id=header["client_id"],
                  seed_id=header["seed_id"], mode=header["mode"],
                  wire=header["wire"], loss=loss)
        off = 0
        if out.mode == "delta":
            out.unit_payload = {}
            for entry in header["layout"]:
                chunk = bufs[off:off + entry["n"]]
                off += entry["n"]
                if entry["unit"] == -1:
                    out.head_payload = chunk
                else:
                    out.unit_payload[int(entry["unit"])] = chunk
        else:
            out.jvps = bufs[0]
        return out

    # -- accounting ---------------------------------------------------------

    def byte_size(self) -> int:
        """Total measured frame size (header + payload)."""
        return len(self.to_bytes())

    def payload_byte_size(self, include_head: bool = True) -> int:
        """Raw payload bytes only (no framing/header overhead) — the number
        the Table-2 analytic parameter counts predict."""
        bufs, layout = self._payload_buffers()
        total = 0
        off = 0
        for entry in layout:
            chunk = bufs[off:off + entry["n"]]
            off += entry["n"]
            if entry["unit"] == -1 and not include_head:
                continue
            total += sum(np.asarray(b).nbytes for b in chunk)
        return total

    def n_payload_scalars(self, include_head: bool = True) -> int:
        bufs, layout = self._payload_buffers()
        total = 0
        off = 0
        for entry in layout:
            chunk = bufs[off:off + entry["n"]]
            off += entry["n"]
            if entry["unit"] == -1 and not include_head:
                continue
            total += sum(int(np.asarray(b).size) for b in chunk)
        return total
