"""Federation wire protocol: the two messages of one SPRY round.

    server -> client   TaskAssignment   round seed ref + unit-mask id +
                                        hyperparams (the weights themselves
                                        travel via the model-distribution
                                        channel, not per-round)
    client -> server   ClientUpdate     per-epoch:     masked delta payload
                                        per-iteration: K jvp scalars + seed
                                                       ref (the paper's
                                                       Table-2 trick: the
                                                       server regenerates
                                                       the perturbations
                                                       from the shared seed)

Both messages serialize to a self-describing, integrity-sealed binary frame
(wire schema v2):

    MAGIC(4) | header_len uint32 LE | header json (utf-8) | raw buffers
    [| fixed trailer] | crc32 uint32 LE over everything preceding it

The magic's 4th byte is the wire VERSION and the header carries a redundant
``schema`` tag plus the raw-payload byte count (``blen``), so strict decode
can classify exactly what went wrong on a flaky uplink: ``WireError.kind``
is one of ``truncated`` / ``corrupt`` (checksum) / ``version_mismatch`` /
``bad_magic`` / ``schema_mismatch`` / ``shape_mismatch``. A frame that
decodes without raising is byte-for-byte the frame that was sent (CRC32
over the full body) — there is no silent third outcome, which is the
contract the engine's quarantine path and tests/test_wire_integrity.py
are built on.

``byte_size()`` is MEASURED from the actual serialized frame — the
reconciliation against the analytic ``fl/comm.py`` Table-2 parameter counts
is asserted in tests/test_messages.py. Scalar payloads are quantized on the
wire with a configurable dtype (fp32 lossless / bf16 / fp16); fp32 framing
round-trips bit-exactly, which is what keeps the runtime's ideal-network
round bit-identical to the in-process round step.

Frames are encoded ONCE per message: ``to_bytes()`` memoizes the sealed
frame so ``byte_size()`` and the send path share a single serialization
(``tests/test_messages.py`` asserts one ``_frame`` call per message), and
``from_bytes`` seeds the cache with the received bytes (CRC-verified to be
exactly what was sealed). Mutating a message after encoding requires
``invalidate_encoding()`` — the engine's poison path does this.

``ClientUpdate.base_version`` is the async engine's staleness round tag:
the server model version the update was computed against. It is ``None``
on synchronous frames and only serialized when set, so sync frames are
byte-identical to wire schema v2 as shipped.
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from typing import Any, Dict, Optional, Tuple

import numpy as np

try:  # bf16 comes with jax's ml_dtypes dependency; fall back gracefully
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover - ml_dtypes ships with jax
    _BF16 = None

WIRE_SCHEMA = 2          # header schema tag; bump with the magic version
MAGIC_ASSIGN = b"SPA2"
MAGIC_UPDATE = b"SPU2"

FAILURE_KINDS = ("truncated", "corrupt", "version_mismatch", "bad_magic",
                 "schema_mismatch", "shape_mismatch")


class WireError(ValueError):
    """A frame that failed strict decode, classified by ``kind``.

    truncated         frame shorter than its own declared layout
    corrupt           CRC32 mismatch or unparseable header (bit flips)
    version_mismatch  right message family, different wire version byte
    bad_magic         not one of our frames at all
    schema_mismatch   header's redundant schema tag disagrees
    shape_mismatch    lengths/meta internally inconsistent (trailing bytes,
                      buffer meta not matching the raw section, bad fields)
    """

    def __init__(self, kind: str, detail: str = ""):
        if kind not in FAILURE_KINDS:
            raise AssertionError(f"unknown failure kind {kind!r}")
        self.kind = kind
        super().__init__(f"[{kind}] {detail}" if detail else kind)

WIRE_DTYPES: Dict[str, np.dtype] = {
    "fp32": np.dtype(np.float32),
    "fp16": np.dtype(np.float16),
}
if _BF16 is not None:
    WIRE_DTYPES["bf16"] = _BF16


def wire_dtype(name: str) -> np.dtype:
    try:
        return WIRE_DTYPES[name]
    except KeyError:
        raise ValueError(
            f"unknown wire dtype {name!r}; available: {sorted(WIRE_DTYPES)}")


def _encode_buffers(buffers):
    """buffers: list of np arrays -> (meta list, concatenated bytes)."""
    meta, blobs = [], []
    for b in buffers:
        b = np.ascontiguousarray(b)
        meta.append({"shape": list(b.shape), "dtype": b.dtype.name})
        blobs.append(b.tobytes())
    return meta, b"".join(blobs)


def _decode_buffers(meta, raw: bytes):
    out, off = [], 0
    if not isinstance(meta, list):
        raise WireError("shape_mismatch", "buffer meta is not a list")
    for m in meta:
        try:
            dt = _BF16 if (m["dtype"] == "bfloat16" and _BF16 is not None) \
                else np.dtype(m["dtype"])
            shape = [int(s) for s in m["shape"]]
        except (KeyError, TypeError, ValueError) as e:
            raise WireError("shape_mismatch", f"bad buffer meta: {e}")
        if any(s < 0 for s in shape):
            raise WireError("shape_mismatch", f"negative dim in {shape}")
        n = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        if off + n > len(raw):
            raise WireError("truncated",
                            f"buffer needs {n} bytes, {len(raw) - off} left")
        out.append(np.frombuffer(raw[off:off + n], dtype=dt).reshape(shape))
        off += n
    if off != len(raw):
        raise WireError("shape_mismatch",
                        f"trailing bytes in frame: {len(raw) - off}")
    return out


def _frame(magic: bytes, header: dict, raw: bytes,
           trailer: bytes = b"") -> bytes:
    """Seal a frame: header gains the schema tag + raw byte count, and a
    CRC32 over the whole body rides as a 4-byte suffix."""
    header = dict(header)
    header["schema"] = WIRE_SCHEMA
    header["blen"] = len(raw)
    hj = json.dumps(header, separators=(",", ":")).encode()
    body = magic + np.uint32(len(hj)).tobytes() + hj + raw + trailer
    return body + np.uint32(zlib.crc32(body)).tobytes()


def _unframe(magic: bytes, data: bytes,
             trailer_len: int = 0) -> Tuple[dict, bytes, bytes]:
    """Strict decode of a sealed frame -> (header, raw, trailer).

    Classification order is structural-first so the taxonomy is useful:
    magic/version, declared lengths, header parse, schema tag, CRC. Every
    failure raises ``WireError``; success implies the bytes are exactly
    what the sender sealed (CRC32 over the full body).
    """
    data = bytes(data)
    if len(data) < 12 + trailer_len:
        raise WireError("truncated", f"{len(data)} bytes < minimum frame")
    got = data[:4]
    if got != magic:
        if got[:3] == magic[:3]:
            raise WireError("version_mismatch", f"{got!r} (want {magic!r})")
        raise WireError("bad_magic", f"{got!r} (want {magic!r})")
    hlen = int(np.frombuffer(data[4:8], np.uint32)[0])
    if 8 + hlen + trailer_len + 4 > len(data):
        raise WireError("truncated",
                        f"header claims {hlen} bytes, frame has {len(data)}")
    try:
        header = json.loads(data[8:8 + hlen].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError("corrupt", f"unparseable header: {e}")
    if not isinstance(header, dict) or "schema" not in header:
        raise WireError("schema_mismatch", "header missing schema tag")
    if header["schema"] != WIRE_SCHEMA:
        raise WireError("schema_mismatch",
                        f"schema {header['schema']!r} != {WIRE_SCHEMA}")
    try:
        blen = int(header["blen"])
    except (KeyError, TypeError, ValueError):
        raise WireError("shape_mismatch", "header missing/bad blen")
    expected = 8 + hlen + blen + trailer_len + 4
    if len(data) < expected:
        raise WireError("truncated",
                        f"frame {len(data)} bytes < declared {expected}")
    if len(data) > expected:
        raise WireError("shape_mismatch",
                        f"frame {len(data)} bytes > declared {expected}")
    body, crc = data[:-4], data[-4:]
    if zlib.crc32(body) != int(np.frombuffer(crc, np.uint32)[0]):
        raise WireError("corrupt", "checksum mismatch")
    raw = data[8 + hlen:8 + hlen + blen]
    trailer = data[8 + hlen + blen:8 + hlen + blen + trailer_len]
    return header, raw, trailer


def decode_frame(data: bytes):
    """Strict decode of an unknown frame -> TaskAssignment | ClientUpdate.

    The single entry point the engine's quarantine path uses: either the
    decoded message is returned (bitwise-faithful, CRC-verified) or a
    ``WireError`` classifies the failure — never a silently-wrong value.
    """
    head = bytes(data[:4]) if len(data) >= 4 else bytes(data)
    if head == MAGIC_ASSIGN:
        return TaskAssignment.from_bytes(data)
    if head == MAGIC_UPDATE:
        return ClientUpdate.from_bytes(data)
    if len(data) < 12:
        raise WireError("truncated", f"{len(data)} bytes < minimum frame")
    for magic in (MAGIC_ASSIGN, MAGIC_UPDATE):
        if head[:3] == magic[:3]:
            raise WireError("version_mismatch", f"{head!r} (want {magic!r})")
    raise WireError("bad_magic", f"{head!r}")


# ---------------------------------------------------------------------------
# TaskAssignment (server -> client)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TaskAssignment:
    """One client's marching orders for one round.

    ``seed_id`` is the client's position in the round's fold_in chain (what
    the reference round step calls ``client_id = arange(M)``); ``client_id``
    is the logical population id (data shard / availability identity).
    ``unit_ids`` are indices into the round's UnitIndex — the unit-mask id.
    """
    round_idx: int
    client_id: int
    seed_id: int
    cohort_size: int
    seed: int                    # global algorithm seed; the chain is
                                 # fold_in(fold_in(key, round), seed_id)
    n_units: int                 # U — so the mask row can be rebuilt
    unit_ids: np.ndarray         # (n_assigned,) int32
    hparams: Dict[str, Any] = dataclasses.field(default_factory=dict)
    _encoded: Optional[bytes] = dataclasses.field(
        default=None, repr=False, compare=False)

    def mask_row(self) -> np.ndarray:
        row = np.zeros((self.n_units,), np.float32)
        row[np.asarray(self.unit_ids, np.int64)] = 1.0
        return row

    def _encode(self) -> bytes:
        meta, raw = _encode_buffers(
            [np.asarray(self.unit_ids, np.int32)])
        header = {
            "round_idx": int(self.round_idx),
            "client_id": int(self.client_id),
            "seed_id": int(self.seed_id),
            "cohort_size": int(self.cohort_size),
            "seed": int(self.seed),
            "n_units": int(self.n_units),
            "hparams": self.hparams,
            "buffers": meta,
        }
        return _frame(MAGIC_ASSIGN, header, raw)

    def to_bytes(self) -> bytes:
        if self._encoded is None:
            self._encoded = self._encode()
        return self._encoded

    def invalidate_encoding(self) -> None:
        """Drop the memoized frame after mutating fields in place."""
        self._encoded = None

    @classmethod
    def from_bytes(cls, data: bytes) -> "TaskAssignment":
        header, raw, _ = _unframe(MAGIC_ASSIGN, data)
        try:
            (unit_ids,) = _decode_buffers(header["buffers"], raw)
            out = cls(round_idx=int(header["round_idx"]),
                      client_id=int(header["client_id"]),
                      seed_id=int(header["seed_id"]),
                      cohort_size=int(header["cohort_size"]),
                      seed=int(header["seed"]),
                      n_units=int(header["n_units"]),
                      unit_ids=unit_ids.astype(np.int32),
                      hparams=header["hparams"])
        except (KeyError, TypeError, ValueError) as e:
            raise WireError("shape_mismatch", f"bad assignment header: {e}")
        # CRC guarantees these bytes are exactly what was sealed, so the
        # received frame IS a faithful encoding — seed the cache with it
        out._encoded = bytes(data)
        return out

    def byte_size(self) -> int:
        return len(self.to_bytes())


# ---------------------------------------------------------------------------
# ClientUpdate (client -> server)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClientUpdate:
    """One client's uplink for one round.

    mode='delta' (per-epoch): ``unit_payload`` maps unit id -> flat list of
    that unit's delta leaves (e.g. the LoRA (A,B) slices at one depth);
    ``head_payload`` carries the always-trained personalisation head.
    mode='jvp' (per-iteration): ``jvps`` carries the K scalars; the seed ref
    (round_idx, seed_id) is all the server needs to rebuild the gradient.

    ``base_version`` is the async staleness tag: the server model version
    this update was computed against (None on synchronous frames; the
    header field is only written when set, keeping sync frames byte-stable).
    """
    round_idx: int
    client_id: int
    seed_id: int
    mode: str                                      # 'delta' | 'jvp'
    wire: str = "fp32"
    unit_payload: Optional[Dict[int, list]] = None  # unit id -> [np arrays]
    head_payload: Optional[list] = None             # [np arrays] or None
    jvps: Optional[np.ndarray] = None               # (K,) in wire dtype
    loss: float = float("nan")                      # telemetry, not payload
    base_version: Optional[int] = None              # async round tag
    _encoded: Optional[bytes] = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- construction from in-process trees ---------------------------------

    @classmethod
    def from_delta(cls, delta_tree, index, unit_ids, *, round_idx, client_id,
                   seed_id, wire="fp32", loss=float("nan"),
                   include_head=True) -> "ClientUpdate":
        """Extract the masked slices of a peft-shaped delta tree.

        Only the leaves of the client's assigned ``unit_ids`` (plus the head)
        are packed — the rest of the tree is exactly zero by construction
        (the estimator masks the gradient), so the wire payload is lossless
        at fp32.
        """
        import jax

        dt = wire_dtype(wire)
        unit_payload: Dict[int, list] = {}
        for uid in np.asarray(unit_ids, np.int64).tolist():
            group, target, layer = index.units[uid]
            leaves = jax.tree.leaves(delta_tree[group][target])
            sel = [np.asarray(l[layer] if layer >= 0 else l).astype(dt)
                   for l in leaves]
            unit_payload[int(uid)] = sel
        head_payload = None
        if include_head and "head" in delta_tree:
            head_payload = [np.asarray(l).astype(dt)
                            for l in jax.tree.leaves(delta_tree["head"])]
        return cls(round_idx=round_idx, client_id=client_id, seed_id=seed_id,
                   mode="delta", wire=wire, unit_payload=unit_payload,
                   head_payload=head_payload, loss=loss)

    @classmethod
    def from_jvps(cls, jvps, *, round_idx, client_id, seed_id, wire="fp32",
                  loss=float("nan")) -> "ClientUpdate":
        dt = wire_dtype(wire)
        return cls(round_idx=round_idx, client_id=client_id, seed_id=seed_id,
                   mode="jvp", wire=wire,
                   jvps=np.asarray(jvps).astype(dt), loss=loss)

    def to_delta(self, peft_template, index):
        """Expand the payload back into a peft-shaped tree (zeros outside the
        assigned units). fp32 wire round-trips bit-exactly."""
        import jax

        leaves, treedef = jax.tree.flatten(peft_template)
        out = [np.zeros(l.shape, np.float32) for l in leaves]
        # enumerate flat positions through the same tree structure so subtree
        # leaves can be mapped to indices without relying on leaf identity
        pos_tree = jax.tree.unflatten(treedef, list(range(len(leaves))))

        def leaf_indices(subtree_pos):
            return jax.tree.leaves(subtree_pos)

        for uid, bufs in (self.unit_payload or {}).items():
            group, target, layer = index.units[int(uid)]
            for li, buf in zip(leaf_indices(pos_tree[group][target]), bufs):
                if layer >= 0:
                    out[li][layer] = np.asarray(buf, np.float32)
                else:
                    out[li][...] = np.asarray(buf, np.float32)
        if self.head_payload is not None and "head" in peft_template:
            for li, buf in zip(leaf_indices(pos_tree["head"]),
                               self.head_payload):
                out[li][...] = np.asarray(buf, np.float32)
        return jax.tree.unflatten(treedef, out)

    # -- serialization ------------------------------------------------------

    def _payload_buffers(self):
        bufs, layout = [], []
        if self.mode == "delta":
            for uid in sorted(self.unit_payload or {}):
                arrs = self.unit_payload[uid]
                layout.append({"unit": int(uid), "n": len(arrs)})
                bufs.extend(arrs)
            if self.head_payload is not None:
                layout.append({"unit": -1, "n": len(self.head_payload)})
                bufs.extend(self.head_payload)
        else:
            layout.append({"unit": -2, "n": 1})
            bufs.append(np.asarray(self.jvps))
        return bufs, layout

    def _encode(self) -> bytes:
        bufs, layout = self._payload_buffers()
        meta, raw = _encode_buffers(bufs)
        header = {
            "round_idx": int(self.round_idx),
            "client_id": int(self.client_id),
            "seed_id": int(self.seed_id),
            "mode": self.mode,
            "wire": self.wire,
            "layout": layout,
            "buffers": meta,
        }
        if self.base_version is not None:
            header["base_version"] = int(self.base_version)
        # loss telemetry rides as a FIXED 4-byte trailer (a json float field
        # would make the frame size value-dependent, breaking the shape-only
        # byte accounting the engine's streamed estimate relies on); the CRC
        # seals it along with the rest of the body
        trailer = np.float32(self.loss).tobytes()
        return _frame(MAGIC_UPDATE, header, raw, trailer)

    def to_bytes(self) -> bytes:
        if self._encoded is None:
            self._encoded = self._encode()
        return self._encoded

    def invalidate_encoding(self) -> None:
        """Drop the memoized frame after mutating fields in place (the
        engine's poison path mutates payloads post-construction)."""
        self._encoded = None

    @classmethod
    def from_bytes(cls, data: bytes) -> "ClientUpdate":
        header, raw, trailer = _unframe(MAGIC_UPDATE, data, trailer_len=4)
        loss = float(np.frombuffer(trailer, np.float32)[0])
        try:
            bufs = _decode_buffers(header["buffers"], raw)
            bv = header.get("base_version")
            out = cls(round_idx=int(header["round_idx"]),
                      client_id=int(header["client_id"]),
                      seed_id=int(header["seed_id"]), mode=header["mode"],
                      wire=header["wire"], loss=loss,
                      base_version=None if bv is None else int(bv))
            layout = header["layout"]
        except (KeyError, TypeError, ValueError) as e:
            raise WireError("shape_mismatch", f"bad update header: {e}")
        if out.mode not in ("delta", "jvp"):
            raise WireError("shape_mismatch", f"unknown mode {out.mode!r}")
        off = 0
        if out.mode == "delta":
            out.unit_payload = {}
            try:
                for entry in layout:
                    chunk = bufs[off:off + entry["n"]]
                    off += entry["n"]
                    if entry["unit"] == -1:
                        out.head_payload = chunk
                    else:
                        out.unit_payload[int(entry["unit"])] = chunk
            except (KeyError, TypeError, ValueError) as e:
                raise WireError("shape_mismatch", f"bad layout: {e}")
        else:
            if len(bufs) != 1:
                raise WireError("shape_mismatch",
                                f"jvp update carries {len(bufs)} buffers")
            out.jvps = bufs[0]
        # CRC-verified: the received bytes are exactly the sealed frame
        out._encoded = bytes(data)
        return out

    # -- accounting ---------------------------------------------------------

    def byte_size(self) -> int:
        """Total measured frame size (header + payload)."""
        return len(self.to_bytes())

    def payload_byte_size(self, include_head: bool = True) -> int:
        """Raw payload bytes only (no framing/header overhead) — the number
        the Table-2 analytic parameter counts predict."""
        bufs, layout = self._payload_buffers()
        total = 0
        off = 0
        for entry in layout:
            chunk = bufs[off:off + entry["n"]]
            off += entry["n"]
            if entry["unit"] == -1 and not include_head:
                continue
            total += sum(np.asarray(b).nbytes for b in chunk)
        return total

    def n_payload_scalars(self, include_head: bool = True) -> int:
        bufs, layout = self._payload_buffers()
        total = 0
        off = 0
        for entry in layout:
            chunk = bufs[off:off + entry["n"]]
            off += entry["n"]
            if entry["unit"] == -1 and not include_head:
                continue
            total += sum(int(np.asarray(b).size) for b in chunk)
        return total
