"""Communication / computation cost accounting (paper Tables 2 & 3).

Analytic formulas, parameterised exactly as the paper: w_g total trainable
params, w_l params per trainable layer, L trainable layer count, M
participating clients, K perturbations per batch.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CommCost:
    client_to_server: float   # parameter count per round, per client aggregate
    server_to_client: float


def comm_cost(method: str, mode: str, w_l: float, L: int, M: int) -> CommCost:
    """Table 2 of the paper. ``mode`` is 'per_epoch' or 'per_iteration'."""
    w_g = w_l * L
    method = method.lower()
    backprop = method in ("fedavg", "fedyogi", "fedsgd")
    zeroorder = method in ("fedmezo", "fwdllm", "baffle")
    if backprop:
        return CommCost(w_g, w_g * M)
    if zeroorder:
        if mode == "per_epoch":
            return CommCost(w_g, w_g * M)
        return CommCost(1, (w_g + 1) * M)
    if method == "spry":
        layers_per_client = max(L / M, 1)
        if mode == "per_epoch":
            return CommCost(w_l * layers_per_client, w_l * max(L, M))
        return CommCost(1, w_l * max(L, M) + M)
    raise ValueError(method)


@dataclasses.dataclass(frozen=True)
class ComputeCost:
    client_per_iter: float
    server_per_round: float


def compute_cost(method: str, mode: str, w_l: float, L: int, M: int,
                 c: float, v: float, K: int = 1) -> ComputeCost:
    """Table 3 of the paper. c = per-layer matmul cost, v = jvp column
    overhead (≈0 under XLA fusion; kept for parity with the paper)."""
    method = method.lower()
    if method in ("fedavg", "fedyogi", "fedsgd"):
        return ComputeCost(3 * L * c, (M - 1) * w_l * L)
    if method == "fedmezo":
        server = (M - 1) * w_l * L if mode == "per_epoch" else 2 * M * w_l * L
        return ComputeCost(L * (2 * c + 3 * w_l), server)
    if method in ("fwdllm", "baffle"):
        server = (M - 1) * w_l * L if mode == "per_epoch" else 2 * M * w_l * L
        return ComputeCost(K * L * (2 * c + w_l), server)
    if method == "spry":
        client = 2 * max(L / M, 1) * (c + v) + w_l * L
        if mode == "per_epoch":
            groups = max(M / L, 1)
            server = (groups - 1) * w_l * max(L / M, 1) * min(L, M)
        else:
            server = 2 * max(M / L, 1) * w_l * max(L / M, 1) * min(L, M)
        return ComputeCost(client, server)
    raise ValueError(method)
