"""JSON-safe encoding of the async engine's virtual-time snapshot.

``AsyncFederationEngine.snapshot()`` captures the event heap and staleness
buffer with in-flight wire frames as RAW BYTES (the exact CRC-sealed frames
— restoring them byte-for-byte is what makes kill-and-resume bitwise even
for updates that were in flight when the process died). The run manifest's
``extra`` dict is JSON, so frames are transported as base64 strings:

    manifest.extra["async"] = encode_async_snapshot(engine.snapshot())
    engine.restore(decode_async_snapshot(manifest.extra["async"]))

Floats round-trip exactly (Python's json emits repr-precision binary64),
so the virtual clock and per-dispatch compute durations restore to the
identical bits the heap ordering depends on.
"""
from __future__ import annotations

import base64
from typing import Any, Dict

_BYTES_KEYS = ("frames",)     # heap payload keys holding lists of frames


def _encode_payload(payload: Dict[str, Any]) -> Dict[str, Any]:
    out = dict(payload)
    for k in _BYTES_KEYS:
        if k in out:
            out[k] = [base64.b64encode(f).decode("ascii") for f in out[k]]
    return out


def _decode_payload(payload: Dict[str, Any]) -> Dict[str, Any]:
    out = dict(payload)
    for k in _BYTES_KEYS:
        if k in out:
            out[k] = [base64.b64decode(f) for f in out[k]]
    return out


def encode_async_snapshot(snap: Dict[str, Any]) -> Dict[str, Any]:
    """Raw engine snapshot (bytes in place) -> JSON-safe dict."""
    out = dict(snap)
    out["heap"] = {
        "next_seq": snap["heap"]["next_seq"],
        "entries": [{"t": e["t"], "seq": e["seq"],
                     "payload": _encode_payload(e["payload"])}
                    for e in snap["heap"]["entries"]],
    }
    out["buffer"] = [
        {**e, "frame": base64.b64encode(e["frame"]).decode("ascii")}
        for e in snap["buffer"]]
    return out


def decode_async_snapshot(doc: Dict[str, Any]) -> Dict[str, Any]:
    """JSON-safe dict -> raw engine snapshot (bytes restored)."""
    out = dict(doc)
    out["heap"] = {
        "next_seq": int(doc["heap"]["next_seq"]),
        "entries": [{"t": float(e["t"]), "seq": int(e["seq"]),
                     "payload": _decode_payload(e["payload"])}
                    for e in doc["heap"]["entries"]],
    }
    out["buffer"] = [
        {**e, "frame": base64.b64decode(e["frame"])}
        for e in doc["buffer"]]
    return out
