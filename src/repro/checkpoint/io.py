"""Minimal npz-based pytree checkpointing (model params + server state).

Keys are '/'-joined pytree paths; structure is reconstructed on load from the
reference tree (the usual "restore into like-structured template" pattern).

``save_pytree`` is ATOMIC: the npz is written to a same-directory ``*.tmp``
file, fsync'd, and ``os.replace``d into place, so a crash mid-write can
never leave a torn checkpoint at the target path — readers see either the
old complete file or the new complete file. ``load_pytree`` is STRICT: the
stored key set must match the template's exactly (missing or extra keys
raise ``CheckpointError`` up front, instead of KeyError-ing mid-restore
with a half-built leaf list).
"""
from __future__ import annotations

import os

import jax
import numpy as np


class CheckpointError(ValueError):
    """A checkpoint that cannot be restored into the given template."""


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out[key] = np.asarray(leaf)
    return out


def _fsync_dir(dirpath: str) -> None:
    """Durably record the directory entry (rename) itself."""
    try:
        fd = os.open(dirpath, os.O_RDONLY)
    except OSError:  # pragma: no cover - platforms without dir fds
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_pytree(path: str, tree) -> None:
    """Atomically write ``tree`` to ``path`` (npz). tmp + fsync + rename."""
    path = path if path.endswith(".npz") else path + ".npz"
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    # np.savez appends .npz to *names* but writes file OBJECTS verbatim, so
    # handing it an open handle keeps the tmp path under our control
    with open(tmp, "wb") as f:
        np.savez(f, **_flatten_with_paths(tree))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path))


def load_pytree(path: str, like):
    """Load arrays saved by ``save_pytree`` into the structure of ``like``.

    Strict: the checkpoint's key set must equal the template's — a renamed
    field, a missing leaf, or a stale extra leaf fails BEFORE any leaf is
    restored, never mid-restore.
    """
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    keyed = []
    for pth, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in pth)
        keyed.append((key, leaf))
    want = {k for k, _ in keyed}
    have = set(data.files)
    if want != have:
        missing, extra = sorted(want - have), sorted(have - want)
        raise CheckpointError(
            f"checkpoint/template key mismatch: missing {missing[:5]}"
            f"{'...' if len(missing) > 5 else ''}, extra {extra[:5]}"
            f"{'...' if len(extra) > 5 else ''}")
    leaves = []
    for key, leaf in keyed:
        arr = data[key]
        if arr.shape != np.shape(leaf):
            raise CheckpointError(
                f"shape mismatch for {key}: {arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
