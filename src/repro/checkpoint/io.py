"""Minimal npz-based pytree checkpointing (model params + server state).

Keys are '/'-joined pytree paths; structure is reconstructed on load from the
reference tree (the usual "restore into like-structured template" pattern).
"""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten_with_paths(tree))


def load_pytree(path: str, like):
    """Load arrays saved by ``save_pytree`` into the structure of ``like``."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in pth)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
