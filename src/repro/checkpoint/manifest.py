"""Crash-safe checkpointing: manifests, content hashes, resume.

A checkpoint is TWO files written in a strict order:

    state_<round>.npz      the pytree (atomic: tmp + fsync + os.replace)
    manifest.json          round idx, algorithm seed, host-RNG state,
                           metric history, a sha256 CONTENT hash of the
                           state tree, and the state filename — also
                           written atomically, and always LAST.

Because the manifest is replaced last, a crash at any instant leaves
``manifest.json`` pointing at a complete, hash-verified state file: either
the previous round's (the new state landed but the manifest didn't — the
round is simply re-run on resume) or the new one. The npz itself is never
byte-compared (zip members embed timestamps); integrity and the
kill-and-resume bitwise test both go through ``tree_content_hash``, which
hashes the sorted (key, dtype, shape, bytes) leaves — the actual numbers.

Determinism on resume comes from the manifest carrying everything the
training loop consumes host-side: the round index (the jit round key is
``fold_in(PRNGKey(seed), round_idx)``), the algorithm seed, and — for the
in-process path — the numpy Generator's ``bit_generator.state`` dict.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.io import (
    CheckpointError,
    _flatten_with_paths,
    _fsync_dir,
    load_pytree,
    save_pytree,
)

MANIFEST_SCHEMA = "repro.checkpoint/v1"
MANIFEST_NAME = "manifest.json"


def tree_content_hash(tree) -> str:
    """sha256 over the tree's sorted (key, dtype, shape, bytes) leaves —
    a pure content identity, independent of npz container timestamps."""
    h = hashlib.sha256()
    for key in sorted(flat := _flatten_with_paths(tree)):
        arr = np.ascontiguousarray(flat[key])
        h.update(key.encode())
        h.update(arr.dtype.str.encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class RunManifest:
    """Everything resume needs to replay the trajectory bit-identically."""
    round_idx: int                   # rounds COMPLETED (resume starts here)
    algo_seed: int
    content_hash: str
    state_file: str                  # npz filename, relative to the dir
    rng_state: Optional[Dict[str, Any]] = None  # np bit_generator.state
    history: List[dict] = dataclasses.field(default_factory=list)
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schema: str = MANIFEST_SCHEMA

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True,
                          default=float)

    @classmethod
    def from_json(cls, text: str) -> "RunManifest":
        doc = json.loads(text)
        if doc.get("schema") != MANIFEST_SCHEMA:
            raise CheckpointError(
                f"unknown manifest schema {doc.get('schema')!r} "
                f"(want {MANIFEST_SCHEMA})")
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(doc) - fields
        if unknown:
            raise CheckpointError(f"unknown manifest keys {sorted(unknown)}")
        return cls(**doc)


def _atomic_write_text(path: str, text: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(os.path.abspath(path)))


def write_manifest(ckpt_dir: str, manifest: RunManifest) -> str:
    path = os.path.join(ckpt_dir, MANIFEST_NAME)
    _atomic_write_text(path, manifest.to_json())
    return path


def read_manifest(ckpt_dir: str) -> RunManifest:
    path = os.path.join(ckpt_dir, MANIFEST_NAME)
    try:
        with open(path) as f:
            return RunManifest.from_json(f.read())
    except OSError as e:
        raise CheckpointError(f"no manifest at {path} ({e})")


def _gc(ckpt_dir: str, current_state: str, keep_last: int) -> None:
    """Drop all but the newest ``keep_last`` state files; never the one the
    manifest points at."""
    states = sorted(f for f in os.listdir(ckpt_dir)
                    if f.startswith("state_") and f.endswith(".npz"))
    for f in states[:-keep_last] if keep_last > 0 else []:
        if f != current_state:
            try:
                os.remove(os.path.join(ckpt_dir, f))
            except OSError:  # pragma: no cover - best-effort cleanup
                pass


def save_checkpoint(ckpt_dir: str, state, *, round_idx: int, algo_seed: int,
                    rng_state: Optional[dict] = None,
                    history: Optional[list] = None,
                    extra: Optional[dict] = None,
                    keep_last: int = 2) -> RunManifest:
    """Write one crash-safe checkpoint: state npz FIRST, manifest LAST."""
    os.makedirs(ckpt_dir, exist_ok=True)
    state_file = f"state_{int(round_idx):06d}.npz"
    save_pytree(os.path.join(ckpt_dir, state_file), state)
    manifest = RunManifest(
        round_idx=int(round_idx), algo_seed=int(algo_seed),
        content_hash=tree_content_hash(state), state_file=state_file,
        rng_state=rng_state, history=list(history or []),
        extra=dict(extra or {}))
    write_manifest(ckpt_dir, manifest)
    _gc(ckpt_dir, state_file, keep_last)
    return manifest


def load_checkpoint(ckpt_dir: str, like) -> Tuple[Any, RunManifest]:
    """Restore (state, manifest), verifying the state's content hash."""
    manifest = read_manifest(ckpt_dir)
    state_path = os.path.join(ckpt_dir, manifest.state_file)
    if not os.path.exists(state_path):
        raise CheckpointError(
            f"manifest points at missing state {manifest.state_file}")
    state = load_pytree(state_path, like)
    got = tree_content_hash(state)
    if got != manifest.content_hash:
        raise CheckpointError(
            f"state content hash {got[:12]} != manifest "
            f"{manifest.content_hash[:12]} — corrupt or tampered checkpoint")
    return state, manifest
