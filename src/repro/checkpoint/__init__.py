from repro.checkpoint.async_state import (
    decode_async_snapshot,
    encode_async_snapshot,
)
from repro.checkpoint.io import CheckpointError, load_pytree, save_pytree
from repro.checkpoint.manifest import (
    RunManifest,
    load_checkpoint,
    read_manifest,
    save_checkpoint,
    tree_content_hash,
    write_manifest,
)
