"""Forward-mode AD gradient estimation (paper §2, Eq. 1-3).

    jvp      = J_f(w) · v           — one jax.jvp forward pass
    grad_est = jvp * v              — unbiased estimator of ∇f for v~N(0,I)

K>1 perturbations are averaged (paper's ablation Fig. 5a). Perturbations are
regenerated from scalar seeds with ``jax.random.fold_in`` chains so the
server can rebuild any client's v exactly (per-iteration communication mode
sends only the jvp scalar back — Table 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.pytree import normal_like


def masked_perturbation(key, peft, mask_tree=None):
    """v ~ N(0, I) over the trainable tree, zeroed outside the client's
    assigned units (SPRY's weight splitting)."""
    v = normal_like(key, peft, dtype=jnp.float32)
    if mask_tree is not None:
        v = jax.tree.map(lambda vi, m: vi * m, v, mask_tree)
    return v


def forward_gradient(loss_fn, peft, key, k_perturbations=1, mask_tree=None,
                     jvp_clip=None):
    """Forward-gradient estimate of ∇_peft loss_fn.

    Returns (loss, grad_estimate, jvps (K,)). ``loss_fn`` must be a function
    of the peft tree only (base weights closed over). One jax.jvp call per
    perturbation — each is a single forward pass, no activation stack.

    ``jvp_clip`` (beyond-paper stabiliser): clamp the jvp scalar to
    [-c, c] before forming jvp*v — bounds the update magnitude of outlier
    perturbations (a biased but much lower-variance estimator; off by
    default, matches the paper exactly when None).
    """
    peft32 = jax.tree.map(lambda x: x.astype(jnp.float32), peft)

    def one(i, carry):
        g, jvps, loss_acc = carry
        ki = jax.random.fold_in(key, i)
        v = masked_perturbation(ki, peft32, mask_tree)
        loss, jvp = jax.jvp(loss_fn, (peft32,), (v,))
        if jvp_clip is not None:
            jvp = jnp.clip(jvp, -jvp_clip, jvp_clip)
        g = jax.tree.map(lambda gi, vi: gi + jvp * vi, g, v)
        return g, jvps.at[i].set(jvp), loss_acc + loss

    g0 = jax.tree.map(jnp.zeros_like, peft32)
    jvps0 = jnp.zeros((k_perturbations,), jnp.float32)
    if k_perturbations == 1:
        g, jvps, loss = one(0, (g0, jvps0, jnp.float32(0.0)))
    else:
        g, jvps, loss = jax.lax.fori_loop(
            0, k_perturbations, one, (g0, jvps0, jnp.float32(0.0)))
    scale = 1.0 / k_perturbations
    g = jax.tree.map(lambda x: x * scale, g)
    return loss * scale, g, jvps


def reconstruct_gradient(peft_template, key, jvps, mask_tree=None):
    """Server-side gradient reconstruction from jvp scalars + the shared seed
    (per-iteration communication mode, paper §3.2). Must be bit-identical to
    the client's estimate — enforced by tests/test_forward_grad.py."""
    K = jvps.shape[0]
    g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), peft_template)
    for i in range(K):
        ki = jax.random.fold_in(key, i)
        v = masked_perturbation(ki, g, mask_tree)
        g = jax.tree.map(lambda gi, vi: gi + jvps[i] * vi, g, v)
    return jax.tree.map(lambda x: x / K, g)
