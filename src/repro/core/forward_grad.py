"""Forward-mode AD gradient estimation (paper §2, Eq. 1-3).

    jvp      = J_f(w) · v           — directional derivative along v
    grad_est = jvp * v              — unbiased estimator of ∇f for v~N(0,I)

K>1 perturbations are averaged (paper's ablation Fig. 5a). Perturbations are
regenerated from scalar seeds with ``jax.random.fold_in`` chains so the
server can rebuild any client's v exactly (per-iteration communication mode
sends only the jvp scalar back — Table 2).

Tangent-axis contract (this module's batched engine)
----------------------------------------------------
K perturbations are stacked on a leading *tangent axis*: a stacked
perturbation tree has leaves of shape ``(K,) + leaf.shape`` and the jvp
vector has shape ``(K,)``. The default path linearizes the loss once
(``jax.linearize``) and evaluates all K tangents through the linear map with
``jax.vmap`` — the frozen-base primal is computed ONCE per estimate instead
of K times (the paper's §5.3 "column-by-column jvp" overhead). Ops whose
inputs carry no tangent stay unbatched under vmap, so only tangent-carrying
intermediates gain the K axis.

On kernel backends the vmap does not stop at batched jnp ops: the dispatch
layer (kernels/dispatch.py) registers custom batching rules so that
vmap-of-tangents through a LoRA projection, an RWKV6 recurrence, or an SWA
attention block lowers DIRECTLY to the corresponding multi-tangent Pallas
kernel (``lora_dual_mt_tangents`` / ``wkv6_scan_mt_tangents`` /
``swa_attention_mt_tangents``) — the same leading-K tangent axis becomes
the kernel's T axis, and one pass over the primal operands serves all K
tangents in VMEM.

``tangent_batch`` trades that amortization against tangent-intermediate
memory (each tangent-carrying activation is K× wider):

    None / >=K  one batched pass (default; max primal amortization)
    1           the sequential fori_loop of full jax.jvp passes — zero
                stacked tangents, primal recomputed per perturbation
                (memory-constrained clients; the seed behaviour)
    1<b<K       ceil(K/b) groups scanned sequentially, b tangents per pass;
                K is padded to a multiple of b with masked-out tangents so
                ONE scanned trace covers everything (no re-traced remainder
                tail), and both the gradient accumulator and the jvp buffer
                ride the scan carry — donated in-place by XLA, so only one
                group of stacked tangents is ever live

Fused contraction (cotangent-known epilogues)
---------------------------------------------
``fused_contraction=True`` with a ``SplitLoss`` — a loss that declares its
final mixer site, ``loss(p) = post(site(*args), ctx, p)`` with
``(args, ctx) = pre(p)`` — exploits that everything downstream of the site
is cheap: the post-head is reversed ONCE (jax.vjp over the head only — no
mixer activations stored) for the cotangents (gy, g_ctx, g_p), and each
tangent's site contribution <gy, ydot_t> is computed by the dispatch
layer's ``*_jvp_contract`` ops, whose custom-vmap lowering picks the
``*_mt_jvps`` contraction-epilogue kernels — the K tangent outputs of the
site are contracted blockwise in VMEM and NEVER written to HBM. The jvp
scalars equal the standard route's up to float reassociation of the
contraction.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.dispatch import forward_ad_region
from repro.utils.pytree import normal_like


def masked_perturbation(key, peft, mask_tree=None):
    """v ~ N(0, I) over the trainable tree, zeroed outside the client's
    assigned units (SPRY's weight splitting)."""
    v = normal_like(key, peft, dtype=jnp.float32)
    if mask_tree is not None:
        v = jax.tree.map(lambda vi, m: vi * m, v, mask_tree)
    return v


def stacked_perturbations(key, peft, indices, mask_tree=None):
    """Perturbations for ``fold_in(key, i) for i in indices`` stacked on a
    leading tangent axis. Bit-identical per index to ``masked_perturbation``
    (vmap of the PRNG chain is deterministic), which is what lets the server
    rebuild the client's exact tangents from the scalar seed."""
    return jax.vmap(
        lambda i: masked_perturbation(jax.random.fold_in(key, i), peft,
                                      mask_tree))(indices)


def _combine(jvps, vs, k_total):
    """g = (1/K) Σ_i jvps[i] · vs[i] — the estimator average, contracted over
    the tangent axis. Shared by the client estimator and the server-side
    reconstruction so the two are bit-identical (same ops, same inputs)."""
    return jax.tree.map(
        lambda v: jnp.tensordot(jvps, v, axes=[[0], [0]]) / k_total, vs)


# ---------------------------------------------------------------------------
# Split losses: a declared final mixer site for the fused-contraction route
# ---------------------------------------------------------------------------

class SplitLoss:
    """A loss with a declared final ("epilogue-eligible") mixer site:

        loss(p) = post(site(*site_args), ctx, p),  (site_args, ctx) = pre(p)

    ``kind`` selects the site op and its tangent-contraction epilogue:

        'lora'    site_args = (x, w, a, b), static ``scale``
                  -> dispatch.lora_proj / lora_jvp_contract
        'wkv6'    site_args = (r, k, v, w, u)
                  -> dispatch.wkv6_mix / wkv6_jvp_contract
        'swa'     site_args = (q, k, v), static ``window``
                  -> dispatch.swa_attend / swa_jvp_contract
        'mamba2'  site_args = (xdt, bmat, cmat, decay)
                  -> dispatch.mamba2_mix / mamba2_jvp_contract

    ``ctx`` is any tangent-carrying side output of ``pre`` the post-head
    also needs (residual streams, aux losses; None if none). Calling the
    object evaluates the composition through the normally-dispatched site
    op, so it is a drop-in ``loss_fn``; ``forward_gradient(...,
    fused_contraction=True)`` additionally exploits the split (see module
    docstring).

    ``x_has_tangent=False`` (lora only) declares that x does NOT depend on
    the trainable tree — the projection is the first perturbed unit — which
    statically removes the input-tangent GEMMs from the epilogue kernel.

    ``site_fn`` optionally overrides the kind-based site PRIMAL (the
    contraction epilogue is still selected by ``kind``): the registry's
    full-model split losses pass the family's backend-gated mixer here so
    the SplitLoss traces exactly the same program as the plain loss closure
    (bitwise-equal values on every backend).
    """

    def __init__(self, pre: Callable, kind: str, post: Callable, *,
                 scale: float = 1.0, window: Optional[int] = None,
                 x_has_tangent: bool = True, site_fn: Optional[Callable] = None):
        if kind not in ("lora", "wkv6", "swa", "mamba2"):
            raise ValueError(f"unknown site kind {kind!r}")
        self.pre = pre
        self.kind = kind
        self.post = post
        self.scale = scale
        self.window = window
        self.x_has_tangent = x_has_tangent
        self.site_fn = site_fn

    def site(self, args):
        if self.site_fn is not None:
            return self.site_fn(args)
        if self.kind == "lora":
            return dispatch.lora_proj(*args, self.scale)
        if self.kind == "wkv6":
            return dispatch.wkv6_mix(*args)
        if self.kind == "mamba2":
            return dispatch.mamba2_mix(*args)
        return dispatch.swa_attend(*args, self.window)

    def __call__(self, p):
        args, ctx = self.pre(p)
        return self.post(self.site(args), ctx, p)


def _tree_vdot(g, t):
    """Σ_leaves <g, t> in fp32 (0.0 for empty trees)."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.vdot(a.astype(jnp.float32),
                              b.astype(jnp.float32)), g, t))
    return sum(leaves) if leaves else jnp.float32(0.0)


def fused_linearize(loss_fn: SplitLoss, peft32):
    """(loss, jvp_of) for the fused-contraction route.

    Linearizes ``pre`` once (forward-mode, inside the kernel AD region),
    runs the site primal, reverses the post-head ONCE for the cotangents,
    and returns ``jvp_of(v)`` whose site term contracts in-kernel. Under
    ``jax.vmap`` the K site contributions lower to ONE ``*_mt_jvps``
    epilogue call — no (K, ..., N) tangent output exists at the site."""
    with forward_ad_region():
        (site_args, ctx), pre_lin = jax.linearize(loss_fn.pre, peft32)
        # site primal evaluated in the SAME trace context as the standard
        # route's linearize, so backend-gated site_fns (the registry's
        # model mixers) pick the same branch on both routes — loss bitwise
        y = loss_fn.site(site_args)
    loss, post_vjp = jax.vjp(loss_fn.post, y, ctx, peft32)
    gy, g_ctx, g_p = post_vjp(jnp.ones_like(loss))

    zw = None
    if loss_fn.kind == "lora":
        # frozen-W completeness term <gy, x @ wd_t> = <xᵀgy, wd_t>: the
        # (K_in, N) factor is primal-only, computed once outside the vmap
        # (wd_t is exact zeros in SPRY — W is frozen — and folds away)
        x, w = site_args[0], site_args[1]
        zw = jnp.einsum("...k,...n->kn", x.astype(jnp.float32),
                        gy.astype(jnp.float32))

    def jvp_of(v):
        argdots, ctxdot = pre_lin(v)
        if loss_fn.kind == "lora":
            x, w, a, b = site_args
            xd, wd, ad, bd = argdots
            val = dispatch.lora_jvp_contract(
                gy, x, w, a, b, ad, bd,
                xd=xd if loss_fn.x_has_tangent else None,
                scale=loss_fn.scale)
            val = val + _tree_vdot(zw, wd)
        elif loss_fn.kind == "wkv6":
            val = dispatch.wkv6_jvp_contract(gy, *site_args, *argdots)
        elif loss_fn.kind == "mamba2":
            val = dispatch.mamba2_jvp_contract(gy, *site_args, *argdots)
        else:
            val = dispatch.swa_jvp_contract(gy, *site_args, *argdots,
                                            loss_fn.window)
        return val + _tree_vdot(g_ctx, ctxdot) + _tree_vdot(g_p, v)

    return loss, jvp_of


# losses already warned about once when fused_contraction was requested but
# the loss declares no final mixer site. Keyed by the function's definition
# site (code object location), not its __name__: distinct lambdas/partials
# each warn once, while per-trace re-creations of the same closure do not.
_warned_unsplit_losses: set = set()


def _unsplit_key(loss_fn):
    fn = getattr(loss_fn, "func", loss_fn)       # unwrap functools.partial
    code = getattr(fn, "__code__", None)
    if code is not None:
        return (code.co_filename, code.co_firstlineno)
    return (type(fn).__module__, type(fn).__qualname__)


def _warn_unsplit_fallback(loss_fn):
    fn = getattr(loss_fn, "func", loss_fn)
    name = (getattr(fn, "__name__", None) or getattr(loss_fn, "__name__", None)
            or type(loss_fn).__name__)
    key = _unsplit_key(loss_fn)
    if key in _warned_unsplit_losses:
        return
    _warned_unsplit_losses.add(key)
    warnings.warn(
        f"fused_contraction=True was requested but loss {name!r} does not "
        f"declare a final mixer site (not a SplitLoss); taking the standard "
        f"materializing tangent route instead. Build the loss with "
        f"repro.models.registry.get_loss_fn(task, split=True) to run the "
        f"fused jvp-contraction epilogues.",
        stacklevel=3)


def forward_gradient(loss_fn, peft, key, k_perturbations=1, mask_tree=None,
                     jvp_clip=None, tangent_batch=None,
                     fused_contraction=False):
    """Forward-gradient estimate of ∇_peft loss_fn.

    Returns (loss, grad_estimate, jvps (K,)). ``loss_fn`` must be a function
    of the peft tree only (base weights closed over).

    ``tangent_batch`` — see module docstring. The batched paths and the
    sequential path are numerically equivalent per seed (same perturbations,
    same jvp values) up to float reassociation of the K-average.

    ``fused_contraction`` — when True AND ``loss_fn`` is a ``SplitLoss``
    (declares its final mixer site), the site's K tangent outputs are
    contracted against the post-head cotangent inside the kernel instead of
    being materialized (see module docstring). A plain callable loss_fn
    keeps the standard route with a one-time ``UserWarning`` naming the
    loss and the route taken (the registry's ``get_loss_fn(task,
    split=True)`` builders produce fused-capable losses for every family).

    ``jvp_clip`` (beyond-paper stabiliser): clamp the jvp scalar to
    [-c, c] before forming jvp*v — bounds the update magnitude of outlier
    perturbations (a biased but much lower-variance estimator; off by
    default, matches the paper exactly when None).
    """
    peft32 = jax.tree.map(lambda x: x.astype(jnp.float32), peft)
    K = int(k_perturbations)
    tb = K if tangent_batch is None else max(1, min(int(tangent_batch), K))
    fused = fused_contraction and isinstance(loss_fn, SplitLoss)
    if fused_contraction and not fused:
        _warn_unsplit_fallback(loss_fn)

    def clip(jvps):
        if jvp_clip is not None:
            return jnp.clip(jvps, -jvp_clip, jvp_clip)
        return jvps

    if K == 1 and not fused:
        # no tangent stacking needed — single dual-number pass
        v = masked_perturbation(jax.random.fold_in(key, 0), peft32, mask_tree)
        with forward_ad_region():
            loss, jvp = jax.jvp(loss_fn, (peft32,), (v,))
        jvps = clip(jnp.reshape(jvp, (1,)))
        vs = jax.tree.map(lambda x: x[None], v)
        return loss, _combine(jvps, vs, 1), jvps

    if tb == 1 and not fused:
        # sequential fallback: one full jax.jvp pass per perturbation — no
        # stacked tangents and in-loop g accumulation (bounded memory), the
        # primal recomputed K times (the seed behaviour)
        def one(i, carry):
            g, jvps, loss_acc = carry
            ki = jax.random.fold_in(key, i)
            v = masked_perturbation(ki, peft32, mask_tree)
            with forward_ad_region():
                loss, jvp = jax.jvp(loss_fn, (peft32,), (v,))
            if jvp_clip is not None:
                jvp = jnp.clip(jvp, -jvp_clip, jvp_clip)
            g = jax.tree.map(lambda gi, vi: gi + jvp * vi, g, v)
            return g, jvps.at[i].set(jvp), loss_acc + loss

        g0 = jax.tree.map(jnp.zeros_like, peft32)
        g, jvps, loss = jax.lax.fori_loop(
            0, K, one,
            (g0, jnp.zeros((K,), jnp.float32), jnp.float32(0.0)))
        scale = 1.0 / K
        return loss * scale, jax.tree.map(lambda x: x * scale, g), jvps

    # batched: linearize once (one primal), push tangent groups through the
    # linear map with vmap — stacked-tangent jvp. (forward_ad_region lets
    # the dispatch layer lower LoRA tangents to the fused Pallas kernel —
    # the tangent jaxpr is fixed here at trace time, so later vmap replays
    # of tangent_map inherit it.) On the fused route the site tangents are
    # contracted in-kernel against the post-head cotangent instead.
    if fused:
        loss, tangent_map = fused_linearize(loss_fn, peft32)
    else:
        with forward_ad_region():
            loss, tangent_map = jax.linearize(loss_fn, peft32)

    if tb >= K:
        vs = stacked_perturbations(key, peft32, jnp.arange(K), mask_tree)
        jvps = clip(jax.vmap(tangent_map)(vs))
        return loss, _combine(jvps, vs, K), jvps

    # chunked: ceil(K/tb) groups of tb tangents, scanned sequentially
    # (bounds the stacked-tangent memory to tb× while still amortizing
    # inside a group). K is padded to a multiple of tb with masked-out
    # tangents so ONE scanned trace covers everything — no re-traced
    # remainder tail — and the padded lanes contribute exact zeros (their
    # jvps are zeroed before the combine). Both accumulators ride the scan
    # carry, which XLA donates in-place: only one group of stacked
    # perturbations is ever live.
    n_groups = -(-K // tb)
    g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), peft32)

    def scan_body(carry, start):
        g_acc, jvps_acc = carry
        idx = start + jnp.arange(tb)
        vs_g = stacked_perturbations(key, peft32, idx, mask_tree)
        live = (idx < K).astype(jnp.float32)
        jvps_g = clip(jax.vmap(tangent_map)(vs_g)) * live
        g_acc = jax.tree.map(jnp.add, g_acc, _combine(jvps_g, vs_g, K))
        jvps_acc = jax.lax.dynamic_update_slice(jvps_acc, jvps_g, (start,))
        return (g_acc, jvps_acc), None

    (g, jvps_pad), _ = jax.lax.scan(
        scan_body, (g0, jnp.zeros((n_groups * tb,), jnp.float32)),
        jnp.arange(n_groups) * tb)
    return loss, g, jvps_pad[:K]


def reconstruct_gradient(peft_template, key, jvps, mask_tree=None):
    """Server-side gradient reconstruction from jvp scalars + the shared seed
    (per-iteration communication mode, paper §3.2). Regenerates the stacked
    perturbations and applies the same ``_combine`` contraction as the
    client-side estimator, so the rebuild is bit-identical to the client's
    estimate and its trace stays O(1) in K — enforced by
    tests/test_forward_grad.py."""
    K = jvps.shape[0]
    template32 = jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), peft_template)
    vs = stacked_perturbations(key, template32, jnp.arange(K), mask_tree)
    return _combine(jvps, vs, K)
