"""Forward-mode AD gradient estimation (paper §2, Eq. 1-3).

    jvp      = J_f(w) · v           — directional derivative along v
    grad_est = jvp * v              — unbiased estimator of ∇f for v~N(0,I)

K>1 perturbations are averaged (paper's ablation Fig. 5a). Perturbations are
regenerated from scalar seeds with ``jax.random.fold_in`` chains so the
server can rebuild any client's v exactly (per-iteration communication mode
sends only the jvp scalar back — Table 2).

Tangent-axis contract (this module's batched engine)
----------------------------------------------------
K perturbations are stacked on a leading *tangent axis*: a stacked
perturbation tree has leaves of shape ``(K,) + leaf.shape`` and the jvp
vector has shape ``(K,)``. The default path linearizes the loss once
(``jax.linearize``) and evaluates all K tangents through the linear map with
``jax.vmap`` — the frozen-base primal is computed ONCE per estimate instead
of K times (the paper's §5.3 "column-by-column jvp" overhead). Ops whose
inputs carry no tangent stay unbatched under vmap, so only tangent-carrying
intermediates gain the K axis.

On kernel backends the vmap does not stop at batched jnp ops: the dispatch
layer (kernels/dispatch.py) registers custom batching rules so that
vmap-of-tangents through a LoRA projection, an RWKV6 recurrence, or an SWA
attention block lowers DIRECTLY to the corresponding multi-tangent Pallas
kernel (``lora_dual_mt_tangents`` / ``wkv6_scan_mt_tangents`` /
``swa_attention_mt_tangents``) — the same leading-K tangent axis becomes
the kernel's T axis, and one pass over the primal operands serves all K
tangents in VMEM.

``tangent_batch`` trades that amortization against tangent-intermediate
memory (each tangent-carrying activation is K× wider):

    None / >=K  one batched pass (default; max primal amortization)
    1           the sequential fori_loop of full jax.jvp passes — zero
                stacked tangents, primal recomputed per perturbation
                (memory-constrained clients; the seed behaviour)
    1<b<K       K/b groups evaluated sequentially, b tangents per pass
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import forward_ad_region
from repro.utils.pytree import normal_like


def masked_perturbation(key, peft, mask_tree=None):
    """v ~ N(0, I) over the trainable tree, zeroed outside the client's
    assigned units (SPRY's weight splitting)."""
    v = normal_like(key, peft, dtype=jnp.float32)
    if mask_tree is not None:
        v = jax.tree.map(lambda vi, m: vi * m, v, mask_tree)
    return v


def stacked_perturbations(key, peft, indices, mask_tree=None):
    """Perturbations for ``fold_in(key, i) for i in indices`` stacked on a
    leading tangent axis. Bit-identical per index to ``masked_perturbation``
    (vmap of the PRNG chain is deterministic), which is what lets the server
    rebuild the client's exact tangents from the scalar seed."""
    return jax.vmap(
        lambda i: masked_perturbation(jax.random.fold_in(key, i), peft,
                                      mask_tree))(indices)


def _combine(jvps, vs, k_total):
    """g = (1/K) Σ_i jvps[i] · vs[i] — the estimator average, contracted over
    the tangent axis. Shared by the client estimator and the server-side
    reconstruction so the two are bit-identical (same ops, same inputs)."""
    return jax.tree.map(
        lambda v: jnp.tensordot(jvps, v, axes=[[0], [0]]) / k_total, vs)


def forward_gradient(loss_fn, peft, key, k_perturbations=1, mask_tree=None,
                     jvp_clip=None, tangent_batch=None):
    """Forward-gradient estimate of ∇_peft loss_fn.

    Returns (loss, grad_estimate, jvps (K,)). ``loss_fn`` must be a function
    of the peft tree only (base weights closed over).

    ``tangent_batch`` — see module docstring. The batched paths and the
    sequential path are numerically equivalent per seed (same perturbations,
    same jvp values) up to float reassociation of the K-average.

    ``jvp_clip`` (beyond-paper stabiliser): clamp the jvp scalar to
    [-c, c] before forming jvp*v — bounds the update magnitude of outlier
    perturbations (a biased but much lower-variance estimator; off by
    default, matches the paper exactly when None).
    """
    peft32 = jax.tree.map(lambda x: x.astype(jnp.float32), peft)
    K = int(k_perturbations)
    tb = K if tangent_batch is None else max(1, min(int(tangent_batch), K))

    def clip(jvps):
        if jvp_clip is not None:
            return jnp.clip(jvps, -jvp_clip, jvp_clip)
        return jvps

    if K == 1:
        # no tangent stacking needed — single dual-number pass
        v = masked_perturbation(jax.random.fold_in(key, 0), peft32, mask_tree)
        with forward_ad_region():
            loss, jvp = jax.jvp(loss_fn, (peft32,), (v,))
        jvps = clip(jnp.reshape(jvp, (1,)))
        vs = jax.tree.map(lambda x: x[None], v)
        return loss, _combine(jvps, vs, 1), jvps

    if tb == 1:
        # sequential fallback: one full jax.jvp pass per perturbation — no
        # stacked tangents and in-loop g accumulation (bounded memory), the
        # primal recomputed K times (the seed behaviour)
        def one(i, carry):
            g, jvps, loss_acc = carry
            ki = jax.random.fold_in(key, i)
            v = masked_perturbation(ki, peft32, mask_tree)
            with forward_ad_region():
                loss, jvp = jax.jvp(loss_fn, (peft32,), (v,))
            if jvp_clip is not None:
                jvp = jnp.clip(jvp, -jvp_clip, jvp_clip)
            g = jax.tree.map(lambda gi, vi: gi + jvp * vi, g, v)
            return g, jvps.at[i].set(jvp), loss_acc + loss

        g0 = jax.tree.map(jnp.zeros_like, peft32)
        g, jvps, loss = jax.lax.fori_loop(
            0, K, one,
            (g0, jnp.zeros((K,), jnp.float32), jnp.float32(0.0)))
        scale = 1.0 / K
        return loss * scale, jax.tree.map(lambda x: x * scale, g), jvps

    # batched: linearize once (one primal), push tangent groups through the
    # linear map with vmap — stacked-tangent jvp. (forward_ad_region lets
    # the dispatch layer lower LoRA tangents to the fused Pallas kernel —
    # the tangent jaxpr is fixed here at trace time, so later vmap replays
    # of tangent_map inherit it.)
    with forward_ad_region():
        loss, tangent_map = jax.linearize(loss_fn, peft32)

    if tb >= K:
        vs = stacked_perturbations(key, peft32, jnp.arange(K), mask_tree)
        jvps = clip(jax.vmap(tangent_map)(vs))
        return loss, _combine(jvps, vs, K), jvps

    # chunked: groups of tb tangents, sequential over groups (bounds the
    # stacked-tangent memory to tb× while still amortizing inside a group)
    n_groups, rem = divmod(K, tb)

    def group(start):
        vs_g = stacked_perturbations(key, peft32, start + jnp.arange(tb),
                                     mask_tree)
        return clip(jax.vmap(tangent_map)(vs_g)), vs_g

    # scan over full groups, accumulating the combine incrementally so the
    # stacked vs of only one group are live at a time
    g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), peft32)

    def scan_body(g_acc, start):
        jvps_g, vs_g = group(start)
        g_acc = jax.tree.map(jnp.add, g_acc, _combine(jvps_g, vs_g, K))
        return g_acc, jvps_g

    g, jvps_groups = jax.lax.scan(
        scan_body, g0, jnp.arange(n_groups) * tb)
    jvps = jvps_groups.reshape(-1)
    if rem:
        vs_r = stacked_perturbations(
            key, peft32, n_groups * tb + jnp.arange(rem), mask_tree)
        jvps_r = clip(jax.vmap(tangent_map)(vs_r))
        g = jax.tree.map(jnp.add, g, _combine(jvps_r, vs_r, K))
        jvps = jnp.concatenate([jvps, jvps_r])
    return loss, g, jvps


def reconstruct_gradient(peft_template, key, jvps, mask_tree=None):
    """Server-side gradient reconstruction from jvp scalars + the shared seed
    (per-iteration communication mode, paper §3.2). Regenerates the stacked
    perturbations and applies the same ``_combine`` contraction as the
    client-side estimator, so the rebuild is bit-identical to the client's
    estimate and its trace stays O(1) in K — enforced by
    tests/test_forward_grad.py."""
    K = jvps.shape[0]
    template32 = jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), peft_template)
    vs = stacked_perturbations(key, template32, jnp.arange(K), mask_tree)
    return _combine(jvps, vs, K)
