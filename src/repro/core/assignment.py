"""Layer-to-client assignment (paper §3.1, Alg. 1 ``MapLayersToClients``).

A *unit* is one trainable PEFT "layer" — e.g. one LoRA (A,B) pair at one
depth for one target matrix. Units are enumerated statically from the peft
tree structure; per-round masks are computed inside jit.

Cyclic rule (generalising the paper's rollover):
    for i in range(max(U, M)):  client (i+off) % M  <-  unit i % U
so every unit is trained each round; when U > M clients get multiple units,
when M > U units get multiple clients (M-tilde > 1). ``off`` rotates with the
round index so coverage is symmetric over time. The classifier head (paper's
personalisation layers) is always assigned to every client.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class UnitIndex:
    """Static description of the trainable units of a peft tree."""
    units: Tuple[Tuple[str, str, int], ...]   # (group, target, layer) ; layer=-1 unstacked
    spans: dict                                # (group, target) -> (start, length, stacked)

    @property
    def n_units(self) -> int:
        return len(self.units)


def enumerate_units(peft) -> UnitIndex:
    units: List[Tuple[str, str, int]] = []
    spans = {}
    for group in sorted(peft.keys()):
        if group == "head":
            continue  # trained by all clients
        gtree = peft[group]
        for target in sorted(gtree.keys()):
            leaves = jax.tree.leaves(gtree[target])
            first = leaves[0]
            # stacked groups carry a leading layer axis
            stacked = group in ("layers", "enc_layers") and first.ndim >= 2
            start = len(units)
            if stacked:
                L = first.shape[0]
                units.extend((group, target, i) for i in range(L))
                spans[(group, target)] = (start, L, True)
            else:
                units.append((group, target, -1))
                spans[(group, target)] = (start, 1, False)
    return UnitIndex(tuple(units), spans)


def assignment_matrix(n_units: int, n_clients: int, round_offset):
    """(M, U) float mask, computed with jnp ops (round_offset may be traced)."""
    U, M = n_units, n_clients
    n = max(U, M)
    i = jnp.arange(n)
    client = (i + round_offset) % M                    # (n,)
    unit = i % U
    mask = jnp.zeros((M, U), jnp.float32)
    mask = mask.at[client, unit].max(1.0)
    return mask


def client_counts(mask_matrix):
    """M-tilde per unit: number of clients training each unit."""
    return jnp.maximum(mask_matrix.sum(axis=0), 1.0)


def build_mask_tree(peft, index: UnitIndex, mask_rows):
    """Expand assignment rows into a peft-shaped mask tree.

    mask_rows: (U,) for one client, or (M, U) under vmap (pass one row).
    Leaves get shape (L, 1, 1, ...) broadcastable against the stacked params.
    """
    out = {}
    for group in peft:
        if group == "head":
            out[group] = jax.tree.map(lambda x: jnp.ones((), jnp.float32),
                                      peft[group])
            continue
        gout = {}
        for target in peft[group]:
            start, length, stacked = index.spans[(group, target)]
            seg = jax.lax.dynamic_slice_in_dim(mask_rows, start, length, axis=-1)

            def leaf_mask(leaf, seg=seg, stacked=stacked):
                if stacked:
                    extra = (1,) * (leaf.ndim - 1)
                    return seg.reshape(seg.shape[-1:] + extra)
                return seg.reshape(())

            gout[target] = jax.tree.map(leaf_mask, peft[group][target])
        out[group] = gout
    return out
