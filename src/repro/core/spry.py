"""SPRY round step (paper Alg. 1) as a single jittable function.

One call = one FL round:
  1. cyclic unit->client assignment masks (assignment.py)
  2. per-client seeded perturbations + forward-gradient local training,
     vmapped over the M simulated clients (client m sees only its own
     minibatch slice and perturbs only its assigned units)
  3. weighted-union aggregation of the per-unit deltas (clients that share a
     unit are averaged, FedAvg-style)
  4. adaptive server update (FedYogi default) on the effective gradient

The same function lowers for the production mesh: the client axis (M) and
per-client batch are sharded over ('pod','data'); base weights are
tensor/2D-sharded over ('model' [, 'data']). See launch/.

The round is decomposed into named pieces so the federation runtime
(fl/runtime/) can execute the SAME math through explicit messages and a
device-parallel cohort executor instead of one in-process vmap:

  make_client_update_fn   per-epoch client: local forward-gradient SGD,
                          returns the masked delta (the wire payload)
  make_client_jvp_fn      per-iteration client: one estimate, returns the
                          K jvp scalars (the wire payload)
  make_rebuild_fn         per-iteration server side: regenerate the
                          perturbations from the seed chain and rebuild the
                          client's gradient from its jvp scalars
  make_count_tree         per-unit client-count divisor tree (head counted
                          by every participating client)
  aggregate_payloads      weighted-union average of stacked client payloads

``make_round_step`` / ``make_round_step_per_iteration`` compose exactly
these pieces; the runtime's ideal path (full participation, no wire
quantization, whole-cohort executor) is bit-identical by construction —
asserted in tests/test_runtime.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.assignment import (
    assignment_matrix,
    build_mask_tree,
    client_counts,
    enumerate_units,
)
from repro.core.forward_grad import forward_gradient, reconstruct_gradient
from repro.fl.server import ServerState, server_init, server_update
from repro.models.registry import get_loss_fn
from repro.utils.pytree import tree_cast


class SpryState(NamedTuple):
    base: Any
    peft: Any
    server: ServerState
    round_idx: jnp.ndarray


def init_state(base, peft) -> SpryState:
    peft32 = tree_cast(peft, jnp.float32)
    return SpryState(base, peft32, server_init(peft32), jnp.zeros([], jnp.int32))


# ---------------------------------------------------------------------------
# Client-side pieces
# ---------------------------------------------------------------------------

def make_task_loss(cfg, spry_cfg, task, base, batch):
    """The client objective as a function of the peft tree only. With
    ``spry_cfg.fused_contraction`` the registry's SplitLoss builder is used
    (the final mixer site is declared, so the estimator runs the in-kernel
    jvp-contraction epilogues); otherwise the plain closure. Both trace the
    identical loss program — the split is a capability, not a numerics
    change."""
    if spry_cfg.fused_contraction:
        return get_loss_fn(task, split=True)(
            cfg, base, batch, lora_scale=spry_cfg.lora_alpha)
    loss_fn_kind = get_loss_fn(task)

    def loss_of(p):
        return loss_fn_kind(cfg, base, p, batch,
                            lora_scale=spry_cfg.lora_alpha)
    return loss_of


def estimator_route(spry_cfg) -> str:
    """The gradient-estimator route the client fns take ('fused' = in-kernel
    jvp-contraction at the final mixer site; 'standard' = materialize tangent
    outputs then contract). Surfaced in round metrics / train-loop logs."""
    return "fused" if spry_cfg.fused_contraction else "standard"


def run_fields(spry_cfg) -> dict:
    """Static estimator facts stamped on run artifacts (telemetry
    ``run_meta`` events, report headers): the active route plus the knobs
    that select it."""
    return {
        "route": estimator_route(spry_cfg),
        "k_perturbations": int(spry_cfg.k_perturbations),
        "tangent_batch": (int(spry_cfg.tangent_batch)
                          if spry_cfg.tangent_batch is not None else None),
        "local_iters": int(spry_cfg.local_iters),
        "local_lr": float(spry_cfg.local_lr),
        "server_lr": float(spry_cfg.server_lr),
    }


def make_client_update_fn(cfg, spry_cfg, task: str = "cls"):
    """Per-epoch client computation (paper Alg. 1 lines 6-13).

    Returns ``client_update(base, peft, round_key, seed_id, mask_row,
    client_batch) -> (delta, loss_mean, jvps)``:
    ``spry_cfg.local_iters`` steps of forward-gradient SGD on the units
    selected by ``mask_row``, starting from the server ``peft``. ``seed_id``
    is the client's position in the round (the fold_in chain the server
    shares), ``delta`` the masked weight change — the per-epoch wire payload.
    """
    K = spry_cfg.k_perturbations
    lr_l = spry_cfg.local_lr

    def client_update(base, peft, round_key, seed_id, mask_row, client_batch):
        index = enumerate_units(peft)
        mask_tree = build_mask_tree(peft, index, mask_row)
        ckey = jax.random.fold_in(round_key, seed_id)
        mb = spry_cfg.microbatch_size

        def grad_of(peft_c, ikey):
            if mb is None or mb >= client_batch["tokens"].shape[0]:
                loss_of = make_task_loss(cfg, spry_cfg, task, base,
                                         client_batch)
                return forward_gradient(
                    loss_of, peft_c, ikey, k_perturbations=K,
                    mask_tree=mask_tree, jvp_clip=spry_cfg.jvp_clip,
                    tangent_batch=spry_cfg.tangent_batch,
                    fused_contraction=spry_cfg.fused_contraction)
            # gradient accumulation: scan over microbatches, fresh
            # perturbation per microbatch (each estimate is unbiased for
            # its microbatch gradient; the average is unbiased for the
            # full-batch gradient), bounded activation memory
            B = client_batch["tokens"].shape[0]
            n_mb = B // mb
            mb_batch = jax.tree.map(
                lambda x: x[: n_mb * mb].reshape((n_mb, mb) + x.shape[1:]),
                client_batch)

            def mb_step(acc, xs):
                i, one = xs
                loss_of = make_task_loss(cfg, spry_cfg, task, base, one)
                loss, g, jvps = forward_gradient(
                    loss_of, peft_c, jax.random.fold_in(ikey, i),
                    k_perturbations=K, mask_tree=mask_tree,
                    jvp_clip=spry_cfg.jvp_clip,
                    tangent_batch=spry_cfg.tangent_batch,
                    fused_contraction=spry_cfg.fused_contraction)
                g_acc, loss_acc = acc
                g_acc = jax.tree.map(lambda a, b: a + b / n_mb, g_acc, g)
                return (g_acc, loss_acc + loss / n_mb), jvps

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                              peft_c)
            (g, loss), jvps = jax.lax.scan(
                mb_step, (g0, jnp.float32(0.0)),
                (jnp.arange(n_mb), mb_batch))
            return loss, g, jvps.reshape(-1)[:K]

        def local_iter(carry, it):
            peft_c = carry
            ikey = jax.random.fold_in(ckey, it)
            loss, g, jvps = grad_of(peft_c, ikey)
            # local SGD on assigned units only (mask already zeroes g
            # outside the assignment, incl. the always-on head)
            peft_c = jax.tree.map(lambda p, gi: p - lr_l * gi, peft_c, g)
            return peft_c, (loss, jvps)

        peft_c, (losses, jvps) = jax.lax.scan(
            local_iter, peft, jnp.arange(spry_cfg.local_iters))
        delta = jax.tree.map(lambda a, b: a - b, peft_c, peft)
        return delta, losses.mean(), jvps

    return client_update


def make_client_jvp_fn(cfg, spry_cfg, task: str = "cls"):
    """Per-iteration client computation (paper §3.2): one forward-jvp at the
    current server weights; the K jvp scalars are the entire uplink payload.

    Returns ``client_jvp(base, peft, round_key, seed_id, mask_row,
    client_batch) -> (loss, jvps)``.
    """
    K = spry_cfg.k_perturbations

    def client_jvp(base, peft, round_key, seed_id, mask_row, client_batch):
        index = enumerate_units(peft)
        mask_tree = build_mask_tree(peft, index, mask_row)
        ckey = jax.random.fold_in(round_key, seed_id)
        ikey = jax.random.fold_in(ckey, 0)
        loss_of = make_task_loss(cfg, spry_cfg, task, base, client_batch)

        loss, _, jvps = forward_gradient(
            loss_of, peft, ikey, k_perturbations=K, mask_tree=mask_tree,
            jvp_clip=spry_cfg.jvp_clip,
            tangent_batch=spry_cfg.tangent_batch,
            fused_contraction=spry_cfg.fused_contraction)
        return loss, jvps

    return client_jvp


def make_rebuild_fn():
    """Server-side per-iteration gradient rebuild: regenerate v from the seed
    chain and combine with the client's jvp scalars (bit-identical to the
    client estimate, see forward_grad.reconstruct_gradient).

    Returns ``rebuild(peft, round_key, seed_id, mask_row, jvps) -> grad``.
    """
    def rebuild(peft, round_key, seed_id, mask_row, jvps):
        index = enumerate_units(peft)
        mask_tree = build_mask_tree(peft, index, mask_row)
        ckey = jax.random.fold_in(round_key, seed_id)
        ikey = jax.random.fold_in(ckey, 0)
        return reconstruct_gradient(peft, ikey, jvps, mask_tree)

    return rebuild


# ---------------------------------------------------------------------------
# Aggregation pieces
# ---------------------------------------------------------------------------

def make_count_tree(peft, index, counts, head_count):
    """Per-unit divisor tree: M-tilde per LoRA unit (``counts``, shape (U,)),
    the participating-client count for the always-on head."""
    count_tree = build_mask_tree(peft, index, counts)
    return {
        g: (jax.tree.map(lambda x: jnp.full_like(x, head_count), count_tree[g])
            if g == "head" else count_tree[g])
        for g in count_tree
    }


def aggregate_payloads(peft, index, stacked, counts, head_count):
    """Weighted-union average of stacked per-client payload trees.

    ``stacked`` leaves carry a leading client axis; clients that share a unit
    are averaged FedAvg-style (sum over clients / per-unit count).
    """
    count_tree = make_count_tree(peft, index, counts, head_count)
    return jax.tree.map(lambda leaf, c: leaf.sum(0) / c, stacked, count_tree)


# ---------------------------------------------------------------------------
# In-process round steps (one vmap over the M simulated clients)
# ---------------------------------------------------------------------------

def make_round_step(cfg, spry_cfg, task: str = "cls", split: bool = True):
    """Build the jittable round_step(state, batch) -> (state, metrics).

    batch: {"tokens": (M, B, S), ...} — leading axis = simulated clients.
    split=False disables the paper's weight splitting (the FedFGD ablation:
    every client perturbs ALL trainable units).
    """
    M = spry_cfg.n_clients_per_round
    client_update = make_client_update_fn(cfg, spry_cfg, task)

    def round_step(state: SpryState, batch):
        base, peft = state.base, state.peft
        index = enumerate_units(peft)
        if split:
            mask_matrix = assignment_matrix(index.n_units, M,
                                            state.round_idx % M)
        else:
            mask_matrix = jnp.ones((M, index.n_units), jnp.float32)
        counts = client_counts(mask_matrix)                      # (U,)
        round_key = jax.random.fold_in(
            jax.random.PRNGKey(spry_cfg.seed), state.round_idx)

        deltas, losses, jvps = jax.vmap(
            lambda sid, row, cb: client_update(base, peft, round_key, sid,
                                               row, cb))(
            jnp.arange(M), mask_matrix, batch)

        # --- weighted union over clients (paper: FedAvg-style average over
        # the clients assigned to each unit; head trained by all M) ---
        delta = aggregate_payloads(peft, index, deltas, counts, M)

        new_peft, server = server_update(
            spry_cfg.server_opt, peft, delta, state.server,
            lr=spry_cfg.server_lr)
        metrics = {
            "loss": losses.mean(),
            "jvp_abs_mean": jnp.abs(jvps).mean(),
            "delta_norm": jnp.sqrt(sum(jnp.sum(d * d) for d in jax.tree.leaves(delta))),
            # active estimator route (1.0 = fused jvp-contraction epilogues
            # at the final mixer site, 0.0 = standard materializing route)
            "fused_route": jnp.float32(spry_cfg.fused_contraction),
        }
        return SpryState(base, new_peft, server, state.round_idx + 1), metrics

    return round_step


# ---------------------------------------------------------------------------
# Per-iteration communication mode (paper §3.2): clients send back only the
# jvp scalar; the server regenerates the perturbations from the shared seed
# and applies the global update directly.
# ---------------------------------------------------------------------------

def make_round_step_per_iteration(cfg, spry_cfg, task: str = "cls"):
    M = spry_cfg.n_clients_per_round
    client_jvp = make_client_jvp_fn(cfg, spry_cfg, task)
    rebuild = make_rebuild_fn()

    def round_step(state: SpryState, batch):
        base, peft = state.base, state.peft
        index = enumerate_units(peft)
        mask_matrix = assignment_matrix(index.n_units, M, state.round_idx % M)
        counts = client_counts(mask_matrix)
        round_key = jax.random.fold_in(
            jax.random.PRNGKey(spry_cfg.seed), state.round_idx)

        # --- client side: one forward-jvp, transmit K scalars ---
        losses, jvps = jax.vmap(
            lambda sid, row, cb: client_jvp(base, peft, round_key, sid, row,
                                            cb))(
            jnp.arange(M), mask_matrix, batch)        # (M,), (M,K)

        # --- server side: regenerate v from the seed, rebuild gradients
        # (stacked-perturbation path, bit-identical to the client estimator
        # and O(1) trace size in K) ---
        grads = jax.vmap(
            lambda sid, row, jv: rebuild(peft, round_key, sid, row, jv))(
            jnp.arange(M), mask_matrix, jvps)
        grad = aggregate_payloads(peft, index, grads, counts, M)
        # server applies the *gradient direction* with its adaptive optimizer
        delta = jax.tree.map(lambda g: -spry_cfg.local_lr * g, grad)
        new_peft, server = server_update(
            spry_cfg.server_opt, peft, delta, state.server,
            lr=spry_cfg.server_lr)
        metrics = {"loss": losses.mean(), "jvp_abs_mean": jnp.abs(jvps).mean(),
                   "fused_route": jnp.float32(spry_cfg.fused_contraction)}
        return SpryState(base, new_peft, server, state.round_idx + 1), metrics

    return round_step
