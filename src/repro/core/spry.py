"""SPRY round step (paper Alg. 1) as a single jittable function.

One call = one FL round:
  1. cyclic unit->client assignment masks (assignment.py)
  2. per-client seeded perturbations + forward-gradient local training,
     vmapped over the M simulated clients (client m sees only its own
     minibatch slice and perturbs only its assigned units)
  3. weighted-union aggregation of the per-unit deltas (clients that share a
     unit are averaged, FedAvg-style)
  4. adaptive server update (FedYogi default) on the effective gradient

The same function lowers for the production mesh: the client axis (M) and
per-client batch are sharded over ('pod','data'); base weights are
tensor/2D-sharded over ('model' [, 'data']). See launch/.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.assignment import (
    assignment_matrix,
    build_mask_tree,
    client_counts,
    enumerate_units,
)
from repro.core.forward_grad import forward_gradient, reconstruct_gradient
from repro.fl.server import ServerState, server_init, server_update
from repro.models.registry import get_loss_fn
from repro.utils.pytree import tree_cast


class SpryState(NamedTuple):
    base: Any
    peft: Any
    server: ServerState
    round_idx: jnp.ndarray


def init_state(base, peft) -> SpryState:
    peft32 = tree_cast(peft, jnp.float32)
    return SpryState(base, peft32, server_init(peft32), jnp.zeros([], jnp.int32))


def make_round_step(cfg, spry_cfg, task: str = "cls", split: bool = True):
    """Build the jittable round_step(state, batch) -> (state, metrics).

    batch: {"tokens": (M, B, S), ...} — leading axis = simulated clients.
    split=False disables the paper's weight splitting (the FedFGD ablation:
    every client perturbs ALL trainable units).
    """
    loss_fn_kind = get_loss_fn(task)
    M = spry_cfg.n_clients_per_round
    K = spry_cfg.k_perturbations
    lr_l = spry_cfg.local_lr

    def round_step(state: SpryState, batch):
        base, peft = state.base, state.peft
        index = enumerate_units(peft)
        if split:
            mask_matrix = assignment_matrix(index.n_units, M,
                                            state.round_idx % M)
        else:
            mask_matrix = jnp.ones((M, index.n_units), jnp.float32)
        counts = client_counts(mask_matrix)                      # (U,)
        round_key = jax.random.fold_in(
            jax.random.PRNGKey(spry_cfg.seed), state.round_idx)

        def client_update(client_id, mask_row, client_batch):
            mask_tree = build_mask_tree(peft, index, mask_row)
            ckey = jax.random.fold_in(round_key, client_id)
            mb = spry_cfg.microbatch_size

            def grad_of(peft_c, ikey):
                if mb is None or mb >= client_batch["tokens"].shape[0]:
                    def loss_of(p):
                        return loss_fn_kind(cfg, base, p, client_batch,
                                            lora_scale=spry_cfg.lora_alpha)
                    return forward_gradient(loss_of, peft_c, ikey,
                                            k_perturbations=K,
                                            mask_tree=mask_tree,
                                            jvp_clip=spry_cfg.jvp_clip,
                                            tangent_batch=spry_cfg.tangent_batch)
                # gradient accumulation: scan over microbatches, fresh
                # perturbation per microbatch (each estimate is unbiased for
                # its microbatch gradient; the average is unbiased for the
                # full-batch gradient), bounded activation memory
                B = client_batch["tokens"].shape[0]
                n_mb = B // mb
                mb_batch = jax.tree.map(
                    lambda x: x[: n_mb * mb].reshape((n_mb, mb) + x.shape[1:]),
                    client_batch)

                def mb_step(acc, xs):
                    i, one = xs
                    def loss_of(p):
                        return loss_fn_kind(cfg, base, p, one,
                                            lora_scale=spry_cfg.lora_alpha)
                    loss, g, jvps = forward_gradient(
                        loss_of, peft_c, jax.random.fold_in(ikey, i),
                        k_perturbations=K, mask_tree=mask_tree,
                        jvp_clip=spry_cfg.jvp_clip,
                        tangent_batch=spry_cfg.tangent_batch)
                    g_acc, loss_acc = acc
                    g_acc = jax.tree.map(lambda a, b: a + b / n_mb, g_acc, g)
                    return (g_acc, loss_acc + loss / n_mb), jvps

                g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                  peft_c)
                (g, loss), jvps = jax.lax.scan(
                    mb_step, (g0, jnp.float32(0.0)),
                    (jnp.arange(n_mb), mb_batch))
                return loss, g, jvps.reshape(-1)[:K]

            def local_iter(carry, it):
                peft_c = carry
                ikey = jax.random.fold_in(ckey, it)
                loss, g, jvps = grad_of(peft_c, ikey)
                # local SGD on assigned units only (mask already zeroes g
                # outside the assignment, incl. the always-on head)
                peft_c = jax.tree.map(lambda p, gi: p - lr_l * gi, peft_c, g)
                return peft_c, (loss, jvps)

            peft_c, (losses, jvps) = jax.lax.scan(
                local_iter, peft, jnp.arange(spry_cfg.local_iters))
            delta = jax.tree.map(lambda a, b: a - b, peft_c, peft)
            return delta, losses.mean(), jvps

        deltas, losses, jvps = jax.vmap(client_update)(
            jnp.arange(M), mask_matrix, batch)

        # --- weighted union over clients (paper: FedAvg-style average over
        # the clients assigned to each unit) ---
        def agg(leaf_deltas, mask_leaf_count):
            # leaf_deltas: (M, ...); sum over clients / count per unit
            return leaf_deltas.sum(0) / mask_leaf_count

        count_tree = build_mask_tree(peft, index, counts)
        # head is trained by all M clients
        count_tree = {
            g: (jax.tree.map(lambda x: jnp.full_like(x, M), count_tree[g])
                if g == "head" else count_tree[g])
            for g in count_tree
        }
        delta = jax.tree.map(agg, deltas, count_tree)

        new_peft, server = server_update(
            spry_cfg.server_opt, peft, delta, state.server,
            lr=spry_cfg.server_lr)
        metrics = {
            "loss": losses.mean(),
            "jvp_abs_mean": jnp.abs(jvps).mean(),
            "delta_norm": jnp.sqrt(sum(jnp.sum(d * d) for d in jax.tree.leaves(delta))),
        }
        return SpryState(base, new_peft, server, state.round_idx + 1), metrics

    return round_step


# ---------------------------------------------------------------------------
# Per-iteration communication mode (paper §3.2): clients send back only the
# jvp scalar; the server regenerates the perturbations from the shared seed
# and applies the global update directly.
# ---------------------------------------------------------------------------

def make_round_step_per_iteration(cfg, spry_cfg, task: str = "cls"):
    loss_fn_kind = get_loss_fn(task)
    M = spry_cfg.n_clients_per_round
    K = spry_cfg.k_perturbations

    def round_step(state: SpryState, batch):
        base, peft = state.base, state.peft
        index = enumerate_units(peft)
        mask_matrix = assignment_matrix(index.n_units, M, state.round_idx % M)
        counts = client_counts(mask_matrix)
        round_key = jax.random.fold_in(
            jax.random.PRNGKey(spry_cfg.seed), state.round_idx)

        # --- client side: one forward-jvp, transmit K scalars ---
        def client_jvp(client_id, mask_row, client_batch):
            mask_tree = build_mask_tree(peft, index, mask_row)
            ckey = jax.random.fold_in(round_key, client_id)
            ikey = jax.random.fold_in(ckey, 0)

            def loss_of(p):
                return loss_fn_kind(cfg, base, p, client_batch,
                                    lora_scale=spry_cfg.lora_alpha)

            loss, _, jvps = forward_gradient(
                loss_of, peft, ikey, k_perturbations=K, mask_tree=mask_tree,
                jvp_clip=spry_cfg.jvp_clip,
                tangent_batch=spry_cfg.tangent_batch)
            return loss, jvps

        losses, jvps = jax.vmap(client_jvp)(
            jnp.arange(M), mask_matrix, batch)        # (M,), (M,K)

        # --- server side: regenerate v from the seed, rebuild gradients
        # (stacked-perturbation path, bit-identical to the client estimator
        # and O(1) trace size in K) ---
        def rebuild(client_id, mask_row, jvps_m):
            mask_tree = build_mask_tree(peft, index, mask_row)
            ckey = jax.random.fold_in(round_key, client_id)
            ikey = jax.random.fold_in(ckey, 0)
            return reconstruct_gradient(peft, ikey, jvps_m, mask_tree)

        grads = jax.vmap(rebuild)(jnp.arange(M), mask_matrix, jvps)
        count_tree = build_mask_tree(peft, index, counts)
        count_tree = {
            g: (jax.tree.map(lambda x: jnp.full_like(x, M), count_tree[g])
                if g == "head" else count_tree[g])
            for g in count_tree
        }
        grad = jax.tree.map(lambda gm, c: gm.sum(0) / c, grads, count_tree)
        # server applies the *gradient direction* with its adaptive optimizer
        delta = jax.tree.map(lambda g: -spry_cfg.local_lr * g, grad)
        new_peft, server = server_update(
            spry_cfg.server_opt, peft, delta, state.server,
            lr=spry_cfg.server_lr)
        metrics = {"loss": losses.mean(), "jvp_abs_mean": jnp.abs(jvps).mean()}
        return SpryState(base, new_peft, server, state.round_idx + 1), metrics

    return round_step
