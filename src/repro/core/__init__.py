"""SPRY — the paper's primary contribution.

forward_grad.py : jvp gradient estimator + seed-synchronised reconstruction
assignment.py   : cyclic trainable-layer -> client splitting (Alg. 1)
spry.py         : jittable FL round step (per-epoch & per-iteration modes)
baselines/      : backprop (FedAvg/FedYogi/FedSGD[,Split]) and zero-order
                  (FedMeZO/BAFFLE+/FwdLLM+) counterparts
"""
from repro.core.forward_grad import (
    forward_gradient,
    masked_perturbation,
    reconstruct_gradient,
    stacked_perturbations,
)
from repro.core.assignment import (
    UnitIndex,
    assignment_matrix,
    build_mask_tree,
    client_counts,
    enumerate_units,
)
from repro.core.spry import (
    SpryState,
    aggregate_payloads,
    estimator_route,
    init_state,
    make_client_jvp_fn,
    make_client_update_fn,
    make_count_tree,
    make_rebuild_fn,
    make_round_step,
    make_round_step_per_iteration,
    make_task_loss,
    run_fields,
)
