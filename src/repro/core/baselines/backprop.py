"""Backpropagation-based FL baselines: FedAvg / FedYogi / FedSGD, plus the
paper's FedAvgSplit ablation (layer splitting applied to backprop).

Same skeleton as core/spry.py, but clients compute exact gradients with
jax.grad (reverse-mode -> full activation stack, which is precisely the
memory cost the paper's Fig. 2 measures against).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.assignment import (
    assignment_matrix,
    build_mask_tree,
    client_counts,
    enumerate_units,
)
from repro.fl.server import server_init, server_update
from repro.models.registry import get_loss_fn
from repro.optim import adamw, sgd
from repro.optim.optimizers import apply_updates
from repro.utils.pytree import tree_cast

from repro.core.spry import SpryState, init_state  # shared state container


def make_backprop_round_step(cfg, spry_cfg, task: str = "cls",
                             method: str = "fedavg", split: bool = False):
    """method: fedavg | fedyogi | fedsgd. split=True -> FedAvgSplit ablation."""
    loss_fn_kind = get_loss_fn(task)
    M = spry_cfg.n_clients_per_round
    server_kind = {"fedavg": "fedavg", "fedsgd": "fedsgd",
                   "fedyogi": "fedyogi"}[method]
    if spry_cfg.client_opt == "adamw":
        client_opt = adamw(spry_cfg.local_lr)
    else:
        client_opt = sgd(spry_cfg.local_lr)

    def round_step(state: SpryState, batch):
        base, peft = state.base, state.peft
        index = enumerate_units(peft)
        if split:
            mask_matrix = assignment_matrix(index.n_units, M,
                                            state.round_idx % M)
        else:
            mask_matrix = jnp.ones((M, index.n_units), jnp.float32)
        counts = client_counts(mask_matrix)

        def client_update(mask_row, client_batch):
            mask_tree = build_mask_tree(peft, index, mask_row)

            def loss_of(p):
                return loss_fn_kind(cfg, base, p, client_batch,
                                    lora_scale=spry_cfg.lora_alpha)

            def local_iter(carry, _):
                peft_c, opt_state = carry
                loss, g = jax.value_and_grad(loss_of)(peft_c)
                g = jax.tree.map(lambda gi, m: gi * m, g, mask_tree)
                updates, opt_state = client_opt.update(g, opt_state, peft_c)
                peft_c = apply_updates(peft_c, updates)
                return (peft_c, opt_state), loss

            (peft_c, _), losses = jax.lax.scan(
                local_iter, (peft, client_opt.init(peft)),
                None, length=spry_cfg.local_iters)
            delta = jax.tree.map(lambda a, b: a - b, peft_c, peft)
            return delta, losses.mean()

        deltas, losses = jax.vmap(client_update)(mask_matrix, batch)

        count_tree = build_mask_tree(peft, index, counts)
        count_tree = {
            g: (jax.tree.map(lambda x: jnp.full_like(x, M), count_tree[g])
                if g == "head" else count_tree[g])
            for g in count_tree
        }
        delta = jax.tree.map(lambda dm, c: dm.sum(0) / c, deltas, count_tree)
        lr = 1.0 if server_kind in ("fedavg", "fedsgd") else spry_cfg.server_lr
        new_peft, server = server_update(server_kind, peft, delta,
                                         state.server, lr=lr)
        metrics = {"loss": losses.mean()}
        return SpryState(base, new_peft, server, state.round_idx + 1), metrics

    return round_step
