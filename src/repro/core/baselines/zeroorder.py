"""Zero-order (finite-difference) FL baselines: FedMeZO, BAFFLE+, FwdLLM+.

All use the *memory-improved* variants the paper builds (perturbing only the
trainable PEFT weights):

  FedMeZO : 1 central-difference perturbation per batch (MeZO seeded regen)
  BAFFLE+ : K (default 20) perturbations averaged
  FwdLLM+ : K candidates; keep the one whose direction best matches the
            previous round's aggregated gradient (cosine similarity), and
            discard clients whose gradient variance exceeds a threshold.

Finite differences introduce truncation + round-off error — the property the
paper contrasts with exact forward-mode jvp. These baselines exist so the
convergence/accuracy comparisons (Table 1, Fig. 3) are runnable end-to-end.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.spry import SpryState
from repro.fl.server import server_update
from repro.models.registry import get_loss_fn
from repro.utils.pytree import normal_like, tree_dot, tree_norm

ZO_DEFAULTS = {
    "fedmezo": dict(k=1, eps=1e-3),
    "baffle": dict(k=20, eps=1e-4),
    "fwdllm": dict(k=10, eps=1e-2, var_threshold=10.0),
}


class ZOState(NamedTuple):
    inner: SpryState
    prev_grad: Any          # FwdLLM's guidance direction


def init_zo_state(state: SpryState) -> ZOState:
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), state.peft)
    return ZOState(state, zeros)


def _central_difference(loss_fn, peft, v, eps):
    """(f(w+eps v) - f(w-eps v)) / (2 eps) — two forward passes."""
    plus = jax.tree.map(lambda p, vi: p + eps * vi, peft, v)
    minus = jax.tree.map(lambda p, vi: p - eps * vi, peft, v)
    return (loss_fn(plus) - loss_fn(minus)) / (2.0 * eps)


def make_zeroorder_round_step(cfg, spry_cfg, task: str = "cls",
                              method: str = "fedmezo", **overrides):
    loss_fn_kind = get_loss_fn(task)
    M = spry_cfg.n_clients_per_round
    hp = dict(ZO_DEFAULTS[method])
    hp.update(overrides)
    K, eps = hp["k"], hp["eps"]

    def round_step(zo_state: ZOState, batch):
        state = zo_state.inner
        base, peft = state.base, state.peft
        round_key = jax.random.fold_in(
            jax.random.PRNGKey(spry_cfg.seed), state.round_idx)

        def client_update(client_id, client_batch):
            ckey = jax.random.fold_in(round_key, client_id)

            def loss_of(p):
                return loss_fn_kind(cfg, base, p, client_batch,
                                    lora_scale=spry_cfg.lora_alpha)

            def one(i):
                v = normal_like(jax.random.fold_in(ckey, i), peft,
                                dtype=jnp.float32)
                fd = _central_difference(loss_of, peft, v, eps)
                return v, fd

            if method == "fwdllm":
                # pick the candidate best aligned with last round's gradient
                def cand(i):
                    v, fd = one(i)
                    g = jax.tree.map(lambda vi: fd * vi, v)
                    cos = tree_dot(g, zo_state.prev_grad) / (
                        tree_norm(g) * tree_norm(zo_state.prev_grad) + 1e-9)
                    return g, cos, fd

                gs, coss, fds = [], [], []
                for i in range(K):
                    g, cos, fd = cand(i)
                    gs.append(g)
                    coss.append(cos)
                    fds.append(fd)
                coss = jnp.stack(coss)
                best = jnp.argmax(coss)
                g = jax.tree.map(
                    lambda *leaves: jnp.stack(leaves)[best], *gs)
                fd_var = jnp.var(jnp.stack(fds))
                # variance filter: zero the client's contribution if noisy
                keep = (fd_var < hp["var_threshold"]).astype(jnp.float32)
                g = jax.tree.map(lambda x: x * keep, g)
            else:
                g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), peft)
                for i in range(K):
                    v, fd = one(i)
                    g = jax.tree.map(lambda gi, vi: gi + fd * vi / K, g, v)

            loss = loss_of(peft)
            delta = jax.tree.map(lambda gi: -spry_cfg.local_lr * gi, g)
            return delta, loss, g

        deltas, losses, grads = jax.vmap(client_update)(
            jnp.arange(M), batch)
        delta = jax.tree.map(lambda d: d.mean(0), deltas)
        grad_mean = jax.tree.map(lambda g: g.mean(0), grads)
        new_peft, server = server_update(
            spry_cfg.server_opt, peft, delta, state.server,
            lr=spry_cfg.server_lr)
        new_inner = SpryState(base, new_peft, server, state.round_idx + 1)
        return ZOState(new_inner, grad_mean), {"loss": losses.mean()}

    return round_step
