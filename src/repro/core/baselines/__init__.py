from repro.core.baselines.backprop import make_backprop_round_step
from repro.core.baselines.zeroorder import make_zeroorder_round_step
