"""Pallas TPU kernels for the compute hot-spots of SPRY finetuning.

lora_dual/     fused LoRA primal+tangent matmul — the forward-mode AD
               hot-spot (paper §5.3 jvp overhead, removed on TPU by fusing
               tangent propagation into the same VMEM-resident pass). The
               multi-tangent (mt) variants stack K tangents on a leading
               axis so ONE pass over x/W serves the primal and all K jvp
               columns (the batched K-perturbation estimator's hot loop).
dispatch.py    backend routing for the fused LoRA projection: models'
               ``proj`` differentiates through the Pallas kernel on TPU and
               the jnp reference mirror on CPU (REPRO_LORA_BACKEND override).
swa_attention/ sliding-window flash attention (gemma3 / h2o-danube / zamba2)
wkv6_scan/     RWKV6 data-dependent-decay recurrence, block-parallel over
               (batch, heads)
mamba2_scan/   Mamba2 state recurrence (zamba2 hybrid blocks), same
               tangent-state-scratch design as wkv6_scan

Every family also ships a ``*_mt_jvps`` contraction epilogue (lora / wkv6 /
swa): when the estimator knows the site's output cotangent gy, the T
tangent outputs are contracted against it blockwise in VMEM and never
written to HBM — see dispatch.py "Cotangent-known route".

Each kernel ships ops.py (jit'd dispatch wrapper) and ref.py (pure-jnp
oracle). Tests sweep shapes/dtypes in interpret mode (CPU) and assert
allclose against the oracle; real-TPU deployment flips interpret=False.
"""
