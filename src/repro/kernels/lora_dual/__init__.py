from repro.kernels.lora_dual.ops import (
    lora_dual,
    lora_dual_mt,
    lora_dual_mt_jvps,
    lora_dual_mt_tangents,
    lora_dual_multi,
)
from repro.kernels.lora_dual.ref import (
    lora_dual_mt_jvps_ref,
    lora_dual_mt_ref,
    lora_dual_multi_ref,
    lora_dual_ref,
)
