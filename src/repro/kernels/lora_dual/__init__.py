from repro.kernels.lora_dual.ops import lora_dual
from repro.kernels.lora_dual.ref import lora_dual_ref
