"""Fused LoRA primal+tangent matmul — Pallas TPU kernel.

This is the TPU answer to the paper's §5.3 observation that PyTorch
Forward-mode AD pays a "column-by-column jvp" overhead: here the tangent
GEMM shares the VMEM residency of the primal GEMM. One pass over HBM for
x/xdot/W computes BOTH y and ydot; the rank-r LoRA factors live entirely in
VMEM scratch across the K-reduction.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary" = sequential reduction).
VMEM blocks are MXU-aligned (multiples of 128 on the matmul dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, xd_ref, w_ref, a_ref, ad_ref, b_ref, bd_ref,
            y_ref, yd_ref,
            acc_y, acc_yd, acc_u, acc_ud,
            *, scale: float, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_y[...] = jnp.zeros_like(acc_y)
        acc_yd[...] = jnp.zeros_like(acc_yd)
        acc_u[...] = jnp.zeros_like(acc_u)
        acc_ud[...] = jnp.zeros_like(acc_ud)

    x = x_ref[...]
    xd = xd_ref[...]
    w = w_ref[...]
    acc_y[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc_yd[...] += jnp.dot(xd, w, preferred_element_type=jnp.float32)
    a = a_ref[...]
    ad = ad_ref[...]
    acc_u[...] += jnp.dot(x, a, preferred_element_type=jnp.float32)
    acc_ud[...] += (jnp.dot(xd, a, preferred_element_type=jnp.float32)
                    + jnp.dot(x, ad, preferred_element_type=jnp.float32))

    @pl.when(k == n_k - 1)
    def _finish():
        b = b_ref[...].astype(jnp.float32)
        bd = bd_ref[...].astype(jnp.float32)
        u = acc_u[...]
        ud = acc_ud[...]
        y = acc_y[...] + scale * jnp.dot(u, b, preferred_element_type=jnp.float32)
        yd = acc_yd[...] + scale * (
            jnp.dot(ud, b, preferred_element_type=jnp.float32)
            + jnp.dot(u, bd, preferred_element_type=jnp.float32))
        y_ref[...] = y.astype(y_ref.dtype)
        yd_ref[...] = yd.astype(yd_ref.dtype)


def lora_dual_kernel(x, xdot, w, a, adot, b, bdot, *, scale: float,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 128, interpret: bool = True):
    """x/xdot: (M,K); w: (K,N); a/adot: (K,r); b/bdot: (r,N) -> (y, ydot)."""
    M, K = x.shape
    N = w.shape[1]
    r = a.shape[1]
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "caller (ops.py) must pad to block multiples")
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)

    kernel = functools.partial(_kernel, scale=scale, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),   # xdot
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),   # w
            pl.BlockSpec((block_k, r), lambda i, j, k: (k, 0)),         # a
            pl.BlockSpec((block_k, r), lambda i, j, k: (k, 0)),         # adot
            pl.BlockSpec((r, block_n), lambda i, j, k: (0, j)),         # b
            pl.BlockSpec((r, block_n), lambda i, j, k: (0, j)),         # bdot
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), x.dtype),
            jax.ShapeDtypeStruct((M, N), x.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((block_m, r), jnp.float32),
            pltpu.VMEM((block_m, r), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, xdot, w, a, adot, b, bdot)
