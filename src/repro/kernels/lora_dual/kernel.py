"""Fused LoRA primal + multi-tangent matmul — Pallas TPU kernel.

This is the TPU answer to the paper's §5.3 observation that PyTorch
Forward-mode AD pays a "column-by-column jvp" overhead: the tangent GEMMs
share the VMEM residency of the primal GEMM. The multi-tangent (mt) variant
extends that to SPRY's K-perturbation estimates — tangent operands
``xdot/adot/bdot`` carry a leading tangent axis T, and ONE pass over HBM for
``x``/``W`` produces the primal ``y`` plus all T ``ydot``s. The frozen-weight
GEMM (the overwhelming majority of FLOPs under LoRA) is read and computed
once instead of T times; the rank-r LoRA factors live entirely in VMEM
scratch across the K-reduction.

Tangent-axis contract: ``xdots (T, M, K)``, ``adots (T, K, r)``,
``bdots (T, r, N)`` -> ``ydots (T, M, N)``. ``has_xdot=False`` statically
removes the input-tangent GEMMs for the common SPRY case where the
projection is the client's first perturbed unit (upstream activations carry
no tangent).

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary" = sequential reduction).
VMEM blocks are MXU-aligned (multiples of 128 on the matmul dims); the T
axis is unrolled statically (T <= ~16 keeps the (T, bm, bn) accumulator
within VMEM budget: 16*128*128*4B = 1 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _mt_kernel(*refs, scale: float, n_k: int, n_t: int, has_xdot: bool,
               emit_primal: bool):
    refs = list(refs)
    x_ref = refs.pop(0)
    xd_ref = refs.pop(0) if has_xdot else None
    w_ref, a_ref, ad_ref, b_ref, bd_ref = refs[:5]
    refs = refs[5:]
    y_ref = refs.pop(0) if emit_primal else None
    yd_ref = refs.pop(0)
    acc_y = refs.pop(0) if emit_primal else None
    acc_yd = refs.pop(0) if has_xdot else None
    acc_u, acc_ud = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        if emit_primal:
            acc_y[...] = jnp.zeros_like(acc_y)
        if has_xdot:
            acc_yd[...] = jnp.zeros_like(acc_yd)
        acc_u[...] = jnp.zeros_like(acc_u)
        acc_ud[...] = jnp.zeros_like(acc_ud)

    x = x_ref[...]
    w = w_ref[...]
    a = a_ref[...]
    # one read of the x/W blocks feeds the primal AND every tangent
    if emit_primal:
        acc_y[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc_u[...] += jnp.dot(x, a, preferred_element_type=jnp.float32)
    for t in range(n_t):  # static unroll over the tangent axis
        acc_ud[t] += jnp.dot(x, ad_ref[t],
                             preferred_element_type=jnp.float32)
        if has_xdot:
            xd_t = xd_ref[t]
            acc_yd[t] += jnp.dot(xd_t, w, preferred_element_type=jnp.float32)
            acc_ud[t] += jnp.dot(xd_t, a, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        b = b_ref[...].astype(jnp.float32)
        u = acc_u[...]
        if emit_primal:
            y = acc_y[...] + scale * jnp.dot(
                u, b, preferred_element_type=jnp.float32)
            y_ref[...] = y.astype(y_ref.dtype)
        for t in range(n_t):
            bd_t = bd_ref[t].astype(jnp.float32)
            yd = scale * (
                jnp.dot(acc_ud[t], b, preferred_element_type=jnp.float32)
                + jnp.dot(u, bd_t, preferred_element_type=jnp.float32))
            if has_xdot:
                yd = yd + acc_yd[t]
            yd_ref[t] = yd.astype(yd_ref.dtype)


def lora_dual_mt_kernel(x, xdots, w, a, adots, b, bdots, *, scale: float,
                        block_m: int = 128, block_n: int = 128,
                        block_k: int = 128, interpret: bool = True,
                        emit_primal: bool = True):
    """x: (M,K); xdots: (T,M,K) or None; w: (K,N); a/adots: (K,r)/(T,K,r);
    b/bdots: (r,N)/(T,r,N) -> (y (M,N), ydots (T,M,N)), or just ydots when
    ``emit_primal=False`` (tangent-only pass — used by the AD dispatch rule,
    whose primal output must stay independent of tangents for
    jax.linearize's partial evaluation to split the two)."""
    M, K = x.shape
    N = w.shape[1]
    r = a.shape[1]
    T = adots.shape[0]
    has_xdot = xdots is not None
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "caller (ops.py) must pad to block multiples")
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)

    kernel = functools.partial(_mt_kernel, scale=scale, n_k=n_k, n_t=T,
                               has_xdot=has_xdot, emit_primal=emit_primal)
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),       # x
    ]
    operands = [x]
    if has_xdot:
        in_specs.append(
            pl.BlockSpec((T, block_m, block_k), lambda i, j, k: (0, i, k)))
        operands.append(xdots)
    in_specs += [
        pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),       # w
        pl.BlockSpec((block_k, r), lambda i, j, k: (k, 0)),             # a
        pl.BlockSpec((T, block_k, r), lambda i, j, k: (0, k, 0)),       # adots
        pl.BlockSpec((r, block_n), lambda i, j, k: (0, j)),             # b
        pl.BlockSpec((T, r, block_n), lambda i, j, k: (0, 0, j)),       # bdots
    ]
    operands += [w, a, adots, b, bdots]
    out_specs = [
        pl.BlockSpec((T, block_m, block_n), lambda i, j, k: (0, i, j)),
    ]
    out_shape = [jax.ShapeDtypeStruct((T, M, N), x.dtype)]
    # the (T, bm, bn) input-tangent accumulator is only allocated when xdots
    # exist — in the common first-perturbed-unit case it would hold zeros
    # while eating ~T*bm*bn*4B of VMEM per grid cell
    scratch = ([pltpu.VMEM((T, block_m, block_n), jnp.float32)]
               if has_xdot else [])
    scratch += [
        pltpu.VMEM((block_m, r), jnp.float32),
        pltpu.VMEM((T, block_m, r), jnp.float32),
    ]
    if emit_primal:
        out_specs.insert(
            0, pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)))
        out_shape.insert(0, jax.ShapeDtypeStruct((M, N), x.dtype))
        scratch.insert(0, pltpu.VMEM((block_m, block_n), jnp.float32))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return outs if emit_primal else outs[0]


def _mt_jvps_kernel(*refs, scale: float, n_k: int, n_t: int, has_xdot: bool):
    """Contraction epilogue: per-(i, j) tile jvp partials <gy, ydot_t>
    without ever forming a ydot tile.

    The k-reduction reuses the mt accumulators (u / per-tangent udots); the
    frozen-weight term is contracted INCREMENTALLY — zw = gy @ w_kᵀ is
    computed once per k step (one frozen-W GEMM shared by all T tangents)
    and dotted against each xdot tile into a (T, 1) jvp-partial accumulator
    in VMEM — so neither a (T, bm, bn) tangent tile nor a (T, bm, bn)
    scratch ever exists. At the last k step the LoRA terms collapse to
    rank-r contractions (z1 = gy @ bᵀ against udots, z2 = uᵀ @ gy against
    bdots) and the (1, 1, T) per-block partials are written out — the only
    HBM the epilogue writes is one scalar per tangent per grid tile.
    """
    refs = list(refs)
    x_ref = refs.pop(0)
    xd_ref = refs.pop(0) if has_xdot else None
    w_ref, a_ref, ad_ref, b_ref, bd_ref, gy_ref = refs[:6]
    refs = refs[6:]
    out_ref = refs.pop(0)
    acc_u, acc_ud = refs[:2]
    acc_j = refs[2] if has_xdot else None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_u[...] = jnp.zeros_like(acc_u)
        acc_ud[...] = jnp.zeros_like(acc_ud)
        if has_xdot:
            acc_j[...] = jnp.zeros_like(acc_j)

    x = x_ref[...]
    a = a_ref[...]
    acc_u[...] += jnp.dot(x, a, preferred_element_type=jnp.float32)
    if has_xdot:
        gy = gy_ref[...].astype(jnp.float32)
        # ONE frozen-weight GEMM per k step, shared across all T tangents:
        # <gy, xd_t @ w_k> = <gy @ w_kᵀ, xd_t>
        zw = jnp.dot(gy, w_ref[...].T, preferred_element_type=jnp.float32)
    for t in range(n_t):  # static unroll over the tangent axis
        acc_ud[t] += jnp.dot(x, ad_ref[t],
                             preferred_element_type=jnp.float32)
        if has_xdot:
            xd_t = xd_ref[t]
            acc_ud[t] += jnp.dot(xd_t, a, preferred_element_type=jnp.float32)
            acc_j[t, 0] += jnp.sum(zw * xd_t.astype(jnp.float32))

    @pl.when(k == n_k - 1)
    def _finish():
        gy = gy_ref[...].astype(jnp.float32)
        b = b_ref[...].astype(jnp.float32)
        u = acc_u[...]
        z1 = jnp.dot(gy, b.T, preferred_element_type=jnp.float32)    # (bm, r)
        z2 = jnp.dot(u.T, gy, preferred_element_type=jnp.float32)    # (r, bn)
        parts = []
        for t in range(n_t):
            bd_t = bd_ref[t].astype(jnp.float32)
            part = scale * (jnp.sum(z1 * acc_ud[t]) + jnp.sum(z2 * bd_t))
            if has_xdot:
                part = part + acc_j[t, 0]
            parts.append(part)
        out_ref[0, 0, :] = jnp.stack(parts)


def lora_dual_mt_jvps_kernel(x, xdots, w, a, adots, b, bdots, gy, *,
                             scale: float, block_m: int = 128,
                             block_n: int = 128, block_k: int = 128,
                             interpret: bool = True):
    """In-kernel fused jvp contraction: all T scalars <gy, ydot_t> with NO
    (T, M, N) tangent output — the HBM side of the epilogue is one (T,)
    partial per (i, j) grid tile, summed by the caller (ops.py).

    x: (M,K); xdots: (T,M,K) or None; w: (K,N); a/adots: (K,r)/(T,K,r);
    b/bdots: (r,N)/(T,r,N); gy: (M,N) -> per-block partials
    (M/bm, N/bn, T) fp32."""
    M, K = x.shape
    N = w.shape[1]
    r = a.shape[1]
    T = adots.shape[0]
    has_xdot = xdots is not None
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "caller (ops.py) must pad to block multiples")
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)

    kernel = functools.partial(_mt_jvps_kernel, scale=scale, n_k=n_k, n_t=T,
                               has_xdot=has_xdot)
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),       # x
    ]
    operands = [x]
    if has_xdot:
        in_specs.append(
            pl.BlockSpec((T, block_m, block_k), lambda i, j, k: (0, i, k)))
        operands.append(xdots)
    in_specs += [
        pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),       # w
        pl.BlockSpec((block_k, r), lambda i, j, k: (k, 0)),             # a
        pl.BlockSpec((T, block_k, r), lambda i, j, k: (0, k, 0)),       # adots
        pl.BlockSpec((r, block_n), lambda i, j, k: (0, j)),             # b
        pl.BlockSpec((T, r, block_n), lambda i, j, k: (0, 0, j)),       # bdots
        pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),       # gy
    ]
    operands += [w, a, adots, b, bdots, gy]
    scratch = [
        pltpu.VMEM((block_m, r), jnp.float32),
        pltpu.VMEM((T, block_m, r), jnp.float32),
    ]
    if has_xdot:
        scratch.append(pltpu.VMEM((T, 1), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, T), lambda i, j, k: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((M // block_m, N // block_n, T),
                                       jnp.float32),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def _multi_kernel(x_ref, idx_ref, w_ref, a_ref, b_ref, y_ref, acc_y, acc_u,
                  *, scale: float, n_k: int, n_pages: int):
    """Multi-adapter LoRA projection: each row of the x block carries an
    adapter-page index; all P resident pages' rank-r partial products
    accumulate in VMEM and the finish epilogue one-hot selects each row's
    page. ONE pass over the shared frozen W serves every adapter — the
    frozen GEMM (the overwhelming majority of FLOPs) is not re-read or
    recomputed per adapter, exactly the ``_mt`` idiom with pages in place
    of tangents."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_y[...] = jnp.zeros_like(acc_y)
        acc_u[...] = jnp.zeros_like(acc_u)

    x = x_ref[...]
    acc_y[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    for p in range(n_pages):  # static unroll over resident adapter pages
        acc_u[p] += jnp.dot(x, a_ref[p], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        idx = idx_ref[...]                             # (bm, 1) int32
        y = acc_y[...]
        for p in range(n_pages):
            bp = b_ref[p].astype(jnp.float32)
            yp = scale * jnp.dot(acc_u[p], bp,
                                 preferred_element_type=jnp.float32)
            # adding the zero-masked other pages is exact (x + 0.0 == x):
            # no cross-adapter contamination, each row sees only its page
            y = y + jnp.where(idx == p, yp, 0.0)
        y_ref[...] = y.astype(y_ref.dtype)


def lora_dual_multi_kernel(x, idx, w, a_stack, b_stack, *, scale: float,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128, interpret: bool = True):
    """x: (M,K); idx: (M,1) int32 adapter-page per row; w: (K,N);
    a_stack: (P,K,r); b_stack: (P,r,N) -> y (M,N).

    Grid and accumulator layout mirror ``lora_dual_mt_kernel`` with the
    page axis P where the tangent axis T was: the (P, bm, r) rank-r
    partials live in VMEM across the K reduction, and the frozen-W GEMM
    runs once for the whole heterogeneous batch. P is the resident-page
    count of the serving adapter cache (small, ≤ batch)."""
    M, K = x.shape
    N = w.shape[1]
    r = a_stack.shape[2]
    P = a_stack.shape[0]
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "caller (ops.py) must pad to block multiples")
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)

    kernel = functools.partial(_multi_kernel, scale=scale, n_k=n_k,
                               n_pages=P)
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),       # x
        pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),             # idx
        pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),       # w
        pl.BlockSpec((P, block_k, r), lambda i, j, k: (0, k, 0)),       # A
        pl.BlockSpec((P, r, block_n), lambda i, j, k: (0, 0, j)),       # B
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((P, block_m, r), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, idx, w, a_stack, b_stack)


def lora_dual_kernel(x, xdot, w, a, adot, b, bdot, *, scale: float,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 128, interpret: bool = True):
    """Single-tangent compatibility wrapper: T=1 slice of the mt kernel.

    x/xdot: (M,K); w: (K,N); a/adot: (K,r); b/bdot: (r,N) -> (y, ydot)."""
    y, ydots = lora_dual_mt_kernel(
        x, xdot[None], w, a, adot[None], b, bdot[None], scale=scale,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret)
    return y, ydots[0]
