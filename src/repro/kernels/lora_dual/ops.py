"""jit'd dispatch wrapper: flattens batch dims, pads to block multiples,
calls the Pallas kernel, unpads."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lora_dual.kernel import lora_dual_kernel


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "block_k", "interpret"))
def lora_dual(x, xdot, w, a, adot, b, bdot, scale: float = 1.0,
              block_m: int = 128, block_n: int = 128, block_k: int = 128,
              interpret: bool = True):
    """Fused y = x@W + s(x@A)@B and its jvp. x may have leading batch dims."""
    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    x2 = x.reshape(-1, K)
    xd2 = xdot.reshape(-1, K)
    M = x2.shape[0]

    x2 = _pad_to(_pad_to(x2, block_m, 0), block_k, 1)
    xd2 = _pad_to(_pad_to(xd2, block_m, 0), block_k, 1)
    wp = _pad_to(_pad_to(w, block_k, 0), block_n, 1)
    ap = _pad_to(a, block_k, 0)
    adp = _pad_to(adot, block_k, 0)
    bp = _pad_to(b, block_n, 1)
    bdp = _pad_to(bdot, block_n, 1)

    y, yd = lora_dual_kernel(x2, xd2, wp, ap, adp, bp, bdp, scale=scale,
                             block_m=block_m, block_n=block_n,
                             block_k=block_k, interpret=interpret)
    y = y[:M, :N].reshape(batch_shape + (N,))
    yd = yd[:M, :N].reshape(batch_shape + (N,))
    return y, yd
