"""jit'd dispatch wrappers: flatten batch dims, pad to block multiples,
call the Pallas kernel, unpad.

``lora_dual``      single-tangent fused pass (y, ydot)
``lora_dual_mt``   multi-tangent fused pass (y, ydots (T, ...)) — one read
                   of x/W serves the primal and all T tangents
``lora_dual_mt_jvps``  fused jvp-contraction epilogue: all T jvp scalars
                   <gy, ydot_t> WITHOUT materializing any (T, M, N) tangent
                   output — the cheap path when the projection output feeds
                   a known cotangent (last-mixer / loss-head sites,
                   benchmarks). ``impl='kernel'`` runs the in-kernel
                   blockwise epilogue (``lora_dual_mt_jvps_kernel``: the
                   per-tangent partials accumulate in VMEM and only one
                   scalar per tangent per grid tile reaches HBM);
                   ``impl='reassoc'`` is the jnp mirror of the same
                   reassociated math (the fast XLA-fused CPU path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lora_dual.kernel import (
    lora_dual_kernel,
    lora_dual_mt_jvps_kernel,
    lora_dual_mt_kernel,
    lora_dual_multi_kernel,
)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "block_k", "interpret"))
def lora_dual(x, xdot, w, a, adot, b, bdot, scale: float = 1.0,
              block_m: int = 128, block_n: int = 128, block_k: int = 128,
              interpret: bool = True):
    """Fused y = x@W + s(x@A)@B and its jvp. x may have leading batch dims."""
    y, ydots = lora_dual_mt(x, xdot[None], w, a, adot[None], b, bdot[None],
                            scale=scale, block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=interpret)
    return y, ydots[0]


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "block_k", "interpret"))
def lora_dual_mt(x, xdots, w, a, adots, b, bdots, scale: float = 1.0,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 interpret: bool = True):
    """Multi-tangent fused pass. x: (..., K); xdots: (T, ..., K) or None;
    adots: (T, K, r); bdots: (T, r, N) -> (y (..., N), ydots (T, ..., N))."""
    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    T = adots.shape[0]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]

    x2 = _pad_to(_pad_to(x2, block_m, 0), block_k, 1)
    if xdots is not None:
        xd2 = xdots.reshape(T, -1, K)
        xd2 = _pad_to(_pad_to(xd2, block_m, 1), block_k, 2)
    else:
        xd2 = None
    wp = _pad_to(_pad_to(w, block_k, 0), block_n, 1)
    ap = _pad_to(a, block_k, 0)
    adp = _pad_to(adots, block_k, 1)
    bp = _pad_to(b, block_n, 1)
    bdp = _pad_to(bdots, block_n, 2)

    y, yds = lora_dual_mt_kernel(x2, xd2, wp, ap, adp, bp, bdp, scale=scale,
                                 block_m=block_m, block_n=block_n,
                                 block_k=block_k, interpret=interpret)
    y = y[:M, :N].reshape(batch_shape + (N,))
    yds = yds[:, :M, :N].reshape((T,) + batch_shape + (N,))
    return y, yds


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "block_k", "interpret"))
def lora_dual_mt_tangents(x, xdots, w, a, adots, b, bdots, scale: float = 1.0,
                          block_m: int = 128, block_n: int = 128,
                          block_k: int = 128, interpret: bool = True):
    """Tangent-only fused pass -> ydots (T, ..., N). Same contract as
    ``lora_dual_mt`` but skips the primal output — the AD dispatch rule uses
    this so its primal stays a pure function of primal inputs (required for
    jax.linearize to partial-eval through the custom-JVP rule)."""
    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    T = adots.shape[0]
    x2 = _pad_to(_pad_to(x.reshape(-1, K), block_m, 0), block_k, 1)
    M = x.reshape(-1, K).shape[0]
    if xdots is not None:
        xdots = _pad_to(_pad_to(xdots.reshape(T, -1, K), block_m, 1),
                        block_k, 2)
    wp = _pad_to(_pad_to(w, block_k, 0), block_n, 1)
    ap = _pad_to(a, block_k, 0)
    adp = _pad_to(adots, block_k, 1)
    bp = _pad_to(b, block_n, 1)
    bdp = _pad_to(bdots, block_n, 2)
    yds = lora_dual_mt_kernel(x2, xdots, wp, ap, adp, bp, bdp, scale=scale,
                              block_m=block_m, block_n=block_n,
                              block_k=block_k, interpret=interpret,
                              emit_primal=False)
    return yds[:, :M, :N].reshape((T,) + batch_shape + (N,))


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "block_k", "interpret"))
def lora_dual_multi(x, idx, w, a_stack, b_stack, scale: float = 1.0,
                    block_m: int = 128, block_n: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """Multi-adapter fused projection: each batch row reads its own LoRA
    page, one pass over the shared frozen W. x: (..., K); idx: adapter-page
    indices broadcastable to x.shape[:-1] (typically (B,) over a (B, S, K)
    batch); a_stack: (P, K, r); b_stack: (P, r, N) -> y (..., N)."""
    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    idx = jnp.reshape(idx, idx.shape + (1,) * (len(batch_shape) - idx.ndim))
    idx = jnp.broadcast_to(idx, batch_shape)
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    x2 = _pad_to(_pad_to(x2, block_m, 0), block_k, 1)
    # padded rows read page 0 over zero inputs; their outputs are discarded
    i2 = _pad_to(idx.reshape(-1, 1).astype(jnp.int32), block_m, 0)
    wp = _pad_to(_pad_to(w, block_k, 0), block_n, 1)
    ap = _pad_to(a_stack, block_k, 1)
    bp = _pad_to(b_stack, block_n, 2)
    y = lora_dual_multi_kernel(x2, i2, wp, ap, bp, scale=scale,
                               block_m=block_m, block_n=block_n,
                               block_k=block_k, interpret=interpret)
    return y[:M, :N].reshape(batch_shape + (N,))


@functools.partial(jax.jit, static_argnames=("scale", "impl", "block_m",
                                             "block_n", "block_k",
                                             "interpret"))
def lora_dual_mt_jvps(x, w, a, adots, b, bdots, gy, scale: float = 1.0,
                      xdots=None, impl: str = "reassoc",
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 128, interpret: bool = True):
    """All T jvp scalars <gy, ydot_t> via the fused contraction epilogue.

    Never materializes a (T, M, N) tangent stack: the frozen-weight GEMM
    appears at most once (gy@Wᵀ, only when ``xdots`` is given) and every
    per-tangent term is rank-r sized. Equivalent (up to float reassociation)
    to contracting ``gy`` with ``lora_dual_mt``'s ydots — the oracle is
    ``ref.lora_dual_mt_jvps_ref``.

    ``impl='kernel'`` runs the blockwise Pallas epilogue
    (``lora_dual_mt_jvps_kernel``); ``impl='reassoc'`` is the whole-array
    jnp mirror of the same math (the fast CPU path the dispatch layer picks
    on the 'jnp' backend).
    """
    T = adots.shape[0]
    if impl == "kernel":
        x2 = x.reshape(-1, x.shape[-1])
        M, K = x2.shape
        N = w.shape[1]
        x2 = _pad_to(_pad_to(x2, block_m, 0), block_k, 1)
        if xdots is not None:
            xd2 = _pad_to(_pad_to(xdots.reshape(T, -1, K), block_m, 1),
                          block_k, 2)
        else:
            xd2 = None
        wp = _pad_to(_pad_to(w, block_k, 0), block_n, 1)
        ap = _pad_to(a, block_k, 0)
        adp = _pad_to(adots, block_k, 1)
        bp = _pad_to(b, block_n, 1)
        bdp = _pad_to(bdots, block_n, 2)
        # zero-padded gy rows/cols contribute exactly 0 to every partial
        gy2 = _pad_to(_pad_to(gy.reshape(-1, N), block_m, 0), block_n, 1)
        parts = lora_dual_mt_jvps_kernel(
            x2, xd2, wp, ap, adp, bp, bdp, gy2, scale=scale,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret)
        return parts.sum(axis=(0, 1))

    x = x.reshape(-1, x.shape[-1])
    gy = gy.reshape(-1, gy.shape[-1]).astype(jnp.float32)
    u = x @ a                                       # (M, r)
    z1 = gy @ b.T                                   # (M, r)  ydot·b side
    z2 = u.T @ gy                                   # (r, N)  u·bdot side
    udots = x @ adots                               # (T, M, r)
    if xdots is not None:
        xdots = xdots.reshape(adots.shape[0], -1, x.shape[-1])
        udots = udots + xdots @ a
    jvps = scale * (jnp.einsum("mr,tmr->t", z1, udots)
                    + jnp.einsum("rn,trn->t", z2,
                                 bdots.astype(jnp.float32)))
    if xdots is not None:
        jvps = jvps + jnp.einsum("mk,tmk->t", gy @ w.T, xdots)
    return jvps
