"""Pure-jnp oracle for the fused LoRA dual-number (primal+tangent) matmul.

Semantics (exactly what jax.jvp produces for y = x@W + s*(x@A)@B with
tangents on x, A, B and frozen W):

    y    = x@W + s*(x@A)@B
    ydot = xdot@W + s*((xdot@A + x@adot)@B + (x@A)@bdot)
"""
from __future__ import annotations

import jax.numpy as jnp


def lora_dual_ref(x, xdot, w, a, adot, b, bdot, scale: float):
    xw = x @ w
    u = x @ a
    y = xw + scale * (u @ b)
    udot = xdot @ a + x @ adot
    ydot = xdot @ w + scale * (udot @ b + u @ bdot)
    return y, ydot
