"""Pure-jnp oracles for the fused LoRA dual-number (primal+tangent) matmul.

Semantics (exactly what jax.jvp produces for y = x@W + s*(x@A)@B with
tangents on x, A, B and frozen W):

    y    = x@W + s*(x@A)@B
    ydot = xdot@W + s*((xdot@A + x@adot)@B + (x@A)@bdot)

Tangent-axis contract (multi-tangent variants): tangent stacks carry a
leading axis T — ``xdots (T,M,K)``, ``adots (T,K,r)``, ``bdots (T,r,N)`` ->
``ydots (T,M,N)``; ``xdots=None`` means the input carries no tangent (the
projection is the first perturbed unit on the client's path).
"""
from __future__ import annotations

import jax.numpy as jnp


def lora_dual_ref(x, xdot, w, a, adot, b, bdot, scale: float):
    xw = x @ w
    u = x @ a
    y = xw + scale * (u @ b)
    udot = xdot @ a + x @ adot
    ydot = xdot @ w + scale * (udot @ b + u @ bdot)
    return y, ydot


def lora_dual_mt_ref(x, xdots, w, a, adots, b, bdots, scale: float):
    """Multi-tangent oracle; x 2-D (M,K), tangent stacks lead with T."""
    u = x @ a                                        # (M, r)
    y = x @ w + scale * (u @ b)
    udots = x @ adots                                # (T, M, r) broadcast
    if xdots is not None:
        udots = udots + xdots @ a
    ydots = scale * (udots @ b + u @ bdots)          # (T, M, N)
    if xdots is not None:
        ydots = ydots + xdots @ w
    return y, ydots


def lora_dual_mt_jvps_ref(x, w, a, adots, b, bdots, gy, scale: float,
                          xdots=None):
    """Oracle for the fused jvp contraction: materializes all T ydots and
    contracts them against the output cotangent ``gy`` (M,N)."""
    _, ydots = lora_dual_mt_ref(x, xdots, w, a, adots, b, bdots, scale)
    return jnp.einsum("mn,tmn->t", gy.astype(jnp.float32),
                      ydots.astype(jnp.float32))
