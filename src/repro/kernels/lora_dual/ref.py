"""Pure-jnp oracles for the fused LoRA dual-number (primal+tangent) matmul.

Semantics (exactly what jax.jvp produces for y = x@W + s*(x@A)@B with
tangents on x, A, B and frozen W):

    y    = x@W + s*(x@A)@B
    ydot = xdot@W + s*((xdot@A + x@adot)@B + (x@A)@bdot)

Tangent-axis contract (multi-tangent variants): tangent stacks carry a
leading axis T — ``xdots (T,M,K)``, ``adots (T,K,r)``, ``bdots (T,r,N)`` ->
``ydots (T,M,N)``; ``xdots=None`` means the input carries no tangent (the
projection is the first perturbed unit on the client's path).
"""
from __future__ import annotations

import jax.numpy as jnp


def lora_dual_ref(x, xdot, w, a, adot, b, bdot, scale: float):
    xw = x @ w
    u = x @ a
    y = xw + scale * (u @ b)
    udot = xdot @ a + x @ adot
    ydot = xdot @ w + scale * (udot @ b + u @ bdot)
    return y, ydot


def lora_dual_mt_ref(x, xdots, w, a, adots, b, bdots, scale: float):
    """Multi-tangent oracle; x 2-D (M,K), tangent stacks lead with T."""
    u = x @ a                                        # (M, r)
    y = x @ w + scale * (u @ b)
    udots = x @ adots                                # (T, M, r) broadcast
    if xdots is not None:
        udots = udots + xdots @ a
    ydots = scale * (udots @ b + u @ bdots)          # (T, M, N)
    if xdots is not None:
        ydots = ydots + xdots @ w
    return y, ydots


def lora_dual_multi_ref(x, idx, w, a_stack, b_stack, scale: float):
    """Multi-adapter oracle: batch row m projects through adapter page
    idx[m]. x (M,K); idx (M,) int32 in [0, P); a_stack (P,K,r);
    b_stack (P,r,N) -> y (M,N) with

        y[m] = x[m] @ W + s * (x[m] @ A[idx[m]]) @ B[idx[m]]

    — i.e. per-row ``lora_dual`` primal semantics with a gathered LoRA
    pair, ONE shared pass over the frozen W."""
    a_sel = a_stack[idx]                              # (M, K, r)
    b_sel = b_stack[idx]                              # (M, r, N)
    u = jnp.einsum("mk,mkr->mr", x, a_sel)
    return x @ w + scale * jnp.einsum("mr,mrn->mn", u, b_sel)


def lora_dual_mt_jvps_ref(x, w, a, adots, b, bdots, gy, scale: float,
                          xdots=None):
    """Oracle for the fused jvp contraction: materializes all T ydots and
    contracts them against the output cotangent ``gy`` (M,N)."""
    _, ydots = lora_dual_mt_ref(x, xdots, w, a, adots, b, bdots, scale)
    return jnp.einsum("mn,tmn->t", gy.astype(jnp.float32),
                      ydots.astype(jnp.float32))
