"""RWKV6 WKV recurrence — Pallas TPU kernel.

Grid: (B*H, S/block_s). The (hd, hd) state matrix lives in VMEM scratch and
persists across the sequential S dimension; each grid step streams one
(block_s, hd) tile of r/k/v/w through VMEM and walks it with a fori_loop.
Within a step the per-token update is rank-1 (outer product) + elementwise
decay — VPU work with an MXU-friendly (hd x hd) layout.

Compared to the pure-jnp lax.scan reference this removes the per-token HBM
round-trip of the state (the dominant cost on TPU for hd=64: 2*hd*hd*4 bytes
per token vs ~6*hd*hd FLOPs — arithmetic intensity < 1 without the kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_scr,
            *, block_s: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    u = u_ref[0]                                    # (1, hd) -> (hd,) via [0]

    def step(t, _):
        rt = r_ref[0, t, :]                         # (hd,)
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]
        wt = w_ref[0, t, :]
        s = state_scr[...]                          # (hd, hd)
        kv = kt[:, None] * vt[None, :]              # rank-1 outer product
        yt = ((s + u[:, None] * kv) * rt[:, None]).sum(axis=0)
        state_scr[...] = wt[:, None] * s + kv
        y_ref[0, t, :] = yt.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, block_s, step, ())


def wkv6_scan_kernel(r, k, v, w, u, *, block_s: int = 64, interpret=True):
    """r,k,v,w: (BH, S, hd) fp32; u: (BH, hd). Returns y (BH, S, hd)."""
    BH, S, hd = r.shape
    assert S % block_s == 0
    grid = (BH, S // block_s)
    kernel = functools.partial(_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, hd), lambda b, s: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)


def _mt_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, rd_ref, kd_ref, vd_ref,
               wd_ref, *rest, block_s: int, n_t: int, has_ud: bool,
               emit_primal: bool):
    rest = list(rest)
    ud_ref = rest.pop(0) if has_ud else None
    y_ref = rest.pop(0) if emit_primal else None
    yd_ref = rest.pop(0)
    state_scr, state_d_scr = rest
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)
        state_d_scr[...] = jnp.zeros_like(state_d_scr)

    u = u_ref[0]                                    # (hd,)

    def step(t, _):
        rt = r_ref[0, t, :]                         # (hd,)
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]
        wt = w_ref[0, t, :]
        s = state_scr[...]                          # (hd, hd)
        kv = kt[:, None] * vt[None, :]
        # the per-tangent math below re-reads s/kv BEFORE the state update,
        # and each tangent lane runs the exact op sequence of the T=1 slice
        # (independent scratch rows) -> stacked ydots are bitwise-equal to
        # T single-tangent passes
        if emit_primal:
            yt = ((s + u[:, None] * kv) * rt[:, None]).sum(axis=0)
            y_ref[0, t, :] = yt.astype(y_ref.dtype)
        for tau in range(n_t):                      # static unroll over T
            rdt = rd_ref[tau, 0, t, :]
            kdt = kd_ref[tau, 0, t, :]
            vdt = vd_ref[tau, 0, t, :]
            wdt = wd_ref[tau, 0, t, :]
            sd = state_d_scr[tau]                   # (hd, hd)
            kvd = kdt[:, None] * vt[None, :] + kt[:, None] * vdt[None, :]
            bonus_d = u[:, None] * kvd
            if has_ud:
                bonus_d = bonus_d + ud_ref[tau, 0][:, None] * kv
            ydt = (((sd + bonus_d) * rt[:, None]).sum(axis=0)
                   + ((s + u[:, None] * kv) * rdt[:, None]).sum(axis=0))
            state_d_scr[tau] = wdt[:, None] * s + wt[:, None] * sd + kvd
            yd_ref[tau, 0, t, :] = ydt.astype(yd_ref.dtype)
        state_scr[...] = wt[:, None] * s + kv
        return ()

    jax.lax.fori_loop(0, block_s, step, ())


def _mt_jvps_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, rd_ref, kd_ref,
                    vd_ref, wd_ref, *rest, block_s: int, n_s: int, n_t: int,
                    has_ud: bool):
    """Contraction epilogue: the same primal-state / tangent-state walk as
    ``_mt_kernel``, but each per-token ydot_t is contracted against the
    incoming gy token on the spot — accumulated into a (T, hd) VMEM partial
    — instead of being written to HBM. Only a (1, T) per-row partial leaves
    the kernel at the last sequence block."""
    rest = list(rest)
    ud_ref = rest.pop(0) if has_ud else None
    gy_ref = rest.pop(0)
    out_ref = rest.pop(0)
    state_scr, state_d_scr, acc_j = rest
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)
        state_d_scr[...] = jnp.zeros_like(state_d_scr)
        acc_j[...] = jnp.zeros_like(acc_j)

    u = u_ref[0]                                    # (hd,)

    def step(t, _):
        rt = r_ref[0, t, :]                         # (hd,)
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]
        wt = w_ref[0, t, :]
        gt = gy_ref[0, t, :].astype(jnp.float32)
        s = state_scr[...]                          # (hd, hd)
        kv = kt[:, None] * vt[None, :]
        for tau in range(n_t):                      # static unroll over T
            rdt = rd_ref[tau, 0, t, :]
            kdt = kd_ref[tau, 0, t, :]
            vdt = vd_ref[tau, 0, t, :]
            wdt = wd_ref[tau, 0, t, :]
            sd = state_d_scr[tau]                   # (hd, hd)
            kvd = kdt[:, None] * vt[None, :] + kt[:, None] * vdt[None, :]
            bonus_d = u[:, None] * kvd
            if has_ud:
                bonus_d = bonus_d + ud_ref[tau, 0][:, None] * kv
            ydt = (((sd + bonus_d) * rt[:, None]).sum(axis=0)
                   + ((s + u[:, None] * kv) * rdt[:, None]).sum(axis=0))
            state_d_scr[tau] = wdt[:, None] * s + wt[:, None] * sd + kvd
            acc_j[tau] += gt * ydt                  # contract, never store
        state_scr[...] = wt[:, None] * s + kv
        return ()

    jax.lax.fori_loop(0, block_s, step, ())

    @pl.when(si == n_s - 1)
    def _finish():
        out_ref[0, :] = acc_j[...].sum(axis=1)


def wkv6_scan_mt_jvps_kernel(r, k, v, w, u, rds, kds, vds, wds, gy, uds=None,
                             *, block_s: int = 64, interpret=True):
    """Fused jvp-contraction epilogue of the multi-tangent WKV recurrence:
    all T scalars <gy, ydot_t> with NO (T, BH, S, hd) tangent output — the
    per-token ydots are contracted against gy in VMEM as the state walk
    produces them. Returns per-row partials (BH, T) fp32, summed by the
    caller (ops.py). Same operand contract as ``wkv6_scan_mt_kernel`` plus
    gy: (BH, S, hd)."""
    BH, S, hd = r.shape
    T = rds.shape[0]
    assert S % block_s == 0
    has_ud = uds is not None
    n_s = S // block_s
    grid = (BH, n_s)
    kernel = functools.partial(_mt_jvps_kernel, block_s=block_s, n_s=n_s,
                               n_t=T, has_ud=has_ud)
    seq_spec = pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0))
    seq_spec_t = pl.BlockSpec((T, 1, block_s, hd), lambda b, s: (0, b, s, 0))
    in_specs = [seq_spec] * 4 + [
        pl.BlockSpec((1, hd), lambda b, s: (b, 0)),
    ] + [seq_spec_t] * 4
    operands = [r, k, v, w, u, rds, kds, vds, wds]
    if has_ud:
        in_specs.append(pl.BlockSpec((T, 1, hd), lambda b, s: (0, b, 0)))
        operands.append(uds)
    in_specs.append(seq_spec)                       # gy
    operands.append(gy)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, T), lambda b, s: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32),
                        pltpu.VMEM((T, hd, hd), jnp.float32),
                        pltpu.VMEM((T, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def wkv6_scan_mt_kernel(r, k, v, w, u, rds, kds, vds, wds, uds=None, *,
                        block_s: int = 64, interpret=True,
                        emit_primal: bool = True):
    """Multi-tangent WKV recurrence: one pass over the primal r/k/v/w
    produces y plus all T ydots (same amortize-the-primal design as
    ``lora_dual_mt_kernel`` — the tangent state recurrence

        Sd_t = wd_t ∘ S_{t-1} + w_t ∘ Sd_{t-1} + kd_t v_t^T + k_t vd_t^T
        yd_t = rd_t^T (S_{t-1} + (u∘k_t) v_t^T)
             + r_t^T (Sd_{t-1} + (u∘kd_t + ud∘k_t) v_t^T + (u∘k_t) vd_t^T)

    shares the primal S walk across all T tangents).

    r,k,v,w: (BH, S, hd) fp32; u: (BH, hd); rds..wds: (T, BH, S, hd);
    uds: (T, BH, hd) or None (frozen u — the SPRY case). Returns
    (y (BH,S,hd), ydots (T,BH,S,hd)), or ydots only when
    ``emit_primal=False`` (the AD dispatch tangent route)."""
    BH, S, hd = r.shape
    T = rds.shape[0]
    assert S % block_s == 0
    has_ud = uds is not None
    grid = (BH, S // block_s)
    kernel = functools.partial(_mt_kernel, block_s=block_s, n_t=T,
                               has_ud=has_ud, emit_primal=emit_primal)
    seq_spec = pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0))
    seq_spec_t = pl.BlockSpec((T, 1, block_s, hd), lambda b, s: (0, b, s, 0))
    in_specs = [seq_spec] * 4 + [
        pl.BlockSpec((1, hd), lambda b, s: (b, 0)),
    ] + [seq_spec_t] * 4
    operands = [r, k, v, w, u, rds, kds, vds, wds]
    if has_ud:
        in_specs.append(pl.BlockSpec((T, 1, hd), lambda b, s: (0, b, 0)))
        operands.append(uds)
    out_specs = [seq_spec_t]
    out_shape = [jax.ShapeDtypeStruct((T, BH, S, hd), jnp.float32)]
    if emit_primal:
        out_specs.insert(0, seq_spec)
        out_shape.insert(0, jax.ShapeDtypeStruct((BH, S, hd), jnp.float32))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32),
                        pltpu.VMEM((T, hd, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return outs if emit_primal else outs[0]
