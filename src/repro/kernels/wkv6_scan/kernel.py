"""RWKV6 WKV recurrence — Pallas TPU kernel.

Grid: (B*H, S/block_s). The (hd, hd) state matrix lives in VMEM scratch and
persists across the sequential S dimension; each grid step streams one
(block_s, hd) tile of r/k/v/w through VMEM and walks it with a fori_loop.
Within a step the per-token update is rank-1 (outer product) + elementwise
decay — VPU work with an MXU-friendly (hd x hd) layout.

Compared to the pure-jnp lax.scan reference this removes the per-token HBM
round-trip of the state (the dominant cost on TPU for hd=64: 2*hd*hd*4 bytes
per token vs ~6*hd*hd FLOPs — arithmetic intensity < 1 without the kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_scr,
            *, block_s: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    u = u_ref[0]                                    # (1, hd) -> (hd,) via [0]

    def step(t, _):
        rt = r_ref[0, t, :]                         # (hd,)
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]
        wt = w_ref[0, t, :]
        s = state_scr[...]                          # (hd, hd)
        kv = kt[:, None] * vt[None, :]              # rank-1 outer product
        yt = ((s + u[:, None] * kv) * rt[:, None]).sum(axis=0)
        state_scr[...] = wt[:, None] * s + kv
        y_ref[0, t, :] = yt.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, block_s, step, ())


def wkv6_scan_kernel(r, k, v, w, u, *, block_s: int = 64, interpret=True):
    """r,k,v,w: (BH, S, hd) fp32; u: (BH, hd). Returns y (BH, S, hd)."""
    BH, S, hd = r.shape
    assert S % block_s == 0
    grid = (BH, S // block_s)
    kernel = functools.partial(_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, hd), lambda b, s: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
