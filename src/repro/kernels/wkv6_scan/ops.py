"""jit'd wrappers: (B,S,H,hd) <-> (B*H, S, hd) layout + padding of S.

``wkv6_scan``             single-pass primal
``wkv6_scan_mt``          multi-tangent fused pass (y, ydots (T, ...)) — one
                          walk of the primal state serves all T tangents
``wkv6_scan_mt_tangents`` tangent-only variant (the AD dispatch route; its
                          primal output must come from the jnp mirror so
                          jax.linearize can split the custom-JVP rule)
``wkv6_scan_mt_jvps``     fused contraction epilogue: all T scalars
                          <gy, ydot_t> — per-token ydots are contracted
                          against gy inside the kernel and never written to
                          HBM (the cotangent-known estimator route)

Tangent-axis contract: tangents carry a leading T axis — rds/kds/vds/wds are
(T, B, S, H, hd) and uds (when the per-head bonus u carries a tangent) is
(T, H, hd); ydots come back as (T, B, S, H, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6_scan.kernel import (
    wkv6_scan_kernel,
    wkv6_scan_mt_jvps_kernel,
    wkv6_scan_mt_kernel,
)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def wkv6_scan(r, k, v, w, u, block_s: int = 64, interpret: bool = True):
    """r,k,v,w: (B,S,H,hd); u: (H,hd). Returns y (B,S,H,hd) fp32.

    Fresh state per call (training semantics); the decode path keeps its
    state outside and uses the jnp reference for single steps.
    """
    B, S, H, hd = r.shape
    bs = min(block_s, S)
    pad = (-S) % bs

    def to_bh(t):
        t = t.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        return t

    # pad w with ones (decay) so padded steps keep state intact — irrelevant
    # anyway since padded y rows are dropped
    rb, kb, vb = to_bh(r), to_bh(k), to_bh(v)
    wb = to_bh(w)
    if pad:
        wb = wb.at[:, S:, :].set(1.0)
    ub = jnp.broadcast_to(u.astype(jnp.float32)[None], (B, H, hd)).reshape(B * H, hd)
    y = wkv6_scan_kernel(rb, kb, vb, wb, ub, block_s=bs, interpret=interpret)
    y = y[:, :S].reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return y


def _mt_layout(r, k, v, w, u, rds, kds, vds, wds, uds, block_s):
    """Shared (B,S,H,hd)->(BH,S,hd) flattening + S padding for the mt entry
    points. Padded steps keep both the primal state (w=1, kv=0) and every
    tangent state (wd=0, kvd=0) intact; padded y/ydot rows are dropped."""
    B, S, H, hd = r.shape
    T = rds.shape[0]
    bs = min(block_s, S)
    pad = (-S) % bs

    def to_bh(t):
        t = t.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        return t

    def to_bh_t(t):
        t = t.astype(jnp.float32).transpose(0, 1, 3, 2, 4).reshape(
            T, B * H, S, hd)
        if pad:
            t = jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return t

    rb, kb, vb, wb = to_bh(r), to_bh(k), to_bh(v), to_bh(w)
    if pad:
        wb = wb.at[:, S:, :].set(1.0)
    rdb, kdb, vdb, wdb = to_bh_t(rds), to_bh_t(kds), to_bh_t(vds), to_bh_t(wds)
    ub = jnp.broadcast_to(u.astype(jnp.float32)[None],
                          (B, H, hd)).reshape(B * H, hd)
    udb = None
    if uds is not None:
        udb = jnp.broadcast_to(uds.astype(jnp.float32)[:, None],
                               (T, B, H, hd)).reshape(T, B * H, hd)
    return (rb, kb, vb, wb, ub, rdb, kdb, vdb, wdb, udb), (B, S, H, hd, T, bs)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def wkv6_scan_mt(r, k, v, w, u, rds, kds, vds, wds, uds=None,
                 block_s: int = 64, interpret: bool = True):
    """Multi-tangent fused pass. r,k,v,w: (B,S,H,hd); u: (H,hd); tangents
    (T,B,S,H,hd) (+ uds (T,H,hd) or None). Returns (y, ydots) fp32."""
    ops, (B, S, H, hd, T, bs) = _mt_layout(r, k, v, w, u, rds, kds, vds, wds,
                                           uds, block_s)
    y, yds = wkv6_scan_mt_kernel(*[o for o in ops if o is not None],
                                 block_s=bs, interpret=interpret)
    y = y[:, :S].reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    yds = yds[:, :, :S].reshape(T, B, H, S, hd).transpose(0, 1, 3, 2, 4)
    return y, yds


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def wkv6_scan_mt_tangents(r, k, v, w, u, rds, kds, vds, wds, uds=None,
                          block_s: int = 64, interpret: bool = True):
    """Tangent-only fused pass -> ydots (T,B,S,H,hd). Same contract as
    ``wkv6_scan_mt`` but skips the primal y output (the primal state walk
    still runs in-kernel — the tangent recurrence needs S_{t-1})."""
    ops, (B, S, H, hd, T, bs) = _mt_layout(r, k, v, w, u, rds, kds, vds, wds,
                                           uds, block_s)
    yds = wkv6_scan_mt_kernel(*[o for o in ops if o is not None],
                              block_s=bs, interpret=interpret,
                              emit_primal=False)
    return yds[:, :, :S].reshape(T, B, H, S, hd).transpose(0, 1, 3, 2, 4)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def wkv6_scan_mt_jvps(r, k, v, w, u, rds, kds, vds, wds, gy, uds=None,
                      block_s: int = 64, interpret: bool = True):
    """Fused jvp-contraction epilogue -> jvps (T,) fp32 = <gy, ydot_t>.

    Same operand contract as ``wkv6_scan_mt`` plus the output cotangent
    gy: (B,S,H,hd); the T tangent outputs are contracted inside the kernel
    and never reach HBM (only (BH, T) per-row partials do)."""
    ops, (B, S, H, hd, T, bs) = _mt_layout(r, k, v, w, u, rds, kds, vds, wds,
                                           uds, block_s)
    rb, kb, vb, wb, ub, rdb, kdb, vdb, wdb, udb = ops
    # zero-padded gy rows contribute exactly 0 to every partial
    gyb = gy.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    pad = (-S) % bs
    if pad:
        gyb = jnp.pad(gyb, ((0, 0), (0, pad), (0, 0)))
    parts = wkv6_scan_mt_jvps_kernel(rb, kb, vb, wb, ub, rdb, kdb, vdb, wdb,
                                     gyb, udb, block_s=bs,
                                     interpret=interpret)
    return parts.sum(axis=0)
