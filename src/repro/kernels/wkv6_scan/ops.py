"""jit'd wrapper: (B,S,H,hd) <-> (B*H, S, hd) layout + padding of S."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6_scan.kernel import wkv6_scan_kernel


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def wkv6_scan(r, k, v, w, u, block_s: int = 64, interpret: bool = True):
    """r,k,v,w: (B,S,H,hd); u: (H,hd). Returns y (B,S,H,hd) fp32.

    Fresh state per call (training semantics); the decode path keeps its
    state outside and uses the jnp reference for single steps.
    """
    B, S, H, hd = r.shape
    bs = min(block_s, S)
    pad = (-S) % bs

    def to_bh(t):
        t = t.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        return t

    # pad w with ones (decay) so padded steps keep state intact — irrelevant
    # anyway since padded y rows are dropped
    rb, kb, vb = to_bh(r), to_bh(k), to_bh(v)
    wb = to_bh(w)
    if pad:
        wb = wb.at[:, S:, :].set(1.0)
    ub = jnp.broadcast_to(u.astype(jnp.float32)[None], (B, H, hd)).reshape(B * H, hd)
    y = wkv6_scan_kernel(rb, kb, vb, wb, ub, block_s=bs, interpret=interpret)
    y = y[:, :S].reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return y
