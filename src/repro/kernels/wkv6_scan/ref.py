"""Pure-jnp oracle for the RWKV6 WKV recurrence (matches
repro.models.ssm.wkv6_recurrence semantics):

    y_t = r_t^T (S_{t-1} + (u*k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_scan_ref(r, k, v, w, u, state=None):
    """r,k,v,w: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd) or None.
    Returns (y (B,S,H,hd), final_state)."""
    B, S, H, hd = r.shape
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        yt = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, yt

    xs = jax.tree.map(lambda a: a.transpose(1, 0, 2, 3), (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state
