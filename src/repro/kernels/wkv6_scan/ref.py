"""Pure-jnp oracle for the RWKV6 WKV recurrence (matches
repro.models.ssm.wkv6_recurrence semantics):

    y_t = r_t^T (S_{t-1} + (u*k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_scan_ref(r, k, v, w, u, state=None):
    """r,k,v,w: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd) or None.
    Returns (y (B,S,H,hd), final_state)."""
    B, S, H, hd = r.shape
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        yt = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, yt

    xs = jax.tree.map(lambda a: a.transpose(1, 0, 2, 3), (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def wkv6_scan_mt_ref(r, k, v, w, u, rds, kds, vds, wds, uds=None):
    """Multi-tangent oracle: (y, ydots) via T independent ``jax.jvp`` calls
    of the single-tangent reference — the column-by-column semantics the mt
    kernel fuses. Tangents carry a leading T axis (uds may be None)."""
    T = rds.shape[0]
    y, _ = wkv6_scan_ref(r, k, v, w, u)

    def f(r_, k_, v_, w_, u_):
        return wkv6_scan_ref(r_, k_, v_, w_, u_)[0]

    def one(tangents):
        rd, kd, vd, wd, ud = tangents
        return jax.jvp(f, (r, k, v, w, u), (rd, kd, vd, wd, ud))[1]

    uds_ = uds if uds is not None else jnp.zeros((T,) + u.shape, jnp.float32)
    yds = jax.vmap(one)((rds, kds, vds, wds, uds_))
    return y, yds


def wkv6_scan_mt_jvps_ref(r, k, v, w, u, rds, kds, vds, wds, gy, uds=None):
    """Oracle for the fused jvp-contraction epilogue: materializes all T
    ydots via ``wkv6_scan_mt_ref`` and contracts them against the output
    cotangent ``gy`` (B,S,H,hd) -> (T,) fp32."""
    _, yds = wkv6_scan_mt_ref(r, k, v, w, u, rds, kds, vds, wds, uds)
    return jnp.einsum("bshd,tbshd->t", gy.astype(jnp.float32),
                      yds.astype(jnp.float32))
