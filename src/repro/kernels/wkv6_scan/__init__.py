from repro.kernels.wkv6_scan.ops import (
    wkv6_scan,
    wkv6_scan_mt,
    wkv6_scan_mt_jvps,
    wkv6_scan_mt_tangents,
)
from repro.kernels.wkv6_scan.ref import (
    wkv6_scan_mt_jvps_ref,
    wkv6_scan_mt_ref,
    wkv6_scan_ref,
)
