from repro.kernels.wkv6_scan.ops import wkv6_scan
from repro.kernels.wkv6_scan.ref import wkv6_scan_ref
