"""Version compatibility for the Pallas TPU API surface.

jax renamed ``TPUCompilerParams`` to ``CompilerParams`` across releases; the
kernels import the resolved name from here so they run on either.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
