"""Backend dispatch for the fused LoRA projection.

``models/common.py::proj`` routes every LoRA-adapted projection through
``lora_proj`` below, whose custom-JVP rule evaluates the primal AND tangent
with the fused dual kernel instead of the pure-jnp mirror:

    backend 'pallas'     compiled Pallas TPU kernel (kernels/lora_dual)
    backend 'interpret'  same kernel under the Pallas interpreter (CPU
                         validation of the exact kernel dataflow)
    backend 'jnp'        reference einsum/matmul mirror — the fast CPU path
                         (XLA fuses it; interpret-mode Pallas would be
                         orders of magnitude slower in the test suite)

Resolution: ``REPRO_LORA_BACKEND`` env var if set (one of auto | jnp |
interpret | pallas), else 'pallas' when jax's default backend is TPU, else
'jnp'. ``set_backend`` overrides per-process (tests).

The kernel route additionally requires being inside ``forward_ad_region()``
(established by core/forward_grad.py while tracing the estimator): Pallas
calls have no transpose rule, so outside that region — in particular under
``jax.grad`` in the backprop baselines — the rule always traces the jnp
mirror, keeping reverse-mode AD working on every backend.

Tangent-axis note: under the batched K-tangent estimator
(core/forward_grad.py) the tangent side of the JVP rule is batched by vmap —
tangent operands gain the leading K axis while primal operands stay
unbatched, which is exactly the multi-tangent kernel contract. The compiled
TPU route currently lowers vmap-of-dual-kernel through the Pallas batching
rule; routing it through ``lora_dual_mt`` directly via a custom batching
rule is an open item (ROADMAP).
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import os

import jax
import jax.numpy as jnp
from jax.custom_derivatives import SymbolicZero

from repro.kernels.lora_dual.ops import lora_dual_mt_tangents

# Pallas calls have no transpose rule, so the kernel tangent route would
# break reverse-mode AD (the backprop baselines) if taken unconditionally.
# The kernel route is therefore gated on a trace-time region that only the
# forward-gradient estimator (core/forward_grad.py) establishes; any other
# differentiation — jax.grad/value_and_grad in the baselines, or user code —
# traces the transposable jnp mirror regardless of backend.
_fwd_region = contextvars.ContextVar("repro_forward_ad_region", default=False)


@contextlib.contextmanager
def forward_ad_region():
    """Trace-time marker: within this context, LoRA projection tangents may
    lower to the (non-transposable) fused Pallas kernel."""
    token = _fwd_region.set(True)
    try:
        yield
    finally:
        _fwd_region.reset(token)


def in_forward_ad_region() -> bool:
    return _fwd_region.get()

_BACKENDS = ("auto", "jnp", "interpret", "pallas")
_backend_override: str | None = None


def set_backend(name: str | None) -> None:
    """Force a dispatch backend for this process (None restores 'auto')."""
    global _backend_override
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {name!r}")
    _backend_override = name


def get_backend() -> str:
    """Resolved backend: override > $REPRO_LORA_BACKEND > platform default."""
    name = _backend_override or os.environ.get("REPRO_LORA_BACKEND", "auto")
    if name not in _BACKENDS:
        raise ValueError(
            f"REPRO_LORA_BACKEND must be one of {_BACKENDS}, got {name!r}")
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return name


def _lora_terms(x, a, b, scale):
    """The rank-r update s*(x@A)@B computed in A's dtype (fp32 master LoRA
    weights), mirroring the pre-dispatch pure-jnp proj numerics exactly."""
    return (x.astype(a.dtype) @ a) @ b * scale


@functools.partial(jax.custom_jvp, nondiff_argnums=(4,))
def lora_proj(x, w, a, b, scale):
    """y = x@W + s*(x@A)@B with a dispatchable fused-dual JVP rule."""
    y = x @ w
    return y + _lora_terms(x, a, b, scale).astype(y.dtype)


def _materialize(t, like):
    if isinstance(t, SymbolicZero):
        return jnp.zeros(like.shape, like.dtype)
    return t


@functools.partial(lora_proj.defjvp, symbolic_zeros=True)
def _lora_proj_jvp(scale, primals, tangents):
    x, w, a, b = primals
    xd, wd, ad, bd = tangents
    has_xd = not isinstance(xd, SymbolicZero)
    has_wd = not isinstance(wd, SymbolicZero)
    backend = get_backend()

    if backend in ("pallas", "interpret") and in_forward_ad_region():
        # primal from the jnp mirror (must stay tangent-independent so
        # linearize can split the rule); tangents from the fused kernel —
        # one pass over x/W per tangent group
        y = x @ w
        y = y + _lora_terms(x, a, b, scale).astype(y.dtype)
        yd = lora_dual_mt_tangents(
            x, None if not has_xd else xd[None], w,
            a, _materialize(ad, a)[None], b, _materialize(bd, b)[None],
            scale=scale, interpret=(backend == "interpret"))[0]
        if has_wd:  # frozen W in SPRY; handled for AD completeness
            yd = yd + (x @ wd).astype(yd.dtype)
        return y, yd

    # 'jnp': reference mirror with symbolic-zero pruning — ops whose inputs
    # carry no tangent never enter the graph (so under the batched estimator
    # only tangent-carrying terms gain the K axis)
    y = x @ w
    y = y + _lora_terms(x, a, b, scale).astype(y.dtype)

    x32 = x.astype(a.dtype)
    u = x32 @ a
    ud = None
    if has_xd:
        ud = xd.astype(a.dtype) @ a
    if not isinstance(ad, SymbolicZero):
        ud = x32 @ ad if ud is None else ud + x32 @ ad
    lo_d = None
    if ud is not None:
        lo_d = (ud @ b) * scale
    if not isinstance(bd, SymbolicZero):
        t = (u @ bd) * scale
        lo_d = t if lo_d is None else lo_d + t
    yd = jnp.zeros(y.shape, y.dtype) if (lo_d is None and not has_xd
                                         and not has_wd) else None
    if yd is None:
        yd = lo_d.astype(y.dtype) if lo_d is not None else 0.0
        if has_xd:
            yd = xd @ w + yd
        if has_wd:
            yd = yd + x @ wd
    return y, yd
