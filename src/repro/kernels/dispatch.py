"""Backend dispatch for the fused forward-gradient kernels.

``models/common.py::proj`` routes every LoRA-adapted projection through
``lora_proj`` below, whose custom-JVP rule evaluates the primal AND tangent
with the fused dual kernel instead of the pure-jnp mirror; the sequence
mixers route the same way — ``models/ssm.py`` (RWKV6) through ``wkv6_mix``,
``models/ssm.py`` (Mamba2) through ``mamba2_mix`` and
``models/attention.py`` (SWA prefill) through ``swa_attend``:

    backend 'pallas'     compiled Pallas TPU kernels (kernels/lora_dual,
                         kernels/wkv6_scan, kernels/swa_attention)
    backend 'interpret'  same kernels under the Pallas interpreter (CPU
                         validation of the exact kernel dataflow)
    backend 'jnp'        reference einsum/scan mirrors — the fast CPU path
                         (XLA fuses them; interpret-mode Pallas would be
                         orders of magnitude slower in the test suite)

Resolution: ``REPRO_LORA_BACKEND`` env var if set (one of auto | jnp |
interpret | pallas), else 'pallas' when jax's default backend is TPU, else
'jnp'. ``set_backend`` overrides per-process (tests).

The kernel route additionally requires being inside ``forward_ad_region()``
(established by core/forward_grad.py while tracing the estimator): Pallas
calls have no transpose rule, so outside that region — in particular under
``jax.grad`` in the backprop baselines — the rules always trace the jnp
mirror, keeping reverse-mode AD working on every backend. The mixer call
sites additionally gate on ``use_kernel_mixers()`` so the pure-jnp model
paths are untouched byte-for-byte on the 'jnp' backend.

Tangent-axis contract
---------------------
Under the batched K-tangent estimator (core/forward_grad.py) the tangent
side of each JVP rule is batched by vmap — tangent operands gain the
leading K axis while primal operands stay unbatched, which is exactly the
multi-tangent kernel contract (``lora_dual_mt_tangents``,
``wkv6_scan_mt_tangents``, ``swa_attention_mt_tangents``: tangents carry a
leading T axis; one pass over the primal serves all T tangents). The
tangent calls are wrapped in ``jax.custom_batching.custom_vmap`` so that
vmap-of-tangents lowers DIRECTLY to the T=K multi-tangent kernel — one
fused pallas_call per projection/mixer — instead of the Pallas default
batching rule (which would re-grid the T=1 kernel over K and recompute the
primal per tangent). Unexpected batching patterns (e.g. a batched primal)
fall back to a sequential ``lax.map`` of the T=1 kernel, which is always
correct.

Cotangent-known route (contraction epilogues)
---------------------------------------------
When the estimator can supply the output cotangent ``gy`` of a site — the
last-mixer / loss-head pattern, where everything downstream of the site is
cheap enough to reverse once — the jvp contribution of the site collapses
to the T scalars <gy, ydot_t>, and the ``*_jvp_contract`` ops below compute
them WITHOUT ever materializing a (T, ..., N) tangent output: their
custom-vmap lowering picks the ``*_mt_jvps`` contraction-epilogue kernel
(per-tangent partials accumulated blockwise in VMEM; only per-block scalars
reach HBM) instead of ``*_mt_tangents``. On the 'jnp' backend the lora
route is the reassociated einsum mirror of the same math (still no
(T, M, N) buffer); the wkv6/swa jnp mirrors materialize-and-contract and
rely on XLA fusion — the memory claim is a kernel-backend property.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import os

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap
from jax.custom_derivatives import SymbolicZero

from repro.kernels.lora_dual.ops import (
    lora_dual_mt_jvps,
    lora_dual_mt_tangents,
    lora_dual_multi,
)
from repro.kernels.mamba2_scan import ops as mamba2_ops
from repro.kernels.mamba2_scan.ref import mamba2_scan_ref
from repro.kernels.swa_attention.ops import (
    swa_attention,
    swa_attention_mt_jvps,
    swa_attention_mt_tangents,
)
from repro.kernels.swa_attention.ref import swa_attention_gqa_ref
from repro.kernels.wkv6_scan.ops import (
    wkv6_scan,
    wkv6_scan_mt_jvps,
    wkv6_scan_mt_tangents,
)
from repro.kernels.wkv6_scan.ref import wkv6_scan_ref

# Pallas calls have no transpose rule, so the kernel tangent route would
# break reverse-mode AD (the backprop baselines) if taken unconditionally.
# The kernel route is therefore gated on a trace-time region that only the
# forward-gradient estimator (core/forward_grad.py) establishes; any other
# differentiation — jax.grad/value_and_grad in the baselines, or user code —
# traces the transposable jnp mirror regardless of backend.
_fwd_region = contextvars.ContextVar("repro_forward_ad_region", default=False)


@contextlib.contextmanager
def forward_ad_region():
    """Trace-time marker: within this context, LoRA projection and sequence
    mixer tangents may lower to the (non-transposable) fused Pallas
    kernels."""
    token = _fwd_region.set(True)
    try:
        yield
    finally:
        _fwd_region.reset(token)


def in_forward_ad_region() -> bool:
    return _fwd_region.get()

_BACKENDS = ("auto", "jnp", "interpret", "pallas")
_backend_override: str | None = None


def set_backend(name: str | None) -> None:
    """Force a dispatch backend for this process (None restores 'auto')."""
    global _backend_override
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {name!r}")
    _backend_override = name


def get_backend() -> str:
    """Resolved backend: override > $REPRO_LORA_BACKEND > platform default."""
    name = _backend_override or os.environ.get("REPRO_LORA_BACKEND", "auto")
    if name not in _BACKENDS:
        raise ValueError(
            f"REPRO_LORA_BACKEND must be one of {_BACKENDS}, got {name!r}")
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return name


def use_kernel_mixers() -> bool:
    """True when the sequence-mixer call sites (models/ssm.py,
    models/attention.py) should route through the dispatched ops below:
    inside the estimator's forward-AD region on a kernel backend. On the
    'jnp' backend the model keeps its native scan/chunked paths untouched."""
    return in_forward_ad_region() and get_backend() in ("pallas", "interpret")


def _materialize(t, like):
    if isinstance(t, SymbolicZero):
        return jnp.zeros(like.shape, like.dtype)
    return t


def _map_fallback(axis_size, in_batched, args, f):
    """custom_vmap fallback for unexpected batching patterns: broadcast the
    unbatched operands and run the T=1 tangent kernel sequentially."""
    args_b = tuple(
        a if b else jnp.broadcast_to(a, (axis_size,) + jnp.shape(a))
        for a, b in zip(args, in_batched))
    return jax.lax.map(lambda xs: f(*xs), args_b), True


def _stack_tangents(axis_size, tangents, batched):
    """Give every tangent the leading T axis. Unbatched tangents (e.g. a
    symbolic zero materialized at linearize time — the same constant for all
    K lanes) are broadcast, so the mt route still fires whenever the
    PRIMALS are unbatched."""
    return tuple(
        t if b else jnp.broadcast_to(t, (axis_size,) + jnp.shape(t))
        for t, b in zip(tangents, batched))


# ---------------------------------------------------------------------------
# Multi-tangent batching rules (vmap-of-tangents -> one mt kernel call)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _lora_tangent_fn(scale: float, has_xd: bool, interpret: bool):
    """Tangent-only LoRA jvp, custom-vmapped so K stacked tangents lower to
    ONE ``lora_dual_mt_tangents`` call (T=K) instead of K re-gridded T=1
    Pallas calls."""
    if has_xd:
        def base(x, w, a, b, xd, ad, bd):
            return lora_dual_mt_tangents(
                x, xd[None], w, a, ad[None], b, bd[None], scale=scale,
                interpret=interpret)[0]

        f = custom_vmap(base)

        @f.def_vmap
        def _rule(axis_size, in_batched, x, w, a, b, xd, ad, bd):
            xb, wb, ab, bb = in_batched[:4]
            if not (xb or wb or ab or bb):
                xd, ad, bd = _stack_tangents(axis_size, (xd, ad, bd),
                                             in_batched[4:])
                return lora_dual_mt_tangents(
                    x, xd, w, a, ad, b, bd, scale=scale,
                    interpret=interpret), True
            return _map_fallback(axis_size, in_batched,
                                 (x, w, a, b, xd, ad, bd), base)
    else:
        def base(x, w, a, b, ad, bd):
            return lora_dual_mt_tangents(
                x, None, w, a, ad[None], b, bd[None], scale=scale,
                interpret=interpret)[0]

        f = custom_vmap(base)

        @f.def_vmap
        def _rule(axis_size, in_batched, x, w, a, b, ad, bd):
            xb, wb, ab, bb = in_batched[:4]
            if not (xb or wb or ab or bb):
                ad, bd = _stack_tangents(axis_size, (ad, bd), in_batched[4:])
                return lora_dual_mt_tangents(
                    x, None, w, a, ad, b, bd, scale=scale,
                    interpret=interpret), True
            return _map_fallback(axis_size, in_batched,
                                 (x, w, a, b, ad, bd), base)
    return f


@functools.lru_cache(maxsize=None)
def _wkv6_tangent_fn(has_ud: bool, interpret: bool):
    """Tangent-only WKV6 jvp, custom-vmapped onto ``wkv6_scan_mt_tangents``
    (one primal state walk for all K tangents)."""
    if has_ud:
        def base(r, k, v, w, u, rd, kd, vd, wd, ud):
            return wkv6_scan_mt_tangents(
                r, k, v, w, u, rd[None], kd[None], vd[None], wd[None],
                ud[None], interpret=interpret)[0]

        f = custom_vmap(base)

        @f.def_vmap
        def _rule(axis_size, in_batched, r, k, v, w, u, rd, kd, vd, wd, ud):
            pb, tb = in_batched[:5], in_batched[5:]
            if not any(pb):
                rd, kd, vd, wd, ud = _stack_tangents(
                    axis_size, (rd, kd, vd, wd, ud), tb)
                return wkv6_scan_mt_tangents(
                    r, k, v, w, u, rd, kd, vd, wd, ud,
                    interpret=interpret), True
            return _map_fallback(axis_size, in_batched,
                                 (r, k, v, w, u, rd, kd, vd, wd, ud), base)
    else:
        def base(r, k, v, w, u, rd, kd, vd, wd):
            return wkv6_scan_mt_tangents(
                r, k, v, w, u, rd[None], kd[None], vd[None], wd[None],
                interpret=interpret)[0]

        f = custom_vmap(base)

        @f.def_vmap
        def _rule(axis_size, in_batched, r, k, v, w, u, rd, kd, vd, wd):
            pb, tb = in_batched[:5], in_batched[5:]
            if not any(pb):
                rd, kd, vd, wd = _stack_tangents(axis_size,
                                                 (rd, kd, vd, wd), tb)
                return wkv6_scan_mt_tangents(
                    r, k, v, w, u, rd, kd, vd, wd, interpret=interpret), True
            return _map_fallback(axis_size, in_batched,
                                 (r, k, v, w, u, rd, kd, vd, wd), base)
    return f


@functools.lru_cache(maxsize=None)
def _swa_tangent_fn(window, interpret: bool):
    """Tangent-only SWA jvp, custom-vmapped onto
    ``swa_attention_mt_tangents`` (one online-softmax walk for all K
    tangents)."""
    def base(q, k, v, qd, kd, vd):
        return swa_attention_mt_tangents(
            q, k, v, qd[None], kd[None], vd[None], window=window,
            interpret=interpret)[0]

    f = custom_vmap(base)

    @f.def_vmap
    def _rule(axis_size, in_batched, q, k, v, qd, kd, vd):
        pb, tb = in_batched[:3], in_batched[3:]
        if not any(pb):
            qd, kd, vd = _stack_tangents(axis_size, (qd, kd, vd), tb)
            return swa_attention_mt_tangents(
                q, k, v, qd, kd, vd, window=window, interpret=interpret), True
        return _map_fallback(axis_size, in_batched, (q, k, v, qd, kd, vd),
                             base)
    return f


@functools.lru_cache(maxsize=None)
def _mamba2_tangent_fn(interpret: bool):
    """Tangent-only Mamba2 jvp, custom-vmapped onto
    ``mamba2_scan_mt_tangents`` (one primal state walk for all K
    tangents)."""
    def base(xdt, bm, cm, dec, xd, bd, cd, dd):
        return mamba2_ops.mamba2_scan_mt_tangents(
            xdt, bm, cm, dec, xd[None], bd[None], cd[None], dd[None],
            interpret=interpret)[0]

    f = custom_vmap(base)

    @f.def_vmap
    def _rule(axis_size, in_batched, xdt, bm, cm, dec, xd, bd, cd, dd):
        pb, tb = in_batched[:4], in_batched[4:]
        if not any(pb):
            xd, bd, cd, dd = _stack_tangents(axis_size, (xd, bd, cd, dd), tb)
            return mamba2_ops.mamba2_scan_mt_tangents(
                xdt, bm, cm, dec, xd, bd, cd, dd, interpret=interpret), True
        return _map_fallback(axis_size, in_batched,
                             (xdt, bm, cm, dec, xd, bd, cd, dd), base)
    return f


# ---------------------------------------------------------------------------
# LoRA projection
# ---------------------------------------------------------------------------

def _lora_terms(x, a, b, scale):
    """The rank-r update s*(x@A)@B computed in A's dtype (fp32 master LoRA
    weights), mirroring the pre-dispatch pure-jnp proj numerics exactly."""
    return (x.astype(a.dtype) @ a) @ b * scale


@functools.partial(jax.custom_jvp, nondiff_argnums=(4,))
def lora_proj(x, w, a, b, scale):
    """y = x@W + s*(x@A)@B with a dispatchable fused-dual JVP rule."""
    y = x @ w
    return y + _lora_terms(x, a, b, scale).astype(y.dtype)


@functools.partial(lora_proj.defjvp, symbolic_zeros=True)
def _lora_proj_jvp(scale, primals, tangents):
    x, w, a, b = primals
    xd, wd, ad, bd = tangents
    has_xd = not isinstance(xd, SymbolicZero)
    has_wd = not isinstance(wd, SymbolicZero)
    backend = get_backend()

    if backend in ("pallas", "interpret") and in_forward_ad_region():
        # primal from the jnp mirror (must stay tangent-independent so
        # linearize can split the rule); tangents from the fused kernel —
        # one pass over x/W per tangent group. The custom-vmapped tangent fn
        # makes the batched estimator's vmap collapse K tangents into ONE
        # mt kernel call.
        y = x @ w
        y = y + _lora_terms(x, a, b, scale).astype(y.dtype)
        fn = _lora_tangent_fn(scale, has_xd, backend == "interpret")
        ad_m, bd_m = _materialize(ad, a), _materialize(bd, b)
        if has_xd:
            yd = fn(x, w, a, b, xd, ad_m, bd_m)
        else:
            yd = fn(x, w, a, b, ad_m, bd_m)
        if has_wd:  # frozen W in SPRY; handled for AD completeness
            yd = yd + (x @ wd).astype(yd.dtype)
        return y, yd

    # 'jnp': reference mirror with symbolic-zero pruning — ops whose inputs
    # carry no tangent never enter the graph (so under the batched estimator
    # only tangent-carrying terms gain the K axis)
    y = x @ w
    y = y + _lora_terms(x, a, b, scale).astype(y.dtype)

    x32 = x.astype(a.dtype)
    u = x32 @ a
    ud = None
    if has_xd:
        ud = xd.astype(a.dtype) @ a
    if not isinstance(ad, SymbolicZero):
        ud = x32 @ ad if ud is None else ud + x32 @ ad
    lo_d = None
    if ud is not None:
        lo_d = (ud @ b) * scale
    if not isinstance(bd, SymbolicZero):
        t = (u @ bd) * scale
        lo_d = t if lo_d is None else lo_d + t
    yd = jnp.zeros(y.shape, y.dtype) if (lo_d is None and not has_xd
                                         and not has_wd) else None
    if yd is None:
        yd = lo_d.astype(y.dtype) if lo_d is not None else 0.0
        if has_xd:
            yd = xd @ w + yd
        if has_wd:
            yd = yd + x @ wd
    return y, yd


# ---------------------------------------------------------------------------
# Multi-adapter LoRA projection (serving path)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _lora_multi_fn(scale: float, backend: str):
    """Per-row multi-adapter projection, custom-vmapped so a batch of rows
    each carrying its own adapter index lowers to ONE ``lora_dual_multi``
    pallas_call (one pass over the shared frozen W for the whole
    heterogeneous batch) on kernel backends, and to the gathered-einsum jnp
    mirror on 'jnp'. The unbatched base is exactly the single-adapter
    ``lora_proj`` primal with the pair gathered from the page stacks, so
    every row is bitwise-equal to single-adapter serving."""
    def base(x, aidx, w, a_stack, b_stack):
        a = a_stack[aidx]
        b = b_stack[aidx]
        y = x @ w
        return y + _lora_terms(x, a, b, scale).astype(y.dtype)

    f = custom_vmap(base)

    @f.def_vmap
    def _rule(axis_size, in_batched, x, aidx, w, a_stack, b_stack):
        xb, ib, wb, ab, bb = in_batched
        if xb and ib and not (wb or ab or bb):
            if backend in ("pallas", "interpret"):
                return lora_dual_multi(
                    x, aidx, w, a_stack, b_stack, scale=scale,
                    interpret=backend == "interpret"), True
            # jnp mirror: gather the per-row pairs, keep the per-row math
            # of ``base`` (x32 @ A) @ B * s — batched matmuls are
            # row-independent, so this stays bitwise per row
            a_sel = jnp.take(a_stack, aidx, axis=0)        # (B, K, r)
            b_sel = jnp.take(b_stack, aidx, axis=0)        # (B, r, N)
            y = x @ w
            u = jnp.einsum("b...k,bkr->b...r", x.astype(a_stack.dtype),
                           a_sel)
            lo = jnp.einsum("b...r,brn->b...n", u, b_sel) * scale
            return y + lo.astype(y.dtype), True
        return _map_fallback(axis_size, in_batched,
                             (x, aidx, w, a_stack, b_stack), base)
    return f


def lora_proj_multi(x, idx, w, a_stack, b_stack, scale=1.0):
    """Batched multi-adapter projection: row b of ``x`` (B, ..., K)
    projects through adapter page ``idx[b]`` of the (P, K, r)/(P, r, N)
    page stacks — y[b] = x[b] @ W + s*(x[b] @ A[idx[b]]) @ B[idx[b]].
    The vmap over rows collapses to one multi-adapter kernel call
    (pallas/interpret) or the gathered batched mirror ('jnp'); either way
    the frozen-W GEMM runs once for the whole batch."""
    fn = _lora_multi_fn(float(scale), get_backend())
    return jax.vmap(fn, in_axes=(0, 0, None, None, None))(
        x, idx, w, a_stack, b_stack)


# ---------------------------------------------------------------------------
# RWKV6 WKV recurrence (fresh-state training path)
# ---------------------------------------------------------------------------

@jax.custom_jvp
def wkv6_mix(r, k, v, w, u):
    """y = WKV6(r, k, v, w, u) from a fresh state — the training-path
    sequence mixer. r,k,v,w: (B,S,H,hd) fp32; u: (H,hd). The primal is the
    jnp scan mirror (bit-identical to models/ssm.py::wkv6_recurrence); the
    JVP rule lowers tangents to ``wkv6_scan_mt_tangents`` on kernel
    backends inside ``forward_ad_region()``."""
    return wkv6_scan_ref(r, k, v, w, u)[0]


@functools.partial(wkv6_mix.defjvp, symbolic_zeros=True)
def _wkv6_mix_jvp(primals, tangents):
    r, k, v, w, u = primals
    rd, kd, vd, wd, ud = tangents
    backend = get_backend()
    if backend in ("pallas", "interpret") and in_forward_ad_region():
        # primal (tangent-independent, so linearize still splits the rule):
        # the compiled state-walk kernel on TPU — the jnp scan pays the
        # per-token HBM round-trip of the (hd,hd) state the kernel exists to
        # remove; under the interpreter keep the fast XLA scan (the kernel
        # dataflow is already exercised by the tangent route)
        if backend == "pallas":
            y = wkv6_scan(r, k, v, w, u, interpret=False)
        else:
            y = wkv6_scan_ref(r, k, v, w, u)[0]
        has_ud = not isinstance(ud, SymbolicZero)
        fn = _wkv6_tangent_fn(has_ud, backend == "interpret")
        args = (r, k, v, w, u, _materialize(rd, r), _materialize(kd, k),
                _materialize(vd, v), _materialize(wd, w))
        if has_ud:
            args += (ud,)
        return y, fn(*args)

    def f(r_, k_, v_, w_, u_):
        return wkv6_scan_ref(r_, k_, v_, w_, u_)[0]

    return jax.jvp(f, primals, (
        _materialize(rd, r), _materialize(kd, k), _materialize(vd, v),
        _materialize(wd, w), _materialize(ud, u)))


# ---------------------------------------------------------------------------
# Mamba2 state recurrence (fresh-state training path)
# ---------------------------------------------------------------------------

@jax.custom_jvp
def mamba2_mix(xdt, bmat, cmat, decay):
    """y = Mamba2 recurrence from a fresh state — the training-path
    sequence mixer. xdt: (B,S,H,hd) fp32 (the dt-premultiplied input
    xh * dt); bmat,cmat: (B,S,N); decay: (B,S,H). The primal is the jnp
    scan mirror (bit-identical to the scan inside models/ssm.py::
    mamba2_mix); the JVP rule lowers tangents to
    ``mamba2_scan_mt_tangents`` on kernel backends inside
    ``forward_ad_region()``."""
    return mamba2_scan_ref(xdt, bmat, cmat, decay)[0]


@functools.partial(mamba2_mix.defjvp, symbolic_zeros=True)
def _mamba2_mix_jvp(primals, tangents):
    xdt, bm, cm, dec = primals
    xd, bd, cd, dd = tangents
    backend = get_backend()
    if backend in ("pallas", "interpret") and in_forward_ad_region():
        # primal (tangent-independent, so linearize still splits the rule):
        # the compiled state-walk kernel on TPU — the jnp scan pays the
        # per-token HBM round-trip of the (hd,N) state; under the
        # interpreter keep the fast XLA scan (the kernel dataflow is
        # already exercised by the tangent route)
        if backend == "pallas":
            y = mamba2_ops.mamba2_scan(xdt, bm, cm, dec, interpret=False)
        else:
            y = mamba2_scan_ref(xdt, bm, cm, dec)[0]
        fn = _mamba2_tangent_fn(backend == "interpret")
        return y, fn(xdt, bm, cm, dec, _materialize(xd, xdt),
                     _materialize(bd, bm), _materialize(cd, cm),
                     _materialize(dd, dec))

    def f(x_, b_, c_, d_):
        return mamba2_scan_ref(x_, b_, c_, d_)[0]

    return jax.jvp(f, primals, (
        _materialize(xd, xdt), _materialize(bd, bm), _materialize(cd, cm),
        _materialize(dd, dec)))


# ---------------------------------------------------------------------------
# Sliding-window attention (prefill/training path)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_jvp, nondiff_argnums=(3,))
def swa_attend(q, k, v, window):
    """Causal (sliding-window) GQA attention, kernel layout: q (B,H,S,hd);
    k,v (B,KV,S,hd), contiguous query-head groups. The primal is the grouped
    jnp mirror (no repeated K/V); the JVP rule lowers tangents to
    ``swa_attention_mt_tangents`` on kernel backends inside
    ``forward_ad_region()``."""
    return swa_attention_gqa_ref(q, k, v, window=window)


@functools.partial(swa_attend.defjvp, symbolic_zeros=True)
def _swa_attend_jvp(window, primals, tangents):
    q, k, v = primals
    qd, kd, vd = tangents
    backend = get_backend()
    if backend in ("pallas", "interpret") and in_forward_ad_region():
        # primal via the flash kernel on TPU: the grouped jnp mirror
        # materializes the (S, S) score tensor, which would make every
        # estimate's primal quadratic in memory; under the interpreter the
        # mirror is the fast CPU path
        if backend == "pallas":
            y = swa_attention(q, k, v, window=window, interpret=False)
        else:
            y = swa_attention_gqa_ref(q, k, v, window=window)
        fn = _swa_tangent_fn(window, backend == "interpret")
        return y, fn(q, k, v, _materialize(qd, q), _materialize(kd, k),
                     _materialize(vd, v))

    def f(q_, k_, v_):
        return swa_attention_gqa_ref(q_, k_, v_, window=window)

    return jax.jvp(f, primals, (
        _materialize(qd, q), _materialize(kd, k), _materialize(vd, v)))


# ---------------------------------------------------------------------------
# Cotangent-known contraction route: <gy, ydot_t> without tangent outputs
# ---------------------------------------------------------------------------

def _vdot32(a, b):
    return jnp.vdot(a.astype(jnp.float32), b.astype(jnp.float32))


@functools.lru_cache(maxsize=None)
def _lora_contract_fn(scale: float, has_xd: bool, backend: str):
    """Single-tangent <gy, lora-ydot>, custom-vmapped so K stacked tangents
    lower to ONE ``lora_dual_mt_jvps`` epilogue call (T=K) — the fused
    contraction kernel on pallas/interpret backends, the reassociated
    einsum mirror on 'jnp'. Neither ever materializes a (K, M, N) tangent
    stack."""
    kw = dict(scale=scale, impl="kernel" if backend in ("pallas", "interpret")
              else "reassoc", interpret=backend == "interpret")
    if has_xd:
        def base(gy, x, w, a, b, xd, ad, bd):
            return lora_dual_mt_jvps(x, w, a, ad[None], b, bd[None], gy,
                                     xdots=xd[None], **kw)[0]

        f = custom_vmap(base)

        @f.def_vmap
        def _rule(axis_size, in_batched, gy, x, w, a, b, xd, ad, bd):
            if not any(in_batched[:5]):
                xd, ad, bd = _stack_tangents(axis_size, (xd, ad, bd),
                                             in_batched[5:])
                return lora_dual_mt_jvps(x, w, a, ad, b, bd, gy, xdots=xd,
                                         **kw), True
            return _map_fallback(axis_size, in_batched,
                                 (gy, x, w, a, b, xd, ad, bd), base)
    else:
        def base(gy, x, w, a, b, ad, bd):
            return lora_dual_mt_jvps(x, w, a, ad[None], b, bd[None], gy,
                                     **kw)[0]

        f = custom_vmap(base)

        @f.def_vmap
        def _rule(axis_size, in_batched, gy, x, w, a, b, ad, bd):
            if not any(in_batched[:5]):
                ad, bd = _stack_tangents(axis_size, (ad, bd), in_batched[5:])
                return lora_dual_mt_jvps(x, w, a, ad, b, bd, gy, **kw), True
            return _map_fallback(axis_size, in_batched,
                                 (gy, x, w, a, b, ad, bd), base)
    return f


def lora_jvp_contract(gy, x, w, a, b, ad, bd, xd=None, *, scale=1.0):
    """jvp partial of a LoRA projection site against a known cotangent:
    <gy, ydot> for tangents (xd, ad, bd) — ``xd=None`` statically removes
    the input-tangent terms (the projection is the first perturbed unit).
    Under the batched estimator's vmap this lowers to ONE ``_jvps``
    epilogue kernel call with no (K, M, N) tangent output."""
    fn = _lora_contract_fn(float(scale), xd is not None, get_backend())
    if xd is not None:
        return fn(gy, x, w, a, b, xd, ad, bd)
    return fn(gy, x, w, a, b, ad, bd)


@functools.lru_cache(maxsize=None)
def _wkv6_contract_fn(has_ud: bool, backend: str):
    """Single-tangent <gy, wkv6-ydot>, custom-vmapped onto
    ``wkv6_scan_mt_jvps`` (per-token contraction inside the state walk) on
    kernel backends; jnp mirror materializes-and-contracts (XLA fuses)."""
    if backend not in ("pallas", "interpret"):
        def jnp_base(gy, r, k, v, w, u, rd, kd, vd, wd, *maybe_ud):
            tangents = (rd, kd, vd, wd,
                        maybe_ud[0] if maybe_ud else jnp.zeros_like(u))
            yd = jax.jvp(lambda *p: wkv6_scan_ref(*p)[0], (r, k, v, w, u),
                         tangents)[1]
            return _vdot32(gy, yd)
        return jnp_base

    interpret = backend == "interpret"
    if has_ud:
        def base(gy, r, k, v, w, u, rd, kd, vd, wd, ud):
            return wkv6_scan_mt_jvps(r, k, v, w, u, rd[None], kd[None],
                                     vd[None], wd[None], gy, ud[None],
                                     interpret=interpret)[0]

        f = custom_vmap(base)

        @f.def_vmap
        def _rule(axis_size, in_batched, gy, r, k, v, w, u, rd, kd, vd, wd,
                  ud):
            if not any(in_batched[:6]):
                rd, kd, vd, wd, ud = _stack_tangents(
                    axis_size, (rd, kd, vd, wd, ud), in_batched[6:])
                return wkv6_scan_mt_jvps(r, k, v, w, u, rd, kd, vd, wd, gy,
                                         ud, interpret=interpret), True
            return _map_fallback(axis_size, in_batched,
                                 (gy, r, k, v, w, u, rd, kd, vd, wd, ud),
                                 base)
    else:
        def base(gy, r, k, v, w, u, rd, kd, vd, wd):
            return wkv6_scan_mt_jvps(r, k, v, w, u, rd[None], kd[None],
                                     vd[None], wd[None], gy,
                                     interpret=interpret)[0]

        f = custom_vmap(base)

        @f.def_vmap
        def _rule(axis_size, in_batched, gy, r, k, v, w, u, rd, kd, vd, wd):
            if not any(in_batched[:6]):
                rd, kd, vd, wd = _stack_tangents(
                    axis_size, (rd, kd, vd, wd), in_batched[6:])
                return wkv6_scan_mt_jvps(r, k, v, w, u, rd, kd, vd, wd, gy,
                                         interpret=interpret), True
            return _map_fallback(axis_size, in_batched,
                                 (gy, r, k, v, w, u, rd, kd, vd, wd), base)
    return f


def wkv6_jvp_contract(gy, r, k, v, w, u, rd, kd, vd, wd, ud=None):
    """jvp partial of a WKV6 mixer site against a known cotangent:
    <gy, ydot>. Batched tangents lower to ONE ``wkv6_scan_mt_jvps``
    epilogue call — no (K, B, S, H, hd) tangent output."""
    fn = _wkv6_contract_fn(ud is not None, get_backend())
    args = (gy, r, k, v, w, u, rd, kd, vd, wd)
    if ud is not None:
        args += (ud,)
    return fn(*args)


@functools.lru_cache(maxsize=None)
def _mamba2_contract_fn(backend: str):
    """Single-tangent <gy, mamba2-ydot>, custom-vmapped onto
    ``mamba2_scan_mt_jvps`` (per-token contraction inside the state walk) on
    kernel backends; jnp mirror materializes-and-contracts (XLA fuses)."""
    if backend not in ("pallas", "interpret"):
        def jnp_base(gy, xdt, bm, cm, dec, xd, bd, cd, dd):
            yd = jax.jvp(lambda *p: mamba2_scan_ref(*p)[0],
                         (xdt, bm, cm, dec), (xd, bd, cd, dd))[1]
            return _vdot32(gy, yd)
        return jnp_base

    interpret = backend == "interpret"

    def base(gy, xdt, bm, cm, dec, xd, bd, cd, dd):
        return mamba2_ops.mamba2_scan_mt_jvps(
            xdt, bm, cm, dec, xd[None], bd[None], cd[None], dd[None], gy,
            interpret=interpret)[0]

    f = custom_vmap(base)

    @f.def_vmap
    def _rule(axis_size, in_batched, gy, xdt, bm, cm, dec, xd, bd, cd, dd):
        if not any(in_batched[:5]):
            xd, bd, cd, dd = _stack_tangents(axis_size, (xd, bd, cd, dd),
                                             in_batched[5:])
            return mamba2_ops.mamba2_scan_mt_jvps(
                xdt, bm, cm, dec, xd, bd, cd, dd, gy,
                interpret=interpret), True
        return _map_fallback(axis_size, in_batched,
                             (gy, xdt, bm, cm, dec, xd, bd, cd, dd), base)
    return f


def mamba2_jvp_contract(gy, xdt, bm, cm, dec, xd, bd, cd, dd):
    """jvp partial of a Mamba2 mixer site against a known cotangent:
    <gy, ydot>. Batched tangents lower to ONE ``mamba2_scan_mt_jvps``
    epilogue call — no (K, B, S, H, hd) tangent output."""
    return _mamba2_contract_fn(get_backend())(gy, xdt, bm, cm, dec, xd, bd,
                                              cd, dd)


@functools.lru_cache(maxsize=None)
def _swa_contract_fn(window, backend: str):
    """Single-tangent <gy, swa-outd>, custom-vmapped onto
    ``swa_attention_mt_jvps`` (per-query-block contraction at the end of
    the online-softmax walk) on kernel backends."""
    if backend not in ("pallas", "interpret"):
        def jnp_base(gy, q, k, v, qd, kd, vd):
            outd = jax.jvp(
                lambda q_, k_, v_: swa_attention_gqa_ref(q_, k_, v_,
                                                         window=window),
                (q, k, v), (qd, kd, vd))[1]
            return _vdot32(gy, outd)
        return jnp_base

    interpret = backend == "interpret"

    def base(gy, q, k, v, qd, kd, vd):
        return swa_attention_mt_jvps(q, k, v, qd[None], kd[None], vd[None],
                                     gy, window=window,
                                     interpret=interpret)[0]

    f = custom_vmap(base)

    @f.def_vmap
    def _rule(axis_size, in_batched, gy, q, k, v, qd, kd, vd):
        if not any(in_batched[:4]):
            qd, kd, vd = _stack_tangents(axis_size, (qd, kd, vd),
                                         in_batched[4:])
            return swa_attention_mt_jvps(q, k, v, qd, kd, vd, gy,
                                         window=window,
                                         interpret=interpret), True
        return _map_fallback(axis_size, in_batched,
                             (gy, q, k, v, qd, kd, vd), base)
    return f


def swa_jvp_contract(gy, q, k, v, qd, kd, vd, window):
    """jvp partial of an SWA attention site against a known cotangent:
    <gy, outd>. Batched tangents lower to ONE ``swa_attention_mt_jvps``
    epilogue call — no (K, B, H, S, hd) tangent output."""
    return _swa_contract_fn(window, get_backend())(gy, q, k, v, qd, kd, vd)
