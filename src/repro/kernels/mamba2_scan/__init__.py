from repro.kernels.mamba2_scan.ops import (
    mamba2_scan,
    mamba2_scan_mt,
    mamba2_scan_mt_jvps,
    mamba2_scan_mt_tangents,
)
from repro.kernels.mamba2_scan.ref import (
    mamba2_scan_mt_jvps_ref,
    mamba2_scan_mt_ref,
    mamba2_scan_ref,
)
