"""Pure-jnp oracle for the Mamba2 state recurrence (matches the scan inside
repro.models.ssm.mamba2_mix with the dt multiplication hoisted out):

    h_t = decay_t * h_{t-1} + xdt_t ⊗ B_t        (per head, h in R^{hd x N})
    y_t = h_t C_t

``xdt`` is the pre-multiplied input xh * dt (the hoist is an exact
elementwise identity, so this reference is bit-identical to the in-scan
multiply the model used before the kernel existed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba2_scan_ref(xdt, bmat, cmat, decay, state=None):
    """xdt: (B,S,H,hd); bmat,cmat: (B,S,N); decay: (B,S,H);
    state: (B,H,hd,N) or None. Returns (y (B,S,H,hd), final_state)."""
    B, S, H, hd = xdt.shape
    N = bmat.shape[-1]
    if state is None:
        state = jnp.zeros((B, H, hd, N), jnp.float32)

    def step(h, xs):
        xt, bt, ct, dct = xs          # (B,H,hd), (B,N), (B,N), (B,H)
        upd = jnp.einsum("bhi,bn->bhin", xt, bt)
        h = dct[..., None, None] * h + upd
        yt = jnp.einsum("bhin,bn->bhi", h, ct)
        return h, yt

    xs = (xdt.transpose(1, 0, 2, 3), bmat.transpose(1, 0, 2),
          cmat.transpose(1, 0, 2), decay.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def mamba2_scan_mt_ref(xdt, bmat, cmat, decay, xdtds, bds, cds, decayds):
    """Multi-tangent oracle: (y, ydots) via T independent ``jax.jvp`` calls
    of the single-tangent reference — the column-by-column semantics the mt
    kernel fuses. Tangents carry a leading T axis."""
    y, _ = mamba2_scan_ref(xdt, bmat, cmat, decay)

    def f(x_, b_, c_, d_):
        return mamba2_scan_ref(x_, b_, c_, d_)[0]

    def one(tangents):
        xd, bd, cd, dd = tangents
        return jax.jvp(f, (xdt, bmat, cmat, decay), (xd, bd, cd, dd))[1]

    yds = jax.vmap(one)((xdtds, bds, cds, decayds))
    return y, yds


def mamba2_scan_mt_jvps_ref(xdt, bmat, cmat, decay, xdtds, bds, cds, decayds,
                            gy):
    """Oracle for the fused jvp-contraction epilogue: materializes all T
    ydots via ``mamba2_scan_mt_ref`` and contracts them against the output
    cotangent ``gy`` (B,S,H,hd) -> (T,) fp32."""
    _, yds = mamba2_scan_mt_ref(xdt, bmat, cmat, decay, xdtds, bds, cds,
                                decayds)
    return jnp.einsum("bshd,tbshd->t", gy.astype(jnp.float32),
                      yds.astype(jnp.float32))
