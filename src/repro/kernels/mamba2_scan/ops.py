"""jit'd wrappers: (B,S,H,hd) <-> (B*H, S, hd) layout + padding of S.

``mamba2_scan``             single-pass primal
``mamba2_scan_mt``          multi-tangent fused pass (y, ydots (T, ...)) —
                            one walk of the primal state serves all T
                            tangents
``mamba2_scan_mt_tangents`` tangent-only variant (the AD dispatch route;
                            its primal output must come from the jnp mirror
                            so jax.linearize can split the custom-JVP rule)
``mamba2_scan_mt_jvps``     fused contraction epilogue: all T scalars
                            <gy, ydot_t> — per-token ydots are contracted
                            against gy inside the kernel and never written
                            to HBM (the cotangent-known estimator route)

Tangent-axis contract: tangents carry a leading T axis — xdtds is
(T, B, S, H, hd), bds/cds are (T, B, S, N), decayds is (T, B, S, H);
ydots come back as (T, B, S, H, hd). B/C streams stay at their (B, S, N)
width end-to-end (the per-head fold happens inside the kernel grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_scan.kernel import (
    mamba2_scan_kernel,
    mamba2_scan_mt_jvps_kernel,
    mamba2_scan_mt_kernel,
)


def _layout(xdt, bmat, cmat, decay, block_s):
    """(B,S,H,hd)->(BH,S,hd) flattening + S padding for the primal operands.
    Padded steps keep the state intact (decay=1, xdt=0); padded y rows are
    dropped."""
    B, S, H, hd = xdt.shape
    bs = min(block_s, S)
    pad = (-S) % bs

    xb = xdt.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    db = decay.astype(jnp.float32).transpose(0, 2, 1).reshape(B * H, S)
    bb = bmat.astype(jnp.float32)
    cb = cmat.astype(jnp.float32)
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad), (0, 0)))
        db = jnp.pad(db, ((0, 0), (0, pad)), constant_values=1.0)
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0)))
        cb = jnp.pad(cb, ((0, 0), (0, pad), (0, 0)))
    return (xb, bb, cb, db), (B, S, H, hd, bs, pad)


def _layout_t(xdtds, bds, cds, decayds, T, B, S, H, hd, pad):
    """Tangent-stack flattening; padded tangent steps are zero (decayd=0,
    xdtd=0, Bd=Cd=0) so every tangent state is preserved too."""
    xdb = xdtds.astype(jnp.float32).transpose(0, 1, 3, 2, 4).reshape(
        T, B * H, S, hd)
    ddb = decayds.astype(jnp.float32).transpose(0, 1, 3, 2).reshape(
        T, B * H, S)
    bdb = bds.astype(jnp.float32)
    cdb = cds.astype(jnp.float32)
    if pad:
        xdb = jnp.pad(xdb, ((0, 0), (0, 0), (0, pad), (0, 0)))
        ddb = jnp.pad(ddb, ((0, 0), (0, 0), (0, pad)))
        bdb = jnp.pad(bdb, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cdb = jnp.pad(cdb, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return xdb, bdb, cdb, ddb


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def mamba2_scan(xdt, bmat, cmat, decay, block_s: int = 64,
                interpret: bool = True):
    """xdt: (B,S,H,hd); bmat,cmat: (B,S,N); decay: (B,S,H). Returns
    y (B,S,H,hd) fp32. Fresh state per call (training semantics); the
    decode path keeps its state outside and uses the jnp reference."""
    (xb, bb, cb, db), (B, S, H, hd, bs, pad) = _layout(
        xdt, bmat, cmat, decay, block_s)
    y = mamba2_scan_kernel(xb, bb, cb, db, n_heads=H, block_s=bs,
                           interpret=interpret)
    return y[:, :S].reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def mamba2_scan_mt(xdt, bmat, cmat, decay, xdtds, bds, cds, decayds,
                   block_s: int = 64, interpret: bool = True):
    """Multi-tangent fused pass -> (y (B,S,H,hd), ydots (T,B,S,H,hd))."""
    T = xdtds.shape[0]
    (xb, bb, cb, db), (B, S, H, hd, bs, pad) = _layout(
        xdt, bmat, cmat, decay, block_s)
    xdb, bdb, cdb, ddb = _layout_t(xdtds, bds, cds, decayds, T, B, S, H, hd,
                                   pad)
    y, yds = mamba2_scan_mt_kernel(xb, bb, cb, db, xdb, bdb, cdb, ddb,
                                   n_heads=H, block_s=bs, interpret=interpret)
    y = y[:, :S].reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    yds = yds[:, :, :S].reshape(T, B, H, S, hd).transpose(0, 1, 3, 2, 4)
    return y, yds


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def mamba2_scan_mt_tangents(xdt, bmat, cmat, decay, xdtds, bds, cds, decayds,
                            block_s: int = 64, interpret: bool = True):
    """Tangent-only fused pass -> ydots (T,B,S,H,hd). Same contract as
    ``mamba2_scan_mt`` but skips the primal y output (the primal state walk
    still runs in-kernel — the tangent recurrence needs h_{t-1})."""
    T = xdtds.shape[0]
    (xb, bb, cb, db), (B, S, H, hd, bs, pad) = _layout(
        xdt, bmat, cmat, decay, block_s)
    xdb, bdb, cdb, ddb = _layout_t(xdtds, bds, cds, decayds, T, B, S, H, hd,
                                   pad)
    yds = mamba2_scan_mt_kernel(xb, bb, cb, db, xdb, bdb, cdb, ddb,
                                n_heads=H, block_s=bs, interpret=interpret,
                                emit_primal=False)
    return yds[:, :, :S].reshape(T, B, H, S, hd).transpose(0, 1, 3, 2, 4)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def mamba2_scan_mt_jvps(xdt, bmat, cmat, decay, xdtds, bds, cds, decayds, gy,
                        block_s: int = 64, interpret: bool = True):
    """Fused jvp-contraction epilogue -> jvps (T,) fp32 = <gy, ydot_t>.

    Same operand contract as ``mamba2_scan_mt`` plus the output cotangent
    gy: (B,S,H,hd); the T tangent outputs are contracted inside the kernel
    and never reach HBM (only (BH, T) per-row partials do)."""
    T = xdtds.shape[0]
    (xb, bb, cb, db), (B, S, H, hd, bs, pad) = _layout(
        xdt, bmat, cmat, decay, block_s)
    xdb, bdb, cdb, ddb = _layout_t(xdtds, bds, cds, decayds, T, B, S, H, hd,
                                   pad)
    # zero-padded gy rows contribute exactly 0 to every partial
    gyb = gy.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    if pad:
        gyb = jnp.pad(gyb, ((0, 0), (0, pad), (0, 0)))
    parts = mamba2_scan_mt_jvps_kernel(xb, bb, cb, db, xdb, bdb, cdb, ddb,
                                       gyb, n_heads=H, block_s=bs,
                                       interpret=interpret)
    return parts.sum(axis=0)
