"""Mamba2 state recurrence — Pallas TPU kernel.

Grid: (B*H, S/block_s). The (hd, N) state matrix lives in VMEM scratch and
persists across the sequential S dimension — the same tangent-state-scratch
design as ``kernels/wkv6_scan`` (which has a data-dependent *elementwise*
decay; Mamba2's decay is a scalar per head and token, so the per-token
update is a scalar-scaled state plus a rank-1 outer product).

B_t / C_t are shared across the H heads of a batch row, so their BlockSpec
index maps fold the flattened (b*H + h) grid row back to batch row b — the
H× repeated-B/C HBM blowup of a naive pre-broadcast never materializes
(same trick as the GQA kv maps in ``kernels/swa_attention``).

The multi-tangent (mt) variant walks T stacked tangent states alongside the
primal:

    hd_t = decayd_t * h_{t-1} + decay_t * hd_{t-1} + xdtd_t B_t^T + xdt_t Bd_t^T
    yd_t = hd_t C_t + h_t Cd_t

one pass over the primal operands serves all T tangents (the batched
K-perturbation estimator's hot loop).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, b_ref, c_ref, d_ref, y_ref, state_scr, *, block_s: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    def step(t, _):
        xt = x_ref[0, t, :]                         # (hd,)
        bt = b_ref[0, t, :]                         # (N,)
        ct = c_ref[0, t, :]
        dct = d_ref[0, t]                           # per-head scalar decay
        h = dct * state_scr[...] + xt[:, None] * bt[None, :]
        y_ref[0, t, :] = (h * ct[None, :]).sum(axis=1).astype(y_ref.dtype)
        state_scr[...] = h
        return ()

    jax.lax.fori_loop(0, block_s, step, ())


def mamba2_scan_kernel(xdt, bmat, cmat, decay, *, n_heads: int,
                       block_s: int = 64, interpret=True):
    """xdt: (BH, S, hd) fp32; bmat,cmat: (B, S, N); decay: (BH, S).
    Returns y (BH, S, hd) fp32. ``n_heads`` folds grid row bh back to batch
    row bh // n_heads for the shared B/C streams."""
    BH, S, hd = xdt.shape
    N = bmat.shape[-1]
    assert S % block_s == 0
    grid = (BH, S // block_s)
    kernel = functools.partial(_kernel, block_s=block_s)
    bc_spec = pl.BlockSpec((1, block_s, N),
                           lambda b, s: (b // n_heads, s, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            bc_spec,
            bc_spec,
            pl.BlockSpec((1, block_s), lambda b, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, bmat, cmat, decay)


def _mt_kernel(x_ref, b_ref, c_ref, d_ref, xd_ref, bd_ref, cd_ref, dd_ref,
               *rest, block_s: int, n_t: int, emit_primal: bool):
    rest = list(rest)
    y_ref = rest.pop(0) if emit_primal else None
    yd_ref = rest.pop(0)
    state_scr, state_d_scr = rest
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)
        state_d_scr[...] = jnp.zeros_like(state_d_scr)

    def step(t, _):
        xt = x_ref[0, t, :]                         # (hd,)
        bt = b_ref[0, t, :]                         # (N,)
        ct = c_ref[0, t, :]
        dct = d_ref[0, t]
        s = state_scr[...]                          # (hd, N)
        h = dct * s + xt[:, None] * bt[None, :]
        if emit_primal:
            y_ref[0, t, :] = (h * ct[None, :]).sum(axis=1).astype(y_ref.dtype)
        # each tangent lane re-reads the pre-update state s and runs the
        # exact op sequence of the T=1 slice on its own scratch row ->
        # stacked ydots are bitwise-equal to T single-tangent passes
        for tau in range(n_t):                      # static unroll over T
            xdt_t = xd_ref[tau, 0, t, :]
            bdt = bd_ref[tau, 0, t, :]
            cdt = cd_ref[tau, 0, t, :]
            ddt = dd_ref[tau, 0, t]
            sd = state_d_scr[tau]                   # (hd, N)
            hd_t = (ddt * s + dct * sd + xdt_t[:, None] * bt[None, :]
                    + xt[:, None] * bdt[None, :])
            ydt = ((hd_t * ct[None, :]).sum(axis=1)
                   + (h * cdt[None, :]).sum(axis=1))
            state_d_scr[tau] = hd_t
            yd_ref[tau, 0, t, :] = ydt.astype(yd_ref.dtype)
        state_scr[...] = h
        return ()

    jax.lax.fori_loop(0, block_s, step, ())


def _mt_jvps_kernel(x_ref, b_ref, c_ref, d_ref, xd_ref, bd_ref, cd_ref,
                    dd_ref, gy_ref, out_ref, state_scr, state_d_scr, acc_j,
                    *, block_s: int, n_s: int, n_t: int):
    """Contraction epilogue: the same primal-state / tangent-state walk as
    ``_mt_kernel``, but each per-token ydot_t is contracted against the
    incoming gy token on the spot — accumulated into a (T, hd) VMEM partial
    — instead of being written to HBM. Only a (1, T) per-row partial leaves
    the kernel at the last sequence block."""
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)
        state_d_scr[...] = jnp.zeros_like(state_d_scr)
        acc_j[...] = jnp.zeros_like(acc_j)

    def step(t, _):
        xt = x_ref[0, t, :]                         # (hd,)
        bt = b_ref[0, t, :]                         # (N,)
        ct = c_ref[0, t, :]
        dct = d_ref[0, t]
        gt = gy_ref[0, t, :].astype(jnp.float32)
        s = state_scr[...]                          # (hd, N)
        h = dct * s + xt[:, None] * bt[None, :]
        # each tangent lane re-reads the pre-update state s and runs the
        # exact op sequence of the T=1 slice on its own scratch row ->
        # stacked partials are bitwise-equal to T single-tangent passes
        for tau in range(n_t):                      # static unroll over T
            xdt_t = xd_ref[tau, 0, t, :]
            bdt = bd_ref[tau, 0, t, :]
            cdt = cd_ref[tau, 0, t, :]
            ddt = dd_ref[tau, 0, t]
            sd = state_d_scr[tau]                   # (hd, N)
            hd_t = (ddt * s + dct * sd + xdt_t[:, None] * bt[None, :]
                    + xt[:, None] * bdt[None, :])
            ydt = ((hd_t * ct[None, :]).sum(axis=1)
                   + (h * cdt[None, :]).sum(axis=1))
            state_d_scr[tau] = hd_t
            acc_j[tau] += gt * ydt                  # contract, never store
        state_scr[...] = h
        return ()

    jax.lax.fori_loop(0, block_s, step, ())

    @pl.when(si == n_s - 1)
    def _finish():
        out_ref[0, :] = acc_j[...].sum(axis=1)


def mamba2_scan_mt_jvps_kernel(xdt, bmat, cmat, decay, xdtds, bds, cds,
                               decayds, gy, *, n_heads: int, block_s: int = 64,
                               interpret=True):
    """Fused jvp-contraction epilogue of the multi-tangent Mamba2
    recurrence: all T scalars <gy, ydot_t> with NO (T, BH, S, hd) tangent
    output — the per-token ydots are contracted against gy in VMEM as the
    state walk produces them. Returns per-row partials (BH, T) fp32, summed
    by the caller (ops.py). Same operand contract as
    ``mamba2_scan_mt_kernel`` plus gy: (BH, S, hd)."""
    BH, S, hd = xdt.shape
    N = bmat.shape[-1]
    T = xdtds.shape[0]
    assert S % block_s == 0
    n_s = S // block_s
    grid = (BH, n_s)
    kernel = functools.partial(_mt_jvps_kernel, block_s=block_s, n_s=n_s,
                               n_t=T)
    seq_spec = pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0))
    seq_spec_t = pl.BlockSpec((T, 1, block_s, hd), lambda b, s: (0, b, s, 0))
    bc_spec = pl.BlockSpec((1, block_s, N),
                           lambda b, s: (b // n_heads, s, 0))
    bcd_spec = pl.BlockSpec((T, 1, block_s, N),
                            lambda b, s: (0, b // n_heads, s, 0))
    in_specs = [
        seq_spec, bc_spec, bc_spec,
        pl.BlockSpec((1, block_s), lambda b, s: (b, s)),
        seq_spec_t, bcd_spec, bcd_spec,
        pl.BlockSpec((T, 1, block_s), lambda b, s: (0, b, s)),
        seq_spec,                                   # gy
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, T), lambda b, s: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32),
                        pltpu.VMEM((T, hd, N), jnp.float32),
                        pltpu.VMEM((T, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, bmat, cmat, decay, xdtds, bds, cds, decayds, gy)


def mamba2_scan_mt_kernel(xdt, bmat, cmat, decay, xdtds, bds, cds, decayds,
                          *, n_heads: int, block_s: int = 64, interpret=True,
                          emit_primal: bool = True):
    """Multi-tangent Mamba2 recurrence: one pass over the primal operands
    produces y plus all T ydots.

    xdt: (BH, S, hd); bmat,cmat: (B, S, N); decay: (BH, S); tangent stacks
    lead with T (xdtds (T,BH,S,hd); bds,cds (T,B,S,N); decayds (T,BH,S)).
    Returns (y (BH,S,hd), ydots (T,BH,S,hd)), or ydots only when
    ``emit_primal=False`` (the AD dispatch tangent route)."""
    BH, S, hd = xdt.shape
    N = bmat.shape[-1]
    T = xdtds.shape[0]
    assert S % block_s == 0
    grid = (BH, S // block_s)
    kernel = functools.partial(_mt_kernel, block_s=block_s, n_t=T,
                               emit_primal=emit_primal)
    seq_spec = pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0))
    seq_spec_t = pl.BlockSpec((T, 1, block_s, hd), lambda b, s: (0, b, s, 0))
    bc_spec = pl.BlockSpec((1, block_s, N),
                           lambda b, s: (b // n_heads, s, 0))
    bcd_spec = pl.BlockSpec((T, 1, block_s, N),
                            lambda b, s: (0, b // n_heads, s, 0))
    in_specs = [
        seq_spec, bc_spec, bc_spec,
        pl.BlockSpec((1, block_s), lambda b, s: (b, s)),
        seq_spec_t, bcd_spec, bcd_spec,
        pl.BlockSpec((T, 1, block_s), lambda b, s: (0, b, s)),
    ]
    out_specs = [seq_spec_t]
    out_shape = [jax.ShapeDtypeStruct((T, BH, S, hd), jnp.float32)]
    if emit_primal:
        out_specs.insert(0, seq_spec)
        out_shape.insert(0, jax.ShapeDtypeStruct((BH, S, hd), jnp.float32))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32),
                        pltpu.VMEM((T, hd, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, bmat, cmat, decay, xdtds, bds, cds, decayds)
    return outs if emit_primal else outs[0]
