"""jit'd wrappers: (B,H,S,hd) <-> (BH,S,hd) reshape and padding of hd to the
lane width.

GQA: k/v stay at their (B,KV,S,hd) width end-to-end — query-head blocks are
mapped to their KV head inside the kernel grid (contiguous groups, matching
``models/attention.py``), so the H/KV× repeated-K/V HBM blowup of the old
``jnp.repeat`` pre-pass never materializes.

``force_pad_hd`` pads hd to a multiple of 128 lanes even under
``interpret=True`` so the CPU oracle exercises the exact padded-lane
dataflow that runs on real TPUs (zero-padded lanes don't affect scores —
both q and k are padded — and the softmax scale keeps the original hd).

``swa_attention_mt`` / ``swa_attention_mt_tangents``: tangents carry a
leading T axis ((T,B,H,S,hd) for qds, (T,B,KV,S,hd) for kds/vds); one pass
over the primal q/k/v produces out plus all T outdots.

``swa_attention_mt_jvps``: fused contraction epilogue — all T scalars
<gy, outd_t> (gy: (B,H,S,hd)); the tangent outputs are contracted against
gy inside the kernel and never written to HBM (cotangent-known estimator
route).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.swa_attention.kernel import (
    swa_attention_kernel,
    swa_attention_mt_jvps_kernel,
    swa_attention_mt_kernel,
)


def _pad_plan(hd, interpret, force_pad_hd):
    return (-hd) % 128 if (not interpret or force_pad_hd) else 0


def _block_plan(S, block_q, block_k):
    """Clamp blocks to S and pick the S padding. When neither clamped block
    divides the other (e.g. S=100 clamps bq to 100 over bk=64), their lcm
    would explode the padding — clamp both to the smaller block instead, so
    the pad is always < max(bq, bk)."""
    bq, bk = min(block_q, S), min(block_k, S)
    if math.lcm(bq, bk) > max(bq, bk):
        bq = bk = min(bq, bk)
    return bq, bk, (-S) % math.lcm(bq, bk)


def _pad_last(t, pad_hd):
    if not pad_hd:
        return t
    return jnp.pad(t, ((0, 0),) * (t.ndim - 1) + ((0, pad_hd),))


def _pad_seq(t, pad_s):
    """Zero-pad S (axis -2). Padded queries are dropped after the call;
    padded keys sit at positions >= S so the causal mask (k_pos <= q_pos)
    never lets a real query attend them."""
    if not pad_s:
        return t
    widths = ((0, 0),) * (t.ndim - 2) + ((0, pad_s), (0, 0))
    return jnp.pad(t, widths)


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret", "force_pad_hd"))
def swa_attention(q, k, v, window=None, block_q=128, block_k=128,
                  interpret=True, force_pad_hd=False):
    """q: (B,H,S,hd); k,v: (B,KV,S,hd) with H % KV == 0. Causal SWA."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    bq, bk, pad_s = _block_plan(S, block_q, block_k)
    Sp = S + pad_s
    pad_hd = _pad_plan(hd, interpret, force_pad_hd)
    q, k, v = (_pad_last(_pad_seq(t, pad_s), pad_hd) for t in (q, k, v))
    out = swa_attention_kernel(
        q.reshape(B * H, Sp, hd + pad_hd),
        k.reshape(B * KV, Sp, hd + pad_hd),
        v.reshape(B * KV, Sp, hd + pad_hd),
        window=window, block_q=bq, block_k=bk, interpret=interpret,
        scale=1.0 / float(hd) ** 0.5, n_heads=H, kv_groups=H // KV)
    out = out.reshape(B, H, Sp, hd + pad_hd)
    return out[:, :, :S, :hd]


def _mt_layout(q, k, v, qds, kds, vds, pad_hd, pad_s):
    B, H, S, hd = q.shape
    KV = k.shape[1]
    T = qds.shape[0]
    hp = hd + pad_hd
    Sp = S + pad_s
    q, k, v, qds, kds, vds = (_pad_last(_pad_seq(t, pad_s), pad_hd)
                              for t in (q, k, v, qds, kds, vds))
    return (q.reshape(B * H, Sp, hp), k.reshape(B * KV, Sp, hp),
            v.reshape(B * KV, Sp, hp), qds.reshape(T, B * H, Sp, hp),
            kds.reshape(T, B * KV, Sp, hp), vds.reshape(T, B * KV, Sp, hp),
            (B, H, KV, S, hd, T))


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret", "force_pad_hd"))
def swa_attention_mt(q, k, v, qds, kds, vds, window=None, block_q=128,
                     block_k=128, interpret=True, force_pad_hd=False):
    """Multi-tangent fused pass -> (out (B,H,S,hd), outds (T,B,H,S,hd))."""
    bq, bk, pad_s = _block_plan(q.shape[-2], block_q, block_k)
    pad_hd = _pad_plan(q.shape[-1], interpret, force_pad_hd)
    qb, kb, vb, qdb, kdb, vdb, (B, H, KV, S, hd, T) = _mt_layout(
        q, k, v, qds, kds, vds, pad_hd, pad_s)
    out, outds = swa_attention_mt_kernel(
        qb, kb, vb, qdb, kdb, vdb, window=window, block_q=bq,
        block_k=bk, interpret=interpret,
        scale=1.0 / float(hd) ** 0.5, n_heads=H, kv_groups=H // KV)
    out = out.reshape(B, H, S + pad_s, hd + pad_hd)[:, :, :S, :hd]
    outds = outds.reshape(T, B, H, S + pad_s, hd + pad_hd)[..., :S, :hd]
    return out, outds


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret", "force_pad_hd"))
def swa_attention_mt_tangents(q, k, v, qds, kds, vds, window=None,
                              block_q=128, block_k=128, interpret=True,
                              force_pad_hd=False):
    """Tangent-only fused pass -> outds (T,B,H,S,hd). Same contract as
    ``swa_attention_mt`` but skips the primal output (the AD dispatch rule
    keeps its primal a pure function of primal inputs for jax.linearize)."""
    bq, bk, pad_s = _block_plan(q.shape[-2], block_q, block_k)
    pad_hd = _pad_plan(q.shape[-1], interpret, force_pad_hd)
    qb, kb, vb, qdb, kdb, vdb, (B, H, KV, S, hd, T) = _mt_layout(
        q, k, v, qds, kds, vds, pad_hd, pad_s)
    outds = swa_attention_mt_kernel(
        qb, kb, vb, qdb, kdb, vdb, window=window, block_q=bq,
        block_k=bk, interpret=interpret,
        scale=1.0 / float(hd) ** 0.5, n_heads=H, kv_groups=H // KV,
        emit_primal=False)
    return outds.reshape(T, B, H, S + pad_s, hd + pad_hd)[..., :S, :hd]


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret", "force_pad_hd"))
def swa_attention_mt_jvps(q, k, v, qds, kds, vds, gy, window=None,
                          block_q=128, block_k=128, interpret=True,
                          force_pad_hd=False):
    """Fused jvp-contraction epilogue -> jvps (T,) fp32 = <gy, outd_t>.

    Same operand contract as ``swa_attention_mt`` plus the output cotangent
    gy: (B,H,S,hd); the T tangent outputs are contracted inside the kernel
    and never reach HBM (only (B*H, S/bq, T) per-block partials do).
    Zero-padded gy rows/lanes contribute exactly 0 to every partial."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    bq, bk, pad_s = _block_plan(S, block_q, block_k)
    pad_hd = _pad_plan(hd, interpret, force_pad_hd)
    qb, kb, vb, qdb, kdb, vdb, (B, H, KV, S, hd, T) = _mt_layout(
        q, k, v, qds, kds, vds, pad_hd, pad_s)
    gyb = _pad_last(_pad_seq(gy, pad_s), pad_hd).reshape(
        B * H, S + pad_s, hd + pad_hd)
    parts = swa_attention_mt_jvps_kernel(
        qb, kb, vb, qdb, kdb, vdb, gyb, window=window, block_q=bq,
        block_k=bk, interpret=interpret,
        scale=1.0 / float(hd) ** 0.5, n_heads=H, kv_groups=H // KV)
    return parts.sum(axis=(0, 1))
