"""jit'd wrapper: (B,H,S,hd) <-> (BH,S,hd) reshape, GQA head repeat, padding
of hd to the lane width."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.swa_attention.kernel import swa_attention_kernel


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret"))
def swa_attention(q, k, v, window=None, block_q=128, block_k=128,
                  interpret=True):
    """q: (B,H,S,hd); k,v: (B,KV,S,hd) with H % KV == 0. Causal SWA."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    bq = min(block_q, S)
    bk = min(block_k, S)
    # pad head_dim to a multiple of 128 lanes if needed (zeros don't affect
    # scores since both q and k are padded)
    pad_hd = (-hd) % 128 if not interpret else 0
    if pad_hd:
        padw = ((0, 0),) * 3 + ((0, pad_hd),)
        q, k, v = (jnp.pad(t, padw) for t in (q, k, v))
    out = swa_attention_kernel(
        q.reshape(B * H, S, hd + pad_hd),
        k.reshape(B * H, S, hd + pad_hd),
        v.reshape(B * H, S, hd + pad_hd),
        window=window, block_q=bq, block_k=bk, interpret=interpret,
        scale=1.0 / float(hd) ** 0.5)
    out = out.reshape(B, H, S, hd + pad_hd)
    return out[..., :hd]
