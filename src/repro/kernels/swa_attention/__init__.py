from repro.kernels.swa_attention.ops import (
    swa_attention,
    swa_attention_mt,
    swa_attention_mt_jvps,
    swa_attention_mt_tangents,
)
from repro.kernels.swa_attention.ref import (
    swa_attention_gqa_ref,
    swa_attention_mt_jvps_ref,
    swa_attention_mt_ref,
    swa_attention_ref,
)
