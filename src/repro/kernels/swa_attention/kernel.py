"""Sliding-window flash attention — Pallas TPU kernel.

Online-softmax over key blocks restricted to the causal band
(q-window, q]. Only ceil((window+block_q)/block_k)+ key blocks are visited
per query block, so HBM traffic and FLOPs are linear in S for SWA layers
(gemma3 local layers, h2o-danube, zamba2's shared attention block).

GQA: q rows are (B*H) while k/v rows stay (B*KV) — the kv BlockSpec index
maps flatten the query head back to its KV group (contiguous groups, head h
reads kv head h // (H//KV)), so repeated K/V are NEVER materialized in HBM.

Grid: (B*H, S/block_q, n_kv_blocks) — kv innermost sequential; the running
max/denominator/accumulator live in VMEM scratch across kv steps.

The multi-tangent variant (``swa_attention_mt_kernel``) pushes T stacked
jvp tangents through the same online-softmax walk: per tangent it carries

    mu_d  = Σ_j e_j sd_j            (softmax-correction numerator)
    acc_d = Σ_j e_j (sd_j v_j + vd_j)

(e_j the unnormalized weights, sd the score tangent), rescaled by the same
alpha as the primal accumulator on every running-max update, and finishes

    outd = acc_d / l - (mu_d / l) * out.

One pass over the primal q/k/v serves all T tangents — the §5.3
"column-by-column jvp" cost collapses into per-tangent VPU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kv_block_index(qi, kv_step, *, block_q, block_k, window, n_k_total,
                    banded):
    """Index of the kv block visited at (query block qi, step kv_step).

    Banded mode: the first visited block covers position qs - window + 1
    (clamped to 0); out-of-band loads are clamped and masked away in-kernel.
    Non-banded mode (full causal, or window so wide the band covers all
    blocks): sweep blocks 0..n_k_total-1.
    """
    if not banded:
        return kv_step
    q_start = qi * block_q
    first = (q_start - (window - 1)) // block_k
    idx = first + kv_step
    return jnp.clip(idx, 0, n_k_total - 1)


def _kv_head_index(bh, *, n_heads, kv_groups):
    """Flat kv row for flat query row ``bh``: head h of H reads kv head
    h // kv_groups (contiguous groups — models/attention.py convention)."""
    if kv_groups == 1:
        return bh
    return (bh // n_heads) * (n_heads // kv_groups) + (bh % n_heads) // kv_groups


def _keep_mask(qi, step, *, block_q, block_k, window, n_k_total, banded):
    """(block_q, block_k) bool mask of valid (q, k) pairs for this step."""
    kv_idx = _kv_block_index(qi, step, block_q=block_q, block_k=block_k,
                             window=window, n_k_total=n_k_total, banded=banded)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kv_idx * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    keep = k_pos <= q_pos
    if window is not None:
        keep = keep & (k_pos > q_pos - window)
    if banded:
        # out-of-range steps are clamped by the index_map and would re-visit
        # an edge block — mask those visits out entirely
        q_start = qi * block_q
        raw_idx = (q_start - (window - 1)) // block_k + step
        keep = keep & (raw_idx >= 0) & (raw_idx < n_k_total)
    return keep


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, block_q, block_k, window, n_kv_steps, n_k_total, scale,
            banded):
    qi = pl.program_id(1)
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                       # (block_q, hd)
    k = k_ref[0]                                       # (block_k, hd)
    v = v_ref[0]

    keep = _keep_mask(qi, step, block_q=block_q, block_k=block_k,
                      window=window, n_k_total=n_k_total, banded=banded)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(keep, s, NEG_INF)

    m_prev = m_scr[...]                                # (block_q, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # explicit keep-gating: exp(NEG_INF - NEG_INF) would be 1, not 0
    p = jnp.where(keep, jnp.exp(s - m_new), 0.0)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(step == n_kv_steps - 1)
    def _finish():
        out = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)
        o_ref[...] = out[None]


def _plan(S, hd, window, block_q, block_k, scale):
    n_k_total = S // block_k
    if window is not None:
        # band spans floor((qs-W+1)/bk) .. floor((qs+bq-1)/bk) inclusive;
        # worst-case count over alignments:
        n_kv_steps = (window - 1 + block_q - 1) // block_k + 2
    else:
        n_kv_steps = n_k_total
    banded = window is not None and n_kv_steps < n_k_total
    if not banded:
        n_kv_steps = n_k_total
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    return n_k_total, n_kv_steps, banded, scale


def swa_attention_kernel(q, k, v, *, window, block_q=128, block_k=128,
                         interpret=True, scale=None, n_heads=None,
                         kv_groups=1):
    """q: (B*H, S, hd); k,v: (B*KV, S, hd) -> out (B*H, S, hd). Causal;
    window may be None. ``scale`` overrides 1/sqrt(hd) (needed when hd was
    zero-padded). GQA (KV < H): pass ``n_heads=H`` and
    ``kv_groups=H // KV`` — kv blocks are indexed per query-head group
    in-grid, never repeated in HBM."""
    BH, S, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0
    n_heads = BH if n_heads is None else n_heads
    n_k_total, n_kv_steps, banded, scale = _plan(S, hd, window, block_q,
                                                 block_k, scale)

    grid = (BH, S // block_q, n_kv_steps)
    kv_map = functools.partial(_kv_block_index, block_q=block_q,
                               block_k=block_k, window=window,
                               n_k_total=n_k_total, banded=banded)
    kv_head = functools.partial(_kv_head_index, n_heads=n_heads,
                                kv_groups=kv_groups)
    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               window=window, n_kv_steps=n_kv_steps,
                               n_k_total=n_k_total, scale=scale,
                               banded=banded)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, s: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, s: (kv_head(b), kv_map(i, s), 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, s: (kv_head(b), kv_map(i, s), 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, s: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def _mt_kernel(q_ref, k_ref, v_ref, qd_ref, kd_ref, vd_ref, *rest,
               block_q, block_k, window, n_kv_steps, n_k_total, scale,
               banded, n_t, emit_primal):
    rest = list(rest)
    o_ref = rest.pop(0) if emit_primal else None
    od_ref = rest.pop(0)
    m_scr, l_scr, acc_scr, mu_d_scr, acc_d_scr = rest
    qi = pl.program_id(1)
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        mu_d_scr[...] = jnp.zeros_like(mu_d_scr)
        acc_d_scr[...] = jnp.zeros_like(acc_d_scr)

    q = q_ref[0]                                       # (block_q, hd)
    k = k_ref[0]                                       # (block_k, hd)
    v = v_ref[0]

    keep = _keep_mask(qi, step, block_q=block_q, block_k=block_k,
                      window=window, n_k_total=n_k_total, banded=banded)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(keep, s, NEG_INF)

    m_prev = m_scr[...]                                # (block_q, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(keep, jnp.exp(s - m_new), 0.0)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    for tau in range(n_t):                             # static unroll over T
        qd = qd_ref[tau, 0]
        kd = kd_ref[tau, 0]
        vd = vd_ref[tau, 0]
        # score tangent; p==0 lanes kill any out-of-band sd values
        sd = (jnp.dot(qd, k.T, preferred_element_type=jnp.float32)
              + jnp.dot(q, kd.T, preferred_element_type=jnp.float32)) * scale
        psd = p * sd
        mu_d_scr[tau] = mu_d_scr[tau] * alpha + psd.sum(axis=-1, keepdims=True)
        acc_d_scr[tau] = acc_d_scr[tau] * alpha + (
            jnp.dot(psd.astype(v.dtype), v, preferred_element_type=jnp.float32)
            + jnp.dot(p.astype(vd.dtype), vd,
                      preferred_element_type=jnp.float32))

    @pl.when(step == n_kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        out = acc_scr[...] / l
        if emit_primal:
            o_ref[...] = out.astype(o_ref.dtype)[None]
        for tau in range(n_t):
            outd = acc_d_scr[tau] / l - (mu_d_scr[tau] / l) * out
            od_ref[tau] = outd.astype(od_ref.dtype)[None]


def _mt_jvps_kernel(q_ref, k_ref, v_ref, qd_ref, kd_ref, vd_ref, gy_ref,
                    out_ref, m_scr, l_scr, acc_scr, mu_d_scr, acc_d_scr,
                    *, block_q, block_k, window, n_kv_steps, n_k_total,
                    scale, banded, n_t):
    """Contraction epilogue: the same online-softmax walk (primal + T
    tangent accumulators) as ``_mt_kernel``, but the per-query-block outd_t
    tiles are contracted against the incoming gy tile at the final kv step
    instead of being written out — only (1, 1, T) per-block partials reach
    HBM."""
    qi = pl.program_id(1)
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        mu_d_scr[...] = jnp.zeros_like(mu_d_scr)
        acc_d_scr[...] = jnp.zeros_like(acc_d_scr)

    q = q_ref[0]                                       # (block_q, hd)
    k = k_ref[0]                                       # (block_k, hd)
    v = v_ref[0]

    keep = _keep_mask(qi, step, block_q=block_q, block_k=block_k,
                      window=window, n_k_total=n_k_total, banded=banded)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(keep, s, NEG_INF)

    m_prev = m_scr[...]                                # (block_q, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(keep, jnp.exp(s - m_new), 0.0)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    for tau in range(n_t):                             # static unroll over T
        qd = qd_ref[tau, 0]
        kd = kd_ref[tau, 0]
        vd = vd_ref[tau, 0]
        sd = (jnp.dot(qd, k.T, preferred_element_type=jnp.float32)
              + jnp.dot(q, kd.T, preferred_element_type=jnp.float32)) * scale
        psd = p * sd
        mu_d_scr[tau] = mu_d_scr[tau] * alpha + psd.sum(axis=-1, keepdims=True)
        acc_d_scr[tau] = acc_d_scr[tau] * alpha + (
            jnp.dot(psd.astype(v.dtype), v, preferred_element_type=jnp.float32)
            + jnp.dot(p.astype(vd.dtype), vd,
                      preferred_element_type=jnp.float32))

    @pl.when(step == n_kv_steps - 1)
    def _finish():
        gy = gy_ref[0].astype(jnp.float32)             # (block_q, hd)
        l = jnp.maximum(l_scr[...], 1e-30)
        out = acc_scr[...] / l
        parts = []
        for tau in range(n_t):
            outd = acc_d_scr[tau] / l - (mu_d_scr[tau] / l) * out
            parts.append(jnp.sum(gy * outd))           # contract, never store
        out_ref[0, 0, :] = jnp.stack(parts)


def swa_attention_mt_jvps_kernel(q, k, v, qds, kds, vds, gy, *, window,
                                 block_q=128, block_k=128, interpret=True,
                                 scale=None, n_heads=None, kv_groups=1):
    """Fused jvp-contraction epilogue of multi-tangent flash SWA: all T
    scalars <gy, outd_t> with NO (T, B*H, S, hd) tangent output. Same
    operand contract as ``swa_attention_mt_kernel`` plus gy: (B*H, S, hd);
    returns per-block partials (B*H, S/block_q, T) fp32, summed by the
    caller (ops.py)."""
    BH, S, hd = q.shape
    T = qds.shape[0]
    assert S % block_q == 0 and S % block_k == 0
    n_heads = BH if n_heads is None else n_heads
    n_k_total, n_kv_steps, banded, scale = _plan(S, hd, window, block_q,
                                                 block_k, scale)

    grid = (BH, S // block_q, n_kv_steps)
    kv_map = functools.partial(_kv_block_index, block_q=block_q,
                               block_k=block_k, window=window,
                               n_k_total=n_k_total, banded=banded)
    kv_head = functools.partial(_kv_head_index, n_heads=n_heads,
                                kv_groups=kv_groups)
    kernel = functools.partial(_mt_jvps_kernel, block_q=block_q,
                               block_k=block_k, window=window,
                               n_kv_steps=n_kv_steps, n_k_total=n_k_total,
                               scale=scale, banded=banded, n_t=T)
    q_spec = pl.BlockSpec((1, block_q, hd), lambda b, i, s: (b, i, 0))
    kv_spec = pl.BlockSpec((1, block_k, hd),
                           lambda b, i, s: (kv_head(b), kv_map(i, s), 0))
    qd_spec = pl.BlockSpec((T, 1, block_q, hd), lambda b, i, s: (0, b, i, 0))
    kvd_spec = pl.BlockSpec(
        (T, 1, block_k, hd),
        lambda b, i, s: (0, kv_head(b), kv_map(i, s), 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec, qd_spec, kvd_spec, kvd_spec,
                  q_spec],
        out_specs=pl.BlockSpec((1, 1, T), lambda b, i, s: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S // block_q, T), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((T, block_q, 1), jnp.float32),
            pltpu.VMEM((T, block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, qds, kds, vds, gy)


def swa_attention_mt_kernel(q, k, v, qds, kds, vds, *, window, block_q=128,
                            block_k=128, interpret=True, scale=None,
                            n_heads=None, kv_groups=1, emit_primal=True):
    """Multi-tangent flash SWA: q/k/v as in ``swa_attention_kernel``;
    qds: (T, B*H, S, hd), kds/vds: (T, B*KV, S, hd). Returns
    (out (B*H,S,hd), outds (T,B*H,S,hd)), or outds only when
    ``emit_primal=False`` (AD dispatch tangent route — the primal
    online-softmax walk still runs; the tangents need p and l)."""
    BH, S, hd = q.shape
    T = qds.shape[0]
    assert S % block_q == 0 and S % block_k == 0
    n_heads = BH if n_heads is None else n_heads
    n_k_total, n_kv_steps, banded, scale = _plan(S, hd, window, block_q,
                                                 block_k, scale)

    grid = (BH, S // block_q, n_kv_steps)
    kv_map = functools.partial(_kv_block_index, block_q=block_q,
                               block_k=block_k, window=window,
                               n_k_total=n_k_total, banded=banded)
    kv_head = functools.partial(_kv_head_index, n_heads=n_heads,
                                kv_groups=kv_groups)
    kernel = functools.partial(_mt_kernel, block_q=block_q, block_k=block_k,
                               window=window, n_kv_steps=n_kv_steps,
                               n_k_total=n_k_total, scale=scale,
                               banded=banded, n_t=T, emit_primal=emit_primal)
    q_spec = pl.BlockSpec((1, block_q, hd), lambda b, i, s: (b, i, 0))
    kv_spec = pl.BlockSpec((1, block_k, hd),
                           lambda b, i, s: (kv_head(b), kv_map(i, s), 0))
    qd_spec = pl.BlockSpec((T, 1, block_q, hd), lambda b, i, s: (0, b, i, 0))
    kvd_spec = pl.BlockSpec(
        (T, 1, block_k, hd),
        lambda b, i, s: (0, kv_head(b), kv_map(i, s), 0))
    out_specs = [qd_spec]
    out_shape = [jax.ShapeDtypeStruct((T, BH, S, hd), q.dtype)]
    if emit_primal:
        out_specs.insert(0, q_spec)
        out_shape.insert(0, jax.ShapeDtypeStruct((BH, S, hd), q.dtype))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec, qd_spec, kvd_spec, kvd_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((T, block_q, 1), jnp.float32),
            pltpu.VMEM((T, block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, qds, kds, vds)
    return outs if emit_primal else outs[0]
