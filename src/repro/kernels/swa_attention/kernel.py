"""Sliding-window flash attention — Pallas TPU kernel.

Online-softmax over key blocks restricted to the causal band
(q-window, q]. Only ceil((window+block_q)/block_k)+ key blocks are visited
per query block, so HBM traffic and FLOPs are linear in S for SWA layers
(gemma3 local layers, h2o-danube, zamba2's shared attention block).

Grid: (B*H, S/block_q, n_kv_blocks) — kv innermost sequential; the running
max/denominator/accumulator live in VMEM scratch across kv steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kv_block_index(qi, kv_step, *, block_q, block_k, window, n_k_total,
                    banded):
    """Index of the kv block visited at (query block qi, step kv_step).

    Banded mode: the first visited block covers position qs - window + 1
    (clamped to 0); out-of-band loads are clamped and masked away in-kernel.
    Non-banded mode (full causal, or window so wide the band covers all
    blocks): sweep blocks 0..n_k_total-1.
    """
    if not banded:
        return kv_step
    q_start = qi * block_q
    first = (q_start - (window - 1)) // block_k
    idx = first + kv_step
    return jnp.clip(idx, 0, n_k_total - 1)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, block_q, block_k, window, n_kv_steps, n_k_total, scale,
            banded):
    qi = pl.program_id(1)
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                       # (block_q, hd)
    k = k_ref[0]                                       # (block_k, hd)
    v = v_ref[0]

    # recompute which absolute kv block we loaded (same formula as index_map)
    kv_idx = _kv_block_index(qi, step, block_q=block_q, block_k=block_k,
                             window=window, n_k_total=n_k_total, banded=banded)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kv_idx * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    keep = k_pos <= q_pos
    if window is not None:
        keep = keep & (k_pos > q_pos - window)
    if banded:
        # out-of-range steps are clamped by the index_map and would re-visit
        # an edge block — mask those visits out entirely
        q_start = qi * block_q
        raw_idx = (q_start - (window - 1)) // block_k + step
        keep = keep & (raw_idx >= 0) & (raw_idx < n_k_total)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(keep, s, NEG_INF)

    m_prev = m_scr[...]                                # (block_q, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # explicit keep-gating: exp(NEG_INF - NEG_INF) would be 1, not 0
    p = jnp.where(keep, jnp.exp(s - m_new), 0.0)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(step == n_kv_steps - 1)
    def _finish():
        out = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)
        o_ref[...] = out[None]


def swa_attention_kernel(q, k, v, *, window, block_q=128, block_k=128,
                         interpret=True, scale=None):
    """q,k,v: (BH, S, hd) -> out (BH, S, hd). Causal; window may be None.
    ``scale`` overrides 1/sqrt(hd) (needed when hd was zero-padded)."""
    BH, S, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0
    n_k_total = S // block_k
    if window is not None:
        # band spans floor((qs-W+1)/bk) .. floor((qs+bq-1)/bk) inclusive;
        # worst-case count over alignments:
        n_kv_steps = (window - 1 + block_q - 1) // block_k + 2
    else:
        n_kv_steps = n_k_total
    banded = window is not None and n_kv_steps < n_k_total
    if not banded:
        n_kv_steps = n_k_total
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5

    grid = (BH, S // block_q, n_kv_steps)
    kv_map = functools.partial(_kv_block_index, block_q=block_q,
                               block_k=block_k, window=window,
                               n_k_total=n_k_total, banded=banded)
    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               window=window, n_kv_steps=n_kv_steps,
                               n_k_total=n_k_total, scale=scale,
                               banded=banded)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, s: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, s: (b, kv_map(i, s), 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, s: (b, kv_map(i, s), 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, s: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
