"""Pure-jnp oracle: causal sliding-window attention.

Token q attends to keys k with  q-window < k <= q  (window=None -> full
causal). Matches repro.models.attention semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_attention_ref(q, k, v, window=None):
    """q,k,v: (B, H, S, hd) -> (B, H, S, hd)."""
    S, hd = q.shape[-2], q.shape[-1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    keep = kpos <= qpos
    if window is not None:
        keep = keep & (kpos > qpos - window)
    scores = jnp.where(keep, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)


def swa_attention_gqa_ref(q, k, v, window=None):
    """GQA oracle without repeated K/V: q (B,H,S,hd); k,v (B,KV,S,hd) with
    contiguous query-head groups (head h reads kv head h // (H//KV) — the
    models/attention.py convention)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    rep = H // KV
    qg = q.reshape(B, KV, rep, S, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.einsum("bgrqd,bgkd->bgrqk", qg, k).astype(jnp.float32) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    keep = kpos <= qpos
    if window is not None:
        keep = keep & (kpos > qpos - window)
    scores = jnp.where(keep, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(q.dtype), v)
    return out.reshape(B, H, S, hd)


def swa_attention_mt_ref(q, k, v, qds, kds, vds, window=None):
    """Multi-tangent oracle: (out, outds) via T independent ``jax.jvp``
    calls of the GQA reference — the column-by-column semantics the mt
    kernel fuses. Tangents carry a leading T axis."""
    out = swa_attention_gqa_ref(q, k, v, window=window)

    def one(tangents):
        qd, kd, vd = tangents
        return jax.jvp(lambda q_, k_, v_: swa_attention_gqa_ref(
            q_, k_, v_, window=window), (q, k, v), (qd, kd, vd))[1]

    outds = jax.vmap(one)((qds, kds, vds))
    return out, outds


def swa_attention_mt_jvps_ref(q, k, v, qds, kds, vds, gy, window=None):
    """Oracle for the fused jvp-contraction epilogue: materializes all T
    outdots via ``swa_attention_mt_ref`` and contracts them against the
    output cotangent ``gy`` (B,H,S,hd) -> (T,) fp32."""
    _, outds = swa_attention_mt_ref(q, k, v, qds, kds, vds, window=window)
    return jnp.einsum("bhsd,tbhsd->t", gy.astype(jnp.float32),
                      outds.astype(jnp.float32))
