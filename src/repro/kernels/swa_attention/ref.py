"""Pure-jnp oracle: causal sliding-window attention.

Token q attends to keys k with  q-window < k <= q  (window=None -> full
causal). Matches repro.models.attention semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_attention_ref(q, k, v, window=None):
    """q,k,v: (B, H, S, hd) -> (B, H, S, hd)."""
    S, hd = q.shape[-2], q.shape[-1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    keep = kpos <= qpos
    if window is not None:
        keep = keep & (kpos > qpos - window)
    scores = jnp.where(keep, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)
