from repro.peft.lora import (
    count_trainable,
    default_lora_targets,
    init_peft,
    peft_layer_groups,
    target_dims,
)
