"""PEFT parameter trees (LoRA default; IA3 / BitFit / classifier-only also
supported, matching the paper's ablation in Appendix G).

The PEFT tree is structurally separate from the frozen base:

    peft = {
      "layers":     {target: {"A": (L, din, r), "B": (L, r, dout)}},   # stacked
      "enc_layers": {...},                      # whisper encoder (if any)
      "shared":     {target: {"A": (din,r), "B": (r,dout)}},           # zamba2
      "head":       {"w": (D, C), "b": (C,)},   # classifier, trained by ALL clients
    }

Only this tree is trainable / perturbed / communicated. SPRY's layer-to-
client splitting enumerates (group, target, layer) units over it — see
core/assignment.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def default_lora_targets(cfg):
    if cfg.family == "ssm":           # rwkv6 projections
        return ("wr", "wv")
    if cfg.family == "hybrid":        # mamba2 projections
        return ("in_proj", "out_proj")
    return ("wq", "wv")


def target_dims(cfg, target: str):
    """(din, dout) of the matrix a LoRA pair adapts."""
    d, hd = cfg.d_model, cfg.hd
    table = {
        "wq": (d, cfg.n_heads * hd),
        "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd),
        "wo": (cfg.n_heads * hd, d),
        "wi": (d, cfg.d_ff),
        "wg": (d, cfg.d_ff),
        "wd": (cfg.d_ff, d),
        # rwkv6
        "wr": (d, d),
        # mamba2
        "in_proj": (d, 2 * (cfg.ssm.expand * d) if cfg.ssm else 2 * d),
        "out_proj": ((cfg.ssm.expand * d) if cfg.ssm else d, d),
    }
    if cfg.family == "ssm" and target in ("wk", "wv", "wo"):
        return (d, d)
    return table[target]


def _lora_pair(key, din, dout, r, stack=()):
    ka, kb = jax.random.split(key)
    return {
        "A": dense_init(ka, stack + (din, r), dtype=jnp.float32),
        "B": jnp.zeros(stack + (r, dout), jnp.float32),   # B=0 -> identity at init
    }


def peft_layer_groups(cfg):
    """(group_name, n_layers) pairs that carry stacked per-layer PEFT params."""
    groups = [("layers", cfg.n_layers)]
    if cfg.encoder_layers:
        groups.append(("enc_layers", cfg.encoder_layers))
    return groups


def init_peft(cfg, key, spry_cfg):
    kind = spry_cfg.peft
    targets = spry_cfg.lora_targets or default_lora_targets(cfg)
    # for ssm/hybrid families, remap the generic defaults
    if cfg.family in ("ssm", "hybrid") and targets == ("wq", "wv"):
        targets = default_lora_targets(cfg)
    r = spry_cfg.lora_rank
    keys = jax.random.split(key, 8)

    peft = {}
    if kind == "lora":
        for gi, (group, L) in enumerate(peft_layer_groups(cfg)):
            gtree = {}
            tkeys = jax.random.split(keys[gi], len(targets))
            for tk, t in zip(tkeys, targets):
                din, dout = target_dims(cfg, t)
                gtree[t] = _lora_pair(tk, din, dout, r, stack=(L,))
            peft[group] = gtree
        if cfg.family == "hybrid":
            # the shared attention block gets one unstacked LoRA pair set
            stree = {}
            tkeys = jax.random.split(keys[3], 2)
            for tk, t in zip(tkeys, ("wq", "wv")):
                din, dout = target_dims(cfg, t)
                stree[t] = _lora_pair(tk, din, dout, r)
            peft["shared"] = stree
    elif kind == "ia3":
        # IA3: elementwise rescaling vectors on k/v/ffn activations.
        for group, L in peft_layer_groups(cfg):
            peft[group] = {
                "ia3_kv": {"s": jnp.ones((L, cfg.n_kv_heads * cfg.hd), jnp.float32)},
                "ia3_ff": {"s": jnp.ones((L, cfg.d_ff), jnp.float32)},
            }
    elif kind == "bitfit":
        for group, L in peft_layer_groups(cfg):
            peft[group] = {
                "bias1": {"b": jnp.zeros((L, cfg.d_model), jnp.float32)},
                "bias2": {"b": jnp.zeros((L, cfg.d_model), jnp.float32)},
            }
    elif kind == "classifier_only":
        pass
    else:
        raise ValueError(f"unknown peft kind {kind!r}")

    if cfg.n_classes:
        kw, _ = jax.random.split(keys[7])
        peft["head"] = {
            "w": dense_init(kw, (cfg.d_model, cfg.n_classes), dtype=jnp.float32),
            "b": jnp.zeros((cfg.n_classes,), jnp.float32),
        }
    return peft


def count_trainable(peft) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(peft))
