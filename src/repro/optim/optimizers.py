"""Pure-JAX first-order optimizers (optax is not available in this environment).

An ``Optimizer`` is a pair of pure functions over pytrees:

    state  = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

matching the optax calling convention so client-side (SGD/Adam/AdamW) and
server-side (FedAdam/FedYogi built on these) code composes uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_zeros_like


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# SGD / momentum
# ---------------------------------------------------------------------------

def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"m": tree_zeros_like(params)}

    def update(grads, state, params=None):
        m = jax.tree.map(lambda mi, g: beta * mi + g, state["m"], grads)
        if nesterov:
            upd = jax.tree.map(lambda mi, g: -lr * (beta * mi + g), m, grads)
        else:
            upd = jax.tree.map(lambda mi: -lr * mi, m)
        return upd, {"m": m}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adam family
# ---------------------------------------------------------------------------

class _AdamState(NamedTuple):
    count: jnp.ndarray
    m: Any
    v: Any


def _adam_core(lr, b1, b2, eps, weight_decay=0.0, second_moment="adam"):
    """Shared Adam/AdamW/Yogi machinery.

    second_moment:
      'adam': v <- b2*v + (1-b2)*g^2
      'yogi': v <- v - (1-b2)*sign(v - g^2)*g^2      (Zaheer et al., 2018)
    """

    def init(params):
        return _AdamState(jnp.zeros([], jnp.int32), tree_zeros_like(params),
                          tree_zeros_like(params))

    def update(grads, state, params=None):
        count = state.count + 1
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g, state.m, grads)
        if second_moment == "adam":
            v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * (g * g),
                             state.v, grads)
        else:  # yogi
            v = jax.tree.map(
                lambda vi, g: vi - (1 - b2) * jnp.sign(vi - g * g) * (g * g),
                state.v, grads)
        # bias correction
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(mi, vi, p):
            mhat = mi / c1
            vhat = vi / c2
            step = -lr * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step = step - lr * weight_decay * p
            return step

        if weight_decay:
            updates = jax.tree.map(upd, m, v, params)
        else:
            updates = jax.tree.map(lambda mi, vi: upd(mi, vi, None), m, v)
        return updates, _AdamState(count, m, v)

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return _adam_core(lr, b1, b2, eps)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay=weight_decay)


def yogi(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-3) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, second_moment="yogi")


# ---------------------------------------------------------------------------
# Gradient transforms
# ---------------------------------------------------------------------------

def clip_by_global_norm(grads, max_norm: float):
    from repro.utils.pytree import tree_norm

    norm = tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm
