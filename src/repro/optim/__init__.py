from repro.optim.optimizers import (
    Optimizer,
    sgd,
    momentum,
    adam,
    adamw,
    yogi,
    clip_by_global_norm,
)
